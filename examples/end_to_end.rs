//! End-to-end driver: the full system on a real small workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```
//!
//! Exercises every layer in one run:
//!
//! 1. **L3 substrates** — synthesize a CPDB-scale graph database and a
//!    splice-scale transaction database;
//! 2. **the paper's method** — compute the regularization path with SPP
//!    and with the boosting baseline on both, verifying they reach
//!    identical optima (certified gaps < 1e-6) and reporting the
//!    paper's headline metric: SPP's time and traversed-node advantage;
//! 3. **L1/L2 via PJRT** — if `artifacts/` exists, re-run the SPP path
//!    with the AOT JAX/Pallas FISTA engine for the restricted solves
//!    and cross-check the SPPC Pallas kernel against the Rust fold.
//!
//! The output of this driver is recorded in EXPERIMENTS.md §End-to-end.

use spp::coordinator::{report, run_experiment, ExperimentSpec, Method};
use spp::path::PathConfig;
use spp::solver::Task;

fn main() {
    let cfg = PathConfig {
        n_lambdas: 20,
        lambda_min_ratio: 0.05,
        ..PathConfig::default()
    };
    let workloads = [("cpdb", 0.3, 4usize), ("splice", 0.2, 3usize)];

    println!("== SPP vs boosting: full paths on two database kinds ==\n");
    let mut pairs = Vec::new();
    for (dataset, scale, maxpat) in workloads {
        let mut results = Vec::new();
        for method in [Method::Spp, Method::Boosting] {
            let spec = ExperimentSpec {
                dataset: dataset.into(),
                scale,
                maxpat,
                method,
                cfg: PathConfig { maxpat, ..cfg },
            };
            let r = run_experiment(&spec).expect("experiment");
            assert!(
                r.max_gap <= 2e-6,
                "{dataset}/{method:?}: uncertified optimum (gap {})",
                r.max_gap
            );
            println!("{}", report::time_row(&r));
            results.push(r);
        }
        // identical optima along the whole path
        let (s, b) = (&results[0], &results[1]);
        for (pa, pb) in s.path.points.iter().zip(&b.path.points) {
            let l1a: f64 = pa.active.iter().map(|(_, w)| w.abs()).sum();
            let l1b: f64 = pb.active.iter().map(|(_, w)| w.abs()).sum();
            assert!(
                (l1a - l1b).abs() < 1e-3 * (1.0 + l1a),
                "{dataset}: optima diverge at λ={}",
                pa.lambda
            );
        }
        println!("{}\n", report::speedup_row(s, b));
        pairs.push((dataset, results));
    }

    println!("== headline ==");
    for (dataset, results) in &pairs {
        let (s, b) = (&results[0], &results[1]);
        println!(
            "{dataset}: SPP solves the identical 20-λ path {:.2}x faster, traversing {:.1}x fewer nodes ({} vs {})",
            b.total_secs / s.total_secs.max(1e-9),
            b.traverse_nodes as f64 / s.traverse_nodes.max(1) as f64,
            s.traverse_nodes,
            b.traverse_nodes
        );
    }

    // 3) the AOT JAX/Pallas engines via PJRT, if artifacts are present
    let dir = spp::runtime::default_artifact_dir();
    if !dir.join("manifest.txt").is_file() {
        println!("\n(artifacts not built — skipping the PJRT leg; run `make artifacts`)");
        return;
    }
    println!("\n== PJRT leg: AOT JAX/Pallas engines ==");
    let rt = match spp::runtime::PjrtRuntime::cpu(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            // e.g. a default build without the `pjrt` feature
            println!("(skipping the PJRT leg: {e})");
            return;
        }
    };
    println!("platform: {}", rt.platform());

    // SPPC Pallas kernel cross-check on live screening data
    use spp::screening::fold_weights;
    use spp::testutil::SplitMix64;
    let mut rng = SplitMix64::new(2016);
    let n = 648;
    let y: Vec<f64> = (0..n).map(|_| if rng.coin(0.5) { 1.0 } else { -1.0 }).collect();
    let theta: Vec<f64> = (0..n).map(|_| rng.gauss() * 0.15).collect();
    let (wpos, wneg) = fold_weights(Task::Classification, &y, &theta);
    let supports: Vec<Vec<u32>> = (0..512)
        .map(|_| {
            let m = rng.range(1, 80);
            rng.sample_distinct(n, m).into_iter().map(|i| i as u32).collect()
        })
        .collect();
    let scorer = spp::runtime::XlaSppcScorer::new(&rt, n).expect("scorer");
    let t = std::time::Instant::now();
    let scores = scorer.score(&supports, &wpos, &wneg, 0.4).expect("score");
    let dt = t.elapsed().as_secs_f64();
    let mut max_err = 0.0f64;
    for (sup, sc) in supports.iter().zip(&scores) {
        let pos: f64 = sup.iter().map(|&i| wpos[i as usize]).sum();
        let neg: f64 = sup.iter().map(|&i| wneg[i as usize]).sum();
        let want = pos.max(-neg) + 0.4 * (sup.len() as f64).sqrt();
        max_err = max_err.max((sc.sppc - want).abs());
    }
    assert!(max_err < 1e-3, "Pallas SPPC kernel disagrees: {max_err}");
    println!(
        "SPPC Pallas kernel: 512 patterns scored in {:.1} ms, max |err| {:.1e} vs Rust fold",
        1e3 * dt,
        max_err
    );

    // full path with the XLA FISTA restricted solver — dispatched
    // through the registry visitor, so this leg is substrate-agnostic
    // (swap the preset name and the same code runs on graphs or
    // sequences)
    use spp::data::registry::{self, lookup, RegistrySubstrate, SubstrateVisitor};
    use spp::path::{compute_path_spp, compute_path_spp_with, PathResult, RestrictedSolver};
    use spp::runtime::engine::XlaRestricted;

    struct BothEngines<'a> {
        task: Task,
        cfg: &'a PathConfig,
        solver: &'a dyn RestrictedSolver,
    }
    impl SubstrateVisitor for BothEngines<'_> {
        type Out = spp::Result<(PathResult, PathResult)>;
        fn visit<S: RegistrySubstrate>(self, db: &S, y: &[f64]) -> Self::Out {
            let rust = compute_path_spp(db, y, self.task, self.cfg)?;
            let xla = compute_path_spp_with(db, y, self.task, self.cfg, self.solver)?;
            Ok((rust, xla))
        }
    }

    let task = registry::require_info("splice").unwrap().task;
    let data = lookup("splice", 0.1).unwrap();
    let small_cfg = PathConfig {
        n_lambdas: 8,
        lambda_min_ratio: 0.1,
        maxpat: 2,
        ..PathConfig::default()
    };
    let xla_solver = XlaRestricted::new(&rt);
    let (rust_path, xla_path) = data
        .visit(BothEngines {
            task,
            cfg: &small_cfg,
            solver: &xla_solver,
        })
        .unwrap();
    for (a, b) in rust_path.points.iter().zip(&xla_path.points) {
        let l1a: f64 = a.active.iter().map(|(_, w)| w.abs()).sum();
        let l1b: f64 = b.active.iter().map(|(_, w)| w.abs()).sum();
        assert!(
            (l1a - l1b).abs() < 1e-3 * (1.0 + l1a),
            "xla path diverges at λ={}",
            a.lambda
        );
    }
    println!(
        "XLA FISTA engine: 8-λ splice path identical to the CD engine ({} CD fallbacks)",
        xla_solver.fallbacks.get()
    );
    println!("\nend_to_end OK");
}
