//! Item-set regression with model selection — the paper's dna scenario.
//!
//! ```bash
//! cargo run --release --example itemset_regression
//! ```
//!
//! A dna-scale regression dataset with planted predictive conjunctions;
//! train/validation split, SPP path on the training half, validation
//! MSE along the path, and a comparison of the chosen model's patterns
//! against the planted rules.

use spp::data::synth_itemsets::{contains_all, generate, ItemsetSynthConfig};
use spp::data::Transactions;
use spp::path::{compute_path_spp, PathConfig};
use spp::solver::Task;

fn main() {
    let cfg = ItemsetSynthConfig::preset_dna(101).scaled(0.15);
    let data = generate(&cfg);
    let n = data.db.len();
    let n_train = n * 3 / 4;
    let train = Transactions {
        n_items: data.db.n_items,
        items: data.db.items[..n_train].to_vec(),
    };
    let test_rows = &data.db.items[n_train..];
    let (y_train, y_test) = data.y.split_at(n_train);
    println!(
        "dna-scale regression: {} train / {} test records, {} items",
        n_train,
        n - n_train,
        data.db.n_items
    );

    let path_cfg = PathConfig {
        n_lambdas: 30,
        lambda_min_ratio: 0.03,
        maxpat: 3,
        ..PathConfig::default()
    };
    let path = compute_path_spp(&train, y_train, Task::Regression, &path_cfg).unwrap();
    println!(
        "path computed: λ_max = {:.3}, {} nodes, {:.2}s\n",
        path.lambda_max,
        path.total_nodes(),
        path.total_secs()
    );

    // validation sweep
    println!(" {:>10} {:>7} {:>10}", "λ", "active", "val-MSE");
    let mut best: Option<(f64, f64, usize)> = None;
    for (k, p) in path.points.iter().enumerate() {
        let feats: Vec<(&[u32], f64)> = p
            .active
            .iter()
            .map(|(pat, w)| (pat.as_itemset().expect("itemset path"), *w))
            .collect();
        let mse: f64 = test_rows
            .iter()
            .zip(y_test)
            .map(|(row, &yi)| {
                let pred: f64 = p.b
                    + feats
                        .iter()
                        .filter(|(items, _)| contains_all(row, items))
                        .map(|(_, w)| w)
                        .sum::<f64>();
                (pred - yi) * (pred - yi)
            })
            .sum::<f64>()
            / y_test.len() as f64;
        if k % 3 == 0 {
            println!(" {:>10.4} {:>7} {:>10.4}", p.lambda, p.active.len(), mse);
        }
        if best.map_or(true, |(_, m, _)| mse < m) {
            best = Some((p.lambda, mse, k));
        }
    }
    let (lam, mse, k) = best.unwrap();
    let var: f64 = {
        let mean = y_test.iter().sum::<f64>() / y_test.len() as f64;
        y_test.iter().map(|&v| (v - mean) * (v - mean)).sum::<f64>() / y_test.len() as f64
    };
    println!(
        "\nselected λ = {:.4}: val MSE {:.4} (variance baseline {:.4}, R² = {:.2})",
        lam,
        mse,
        var,
        1.0 - mse / var
    );

    // did we recover planted structure?
    let chosen = &path.points[k];
    println!("\ntop patterns at the selected λ:");
    let mut active = chosen.active.clone();
    active.sort_by(|a, b| b.1.abs().partial_cmp(&a.1.abs()).unwrap());
    for (pat, w) in active.iter().take(8) {
        println!("  {:+.3}  {}", w, pat.display());
    }
    println!("\nplanted rules:");
    for r in &data.rules {
        println!("  {:+.2}  {:?}", r.weight, r.items);
    }
    assert!(mse < var, "model failed to beat the variance baseline");
}
