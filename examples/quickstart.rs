//! Quickstart: mine predictive item-sets with Safe Pattern Pruning.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Generates a small transaction dataset with planted predictive
//! conjunctions, computes the SPP regularization path, and prints the
//! discovered patterns at a mid-path λ.

use spp::data::synth_itemsets::{generate, ItemsetSynthConfig};
use spp::path::{compute_path_spp, PathConfig};
use spp::screening::Database;
use spp::solver::Task;

fn main() {
    // 1. Data: 300 transactions over 40 items; y is driven by a few
    //    planted item conjunctions (the "patterns" we want back).
    let mut cfg = ItemsetSynthConfig::tiny(7, false);
    cfg.n = 300;
    cfg.d = 40;
    cfg.avg_items = 8.0;
    let data = generate(&cfg);
    println!("planted rules:");
    for r in &data.rules {
        println!("  {:?} (weight {:+.2})", r.items, r.weight);
    }

    // 2. The SPP path: 30 λ values, patterns up to 3 items.
    let path_cfg = PathConfig {
        n_lambdas: 30,
        lambda_min_ratio: 0.05,
        maxpat: 3,
        ..PathConfig::default()
    };
    let db = Database::Itemsets(&data.db);
    let path = compute_path_spp(&db, &data.y, Task::Regression, &path_cfg);

    println!(
        "\npath: λ_max = {:.3}, {} λ values, {} tree nodes visited, {:.3}s total",
        path.lambda_max,
        path.points.len(),
        path.total_nodes(),
        path.total_secs()
    );

    // 3. Inspect the model mid-path.
    let mid = &path.points[path.points.len() / 2];
    println!(
        "\nmodel at λ = {:.4} ({} active patterns, intercept {:+.3}):",
        mid.lambda,
        mid.active.len(),
        mid.b
    );
    let mut active = mid.active.clone();
    active.sort_by(|a, b| b.1.abs().partial_cmp(&a.1.abs()).unwrap());
    for (pattern, w) in active.iter().take(10) {
        println!("  {:+.3}  {}", w, pattern.display());
    }
    println!("\n(compare the top patterns with the planted rules above)");
}
