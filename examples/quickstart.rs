//! Quickstart: mine predictive item-sets with Safe Pattern Pruning.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Generates a small transaction dataset with planted predictive
//! conjunctions, fits the SPP regularization path through the
//! `SppEstimator` facade, and prints the discovered patterns at a
//! mid-path λ.  The same code fits graph or sequence databases — `fit`
//! is generic over `spp::mining::PatternSubstrate`.

use spp::data::synth_itemsets::{generate, ItemsetSynthConfig};
use spp::solver::Task;
use spp::SppEstimator;

fn main() {
    // 1. Data: 300 transactions over 40 items; y is driven by a few
    //    planted item conjunctions (the "patterns" we want back).
    let mut cfg = ItemsetSynthConfig::tiny(7, false);
    cfg.n = 300;
    cfg.d = 40;
    cfg.avg_items = 8.0;
    let data = generate(&cfg);
    println!("planted rules:");
    for r in &data.rules {
        println!("  {:?} (weight {:+.2})", r.items, r.weight);
    }

    // 2. Fit: 30 λ values, patterns up to 3 items — three lines.
    let fit = SppEstimator::new(Task::Regression)
        .maxpat(3)
        .lambda_grid(30, 0.05)
        .fit(&data.db, &data.y)
        .expect("fit");

    println!(
        "\npath: λ_max = {:.3}, {} λ values, {} tree nodes visited, {:.3}s total",
        fit.path.lambda_max,
        fit.path.points.len(),
        fit.path.total_nodes(),
        fit.path.total_secs()
    );

    // 3. Inspect the model mid-path.
    let mid = fit.model_at(fit.path.points.len() / 2);
    println!(
        "\nmodel at λ = {:.4} ({} active patterns, intercept {:+.3}):",
        mid.lambda,
        mid.terms.len(),
        mid.b
    );
    let mut active = mid.terms.clone();
    active.sort_by(|a, b| b.1.abs().partial_cmp(&a.1.abs()).unwrap());
    for (pattern, w) in active.iter().take(10) {
        println!("  {:+.3}  {}", w, pattern.display());
    }
    println!("\n(compare the top patterns with the planted rules above)");
}
