//! Sequence-motif discovery — the third substrate, end to end.
//!
//! ```bash
//! cargo run --release --example sequence_motifs
//! ```
//!
//! Event streams over a small alphabet carry planted subsequence motifs
//! that drive a binary label.  The example fits an SPP path over the
//! PrefixSpan tree through the `SppEstimator` facade, evaluates held-out
//! accuracy, round-trips the fitted model through the text format, and
//! prints the discovered patterns next to the planted motifs — the same
//! workflow as the item-set and graph examples, on a pattern language
//! the paper never shipped.

use spp::data::sequence::{generate, SeqSynthConfig};
use spp::mining::PatternSubstrate;
use spp::model::SparsePatternModel;
use spp::solver::Task;
use spp::SppEstimator;

fn main() {
    // 1. Data: 400 event streams over a 20-symbol alphabet; y is driven
    //    by a few planted subsequence motifs.
    let mut cfg = SeqSynthConfig::tiny(11, true);
    cfg.n = 400;
    cfg.n_symbols = 20;
    cfg.min_len = 8;
    cfg.max_len = 24;
    cfg.n_rules = 4;
    cfg.max_rule_len = 3;
    let data = generate(&cfg);
    println!("planted motifs:");
    for r in &data.rules {
        println!("  {:?} (weight {:+.2})", r.symbols, r.weight);
    }

    // train/test split
    let n = data.db.len();
    let n_train = n * 3 / 4;
    let train = data.db.select(&(0..n_train).collect::<Vec<_>>());
    let (y_train, y_test) = data.y.split_at(n_train);

    // 2. Fit: the estimator facade over the generic SPP path.
    let fit = SppEstimator::new(Task::Classification)
        .maxpat(3)
        .lambda_grid(25, 0.05)
        .fit(&train, y_train)
        .expect("fit");
    println!(
        "\npath over the PrefixSpan tree: λ_max = {:.3}, {} λ values, {} tree nodes, {:.2}s",
        fit.path.lambda_max,
        fit.path.points.len(),
        fit.path.total_nodes(),
        fit.path.total_secs()
    );

    // 3. Model selection: held-out accuracy at every λ.
    let mut best = (0usize, 0.0f64);
    for (k, _) in fit.path.points.iter().enumerate() {
        let model = fit.model_at(k);
        let correct = (n_train..n)
            .filter(|&i| {
                let s = model.score_sequence(data.db.record(i));
                (s >= 0.0) == (data.y[i] > 0.0)
            })
            .count();
        let acc = correct as f64 / y_test.len() as f64;
        if acc > best.1 {
            best = (k, acc);
        }
    }
    let chosen = fit.model_at(best.0);
    println!(
        "best held-out accuracy {:.1}% at λ = {:.4} ({} active patterns)",
        100.0 * best.1,
        chosen.lambda,
        chosen.terms.len()
    );

    // 4. Persistence: the substrate codec round-trips sequence terms.
    let text = chosen.serialize().expect("fitted weights are finite");
    let back = SparsePatternModel::parse(&text).expect("parse");
    assert_eq!(back, chosen, "model text format must round-trip");

    println!("\ntop patterns at the selected λ:");
    let mut active = chosen.terms.clone();
    active.sort_by(|a, b| b.1.abs().partial_cmp(&a.1.abs()).unwrap());
    for (pattern, w) in active.iter().take(8) {
        println!("  {:+.3}  {}", w, pattern.display());
    }
    println!("\n(compare the top patterns with the planted motifs above)");
    let majority = y_test.iter().filter(|&&v| v > 0.0).count().max(
        y_test.iter().filter(|&&v| v < 0.0).count(),
    ) as f64
        / y_test.len() as f64;
    println!("majority-class baseline: {:.1}%", 100.0 * majority);
    assert!(best.1 > 0.55, "model failed to beat chance on planted data");
    println!("\nsequence_motifs OK");
}
