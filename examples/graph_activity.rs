//! Graph-activity classification — the paper's CPDB scenario.
//!
//! ```bash
//! cargo run --release --example graph_activity
//! ```
//!
//! Molecule-like graphs carry planted structural motifs that determine
//! a binary activity label (think mutagenicity).  The example trains on
//! one split with the SPP path over the gSpan tree, evaluates held-out
//! accuracy at every λ (model selection!), and reports the screening
//! statistics the paper plots.

use std::collections::HashSet;

use spp::data::graph::GraphDatabase;
use spp::data::synth_graphs::{generate, GraphSynthConfig};
use spp::path::{compute_path_spp, PathConfig};
use spp::solver::Task;
use spp::testutil::oracle;

/// Canonical subgraph presence sets for each graph (slow but exact;
/// fine at example scale).
fn presence_sets(db: &GraphDatabase, max_edges: usize) -> Vec<HashSet<String>> {
    let mut out = Vec::with_capacity(db.len());
    for g in &db.graphs {
        let mut single = GraphDatabase::default();
        single.graphs.push(g.clone());
        single.y.push(0.0);
        let m = oracle::all_subgraphs_canonical(&single, max_edges);
        out.push(m.into_keys().collect());
    }
    out
}

fn main() {
    let maxpat = 3;
    // CPDB-scale data, scaled down so the example runs in seconds.
    let cfg = GraphSynthConfig::preset_cpdb(13).scaled(0.25);
    let data = generate(&cfg);
    let n = data.db.len();
    let n_train = n * 3 / 4;
    let mut train = GraphDatabase::default();
    let mut test = GraphDatabase::default();
    for i in 0..n {
        if i < n_train {
            train.graphs.push(data.db.graphs[i].clone());
            train.y.push(data.db.y[i]);
        } else {
            test.graphs.push(data.db.graphs[i].clone());
            test.y.push(data.db.y[i]);
        }
    }
    println!(
        "dataset: {} train / {} test molecules, {} planted motifs",
        train.len(),
        test.len(),
        data.motifs.len()
    );

    let path_cfg = PathConfig {
        n_lambdas: 20,
        lambda_min_ratio: 0.05,
        maxpat,
        ..PathConfig::default()
    };
    let path = compute_path_spp(&train, &train.y, Task::Classification, &path_cfg).unwrap();
    println!(
        "SPP path over the gSpan tree: λ_max = {:.3}, {} nodes visited, traverse {:.2}s + solve {:.2}s",
        path.lambda_max,
        path.total_nodes(),
        path.total_traverse_secs(),
        path.total_solve_secs()
    );

    // Held-out evaluation at every λ: model selection along the path.
    let test_presence = presence_sets(&test, maxpat);
    println!("\n {:>10} {:>6} {:>6} {:>10}", "λ", "|Â|", "active", "test-acc");
    let mut best = (0.0f64, 0.0f64);
    for p in &path.points {
        let feats: Vec<(String, f64)> = p
            .active
            .iter()
            .map(|(pat, w)| {
                let code = pat.as_subgraph().expect("graph path");
                (
                    oracle::canonical_form(&spp::mining::gspan::code_to_labeled_graph(code)),
                    *w,
                )
            })
            .collect();
        let mut correct = 0usize;
        for (present, &yi) in test_presence.iter().zip(&test.y) {
            let score: f64 = p.b
                + feats
                    .iter()
                    .filter(|(c, _)| present.contains(c))
                    .map(|(_, w)| w)
                    .sum::<f64>();
            if (score >= 0.0) == (yi > 0.0) {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.len() as f64;
        if acc > best.1 {
            best = (p.lambda, acc);
        }
        println!(
            " {:>10.4} {:>6} {:>6} {:>9.1}%",
            p.lambda,
            p.working_size,
            p.active.len(),
            100.0 * acc
        );
    }
    println!(
        "\nbest held-out accuracy {:.1}% at λ = {:.4} (majority class baseline {:.1}%)",
        100.0 * best.1,
        best.0,
        100.0 * test
            .y
            .iter()
            .filter(|&&v| v > 0.0)
            .count()
            .max(test.y.iter().filter(|&&v| v < 0.0).count()) as f64
            / test.len() as f64
    );
    assert!(best.1 > 0.55, "model failed to beat chance on planted data");
}
