//! Vendored minimal subset of the `anyhow` error-handling API.
//!
//! This workspace builds hermetically — no registry or network access —
//! so the one ubiquitous external dependency of the `spp` crate is
//! provided as this small path crate instead.  It implements exactly
//! the surface the codebase uses:
//!
//! * [`Error`] — an opaque, `Send + Sync` error value with `Display`
//!   (`{e}` and `{e:#}`) and `Debug` formatting;
//! * [`Result<T>`] — alias for `Result<T, Error>`;
//! * blanket `From<E: std::error::Error>` so `?` converts `io::Error`,
//!   `ParseIntError`, `ParseFloatError`, … into [`Error`];
//! * the [`anyhow!`], [`bail!`] and [`ensure!`] macros (format-string
//!   forms);
//! * a minimal [`Context`] extension trait.
//!
//! Semantics intentionally mirror the real `anyhow` closely enough that
//! swapping in the crates.io crate is a one-line change in
//! `rust/Cargo.toml`; nothing here is a public API of its own.

use std::fmt;

/// An opaque error: a message plus an optional chain of causes
/// (rendered oldest-last, like `anyhow`'s alternate format).
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            msg: message.to_string(),
            source: None,
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error {
            msg: context.to_string(),
            source: Some(Box::new(self)),
        }
    }

    /// The outermost message (no cause chain).
    pub fn root_message(&self) -> &str {
        &self.msg
    }

    /// Iterate the cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &Error> {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source.as_deref();
            Some(cur)
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cause = self.source.as_deref();
            while let Some(c) = cause {
                write!(f, ": {}", c.msg)?;
                cause = c.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cause = self.source.as_deref();
        if cause.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(c) = cause {
            write!(f, "\n    {}", c.msg)?;
            cause = c.source.as_deref();
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`
// (same design as the real anyhow) — that is what makes the blanket
// `From` below coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // Preserve the std error's own source chain as context.
        let mut chain: Vec<String> = vec![e.to_string()];
        let mut src = std::error::Error::source(&e);
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for msg in chain.into_iter().rev() {
            err = Some(match err {
                None => Error::msg(msg),
                Some(inner) => inner.context(msg),
            });
        }
        err.expect("chain is non-empty")
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait: attach context to a `Result`'s error.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_two(s: &str) -> Result<i64> {
        let v: i64 = s.parse()?; // ParseIntError -> Error via blanket From
        ensure!(v == 2, "expected 2, got {v}");
        Ok(v)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse_two("2").unwrap(), 2);
        let e = parse_two("xyz").unwrap_err();
        assert!(e.to_string().contains("invalid digit"));
    }

    #[test]
    fn ensure_and_bail_format() {
        let e = parse_two("3").unwrap_err();
        assert_eq!(e.to_string(), "expected 2, got 3");

        fn fails() -> Result<()> {
            bail!("boom {}", 42);
        }
        assert_eq!(fails().unwrap_err().to_string(), "boom 42");
    }

    #[test]
    fn display_alternate_includes_chain() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
        assert!(format!("{e:?}").contains("Caused by"));
        assert_eq!(e.chain().count(), 2);
    }

    #[test]
    fn context_trait_wraps_results() {
        let r: Result<(), std::num::ParseIntError> = "x".parse::<i64>().map(|_| ());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config");
        assert!(format!("{e:#}").contains("invalid digit"));
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
