//! Vendored **compile-surface stub** of the `xla-rs` PJRT bindings.
//!
//! The real backend of `spp::runtime::engine` (feature `pjrt`,
//! `rust/src/runtime/engine_xla.rs`) is written against the `xla`
//! bindings crate, which needs a native `xla_extension` install and is
//! therefore not vendorable.  Without *any* `xla` crate, however, the
//! real engine cannot even be type-checked, and CI could only compile
//! the stub twin — the accelerated engine would rot silently.
//!
//! This crate is the minimal API subset `engine_xla.rs` uses, with the
//! same signatures, so `cargo check --features pjrt` type-checks the
//! real engine offline.  Host-side data plumbing ([`Literal`]
//! construction, reshape, readback) is implemented for real; everything
//! that needs the native PJRT runtime fails at the single entry point
//! ([`PjRtClient::cpu`]) with a descriptive error, preserving the
//! crate-wide graceful-degradation contract.  To run on the real
//! backend, point the `xla` dependency in `rust/Cargo.toml` at the
//! upstream `xla-rs` crate instead of this stub.

use std::fmt;

/// The stub's error type (the real crate's is also opaque + `Debug`).
pub struct Error(String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

fn need_runtime<T>() -> Result<T, Error> {
    Err(Error(
        "vendored xla stub is compile-only: link the real xla-rs crate (and a native \
         xla_extension) to execute PJRT artifacts — see rust/Cargo.toml"
            .to_string(),
    ))
}

/// An f32 host literal: flat data plus dimensions (scalar = no dims).
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

/// Array shape of a [`Literal`].
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1(v: &[f32]) -> Literal {
        Literal {
            data: v.to_vec(),
            dims: vec![v.len() as i64],
        }
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar(v: f32) -> Literal {
        Literal {
            data: vec![v],
            dims: Vec::new(),
        }
    }

    /// Reinterpret the flat data under new dimensions.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let count: i64 = dims.iter().product();
        if count as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements into shape {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        Ok(ArrayShape {
            dims: self.dims.clone(),
        })
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    /// Copy the host data into `dst` (must be large enough).
    pub fn copy_raw_to(&self, dst: &mut [f32]) -> Result<(), Error> {
        if dst.len() < self.data.len() {
            return Err(Error("copy_raw_to: destination too small".to_string()));
        }
        dst[..self.data.len()].copy_from_slice(&self.data);
        Ok(())
    }

    pub fn to_vec(&self) -> Result<Vec<f32>, Error> {
        Ok(self.data.clone())
    }

    /// Unpack a 1-element tuple literal (runtime-produced only).
    pub fn to_tuple1(self) -> Result<Literal, Error> {
        need_runtime()
    }

    /// Unpack a 3-element tuple literal (runtime-produced only).
    pub fn to_tuple3(self) -> Result<(Literal, Literal, Literal), Error> {
        need_runtime()
    }
}

/// Parsed HLO module text (runtime-only in the stub).
pub struct HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<std::path::Path>>(_path: P) -> Result<Self, Error> {
        need_runtime()
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

/// A device buffer handle returned by [`PjRtLoadedExecutable::execute`].
pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        need_runtime()
    }
}

/// A compiled executable (never constructible in the stub).
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    /// Execute with borrowed or owned literal arguments; the result is
    /// indexed `[device][output]`.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        need_runtime()
    }
}

/// A PJRT client (the stub's single failure point: [`PjRtClient::cpu`]
/// always errors, so no downstream runtime call is ever reached).
pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        need_runtime()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        need_runtime()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_plumbing_round_trips() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let m = l.reshape(&[2, 2]).unwrap();
        assert_eq!(m.array_shape().unwrap().dims(), &[2, 2]);
        let mut buf = vec![0.0f32; 4];
        m.copy_raw_to(&mut buf).unwrap();
        assert_eq!(buf, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.to_vec().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 3]).is_err());
        assert!(Literal::scalar(7.0).array_shape().unwrap().dims().is_empty());
    }

    #[test]
    fn runtime_entry_points_error_descriptively() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(format!("{e:?}").contains("compile-only"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
