"""L2 graph correctness: FISTA epochs vs ref, gap/dual-point properties,
and convergence of the full artifact loop on small synthetic problems.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

N = 512  # one kernel tile — smallest legal padded n


def _problem(seed, n=N, d=16, n_valid=None, classify=False):
    rng = np.random.default_rng(seed)
    n_valid = n_valid or n
    x = np.zeros((n, d), np.float32)
    x[:n_valid] = (rng.random((n_valid, d)) < 0.3).astype(np.float32)
    w_true = np.zeros(d, np.float32)
    w_true[: d // 4] = rng.standard_normal(d // 4).astype(np.float32)
    y = np.zeros(n, np.float32)
    score = x[:n_valid] @ w_true + 0.1 * rng.standard_normal(n_valid)
    y[:n_valid] = np.sign(score) if classify else score
    y[:n_valid][y[:n_valid] == 0] = 1.0
    mask = np.zeros(n, np.float32)
    mask[:n_valid] = 1.0
    return x, y.astype(np.float32), mask


def _lip(x, hinge=False):
    xa = np.concatenate([x, np.ones((x.shape[0], 1), np.float32)], axis=1)
    s = np.linalg.svd(xa, compute_uv=False)[0]
    return np.float32(s * s * (1.0 if not hinge else 1.0) + 1e-3)


def _init_state(d):
    w = np.zeros(d, np.float32)
    vw = np.zeros(d, np.float32)
    tail = np.zeros(8, np.float32)
    tail[2] = 1.0  # tk
    return w, vw, tail


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), lam=st.floats(0.05, 5.0))
def test_fista_squared_matches_ref_epoch(seed, lam):
    x, y, mask = _problem(seed)
    lip = _lip(x)
    w, vw, tail = _init_state(x.shape[1])
    w2, vw2, tail2 = model.fista_squared(
        x, y, mask, w, vw, tail, np.array([lam], np.float32),
        np.array([lip], np.float32),
    )
    rw, rb, rvw, rvb, rtk = ref.fista_epoch_squared_ref(
        x, y, mask, jnp.asarray(w), jnp.float32(0), jnp.asarray(vw),
        jnp.float32(0), jnp.float32(1.0), lam, lip, model.STEPS,
    )
    np.testing.assert_allclose(w2, rw, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(vw2, rvw, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(tail2[0], rb, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(tail2[1], rvb, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(tail2[2], rtk, rtol=1e-5, atol=1e-5)
    # epilogue agrees with the oracles
    p = ref.primal_squared_ref(x, y, mask, rw, rb, lam)
    np.testing.assert_allclose(tail2[3], p, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), lam=st.floats(0.05, 5.0))
def test_fista_hinge_matches_ref_epoch(seed, lam):
    x, y, mask = _problem(seed, classify=True)
    lip = _lip(x, hinge=True)
    w, vw, tail = _init_state(x.shape[1])
    w2, vw2, tail2 = model.fista_hinge(
        x, y, mask, w, vw, tail, np.array([lam], np.float32),
        np.array([lip], np.float32),
    )
    rw, rb, rvw, rvb, rtk = ref.fista_epoch_hinge_ref(
        x, y, mask, jnp.asarray(w), jnp.float32(0), jnp.asarray(vw),
        jnp.float32(0), jnp.float32(1.0), lam, lip, model.STEPS,
    )
    np.testing.assert_allclose(w2, rw, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(tail2[0], rb, rtol=1e-4, atol=1e-4)
    p = ref.primal_hinge_ref(x, y, mask, rw, rb, lam)
    np.testing.assert_allclose(tail2[3], p, rtol=1e-4, atol=1e-4)


def _run_to_gap(fn, x, y, mask, lam, lip, max_execs=400, tol=1e-5):
    w, vw, tail = _init_state(x.shape[1])
    lam_a = np.array([lam], np.float32)
    lip_a = np.array([lip], np.float32)
    gap = np.inf
    for _ in range(max_execs):
        w, vw, tail = fn(x, y, mask, w, vw, tail, lam_a, lip_a)
        gap = float(tail[5])
        if gap < tol * max(1.0, float(tail[3])):
            break
    return np.asarray(w), float(tail[0]), gap, float(tail[3]), float(tail[4])


def test_fista_squared_converges_and_gap_closes():
    x, y, mask = _problem(3, n_valid=400)
    w, b, gap, primal, dual = _run_to_gap(
        model.fista_squared, x, y, mask, 2.0, _lip(x)
    )
    assert gap < 1e-4 * max(1.0, primal)
    assert dual <= primal + 1e-5
    # KKT box: |x_t^T residual| <= lam (+tol) for all columns.
    resid = mask * (y - x @ w - b)
    assert np.max(np.abs(x.T @ resid)) <= 2.0 * (1 + 1e-3) + 1e-3
    # intercept optimality: residual mean ~ 0
    assert abs(resid.sum()) < 1e-2


def test_fista_hinge_converges_and_gap_closes():
    x, y, mask = _problem(7, n_valid=384, classify=True)
    w, b, gap, primal, dual = _run_to_gap(
        model.fista_hinge, x, y, mask, 1.0, _lip(x, hinge=True)
    )
    assert gap < 1e-3 * max(1.0, primal)
    assert dual <= primal + 1e-5


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_dual_point_squared_is_feasible(seed):
    x, y, mask = _problem(seed)
    rng = np.random.default_rng(seed + 1)
    w = rng.standard_normal(x.shape[1]).astype(np.float32) * 0.1
    b = np.float32(rng.standard_normal() * 0.1)
    lam = 1.0
    theta = np.asarray(ref.dual_point_squared_ref(x, y, mask, w, b, lam))
    assert abs(theta.sum()) < 1e-3  # beta^T theta = 0 (beta = 1)
    assert np.max(np.abs(x.T @ theta)) <= 1.0 + 1e-4  # box
    # weak duality: P >= D
    p = float(ref.primal_squared_ref(x, y, mask, w, b, lam))
    d = float(ref.dual_squared_ref(theta, y, lam))
    assert p >= d - 1e-4


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_dual_point_hinge_is_feasible(seed):
    x, y, mask = _problem(seed, classify=True)
    rng = np.random.default_rng(seed + 1)
    w = rng.standard_normal(x.shape[1]).astype(np.float32) * 0.1
    b = np.float32(rng.standard_normal() * 0.1)
    lam = 1.0
    theta = np.asarray(ref.dual_point_hinge_ref(x, y, mask, w, b, lam))
    assert theta.min() >= -1e-6  # theta >= 0
    assert abs(float(y @ theta)) < 5e-3  # y^T theta ~= 0
    assert np.max(np.abs(x.T @ (y * theta))) <= 1.0 + 1e-4
    p = float(ref.primal_hinge_ref(x, y, mask, w, b, lam))
    d = float(ref.dual_hinge_ref(theta, lam))
    assert p >= d - 1e-4


def test_padding_rows_do_not_change_objective():
    """Same data at two paddings -> identical primal/dual trajectory."""
    x, y, mask = _problem(11, n=512, d=8, n_valid=300)
    x2 = np.zeros((1024, 8), np.float32)
    y2 = np.zeros(1024, np.float32)
    mask2 = np.zeros(1024, np.float32)
    x2[:512], y2[:512], mask2[:512] = x, y, mask
    lip = _lip(x[:300])
    w1, b1, g1, p1, d1 = _run_to_gap(model.fista_squared, x, y, mask, 1.5, lip)
    w2, b2, g2, p2, d2 = _run_to_gap(model.fista_squared, x2, y2, mask2, 1.5, lip)
    np.testing.assert_allclose(w1, w2, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(p1, p2, rtol=1e-5)


def test_sppc_block_packs_scores():
    rng = np.random.default_rng(0)
    x = (rng.random((512, 8)) < 0.3).astype(np.float32)
    theta = rng.standard_normal(512).astype(np.float32)
    w_pos = np.where(theta > 0, theta, 0).astype(np.float32)
    w_neg = np.where(theta < 0, theta, 0).astype(np.float32)
    (out,) = model.sppc_block(x, w_pos, w_neg, np.float32(0.7))
    s, u, v = ref.sppc_scores_ref(x, w_pos, w_neg, 0.7)
    np.testing.assert_allclose(out[:, 0], s, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(out[:, 1], u, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(out[:, 2], v, rtol=1e-5, atol=1e-4)
