"""L1 kernel vs pure-jnp oracle — the CORE correctness signal.

Hypothesis sweeps shapes/seeds; every Pallas kernel must match ref.py to
float32 tolerance for all of them.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import linalg, ref, sppc

jax.config.update("jax_platform_name", "cpu")

# Sample-axis sizes must be multiples of the kernel tile.
N_SIZES = [512, 1024, 2048]
B_SIZES = [1, 3, 8, 64, 256]


def _rng(seed):
    return np.random.default_rng(seed)


def _dense_supports(rng, n, b, density):
    return (rng.random((n, b)) < density).astype(np.float32)


def _folded_weights(rng, n):
    """Random theta/beta folded into (w_pos, w_neg) with disjoint support."""
    theta = rng.standard_normal(n).astype(np.float32)
    beta = rng.choice([-1.0, 1.0], size=n).astype(np.float32)
    a = rng.choice([-1.0, 1.0], size=n).astype(np.float32)
    prod = beta * theta
    w_pos = np.where(prod > 0, a * theta, 0.0).astype(np.float32)
    w_neg = np.where(prod < 0, a * theta, 0.0).astype(np.float32)
    return w_pos, w_neg


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from(N_SIZES),
    b=st.sampled_from(B_SIZES),
    density=st.floats(0.01, 0.9),
    seed=st.integers(0, 2**31 - 1),
)
def test_sppc_reduce_matches_ref(n, b, density, seed):
    rng = _rng(seed)
    x = _dense_supports(rng, n, b, density)
    w_pos, w_neg = _folded_weights(rng, n)
    got = sppc.sppc_reduce(x, w_pos, w_neg)
    want = ref.sppc_reduce_ref(x, w_pos, w_neg)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from(N_SIZES),
    b=st.sampled_from([8, 256]),
    r=st.floats(0.0, 10.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_sppc_scores_matches_ref(n, b, r, seed):
    rng = _rng(seed)
    x = _dense_supports(rng, n, b, 0.3)
    w_pos, w_neg = _folded_weights(rng, n)
    s_got, u_got, v_got = sppc.sppc_scores(x, w_pos, w_neg, jnp.float32(r))
    s_want, u_want, v_want = ref.sppc_scores_ref(x, w_pos, w_neg, r)
    np.testing.assert_allclose(u_got, u_want, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(v_got, v_want, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(s_got, s_want, rtol=1e-5, atol=1e-4)


def test_sppc_v_is_support_count():
    """v_t = support size exactly (binary x, unit a_i^2)."""
    rng = _rng(0)
    x = _dense_supports(rng, 512, 16, 0.2)
    w_pos, w_neg = _folded_weights(rng, 512)
    _, _, v = sppc.sppc_scores(x, w_pos, w_neg, jnp.float32(1.0))
    np.testing.assert_allclose(np.asarray(v), x.sum(axis=0), atol=1e-3)


def test_sppc_u_sign_split_semantics():
    """u_t with hand-built weights: pos-only rows raise pos, etc."""
    n, b = 512, 4
    x = np.zeros((n, b), np.float32)
    x[:8, 0] = 1.0  # pattern 0 hits rows 0..7
    w_pos = np.zeros(n, np.float32)
    w_neg = np.zeros(n, np.float32)
    w_pos[:4] = 2.0  # pos mass 8.0
    w_neg[4:8] = -3.0  # neg mass -12.0 -> -sum = 12.0
    s, u, v = sppc.sppc_scores(x, w_pos, w_neg, jnp.float32(0.0))
    assert np.isclose(u[0], 12.0, atol=1e-5)  # max(8, 12)
    assert np.isclose(v[0], 8.0, atol=1e-5)
    assert np.isclose(s[0], 12.0, atol=1e-5)
    assert np.allclose(np.asarray(u)[1:], 0.0, atol=1e-6)


def test_sppc_rejects_untiled_n():
    rng = _rng(1)
    x = _dense_supports(rng, 500, 4, 0.3)
    w_pos, w_neg = _folded_weights(rng, 500)
    with pytest.raises(ValueError):
        sppc.sppc_reduce(x, w_pos, w_neg)


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from(N_SIZES),
    d=st.sampled_from([1, 7, 64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matvec_matches_ref(n, d, seed):
    rng = _rng(seed)
    x = rng.standard_normal((n, d)).astype(np.float32)
    w = rng.standard_normal(d).astype(np.float32)
    np.testing.assert_allclose(
        linalg.matvec(x, w), ref.matvec_ref(x, w), rtol=1e-4, atol=1e-3
    )


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from(N_SIZES),
    d=st.sampled_from([1, 7, 64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_rmatvec_matches_ref(n, d, seed):
    rng = _rng(seed)
    x = rng.standard_normal((n, d)).astype(np.float32)
    r = rng.standard_normal(n).astype(np.float32)
    np.testing.assert_allclose(
        linalg.rmatvec(x, r), ref.rmatvec_ref(x, r), rtol=1e-4, atol=1e-3
    )


@settings(max_examples=30, deadline=None)
@given(
    d=st.integers(1, 512),
    tau=st.floats(0.0, 5.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_soft_threshold_matches_ref(d, tau, seed):
    rng = _rng(seed)
    z = (rng.standard_normal(d) * 3).astype(np.float32)
    got = linalg.soft_threshold(z, jnp.float32(tau))
    want = ref.soft_threshold_ref(z, tau)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_soft_threshold_kills_small_entries():
    z = np.array([0.5, -0.5, 2.0, -2.0], np.float32)
    got = np.asarray(linalg.soft_threshold(z, jnp.float32(1.0)))
    np.testing.assert_allclose(got, [0.0, 0.0, 1.0, -1.0], atol=1e-6)
