"""AOT lowering sanity: HLO text is produced, parseable-looking, and the
manifest describes exactly what was written (quick shapes)."""

import json
import subprocess
import sys
from pathlib import Path

import pytest

from compile import aot

PYDIR = Path(__file__).resolve().parents[1]


def test_lower_sppc_produces_hlo_text():
    text = aot.to_hlo_text(aot.lower_sppc(1024, 256))
    assert text.startswith("HloModule")
    assert "f32[1024,256]" in text
    assert "ROOT" in text


def test_lower_fista_produces_hlo_text():
    from compile import model

    text = aot.to_hlo_text(aot.lower_fista(model.fista_squared, 1024, 256))
    assert text.startswith("HloModule")
    assert "f32[1024,256]" in text


@pytest.mark.slow
def test_quick_aot_writes_manifest(tmp_path):
    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--quick"],
        cwd=PYDIR,
        check=True,
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["format"] == "hlo-text"
    names = {a["name"] for a in manifest["artifacts"]}
    assert "sppc_1024x256" in names
    assert "fista_sq_1024x256" in names
    assert "fista_hinge_1024x256" in names
    for a in manifest["artifacts"]:
        f = out / a["file"]
        assert f.exists() and f.stat().st_size > 0
        assert f.read_text().startswith("HloModule")
