"""AOT: lower the L2 graphs to HLO *text* artifacts for the Rust runtime.

HLO text (NOT `lowered.compile()` / serialized HloModuleProto) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which the `xla` crate's xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Emits, per shape in the family below:
  artifacts/sppc_{n}x{b}.hlo.txt
  artifacts/fista_sq_{n}x{d}.hlo.txt
  artifacts/fista_hinge_{n}x{d}.hlo.txt
plus artifacts/manifest.json describing every artifact (kind, shapes,
steps, input/output signature) — the Rust runtime discovers artifacts
through the manifest, never by parsing file names.

Usage: python -m compile.aot [--out-dir ../artifacts] [--quick]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Shape family.  n is padded sample count (multiples of kernel TILE_N =
# 512), b the SPPC frontier block width, d the active-set panel width.
# Chosen to cover the paper's datasets: graphs n <= 4337 -> 8192;
# a9a n = 32561 -> 32768.
SPPC_SHAPES = [(1024, 256), (8192, 256), (32768, 256)]
FISTA_SHAPES = [(1024, 256), (8192, 256), (8192, 1024), (32768, 1024)]
QUICK_SPPC = [(1024, 256)]
QUICK_FISTA = [(1024, 256)]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_sppc(n, b):
    return jax.jit(model.sppc_block).lower(
        _spec(n, b), _spec(n), _spec(n), _spec()
    )


def lower_fista(fn, n, d):
    return jax.jit(fn).lower(
        _spec(n, d),  # x
        _spec(n),  # y
        _spec(n),  # mask
        _spec(d),  # w
        _spec(d),  # vw
        _spec(8),  # tail
        _spec(1),  # lam
        _spec(1),  # lip
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="also write a sentinel copy")
    ap.add_argument(
        "--quick", action="store_true", help="smallest shapes only (CI)"
    )
    args = ap.parse_args()
    out_dir = args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    sppc_shapes = QUICK_SPPC if args.quick else SPPC_SHAPES
    fista_shapes = QUICK_FISTA if args.quick else FISTA_SHAPES
    manifest = {"format": "hlo-text", "steps": model.STEPS, "artifacts": []}

    for n, b in sppc_shapes:
        name = f"sppc_{n}x{b}"
        text = to_hlo_text(lower_sppc(n, b))
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": name,
                "kind": "sppc",
                "n": n,
                "b": b,
                "file": f"{name}.hlo.txt",
                "inputs": ["x[n,b]", "w_pos[n]", "w_neg[n]", "r[]"],
                "outputs": ["scores[b,3]"],
            }
        )
        print(f"wrote {path} ({len(text)} chars)")

    for kind, fn in (("fista_sq", model.fista_squared), ("fista_hinge", model.fista_hinge)):
        for n, d in fista_shapes:
            name = f"{kind}_{n}x{d}"
            text = to_hlo_text(lower_fista(fn, n, d))
            path = os.path.join(out_dir, f"{name}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            manifest["artifacts"].append(
                {
                    "name": name,
                    "kind": kind,
                    "n": n,
                    "d": d,
                    "steps": model.STEPS,
                    "file": f"{name}.hlo.txt",
                    "inputs": [
                        "x[n,d]",
                        "y[n]",
                        "mask[n]",
                        "w[d]",
                        "vw[d]",
                        "tail[8]",
                        "lam[1]",
                        "lip[1]",
                    ],
                    "outputs": ["w[d]", "vw[d]", "tail[8]"],
                }
            )
            print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath} ({len(manifest['artifacts'])} artifacts)")

    # Tab-separated twin of the manifest for the Rust runtime (the
    # vendored crate set has no JSON parser; this stays trivially
    # parseable): name kind n cols steps file
    tpath = os.path.join(out_dir, "manifest.txt")
    with open(tpath, "w") as f:
        f.write("# name\tkind\tn\tcols\tsteps\tfile\n")
        for a in manifest["artifacts"]:
            cols = a.get("b", a.get("d", 0))
            f.write(
                f"{a['name']}\t{a['kind']}\t{a['n']}\t{cols}\t"
                f"{a.get('steps', 0)}\t{a['file']}\n"
            )
    print(f"wrote {tpath}")

    if args.out:
        # Makefile sentinel: the freshest sppc artifact doubles as the
        # up-to-date marker.
        src = os.path.join(
            out_dir, f"sppc_{sppc_shapes[0][0]}x{sppc_shapes[0][1]}.hlo.txt"
        )
        with open(src) as f, open(args.out, "w") as g:
            g.write(f.read())
        print(f"wrote sentinel {args.out}")


if __name__ == "__main__":
    main()
