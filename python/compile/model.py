"""L2: the JAX compute graphs AOT-lowered for the Rust runtime.

Three graph families, each parameterized by padded shapes (the Rust
runtime pads live data up to the artifact's shape; `mask` marks valid
rows so padding never perturbs the math):

  * sppc_block      — batched SPPC frontier scoring (calls the L1
                      Pallas kernel in kernels/sppc.py);
  * fista_squared / fista_hinge
                    — `STEPS` FISTA iterations on the active-set
                      subproblem (paper eq. 6) + duality-gap epilogue
                      (dual-feasible point, primal, dual);
  * lambda_max_block — the §3.4.1 bound weights are just a special case
                      of sppc_block (w_pos/w_neg folded from y - ybar),
                      so no separate graph is needed; the Rust side
                      reuses sppc artifacts.

Everything here is **build-time only**: `aot.py` lowers these once to
HLO text in artifacts/, and the Rust coordinator executes them via PJRT
with no Python anywhere near the request path.
"""

from __future__ import annotations

import jax.numpy as jnp

from compile.kernels import linalg, sppc

# FISTA iterations per artifact execution.  The Rust driver loops
# executions until the gap (returned by the artifact) is under
# tolerance, so this only sets the check granularity.
STEPS = 16


def sppc_block(x, w_pos, w_neg, r):
    """Score one frontier block.  Returns a single (B, 3) panel
    [sppc | u | v] (tupled outputs keep the Rust unpacking trivial)."""
    s, u, v = sppc.sppc_scores(x, w_pos, w_neg, r)
    return (jnp.stack([s, u, v], axis=1),)


def _momentum(tk):
    t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * tk * tk))
    return t_new, (tk - 1.0) / t_new


def _pack_state(w, b, vw, vb, tk, primal, dual):
    """Scalars ride in a length-8 tail vector: [b, vb, tk, P, D, gap, 0, 0]."""
    tail = jnp.stack(
        [b, vb, tk, primal, dual, primal - dual, jnp.float32(0), jnp.float32(0)]
    )
    return w, vw, tail


def fista_squared(x, y, mask, w, vw, tail, lam, lip):
    """One artifact execution = STEPS FISTA iterations + gap epilogue.

    Args:
      x: (n, d) active-set panel (padded; pad rows AND pad columns zero).
      y: (n,) targets (pad rows zero).
      mask: (n,) {0,1} valid-row mask.
      w, vw: (d,) iterate and momentum point.
      tail: (8,) packed scalars [b, vb, tk, ...] (see _pack_state).
      lam, lip: (1,) scalars — L1 weight, Lipschitz constant of the
        smooth part (precomputed by the Rust driver).

    Returns (w, vw, tail) with tail[3:6] = (primal, dual, gap).
    """
    b, vb, tk = tail[0], tail[1], tail[2]
    lam = lam[0]
    lip = lip[0]
    for _ in range(STEPS):
        r = mask * (linalg.matvec(x, vw) + vb - y)
        gw = linalg.rmatvec(x, r)
        gb = jnp.sum(r)
        w_new = linalg.soft_threshold(vw - gw / lip, lam / lip)
        b_new = vb - gb / lip
        t_new, beta = _momentum(tk)
        vw = w_new + beta * (w_new - w)
        vb = b_new + beta * (b_new - b)
        w, b, tk = w_new, b_new, t_new

    # Duality-gap epilogue (see kernels/ref.py for the derivation).
    n_valid = jnp.maximum(jnp.sum(mask), 1.0)
    resid = mask * (y - linalg.matvec(x, w) - b)
    primal = 0.5 * jnp.sum(resid * resid) + lam * jnp.sum(jnp.abs(w))
    rc = mask * (resid - jnp.sum(resid) / n_valid)
    theta = rc / lam
    viol = jnp.max(jnp.abs(linalg.rmatvec(x, theta)))
    theta = theta * jnp.minimum(1.0, 1.0 / jnp.maximum(viol, 1e-30))
    dual = -0.5 * lam * lam * jnp.sum(theta * theta) + lam * jnp.dot(y, theta)
    return _pack_state(w, b, vw, vb, tk, primal, dual)


def fista_hinge(x, y, mask, w, vw, tail, lam, lip):
    """Squared-hinge variant of fista_squared; same calling convention.

    x carries plain supports x_{it}; the y-folding (alpha = y*x) happens
    inside, so the Rust panel builder is shared between problems.
    """
    b, vb, tk = tail[0], tail[1], tail[2]
    lam = lam[0]
    lip = lip[0]
    for _ in range(STEPS):
        z = y * (linalg.matvec(x, vw) + vb)
        h = mask * jnp.maximum(0.0, 1.0 - z)
        gw = -linalg.rmatvec(x, y * h)
        gb = -jnp.sum(y * h)
        w_new = linalg.soft_threshold(vw - gw / lip, lam / lip)
        b_new = vb - gb / lip
        t_new, beta = _momentum(tk)
        vw = w_new + beta * (w_new - w)
        vb = b_new + beta * (b_new - b)
        w, b, tk = w_new, b_new, t_new

    n_valid = jnp.maximum(jnp.sum(mask), 1.0)
    z = y * (linalg.matvec(x, w) + b)
    h = mask * jnp.maximum(0.0, 1.0 - z)
    primal = 0.5 * jnp.sum(h * h) + lam * jnp.sum(jnp.abs(w))
    theta = h / lam
    for _ in range(12):
        theta = theta - (jnp.dot(y, theta) / n_valid) * y * mask
        theta = jnp.maximum(theta, 0.0)
    theta = theta - (jnp.dot(y, theta) / n_valid) * y * mask
    theta = jnp.maximum(theta, 0.0)
    viol = jnp.max(jnp.abs(linalg.rmatvec(x, y * theta)))
    theta = theta * jnp.minimum(1.0, 1.0 / jnp.maximum(viol, 1e-30))
    dual = -0.5 * lam * lam * jnp.sum(theta * theta) + lam * jnp.sum(theta)
    return _pack_state(w, b, vw, vb, tk, primal, dual)
