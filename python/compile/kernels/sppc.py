"""L1 Pallas kernel: blocked SPPC frontier scoring.

This is the compute hot-spot of Safe Pattern Pruning: for every pattern
node the traversal visits, the rule needs

    pos_t = sum_i x_{it} * w_pos_i
    neg_t = sum_i x_{it} * w_neg_i
    v_t   = sum_i x_{it}

over the n samples (see kernels/ref.py for the w_pos/w_neg folding).
The Rust coordinator densifies a *frontier block* of B pattern supports
into an (n, B) panel and scores all B nodes in one kernel launch.

TPU-style design (DESIGN.md §3 Hardware-Adaptation):
  * grid = (n / TN,): the sample axis is the reduction axis of the grid;
  * each grid step holds one (TN, B) panel of X and one (TN, 3) panel of
    the folded weights in VMEM and accumulates a (B, 3) panel of partial
    sums in the output block (revisited by every grid step — the
    canonical Pallas accumulation pattern);
  * the inner op is a single (B, TN) x (TN, 3) contraction, which on a
    real TPU maps onto the MXU with bf16 inputs / f32 accumulation; here
    we keep f32 end-to-end because correctness is validated on CPU
    (interpret=True — Mosaic custom-calls cannot run on the CPU PJRT
    plugin).

VMEM footprint per grid step (f32): TN*B + TN*3 + B*3 floats.  For the
shipped TN=512, B=256 that is ~0.53 MB — far below the ~16 MB VMEM of a
TPUv4 core, leaving room for double-buffering the X panels (the kernel
is bandwidth-bound: ~3 FLOPs per loaded X element).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step (sample-axis tile).  All AOT shapes are multiples.
TILE_N = 512


def _sppc_reduce_kernel(x_ref, w3_ref, o_ref):
    """One grid step: o += x_panel.T @ w3_panel.

    x_ref:  (TILE_N, B) VMEM panel of densified supports.
    w3_ref: (TILE_N, 3) VMEM panel of folded weights (w_pos, w_neg, 1).
    o_ref:  (B, 3) accumulator block (same block for every grid step).
    """

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...].T, w3_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("tile_n",))
def sppc_reduce(x, w_pos, w_neg, *, tile_n=TILE_N):
    """Blocked (pos, neg, v) reduction; see kernels/ref.py:sppc_reduce_ref.

    Args:
      x: (n, B) f32 densified supports, n % tile_n == 0.
      w_pos, w_neg: (n,) f32 folded weights.

    Returns:
      (B, 3) f32 [pos | neg | v].
    """
    n, b = x.shape
    if n % tile_n != 0:
        raise ValueError(f"n={n} must be a multiple of tile_n={tile_n}")
    w3 = jnp.stack([w_pos, w_neg, jnp.ones_like(w_pos)], axis=1)  # (n, 3)
    return pl.pallas_call(
        _sppc_reduce_kernel,
        grid=(n // tile_n,),
        in_specs=[
            pl.BlockSpec((tile_n, b), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, 3), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((b, 3), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 3), jnp.float32),
        interpret=True,
    )(x, w3)


def sppc_scores(x, w_pos, w_neg, r, *, tile_n=TILE_N):
    """SPPC(t) = u_t + r*sqrt(v_t) for a frontier block.

    Returns (sppc, u, v), each (B,) f32.  The max/sqrt epilogue is plain
    XLA (it is O(B), negligible next to the O(n*B) reduction).
    """
    acc = sppc_reduce(x, w_pos, w_neg, tile_n=tile_n)
    pos, neg, v = acc[:, 0], acc[:, 1], acc[:, 2]
    u = jnp.maximum(pos, -neg)
    sppc = u + r * jnp.sqrt(jnp.maximum(v, 0.0))
    return sppc, u, v
