"""L1 Pallas kernels: blocked matvecs + soft-threshold for the FISTA engine.

The active-set subproblem (paper eq. 6) restricted to the surviving
patterns is a dense L1 problem over an (n, d) panel.  The FISTA epoch in
model.py is built from three kernels:

  * matvec(x, w)    -> x @ w      (residual / margin computation)
  * rmatvec(x, r)   -> x.T @ r    (gradient computation)
  * soft_threshold  -> prox of lam*||.||_1

Same VMEM discipline as kernels/sppc.py: the sample axis is the grid's
reduction axis for rmatvec and the parallel axis for matvec; panels are
(TILE_N, d).  interpret=True throughout (CPU PJRT).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 512


def _matvec_kernel(x_ref, w_ref, o_ref):
    """o_panel = x_panel @ w  (parallel over sample tiles)."""
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("tile_n",))
def matvec(x, w, *, tile_n=TILE_N):
    """x @ w for x (n, d), w (d,); n % tile_n == 0."""
    n, d = x.shape
    if n % tile_n != 0:
        raise ValueError(f"n={n} must be a multiple of tile_n={tile_n}")
    return pl.pallas_call(
        _matvec_kernel,
        grid=(n // tile_n,),
        in_specs=[
            pl.BlockSpec((tile_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x, w)


def _rmatvec_kernel(x_ref, r_ref, o_ref):
    """o += x_panel.T @ r_panel (reduction over sample tiles)."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...].T, r_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("tile_n",))
def rmatvec(x, r, *, tile_n=TILE_N):
    """x.T @ r for x (n, d), r (n,); n % tile_n == 0."""
    n, d = x.shape
    if n % tile_n != 0:
        raise ValueError(f"n={n} must be a multiple of tile_n={tile_n}")
    return pl.pallas_call(
        _rmatvec_kernel,
        grid=(n // tile_n,),
        in_specs=[
            pl.BlockSpec((tile_n, d), lambda i: (i, 0)),
            pl.BlockSpec((tile_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((d,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=True,
    )(x, r)


def _soft_threshold_kernel(z_ref, tau_ref, o_ref):
    z = z_ref[...]
    tau = tau_ref[0]
    o_ref[...] = jnp.sign(z) * jnp.maximum(jnp.abs(z) - tau, 0.0)


@jax.jit
def soft_threshold(z, tau):
    """Elementwise prox of tau*||.||_1; z (d,), tau scalar -> (d,)."""
    (d,) = z.shape
    tau_arr = jnp.reshape(tau, (1,)).astype(jnp.float32)
    return pl.pallas_call(
        _soft_threshold_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((d,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=True,
    )(z, tau_arr)
