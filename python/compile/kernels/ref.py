"""Pure-jnp correctness oracles for every Pallas kernel (L1).

These are the ground truth the kernels are tested against at build time
(pytest, hypothesis sweeps).  They are also what the kernels must lower
to *semantically* — the Pallas versions only re-express the same math
with an explicit HBM<->VMEM block schedule.

Notation follows the paper (KDD'16 "Safe Pattern Pruning"):
  alpha_{it} = a_i * x_{it}  with a_i = 1 (regression) or y_i
  (classification); x_{it} in {0,1}.
  u_t = max( sum_{i: beta_i theta_i > 0} alpha_{it} theta_i,
            -sum_{i: beta_i theta_i < 0} alpha_{it} theta_i )
  v_t = sum_i alpha_{it}^2 = support(t)      (since a_i^2 = x_{it}^2 = 1)
  SPPC(t) = u_t + r * sqrt(v_t)

The kernel does not see (a, theta, beta) separately: the Rust
coordinator (L3) pre-folds them into two n-vectors
  w_pos_i = a_i * theta_i * [beta_i theta_i > 0]
  w_neg_i = a_i * theta_i * [beta_i theta_i < 0]
so the scorer is a pure (B x n) @ (n x 3) reduction over the frontier
block's densified supports.
"""

from __future__ import annotations

import jax.numpy as jnp


def sppc_reduce_ref(x, w_pos, w_neg):
    """Reference for the blocked SPPC reduction.

    Args:
      x: (n, B) float — densified {0,1} supports for a frontier block of
         B patterns (column t is pattern t's indicator over samples).
      w_pos: (n,) float — a_i * theta_i where beta_i*theta_i > 0, else 0.
      w_neg: (n,) float — a_i * theta_i where beta_i*theta_i < 0, else 0.

    Returns:
      (B, 3) float: columns are (pos_t, neg_t, v_t) with
        pos_t = sum_i x_{it} w_pos_i
        neg_t = sum_i x_{it} w_neg_i
        v_t   = sum_i x_{it}            (support size; == sum alpha^2)
    """
    w3 = jnp.stack([w_pos, w_neg, jnp.ones_like(w_pos)], axis=1)  # (n,3)
    return x.T @ w3


def sppc_scores_ref(x, w_pos, w_neg, r):
    """Full SPPC: reduce, then u_t = max(pos, -neg), sppc = u + r*sqrt(v)."""
    acc = sppc_reduce_ref(x, w_pos, w_neg)
    pos, neg, v = acc[:, 0], acc[:, 1], acc[:, 2]
    u = jnp.maximum(pos, -neg)
    sppc = u + r * jnp.sqrt(jnp.maximum(v, 0.0))
    return sppc, u, v


def soft_threshold_ref(z, tau):
    """Elementwise soft-threshold S(z, tau) = sign(z) * max(|z| - tau, 0)."""
    return jnp.sign(z) * jnp.maximum(jnp.abs(z) - tau, 0.0)


def matvec_ref(x, w):
    """x @ w for (n, d) x (d,)."""
    return x @ w


def rmatvec_ref(x, r):
    """x.T @ r for (n, d), (n,)."""
    return x.T @ r


# ---------------------------------------------------------------------------
# L2-level oracles (model.py graphs are checked against these in pytest).
# ---------------------------------------------------------------------------


def primal_squared_ref(x, y, mask, w, b, lam):
    r = mask * (y - x @ w - b)
    return 0.5 * jnp.sum(r * r) + lam * jnp.sum(jnp.abs(w))


def dual_squared_ref(theta, y, lam):
    return -0.5 * lam * lam * jnp.sum(theta * theta) + lam * jnp.dot(y, theta)


def dual_point_squared_ref(x, y, mask, w, b, lam):
    """Gap-safe dual-feasible point for the L1 least-squares subproblem.

    Residual, centered over valid rows (so sum(theta) = 0 matches the
    beta^T theta = 0 constraint), then scaled into the dual box
    |x_t^T theta| <= 1 over the columns present.
    """
    n_valid = jnp.maximum(jnp.sum(mask), 1.0)
    r = mask * (y - x @ w - b)
    r = mask * (r - jnp.sum(r) / n_valid)
    theta = r / lam
    viol = jnp.max(jnp.abs(x.T @ theta))
    scale = jnp.minimum(1.0, 1.0 / jnp.maximum(viol, 1e-30))
    return theta * scale


def primal_hinge_ref(x, y, mask, w, b, lam):
    z = y * (x @ w + b)
    h = mask * jnp.maximum(0.0, 1.0 - z)
    return 0.5 * jnp.sum(h * h) + lam * jnp.sum(jnp.abs(w))


def dual_hinge_ref(theta, lam):
    return -0.5 * lam * lam * jnp.sum(theta * theta) + lam * jnp.sum(theta)


def dual_point_hinge_ref(x, y, mask, w, b, lam, proj_iters=12):
    """Dual-feasible point for the squared-hinge subproblem.

    theta0 = max(0, 1 - z)/lam >= 0; alternating projections push it
    toward {theta >= 0} ∩ {y^T theta = 0}, then a scale pulls it inside
    the box |(y .* x_t)^T theta| <= 1 over the columns present.
    """
    n_valid = jnp.maximum(jnp.sum(mask), 1.0)
    z = y * (x @ w + b)
    theta = mask * jnp.maximum(0.0, 1.0 - z) / lam
    for _ in range(proj_iters):
        theta = theta - (jnp.dot(y, theta) / n_valid) * y * mask
        theta = jnp.maximum(theta, 0.0)
    # exact hyperplane step (may leave O(eps) negatives; clip them).
    theta = theta - (jnp.dot(y, theta) / n_valid) * y * mask
    theta = jnp.maximum(theta, 0.0)
    viol = jnp.max(jnp.abs(x.T @ (y * theta)))
    scale = jnp.minimum(1.0, 1.0 / jnp.maximum(viol, 1e-30))
    return theta * scale


def fista_epoch_squared_ref(x, y, mask, w, b, vw, vb, tk, lam, lip, steps):
    """`steps` FISTA iterations on the L1 least-squares subproblem.

    Intercept b is unpenalized.  (vw, vb, tk) is the momentum state.
    Returns the updated (w, b, vw, vb, tk).
    """
    for _ in range(steps):
        r = mask * (x @ vw + vb - y)
        gw = x.T @ r
        gb = jnp.sum(r)
        w_new = soft_threshold_ref(vw - gw / lip, lam / lip)
        b_new = vb - gb / lip
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * tk * tk))
        beta = (tk - 1.0) / t_new
        vw = w_new + beta * (w_new - w)
        vb = b_new + beta * (b_new - b)
        w, b, tk = w_new, b_new, t_new
    return w, b, vw, vb, tk


def fista_epoch_hinge_ref(x, y, mask, w, b, vw, vb, tk, lam, lip, steps):
    """`steps` FISTA iterations on the L1 squared-hinge subproblem."""
    for _ in range(steps):
        z = y * (x @ vw + vb)
        h = mask * jnp.maximum(0.0, 1.0 - z)
        gw = -(x.T @ (y * h))
        gb = -jnp.sum(y * h)
        w_new = soft_threshold_ref(vw - gw / lip, lam / lip)
        b_new = vb - gb / lip
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * tk * tk))
        beta = (tk - 1.0) / t_new
        vw = w_new + beta * (w_new - w)
        vb = b_new + beta * (b_new - b)
        w, b, tk = w_new, b_new, t_new
    return w, b, vw, vb, tk
