#!/usr/bin/env bash
# Dispatch-hygiene gate: the substrate enums (`Dataset`,
# `ShardedDataset`, `registry::Kind`) may only be matched inside the
# two registries — data/registry.rs (dataset side) and
# serve/registry.rs (tag-keyed model side).  Everything else reaches a
# concrete substrate through the visitor hop, so a `Dataset::Itemsets`
# arm appearing anywhere else is a regression toward the per-substrate
# match ladders this gate exists to keep dead.
#
# The pattern is word-bounded so unrelated `ArtifactKind::` /
# `ErrorKind::` paths don't trip it.  Library sources and the runnable
# examples are gated; benches and tests may still destructure the enums
# (some are differential oracles that want the raw substrate).
set -u
cd "$(dirname "$0")/.."

strays=$(grep -rnE '\b(Dataset|ShardedDataset|Kind)::' rust/src examples \
    --include='*.rs' \
    | grep -vE '^rust/src/(data|serve)/registry\.rs:' || true)

if [ -n "$strays" ]; then
    echo "substrate dispatch outside the registries:" >&2
    echo "$strays" >&2
    echo >&2
    echo "route the code through data::registry's visitors instead" >&2
    exit 1
fi
echo "dispatch hygiene OK: substrate matches only in the registries"
