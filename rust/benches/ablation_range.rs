//! Ablation A5: range-based (interval) SPP vs per-λ screening.
//!
//! Same workload, same λ-grid, four engine shapes on each of the three
//! substrates (item-sets, graphs, sequences):
//!
//! * `perlambda`        — one screening traversal per λ (`--range-chunk
//!   1 --no-reuse`, the paper-literal Algorithm 1 cadence);
//! * `chunked`          — one interval-radius mine per chunk of λs
//!   (`--range-chunk C --no-reuse`; a chunk-local stored tree serves
//!   the per-λ screens);
//! * `perlambda-forest` / `chunked-forest` — the same pair on the
//!   persistent incremental forest (PR 3's engine).
//!
//! All four produce **bit-identical** paths (asserted here on active
//! sets, weight bits within each reuse family, and 1e-9 weights across
//! families; the full property lives in `tests/integration_range.rs`),
//! so every ROW quadruple is a like-for-like traverse-cost comparison:
//! wall/traverse seconds, substrate node counts, chunk-mine nodes and
//! chunk hits.  Workload size obeys the usual `SPP_BENCH_*` env knobs;
//! the `n_lambdas >= 20` default is the acceptance regime: the chunked
//! scratch engine must traverse **strictly fewer** nodes than per-λ
//! scratch screening (at smoke scale — 3 λs — the assertion is skipped
//! and says so: a 2-λ tail cannot amortize a chunk mine).

use std::time::Instant;

use spp::benchkit::{bench_knobs, bench_threads};
use spp::data::registry::{info, lookup, Dataset};
use spp::path::{compute_path_spp, PathConfig, PathResult};

const CHUNK: usize = 5;

fn run(dataset: &str, default_scale: f64, maxpat: usize, default_lambdas: usize) {
    let (scale, n_lambdas, ratio) = bench_knobs(default_scale, default_lambdas);
    let task = info(dataset).unwrap().task;
    let data = lookup(dataset, scale).unwrap();
    let variants: [(&str, usize, bool); 4] = [
        ("perlambda", 1, false),
        ("chunked", CHUNK, false),
        ("perlambda-forest", 1, true),
        ("chunked-forest", CHUNK, true),
    ];
    let mut results: Vec<(&str, PathResult)> = Vec::new();
    for (variant, range_chunk, reuse) in variants {
        let cfg = PathConfig {
            n_lambdas,
            lambda_min_ratio: ratio,
            maxpat,
            reuse_forest: reuse,
            range_chunk,
            // pinned worker count (default 1): timings must not depend
            // on the CI runner's core count
            threads: bench_threads(),
            ..PathConfig::default()
        };
        let t0 = Instant::now();
        let path = match &data {
            Dataset::Graphs(g) => compute_path_spp(g, &g.y, task, &cfg),
            Dataset::Itemsets(t) => compute_path_spp(&t.db, &t.y, task, &cfg),
            Dataset::Sequences(s) => compute_path_spp(&s.db, &s.y, task, &cfg),
        }
        .unwrap();
        let wall = t0.elapsed().as_secs_f64();
        let max_gap = path.points.iter().map(|p| p.gap).fold(0.0f64, f64::max);
        assert!(max_gap <= 2e-6, "{dataset}/{variant}: uncertified path");
        println!(
            "ROW fig=A5 dataset={dataset} maxpat={maxpat} lambdas={n_lambdas} \
             chunk={range_chunk} variant={variant} total={wall:.4} traverse={:.4} \
             nodes={} chunk_mine_nodes={} chunk_hits={} forest_hits={} reopened={}",
            path.total_traverse_secs(),
            path.total_nodes(),
            path.total_chunk_mine_nodes(),
            path.chunk_hits(),
            path.total_forest_hits(),
            path.total_reopened(),
        );
        results.push((variant, path));
    }

    // like-for-like guard: within each reuse family the chunked engine
    // must be BIT-identical to per-λ (the acceptance contract); across
    // families, identical to solver tolerance
    let baseline = &results[0].1;
    for (variant, path) in &results[1..] {
        assert_eq!(baseline.points.len(), path.points.len());
        let bitwise = *variant == "chunked"; // same (scratch) family as the baseline
        for (a, b) in baseline.points.iter().zip(&path.points) {
            assert_eq!(
                a.active.len(),
                b.active.len(),
                "{dataset}/{variant}: engines disagree at λ={}",
                a.lambda
            );
            for ((pa, wa), (pb, wb)) in a.active.iter().zip(&b.active) {
                assert_eq!(pa, pb, "{dataset}/{variant}: pattern order at λ={}", a.lambda);
                if bitwise {
                    assert_eq!(
                        wa.to_bits(),
                        wb.to_bits(),
                        "{dataset}/{variant}: weight bits at λ={}",
                        a.lambda
                    );
                } else {
                    assert!((wa - wb).abs() <= 1e-9, "{dataset}/{variant}: λ={}", a.lambda);
                }
            }
        }
    }

    let (perlambda, chunked) = (&results[0].1, &results[1].1);
    if n_lambdas >= 20 {
        assert!(
            chunked.total_nodes() < perlambda.total_nodes(),
            "{dataset}: chunked screening did not reduce traversal \
             ({} vs {} nodes)",
            chunked.total_nodes(),
            perlambda.total_nodes()
        );
    } else {
        println!(
            "# note: {dataset}: node-reduction assertion needs >= 20 λs (got {n_lambdas}); skipped"
        );
    }
    println!(
        "A5 {dataset:<10} maxpat={maxpat} λs={n_lambdas} chunk={CHUNK}: \
         nodes x{:.1} fewer ({} -> {}), {} chunk hits / {} λs",
        perlambda.total_nodes() as f64 / chunked.total_nodes().max(1) as f64,
        perlambda.total_nodes(),
        chunked.total_nodes(),
        chunked.chunk_hits(),
        n_lambdas.saturating_sub(1),
    );
}

fn main() {
    println!("# A5 range-based-SPP ablation: per-λ vs chunked screening, all three substrates");
    run("splice", 0.15, 3, 20);
    run("cpdb", 0.2, 3, 20);
    run("synth-seq", 0.25, 3, 20);
    println!("# expectation: chunked nodes ≪ per-λ nodes (scratch family); paths bit-identical;");
    println!("# chunk_hits ≈ non-leading λs in the SCRATCH family (there the chunk pre-mine is");
    println!("# the only source of stored columns; under the persistent forest the credit is");
    println!("# shared with ordinary cross-λ reuse)");
}
