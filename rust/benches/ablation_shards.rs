//! Ablation A6: in-memory vs out-of-core sharded screening.
//!
//! Same workload, same λ-grid, three database shapes on each of the
//! three substrates (item-sets, graphs, sequences):
//!
//! * `memory`         — the ordinary resident database (`lookup`);
//! * `sharded`        — the on-disk shard container (`lookup_sharded`,
//!   4 shards), screened shard by shard with no memory budget;
//! * `sharded-budget` — the same container with a deliberately tiny
//!   `memory_budget`, so the support pool must spill columns to disk
//!   and reload them (LRU) along the path.
//!
//! All three produce **bit-identical** paths (asserted here on λ
//! values, active sets, weight bits, intercept bits and gap bits; the
//! full property lives in `tests/integration_shards.rs`), so every ROW
//! triple is a like-for-like cost comparison: wall/traverse seconds,
//! substrate node counts, the peak resident column gauge and the
//! spill-tier reload/eviction counters.  Workload size obeys the usual
//! `SPP_BENCH_*` env knobs.  Expectation: `sharded` pays a bounded
//! serialization/streaming overhead for a flat memory ceiling;
//! `sharded-budget` shows `resident_peak` pinned near the budget with
//! nonzero reload traffic.

use std::time::Instant;

use spp::benchkit::{bench_knobs, bench_threads};
use spp::data::registry::{info, lookup, lookup_sharded, Dataset, ShardedDataset};
use spp::path::{compute_path_spp, PathConfig, PathResult};

const SHARDS: usize = 4;
/// Deliberately tiny: small enough that the bench workloads overflow
/// it (forcing spill traffic), large enough to hold any single column.
const BUDGET: usize = 32 * 1024;

fn shard_dir() -> std::path::PathBuf {
    std::env::temp_dir().join(format!("spp-bench-shards-{}", std::process::id()))
}

fn run(dataset: &str, default_scale: f64, maxpat: usize, default_lambdas: usize) {
    let (scale, n_lambdas, ratio) = bench_knobs(default_scale, default_lambdas);
    let task = info(dataset).unwrap().task;
    let cfg = |memory_budget: usize| PathConfig {
        n_lambdas,
        lambda_min_ratio: ratio,
        maxpat,
        memory_budget,
        // pinned worker count (default 1): timings must not depend on
        // the CI runner's core count
        threads: bench_threads(),
        ..PathConfig::default()
    };

    let variants: [(&str, usize, usize); 3] = [
        ("memory", 0, 0),
        ("sharded", SHARDS, 0),
        ("sharded-budget", SHARDS, BUDGET),
    ];
    let mut results: Vec<(&str, PathResult)> = Vec::new();
    for (variant, shards, budget) in variants {
        let t0 = Instant::now();
        let path = if shards == 0 {
            match &lookup(dataset, scale).unwrap() {
                Dataset::Graphs(g) => compute_path_spp(g, &g.y, task, &cfg(budget)),
                Dataset::Itemsets(t) => compute_path_spp(&t.db, &t.y, task, &cfg(budget)),
                Dataset::Sequences(s) => compute_path_spp(&s.db, &s.y, task, &cfg(budget)),
            }
        } else {
            match &lookup_sharded(dataset, scale, shards, &shard_dir()).unwrap() {
                ShardedDataset::Itemsets { db, y } => compute_path_spp(db, y, task, &cfg(budget)),
                ShardedDataset::Graphs { db, y } => compute_path_spp(db, y, task, &cfg(budget)),
                ShardedDataset::Sequences { db, y } => compute_path_spp(db, y, task, &cfg(budget)),
            }
        }
        .unwrap();
        let wall = t0.elapsed().as_secs_f64();
        let max_gap = path.points.iter().map(|p| p.gap).fold(0.0f64, f64::max);
        assert!(max_gap <= 2e-6, "{dataset}/{variant}: uncertified path");
        println!(
            "ROW fig=A6 dataset={dataset} maxpat={maxpat} lambdas={n_lambdas} \
             variant={variant} shards={shards} budget={budget} total={wall:.4} \
             traverse={:.4} nodes={} resident_peak={} reloads={} evictions={}",
            path.total_traverse_secs(),
            path.total_nodes(),
            path.max_resident_bytes(),
            path.total_spill_reloads(),
            path.total_spill_evictions(),
        );
        results.push((variant, path));
    }

    // like-for-like guard: the sharded runs must be BIT-identical to
    // the in-memory run — shard streaming and column spilling are
    // storage moves, never math moves
    let baseline = &results[0].1;
    for (variant, path) in &results[1..] {
        assert_eq!(baseline.points.len(), path.points.len());
        for (a, b) in baseline.points.iter().zip(&path.points) {
            assert_eq!(
                a.lambda.to_bits(),
                b.lambda.to_bits(),
                "{dataset}/{variant}: λ grid"
            );
            assert_eq!(a.b.to_bits(), b.b.to_bits(), "{dataset}/{variant}: intercept");
            assert_eq!(a.gap.to_bits(), b.gap.to_bits(), "{dataset}/{variant}: gap");
            assert_eq!(
                a.active.len(),
                b.active.len(),
                "{dataset}/{variant}: engines disagree at λ={}",
                a.lambda
            );
            for ((pa, wa), (pb, wb)) in a.active.iter().zip(&b.active) {
                assert_eq!(pa, pb, "{dataset}/{variant}: pattern order at λ={}", a.lambda);
                assert_eq!(
                    wa.to_bits(),
                    wb.to_bits(),
                    "{dataset}/{variant}: weight bits at λ={}",
                    a.lambda
                );
            }
        }
    }

    let budgeted = &results[2].1;
    println!(
        "A6 {dataset:<10} maxpat={maxpat} λs={n_lambdas} shards={SHARDS}: \
         resident peak {} -> {} bytes under a {BUDGET}-byte budget \
         ({} reloads, {} evictions)",
        baseline.max_resident_bytes(),
        budgeted.max_resident_bytes(),
        budgeted.total_spill_reloads(),
        budgeted.total_spill_evictions(),
    );
}

fn main() {
    println!("# A6 out-of-core ablation: in-memory vs sharded screening, all three substrates");
    run("a9a", 0.05, 3, 10);
    run("cpdb", 0.2, 3, 10);
    run("synth-seq", 0.25, 3, 10);
    let _ = std::fs::remove_dir_all(shard_dir());
    println!("# expectation: identical λ grids, active sets and weight/intercept/gap bits across");
    println!("# variants; sharded totals within a small constant factor of memory; the budgeted");
    println!("# run's resident_peak gauge lands at or under the budget with reloads > 0");
}
