//! MICRO: the traversal and solver hot paths in isolation.
//!
//! These are the quantities the §Perf log tracks: tid-list
//! intersection, SPPC node evaluation, CD epochs, and gSpan
//! enumeration (whose cost is dominated by the minimality check).

use spp::benchkit::{bench_fn, bench_throughput};
use spp::data::synth_graphs::{self, GraphSynthConfig};
use spp::data::synth_itemsets::{generate, ItemsetSynthConfig};
use spp::mining::gspan::GSpanMiner;
use spp::mining::itemset::{intersect_into, ItemsetMiner};
use spp::mining::{Pattern, PatternNode, Walk};
use spp::path::working_set::WorkingSet;
use spp::screening::sppc::SppScreen;
use spp::screening::SupportPool;
use spp::solver::{CdSolver, Task};
use spp::testutil::SplitMix64;

fn sorted_sample(rng: &mut SplitMix64, universe: usize, len: usize) -> Vec<u32> {
    rng.sample_distinct(universe, len).into_iter().map(|i| i as u32).collect()
}

fn main() {
    let mut rng = SplitMix64::new(1);

    // --- tid-list intersection (the item-set hot loop) ---
    for (la, lb) in [(1000usize, 1000usize), (100, 10_000), (10, 100_000)] {
        let a = sorted_sample(&mut rng, 200_000, la);
        let b = sorted_sample(&mut rng, 200_000, lb);
        let mut out = Vec::with_capacity(la.min(lb));
        bench_throughput(&format!("intersect {la}x{lb}"), 7, || {
            let iters = 2000;
            for _ in 0..iters {
                intersect_into(&a, &b, &mut out);
                std::hint::black_box(out.len());
            }
            iters * (la.min(lb)) as u64
        });
    }

    // --- SPPC evaluation throughput (nodes/s scored) ---
    {
        let n = 4000usize;
        let theta: Vec<f64> = (0..n).map(|_| rng.gauss() * 0.1).collect();
        let y = vec![1.0; n];
        let mut pool = SupportPool::new();
        let screen = SppScreen::new(Task::Regression, &y, &theta, 0.4, &mut pool);
        let supports: Vec<Vec<u32>> = (0..1000)
            .map(|_| { let m = rng.range(4, 200); sorted_sample(&mut rng, n, m) })
            .collect();
        let nnz: u64 = supports.iter().map(|s| s.len() as u64).sum();
        bench_throughput("sppc-eval (nnz/s)", 7, || {
            for sup in &supports {
                std::hint::black_box(screen.sppc(sup));
            }
            nnz
        });
    }

    // --- full itemset traversal + SPP visitor (nodes/s) ---
    {
        let d = generate(&ItemsetSynthConfig::preset_splice(5).scaled(0.1));
        let theta: Vec<f64> = (0..d.db.len()).map(|_| rng.gauss() * 0.02).collect();
        let mut pool = SupportPool::new();
        bench_fn("itemset traversal+screen splice@0.1 maxpat=3", 5, || {
            let mut screen = SppScreen::new(Task::Regression, &d.y, &theta, 0.2, &mut pool);
            ItemsetMiner::new(&d.db, 3).traverse(&mut screen);
            std::hint::black_box(screen.survivors.len());
        });
        // raw enumeration without screening work
        bench_fn("itemset traversal raw       maxpat=3", 5, || {
            let mut count = 0u64;
            let mut v = |_: &PatternNode<'_>| {
                count += 1;
                Walk::Descend
            };
            ItemsetMiner::new(&d.db, 3).traverse(&mut v);
            std::hint::black_box(count);
        });
    }

    // --- gSpan enumeration (minimality check dominated) ---
    {
        let d = synth_graphs::generate(&GraphSynthConfig::preset_cpdb(5).scaled(0.15));
        for maxpat in [3usize, 4] {
            bench_fn(&format!("gspan enumerate cpdb@0.15 maxpat={maxpat}"), 3, || {
                let mut count = 0u64;
                let mut v = |_: &PatternNode<'_>| {
                    count += 1;
                    Walk::Descend
                };
                GSpanMiner::new(&d.db, maxpat).traverse(&mut v);
                std::hint::black_box(count);
            });
        }
    }

    // --- CD solver epochs ---
    {
        let n = 2000usize;
        let k = 300usize;
        let supports: Vec<Vec<u32>> = (0..k)
            .map(|_| { let m = rng.range(5, n / 4); sorted_sample(&mut rng, n, m) })
            .collect();
        let y: Vec<f64> = (0..n).map(|_| rng.gauss() * 2.0).collect();
        for task in [Task::Regression, Task::Classification] {
            let yy: Vec<f64> = match task {
                Task::Regression => y.clone(),
                Task::Classification => y.iter().map(|v| v.signum()).collect(),
            };
            bench_fn(&format!("cd solve {task:?} n={n} k={k}"), 5, || {
                let s = CdSolver::default().solve(task, &supports, &yy, 8.0, None);
                std::hint::black_box((s.epochs, s.gap));
            });
        }
    }

    // --- warm-start weight transfer between λ steps ---
    // two adjacent-λ working sets sharing most columns: the id-indexed
    // SupportPool transfer vs what a per-pattern hash probe would cost
    {
        let n = 5000usize;
        let k = 4000usize;
        let base = (k + 512) as u32;
        let mut pool = SupportPool::new();
        // a unique leading tid per column keeps all columns (and hence
        // SupportIds) distinct, matching the path invariant transfer
        // relies on
        let cols: Vec<Vec<u32>> = (0..k + 512)
            .map(|t| {
                let m = rng.range(2, 40);
                let mut c: Vec<u32> = sorted_sample(&mut rng, n - base as usize, m)
                    .into_iter()
                    .map(|i| i + base)
                    .collect();
                c.insert(0, t as u32);
                c
            })
            .collect();
        let mut prev = WorkingSet::new();
        for (t, c) in cols.iter().take(k).enumerate() {
            prev.insert(Pattern::Itemset(vec![t as u32]), pool.intern(c));
        }
        let mut next = WorkingSet::new();
        for (t, c) in cols.iter().skip(256).take(k).enumerate() {
            next.insert(Pattern::Itemset(vec![(t + 256) as u32]), pool.intern(c));
        }
        let w_prev: Vec<f64> = (0..k).map(|t| if t % 3 == 0 { 1.0 } else { 0.0 }).collect();
        bench_throughput("warm-start transfer_weights (cols/s)", 7, || {
            let iters = 200u64;
            for _ in 0..iters {
                std::hint::black_box(next.transfer_weights(&prev, &w_prev));
            }
            iters * k as u64
        });
    }

    // --- end-to-end λ_max search (bounded) ---
    {
        let d = generate(&ItemsetSynthConfig::preset_splice(5).scaled(0.2));
        bench_fn("lambda-max search splice@0.2 maxpat=3", 5, || {
            let lm = spp::screening::lambda_max::lambda_max(
                &d.db,
                &d.y,
                Task::Classification,
                3,
                1,
            );
            std::hint::black_box(lm.lambda_max);
        });
    }
}
