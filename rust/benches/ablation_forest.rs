//! Ablation A4: what the incremental screening forest buys.
//!
//! Same workload, same λ-grid, two engines:
//!
//! * `scratch` — the paper-literal Algorithm 1: one full substrate
//!   traversal per λ (`reuse_forest: false`, the `--no-reuse` path);
//! * `forest`  — the incremental engine: stored-tree re-evaluation with
//!   λ-range drift certificates, substrate re-entered only below
//!   re-opened frontiers.
//!
//! Both engines produce bit-identical paths (asserted here on gaps and
//! active counts; the full property lives in
//! `tests/integration_forest.rs`), so every ROW pair is a like-for-like
//! traverse-cost comparison: seconds and substrate node counts, plus
//! the forest's reuse telemetry (stored-node hits, certificate skips,
//! re-opened subtrees, solver-frozen columns).  Workload size obeys the
//! usual `SPP_BENCH_*` env knobs (`benchkit`); the synth presets at
//! `n_lambdas >= 20` are the acceptance regime: forest nodes must be
//! strictly fewer than scratch nodes.

use std::time::Instant;

use spp::benchkit::{bench_knobs, bench_threads};
use spp::data::registry::{info, lookup, Dataset};
use spp::path::{compute_path_spp, PathConfig, PathResult};

fn run(dataset: &str, default_scale: f64, maxpat: usize, default_lambdas: usize) {
    // the same env knobs as benchkit::run_figure, via the shared resolver
    let (scale, n_lambdas, ratio) = bench_knobs(default_scale, default_lambdas);
    let task = info(dataset).unwrap().task;
    let data = lookup(dataset, scale).unwrap();
    let mut results: Vec<(&str, PathResult, f64)> = Vec::new();
    for (variant, reuse) in [("scratch", false), ("forest", true)] {
        let cfg = PathConfig {
            n_lambdas,
            lambda_min_ratio: ratio,
            maxpat,
            reuse_forest: reuse,
            // pinned worker count (default 1): timings must not depend
            // on the CI runner's core count
            threads: bench_threads(),
            // A4 isolates the forest; per-λ screening pinned (the
            // chunked engine has its own ablation, A5)
            range_chunk: 1,
            ..PathConfig::default()
        };
        let t0 = Instant::now();
        let path = match &data {
            Dataset::Graphs(g) => compute_path_spp(g, &g.y, task, &cfg),
            Dataset::Itemsets(t) => compute_path_spp(&t.db, &t.y, task, &cfg),
            Dataset::Sequences(s) => compute_path_spp(&s.db, &s.y, task, &cfg),
        }
        .unwrap();
        let wall = t0.elapsed().as_secs_f64();
        let max_gap = path.points.iter().map(|p| p.gap).fold(0.0f64, f64::max);
        assert!(max_gap <= 2e-6, "{dataset}/{variant}: uncertified path");
        println!(
            "ROW fig=A4 dataset={dataset} maxpat={maxpat} lambdas={n_lambdas} \
             variant={variant} total={wall:.4} traverse={:.4} nodes={} hits={} \
             cert_skips={} reopened={} solver_screened={}",
            path.total_traverse_secs(),
            path.total_nodes(),
            path.total_forest_hits(),
            path.points.iter().map(|p| p.reuse.cert_skips).sum::<u64>(),
            path.total_reopened(),
            path.total_solver_screened(),
        );
        results.push((variant, path, wall));
    }
    let (scratch, forest) = (&results[0].1, &results[1].1);
    // like-for-like guard: identical optima at every λ
    for (a, b) in scratch.points.iter().zip(&forest.points) {
        assert_eq!(
            a.active.len(),
            b.active.len(),
            "{dataset}: engines disagree at λ={}",
            a.lambda
        );
    }
    assert!(
        forest.total_nodes() < scratch.total_nodes(),
        "{dataset}: forest engine did not reduce traversal \
         ({} vs {} nodes)",
        forest.total_nodes(),
        scratch.total_nodes()
    );
    println!(
        "A4 {dataset:<10} maxpat={maxpat} λs={n_lambdas}: traverse x{:.2} faster, \
         nodes x{:.1} fewer ({} -> {})",
        scratch.total_traverse_secs() / forest.total_traverse_secs().max(1e-12),
        scratch.total_nodes() as f64 / forest.total_nodes().max(1) as f64,
        scratch.total_nodes(),
        forest.total_nodes(),
    );
}

fn main() {
    println!("# A4 incremental-forest ablation: scratch vs forest engines, 20-λ paths");
    run("splice", 0.15, 3, 20);
    run("dna", 0.1, 3, 20);
    run("cpdb", 0.2, 3, 20);
    run("synth-seq", 0.25, 3, 20);
    println!("# expectation: forest nodes ≪ scratch nodes; traverse seconds follow;");
    println!("# hits ≈ scratch nodes (same decisions, made on stored columns)");
}
