//! Ablation A3: Rust engines vs the AOT JAX/Pallas engines via PJRT.
//!
//! * SPPC frontier scoring — the Rust sparse fold vs the Pallas kernel
//!   (which densifies to a padded (n, 256) panel).  The crossover shows
//!   where batched dense scoring would pay on a real accelerator: on
//!   CPU PJRT (interpret-mode lowering) the dense kernel moves
//!   n_pad×256 floats per block, so the sparse fold wins; on TPU the
//!   same artifact streams panels through VMEM at HBM bandwidth
//!   (DESIGN.md §8 carries the estimate).
//! * Restricted solve — f64 sparse CD vs f32 dense FISTA artifact.
//!
//! Requires `artifacts/`; prints SKIP rows when absent.

use spp::runtime::{default_artifact_dir, PjrtRuntime, XlaFistaSolver, XlaSppcScorer};
use spp::screening::fold_weights;
use spp::solver::{CdSolver, Task};
use spp::testutil::SplitMix64;

fn main() {
    println!("# A3 engine ablation (rust vs xla/PJRT)");
    let dir = default_artifact_dir();
    if !dir.join("manifest.txt").is_file() {
        println!("ROW fig=A3 SKIP no artifacts at {}", dir.display());
        return;
    }
    let rt = match PjrtRuntime::cpu(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            // e.g. a default build without the `pjrt` feature
            println!("ROW fig=A3 SKIP {e}");
            return;
        }
    };
    let mut rng = SplitMix64::new(33);

    // --- SPPC scoring ---
    for n in [648usize] {
        let y: Vec<f64> = (0..n).map(|_| if rng.coin(0.5) { 1.0 } else { -1.0 }).collect();
        let theta: Vec<f64> = (0..n).map(|_| rng.gauss() * 0.1).collect();
        let (wpos, wneg) = fold_weights(Task::Classification, &y, &theta);
        let k = 1024usize;
        let supports: Vec<Vec<u32>> = (0..k)
            .map(|_| {
                let m = rng.range(2, (n / 8).max(3));
                rng.sample_distinct(n, m).into_iter().map(|i| i as u32).collect()
            })
            .collect();
        let nnz: usize = supports.iter().map(|s| s.len()).sum();

        // rust sparse fold
        let (_, med_rust, _) = spp::benchkit::bench_fn(&format!("sppc-rust n={n} k={k}"), 9, || {
            let mut acc = 0.0f64;
            for sup in &supports {
                let mut pos = 0.0;
                let mut neg = 0.0;
                for &i in sup {
                    pos += wpos[i as usize];
                    neg += wneg[i as usize];
                }
                acc += pos.max(-neg) + 0.3 * (sup.len() as f64).sqrt();
            }
            std::hint::black_box(acc);
        });
        // xla pallas kernel
        let scorer = XlaSppcScorer::new(&rt, n).expect("scorer");
        let (_, med_xla, _) = spp::benchkit::bench_fn(&format!("sppc-xla  n={n} k={k}"), 5, || {
            let s = scorer.score(&supports, &wpos, &wneg, 0.3).expect("score");
            std::hint::black_box(s.len());
        });
        println!(
            "ROW fig=A3 bench=sppc n={n} k={k} nnz={nnz} rust_ms={:.3} xla_ms={:.3} ratio={:.1}",
            1e3 * med_rust,
            1e3 * med_xla,
            med_xla / med_rust
        );
    }

    // --- restricted solve ---
    for (n, k) in [(500usize, 50usize), (500, 200)] {
        let supports: Vec<Vec<u32>> = (0..k)
            .map(|_| {
                let m = rng.range(2, n / 4);
                rng.sample_distinct(n, m).into_iter().map(|i| i as u32).collect()
            })
            .collect();
        let y: Vec<f64> = (0..n).map(|_| rng.gauss() * 2.0).collect();
        let lam = 4.0;
        let cd = CdSolver::default();
        let (_, med_cd, _) = spp::benchkit::bench_fn(&format!("solve-cd  n={n} k={k}"), 5, || {
            let s = cd.solve(Task::Regression, &supports, &y, lam, None);
            std::hint::black_box(s.primal);
        });
        let mut fista = XlaFistaSolver::new(&rt);
        fista.max_execs = 150;
        let mut primal_xla = 0.0;
        let (_, med_xla, _) = spp::benchkit::bench_fn(&format!("solve-xla n={n} k={k}"), 3, || {
            let s = fista.solve(Task::Regression, &supports, &y, lam).expect("fista");
            primal_xla = s.primal;
            std::hint::black_box(s.execs);
        });
        let cd_primal = cd.solve(Task::Regression, &supports, &y, lam, None).primal;
        let rel = (primal_xla - cd_primal).abs() / cd_primal.abs().max(1.0);
        println!(
            "ROW fig=A3 bench=solve n={n} k={k} cd_ms={:.2} xla_ms={:.2} ratio={:.1} primal_rel_err={:.1e}",
            1e3 * med_cd,
            1e3 * med_xla,
            med_xla / med_cd,
            rel
        );
    }
    println!("# expectation on CPU PJRT: rust wins (sparse f64 vs padded dense f32);");
    println!("# the artifact path exists for accelerator targets and is verified identical.");
}
