//! Ablation A2: the screening pair's quality is the rule's power.
//!
//! Two knobs, same workload:
//!
//! * **warm vs cold pair** — Algorithm 1 screens λ_k with the λ_{k-1}
//!   optimum (warm).  The cold variant always screens with the λ_max
//!   zero-solution pair, whose duality gap at small λ is huge, so the
//!   gap-safe radius balloons and pruning collapses.
//! * **grid density** — a finer λ-grid means smaller per-step gaps.
//!   The paper's 100-step grid is not an accident; this sweep shows
//!   nodes/λ falling as the grid refines.
//!
//! Also reports the `--certify` overhead (exact dual feasibility pass).

use std::time::Instant;

use spp::data::registry::{lookup, Dataset};
use spp::mining::{Counting, PatternSubstrate};
use spp::path::{compute_path_spp, lambda_grid, working_set::WorkingSet, PathConfig};
use spp::screening::lambda_max::lambda_max;
use spp::screening::sppc::SppScreen;
use spp::screening::SupportPool;
use spp::solver::dual::safe_radius;
use spp::solver::problem::{dual_value, primal_value};
use spp::solver::{CdSolver, Task};

/// Cold screening path: the pair is ALWAYS the λmax zero solution.
fn cold_path<S: PatternSubstrate>(
    db: &S,
    y: &[f64],
    task: Task,
    maxpat: usize,
    n_lambdas: usize,
) -> (f64, u64) {
    let lm = lambda_max(db, y, task, maxpat, 1);
    let grid = lambda_grid(lm.lambda_max, n_lambdas, 0.05);
    let solver = CdSolver::default();
    let theta0: Vec<f64> = lm.slack0.iter().map(|&s| s / lm.lambda_max).collect();

    let mut pool = SupportPool::new();
    let mut ws = WorkingSet::new();
    let mut w: Vec<f64> = Vec::new();
    let mut b = lm.b0;
    let t0 = Instant::now();
    let mut nodes = 0u64;
    for &lam in &grid[1..] {
        let primal = primal_value(&lm.slack0, 0.0, lam);
        let dualv = dual_value(task, &theta0, y, lam);
        let radius = safe_radius(primal, dualv, lam);
        let mut screen = SppScreen::new(task, y, &theta0, radius, &mut pool);
        let stats = {
            let mut counting = Counting::new(&mut screen);
            db.traverse(maxpat, 1, &mut counting);
            counting.stats
        };
        nodes += stats.nodes;
        let survivors = std::mem::take(&mut screen.survivors);
        let mut new_ws = WorkingSet::new();
        let mut seen = std::collections::HashMap::new();
        for (i, p) in ws.patterns.iter().enumerate() {
            if w[i] != 0.0 {
                let sid = ws.support_ids[i];
                let idx = new_ws.insert(p.clone(), sid);
                seen.entry(sid).or_insert(idx);
            }
        }
        for s in survivors {
            if !seen.contains_key(&s.support) {
                let idx = new_ws.insert(s.pattern, s.support);
                seen.insert(s.support, idx);
            }
        }
        let w0 = new_ws.transfer_weights(&ws, &w);
        ws = new_ws;
        let cols = ws.columns(&pool);
        let sol = solver.solve(task, &cols, y, lam, Some(spp::solver::cd::Warm { w: &w0, b }));
        w = sol.w;
        b = sol.b;
    }
    (t0.elapsed().as_secs_f64(), nodes)
}

fn main() {
    println!("# A2 warm-start / grid-density ablation: splice @0.15 maxpat=3");
    let data = lookup("splice", 0.15).unwrap();
    let Dataset::Itemsets(t) = &data else { unreachable!() };
    let db = &t.db;
    let task = Task::Classification;

    // warm vs cold at a fixed grid
    let cfg = PathConfig {
        n_lambdas: 15,
        lambda_min_ratio: 0.05,
        maxpat: 3,
        threads: spp::benchkit::bench_threads(),
        // A2 measures per-λ screening-pair quality; chunking pinned off
        range_chunk: 1,
        ..PathConfig::default()
    };
    let t0 = Instant::now();
    let warm = compute_path_spp(db, &t.y, task, &cfg).unwrap();
    let warm_secs = t0.elapsed().as_secs_f64();
    println!(
        "ROW fig=A2 variant=warm total={warm_secs:.4} nodes={}",
        warm.total_nodes()
    );
    let (cold_secs, cold_nodes) = cold_path(db, &t.y, task, 3, 15);
    println!("ROW fig=A2 variant=cold total={cold_secs:.4} nodes={cold_nodes}");

    // grid density sweep (warm): nodes per λ should fall as grids refine
    for n_lambdas in [5usize, 15, 40, 100] {
        let cfg = PathConfig {
            n_lambdas,
            lambda_min_ratio: 0.05,
            maxpat: 3,
            threads: spp::benchkit::bench_threads(),
            range_chunk: 1,
            ..PathConfig::default()
        };
        let t1 = Instant::now();
        let p = compute_path_spp(db, &t.y, task, &cfg).unwrap();
        println!(
            "ROW fig=A2 variant=grid lambdas={n_lambdas} total={:.4} nodes={} \
             nodes_per_lambda={:.0}",
            t1.elapsed().as_secs_f64(),
            p.total_nodes(),
            p.total_nodes() as f64 / n_lambdas as f64
        );
    }

    // certify overhead
    let mut ccfg = cfg;
    ccfg.certify = true;
    let t2 = Instant::now();
    let certified = compute_path_spp(db, &t.y, task, &ccfg).unwrap();
    println!(
        "ROW fig=A2 variant=certify total={:.4} nodes={}",
        t2.elapsed().as_secs_f64(),
        certified.total_nodes()
    );
    println!("# expectation: cold nodes ≫ warm nodes; nodes/λ falls with grid density;");
    println!("# certify ≈ 2× traversal (one exact feasibility search per λ)");
}
