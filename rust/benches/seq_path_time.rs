//! Sequence substrate: SPP vs boosting on the `synth-seq` preset.
//!
//! Beyond the paper's figures — the same (dataset × maxpat × method)
//! sweep as Figures 2/3, run over the PrefixSpan subsequence tree
//! through the open `PatternSubstrate` trait.  The headline quantity is
//! unchanged: one tree search per λ (SPP) vs one per round (boosting),
//! now on a third pattern language the original code could not express.
fn main() {
    spp::benchkit::run_figure("seq", spp::benchkit::SEQ_WORKLOADS);
}
