//! Tabular-rule substrate: SPP vs boosting on the `synth-tab` preset.
//!
//! Beyond the paper's figures — the same (dataset × maxpat × method)
//! sweep as Figures 2/3, run over the RuleFit threshold-refinement
//! tree through the open `PatternSubstrate` trait.  The headline
//! quantity is unchanged: one tree search per λ (SPP) vs one per round
//! (boosting), now on numeric tabular data the original code could not
//! express.
fn main() {
    spp::benchkit::run_figure("tab", spp::benchkit::TAB_WORKLOADS);
}
