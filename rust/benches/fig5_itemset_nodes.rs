//! Figure 5: # traversed nodes, item-set mining.  Same sweep as
//! Figure 3; the reported currency is ROW ... nodes=...
fn main() {
    spp::benchkit::run_figure("fig5", spp::benchkit::ITEMSET_WORKLOADS);
}
