//! Figure 3: computation time, item-set classification/regression.
//!
//! Paper setup: splice / a9a (classification), dna / protein
//! (regression); SPP vs boosting; 100-λ path; maxpat ∈ {3..6}.
fn main() {
    spp::benchkit::run_figure("fig3", spp::benchkit::ITEMSET_WORKLOADS);
}
