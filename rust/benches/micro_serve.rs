//! MICRO: naive vs compiled batch matchers, per substrate.
//!
//! The serve layer's claim is one pass per record: the naive scorer
//! makes `records × patterns` matcher calls, the compiled matcher
//! walks each record once through a specialized index.  One `ROW` per
//! substrate records both rates (records/s), the work metric on each
//! side (`naive_calls` = records × patterns vs `compiled_ops` =
//! posting visits / trie activations / containment calls), and the
//! speedup.  Every measured pair is asserted score-bit-identical
//! inline first, so a matcher regression fails the bench before it
//! skews a number.  `SPP_BENCH_SCALE` scales the dataset (CI smoke
//! runs 0.05).

use spp::data::registry::{self, Dataset};
use spp::mining::{Pattern, PatternNode, PatternSubstrate, Walk};
use spp::model::SparsePatternModel;
use spp::serve::compiled::CompiledModel;

/// Best records/s over `samples` runs of `f` (returns records done).
fn best_rate<F: FnMut() -> u64>(samples: usize, mut f: F) -> f64 {
    let mut best = 0.0f64;
    for _ in 0..samples {
        let t = std::time::Instant::now();
        let recs = f();
        let dt = t.elapsed().as_secs_f64();
        best = best.max(recs as f64 / dt);
    }
    best
}

/// Mine up to `cap` patterns and attach deterministic weights.
fn mined_model(data: &Dataset, maxpat: usize, minsup: usize, cap: usize) -> SparsePatternModel {
    let mut pats: Vec<Pattern> = Vec::new();
    {
        let mut v = |n: &PatternNode<'_>| {
            pats.push(n.to_pattern());
            Walk::Descend
        };
        match data {
            Dataset::Graphs(g) => g.traverse(maxpat, minsup, &mut v),
            Dataset::Itemsets(t) => t.db.traverse(maxpat, minsup, &mut v),
            Dataset::Sequences(s) => s.db.traverse(maxpat, minsup, &mut v),
        }
    }
    pats.truncate(cap);
    let terms = pats
        .into_iter()
        .enumerate()
        .map(|(i, p)| (p, ((i % 7) as f64 - 3.0) * 0.25 + 0.125))
        .collect();
    SparsePatternModel { task: spp::solver::Task::Classification, lambda: 0.25, b: 0.375, terms }
}

fn naive_scores(model: &SparsePatternModel, data: &Dataset) -> Vec<f64> {
    match data {
        Dataset::Graphs(g) => g.graphs.iter().map(|r| model.score_graph(r)).collect(),
        Dataset::Itemsets(t) => t.db.items.iter().map(|r| model.score_itemset(r)).collect(),
        Dataset::Sequences(s) => s.db.seqs.iter().map(|r| model.score_sequence(r)).collect(),
    }
}

fn main() {
    let scale: f64 = std::env::var("SPP_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.0);
    println!("# micro_serve: naive vs compiled matchers (SPP_BENCH_SCALE={scale})");

    // (dataset, base scale, maxpat, minsup, pattern cap) per substrate.
    let cases = [
        ("splice", 0.5, 3, 5, 400),
        ("synth-seq", 0.5, 3, 2, 400),
        ("cpdb", 0.3, 3, 2, 200),
    ];
    for (name, base, maxpat, minsup, cap) in cases {
        let data = match registry::lookup(name, (base * scale).clamp(0.01, 1.0)) {
            Ok(d) => d,
            Err(e) => {
                eprintln!("skip {name}: {e}");
                continue;
            }
        };
        let model = mined_model(&data, maxpat, minsup, cap);
        if model.terms.is_empty() {
            eprintln!("skip {name}: no patterns mined");
            continue;
        }
        let kind = model.terms[0].0.kind_tag();
        let compiled = CompiledModel::compile_for(&model, kind).expect("compile");
        let n = match &data {
            Dataset::Graphs(g) => g.graphs.len(),
            Dataset::Itemsets(t) => t.db.items.len(),
            Dataset::Sequences(s) => s.db.seqs.len(),
        } as u64;

        // Inline oracle: the compiled matcher must be score-bit-exact
        // against the naive scorer before any rate is reported.
        let oracle = naive_scores(&model, &data);
        let out = compiled.score_dataset(&data, 1).expect("score");
        assert_eq!(out.scores.len(), oracle.len());
        for (a, b) in out.scores.iter().zip(&oracle) {
            assert_eq!(a.to_bits(), b.to_bits(), "{name}: compiled != naive");
        }
        let compiled_ops = out.ops;
        let naive_calls = n * model.terms.len() as u64;

        let naive_rate = best_rate(3, || {
            std::hint::black_box(naive_scores(&model, &data));
            n
        });
        let compiled_rate = best_rate(3, || {
            let out = compiled.score_dataset(&data, 1).expect("score");
            std::hint::black_box(out.scores.len());
            n
        });
        println!(
            "ROW bench=serve kind={kind} dataset={name} n={n} patterns={} \
             naive_calls={naive_calls} compiled_ops={compiled_ops} \
             naive_rps={:.1} compiled_rps={:.1} speedup={:.2}",
            model.terms.len(),
            naive_rate,
            compiled_rate,
            compiled_rate / naive_rate
        );
    }
}
