//! Figure 2: computation time, graph classification/regression.
//!
//! Paper setup: CPDB / Mutagenicity (classification), Bergstrom /
//! Karthikeyan (regression); SPP vs boosting; 100-λ path to 0.01·λmax;
//! bars split into traverse + solve; maxpat ∈ {5..10}.
//!
//! Default run uses reduced scale/λ-grid (see benchkit env knobs);
//! `SPP_BENCH_FULL=1` reproduces the paper's exact sweep.
fn main() {
    spp::benchkit::run_figure("fig2", spp::benchkit::GRAPH_WORKLOADS);
}
