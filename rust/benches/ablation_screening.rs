//! Ablation A1: what each part of the screening rule buys.
//!
//! Three variants on the same workload and λ-grid:
//!
//! * `sppc+ub`   — the full method (Theorem 2 subtree rule + Lemma 6
//!                 per-feature UB trimming Â);
//! * `sppc-only` — subtree rule alone (Â keeps every non-pruned node);
//! * `ub-only`   — per-feature safe screening WITHOUT the subtree rule:
//!                 the tree is walked exhaustively and each node is
//!                 tested individually.  This is what classic gap-safe
//!                 screening would do in pattern space — the paper's
//!                 motivation for SPP is exactly that this traversal is
//!                 intractable at scale.
//!
//! All three run the from-scratch traversal per λ (the quantity being
//! ablated is the rule itself; `ablation_forest` ablates the reuse).
//! Reported per λ-path: wall time, traversed nodes, Σ|Â|.

use std::time::Instant;

use spp::data::registry::{lookup, Dataset};
use spp::mining::{Counting, PatternNode, PatternSubstrate, TreeVisitor, Walk};
use spp::path::{lambda_grid, working_set::WorkingSet};
use spp::screening::lambda_max::lambda_max;
use spp::screening::sppc::SppScreen;
use spp::screening::SupportPool;
use spp::solver::dual::safe_radius;
use spp::solver::problem::{dual_value, primal_value};
use spp::solver::{CdSolver, Task};

/// SppScreen wrapper that disables subtree pruning (ub-only mode).
struct NoPrune<'a, 'p>(&'a mut SppScreen<'p>);

impl TreeVisitor for NoPrune<'_, '_> {
    fn visit(&mut self, node: &PatternNode<'_>) -> Walk {
        let _ = self.0.visit(node);
        Walk::Descend
    }
}

#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Full,
    SppcOnly,
    UbOnly,
}

fn run<S: PatternSubstrate>(db: &S, y: &[f64], task: Task, maxpat: usize, mode: Mode) {
    let lm = lambda_max(db, y, task, maxpat, 1);
    let grid = lambda_grid(lm.lambda_max, 15, 0.05);
    let solver = CdSolver::default();

    let mut pool = SupportPool::new();
    let mut ws = WorkingSet::new();
    let mut w: Vec<f64> = Vec::new();
    let mut b = lm.b0;
    let mut slack = lm.slack0.clone();
    let mut theta: Vec<f64> = lm.slack0.iter().map(|&s| s / lm.lambda_max).collect();

    let t0 = Instant::now();
    let mut nodes = 0u64;
    let mut sum_ahat = 0u64;
    for &lam in &grid[1..] {
        let l1: f64 = w.iter().map(|x| x.abs()).sum();
        let primal = primal_value(&slack, l1, lam);
        let dualv = dual_value(task, &theta, y, lam);
        let radius = safe_radius(primal, dualv, lam);
        let mut screen = SppScreen::new(task, y, &theta, radius, &mut pool);
        screen.feature_test = mode != Mode::SppcOnly;
        let stats = if mode == Mode::UbOnly {
            let mut np = NoPrune(&mut screen);
            let mut counting = Counting::new(&mut np);
            db.traverse(maxpat, 1, &mut counting);
            counting.stats
        } else {
            let mut counting = Counting::new(&mut screen);
            db.traverse(maxpat, 1, &mut counting);
            counting.stats
        };
        nodes += stats.nodes;
        sum_ahat += screen.survivors.len() as u64;
        let survivors = std::mem::take(&mut screen.survivors);

        let mut new_ws = WorkingSet::new();
        let mut seen = std::collections::HashMap::new();
        for (i, p) in ws.patterns.iter().enumerate() {
            if w[i] != 0.0 {
                let sid = ws.support_ids[i];
                let idx = new_ws.insert(p.clone(), sid);
                seen.entry(sid).or_insert(idx);
            }
        }
        for s in survivors {
            if !seen.contains_key(&s.support) {
                let idx = new_ws.insert(s.pattern, s.support);
                seen.insert(s.support, idx);
            }
        }
        let w0 = new_ws.transfer_weights(&ws, &w);
        ws = new_ws;
        let cols = ws.columns(&pool);
        let sol = solver.solve(
            task,
            &cols,
            y,
            lam,
            Some(spp::solver::cd::Warm { w: &w0, b }),
        );
        w = sol.w;
        b = sol.b;
        slack = sol.slack;
        theta = sol.theta;
    }
    let name = match mode {
        Mode::Full => "sppc+ub",
        Mode::SppcOnly => "sppc-only",
        Mode::UbOnly => "ub-only",
    };
    println!(
        "ROW fig=A1 mode={name} total={:.4} nodes={nodes} sum_ahat={sum_ahat}",
        t0.elapsed().as_secs_f64()
    );
}

fn main() {
    println!("# A1 screening ablation: splice @0.15 maxpat=3, 15-λ path");
    let data = lookup("splice", 0.15).unwrap();
    let Dataset::Itemsets(t) = &data else { unreachable!() };
    for mode in [Mode::Full, Mode::SppcOnly, Mode::UbOnly] {
        run(&t.db, &t.y, Task::Classification, 3, mode);
    }
    println!("# expectation: sppc+ub ≈ sppc-only time ≪ ub-only time;");
    println!("# sum_ahat(sppc+ub) < sum_ahat(sppc-only); ub-only nodes = full tree × λ count");
}
