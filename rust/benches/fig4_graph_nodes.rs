//! Figure 4: # traversed nodes, graph mining.  Same sweep as Figure 2;
//! the reported currency is the per-path total of visitor invocations
//! (ROW ... nodes=...).
fn main() {
    spp::benchkit::run_figure("fig4", spp::benchkit::GRAPH_WORKLOADS);
}
