//! MICRO: sparse vs hybrid support-column kernels in isolation.
//!
//! The quantities `crate::columns` exists for: inner products
//! (`dot`, the SPPC/CD gather) and tid-list intersection (the itemset
//! hot loop), measured on the SAME id sets stored both ways — plain
//! sorted `Vec<u32>` (the scalar oracle) vs [`HybridColumn`] (dense
//! 4096-id chunks as 64-bit bitmap words).  One `ROW` line per
//! (kernel, density) records both rates and the speedup; every
//! measured pair is also asserted bit-identical inline, so a kernel
//! regression fails the bench before it skews a number.
//!
//! Densities bracket the paper's regimes: splice/dna supports cover
//! most records (0.5–0.9), a9a/cpdb sit near 0.1, and 0.01 is the
//! sparse tail where the hybrid layout must fall back gracefully.
//! `SPP_BENCH_SCALE` scales the record count (CI smoke runs 0.05).

use spp::columns::{ColumnRead, HybridColumn};
use spp::mining::itemset::intersect_into;
use spp::testutil::SplitMix64;

fn sorted_sample(rng: &mut SplitMix64, universe: usize, len: usize) -> Vec<u32> {
    rng.sample_distinct(universe, len).into_iter().map(|i| i as u32).collect()
}

/// Best ops/s over `samples` runs of `f` (which returns its op count).
fn best_rate<F: FnMut() -> u64>(samples: usize, mut f: F) -> f64 {
    let mut best = 0.0f64;
    for _ in 0..samples {
        let t = std::time::Instant::now();
        let ops = f();
        let dt = t.elapsed().as_secs_f64();
        best = best.max(ops as f64 / dt);
    }
    best
}

fn main() {
    let scale: f64 = std::env::var("SPP_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.0);
    let n = ((32_768.0 * scale) as usize).max(8_192);
    let mut rng = SplitMix64::new(3);
    let g: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
    println!("# micro_bitset_kernels: n={n} (SPP_BENCH_SCALE={scale})");

    // --- dot products (the SPPC fold / CD gather shape) ---
    for density in [0.9f64, 0.5, 0.1, 0.01] {
        let m = ((n as f64 * density) as usize).max(1);
        let ids = sorted_sample(&mut rng, n, m);
        let col = HybridColumn::from_sorted(ids.clone());
        // inline oracle: the word kernel must be bit-identical
        assert_eq!(col.dot_words(&g).to_bits(), ids.as_slice().dot(&g).to_bits());
        let iters = (40_000_000 / m).clamp(8, 20_000) as u64;
        let sparse = best_rate(5, || {
            for _ in 0..iters {
                std::hint::black_box(ids.as_slice().dot(&g));
            }
            iters * m as u64
        });
        let hybrid = best_rate(5, || {
            for _ in 0..iters {
                std::hint::black_box(col.dot_words(&g));
            }
            iters * m as u64
        });
        println!(
            "ROW bench=bitset kernel=dot n={n} density={density} nnz={m} \
             sparse_mops={:.1} hybrid_mops={:.1} speedup={:.2}",
            sparse / 1e6,
            hybrid / 1e6,
            hybrid / sparse
        );
    }

    // --- tid-list intersection (the itemset traversal hot loop) ---
    for (da, db) in [(0.9f64, 0.9f64), (0.5, 0.5), (0.5, 0.01), (0.1, 0.1)] {
        let (ma, mb) = (
            ((n as f64 * da) as usize).max(1),
            ((n as f64 * db) as usize).max(1),
        );
        let a = sorted_sample(&mut rng, n, ma);
        let b = sorted_sample(&mut rng, n, mb);
        let (ha, hb) = (
            HybridColumn::from_sorted(a.clone()),
            HybridColumn::from_sorted(b.clone()),
        );
        let mut out_v: Vec<u32> = Vec::with_capacity(ma.min(mb));
        let mut out_h = HybridColumn::default();
        // inline oracle: identical id sets out of both kernels
        intersect_into(&a, &b, &mut out_v);
        HybridColumn::intersect_into(&ha, &hb, &mut out_h);
        assert_eq!(out_h.ids(), &out_v[..]);
        let iters = (20_000_000 / (ma + mb)).clamp(4, 10_000) as u64;
        let ops = (ma + mb) as u64;
        let sparse = best_rate(5, || {
            for _ in 0..iters {
                intersect_into(&a, &b, &mut out_v);
                std::hint::black_box(out_v.len());
            }
            iters * ops
        });
        let hybrid = best_rate(5, || {
            for _ in 0..iters {
                HybridColumn::intersect_into(&ha, &hb, &mut out_h);
                std::hint::black_box(out_h.len());
            }
            iters * ops
        });
        println!(
            "ROW bench=bitset kernel=intersect n={n} density_a={da} density_b={db} \
             out={} sparse_mops={:.1} hybrid_mops={:.1} speedup={:.2}",
            out_v.len(),
            sparse / 1e6,
            hybrid / 1e6,
            hybrid / sparse
        );
    }
}
