//! Subsequence enumeration tree, PrefixSpan style (Pei et al., ICDE
//! 2001) — the third substrate, proving [`super::PatternSubstrate`] is
//! genuinely open.
//!
//! A pattern is an ordered list of symbols `⟨a_1 … a_k⟩` (repeats
//! allowed); it matches record `s` iff it is a — not necessarily
//! contiguous — subsequence of `s`.  The enumeration tree extends each
//! prefix by one symbol, so every pattern has exactly one parent (its
//! longest proper prefix) and is visited exactly once, in lexicographic
//! order.
//!
//! Traversal uses the classic pseudo-projection: each node carries, per
//! supporting sequence, the position just past the *leftmost* embedding
//! of the prefix.  Greedy leftmost matching is optimal for subsequence
//! containment (it leaves the longest possible suffix), so the
//! projected suffix contains symbol `a` iff `prefix·a` is a subsequence
//! of the record — which makes the reported supports exactly the
//! `x_{it}` columns, and makes them shrink along every root-to-leaf
//! path.  That anti-monotonicity is what the SPP rule and the boosting
//! envelope bound require of a substrate.

use super::{PatternNode, SubtreeVisitors, TreeVisitor, Walk};
use crate::data::sequence::Sequences;

/// Configurable PrefixSpan miner.
pub struct PrefixSpanMiner<'a> {
    db: &'a Sequences,
    /// Maximum pattern length (the paper's `maxpat`).
    pub maxpat: usize,
    /// Minimum support; patterns below it are not visited (their
    /// subtrees are skipped — safe, supports are anti-monotone).
    pub minsup: usize,
}

/// Reusable per-suffix first-occurrence marks (one stamp slot per
/// symbol; epoch bumped per suffix scan, so no clearing in the loop).
struct Scratch {
    stamp: Vec<u64>,
    epoch: u64,
}

impl<'a> PrefixSpanMiner<'a> {
    pub fn new(db: &'a Sequences, maxpat: usize) -> Self {
        PrefixSpanMiner {
            db,
            maxpat,
            minsup: 1,
        }
    }

    /// Depth-1 pseudo-projections: per symbol, the position past its
    /// first occurrence in every containing sequence (ascending sid),
    /// minsup-filtered, in symbol order.  The ONE root-frontier
    /// definition shared by [`Self::traverse`] and
    /// [`Self::traverse_par`] — the splice guarantee depends on both
    /// engines expanding the same frontier.
    fn root_projections(&self) -> Vec<(u32, Vec<(u32, u32)>)> {
        let mut scratch = Scratch {
            stamp: vec![0; self.db.n_symbols],
            epoch: 0,
        };
        let mut ext: std::collections::BTreeMap<u32, Vec<(u32, u32)>> =
            std::collections::BTreeMap::new();
        for sid in 0..self.db.seqs.len() as u32 {
            let seq = &self.db.seqs[sid as usize];
            scratch.epoch += 1;
            for (k, &a) in seq.iter().enumerate() {
                let slot = &mut scratch.stamp[a as usize];
                if *slot != scratch.epoch {
                    *slot = scratch.epoch;
                    ext.entry(a).or_default().push((sid, k as u32 + 1));
                }
            }
        }
        ext.into_iter().filter(|(_, c)| c.len() >= self.minsup).collect()
    }

    /// Depth-first traversal; the visitor sees each subsequence pattern
    /// exactly once, in lexicographic order.
    pub fn traverse<V: TreeVisitor + ?Sized>(&self, visitor: &mut V) {
        if self.maxpat == 0 || self.db.seqs.is_empty() {
            return;
        }
        let roots = self.root_projections();
        let mut prefix: Vec<u32> = Vec::with_capacity(self.maxpat);
        let mut scratch = Scratch {
            stamp: vec![0; self.db.n_symbols],
            epoch: 0,
        };
        for (a, child) in &roots {
            prefix.push(*a);
            let support: Vec<u32> = child.iter().map(|&(sid, _)| sid).collect();
            let node = PatternNode::sequence(&prefix, &support);
            let walk = visitor.visit(&node);
            if walk == Walk::Descend && prefix.len() < self.maxpat {
                self.recurse(child, &mut prefix, &mut scratch, visitor);
            }
            prefix.pop();
        }
    }

    /// Subtree-parallel traversal (see
    /// [`crate::mining::PatternSubstrate::traverse_parallel`]): the
    /// root projection pass (`root_projections`, shared with the
    /// sequential engine) runs once; each surviving symbol's
    /// pseudo-projection is then an independent subtree task with its
    /// own scratch marks, so per-subtree node sequences concatenated in
    /// symbol order equal the sequential traversal.
    pub fn traverse_par<F: SubtreeVisitors>(&self, threads: usize, factory: &F) -> Vec<F::V> {
        if self.maxpat == 0 || self.db.seqs.is_empty() {
            return Vec::new();
        }
        let roots = self.root_projections();
        let roots = &roots;
        crate::runtime::parallel::map_indexed(threads, roots.len(), move |i| {
            let mut visitor = factory.visitor(i);
            let (a, child) = &roots[i];
            let mut prefix = vec![*a];
            let support: Vec<u32> = child.iter().map(|&(sid, _)| sid).collect();
            let node = PatternNode::sequence(&prefix, &support);
            let walk = visitor.visit(&node);
            if walk == Walk::Descend && prefix.len() < self.maxpat {
                let mut scratch = Scratch {
                    stamp: vec![0; self.db.n_symbols],
                    epoch: 0,
                };
                self.recurse(child, &mut prefix, &mut scratch, &mut visitor);
            }
            visitor
        })
    }

    /// `proj` holds one `(sid, pos)` entry per supporting sequence:
    /// `pos` is just past the leftmost embedding of `prefix` in `sid`.
    /// Entries are in ascending `sid` order, so child supports come out
    /// sorted for free.
    fn recurse<V: TreeVisitor + ?Sized>(
        &self,
        proj: &[(u32, u32)],
        prefix: &mut Vec<u32>,
        scratch: &mut Scratch,
        visitor: &mut V,
    ) {
        // One pass over the projected suffixes: for each symbol, the
        // first occurrence per sequence becomes the child projection.
        let mut ext: std::collections::BTreeMap<u32, Vec<(u32, u32)>> =
            std::collections::BTreeMap::new();
        for &(sid, pos) in proj {
            let seq = &self.db.seqs[sid as usize];
            scratch.epoch += 1;
            for (k, &a) in seq[pos as usize..].iter().enumerate() {
                let slot = &mut scratch.stamp[a as usize];
                if *slot != scratch.epoch {
                    *slot = scratch.epoch;
                    ext.entry(a).or_default().push((sid, pos + k as u32 + 1));
                }
            }
        }
        for (a, child) in &ext {
            if child.len() < self.minsup {
                continue;
            }
            prefix.push(*a);
            let support: Vec<u32> = child.iter().map(|&(sid, _)| sid).collect();
            let node = PatternNode::sequence(prefix, &support);
            let walk = visitor.visit(&node);
            if walk == Walk::Descend && prefix.len() < self.maxpat {
                self.recurse(child, prefix, scratch, visitor);
            }
            prefix.pop();
        }
    }
}

/// Length of the longest common prefix of two symbol lists.
///
/// The enumeration tree's parent relation *is* "longest proper prefix"
/// (module docs), so this is the amount of tree path two patterns
/// share.  The serve-time compiled matcher (`serve::compiled`) uses it
/// to fold a model's sequence patterns, sorted lexicographically, into
/// a shared-prefix discrimination trie with a stack walk.
#[inline]
pub fn common_prefix_len(a: &[u32], b: &[u32]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sequence::is_subsequence;
    use crate::mining::Pattern;
    use crate::testutil::oracle;

    #[test]
    fn common_prefix_len_basics() {
        assert_eq!(common_prefix_len(&[], &[1, 2]), 0);
        assert_eq!(common_prefix_len(&[1, 2, 3], &[1, 2, 9]), 2);
        assert_eq!(common_prefix_len(&[1, 2], &[1, 2, 9]), 2);
        assert_eq!(common_prefix_len(&[4], &[5]), 0);
    }

    fn db() -> Sequences {
        Sequences {
            n_symbols: 4,
            seqs: vec![
                vec![0, 1, 2],
                vec![1, 0, 1],
                vec![2, 2, 3],
                vec![0, 1],
            ],
        }
    }

    fn collect(db: &Sequences, maxpat: usize, minsup: usize) -> Vec<(Vec<u32>, Vec<u32>)> {
        let mut out = Vec::new();
        let mut v = |n: &PatternNode<'_>| {
            if let Pattern::Sequence(s) = n.to_pattern() {
                out.push((s, n.support.to_vec()));
            }
            Walk::Descend
        };
        let mut m = PrefixSpanMiner::new(db, maxpat);
        m.minsup = minsup;
        m.traverse(&mut v);
        out
    }

    #[test]
    fn matches_bruteforce_enumeration() {
        let db = db();
        for maxpat in [1usize, 2, 3] {
            let got: std::collections::BTreeMap<Vec<u32>, Vec<u32>> =
                collect(&db, maxpat, 1).into_iter().collect();
            let brute = oracle::all_sequences(&db, maxpat);
            assert_eq!(got, brute, "maxpat={maxpat}");
        }
    }

    #[test]
    fn supports_agree_with_subsequence_matcher() {
        let db = db();
        for (pat, sup) in collect(&db, 3, 1) {
            let expected: Vec<u32> = db
                .seqs
                .iter()
                .enumerate()
                .filter(|(_, s)| is_subsequence(s, &pat))
                .map(|(i, _)| i as u32)
                .collect();
            assert_eq!(sup, expected, "pattern {pat:?}");
        }
    }

    #[test]
    fn repeats_are_enumerated() {
        // <1,1> occurs in [1,0,1]; <2,2> occurs in [2,2,3]
        let got: std::collections::BTreeMap<Vec<u32>, Vec<u32>> =
            collect(&db(), 2, 1).into_iter().collect();
        assert_eq!(got[&vec![1u32, 1]], vec![1]);
        assert_eq!(got[&vec![2u32, 2]], vec![2]);
    }

    #[test]
    fn respects_maxpat_and_minsup() {
        let db = db();
        assert!(collect(&db, 2, 1).iter().all(|(p, _)| p.len() <= 2));
        assert!(collect(&db, 3, 2).iter().all(|(_, s)| s.len() >= 2));
        assert!(collect(&db, 0, 1).is_empty());
    }

    #[test]
    fn prune_skips_subtree_but_not_siblings() {
        let db = db();
        let mut seen: Vec<Vec<u32>> = Vec::new();
        let mut v = |n: &PatternNode<'_>| {
            let Pattern::Sequence(s) = n.to_pattern() else {
                unreachable!()
            };
            seen.push(s.clone());
            if s == vec![0] {
                Walk::Prune
            } else {
                Walk::Descend
            }
        };
        PrefixSpanMiner::new(&db, 3).traverse(&mut v);
        assert!(seen.contains(&vec![0]));
        assert!(!seen.iter().any(|s| s.len() > 1 && s[0] == 0));
        assert!(seen.contains(&vec![1, 2]), "{seen:?}"); // sibling subtree intact
    }

    #[test]
    fn parallel_traversal_matches_sequential_blocks() {
        struct Coll(Vec<(Vec<u32>, Vec<u32>)>);
        impl TreeVisitor for Coll {
            fn visit(&mut self, n: &PatternNode<'_>) -> Walk {
                if let Pattern::Sequence(s) = n.to_pattern() {
                    self.0.push((s, n.support.to_vec()));
                }
                Walk::Descend
            }
        }
        struct Fac;
        impl SubtreeVisitors for Fac {
            type V = Coll;

            fn visitor(&self, _root: usize) -> Coll {
                Coll(Vec::new())
            }
        }
        let db = db();
        for (maxpat, minsup, threads) in [(3, 1, 1), (3, 1, 4), (2, 2, 2)] {
            let want = collect(&db, maxpat, minsup);
            let mut m = PrefixSpanMiner::new(&db, maxpat);
            m.minsup = minsup;
            let got: Vec<(Vec<u32>, Vec<u32>)> =
                m.traverse_par(threads, &Fac).into_iter().flat_map(|c| c.0).collect();
            assert_eq!(got, want, "maxpat={maxpat} minsup={minsup} threads={threads}");
        }
    }

    #[test]
    fn anti_monotone_supports_along_paths() {
        let db = db();
        let mut stack: Vec<Vec<u32>> = Vec::new();
        let mut v = |n: &PatternNode<'_>| {
            while stack.len() >= n.depth {
                stack.pop();
            }
            if let Some(parent) = stack.last() {
                assert!(n.support.iter().all(|t| parent.contains(t)));
            }
            stack.push(n.support.to_vec());
            Walk::Descend
        };
        PrefixSpanMiner::new(&db, 3).traverse(&mut v);
    }
}
