//! Item-set enumeration tree (paper Fig. 1, right).
//!
//! Depth-first prefix extension in the eclat vertical layout: a node is
//! an item-set `{j_1 < … < j_k}`; its children extend with `j > j_k`.
//! Each node carries its transaction-id list; a child's tid-list is the
//! intersection of the parent's with the new item's — so supports
//! shrink monotonically along every path, which is exactly the
//! anti-monotonicity the SPP / boosting bounds need.
//!
//! Candidate item lists are propagated downward (a child only considers
//! items that still have non-empty intersection at the parent), keeping
//! per-node work `O(Σ |candidate tid-lists|)` with zero allocation in
//! the intersection inner loop.
//!
//! Tid-lists are carried as any [`TidSet`]: plain sorted `Vec<u32>`
//! (the scalar oracle) or [`HybridColumn`], whose dense chunks
//! intersect by 64-bit word ANDs.  Both produce identical id lists, so
//! the visitor always sees the same sorted `&[u32]` support.

use super::{PatternNode, SubtreeVisitors, TreeVisitor, Walk};
use crate::columns::{resolve_columns, ColumnLayout, HybridColumn, TidSet};
use crate::data::Transactions;

/// Where the depth-1 vertical layout comes from: a borrowed in-memory
/// database (tid-lists built on demand), or pre-built `(item,
/// tid-list)` pairs — the out-of-core sharded traversal
/// (`storage::ShardCodec for Transactions`) streams each shard once to
/// assemble exactly the pairs the in-memory path would have built, so
/// both sources drive bit-identical traversals.
enum VerticalSource<'a> {
    Db(&'a Transactions),
    Owned(Vec<(u32, Vec<u32>)>),
}

/// Configurable item-set miner.
pub struct ItemsetMiner<'a> {
    source: VerticalSource<'a>,
    /// Maximum item-set size (the paper's `maxpat`).
    pub maxpat: usize,
    /// Minimum support; patterns below it are not visited (and their
    /// subtrees are skipped — safe, supports are anti-monotone).
    pub minsup: usize,
    /// Tid-list carrier: `Sparse` walks sorted `Vec<u32>` lists,
    /// `Hybrid` intersects dense chunks by word ANDs.  Defaults to the
    /// `SPP_COLUMNS` resolution; the enumerated patterns and supports
    /// are identical either way.
    pub layout: ColumnLayout,
}

impl<'a> ItemsetMiner<'a> {
    pub fn new(db: &'a Transactions, maxpat: usize) -> Self {
        ItemsetMiner {
            source: VerticalSource::Db(db),
            maxpat,
            minsup: 1,
            layout: resolve_columns(None),
        }
    }

    /// A miner over a pre-built vertical layout: ascending `(item,
    /// sorted global tid-list)` pairs.  Eclat never touches records —
    /// only this layout — so a caller that can produce the pairs some
    /// other way (e.g. streamed shard-by-shard) gets the exact
    /// traversal [`Self::new`] would run on the equivalent database.
    pub fn from_tidlists(pairs: Vec<(u32, Vec<u32>)>, maxpat: usize) -> ItemsetMiner<'static> {
        ItemsetMiner {
            source: VerticalSource::Owned(pairs),
            maxpat,
            minsup: 1,
            layout: resolve_columns(None),
        }
    }

    /// Depth-1 candidates: the vertical tid-list layout with the minsup
    /// filter applied, in item order.  The ONE root-frontier definition
    /// shared by [`Self::traverse`] and [`Self::traverse_par`] — the
    /// splice guarantee depends on both engines expanding the same
    /// frontier.
    fn root_candidates(&self) -> Vec<(u32, Vec<u32>)> {
        let pairs: Vec<(u32, Vec<u32>)> = match &self.source {
            VerticalSource::Db(db) => db
                .tidlists()
                .into_iter()
                .enumerate()
                .map(|(j, t)| (j as u32, t))
                .collect(),
            // Cloned because the carriers below take ownership; the
            // transient copy is the minsup-filtered vertical layout,
            // not the record database.
            VerticalSource::Owned(pairs) => pairs.clone(),
        };
        pairs.into_iter().filter(|(_, t)| t.len() >= self.minsup).collect()
    }

    /// Depth-first traversal; the visitor sees each item-set exactly
    /// once, in lexicographic order.
    pub fn traverse<V: TreeVisitor + ?Sized>(&self, visitor: &mut V) {
        match self.layout {
            ColumnLayout::Sparse => self.traverse_with::<Vec<u32>, V>(visitor),
            ColumnLayout::Hybrid => self.traverse_with::<HybridColumn, V>(visitor),
        }
    }

    fn traverse_with<T: TidSet, V: TreeVisitor + ?Sized>(&self, visitor: &mut V) {
        if self.maxpat == 0 {
            return;
        }
        let root: Vec<(u32, T)> = self
            .root_candidates()
            .into_iter()
            .map(|(j, t)| (j, T::from_sorted(t)))
            .collect();
        let mut prefix: Vec<u32> = Vec::with_capacity(self.maxpat);
        // Buffer pools: tid-list carriers and per-node candidate lists
        // are recycled across the whole traversal, so the hot loop does
        // no allocation once the pools warm up.
        let mut pool = Pools::default();
        self.recurse(&root, &mut prefix, &mut pool, visitor);
    }

    /// Subtree-parallel traversal (see
    /// [`crate::mining::PatternSubstrate::traverse_parallel`]): the
    /// root candidate list — the vertical tid-list layout — is built
    /// once and shared read-only; each depth-1 item's subtree is an
    /// independent task (its children come from the candidates *after*
    /// it, intersected with its tids, exactly as in [`Self::traverse`]),
    /// so per-subtree node sequences concatenated in item order equal
    /// the sequential traversal.
    pub fn traverse_par<F: SubtreeVisitors>(&self, threads: usize, factory: &F) -> Vec<F::V> {
        match self.layout {
            ColumnLayout::Sparse => self.traverse_par_with::<Vec<u32>, F>(threads, factory),
            ColumnLayout::Hybrid => self.traverse_par_with::<HybridColumn, F>(threads, factory),
        }
    }

    fn traverse_par_with<T: TidSet + Sync, F: SubtreeVisitors>(
        &self,
        threads: usize,
        factory: &F,
    ) -> Vec<F::V> {
        if self.maxpat == 0 {
            return Vec::new();
        }
        let root: Vec<(u32, T)> = self
            .root_candidates()
            .into_iter()
            .map(|(j, t)| (j, T::from_sorted(t)))
            .collect();
        let root = &root;
        crate::runtime::parallel::map_indexed(threads, root.len(), move |i| {
            let mut visitor = factory.visitor(i);
            let (item, tids) = &root[i];
            let mut prefix = vec![*item];
            let node = PatternNode::itemset(&prefix, tids.ids());
            let walk = visitor.visit(&node);
            if walk == Walk::Descend && prefix.len() < self.maxpat {
                let mut pool = Pools::default();
                let mut children = pool.take_list();
                for (next, next_tids) in &root[i + 1..] {
                    let mut buf = pool.take_tids();
                    T::intersect(tids, next_tids, &mut buf);
                    if buf.len() >= self.minsup {
                        children.push((*next, buf));
                    } else {
                        pool.put_tids(buf);
                    }
                }
                if !children.is_empty() {
                    self.recurse(&children, &mut prefix, &mut pool, &mut visitor);
                }
                pool.put_list(children);
            }
            visitor
        })
    }

    fn recurse<T: TidSet, V: TreeVisitor + ?Sized>(
        &self,
        candidates: &[(u32, T)],
        prefix: &mut Vec<u32>,
        pool: &mut Pools<T>,
        visitor: &mut V,
    ) {
        for (ci, (item, tids)) in candidates.iter().enumerate() {
            prefix.push(*item);
            let node = PatternNode::itemset(prefix, tids.ids());
            let walk = visitor.visit(&node);
            if walk == Walk::Descend && prefix.len() < self.maxpat {
                // Children: items after `item` in the candidate list,
                // intersected with this node's tids.
                let mut children = pool.take_list();
                for (next, next_tids) in &candidates[ci + 1..] {
                    let mut buf = pool.take_tids();
                    T::intersect(tids, next_tids, &mut buf);
                    if buf.len() >= self.minsup {
                        children.push((*next, buf));
                    } else {
                        pool.put_tids(buf);
                    }
                }
                if !children.is_empty() {
                    self.recurse(&children, prefix, pool, visitor);
                }
                pool.put_list(children);
            }
            prefix.pop();
        }
    }
}

/// Recycled buffers for the traversal (tid carriers + candidate lists).
struct Pools<T> {
    tids: Vec<T>,
    lists: Vec<Vec<(u32, T)>>,
}

impl<T> Default for Pools<T> {
    fn default() -> Self {
        Pools {
            tids: Vec::new(),
            lists: Vec::new(),
        }
    }
}

impl<T: TidSet> Pools<T> {
    #[inline]
    fn take_tids(&mut self) -> T {
        self.tids.pop().unwrap_or_default()
    }

    #[inline]
    fn put_tids(&mut self, mut v: T) {
        v.clear();
        self.tids.push(v);
    }

    #[inline]
    fn take_list(&mut self) -> Vec<(u32, T)> {
        self.lists.pop().unwrap_or_default()
    }

    #[inline]
    fn put_list(&mut self, mut l: Vec<(u32, T)>) {
        for (_, v) in l.drain(..) {
            self.put_tids(v);
        }
        self.lists.push(l);
    }
}

/// `Vec<u32>` is the reference [`TidSet`]: a plain sorted id list with
/// the galloping/merge [`intersect_into`] kernel.
impl TidSet for Vec<u32> {
    #[inline]
    fn from_sorted(ids: Vec<u32>) -> Self {
        ids
    }

    #[inline]
    fn ids(&self) -> &[u32] {
        self
    }

    #[inline]
    fn clear(&mut self) {
        Vec::clear(self);
    }

    #[inline]
    fn intersect(a: &Self, b: &Self, out: &mut Self) {
        intersect_into(a, b, out);
    }
}

/// Sorted-list intersection into `out` (cleared first).  This is the
/// traversal hot loop — galloping for skewed sizes, linear merge
/// otherwise.
#[inline]
pub fn intersect_into(a: &[u32], b: &[u32], out: &mut Vec<u32>) {
    out.clear();
    let (small, large) = if a.len() <= b.len() { (a, b) } else { (b, a) };
    if small.is_empty() {
        return;
    }
    // Galloping pays when sizes are very skewed.
    if large.len() / small.len().max(1) >= 16 {
        let mut lo = 0usize;
        for &x in small {
            if lo >= large.len() {
                break;
            }
            // exponential gallop: find a window [lo, hi) that must
            // contain the insertion point of x
            let mut bound = 1usize;
            while lo + bound < large.len() && large[lo + bound] < x {
                bound <<= 1;
            }
            let hi = (lo + bound + 1).min(large.len());
            match large[lo..hi].binary_search(&x) {
                Ok(k) => {
                    out.push(x);
                    lo += k + 1;
                }
                Err(k) => lo += k,
            }
        }
    } else {
        let (mut i, mut j) = (0usize, 0usize);
        while i < small.len() && j < large.len() {
            let (x, y) = (small[i], large[j]);
            if x == y {
                out.push(x);
                i += 1;
                j += 1;
            } else if x < y {
                i += 1;
            } else {
                j += 1;
            }
        }
    }
}

/// Is `items` in transaction normal form — strictly increasing ids?
///
/// Every row the miners and generators produce satisfies this, and it
/// is exactly the precondition under which the merge-based matcher
/// (`synth_itemsets::contains_all`) reduces to a plain subset test —
/// the reduction the serve-time compiled matcher
/// (`serve::compiled`) builds its postings on.
#[inline]
pub fn is_strictly_increasing(items: &[u32]) -> bool {
    items.windows(2).all(|w| w[0] < w[1])
}

/// Bring an arbitrary item list into transaction normal form: sort
/// ascending and drop duplicates.  Used by the serve protocol to
/// normalize item-set records arriving over the wire before they meet
/// kernels that assume the [`is_strictly_increasing`] invariant.
pub fn normalize_items(mut items: Vec<u32>) -> Vec<u32> {
    if !is_strictly_increasing(&items) {
        items.sort_unstable();
        items.dedup();
    }
    items
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mining::Pattern;

    #[test]
    fn normal_form_checks_and_normalization() {
        assert!(is_strictly_increasing(&[]));
        assert!(is_strictly_increasing(&[3]));
        assert!(is_strictly_increasing(&[1, 2, 9]));
        assert!(!is_strictly_increasing(&[1, 1]));
        assert!(!is_strictly_increasing(&[2, 1]));
        assert_eq!(normalize_items(vec![]), Vec::<u32>::new());
        assert_eq!(normalize_items(vec![1, 2, 9]), vec![1, 2, 9]);
        assert_eq!(normalize_items(vec![9, 1, 2, 1, 9]), vec![1, 2, 9]);
    }

    fn db() -> Transactions {
        // 4 items, 5 transactions
        Transactions {
            n_items: 4,
            items: vec![
                vec![0, 1, 2],
                vec![0, 1],
                vec![1, 2, 3],
                vec![0, 2],
                vec![1, 2],
            ],
        }
    }

    /// Collect all visited patterns with supports.
    fn collect(db: &Transactions, maxpat: usize, minsup: usize) -> Vec<(Pattern, Vec<u32>)> {
        let mut out = Vec::new();
        let mut v = |n: &PatternNode<'_>| {
            out.push((n.to_pattern(), n.support.to_vec()));
            Walk::Descend
        };
        let mut miner = ItemsetMiner::new(db, maxpat);
        miner.minsup = minsup;
        miner.traverse(&mut v);
        out
    }

    #[test]
    fn enumerates_all_itemsets_up_to_maxpat() {
        let db = db();
        let got = collect(&db, 2, 1);
        // size-1: 4, size-2 with non-empty support: {0,1},{0,2},{1,2},{1,3},{2,3}
        let names: Vec<String> = got.iter().map(|(p, _)| p.display()).collect();
        assert!(names.contains(&"{0}".into()));
        assert!(names.contains(&"{1,2}".into()));
        assert!(names.contains(&"{2,3}".into()));
        assert!(!names.contains(&"{0,3}".into())); // empty support
        assert_eq!(got.len(), 4 + 5);
    }

    #[test]
    fn supports_are_correct() {
        let db = db();
        for (p, sup) in collect(&db, 3, 1) {
            if let Pattern::Itemset(items) = &p {
                let expected: Vec<u32> = db
                    .items
                    .iter()
                    .enumerate()
                    .filter(|(_, row)| {
                        crate::data::synth_itemsets::contains_all(row, items)
                    })
                    .map(|(i, _)| i as u32)
                    .collect();
                assert_eq!(sup, expected, "pattern {}", p.display());
            }
        }
    }

    #[test]
    fn respects_maxpat() {
        let db = db();
        assert!(collect(&db, 1, 1).iter().all(|(p, _)| p.size() == 1));
        assert!(collect(&db, 2, 1).iter().all(|(p, _)| p.size() <= 2));
    }

    #[test]
    fn respects_minsup() {
        let db = db();
        for (_, sup) in collect(&db, 3, 2) {
            assert!(sup.len() >= 2);
        }
    }

    #[test]
    fn prune_skips_subtree() {
        let db = db();
        let mut seen = Vec::new();
        let mut v = |n: &PatternNode<'_>| {
            seen.push(n.to_pattern().display());
            if n.to_pattern() == Pattern::Itemset(vec![0]) {
                Walk::Prune
            } else {
                Walk::Descend
            }
        };
        ItemsetMiner::new(&db, 3).traverse(&mut v);
        // nothing starting with {0, ...} beyond {0} itself
        assert!(seen.contains(&"{0}".to_string()));
        assert!(!seen.iter().any(|s| s.starts_with("{0,")));
        // but sibling subtrees still fully explored
        assert!(seen.contains(&"{1,2,3}".to_string()));
    }

    #[test]
    fn maxpat_zero_visits_nothing() {
        let db = db();
        assert!(collect(&db, 0, 1).is_empty());
    }

    #[test]
    fn anti_monotone_supports_along_paths() {
        // child support must be a subset of parent support
        let db = db();
        let mut stack: Vec<Vec<u32>> = Vec::new();
        let mut v = |n: &PatternNode<'_>| {
            while stack.len() >= n.depth {
                stack.pop();
            }
            if let Some(parent) = stack.last() {
                assert!(n.support.iter().all(|t| parent.contains(t)));
            }
            stack.push(n.support.to_vec());
            Walk::Descend
        };
        ItemsetMiner::new(&db, 4).traverse(&mut v);
    }

    #[test]
    fn parallel_traversal_matches_sequential_blocks() {
        struct Coll(Vec<(Pattern, Vec<u32>)>);
        impl TreeVisitor for Coll {
            fn visit(&mut self, n: &PatternNode<'_>) -> Walk {
                self.0.push((n.to_pattern(), n.support.to_vec()));
                Walk::Descend
            }
        }
        struct Fac;
        impl SubtreeVisitors for Fac {
            type V = Coll;

            fn visitor(&self, _root: usize) -> Coll {
                Coll(Vec::new())
            }
        }
        let db = db();
        for (maxpat, minsup, threads) in [(3, 1, 1), (3, 1, 4), (4, 1, 2), (2, 2, 3)] {
            let want = collect(&db, maxpat, minsup);
            let mut m = ItemsetMiner::new(&db, maxpat);
            m.minsup = minsup;
            let got: Vec<(Pattern, Vec<u32>)> =
                m.traverse_par(threads, &Fac).into_iter().flat_map(|c| c.0).collect();
            assert_eq!(got, want, "maxpat={maxpat} minsup={minsup} threads={threads}");
        }
    }

    #[test]
    fn hybrid_layout_enumerates_identically() {
        // Large enough that several tid-lists cross the dense-chunk
        // cutoff, so the word-AND intersection path actually runs.
        let n = 6000usize;
        let items: Vec<Vec<u32>> = (0..n)
            .map(|t| {
                let mut row = Vec::new();
                if t % 2 == 0 {
                    row.push(0); // dense: 3000 tids
                }
                if t % 3 == 0 {
                    row.push(1); // dense: 2000 tids
                }
                if t % 97 == 0 {
                    row.push(2); // sparse: 62 tids
                }
                row
            })
            .collect();
        let big = Transactions { n_items: 3, items };
        let run = |layout: ColumnLayout, threads: usize| {
            let mut m = ItemsetMiner::new(&big, 3);
            m.minsup = 2;
            m.layout = layout;
            if threads == 0 {
                let mut out = Vec::new();
                let mut v = |n: &PatternNode<'_>| {
                    out.push((n.to_pattern(), n.support.to_vec()));
                    Walk::Descend
                };
                m.traverse(&mut v);
                out
            } else {
                struct Coll(Vec<(Pattern, Vec<u32>)>);
                impl TreeVisitor for Coll {
                    fn visit(&mut self, n: &PatternNode<'_>) -> Walk {
                        self.0.push((n.to_pattern(), n.support.to_vec()));
                        Walk::Descend
                    }
                }
                struct Fac;
                impl SubtreeVisitors for Fac {
                    type V = Coll;

                    fn visitor(&self, _root: usize) -> Coll {
                        Coll(Vec::new())
                    }
                }
                m.traverse_par(threads, &Fac).into_iter().flat_map(|c| c.0).collect()
            }
        };
        let want = run(ColumnLayout::Sparse, 0);
        assert!(!want.is_empty());
        assert_eq!(run(ColumnLayout::Hybrid, 0), want, "sequential");
        assert_eq!(run(ColumnLayout::Hybrid, 3), want, "parallel");
    }

    mod intersect {
        use super::super::intersect_into;

        fn isect(a: &[u32], b: &[u32]) -> Vec<u32> {
            let mut out = Vec::new();
            intersect_into(a, b, &mut out);
            out
        }

        #[test]
        fn basic() {
            assert_eq!(isect(&[1, 2, 3], &[2, 3, 4]), vec![2, 3]);
            assert_eq!(isect(&[], &[1]), Vec::<u32>::new());
            assert_eq!(isect(&[5], &[5]), vec![5]);
            assert_eq!(isect(&[1, 3], &[2, 4]), Vec::<u32>::new());
        }

        #[test]
        fn galloping_path_matches_linear() {
            use crate::testutil::SplitMix64;
            let mut rng = SplitMix64::new(42);
            for _ in 0..200 {
                let mut a: Vec<u32> =
                    (0..rng.range(0, 8)).map(|_| rng.below(1000) as u32).collect();
                let mut b: Vec<u32> =
                    (0..rng.range(200, 400)).map(|_| rng.below(1000) as u32).collect();
                a.sort_unstable();
                a.dedup();
                b.sort_unstable();
                b.dedup();
                let naive: Vec<u32> =
                    a.iter().filter(|x| b.binary_search(x).is_ok()).copied().collect();
                assert_eq!(isect(&a, &b), naive);
                assert_eq!(isect(&b, &a), naive);
            }
        }
    }
}
