//! Rule-conjunction enumeration tree over numeric features — the
//! fourth substrate, after Kato et al.'s Safe RuleFit (meta safe
//! screening; see PAPERS.md).
//!
//! A pattern is a conjunction of threshold predicates
//! `x_{j_1} ◇ t_1 ∧ … ∧ x_{j_k} ◇ t_k` (◇ ∈ {≤, >}) over the numeric
//! feature columns of a [`TabularData`] database; the binary feature is
//! `x_it = I(rule t holds on row i)`.  The enumeration tree refines a
//! rule one predicate at a time, so every child's support is a filter
//! of its parent's — the anti-monotonicity the SPP rule (paper
//! Theorem 2) and the boosting envelope bound require.  Applied to
//! this lattice the per-node SPPC test *is* Kato et al.'s meta safe
//! screening bound: one evaluation certifies the whole refinement
//! subtree below a rule, not a single feature.
//!
//! Canonical enumeration: the finite predicate universe
//! ([`predicate_universe`]) is ordered feature-major / threshold-
//! ascending / `Le` before `Gt`, and a rule is extended only by
//! predicates with a strictly larger universe index (skipping a
//! `(feature, direction)` pair the rule already constrains — a second
//! `x_j ≤ t'` is subsumed by the tighter of the two).  Every rule is
//! therefore a strictly increasing predicate-id list and is visited
//! exactly once, in lexicographic id order.

use super::{PatternNode, SubtreeVisitors, TreeVisitor, Walk};
use crate::data::tabular::TabularData;

/// Direction of a threshold predicate.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum RuleOp {
    /// `x_j <= t`
    Le,
    /// `x_j > t`
    Gt,
}

impl RuleOp {
    /// The codec/display token (`<=` or `>`).
    pub fn token(self) -> &'static str {
        match self {
            RuleOp::Le => "<=",
            RuleOp::Gt => ">",
        }
    }
}

/// One threshold predicate `x_feature ◇ threshold`.
///
/// The threshold is stored as its IEEE-754 bit pattern so the type can
/// derive `Eq`/`Hash`/`Ord` (which [`crate::mining::Pattern`]
/// requires); the derived order is only used for map keys and is
/// consistent because equal bits ⇔ equal thresholds.  Construct via
/// [`RulePredicate::new`] and read back via
/// [`RulePredicate::threshold`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RulePredicate {
    /// Feature (column) index.
    pub feature: u32,
    /// Predicate direction.
    pub op: RuleOp,
    bits: u64,
}

impl RulePredicate {
    pub fn new(feature: u32, op: RuleOp, threshold: f64) -> Self {
        RulePredicate {
            feature,
            op,
            bits: threshold.to_bits(),
        }
    }

    /// The threshold value `t` of `x_feature ◇ t`.
    pub fn threshold(&self) -> f64 {
        f64::from_bits(self.bits)
    }

    /// Does the predicate hold on `row`?  A missing column (foreign
    /// record width) or a NaN value fails the comparison — a rule
    /// never matches a record it cannot be evaluated on.
    pub fn eval(&self, row: &[f64]) -> bool {
        match row.get(self.feature as usize) {
            Some(&v) => match self.op {
                RuleOp::Le => v <= self.threshold(),
                RuleOp::Gt => v > self.threshold(),
            },
            None => false,
        }
    }

    /// Codec/display form, e.g. `x3<=0.25`.  Thresholds print through
    /// `f64`'s shortest-round-trip `Display`, so
    /// [`RulePredicate::parse`] recovers the exact bits.
    pub fn display(&self) -> String {
        format!("x{}{}{}", self.feature, self.op.token(), self.threshold())
    }

    /// Inverse of [`RulePredicate::display`].
    pub fn parse(token: &str) -> crate::Result<RulePredicate> {
        let rest = token
            .strip_prefix('x')
            .ok_or_else(|| anyhow::anyhow!("rule predicate '{token}' does not start with 'x'"))?;
        let cut = rest
            .find(|c: char| !c.is_ascii_digit())
            .ok_or_else(|| anyhow::anyhow!("rule predicate '{token}' has no operator"))?;
        let feature: u32 = rest[..cut].parse()?;
        let (op, value) = if let Some(v) = rest[cut..].strip_prefix("<=") {
            (RuleOp::Le, v)
        } else if let Some(v) = rest[cut..].strip_prefix('>') {
            (RuleOp::Gt, v)
        } else {
            anyhow::bail!("rule predicate '{token}' has an unknown operator");
        };
        let threshold: f64 = value.parse()?;
        if !threshold.is_finite() {
            anyhow::bail!("rule predicate '{token}' threshold is not finite");
        }
        Ok(RulePredicate::new(feature, op, threshold))
    }
}

/// The deterministic candidate-threshold universe of a database: per
/// feature, the midpoints between consecutive distinct sorted values,
/// quantile-thinned to at most
/// [`TabularData::max_thresholds`] cuts, each paired with both
/// directions.  Ordered feature-major, threshold-ascending, [`RuleOp::Le`]
/// before [`RuleOp::Gt`] — the canonical predicate-id order every rule
/// enumeration (production miner and test oracle alike) is defined
/// over.
pub fn predicate_universe(db: &TabularData) -> Vec<RulePredicate> {
    let mut preds = Vec::new();
    for j in 0..db.n_features {
        let mut vals: Vec<f64> = db.rows.iter().map(|r| r[j]).collect();
        vals.sort_by(|a, b| a.partial_cmp(b).expect("validate() refuses NaN"));
        vals.dedup();
        let k = vals.len();
        if k < 2 {
            continue; // a constant column supports no split
        }
        let cuts = k - 1;
        let take = cuts.min(db.max_thresholds.max(1));
        let mut last_idx = 0usize;
        for t in 0..take {
            // Evenly spaced cut indices in [1, cuts]; when cuts <= take
            // this selects every cut exactly once.
            let idx = ((t + 1) * k / (take + 1)).clamp(1, cuts);
            if idx == last_idx {
                continue;
            }
            last_idx = idx;
            let thr = (vals[idx - 1] + vals[idx]) / 2.0;
            if !thr.is_finite() {
                continue;
            }
            preds.push(RulePredicate::new(j as u32, RuleOp::Le, thr));
            preds.push(RulePredicate::new(j as u32, RuleOp::Gt, thr));
        }
    }
    preds
}

/// Configurable rule miner (RuleFit-style conjunction enumeration).
pub struct RulefitMiner<'a> {
    db: &'a TabularData,
    /// Maximum rule length (#predicates; the paper's `maxpat`).
    pub maxpat: usize,
    /// Minimum support; rules below it are not visited (their subtrees
    /// are skipped — safe, supports are anti-monotone).
    pub minsup: usize,
    preds: Vec<RulePredicate>,
}

impl<'a> RulefitMiner<'a> {
    pub fn new(db: &'a TabularData, maxpat: usize) -> Self {
        RulefitMiner {
            db,
            maxpat,
            minsup: 1,
            preds: predicate_universe(db),
        }
    }

    /// The predicate universe this miner enumerates over (pid order).
    pub fn predicates(&self) -> &[RulePredicate] {
        &self.preds
    }

    /// Depth-1 root frontier: every universe predicate with support
    /// `>= minsup`, with its sorted row-id support, in pid order.  The
    /// ONE root-frontier definition shared by [`Self::traverse`] and
    /// [`Self::traverse_par`] — the splice guarantee depends on both
    /// engines expanding the same frontier.
    fn roots(&self) -> Vec<(usize, Vec<u32>)> {
        (0..self.preds.len())
            .filter_map(|pid| {
                let p = self.preds[pid];
                let support: Vec<u32> = (0..self.db.rows.len() as u32)
                    .filter(|&i| p.eval(&self.db.rows[i as usize]))
                    .collect();
                (support.len() >= self.minsup).then_some((pid, support))
            })
            .collect()
    }

    /// Depth-first traversal; the visitor sees each canonical rule
    /// exactly once, in lexicographic predicate-id order.
    pub fn traverse<V: TreeVisitor + ?Sized>(&self, visitor: &mut V) {
        if self.maxpat == 0 || self.db.rows.is_empty() {
            return;
        }
        for (pid, support) in self.roots() {
            let mut rule = vec![self.preds[pid]];
            let node = PatternNode::rule(&rule, &support);
            let walk = visitor.visit(&node);
            if walk == Walk::Descend && rule.len() < self.maxpat {
                self.recurse(pid, &support, &mut rule, visitor);
            }
        }
    }

    /// Subtree-parallel traversal (see
    /// [`crate::mining::PatternSubstrate::traverse_parallel`]): the
    /// root frontier (`roots`, shared with the sequential engine) is
    /// computed once; each surviving predicate's subtree is then an
    /// independent task, so per-subtree node sequences concatenated in
    /// pid order equal the sequential traversal.
    pub fn traverse_par<F: SubtreeVisitors>(&self, threads: usize, factory: &F) -> Vec<F::V> {
        if self.maxpat == 0 || self.db.rows.is_empty() {
            return Vec::new();
        }
        let roots = self.roots();
        let roots = &roots;
        crate::runtime::parallel::map_indexed(threads, roots.len(), move |i| {
            let mut visitor = factory.visitor(i);
            let (pid, support) = &roots[i];
            let mut rule = vec![self.preds[*pid]];
            let node = PatternNode::rule(&rule, support);
            let walk = visitor.visit(&node);
            if walk == Walk::Descend && rule.len() < self.maxpat {
                self.recurse(*pid, support, &mut rule, &mut visitor);
            }
            visitor
        })
    }

    fn recurse<V: TreeVisitor + ?Sized>(
        &self,
        last_pid: usize,
        support: &[u32],
        rule: &mut Vec<RulePredicate>,
        visitor: &mut V,
    ) {
        for pid in last_pid + 1..self.preds.len() {
            let p = self.preds[pid];
            // One predicate per (feature, direction): a second bound in
            // the same direction is subsumed by the tighter of the two.
            if rule.iter().any(|q| q.feature == p.feature && q.op == p.op) {
                continue;
            }
            let child: Vec<u32> = support
                .iter()
                .copied()
                .filter(|&i| p.eval(&self.db.rows[i as usize]))
                .collect();
            if child.len() < self.minsup {
                continue;
            }
            rule.push(p);
            let node = PatternNode::rule(rule, &child);
            let walk = visitor.visit(&node);
            if walk == Walk::Descend && rule.len() < self.maxpat {
                self.recurse(pid, &child, rule, visitor);
            }
            rule.pop();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mining::Pattern;
    use crate::testutil::oracle;

    fn db() -> TabularData {
        TabularData::new(
            2,
            vec![
                vec![0.0, 1.0],
                vec![1.0, 0.0],
                vec![2.0, 1.0],
                vec![3.0, 0.0],
            ],
        )
    }

    fn collect(db: &TabularData, maxpat: usize, minsup: usize) -> Vec<(Vec<RulePredicate>, Vec<u32>)> {
        let mut out = Vec::new();
        let mut v = |n: &PatternNode<'_>| {
            if let Pattern::Rule(r) = n.to_pattern() {
                out.push((r, n.support.to_vec()));
            }
            Walk::Descend
        };
        let mut m = RulefitMiner::new(db, maxpat);
        m.minsup = minsup;
        m.traverse(&mut v);
        out
    }

    #[test]
    fn predicate_eval_cases() {
        let le = RulePredicate::new(0, RuleOp::Le, 1.5);
        let gt = RulePredicate::new(0, RuleOp::Gt, 1.5);
        assert!(le.eval(&[1.5]) && !gt.eval(&[1.5])); // boundary goes left
        assert!(!le.eval(&[2.0]) && gt.eval(&[2.0]));
        assert!(!le.eval(&[f64::NAN]) && !gt.eval(&[f64::NAN]));
        assert!(!RulePredicate::new(3, RuleOp::Le, 0.0).eval(&[1.0])); // missing column
    }

    #[test]
    fn predicate_display_parse_round_trip() {
        for p in [
            RulePredicate::new(0, RuleOp::Le, 0.1),
            RulePredicate::new(7, RuleOp::Gt, -2.25),
            RulePredicate::new(3, RuleOp::Le, 1.0 / 3.0),
        ] {
            assert_eq!(RulePredicate::parse(&p.display()).unwrap(), p);
        }
        assert!(RulePredicate::parse("y0<=1").is_err());
        assert!(RulePredicate::parse("x0=1").is_err());
        assert!(RulePredicate::parse("x0<=inf").is_err());
    }

    #[test]
    fn universe_is_ordered_and_thinned() {
        let d = db();
        let preds = predicate_universe(&d);
        // feature 0 has 4 distinct values (3 cuts), feature 1 has 2 (1
        // cut); each cut yields a Le and a Gt predicate.
        assert_eq!(preds.len(), 2 * 3 + 2 * 1);
        // canonical order: feature-major, threshold-ascending, Le<Gt
        let key = |p: &RulePredicate| (p.feature, p.threshold().to_bits(), p.op);
        assert!(preds.windows(2).all(|w| key(&w[0]) < key(&w[1])));
        // thinning: cap at 2 keeps 2 cuts of feature 0
        let mut capped = d.clone();
        capped.max_thresholds = 2;
        assert_eq!(predicate_universe(&capped).len(), 2 * 2 + 2 * 1);
    }

    #[test]
    fn matches_bruteforce_enumeration() {
        let d = db();
        for maxpat in [1usize, 2, 3] {
            let got: std::collections::BTreeMap<Vec<RulePredicate>, Vec<u32>> =
                collect(&d, maxpat, 1).into_iter().collect();
            let brute = oracle::all_rules(&d, maxpat, 1, &predicate_universe(&d));
            assert_eq!(got, brute, "maxpat={maxpat}");
        }
    }

    #[test]
    fn respects_maxpat_and_minsup() {
        let d = db();
        assert!(collect(&d, 2, 1).iter().all(|(p, _)| p.len() <= 2));
        assert!(collect(&d, 3, 2).iter().all(|(_, s)| s.len() >= 2));
        assert!(collect(&d, 0, 1).is_empty());
    }

    #[test]
    fn prune_skips_subtree_but_not_siblings() {
        let d = db();
        let m = RulefitMiner::new(&d, 2);
        let first = m.predicates()[0];
        let mut seen: Vec<Vec<RulePredicate>> = Vec::new();
        let mut v = |n: &PatternNode<'_>| {
            let Pattern::Rule(r) = n.to_pattern() else {
                unreachable!()
            };
            seen.push(r.clone());
            if r == vec![first] {
                Walk::Prune
            } else {
                Walk::Descend
            }
        };
        m.traverse(&mut v);
        assert!(seen.contains(&vec![first]));
        assert!(!seen.iter().any(|r| r.len() > 1 && r[0] == first));
        assert!(seen.iter().any(|r| r.len() == 2), "{seen:?}"); // sibling subtrees intact
    }

    #[test]
    fn parallel_traversal_matches_sequential_blocks() {
        struct Coll(Vec<(Vec<RulePredicate>, Vec<u32>)>);
        impl TreeVisitor for Coll {
            fn visit(&mut self, n: &PatternNode<'_>) -> Walk {
                if let Pattern::Rule(r) = n.to_pattern() {
                    self.0.push((r, n.support.to_vec()));
                }
                Walk::Descend
            }
        }
        struct Fac;
        impl SubtreeVisitors for Fac {
            type V = Coll;

            fn visitor(&self, _root: usize) -> Coll {
                Coll(Vec::new())
            }
        }
        let d = db();
        for (maxpat, minsup, threads) in [(3, 1, 1), (3, 1, 4), (2, 2, 2)] {
            let want = collect(&d, maxpat, minsup);
            let mut m = RulefitMiner::new(&d, maxpat);
            m.minsup = minsup;
            let got: Vec<(Vec<RulePredicate>, Vec<u32>)> =
                m.traverse_par(threads, &Fac).into_iter().flat_map(|c| c.0).collect();
            assert_eq!(got, want, "maxpat={maxpat} minsup={minsup} threads={threads}");
        }
    }

    #[test]
    fn anti_monotone_supports_along_paths() {
        let d = db();
        let mut stack: Vec<Vec<u32>> = Vec::new();
        let mut v = |n: &PatternNode<'_>| {
            while stack.len() >= n.depth {
                stack.pop();
            }
            if let Some(parent) = stack.last() {
                assert!(n.support.iter().all(|t| parent.contains(t)));
            }
            stack.push(n.support.to_vec());
            Walk::Descend
        };
        RulefitMiner::new(&d, 3).traverse(&mut v);
    }
}
