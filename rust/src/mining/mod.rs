//! Pattern-tree mining substrates.
//!
//! Both miners ([`itemset::ItemsetMiner`] and [`gspan::GSpanMiner`])
//! enumerate an anti-monotone pattern tree (paper Fig. 1): every child
//! pattern is a superset of its parent, so `x_{it'} = 1 ⟹ x_{it} = 1`
//! and supports only shrink along any root-to-leaf path.  That property
//! is what both the SPP rule and the boosting bound exploit.
//!
//! The search is driven through the [`TreeVisitor`] callback: the
//! visitor sees each canonical pattern exactly once, together with its
//! support (sorted transaction ids), and decides whether the subtree
//! below it should be explored ([`Walk::Descend`]) or safely discarded
//! ([`Walk::Prune`]).  SPP, the boosting most-violating search, and the
//! λ_max search are all visitors over the same trees — which is exactly
//! the fairness discipline the paper's timing comparison needs.

pub mod gspan;
pub mod itemset;

/// Decision returned by a visitor for the subtree rooted at a node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Walk {
    /// Skip the entire subtree (safe when the visitor's bound certifies
    /// no descendant can matter).
    Prune,
    /// Expand children.
    Descend,
}

/// Owned identity of a pattern (for reporting / model output).
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Pattern {
    /// Sorted item ids.
    Itemset(Vec<u32>),
    /// Canonical (minimal) DFS code.
    Subgraph(Vec<gspan::DfsEdge>),
}

impl Pattern {
    /// Pattern size: #items or #edges — the quantity `maxpat` bounds.
    pub fn size(&self) -> usize {
        match self {
            Pattern::Itemset(v) => v.len(),
            Pattern::Subgraph(c) => c.len(),
        }
    }

    /// Human-readable form used in model dumps.
    pub fn display(&self) -> String {
        match self {
            Pattern::Itemset(v) => format!(
                "{{{}}}",
                v.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(",")
            ),
            Pattern::Subgraph(c) => c
                .iter()
                .map(|e| {
                    format!(
                        "({}-{},{},{},{})",
                        e.from, e.to, e.from_label, e.elabel, e.to_label
                    )
                })
                .collect::<Vec<_>>()
                .join(""),
        }
    }
}

/// A node of the pattern tree as shown to visitors.
pub struct PatternNode<'a> {
    /// Sorted, deduplicated transaction ids with `x_{it} = 1`.
    pub support: &'a [u32],
    /// Pattern size (= tree depth; #items or #edges).
    pub depth: usize,
    /// Borrowed identity; clone via `to_pattern()` only when keeping it.
    pattern: PatternBorrow<'a>,
}

pub(crate) enum PatternBorrow<'a> {
    Itemset(&'a [u32]),
    Subgraph(&'a [gspan::DfsEdge]),
}

impl<'a> PatternNode<'a> {
    pub(crate) fn itemset(items: &'a [u32], support: &'a [u32]) -> Self {
        PatternNode {
            support,
            depth: items.len(),
            pattern: PatternBorrow::Itemset(items),
        }
    }

    pub(crate) fn subgraph(code: &'a [gspan::DfsEdge], support: &'a [u32]) -> Self {
        PatternNode {
            support,
            depth: code.len(),
            pattern: PatternBorrow::Subgraph(code),
        }
    }

    /// Clone the borrowed identity into an owned [`Pattern`].
    pub fn to_pattern(&self) -> Pattern {
        match self.pattern {
            PatternBorrow::Itemset(v) => Pattern::Itemset(v.to_vec()),
            PatternBorrow::Subgraph(c) => Pattern::Subgraph(c.to_vec()),
        }
    }
}

/// Callback driving a tree traversal.
pub trait TreeVisitor {
    fn visit(&mut self, node: &PatternNode<'_>) -> Walk;
}

/// Blanket impl so closures can be used as visitors in tests.
impl<F: FnMut(&PatternNode<'_>) -> Walk> TreeVisitor for F {
    fn visit(&mut self, node: &PatternNode<'_>) -> Walk {
        self(node)
    }
}

/// Traversal statistics shared by every search (figure 4/5 currency).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TraverseStats {
    /// Number of visitor invocations (canonical nodes reached).
    pub nodes: u64,
    /// Of those, how many returned [`Walk::Prune`].
    pub pruned: u64,
}

/// Wrapper visitor that counts nodes while delegating.
pub struct Counting<'v, V: TreeVisitor + ?Sized> {
    pub inner: &'v mut V,
    pub stats: TraverseStats,
}

impl<'v, V: TreeVisitor + ?Sized> Counting<'v, V> {
    pub fn new(inner: &'v mut V) -> Self {
        Counting {
            inner,
            stats: TraverseStats::default(),
        }
    }
}

impl<V: TreeVisitor + ?Sized> TreeVisitor for Counting<'_, V> {
    fn visit(&mut self, node: &PatternNode<'_>) -> Walk {
        self.stats.nodes += 1;
        let w = self.inner.visit(node);
        if w == Walk::Prune {
            self.stats.pruned += 1;
        }
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pattern_size_and_display() {
        let p = Pattern::Itemset(vec![1, 4, 9]);
        assert_eq!(p.size(), 3);
        assert_eq!(p.display(), "{1,4,9}");
    }

    #[test]
    fn counting_wraps_and_counts() {
        let mut inner = |_n: &PatternNode<'_>| Walk::Prune;
        let mut c = Counting::new(&mut inner);
        let sup = vec![0u32, 2];
        let items = vec![3u32];
        let node = PatternNode::itemset(&items, &sup);
        assert_eq!(c.visit(&node), Walk::Prune);
        assert_eq!(c.stats.nodes, 1);
        assert_eq!(c.stats.pruned, 1);
    }

    #[test]
    fn to_pattern_clones_identity() {
        let sup = vec![1u32];
        let items = vec![2u32, 5];
        let node = PatternNode::itemset(&items, &sup);
        assert_eq!(node.to_pattern(), Pattern::Itemset(vec![2, 5]));
        assert_eq!(node.depth, 2);
    }
}
