//! Pattern-tree mining substrates.
//!
//! The miners ([`itemset::ItemsetMiner`], [`gspan::GSpanMiner`],
//! [`prefixspan::PrefixSpanMiner`]) enumerate an anti-monotone pattern
//! tree (paper Fig. 1): every child pattern extends its parent, so
//! `x_{it'} = 1 ⟹ x_{it} = 1` and supports only shrink along any
//! root-to-leaf path.  That property is what both the SPP rule and the
//! boosting bound exploit.
//!
//! The search is driven through the [`TreeVisitor`] callback: the
//! visitor sees each canonical pattern exactly once, together with its
//! support (sorted transaction ids), and decides whether the subtree
//! below it should be explored ([`Walk::Descend`]) or safely discarded
//! ([`Walk::Prune`]).  SPP, the boosting most-violating search, and the
//! λ_max search are all visitors over the same trees — which is exactly
//! the fairness discipline the paper's timing comparison needs.
//!
//! The substrates themselves plug into the rest of the crate through
//! the open [`PatternSubstrate`] trait: every search (`sppc`,
//! `lambda_max`, `certify`, boosting, the regularization path, CV) is
//! generic over it, so adding a new pattern language is a matter of
//! implementing the trait — no search code changes.  The crate ships
//! four substrates: transaction databases (item-sets), graph databases
//! (connected subgraphs), sequence databases (subsequences), and
//! numeric tabular databases (RuleFit-style threshold-rule
//! conjunctions, [`rulefit`]).
//!
//! Traversal has a deterministic parallel form as well:
//! [`PatternSubstrate::traverse_parallel`] farms independent depth-1
//! subtrees to `runtime::parallel` workers, one [`SubtreeVisitors`]
//! visitor per subtree, returned in canonical root order — so splicing
//! the per-subtree results reproduces the sequential traversal exactly
//! (DESIGN.md §6, "Threading model").

pub mod gspan;
pub mod itemset;
pub mod prefixspan;
pub mod rulefit;

/// Decision returned by a visitor for the subtree rooted at a node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Walk {
    /// Skip the entire subtree (safe when the visitor's bound certifies
    /// no descendant can matter).
    Prune,
    /// Expand children.
    Descend,
}

/// Owned identity of a pattern (for reporting / model output).
///
/// One variant per shipped substrate; the per-kind logic (matching,
/// persistence codec) lives in each substrate's [`PatternSubstrate`]
/// impl — adding a substrate means adding a variant here and
/// implementing the trait next to its database type.
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Pattern {
    /// Sorted item ids.
    Itemset(Vec<u32>),
    /// Canonical (minimal) DFS code.
    Subgraph(Vec<gspan::DfsEdge>),
    /// Ordered symbol ids (a subsequence pattern; repeats allowed).
    Sequence(Vec<u32>),
    /// Conjunction of threshold predicates over numeric features, in
    /// canonical (universe-id) order.
    Rule(Vec<rulefit::RulePredicate>),
}

impl Pattern {
    /// Pattern size: #items, #edges or #symbols — what `maxpat` bounds.
    pub fn size(&self) -> usize {
        match self {
            Pattern::Itemset(v) => v.len(),
            Pattern::Subgraph(c) => c.len(),
            Pattern::Sequence(s) => s.len(),
            Pattern::Rule(r) => r.len(),
        }
    }

    /// The item list of an [`Pattern::Itemset`], else `None` — the
    /// introspection hook the serve-time compiled matcher
    /// (`serve::compiled`) specializes postings from.
    pub fn as_itemset(&self) -> Option<&[u32]> {
        match self {
            Pattern::Itemset(v) => Some(v),
            _ => None,
        }
    }

    /// The DFS code of a [`Pattern::Subgraph`], else `None`.
    pub fn as_subgraph(&self) -> Option<&[gspan::DfsEdge]> {
        match self {
            Pattern::Subgraph(c) => Some(c),
            _ => None,
        }
    }

    /// The symbol list of a [`Pattern::Sequence`], else `None`.
    pub fn as_sequence(&self) -> Option<&[u32]> {
        match self {
            Pattern::Sequence(s) => Some(s),
            _ => None,
        }
    }

    /// The predicate list of a [`Pattern::Rule`], else `None` — the
    /// introspection hook the serve-time compiled matcher collapses
    /// into per-feature intervals.
    pub fn as_rule(&self) -> Option<&[rulefit::RulePredicate]> {
        match self {
            Pattern::Rule(r) => Some(r),
            _ => None,
        }
    }

    /// Human-readable form used in model dumps.
    pub fn display(&self) -> String {
        match self {
            Pattern::Itemset(v) => format!(
                "{{{}}}",
                v.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(",")
            ),
            Pattern::Subgraph(c) => c
                .iter()
                .map(|e| {
                    format!(
                        "({}-{},{},{},{})",
                        e.from, e.to, e.from_label, e.elabel, e.to_label
                    )
                })
                .collect::<Vec<_>>()
                .join(""),
            Pattern::Sequence(s) => format!(
                "<{}>",
                s.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(",")
            ),
            Pattern::Rule(r) => format!(
                "[{}]",
                r.iter().map(|p| p.display()).collect::<Vec<_>>().join(" & ")
            ),
        }
    }

    /// The persistence tag of the substrate owning this pattern kind
    /// (the record tag of the `spp-model v1` text format).
    pub fn kind_tag(&self) -> &'static str {
        match self {
            Pattern::Itemset(_) => crate::data::Transactions::KIND_TAG,
            Pattern::Subgraph(_) => crate::data::graph::GraphDatabase::KIND_TAG,
            Pattern::Sequence(_) => crate::data::sequence::Sequences::KIND_TAG,
            Pattern::Rule(_) => crate::data::tabular::TabularData::KIND_TAG,
        }
    }

    /// Serialize the pattern body through the owning substrate's codec
    /// (inverse of [`Pattern::decode`] for the same tag).
    pub fn encode_body(&self) -> String {
        match self {
            Pattern::Itemset(_) => crate::data::Transactions::format_pattern(self),
            Pattern::Subgraph(_) => crate::data::graph::GraphDatabase::format_pattern(self),
            Pattern::Sequence(_) => crate::data::sequence::Sequences::format_pattern(self),
            Pattern::Rule(_) => crate::data::tabular::TabularData::format_pattern(self),
        }
    }

    /// Parse a persisted pattern by dispatching `tag` to the substrate
    /// that registered it (the only tag → substrate map in the crate).
    pub fn decode(tag: &str, body: &str) -> crate::Result<Pattern> {
        use crate::data::{
            graph::GraphDatabase, sequence::Sequences, tabular::TabularData, Transactions,
        };
        match tag {
            t if t == Transactions::KIND_TAG => Transactions::parse_pattern(body),
            t if t == GraphDatabase::KIND_TAG => GraphDatabase::parse_pattern(body),
            t if t == Sequences::KIND_TAG => Sequences::parse_pattern(body),
            t if t == TabularData::KIND_TAG => TabularData::parse_pattern(body),
            other => anyhow::bail!("unknown pattern record '{other}'"),
        }
    }
}

/// An open pattern-mining substrate: a database whose records carry an
/// anti-monotone pattern tree.
///
/// This is the seam every search in the crate is generic over.  The
/// contract an implementation must honour:
///
/// * **Anti-monotonicity** — `traverse` must enumerate a tree in which
///   every child pattern's support is a subset of its parent's (paper
///   Fig. 1).  The SPP rule (Theorem 2) and the boosting envelope bound
///   are *unsafe* without it: both certify whole subtrees from a bound
///   that only decreases along root-to-leaf paths.
/// * **Canonical enumeration** — each pattern is visited exactly once,
///   with its sorted, deduplicated record-id support.
/// * **Miner/matcher agreement** — `matches(p, record(i))` must hold
///   exactly when `i` appears in the support `traverse` reports for
///   `p`; prediction on new records and CV rely on it.
/// * **Codec round-trip** — `parse_pattern(format_pattern(p)) == p` for
///   every pattern this substrate can emit, and `KIND_TAG` must be
///   unique across substrates (it keys [`Pattern::decode`]).
///
/// See `DESIGN.md` §"Substrate API" for a walkthrough of adding a
/// fourth substrate.
pub trait PatternSubstrate {
    /// One record of the database (a transaction row, a graph, a
    /// sequence); unsized view types like `[u32]` are allowed.
    type Record: ?Sized;

    /// Number of records (= length of every support universe).
    fn n_records(&self) -> usize;

    /// Depth-first canonical traversal with subtree pruning: the
    /// visitor sees each pattern of size `1..=maxpat` with support
    /// `>= minsup` exactly once and steers via [`Walk`].
    fn traverse(&self, maxpat: usize, minsup: usize, visitor: &mut dyn TreeVisitor);

    /// Subtree-parallel canonical traversal: expand the depth-1 root
    /// frontier sequentially (in canonical order), then traverse each
    /// root's subtree depth-first with its **own** visitor from
    /// `factory` — possibly on `threads` pool workers — and return the
    /// visitors in canonical root order.
    ///
    /// **Contract**: visitor `i` must see exactly the node sequence
    /// [`PatternSubstrate::traverse`] delivers between the `i`-th and
    /// `(i+1)`-th depth-1 nodes, in the same order, with the same
    /// supports; concatenating the per-subtree sequences in root order
    /// therefore reproduces the sequential traversal exactly.  This is
    /// the splice guarantee the deterministic parallel engine
    /// (`runtime::parallel`, `--threads N`) builds on.
    ///
    /// The default implementation runs on the sequential `traverse`
    /// (handing out one visitor per depth-1 node) and is correct for
    /// any substrate; the shipped substrates override it to farm
    /// subtrees to the worker pool.
    fn traverse_parallel<F: SubtreeVisitors>(
        &self,
        maxpat: usize,
        minsup: usize,
        threads: usize,
        factory: &F,
    ) -> Vec<F::V>
    where
        Self: Sized,
    {
        let _ = threads;
        struct Split<'f, F: SubtreeVisitors> {
            factory: &'f F,
            out: Vec<F::V>,
        }
        impl<F: SubtreeVisitors> TreeVisitor for Split<'_, F> {
            fn visit(&mut self, node: &PatternNode<'_>) -> Walk {
                if node.depth == 1 {
                    self.out.push(self.factory.visitor(self.out.len()));
                }
                self.out
                    .last_mut()
                    .expect("canonical traversals start every subtree at depth 1")
                    .visit(node)
            }
        }
        let mut split = Split {
            factory,
            out: Vec::new(),
        };
        self.traverse(maxpat, minsup, &mut split);
        split.out
    }

    /// Does `pattern` occur in `record`?  Must return `false` for
    /// foreign pattern kinds (a model mixing substrates scores only its
    /// own terms against each record type).
    fn matches(pattern: &Pattern, record: &Self::Record) -> bool;

    /// Borrow record `i` (prediction / validation input).
    fn record(&self, i: usize) -> &Self::Record;

    /// Clone the sub-database holding `indices` (in order) — the CV
    /// fold split and any other record-subset workflow.
    fn select(&self, indices: &[usize]) -> Self
    where
        Self: Sized;

    /// Parse a persisted pattern body (inverse of `format_pattern`).
    fn parse_pattern(body: &str) -> crate::Result<Pattern>
    where
        Self: Sized;

    /// Serialize a pattern of this substrate's kind to its persisted
    /// body form.  Panics on foreign kinds (only reachable through
    /// [`Pattern::encode_body`], which dispatches by kind).
    fn format_pattern(pattern: &Pattern) -> String
    where
        Self: Sized;

    /// Unique one-token tag naming this substrate's patterns in the
    /// model text format (`I`, `G`, `S`, `R` for the shipped four).
    const KIND_TAG: &'static str;
}

/// A node of the pattern tree as shown to visitors.
pub struct PatternNode<'a> {
    /// Sorted, deduplicated transaction ids with `x_{it} = 1`.
    pub support: &'a [u32],
    /// Pattern size (= tree depth; #items or #edges).
    pub depth: usize,
    /// Borrowed identity; clone via `to_pattern()` only when keeping it.
    pattern: PatternBorrow<'a>,
}

pub(crate) enum PatternBorrow<'a> {
    Itemset(&'a [u32]),
    Subgraph(&'a [gspan::DfsEdge]),
    Sequence(&'a [u32]),
    Rule(&'a [rulefit::RulePredicate]),
}

impl<'a> PatternNode<'a> {
    pub(crate) fn itemset(items: &'a [u32], support: &'a [u32]) -> Self {
        PatternNode {
            support,
            depth: items.len(),
            pattern: PatternBorrow::Itemset(items),
        }
    }

    pub(crate) fn subgraph(code: &'a [gspan::DfsEdge], support: &'a [u32]) -> Self {
        PatternNode {
            support,
            depth: code.len(),
            pattern: PatternBorrow::Subgraph(code),
        }
    }

    pub(crate) fn sequence(symbols: &'a [u32], support: &'a [u32]) -> Self {
        PatternNode {
            support,
            depth: symbols.len(),
            pattern: PatternBorrow::Sequence(symbols),
        }
    }

    pub(crate) fn rule(predicates: &'a [rulefit::RulePredicate], support: &'a [u32]) -> Self {
        PatternNode {
            support,
            depth: predicates.len(),
            pattern: PatternBorrow::Rule(predicates),
        }
    }

    /// Clone the borrowed identity into an owned [`Pattern`].
    pub fn to_pattern(&self) -> Pattern {
        match self.pattern {
            PatternBorrow::Itemset(v) => Pattern::Itemset(v.to_vec()),
            PatternBorrow::Subgraph(c) => Pattern::Subgraph(c.to_vec()),
            PatternBorrow::Sequence(s) => Pattern::Sequence(s.to_vec()),
            PatternBorrow::Rule(r) => Pattern::Rule(r.to_vec()),
        }
    }
}

/// Callback driving a tree traversal.
pub trait TreeVisitor {
    fn visit(&mut self, node: &PatternNode<'_>) -> Walk;
}

/// Per-subtree visitor factory for
/// [`PatternSubstrate::traverse_parallel`]: hands out one fresh visitor
/// per depth-1 subtree.  The factory is shared across pool workers
/// (`Sync`); each visitor is owned by exactly one subtree task (`Send`)
/// and is returned to the caller, carrying whatever it collected, in
/// canonical root order.
pub trait SubtreeVisitors: Sync {
    /// The per-subtree visitor type.
    type V: TreeVisitor + Send;

    /// A fresh visitor for the subtree rooted at canonical depth-1
    /// index `root`.
    fn visitor(&self, root: usize) -> Self::V;
}

/// Blanket impl so closures can be used as visitors in tests.
impl<F: FnMut(&PatternNode<'_>) -> Walk> TreeVisitor for F {
    fn visit(&mut self, node: &PatternNode<'_>) -> Walk {
        self(node)
    }
}

/// Traversal statistics shared by every search (figure 4/5 currency).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TraverseStats {
    /// Number of visitor invocations (canonical nodes reached).
    pub nodes: u64,
    /// Of those, how many returned [`Walk::Prune`].
    pub pruned: u64,
}

/// Wrapper visitor that counts nodes while delegating.
pub struct Counting<'v, V: TreeVisitor + ?Sized> {
    pub inner: &'v mut V,
    pub stats: TraverseStats,
}

impl<'v, V: TreeVisitor + ?Sized> Counting<'v, V> {
    pub fn new(inner: &'v mut V) -> Self {
        Counting {
            inner,
            stats: TraverseStats::default(),
        }
    }
}

impl<V: TreeVisitor + ?Sized> TreeVisitor for Counting<'_, V> {
    fn visit(&mut self, node: &PatternNode<'_>) -> Walk {
        self.stats.nodes += 1;
        let w = self.inner.visit(node);
        if w == Walk::Prune {
            self.stats.pruned += 1;
        }
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pattern_size_and_display() {
        let p = Pattern::Itemset(vec![1, 4, 9]);
        assert_eq!(p.size(), 3);
        assert_eq!(p.display(), "{1,4,9}");
        let r = Pattern::Rule(vec![
            rulefit::RulePredicate::new(0, rulefit::RuleOp::Le, 1.5),
            rulefit::RulePredicate::new(2, rulefit::RuleOp::Gt, 0.25),
        ]);
        assert_eq!(r.size(), 2);
        assert_eq!(r.display(), "[x0<=1.5 & x2>0.25]");
    }

    #[test]
    fn introspection_accessors_return_own_kind_only() {
        let i = Pattern::Itemset(vec![1, 4]);
        let g = Pattern::Subgraph(vec![gspan::DfsEdge {
            from: 0,
            to: 1,
            from_label: 2,
            elabel: 0,
            to_label: 3,
        }]);
        let s = Pattern::Sequence(vec![7, 7]);
        let r = Pattern::Rule(vec![rulefit::RulePredicate::new(0, rulefit::RuleOp::Le, 1.5)]);
        assert_eq!(i.as_itemset(), Some(&[1u32, 4][..]));
        assert!(i.as_subgraph().is_none() && i.as_sequence().is_none() && i.as_rule().is_none());
        assert_eq!(g.as_subgraph().map(|c| c.len()), Some(1));
        assert!(g.as_itemset().is_none() && g.as_sequence().is_none() && g.as_rule().is_none());
        assert_eq!(s.as_sequence(), Some(&[7u32, 7][..]));
        assert!(s.as_itemset().is_none() && s.as_subgraph().is_none() && s.as_rule().is_none());
        assert_eq!(r.as_rule().map(|p| p.len()), Some(1));
        assert!(r.as_itemset().is_none() && r.as_subgraph().is_none() && r.as_sequence().is_none());
    }

    #[test]
    fn counting_wraps_and_counts() {
        let mut inner = |_n: &PatternNode<'_>| Walk::Prune;
        let mut c = Counting::new(&mut inner);
        let sup = vec![0u32, 2];
        let items = vec![3u32];
        let node = PatternNode::itemset(&items, &sup);
        assert_eq!(c.visit(&node), Walk::Prune);
        assert_eq!(c.stats.nodes, 1);
        assert_eq!(c.stats.pruned, 1);
    }

    #[test]
    fn to_pattern_clones_identity() {
        let sup = vec![1u32];
        let items = vec![2u32, 5];
        let node = PatternNode::itemset(&items, &sup);
        assert_eq!(node.to_pattern(), Pattern::Itemset(vec![2, 5]));
        assert_eq!(node.depth, 2);
    }

    #[test]
    fn sequence_patterns_have_size_display_and_identity() {
        let sup = vec![0u32, 3];
        let syms = vec![4u32, 4, 1];
        let node = PatternNode::sequence(&syms, &sup);
        assert_eq!(node.depth, 3);
        let p = node.to_pattern();
        assert_eq!(p, Pattern::Sequence(vec![4, 4, 1]));
        assert_eq!(p.size(), 3);
        assert_eq!(p.display(), "<4,4,1>");
    }

    #[test]
    fn default_traverse_parallel_splits_by_root() {
        // A substrate that does NOT override traverse_parallel: the
        // sequential fallback must hand each depth-1 subtree its own
        // visitor, in canonical root order.
        struct Toy;
        impl PatternSubstrate for Toy {
            type Record = ();

            fn n_records(&self) -> usize {
                3
            }

            fn traverse(&self, maxpat: usize, _minsup: usize, visitor: &mut dyn TreeVisitor) {
                let sup = [0u32, 1, 2];
                for root in 0..2u32 {
                    let items = [root];
                    let node = PatternNode::itemset(&items, &sup);
                    if visitor.visit(&node) == Walk::Descend && maxpat >= 2 {
                        let items = [root, 9];
                        let child = PatternNode::itemset(&items, &sup[..1]);
                        visitor.visit(&child);
                    }
                }
            }

            fn matches(_pattern: &Pattern, _record: &()) -> bool {
                false
            }

            fn record(&self, _i: usize) -> &() {
                &()
            }

            fn select(&self, _indices: &[usize]) -> Self {
                Toy
            }

            fn parse_pattern(_body: &str) -> crate::Result<Pattern> {
                anyhow::bail!("toy substrate has no codec")
            }

            fn format_pattern(_pattern: &Pattern) -> String {
                String::new()
            }

            const KIND_TAG: &'static str = "toy";
        }

        struct Collect {
            root: usize,
            seen: Vec<Pattern>,
        }
        impl TreeVisitor for Collect {
            fn visit(&mut self, node: &PatternNode<'_>) -> Walk {
                self.seen.push(node.to_pattern());
                Walk::Descend
            }
        }
        struct Fac;
        impl SubtreeVisitors for Fac {
            type V = Collect;

            fn visitor(&self, root: usize) -> Collect {
                Collect {
                    root,
                    seen: Vec::new(),
                }
            }
        }

        let out = Toy.traverse_parallel(2, 1, 4, &Fac);
        assert_eq!(out.len(), 2);
        for (i, c) in out.iter().enumerate() {
            assert_eq!(c.root, i);
            assert_eq!(
                c.seen,
                vec![
                    Pattern::Itemset(vec![i as u32]),
                    Pattern::Itemset(vec![i as u32, 9]),
                ]
            );
        }
    }

    #[test]
    fn codec_round_trips_every_kind() {
        let pats = [
            Pattern::Itemset(vec![1, 4, 9]),
            Pattern::Subgraph(vec![gspan::DfsEdge {
                from: 0,
                to: 1,
                from_label: 2,
                elabel: 0,
                to_label: 3,
            }]),
            Pattern::Sequence(vec![7, 7, 2]),
            Pattern::Rule(vec![
                rulefit::RulePredicate::new(0, rulefit::RuleOp::Le, 0.25),
                rulefit::RulePredicate::new(3, rulefit::RuleOp::Gt, -1.5),
            ]),
        ];
        let mut tags = std::collections::HashSet::new();
        for p in &pats {
            assert!(tags.insert(p.kind_tag()), "duplicate substrate tag");
            let back = Pattern::decode(p.kind_tag(), &p.encode_body()).unwrap();
            assert_eq!(&back, p);
        }
        assert!(Pattern::decode("X", "1").is_err());
    }
}
