//! Dense FISTA oracle.
//!
//! An independent solver for the same problems as [`super::cd`], used
//! by the test-suite to cross-validate the CD solver (two different
//! algorithms agreeing on the optimum is strong evidence both are
//! right) and by the safety property tests, which need the *full*
//! problem solved over an exhaustively enumerated pattern space.
//!
//! Accelerated proximal gradient with the conservative Lipschitz bound
//! `L = Σ_t v_t + n` (Frobenius bound on the intercept-augmented
//! design).  Slow but simple — it only ever runs on test-sized data.

use super::problem::Task;

/// Oracle output.
#[derive(Clone, Debug)]
pub struct DenseSolution {
    pub w: Vec<f64>,
    pub b: f64,
    pub primal: f64,
    pub iters: usize,
}

/// Solve eq. (6) on materialized support columns with FISTA.
///
/// Stops when `max(|Δw|, |Δb|)` over an iteration drops below `tol`
/// (iterate-change criterion; callers pick `tol` well below the
/// precision they assert).
pub fn solve_dense(
    task: Task,
    supports: &[Vec<u32>],
    y: &[f64],
    lam: f64,
    tol: f64,
    max_iter: usize,
) -> DenseSolution {
    let n = y.len();
    let k = supports.len();
    let v: Vec<f64> = supports.iter().map(|s| s.len() as f64).collect();
    let lip = v.iter().sum::<f64>() + n as f64 + 1e-12;

    let mut w = vec![0.0; k];
    let mut b = 0.0;
    let mut vw = w.clone();
    let mut vb = b;
    let mut tk = 1.0f64;
    let mut iters = 0;

    let mut m = vec![0.0; n]; // margins at the momentum point
    for it in 0..max_iter {
        iters = it + 1;
        // m = X vw + vb
        m.iter_mut().for_each(|mi| *mi = vb);
        for (t, sup) in supports.iter().enumerate() {
            if vw[t] != 0.0 {
                for &i in sup {
                    m[i as usize] += vw[t];
                }
            }
        }
        // gradient of the smooth part at (vw, vb)
        let slack: Vec<f64> = match task {
            Task::Regression => y.iter().zip(&m).map(|(&yi, &mi)| yi - mi).collect(),
            Task::Classification => y
                .iter()
                .zip(&m)
                .map(|(&yi, &mi)| (1.0 - yi * mi).max(0.0))
                .collect(),
        };
        let mut gw = vec![0.0; k];
        let mut gb = 0.0;
        match task {
            Task::Regression => {
                for (t, sup) in supports.iter().enumerate() {
                    gw[t] = -sup.iter().map(|&i| slack[i as usize]).sum::<f64>();
                }
                gb = -slack.iter().sum::<f64>();
            }
            Task::Classification => {
                for (t, sup) in supports.iter().enumerate() {
                    gw[t] = -sup
                        .iter()
                        .map(|&i| y[i as usize] * slack[i as usize])
                        .sum::<f64>();
                }
                for i in 0..n {
                    gb -= y[i] * slack[i];
                }
            }
        }
        // prox step
        let mut w_new = vec![0.0; k];
        let mut max_delta = 0.0f64;
        for t in 0..k {
            let z = vw[t] - gw[t] / lip;
            w_new[t] = super::cd::soft_threshold(z, lam / lip);
            max_delta = max_delta.max((w_new[t] - w[t]).abs());
        }
        let b_new = vb - gb / lip;
        max_delta = max_delta.max((b_new - b).abs());
        // momentum
        let t_new = 0.5 * (1.0 + (1.0 + 4.0 * tk * tk).sqrt());
        let beta = (tk - 1.0) / t_new;
        for t in 0..k {
            vw[t] = w_new[t] + beta * (w_new[t] - w[t]);
        }
        vb = b_new + beta * (b_new - b);
        w = w_new;
        b = b_new;
        tk = t_new;
        if max_delta < tol {
            break;
        }
    }

    // primal at (w, b)
    let mut m = vec![b; n];
    for (t, sup) in supports.iter().enumerate() {
        if w[t] != 0.0 {
            for &i in sup {
                m[i as usize] += w[t];
            }
        }
    }
    let loss: f64 = match task {
        Task::Regression => m
            .iter()
            .zip(y)
            .map(|(&mi, &yi)| {
                let r = yi - mi;
                0.5 * r * r
            })
            .sum(),
        Task::Classification => m
            .iter()
            .zip(y)
            .map(|(&mi, &yi)| {
                let h = (1.0 - yi * mi).max(0.0);
                0.5 * h * h
            })
            .sum(),
    };
    let primal = loss + lam * w.iter().map(|x| x.abs()).sum::<f64>();
    DenseSolution { w, b, primal, iters }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shrinks_everything_at_huge_lambda() {
        let sup = vec![vec![0u32, 1], vec![2u32]];
        let y = vec![1.0, 2.0, 3.0];
        let s = solve_dense(Task::Regression, &sup, &y, 1e9, 1e-12, 50_000);
        assert!(s.w.iter().all(|&w| w == 0.0));
        assert!((s.b - 2.0).abs() < 1e-6);
    }

    #[test]
    fn fits_exactly_at_tiny_lambda() {
        // y perfectly explained by one column + intercept
        let sup = vec![vec![0u32, 2]];
        let y = vec![3.0, 1.0, 3.0, 1.0];
        let s = solve_dense(Task::Regression, &sup, &y, 1e-8, 1e-12, 200_000);
        assert!((s.w[0] - 2.0).abs() < 1e-4, "w {:?}", s.w);
        assert!((s.b - 1.0).abs() < 1e-4, "b {}", s.b);
    }

    #[test]
    fn classification_separates_trivial_data() {
        let sup = vec![vec![0u32, 1]];
        let y = vec![1.0, 1.0, -1.0, -1.0];
        let s = solve_dense(Task::Classification, &sup, &y, 0.01, 1e-12, 200_000);
        // margin positive for positives: w + b > 0; negative side: b < 0
        assert!(s.w[0] + s.b > 0.5);
        assert!(s.b < -0.5);
    }
}
