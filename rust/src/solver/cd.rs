//! Working-set solver: cyclic proximal coordinate descent.
//!
//! This is the paper's "coordinate gradient descent method [18]"
//! (Tseng & Yun): each coordinate takes a prox step against a quadratic
//! majorizer of the smooth part.  For squared loss the majorizer is
//! exact (the step is exact coordinate minimization); for squared hinge
//! the curvature bound is `Σ_i x_it² = v_t` (since `f'' ≤ 1`), giving a
//! monotone, globally convergent scheme with no line search in the hot
//! loop.
//!
//! Columns are the sparse pattern supports (sorted tid lists) — exactly
//! what the miners emit — so one epoch costs `O(Σ_t |supp(t)|)`.
//! Stopping follows the paper: duality gap below `tol` (1e-6 default),
//! checked every few epochs against the gap-safe dual point from
//! [`super::dual`].

use super::dual;
use super::problem::{dual_value, primal_value, Task};

/// Solver configuration.
#[derive(Clone, Copy, Debug)]
pub struct CdConfig {
    /// Absolute duality-gap tolerance (the paper uses 1e-6).
    pub tol: f64,
    /// Hard epoch cap (one epoch = one cyclic pass).
    pub max_epochs: usize,
    /// Gap evaluation cadence in epochs.
    pub gap_check_every: usize,
}

impl Default for CdConfig {
    fn default() -> Self {
        CdConfig {
            tol: 1e-6,
            max_epochs: 100_000,
            gap_check_every: 10,
        }
    }
}

/// Solver output: primal iterate, dual-feasible certificate, and the
/// objective values that certify it.
#[derive(Clone, Debug)]
pub struct Solution {
    pub w: Vec<f64>,
    pub b: f64,
    /// Gap-safe dual-feasible point at the returned iterate.
    pub theta: Vec<f64>,
    /// Per-sample slack: residual (regression) / hinge (classification).
    pub slack: Vec<f64>,
    pub primal: f64,
    pub dual: f64,
    pub gap: f64,
    pub epochs: usize,
}

/// Warm-start state.
pub struct Warm<'a> {
    pub w: &'a [f64],
    pub b: f64,
}

#[derive(Default)]
pub struct CdSolver {
    pub cfg: CdConfig,
}

impl CdSolver {
    pub fn new(cfg: CdConfig) -> Self {
        CdSolver { cfg }
    }

    /// Solve eq. (6) over the given support columns.
    ///
    /// `supports[t]` is the sorted tid list of pattern `t` (binary
    /// features).  `warm` seeds `(w, b)`; pass `None` for a cold start.
    pub fn solve(
        &self,
        task: Task,
        supports: &[Vec<u32>],
        y: &[f64],
        lam: f64,
        warm: Option<Warm<'_>>,
    ) -> Solution {
        assert!(lam > 0.0, "lambda must be positive");
        let n = y.len();
        let k = supports.len();
        let (mut w, mut b) = match warm {
            Some(wm) => {
                assert_eq!(wm.w.len(), k);
                (wm.w.to_vec(), wm.b)
            }
            None => (vec![0.0; k], 0.0),
        };
        // Model output m_i = x_i^T w + b, maintained incrementally.
        let mut m = vec![b; n];
        for (t, sup) in supports.iter().enumerate() {
            if w[t] != 0.0 {
                for &i in sup {
                    m[i as usize] += w[t];
                }
            }
        }
        let v: Vec<f64> = supports.iter().map(|s| s.len() as f64).collect();
        let all: Vec<usize> = (0..k).collect();
        let mut active: Vec<usize> = Vec::with_capacity(k);

        // Active-set strategy: most working-set columns stay at zero, so
        // inner passes cycle only over the nonzero coordinates; a full
        // pass re-scans everything and re-seeds the active set.  The
        // duality gap (checked after each full pass) is the only
        // stopping criterion, so the strategy cannot change the result.
        let mut epochs = 0usize;
        let mut best = self.certify(task, supports, y, &w, b, &m, lam);
        while best.gap > self.cfg.tol && epochs < self.cfg.max_epochs {
            epochs += 1;
            let full_delta = match task {
                Task::Regression => {
                    epoch_regression(&all, supports, y, &v, &mut w, &mut b, &mut m, lam)
                }
                Task::Classification => {
                    epoch_classification(&all, supports, y, &v, &mut w, &mut b, &mut m, lam)
                }
            };
            active.clear();
            active.extend((0..k).filter(|&t| w[t] != 0.0));
            let inner_cap = self.cfg.gap_check_every.max(1) * 10;
            for _ in 0..inner_cap {
                if epochs >= self.cfg.max_epochs {
                    break;
                }
                epochs += 1;
                let delta = match task {
                    Task::Regression => {
                        epoch_regression(&active, supports, y, &v, &mut w, &mut b, &mut m, lam)
                    }
                    Task::Classification => {
                        epoch_classification(&active, supports, y, &v, &mut w, &mut b, &mut m, lam)
                    }
                };
                if delta < 1e-12 * (1.0 + full_delta) {
                    break;
                }
            }
            best = self.certify(task, supports, y, &w, b, &m, lam);
        }
        best.epochs = epochs;
        best
    }

    /// Build the dual certificate and objective values at `(w, b)`.
    fn certify(
        &self,
        task: Task,
        supports: &[Vec<u32>],
        y: &[f64],
        w: &[f64],
        b: f64,
        m: &[f64],
        lam: f64,
    ) -> Solution {
        let slack: Vec<f64> = match task {
            Task::Regression => y.iter().zip(m).map(|(&yi, &mi)| yi - mi).collect(),
            Task::Classification => y
                .iter()
                .zip(m)
                .map(|(&yi, &mi)| (1.0 - yi * mi).max(0.0))
                .collect(),
        };
        let l1: f64 = w.iter().map(|x| x.abs()).sum();
        let primal = primal_value(&slack, l1, lam);
        let theta = dual::dual_point(task, &slack, y, lam, supports);
        let dualv = dual_value(task, &theta, y, lam);
        Solution {
            w: w.to_vec(),
            b,
            theta,
            slack,
            primal,
            dual: dualv,
            gap: primal - dualv,
            epochs: 0,
        }
    }
}

/// Soft-threshold `S(z, τ)`.
#[inline]
pub fn soft_threshold(z: f64, tau: f64) -> f64 {
    if z > tau {
        z - tau
    } else if z < -tau {
        z + tau
    } else {
        0.0
    }
}

/// One cyclic pass for L1 least squares over the coordinates in
/// `idxs`.  Returns max |Δ| seen.
fn epoch_regression(
    idxs: &[usize],
    supports: &[Vec<u32>],
    y: &[f64],
    v: &[f64],
    w: &mut [f64],
    b: &mut f64,
    m: &mut [f64],
    lam: f64,
) -> f64 {
    let n = y.len() as f64;
    let mut max_delta = 0.0f64;
    for &t in idxs {
        let sup = &supports[t];
        if v[t] == 0.0 {
            continue;
        }
        // g = x_t^T r + v_t w_t  with r = y - m
        let mut g = v[t] * w[t];
        for &i in sup {
            let i = i as usize;
            g += y[i] - m[i];
        }
        let w_new = soft_threshold(g, lam) / v[t];
        let delta = w_new - w[t];
        if delta != 0.0 {
            for &i in sup {
                m[i as usize] += delta;
            }
            w[t] = w_new;
            max_delta = max_delta.max(delta.abs());
        }
    }
    // exact intercept step
    let mean_r: f64 = y.iter().zip(m.iter()).map(|(&yi, &mi)| yi - mi).sum::<f64>() / n;
    if mean_r != 0.0 {
        *b += mean_r;
        m.iter_mut().for_each(|mi| *mi += mean_r);
        max_delta = max_delta.max(mean_r.abs());
    }
    max_delta
}

/// One cyclic pass for L1 squared hinge over the coordinates in
/// `idxs`.  Majorized prox steps with curvature `v_t`; returns max |Δ|.
fn epoch_classification(
    idxs: &[usize],
    supports: &[Vec<u32>],
    y: &[f64],
    v: &[f64],
    w: &mut [f64],
    b: &mut f64,
    m: &mut [f64],
    lam: f64,
) -> f64 {
    let n = y.len() as f64;
    let mut max_delta = 0.0f64;
    for &t in idxs {
        let sup = &supports[t];
        if v[t] == 0.0 {
            continue;
        }
        // grad_t = -sum_{i in sup} y_i h_i
        let mut grad = 0.0;
        for &i in sup {
            let i = i as usize;
            let h = 1.0 - y[i] * m[i];
            if h > 0.0 {
                grad -= y[i] * h;
            }
        }
        let w_new = soft_threshold(v[t] * w[t] - grad, lam) / v[t];
        let delta = w_new - w[t];
        if delta != 0.0 {
            for &i in sup {
                m[i as usize] += delta;
            }
            w[t] = w_new;
            max_delta = max_delta.max(delta.abs());
        }
    }
    // intercept: majorized step with curvature n
    let mut grad_b = 0.0;
    for i in 0..y.len() {
        let h = 1.0 - y[i] * m[i];
        if h > 0.0 {
            grad_b -= y[i] * h;
        }
    }
    let delta_b = -grad_b / n;
    if delta_b != 0.0 {
        *b += delta_b;
        m.iter_mut().for_each(|mi| *mi += delta_b);
        max_delta = max_delta.max(delta_b.abs());
    }
    max_delta
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::ista;
    use crate::testutil::SplitMix64;

    fn random_problem(
        seed: u64,
        n: usize,
        k: usize,
        classify: bool,
    ) -> (Vec<Vec<u32>>, Vec<f64>) {
        let mut rng = SplitMix64::new(seed);
        let supports: Vec<Vec<u32>> = (0..k)
            .map(|_| {
                let m = rng.range(1, (n * 2 / 3).max(2));
                rng.sample_distinct(n, m).into_iter().map(|i| i as u32).collect()
            })
            .collect();
        let w_true: Vec<f64> = (0..k)
            .map(|t| if t < k / 3 { rng.gauss() * 2.0 } else { 0.0 })
            .collect();
        let mut score = vec![0.0; n];
        for (t, sup) in supports.iter().enumerate() {
            for &i in sup {
                score[i as usize] += w_true[t];
            }
        }
        let y: Vec<f64> = score
            .iter()
            .map(|&s| {
                let v = s + 0.2 * rng.gauss();
                if classify {
                    if v >= 0.0 {
                        1.0
                    } else {
                        -1.0
                    }
                } else {
                    v
                }
            })
            .collect();
        (supports, y)
    }

    #[test]
    fn regression_gap_closes() {
        let (sup, y) = random_problem(1, 60, 12, false);
        let sol = CdSolver::default().solve(Task::Regression, &sup, &y, 1.0, None);
        assert!(sol.gap <= 1e-6, "gap {}", sol.gap);
        assert!(sol.dual <= sol.primal + 1e-12);
    }

    #[test]
    fn classification_gap_closes() {
        let (sup, y) = random_problem(2, 80, 10, true);
        let sol = CdSolver::default().solve(Task::Classification, &sup, &y, 0.5, None);
        assert!(sol.gap <= 1e-6, "gap {}", sol.gap);
    }

    #[test]
    fn regression_kkt_holds() {
        let (sup, y) = random_problem(3, 50, 8, false);
        let lam = 0.8;
        let sol = CdSolver::default().solve(Task::Regression, &sup, &y, lam, None);
        // residual correlations: |x_t^T r| <= lam (active: == lam sign(w))
        for (t, s) in sup.iter().enumerate() {
            let corr: f64 = s.iter().map(|&i| sol.slack[i as usize]).sum();
            if sol.w[t] != 0.0 {
                assert!(
                    (corr - lam * sol.w[t].signum()).abs() < 1e-3,
                    "active KKT: corr={corr} w={}",
                    sol.w[t]
                );
            } else {
                assert!(corr.abs() <= lam + 1e-3, "inactive KKT: {corr}");
            }
        }
        // intercept optimality
        let sum_r: f64 = sol.slack.iter().sum();
        assert!(sum_r.abs() < 1e-3);
    }

    #[test]
    fn matches_dense_ista_oracle() {
        for seed in [5u64, 6, 7] {
            let (sup, y) = random_problem(seed, 40, 6, false);
            let lam = 0.6;
            let sol = CdSolver::default().solve(Task::Regression, &sup, &y, lam, None);
            let oracle = ista::solve_dense(Task::Regression, &sup, &y, lam, 1e-9, 200_000);
            assert!(
                (sol.primal - oracle.primal).abs() < 1e-4 * (1.0 + oracle.primal.abs()),
                "primal {} vs oracle {}",
                sol.primal,
                oracle.primal
            );
            for (a, b) in sol.w.iter().zip(&oracle.w) {
                assert!((a - b).abs() < 5e-3, "w mismatch {a} vs {b}");
            }
        }
    }

    #[test]
    fn classification_matches_ista_oracle() {
        let (sup, y) = random_problem(8, 60, 6, true);
        let lam = 0.4;
        let sol = CdSolver::default().solve(Task::Classification, &sup, &y, lam, None);
        let oracle = ista::solve_dense(Task::Classification, &sup, &y, lam, 1e-9, 200_000);
        assert!(
            (sol.primal - oracle.primal).abs() < 1e-4 * (1.0 + oracle.primal.abs()),
            "primal {} vs oracle {}",
            sol.primal,
            oracle.primal
        );
    }

    #[test]
    fn large_lambda_gives_zero_weights() {
        let (sup, y) = random_problem(9, 40, 5, false);
        let sol = CdSolver::default().solve(Task::Regression, &sup, &y, 1e6, None);
        assert!(sol.w.iter().all(|&w| w == 0.0));
        // intercept-only optimum: b = mean(y)
        let mean = y.iter().sum::<f64>() / y.len() as f64;
        assert!((sol.b - mean).abs() < 1e-6);
    }

    #[test]
    fn warm_start_converges_faster() {
        let (sup, y) = random_problem(10, 120, 20, false);
        let cold = CdSolver::default().solve(Task::Regression, &sup, &y, 0.5, None);
        let warm = CdSolver::default().solve(
            Task::Regression,
            &sup,
            &y,
            0.45,
            Some(Warm {
                w: &cold.w,
                b: cold.b,
            }),
        );
        let cold2 = CdSolver::default().solve(Task::Regression, &sup, &y, 0.45, None);
        assert!(warm.epochs <= cold2.epochs, "warm {} cold {}", warm.epochs, cold2.epochs);
        assert!((warm.primal - cold2.primal).abs() < 1e-5 * (1.0 + cold2.primal.abs()));
    }

    #[test]
    fn empty_support_columns_are_ignored() {
        let sup = vec![vec![], vec![0u32, 1]];
        let y = vec![1.0, -1.0, 2.0];
        let sol = CdSolver::default().solve(Task::Regression, &sup, &y, 0.1, None);
        assert_eq!(sol.w[0], 0.0);
        assert!(sol.gap <= 1e-6);
    }

    #[test]
    fn no_columns_solves_intercept_only() {
        let y = vec![1.0, 3.0, 5.0];
        let sol = CdSolver::default().solve(Task::Regression, &[], &y, 1.0, None);
        assert!((sol.b - 3.0).abs() < 1e-9);
        assert!(sol.gap <= 1e-6);
    }

    #[test]
    fn soft_threshold_branches() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
    }
}
