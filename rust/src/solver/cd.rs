//! Working-set solver: cyclic proximal coordinate descent.
//!
//! This is the paper's "coordinate gradient descent method [18]"
//! (Tseng & Yun): each coordinate takes a prox step against a quadratic
//! majorizer of the smooth part.  For squared loss the majorizer is
//! exact (the step is exact coordinate minimization); for squared hinge
//! the curvature bound is `Σ_i x_it² = v_t` (since `f'' ≤ 1`), giving a
//! monotone, globally convergent scheme with no line search in the hot
//! loop.
//!
//! Columns are the sparse pattern supports (sorted tid lists) — exactly
//! what the miners emit; `solve` accepts anything column-shaped
//! through [`crate::columns::ColumnRead`] (`&[Vec<u32>]`, `&[&[u32]]`,
//! and the layout-aware [`crate::columns::ColumnView`]s borrowed from a
//! [`crate::screening::SupportPool`] — hybrid views run the gather and
//! dynamic-screening folds over 64-bit bitmap words, bit-identically to
//! the scalar walk).  Stopping follows the paper:
//! duality gap below `tol` (1e-6 default), checked every few epochs
//! against the gap-safe dual point from [`super::dual`].
//!
//! **Dynamic gap-safe screening** (Safe RuleFit-style, Kato et al.
//! 2018; on by default): at every gap check the solver recomputes the
//! safe radius and applies the Lemma-6 per-feature test to the columns
//! still in play; columns certified inactive are *frozen* — zeroed and
//! removed from all subsequent epochs.  The test is safe (a frozen
//! column is provably zero at this subproblem's optimum), so the
//! returned solution is unchanged while late-path epochs cycle over a
//! shrinking coordinate set.  `CdConfig::dynamic_screen = false`
//! restores the plain solver for ablation.

use super::dual;
use super::problem::{dual_value, primal_value, Task};
use crate::columns::ColumnRead;

/// Solver configuration.
#[derive(Clone, Copy, Debug)]
pub struct CdConfig {
    /// Absolute duality-gap tolerance (the paper uses 1e-6).
    pub tol: f64,
    /// Hard epoch cap (one epoch = one cyclic pass).
    pub max_epochs: usize,
    /// Gap evaluation cadence in epochs.
    pub gap_check_every: usize,
    /// Freeze gap-safe-screened columns out of subsequent epochs (see
    /// module docs).
    pub dynamic_screen: bool,
}

impl Default for CdConfig {
    fn default() -> Self {
        CdConfig {
            tol: 1e-6,
            max_epochs: 100_000,
            gap_check_every: 10,
            dynamic_screen: true,
        }
    }
}

/// Solver output: primal iterate, dual-feasible certificate, and the
/// objective values that certify it.
#[derive(Clone, Debug)]
pub struct Solution {
    pub w: Vec<f64>,
    pub b: f64,
    /// Gap-safe dual-feasible point at the returned iterate.
    pub theta: Vec<f64>,
    /// Per-sample slack: residual (regression) / hinge (classification).
    pub slack: Vec<f64>,
    pub primal: f64,
    pub dual: f64,
    pub gap: f64,
    pub epochs: usize,
    /// Columns frozen by dynamic gap-safe screening during this solve.
    pub screened: usize,
}

/// Warm-start state.
pub struct Warm<'a> {
    pub w: &'a [f64],
    pub b: f64,
}

#[derive(Default)]
pub struct CdSolver {
    pub cfg: CdConfig,
}

impl CdSolver {
    pub fn new(cfg: CdConfig) -> Self {
        CdSolver { cfg }
    }

    /// Solve eq. (6) over the given support columns.
    ///
    /// `supports[t]` is the sorted tid list of pattern `t` (binary
    /// features), in any [`ColumnRead`] carrier.  `warm` seeds `(w, b)`;
    /// pass `None` for a cold start.
    pub fn solve<S: ColumnRead>(
        &self,
        task: Task,
        supports: &[S],
        y: &[f64],
        lam: f64,
        warm: Option<Warm<'_>>,
    ) -> Solution {
        let cols = supports;
        assert!(lam > 0.0, "lambda must be positive");
        let n = y.len();
        let k = cols.len();
        let (mut w, mut b) = match warm {
            Some(wm) => {
                assert_eq!(wm.w.len(), k);
                (wm.w.to_vec(), wm.b)
            }
            None => (vec![0.0; k], 0.0),
        };
        // Model output m_i = x_i^T w + b, maintained incrementally.
        let mut m = vec![b; n];
        for (t, sup) in cols.iter().enumerate() {
            if w[t] != 0.0 {
                sup.for_each_id(|i| m[i] += w[t]);
            }
        }
        let v: Vec<f64> = cols.iter().map(|s| s.len() as f64).collect();
        // Coordinates still in play; dynamic screening shrinks this.
        let mut unfrozen: Vec<usize> = (0..k).collect();
        let mut screened = 0usize;
        let mut active: Vec<usize> = Vec::with_capacity(k);

        // Active-set strategy: most working-set columns stay at zero, so
        // inner passes cycle only over the nonzero coordinates; a full
        // pass re-scans every unfrozen coordinate and re-seeds the
        // active set.  The duality gap (checked after each full pass) is
        // the only stopping criterion, so the strategy cannot change the
        // result.
        let mut epochs = 0usize;
        let mut best = self.certify(task, cols, y, &w, b, &m, lam);
        while best.gap > self.cfg.tol && epochs < self.cfg.max_epochs {
            if self.cfg.dynamic_screen {
                screened +=
                    freeze_screened(task, cols, y, lam, &best, &v, &mut unfrozen, &mut w, &mut m);
            }
            epochs += 1;
            let full_delta = match task {
                Task::Regression => {
                    epoch_regression(&unfrozen, cols, y, &v, &mut w, &mut b, &mut m, lam)
                }
                Task::Classification => {
                    epoch_classification(&unfrozen, cols, y, &v, &mut w, &mut b, &mut m, lam)
                }
            };
            active.clear();
            active.extend(unfrozen.iter().copied().filter(|&t| w[t] != 0.0));
            let inner_cap = self.cfg.gap_check_every.max(1) * 10;
            for _ in 0..inner_cap {
                if epochs >= self.cfg.max_epochs {
                    break;
                }
                epochs += 1;
                let delta = match task {
                    Task::Regression => {
                        epoch_regression(&active, cols, y, &v, &mut w, &mut b, &mut m, lam)
                    }
                    Task::Classification => {
                        epoch_classification(&active, cols, y, &v, &mut w, &mut b, &mut m, lam)
                    }
                };
                if delta < 1e-12 * (1.0 + full_delta) {
                    break;
                }
            }
            best = self.certify(task, cols, y, &w, b, &m, lam);
        }
        best.epochs = epochs;
        best.screened = screened;
        best
    }

    /// Build the dual certificate and objective values at `(w, b)`.
    #[allow(clippy::too_many_arguments)]
    fn certify<S: ColumnRead>(
        &self,
        task: Task,
        cols: &[S],
        y: &[f64],
        w: &[f64],
        b: f64,
        m: &[f64],
        lam: f64,
    ) -> Solution {
        let slack: Vec<f64> = match task {
            Task::Regression => y.iter().zip(m).map(|(&yi, &mi)| yi - mi).collect(),
            Task::Classification => y
                .iter()
                .zip(m)
                .map(|(&yi, &mi)| (1.0 - yi * mi).max(0.0))
                .collect(),
        };
        let l1: f64 = w.iter().map(|x| x.abs()).sum();
        let primal = primal_value(&slack, l1, lam);
        let theta = dual::dual_point(task, &slack, y, lam, cols);
        let dualv = dual_value(task, &theta, y, lam);
        Solution {
            w: w.to_vec(),
            b,
            theta,
            slack,
            primal,
            dual: dualv,
            gap: primal - dualv,
            epochs: 0,
            screened: 0,
        }
    }
}

/// Gap-safe dynamic screening pass: apply the Lemma-6 per-feature test
/// at the certificate `sol` and freeze every certified-inactive column
/// (zeroing its weight and patching the model output).  Returns the
/// number of columns frozen.  Safe: a frozen column is provably zero at
/// the optimum of *this* restricted problem, so the final solution is
/// unchanged.
#[allow(clippy::too_many_arguments)]
fn freeze_screened<S: ColumnRead>(
    task: Task,
    cols: &[S],
    y: &[f64],
    lam: f64,
    sol: &Solution,
    v: &[f64],
    unfrozen: &mut Vec<usize>,
    w: &mut [f64],
    m: &mut [f64],
) -> usize {
    let radius = dual::safe_radius(sol.primal, sol.dual, lam);
    let n = y.len() as f64;
    let g: Vec<f64> = y
        .iter()
        .zip(&sol.theta)
        .map(|(&yi, &ti)| task.a(yi) * ti)
        .collect();
    let before = unfrozen.len();
    unfrozen.retain(|&t| {
        // layout-aware gather: hybrid columns sum over bitmap words
        let s = cols[t].dot(&g);
        let inner = (v[t] - v[t] * v[t] / n).max(0.0);
        let ub = s.abs() + radius * inner.sqrt();
        if ub < 1.0 {
            if w[t] != 0.0 {
                cols[t].for_each_id(|i| m[i] -= w[t]);
                w[t] = 0.0;
            }
            false
        } else {
            true
        }
    });
    before - unfrozen.len()
}

/// Soft-threshold `S(z, τ)`.
#[inline]
pub fn soft_threshold(z: f64, tau: f64) -> f64 {
    if z > tau {
        z - tau
    } else if z < -tau {
        z + tau
    } else {
        0.0
    }
}

/// One cyclic pass for L1 least squares over the coordinates in
/// `idxs`.  Returns max |Δ| seen.
#[allow(clippy::too_many_arguments)]
fn epoch_regression<S: ColumnRead>(
    idxs: &[usize],
    cols: &[S],
    y: &[f64],
    v: &[f64],
    w: &mut [f64],
    b: &mut f64,
    m: &mut [f64],
    lam: f64,
) -> f64 {
    let n = y.len() as f64;
    let mut max_delta = 0.0f64;
    for &t in idxs {
        let sup = &cols[t];
        if v[t] == 0.0 {
            continue;
        }
        // g = x_t^T r + v_t w_t  with r = y - m
        let mut g = v[t] * w[t];
        sup.for_each_id(|i| g += y[i] - m[i]);
        let w_new = soft_threshold(g, lam) / v[t];
        let delta = w_new - w[t];
        if delta != 0.0 {
            sup.for_each_id(|i| m[i] += delta);
            w[t] = w_new;
            max_delta = max_delta.max(delta.abs());
        }
    }
    // exact intercept step
    let mean_r: f64 = y.iter().zip(m.iter()).map(|(&yi, &mi)| yi - mi).sum::<f64>() / n;
    if mean_r != 0.0 {
        *b += mean_r;
        m.iter_mut().for_each(|mi| *mi += mean_r);
        max_delta = max_delta.max(mean_r.abs());
    }
    max_delta
}

/// One cyclic pass for L1 squared hinge over the coordinates in
/// `idxs`.  Majorized prox steps with curvature `v_t`; returns max |Δ|.
#[allow(clippy::too_many_arguments)]
fn epoch_classification<S: ColumnRead>(
    idxs: &[usize],
    cols: &[S],
    y: &[f64],
    v: &[f64],
    w: &mut [f64],
    b: &mut f64,
    m: &mut [f64],
    lam: f64,
) -> f64 {
    let n = y.len() as f64;
    let mut max_delta = 0.0f64;
    for &t in idxs {
        let sup = &cols[t];
        if v[t] == 0.0 {
            continue;
        }
        // grad_t = -sum_{i in sup} y_i h_i
        let mut grad = 0.0;
        sup.for_each_id(|i| {
            let h = 1.0 - y[i] * m[i];
            if h > 0.0 {
                grad -= y[i] * h;
            }
        });
        let w_new = soft_threshold(v[t] * w[t] - grad, lam) / v[t];
        let delta = w_new - w[t];
        if delta != 0.0 {
            sup.for_each_id(|i| m[i] += delta);
            w[t] = w_new;
            max_delta = max_delta.max(delta.abs());
        }
    }
    // intercept: majorized step with curvature n
    let mut grad_b = 0.0;
    for i in 0..y.len() {
        let h = 1.0 - y[i] * m[i];
        if h > 0.0 {
            grad_b -= y[i] * h;
        }
    }
    let delta_b = -grad_b / n;
    if delta_b != 0.0 {
        *b += delta_b;
        m.iter_mut().for_each(|mi| *mi += delta_b);
        max_delta = max_delta.max(delta_b.abs());
    }
    max_delta
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::ista;
    use crate::testutil::SplitMix64;

    fn random_problem(
        seed: u64,
        n: usize,
        k: usize,
        classify: bool,
    ) -> (Vec<Vec<u32>>, Vec<f64>) {
        let mut rng = SplitMix64::new(seed);
        let supports: Vec<Vec<u32>> = (0..k)
            .map(|_| {
                let m = rng.range(1, (n * 2 / 3).max(2));
                rng.sample_distinct(n, m).into_iter().map(|i| i as u32).collect()
            })
            .collect();
        let w_true: Vec<f64> = (0..k)
            .map(|t| if t < k / 3 { rng.gauss() * 2.0 } else { 0.0 })
            .collect();
        let mut score = vec![0.0; n];
        for (t, sup) in supports.iter().enumerate() {
            for &i in sup {
                score[i as usize] += w_true[t];
            }
        }
        let y: Vec<f64> = score
            .iter()
            .map(|&s| {
                let v = s + 0.2 * rng.gauss();
                if classify {
                    if v >= 0.0 {
                        1.0
                    } else {
                        -1.0
                    }
                } else {
                    v
                }
            })
            .collect();
        (supports, y)
    }

    #[test]
    fn regression_gap_closes() {
        let (sup, y) = random_problem(1, 60, 12, false);
        let sol = CdSolver::default().solve(Task::Regression, &sup, &y, 1.0, None);
        assert!(sol.gap <= 1e-6, "gap {}", sol.gap);
        assert!(sol.dual <= sol.primal + 1e-12);
    }

    #[test]
    fn classification_gap_closes() {
        let (sup, y) = random_problem(2, 80, 10, true);
        let sol = CdSolver::default().solve(Task::Classification, &sup, &y, 0.5, None);
        assert!(sol.gap <= 1e-6, "gap {}", sol.gap);
    }

    #[test]
    fn regression_kkt_holds() {
        let (sup, y) = random_problem(3, 50, 8, false);
        let lam = 0.8;
        let sol = CdSolver::default().solve(Task::Regression, &sup, &y, lam, None);
        // residual correlations: |x_t^T r| <= lam (active: == lam sign(w))
        for (t, s) in sup.iter().enumerate() {
            let corr: f64 = s.iter().map(|&i| sol.slack[i as usize]).sum();
            if sol.w[t] != 0.0 {
                assert!(
                    (corr - lam * sol.w[t].signum()).abs() < 1e-3,
                    "active KKT: corr={corr} w={}",
                    sol.w[t]
                );
            } else {
                assert!(corr.abs() <= lam + 1e-3, "inactive KKT: {corr}");
            }
        }
        // intercept optimality
        let sum_r: f64 = sol.slack.iter().sum();
        assert!(sum_r.abs() < 1e-3);
    }

    #[test]
    fn matches_dense_ista_oracle() {
        for seed in [5u64, 6, 7] {
            let (sup, y) = random_problem(seed, 40, 6, false);
            let lam = 0.6;
            let sol = CdSolver::default().solve(Task::Regression, &sup, &y, lam, None);
            let oracle = ista::solve_dense(Task::Regression, &sup, &y, lam, 1e-9, 200_000);
            assert!(
                (sol.primal - oracle.primal).abs() < 1e-4 * (1.0 + oracle.primal.abs()),
                "primal {} vs oracle {}",
                sol.primal,
                oracle.primal
            );
            for (a, b) in sol.w.iter().zip(&oracle.w) {
                assert!((a - b).abs() < 5e-3, "w mismatch {a} vs {b}");
            }
        }
    }

    #[test]
    fn classification_matches_ista_oracle() {
        let (sup, y) = random_problem(8, 60, 6, true);
        let lam = 0.4;
        let sol = CdSolver::default().solve(Task::Classification, &sup, &y, lam, None);
        let oracle = ista::solve_dense(Task::Classification, &sup, &y, lam, 1e-9, 200_000);
        assert!(
            (sol.primal - oracle.primal).abs() < 1e-4 * (1.0 + oracle.primal.abs()),
            "primal {} vs oracle {}",
            sol.primal,
            oracle.primal
        );
    }

    #[test]
    fn dynamic_screening_changes_nothing_but_freezes_columns() {
        // same optimum with and without screening, on both tasks
        for (seed, classify, lam) in [(31u64, false, 0.9), (32, true, 0.6)] {
            let task = if classify {
                Task::Classification
            } else {
                Task::Regression
            };
            let (sup, y) = random_problem(seed, 70, 20, classify);
            let on = CdSolver::default().solve(task, &sup, &y, lam, None);
            let mut plain = CdSolver::default();
            plain.cfg.dynamic_screen = false;
            let off = plain.solve(task, &sup, &y, lam, None);
            assert_eq!(off.screened, 0);
            assert!(on.gap <= 1e-6 && off.gap <= 1e-6);
            assert!(
                (on.primal - off.primal).abs() < 1e-6 * (1.0 + off.primal.abs()),
                "screening moved the optimum: {} vs {}",
                on.primal,
                off.primal
            );
            // same tolerance the ISTA-oracle cross-check uses: at gap
            // 1e-6 the weights are pinned to ~sqrt(gap) per coordinate
            for (a, b) in on.w.iter().zip(&off.w) {
                assert!((a - b).abs() < 5e-3, "w mismatch {a} vs {b}");
            }
            // frozen columns really are inactive
            assert!(on.w.iter().filter(|&&w| w == 0.0).count() >= on.screened);
        }
    }

    #[test]
    fn dynamic_screening_fires_on_sparse_problems() {
        // plenty of irrelevant columns at a mid-path λ: screening must
        // actually freeze some of them before convergence (frequent gap
        // checks so an intermediate-gap round is guaranteed to exist)
        let (sup, y) = random_problem(33, 200, 60, false);
        let mut solver = CdSolver::default();
        solver.cfg.gap_check_every = 1;
        let sol = solver.solve(Task::Regression, &sup, &y, 4.0, None);
        assert!(sol.gap <= 1e-6);
        assert!(sol.screened > 0, "no column was ever frozen");
    }

    #[test]
    fn borrowed_column_views_solve_identically() {
        let (sup, y) = random_problem(34, 50, 8, false);
        let views: Vec<&[u32]> = sup.iter().map(|s| s.as_slice()).collect();
        let a = CdSolver::default().solve(Task::Regression, &sup, &y, 0.7, None);
        let b = CdSolver::default().solve(Task::Regression, &views, &y, 0.7, None);
        assert_eq!(a.w, b.w);
        assert_eq!(a.b, b.b);
        assert_eq!(a.gap, b.gap);
    }

    #[test]
    fn hybrid_columns_solve_bit_identically() {
        use crate::columns::HybridColumn;
        // n past one chunk and columns dense enough to build bitmap
        // words: the whole solve — epochs, dynamic screening, dual
        // certificates — must be bit-identical across layouts
        for (seed, classify, lam) in [(35u64, false, 0.7), (36, true, 0.4)] {
            let task = if classify {
                Task::Classification
            } else {
                Task::Regression
            };
            let (sup, y) = random_problem(seed, 6000, 10, classify);
            let hybrids: Vec<HybridColumn> =
                sup.iter().map(|s| HybridColumn::from_sorted(s.clone())).collect();
            let a = CdSolver::default().solve(task, &sup, &y, lam, None);
            let b = CdSolver::default().solve(task, &hybrids, &y, lam, None);
            assert_eq!(a.w, b.w, "weights drifted across layouts");
            assert_eq!(a.b.to_bits(), b.b.to_bits());
            assert_eq!(a.gap.to_bits(), b.gap.to_bits());
            assert_eq!(a.epochs, b.epochs);
            assert_eq!(a.screened, b.screened);
        }
    }

    #[test]
    fn large_lambda_gives_zero_weights() {
        let (sup, y) = random_problem(9, 40, 5, false);
        let sol = CdSolver::default().solve(Task::Regression, &sup, &y, 1e6, None);
        assert!(sol.w.iter().all(|&w| w == 0.0));
        // intercept-only optimum: b = mean(y)
        let mean = y.iter().sum::<f64>() / y.len() as f64;
        assert!((sol.b - mean).abs() < 1e-6);
    }

    #[test]
    fn warm_start_converges_faster() {
        let (sup, y) = random_problem(10, 120, 20, false);
        let cold = CdSolver::default().solve(Task::Regression, &sup, &y, 0.5, None);
        let warm = CdSolver::default().solve(
            Task::Regression,
            &sup,
            &y,
            0.45,
            Some(Warm {
                w: &cold.w,
                b: cold.b,
            }),
        );
        let cold2 = CdSolver::default().solve(Task::Regression, &sup, &y, 0.45, None);
        assert!(warm.epochs <= cold2.epochs, "warm {} cold {}", warm.epochs, cold2.epochs);
        assert!((warm.primal - cold2.primal).abs() < 1e-5 * (1.0 + cold2.primal.abs()));
    }

    #[test]
    fn empty_support_columns_are_ignored() {
        let sup = vec![vec![], vec![0u32, 1]];
        let y = vec![1.0, -1.0, 2.0];
        let sol = CdSolver::default().solve(Task::Regression, &sup, &y, 0.1, None);
        assert_eq!(sol.w[0], 0.0);
        assert!(sol.gap <= 1e-6);
    }

    #[test]
    fn no_columns_solves_intercept_only() {
        let y = vec![1.0, 3.0, 5.0];
        let none: [Vec<u32>; 0] = [];
        let sol = CdSolver::default().solve(Task::Regression, &none, &y, 1.0, None);
        assert!((sol.b - 3.0).abs() < 1e-9);
        assert!(sol.gap <= 1e-6);
    }

    #[test]
    fn soft_threshold_branches() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
    }
}
