//! Gap-safe dual-feasible point construction.
//!
//! The SPP rule (Theorem 2) needs *any* dual-feasible `θ̃`; its power
//! scales with the duality gap, so we build the natural choice from the
//! current primal iterate: `θᵢ = −f'(zᵢ)/λ` (the residual/hinge slack),
//! then repair feasibility:
//!
//! * `βᵀθ = 0` — exact recentering (regression) / alternating
//!   projection with the `θ ≥ 0` cone (classification);
//! * `|Σᵢ α_it θᵢ| ≤ 1` for the *columns at hand* — one global shrink
//!   by the worst violation.  Feasibility over all of `T` is inherited
//!   from solving the Â-restricted problem to tolerance, exactly as in
//!   the paper's Algorithm 1 (a `certify` pass in `screening` can make
//!   it exact via one bounded tree search).

use super::problem::Task;
use crate::columns::ColumnRead;

/// Max over columns of `|Σ_{i∈sup} g_i|` for sparse supports (accepts
/// any [`ColumnRead`] carrier — owned columns, borrowed `&[u32]` views,
/// or the pool's layout-aware views, whose hybrid columns sum over
/// bitmap words bit-identically to the scalar walk).
pub fn max_abs_col_sum<S: ColumnRead>(supports: &[S], g: &[f64]) -> f64 {
    let mut best = 0.0f64;
    for sup in supports {
        let s = sup.dot(g);
        best = best.max(s.abs());
    }
    best
}

/// Dual-feasible point for regression from the residual vector
/// `r_i = y_i − (xᵢᵀw + b)`.
///
/// Returns `θ` with `Σθ = 0` and `|x_tᵀθ| ≤ 1` over `supports`.
pub fn dual_point_regression<S: ColumnRead>(r: &[f64], lam: f64, supports: &[S]) -> Vec<f64> {
    let n = r.len();
    let mean = r.iter().sum::<f64>() / n as f64;
    let mut theta: Vec<f64> = r.iter().map(|&ri| (ri - mean) / lam).collect();
    let viol = max_abs_col_sum(supports, &theta);
    if viol > 1.0 {
        let s = 1.0 / viol;
        theta.iter_mut().for_each(|t| *t *= s);
    }
    theta
}

/// Dual-feasible point for classification from the hinge slacks
/// `h_i = max(0, 1 − y_i(xᵢᵀw + b))`.
///
/// Returns `θ ≥ 0` with `yᵀθ ≈ 0` (alternating projections + exact
/// final step, clipping O(eps) negatives) and `|Σ y_i x_it θ_i| ≤ 1`
/// over `supports`.
pub fn dual_point_classification<S: ColumnRead>(
    h: &[f64],
    y: &[f64],
    lam: f64,
    supports: &[S],
) -> Vec<f64> {
    let n = h.len() as f64;
    let mut theta: Vec<f64> = h.iter().map(|&hi| hi.max(0.0) / lam).collect();
    for _ in 0..12 {
        let dot: f64 = y.iter().zip(&theta).map(|(a, b)| a * b).sum();
        if dot.abs() < 1e-15 {
            break;
        }
        let c = dot / n;
        for (t, &yi) in theta.iter_mut().zip(y) {
            *t = (*t - c * yi).max(0.0);
        }
    }
    // exact hyperplane step; tiny negatives are clipped
    let dot: f64 = y.iter().zip(&theta).map(|(a, b)| a * b).sum();
    let c = dot / n;
    for (t, &yi) in theta.iter_mut().zip(y) {
        *t = (*t - c * yi).max(0.0);
    }
    // box shrink over present columns (alpha = y .* x)
    let g: Vec<f64> = y.iter().zip(&theta).map(|(a, b)| a * b).collect();
    let viol = max_abs_col_sum(supports, &g);
    if viol > 1.0 {
        let s = 1.0 / viol;
        theta.iter_mut().for_each(|t| *t *= s);
    }
    theta
}

/// Unified entry: slacks are residuals (regression) or hinge slacks
/// (classification); see `problem::SampleState`.
pub fn dual_point<S: ColumnRead>(
    task: Task,
    slack: &[f64],
    y: &[f64],
    lam: f64,
    supports: &[S],
) -> Vec<f64> {
    match task {
        Task::Regression => dual_point_regression(slack, lam, supports),
        Task::Classification => dual_point_classification(slack, y, lam, supports),
    }
}

/// Gap-safe ball radius `r_λ = sqrt(2·gap)/λ` (Lemma 5).  Negative gaps
/// (numerical noise at convergence) clamp to zero.
pub fn safe_radius(primal: f64, dual: f64, lam: f64) -> f64 {
    (2.0 * (primal - dual).max(0.0)).sqrt() / lam
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::SplitMix64;

    fn rand_supports(rng: &mut SplitMix64, n: usize, k: usize) -> Vec<Vec<u32>> {
        (0..k)
            .map(|_| {
                let m = rng.range(1, n / 2);
                rng.sample_distinct(n, m).into_iter().map(|i| i as u32).collect()
            })
            .collect()
    }

    #[test]
    fn regression_point_is_feasible() {
        let mut rng = SplitMix64::new(11);
        for _ in 0..20 {
            let n = 40;
            let r: Vec<f64> = (0..n).map(|_| rng.gauss() * 3.0).collect();
            let sup = rand_supports(&mut rng, n, 8);
            let theta = dual_point_regression(&r, 0.7, &sup);
            let sum: f64 = theta.iter().sum();
            assert!(sum.abs() < 1e-9, "sum {sum}");
            assert!(max_abs_col_sum(&sup, &theta) <= 1.0 + 1e-12);
        }
    }

    #[test]
    fn classification_point_is_feasible() {
        let mut rng = SplitMix64::new(13);
        for _ in 0..20 {
            let n = 50;
            let y: Vec<f64> = (0..n).map(|_| if rng.coin(0.5) { 1.0 } else { -1.0 }).collect();
            let h: Vec<f64> = (0..n).map(|_| rng.next_f64() * 2.0).collect();
            let sup = rand_supports(&mut rng, n, 6);
            let theta = dual_point_classification(&h, &y, 0.5, &sup);
            assert!(theta.iter().all(|&t| t >= 0.0));
            let ydot: f64 = y.iter().zip(&theta).map(|(a, b)| a * b).sum();
            assert!(ydot.abs() < 5e-2, "y^T theta = {ydot}");
            let g: Vec<f64> = y.iter().zip(&theta).map(|(a, b)| a * b).collect();
            assert!(max_abs_col_sum(&sup, &g) <= 1.0 + 1e-12);
        }
    }

    #[test]
    fn no_shrink_when_inside_box() {
        // residuals so small the box is slack: theta = centered r / lam
        let r = vec![0.01, -0.01, 0.0, 0.0];
        let sup = vec![vec![0u32, 1]];
        let theta = dual_point_regression(&r, 1.0, &sup);
        assert!((theta[0] - 0.01).abs() < 1e-12);
    }

    #[test]
    fn safe_radius_matches_lemma5() {
        assert!((safe_radius(2.0, 0.0, 2.0) - 1.0).abs() < 1e-12);
        assert_eq!(safe_radius(1.0, 1.5, 1.0), 0.0); // clamped
    }

    #[test]
    fn max_abs_col_sum_picks_worst() {
        let g = vec![1.0, -2.0, 3.0];
        let sup = vec![vec![0u32], vec![1u32, 2]];
        assert!((max_abs_col_sum(&sup, &g) - 1.0f64.max(1.0)).abs() < 1e-12);
        let sup2 = vec![vec![1u32], vec![2u32]];
        assert!((max_abs_col_sum(&sup2, &g) - 3.0).abs() < 1e-12);
    }
}
