//! The paper's unified problem form and its two instantiations.
//!
//! Primal (eq. 2): `min_{w,b} Σ_i f(αᵢᵀw + βᵢb + γᵢ) + λ‖w‖₁` with
//!
//! * **Regression** (eq. 3): `f(z) = z²/2`, `αᵢ = xᵢ`, `βᵢ = 1`,
//!   `γᵢ = −yᵢ` → L1 least squares.
//! * **Classification** (eq. 4): `f(z) = max(0, 1−z)²/2`, `αᵢ = yᵢxᵢ`,
//!   `βᵢ = yᵢ`, `γᵢ = 0` → L1 squared-hinge SVM.
//!
//! Dual (eq. 5): `max_θ −(λ²/2)‖θ‖² + λδᵀθ` s.t. `|Σᵢ α_it θᵢ| ≤ 1 ∀t`,
//! `βᵀθ = 0`, `θᵢ ≥ ε`, with `(δ, ε) = (y, −∞)` and `(1, 0)`
//! respectively.
//!
//! Everything downstream (CD steps, SPP weights, boosting scores) works
//! through the per-sample quantities defined here, so both tasks share
//! one code path — mirroring the paper's presentation.

/// Which instantiation of eq. (2) is being solved.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Task {
    Regression,
    Classification,
}

impl Task {
    /// `a_i` such that `α_it = a_i · x_it` (1 or `y_i`).
    #[inline]
    pub fn a(self, yi: f64) -> f64 {
        match self {
            Task::Regression => 1.0,
            Task::Classification => yi,
        }
    }

    /// `β_i` (1 or `y_i`).
    #[inline]
    pub fn beta(self, yi: f64) -> f64 {
        match self {
            Task::Regression => 1.0,
            Task::Classification => yi,
        }
    }

    /// `δ_i` in the dual objective (`y_i` or 1).
    #[inline]
    pub fn delta(self, yi: f64) -> f64 {
        match self {
            Task::Regression => yi,
            Task::Classification => 1.0,
        }
    }
}

/// Loss value `f(z_i)` given the per-sample *model margin*.
///
/// The solver tracks, per sample, the quantity the loss consumes:
/// * regression: the residual `r_i = y_i − (xᵢᵀw + b)`, `f = r²/2`;
/// * classification: the hinge slack `h_i = max(0, 1 − y_i(xᵢᵀw + b))`,
///   `f = h²/2`.
///
/// Both are "how far sample i is from being perfectly fit", and in both
/// cases `−f'(z_i) = r_i` (resp. `h_i`), which is why the same vector
/// doubles as the unscaled dual point (θᵢ = r_i/λ resp. h_i/λ).
#[derive(Clone, Debug)]
pub struct SampleState {
    /// `r_i` (regression) or `h_i` (classification); see above.
    pub slack: Vec<f64>,
}

/// Primal objective from the per-sample slacks.
pub fn primal_value(slack: &[f64], l1_norm_w: f64, lam: f64) -> f64 {
    0.5 * slack.iter().map(|s| s * s).sum::<f64>() + lam * l1_norm_w
}

/// Dual objective `−(λ²/2)‖θ‖² + λ δᵀθ`.
pub fn dual_value(task: Task, theta: &[f64], y: &[f64], lam: f64) -> f64 {
    let mut quad = 0.0;
    let mut lin = 0.0;
    for (i, &t) in theta.iter().enumerate() {
        quad += t * t;
        lin += task.delta(y[i]) * t;
    }
    -0.5 * lam * lam * quad + lam * lin
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coefficient_folding_matches_paper() {
        assert_eq!(Task::Regression.a(-3.0), 1.0);
        assert_eq!(Task::Classification.a(-1.0), -1.0);
        assert_eq!(Task::Regression.beta(2.0), 1.0);
        assert_eq!(Task::Classification.beta(-1.0), -1.0);
        assert_eq!(Task::Regression.delta(2.5), 2.5);
        assert_eq!(Task::Classification.delta(2.5), 1.0);
    }

    #[test]
    fn primal_value_basic() {
        // slacks [1, 2], ||w||_1 = 3, lam = 0.5 -> 0.5*(1+4) + 1.5 = 4.0
        assert!((primal_value(&[1.0, 2.0], 3.0, 0.5) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn dual_value_regression_vs_classification() {
        let theta = vec![0.5, -0.5];
        let y = vec![1.0, -1.0];
        // regression: -lam^2/2 * 0.5 + lam*(0.5*1 + (-0.5)(-1)) with lam=1
        let dr = dual_value(Task::Regression, &theta, &y, 1.0);
        assert!((dr - (-0.25 + 1.0)).abs() < 1e-12);
        // classification: delta = 1 -> linear term 0
        let dc = dual_value(Task::Classification, &theta, &y, 1.0);
        assert!((dc - (-0.25 + 0.0)).abs() < 1e-12);
    }
}
