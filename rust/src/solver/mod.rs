//! L1-regularized solvers for the paper's unified problem (eq. 2).
//!
//! * [`problem`] — the `(α, β, γ, δ, ε)` instantiations: L1 least
//!   squares (eq. 3) and L1 squared-hinge SVM (eq. 4), plus shared
//!   primal/dual objective code.
//! * [`cd`] — the working-set solver: cyclic proximal coordinate
//!   descent (Tseng & Yun style majorized steps), duality-gap stopping
//!   at the paper's 1e-6, warm starts.
//! * [`dual`] — gap-safe dual-feasible point construction (the `θ̃` the
//!   SPP rule consumes).
//! * [`ista`] — a dense FISTA oracle used by the test-suite to verify
//!   the CD solver on materialized problems.

pub mod cd;
pub mod dual;
pub mod ista;
pub mod problem;

pub use cd::{CdConfig, CdSolver, Solution};
pub use problem::Task;
