//! Result reporting: paper-style tables and a JSON dump.
//!
//! The vendored crate set has no serde/serde_json, so the JSON emitter
//! is hand-rolled (flat structure, numbers and strings only — easy to
//! keep correct).

use super::ExperimentResult;

/// Minimal JSON string escaping.
fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Format a float compactly but losslessly enough for analysis.
fn num(x: f64) -> String {
    if x == x.trunc() && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x:.6e}")
    }
}

/// One experiment as a JSON object (single line).
pub fn result_json(r: &ExperimentResult) -> String {
    let mut per_lambda = String::from("[");
    for (i, p) in r.path.points.iter().enumerate() {
        if i > 0 {
            per_lambda.push(',');
        }
        per_lambda.push_str(&format!(
            "{{\"lambda\":{},\"traverse_secs\":{},\"solve_secs\":{},\"nodes\":{},\"working\":{},\"active\":{},\"rounds\":{},\"gap\":{},\"screen_workers\":{},\"screen_tasks\":{},\"chunk_mine_nodes\":{},\"chunk_hit\":{},\"resident_cols\":{},\"resident_bytes\":{},\"spilled_cols\":{},\"reloaded\":{},\"evicted\":{}}}",
            num(p.lambda),
            num(p.traverse_secs),
            num(p.solve_secs),
            p.stats.nodes,
            p.working_size,
            p.active.len(),
            p.rounds,
            num(p.gap),
            p.threads.workers,
            p.threads.tasks,
            p.reuse.chunk_mine_nodes,
            p.reuse.chunk_hit,
            p.spill.resident_cols,
            p.spill.resident_bytes,
            p.spill.spilled_cols,
            p.spill.reloaded,
            p.spill.evicted
        ));
    }
    per_lambda.push(']');
    format!(
        "{{\"dataset\":\"{}\",\"method\":\"{}\",\"maxpat\":{},\"scale\":{},\"n\":{},\"lambda_max\":{},\"traverse_secs\":{},\"solve_secs\":{},\"total_secs\":{},\"nodes\":{},\"final_active\":{},\"max_gap\":{},\"per_lambda\":{}}}",
        esc(&r.spec.dataset),
        r.spec.method.name(),
        r.spec.maxpat,
        num(r.spec.scale),
        r.n_records,
        num(r.lambda_max),
        num(r.traverse_secs),
        num(r.solve_secs),
        num(r.total_secs),
        r.traverse_nodes,
        r.final_active,
        num(r.max_gap),
        per_lambda
    )
}

/// Paper-style time row (Figures 2/3): total with traverse/solve split.
pub fn time_row(r: &ExperimentResult) -> String {
    format!(
        "{:<14} maxpat={:<2} {:<9} total={:>9.3}s  traverse={:>9.3}s  solve={:>9.3}s  nodes={:>10}  active={:>5}",
        r.spec.dataset,
        r.spec.maxpat,
        r.spec.method.name(),
        r.total_secs,
        r.traverse_secs,
        r.solve_secs,
        r.traverse_nodes,
        r.final_active,
    )
}

/// Paper-style node-count row (Figures 4/5).
pub fn nodes_row(r: &ExperimentResult) -> String {
    format!(
        "{:<14} maxpat={:<2} {:<9} traversed_nodes={:>12}",
        r.spec.dataset,
        r.spec.maxpat,
        r.spec.method.name(),
        r.traverse_nodes,
    )
}

/// Speedup summary for a (spp, boosting) pair on the same workload.
pub fn speedup_row(spp: &ExperimentResult, boost: &ExperimentResult) -> String {
    assert_eq!(spp.spec.dataset, boost.spec.dataset);
    assert_eq!(spp.spec.maxpat, boost.spec.maxpat);
    let t = boost.total_secs / spp.total_secs.max(1e-12);
    let n = boost.traverse_nodes as f64 / spp.traverse_nodes.max(1) as f64;
    format!(
        "{:<14} maxpat={:<2} speedup: time x{:.2}  nodes x{:.2}",
        spp.spec.dataset, spp.spec.maxpat, t, n
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{run_experiment, ExperimentSpec, Method};
    use crate::path::PathConfig;

    fn mini() -> ExperimentResult {
        run_experiment(&ExperimentSpec {
            dataset: "splice".into(),
            scale: 0.02,
            maxpat: 2,
            method: Method::Spp,
            cfg: PathConfig {
                n_lambdas: 3,
                lambda_min_ratio: 0.2,
                ..PathConfig::default()
            },
        })
        .unwrap()
    }

    #[test]
    fn json_has_expected_fields_and_balance() {
        let j = result_json(&mini());
        for key in [
            "\"dataset\":\"splice\"",
            "\"method\":\"spp\"",
            "\"per_lambda\":[",
            "\"nodes\":",
            "\"screen_workers\":",
            "\"chunk_mine_nodes\":",
            "\"chunk_hit\":",
            "\"resident_bytes\":",
            "\"reloaded\":",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
        // crude structural validity: balanced braces/brackets
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }

    #[test]
    fn rows_render() {
        let r = mini();
        assert!(time_row(&r).contains("traverse="));
        assert!(nodes_row(&r).contains("traversed_nodes="));
        let s = speedup_row(&r, &r);
        assert!(s.contains("x1.00"));
    }

    #[test]
    fn esc_escapes_quotes() {
        assert_eq!(esc("a\"b\\c"), "a\\\"b\\\\c");
    }

    #[test]
    fn num_formats_integers_plainly() {
        assert_eq!(num(5.0), "5");
        assert!(num(0.5).contains('e'));
    }
}
