//! Experiment orchestration: the L3 coordinator.
//!
//! Figure benches and the CLI express work as [`ExperimentSpec`]s
//! (dataset × maxpat × method); the coordinator materializes the data,
//! runs the regularization path, and emits [`ExperimentResult`] rows —
//! the exact currency of the paper's Figures 2–5.  A [`Pool`] runs
//! independent specs in parallel on the shared
//! [`crate::runtime::parallel`] worker pool (benches pin `workers = 1`
//! to match the paper's single-core discipline).

pub mod report;

use std::time::Instant;

use crate::data::registry::{
    self, RegistrySubstrate, ShardedSubstrateVisitor, SubstrateVisitor,
};
use crate::path::{
    compute_path_boosting, compute_path_spp, compute_path_spp_with, PathConfig, PathResult,
    RestrictedSolver,
};
use crate::solver::Task;
use crate::storage::{ShardCodec, ShardedDb};

/// Which method computes the path.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    Spp,
    Boosting,
}

impl Method {
    pub fn name(self) -> &'static str {
        match self {
            Method::Spp => "spp",
            Method::Boosting => "boosting",
        }
    }
}

/// One experiment: a dataset preset at a scale, a maxpat, a method.
#[derive(Clone, Debug)]
pub struct ExperimentSpec {
    pub dataset: String,
    pub scale: f64,
    pub maxpat: usize,
    pub method: Method,
    pub cfg: PathConfig,
}

/// Aggregated outcome of one experiment.
#[derive(Clone, Debug)]
pub struct ExperimentResult {
    pub spec: ExperimentSpec,
    pub task: Task,
    pub n_records: usize,
    pub lambda_max: f64,
    pub traverse_secs: f64,
    pub solve_secs: f64,
    pub total_secs: f64,
    pub wall_secs: f64,
    pub traverse_nodes: u64,
    /// Active-set size at the smallest λ.
    pub final_active: usize,
    /// Max duality gap across the path (certifies optimality).
    pub max_gap: f64,
    pub path: PathResult,
}

/// The coordinator's path visitor: per-method dispatch (SPP vs
/// boosting — both run the shared `PathDriver`) over any substrate.
/// Implements both visitor traits, so the same code runs in-memory
/// datasets and out-of-core shard containers (`ShardedDb` is itself a
/// `PatternSubstrate`).
struct PathVisitor<'a> {
    task: Task,
    method: Method,
    cfg: &'a PathConfig,
}

impl SubstrateVisitor for PathVisitor<'_> {
    type Out = crate::Result<PathResult>;
    fn visit<S: RegistrySubstrate>(self, db: &S, y: &[f64]) -> Self::Out {
        match self.method {
            Method::Spp => compute_path_spp(db, y, self.task, self.cfg),
            Method::Boosting => compute_path_boosting(db, y, self.task, self.cfg),
        }
    }
}

impl ShardedSubstrateVisitor for PathVisitor<'_> {
    type Out = crate::Result<PathResult>;
    fn visit<S>(self, db: &ShardedDb<S>, y: &[f64]) -> Self::Out
    where
        S: RegistrySubstrate + ShardCodec,
    {
        match self.method {
            Method::Spp => compute_path_spp(db, y, self.task, self.cfg),
            Method::Boosting => compute_path_boosting(db, y, self.task, self.cfg),
        }
    }
}

/// SPP path with an explicit restricted-solver engine (the XLA FISTA
/// engine in `run_experiment_xla`).
struct SolverPathVisitor<'a> {
    task: Task,
    cfg: &'a PathConfig,
    solver: &'a dyn RestrictedSolver,
}

impl SubstrateVisitor for SolverPathVisitor<'_> {
    type Out = crate::Result<PathResult>;
    fn visit<S: RegistrySubstrate>(self, db: &S, y: &[f64]) -> Self::Out {
        compute_path_spp_with(db, y, self.task, self.cfg, self.solver)
    }
}

/// Fold a finished path into the result row every engine shape shares.
fn finish(
    spec: &ExperimentSpec,
    task: Task,
    n_records: usize,
    path: PathResult,
    wall_secs: f64,
) -> ExperimentResult {
    let max_gap = path.points.iter().map(|p| p.gap).fold(0.0f64, f64::max);
    ExperimentResult {
        task,
        n_records,
        lambda_max: path.lambda_max,
        traverse_secs: path.total_traverse_secs(),
        solve_secs: path.total_solve_secs(),
        total_secs: path.total_secs(),
        wall_secs,
        traverse_nodes: path.total_nodes(),
        final_active: path.points.last().map(|p| p.active.len()).unwrap_or(0),
        max_gap,
        path,
        spec: spec.clone(),
    }
}

/// Run one experiment spec to completion.
pub fn run_experiment(spec: &ExperimentSpec) -> crate::Result<ExperimentResult> {
    let info = registry::require_info(&spec.dataset)?;
    let data = registry::lookup(&spec.dataset, spec.scale)?;
    let mut cfg = spec.cfg;
    cfg.maxpat = spec.maxpat;

    let wall = Instant::now();
    let path = data.visit(PathVisitor {
        task: info.task,
        method: spec.method,
        cfg: &cfg,
    })?;
    Ok(finish(
        spec,
        info.task,
        data.n_records(),
        path,
        wall.elapsed().as_secs_f64(),
    ))
}

/// Path over an on-disk sharded database ([`registry::lookup_sharded`]).
///
/// Identical math to [`run_experiment`] — `ShardedDb` implements
/// `PatternSubstrate`, so the whole path stack runs unchanged; the
/// shard layer only changes *where the records live* during the
/// screening traversals (per-shard streaming for item sets, a resident
/// union for graph/sequence shards — DESIGN.md "Out-of-core shards").
pub fn run_experiment_sharded(
    spec: &ExperimentSpec,
    shards: usize,
    dir: &std::path::Path,
) -> crate::Result<ExperimentResult> {
    let info = registry::require_info(&spec.dataset)?;
    let data = registry::lookup_sharded(&spec.dataset, spec.scale, shards, dir)?;
    let mut cfg = spec.cfg;
    cfg.maxpat = spec.maxpat;

    let wall = Instant::now();
    let path = data.visit(PathVisitor {
        task: info.task,
        method: spec.method,
        cfg: &cfg,
    })?;
    eprintln!(
        "sharded engine: {} shards in {}, peak resident columns {} bytes, {} reloads",
        shards,
        dir.display(),
        path.max_resident_bytes(),
        path.total_spill_reloads()
    );
    Ok(finish(
        spec,
        info.task,
        data.n_records(),
        path,
        wall.elapsed().as_secs_f64(),
    ))
}

/// SPP path with the XLA FISTA engine for the restricted solves.
pub fn run_experiment_xla(spec: &ExperimentSpec) -> crate::Result<ExperimentResult> {
    use crate::runtime::{default_artifact_dir, engine::XlaRestricted, PjrtRuntime};

    let info = registry::require_info(&spec.dataset)?;
    let data = registry::lookup(&spec.dataset, spec.scale)?;
    let mut cfg = spec.cfg;
    cfg.maxpat = spec.maxpat;
    let rt = PjrtRuntime::cpu(&default_artifact_dir())?;
    let solver = XlaRestricted::new(&rt);

    let wall = Instant::now();
    let path = data.visit(SolverPathVisitor {
        task: info.task,
        cfg: &cfg,
        solver: &solver,
    })?;
    eprintln!(
        "xla engine: {} subproblem fallbacks to CD",
        solver.fallbacks.get()
    );
    Ok(finish(
        spec,
        info.task,
        data.n_records(),
        path,
        wall.elapsed().as_secs_f64(),
    ))
}

/// A fixed-size worker pool over experiment specs.
pub struct Pool {
    pub workers: usize,
}

impl Pool {
    pub fn new(workers: usize) -> Self {
        Pool {
            workers: workers.max(1),
        }
    }

    /// Run all specs; results come back in input order.  Worker panics
    /// surface as errors for their spec, not crashes of the pool
    /// (caught inside the task, so the shared `map_indexed` scope never
    /// sees them).
    ///
    /// When the pool itself fans out, each experiment's engine is
    /// pinned to one worker — otherwise every experiment would
    /// re-resolve `PathConfig::threads` (auto by default) and the two
    /// parallel levels would multiply into workers×threads live
    /// threads.  Bit-identity makes this a pure scheduling choice, the
    /// same pinning `path::cv` applies to its folds.
    pub fn run(&self, specs: Vec<ExperimentSpec>) -> Vec<crate::Result<ExperimentResult>> {
        let mut specs = specs;
        if crate::runtime::parallel::effective_workers(self.workers, specs.len()) > 1 {
            for s in &mut specs {
                s.cfg.threads = 1;
            }
        }
        let specs = &specs;
        crate::runtime::parallel::map_indexed(self.workers, specs.len(), |i| {
            std::panic::catch_unwind(|| run_experiment(&specs[i]))
                .unwrap_or_else(|_| Err(anyhow::anyhow!("worker panicked")))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec(method: Method) -> ExperimentSpec {
        ExperimentSpec {
            dataset: "splice".into(),
            scale: 0.03,
            maxpat: 2,
            method,
            cfg: PathConfig {
                n_lambdas: 5,
                lambda_min_ratio: 0.1,
                ..PathConfig::default()
            },
        }
    }

    #[test]
    fn run_experiment_produces_certified_path() {
        let r = run_experiment(&tiny_spec(Method::Spp)).unwrap();
        assert_eq!(r.path.points.len(), 5);
        assert!(r.max_gap <= 2e-6, "max gap {}", r.max_gap);
        assert!(r.traverse_nodes > 0);
        assert_eq!(r.task, Task::Classification);
    }

    #[test]
    fn pool_preserves_order_and_handles_errors() {
        let mut bad = tiny_spec(Method::Spp);
        bad.dataset = "no-such-dataset".into();
        let specs = vec![tiny_spec(Method::Spp), bad, tiny_spec(Method::Boosting)];
        let results = Pool::new(3).run(specs);
        assert_eq!(results.len(), 3);
        assert!(results[0].is_ok());
        assert!(results[1].is_err());
        assert!(results[2].is_ok());
        // both methods reach the same optimum: identical (‖w‖₁, b) at
        // every λ (active-set *sizes* may differ under duplicate
        // support columns, where w is not unique but the objective is)
        let a = results[0].as_ref().unwrap();
        let c = results[2].as_ref().unwrap();
        for (pa, pc) in a.path.points.iter().zip(&c.path.points) {
            let l1a: f64 = pa.active.iter().map(|(_, w)| w.abs()).sum();
            let l1c: f64 = pc.active.iter().map(|(_, w)| w.abs()).sum();
            assert!((l1a - l1c).abs() < 1e-3 * (1.0 + l1a), "λ={}", pa.lambda);
            assert!((pa.b - pc.b).abs() < 1e-3);
        }
    }
}
