//! Minimal CLI argument parsing (the vendored crate set has no clap).
//!
//! ## Grammar
//!
//! ```text
//! spp <command> [TOKEN...]
//! TOKEN := --name=value        flag with inline value (never consumes
//!                              the next token; `--certify=false` turns
//!                              a switch OFF)
//!        | --name value        flag: a bare `--name` consumes the next
//!                              token as its value IFF (a) `name` is not
//!                              a declared switch and (b) the next token
//!                              does not start with `--`.  Negative
//!                              numbers ("-1e-6") do not start with
//!                              `--`, so `--viol-tol -1e-6 --certify`
//!                              parses as expected.
//!        | --switch [BOOL]     a *declared* switch consumes the next
//!                              token only when it is a boolean literal
//!                              (true/false/1/0/yes/no/on/off), so
//!                              `--certify false` reads as OFF while
//!                              `--certify out.json` keeps `out.json`
//!                              positional
//!        | --name              switch (no value consumed): undeclared
//!                              names at end of argv or followed by
//!                              `--…`
//!        | anything else       positional
//! ```
//!
//! Flag-value consumption is *explicit* for the declared grammar
//! ([`Args::parse_with_switches`]): a declared switch never swallows a
//! following non-boolean positional, a declared value flag must get a
//! value, and any `--name` outside the declared switch + flag sets is
//! **rejected** with an error naming the flag (so a typo'd
//! `--treads 4` fails loudly instead of being silently ignored, and a
//! flag in the command position no longer falls through to the generic
//! "unknown command '--…'" message).  The zero-declaration
//! [`Args::parse`] keeps the historical permissive peek-based
//! behaviour for undeclared names — that footgun is pinned by tests
//! below so it stays documented.
//!
//! [`Args::switch`] answers truthiness from either form: a bare
//! `--name` is on; `--name=false`, `--name=0`, `--name=no` and
//! `--name=off` are off; any other value is on.
//!
//! The subcommand implementations live in [`commands`]; the `spp`
//! binary is a thin parse-and-[`commands::dispatch`] shell.

pub mod commands;

use std::collections::HashMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]), declaring
    /// nothing: every bare `--name` may consume a value and unknown
    /// names are accepted silently (see module docs).  Library /
    /// test-harness use; the `spp` binary parses its declared grammar
    /// via [`Args::parse_with_switches`].
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        Self::parse_inner(raw, &[], None).expect("permissive parse is infallible")
    }

    /// Parse against a fully declared grammar: `known_switches` are
    /// names that consume a following token only when it is a boolean
    /// literal (so they can never swallow a positional or a path);
    /// `known_flags` are the value-taking names.  Together they are the
    /// *only* accepted `--name`s — anything else errors with the
    /// offending flag named, as does a declared value flag with no
    /// value, or a flag sitting where the command should be.  This is
    /// the grammar the `spp` binary uses (its switch/flag sets live
    /// next to `main`).
    pub fn parse_with_switches<I: IntoIterator<Item = String>>(
        raw: I,
        known_switches: &[&str],
        known_flags: &[&str],
    ) -> crate::Result<Self> {
        Self::parse_inner(raw, known_switches, Some(known_flags))
    }

    /// Shared parser; `known_flags: None` = permissive (legacy
    /// behaviour, infallible), `Some(flags)` = strict declared grammar.
    fn parse_inner<I: IntoIterator<Item = String>>(
        raw: I,
        known_switches: &[&str],
        known_flags: Option<&[&str]>,
    ) -> crate::Result<Self> {
        let mut it = raw.into_iter().peekable();
        let command = it.next().unwrap_or_default();
        if known_flags.is_some() && command.starts_with("--") && command != "--help" {
            anyhow::bail!(
                "unexpected flag '{command}' where a command was expected \
                 (flags go after the command; try `spp help`)"
            );
        }
        let mut args = Args {
            command,
            ..Args::default()
        };
        loop {
            let Some(tok) = it.next() else { break };
            let Some(name) = tok.strip_prefix("--") else {
                args.positional.push(tok);
                continue;
            };
            if let Some((k, v)) = name.split_once('=') {
                if let Some(flags) = known_flags {
                    if !flags.contains(&k) && !known_switches.contains(&k) {
                        anyhow::bail!("unknown flag '--{k}' (try `spp help`)");
                    }
                }
                args.flags.insert(k.to_string(), v.to_string());
            } else if known_switches.contains(&name) {
                // a declared switch takes a value only when the next
                // token is unambiguously boolean, so `--certify false`
                // and `--certify=false` agree
                if it.peek().map(|nxt| is_bool_token(nxt)).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.flags.insert(name.to_string(), v);
                } else {
                    args.switches.push(name.to_string());
                }
            } else if let Some(flags) = known_flags {
                // strict grammar: only declared value flags remain, and
                // they must actually receive a value
                if !flags.contains(&name) {
                    anyhow::bail!("unknown flag '--{name}' (try `spp help`)");
                }
                let has_value = it.peek().map(|nxt| !nxt.starts_with("--")).unwrap_or(false);
                if !has_value {
                    anyhow::bail!("flag '--{name}' needs a value");
                }
                let v = it.next().unwrap();
                args.flags.insert(name.to_string(), v);
            } else if it
                .peek()
                .map(|nxt| !nxt.starts_with("--"))
                .unwrap_or(false)
            {
                let v = it.next().unwrap();
                args.flags.insert(name.to_string(), v);
            } else {
                args.switches.push(name.to_string());
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// Is the boolean flag `name` on?  A bare `--name` is on; a valued
    /// form is interpreted: `false`/`0`/`no`/`off` (exact,
    /// case-sensitive) are off, anything else is on.
    pub fn switch(&self, name: &str) -> bool {
        if self.switches.iter().any(|s| s == name) {
            return true;
        }
        match self.flag(name) {
            Some("false") | Some("0") | Some("no") | Some("off") => false,
            Some(_) => true,
            None => false,
        }
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.flag(name).unwrap_or(default)
    }

    /// The value of a mandatory flag, or an error naming it.
    pub fn require(&self, name: &str) -> crate::Result<&str> {
        self.flag(name)
            .ok_or_else(|| anyhow::anyhow!("--{name} <value> is required"))
    }

    pub fn get_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name}: bad number '{v}': {e}")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name}: bad integer '{v}': {e}")),
        }
    }
}

/// Boolean literals a *declared* switch may consume as its value.
fn is_bool_token(tok: &str) -> bool {
    matches!(tok, "true" | "false" | "1" | "0" | "yes" | "no" | "on" | "off")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    fn parse_sw(s: &str, switches: &[&str], flags: &[&str]) -> Args {
        Args::parse_with_switches(s.split_whitespace().map(String::from), switches, flags)
            .expect("declared grammar accepts this line")
    }

    #[test]
    fn parses_flags_switches_positionals() {
        // note: an *undeclared* bare `--switch` followed by a non-flag
        // token consumes it as a value (documented grammar);
        // positionals go first, the switch goes last, or the switch is
        // declared via parse_with_switches.
        let a = parse("path out.json --dataset cpdb --maxpat 5 --certify");
        assert_eq!(a.command, "path");
        assert_eq!(a.flag("dataset"), Some("cpdb"));
        assert_eq!(a.get_usize("maxpat", 0).unwrap(), 5);
        assert!(a.switch("certify"));
        assert_eq!(a.positional, vec!["out.json"]);
    }

    #[test]
    fn switch_before_positional_swallows_it_unless_declared() {
        // the documented footgun, pinned so it stays documented …
        let a = parse("path --certify out.json");
        assert_eq!(a.flag("certify"), Some("out.json"));
        assert!(a.switch("certify"));
        assert!(a.positional.is_empty());
        // … and the explicit-grammar fix: declared switches only
        // consume boolean literals, never positionals
        let a = parse_sw("path --certify out.json", &["certify"], &[]);
        assert!(a.switch("certify"));
        assert!(a.flag("certify").is_none());
        assert_eq!(a.positional, vec!["out.json"]);
    }

    #[test]
    fn declared_switch_space_and_equals_booleans_agree() {
        for off in ["false", "0", "no", "off"] {
            let a = parse_sw(&format!("path --certify {off}"), &["certify"], &[]);
            assert!(!a.switch("certify"), "--certify {off} must be OFF");
            assert!(a.positional.is_empty());
        }
        let a = parse_sw("path --certify true out.json", &["certify"], &[]);
        assert!(a.switch("certify"));
        assert_eq!(a.positional, vec!["out.json"]);
    }

    #[test]
    fn equals_form_and_defaults() {
        let a = parse("mine --scale=0.5");
        assert_eq!(a.get_f64("scale", 1.0).unwrap(), 0.5);
        assert_eq!(a.get_f64("missing", 2.5).unwrap(), 2.5);
        assert_eq!(a.get_or("dataset", "cpdb"), "cpdb");
    }

    #[test]
    fn valued_switches_parse_booleans() {
        for off in ["false", "0", "no", "off"] {
            let a = parse(&format!("path --certify={off}"));
            assert!(!a.switch("certify"), "--certify={off} must be OFF");
        }
        for on in ["true", "1", "yes", "on"] {
            let a = parse(&format!("path --certify={on}"));
            assert!(a.switch("certify"), "--certify={on} must be ON");
        }
        // space-separated value form reads the same way
        assert!(!parse("path --certify false").switch("certify"));
        assert!(!parse("path --certify 0").switch("certify"));
    }

    #[test]
    fn negative_value_then_flag_parses_explicitly() {
        // the satellite case: a negative numeric value followed by
        // another flag, with the whole grammar declared
        let a = parse_sw("path --viol-tol -1e-6 --certify", &["certify"], &["viol-tol"]);
        assert_eq!(a.get_f64("viol-tol", 0.0).unwrap(), -1e-6);
        assert!(a.switch("certify"));
        assert!(a.positional.is_empty());
    }

    #[test]
    fn declared_grammar_rejects_unknown_flags_by_name() {
        let err = |line: &str| {
            Args::parse_with_switches(
                line.split_whitespace().map(String::from),
                &["certify"],
                &["threads", "maxpat"],
            )
            .unwrap_err()
            .to_string()
        };
        // a typo'd value flag is rejected with the flag named …
        let e = err("path --treads 4");
        assert!(e.contains("--treads"), "{e}");
        // … in every token form …
        let e = err("path --treads=4");
        assert!(e.contains("--treads"), "{e}");
        // … a declared value flag must actually get a value …
        let e = err("path --threads");
        assert!(e.contains("--threads") && e.contains("value"), "{e}");
        let e = err("path --threads --certify");
        assert!(e.contains("--threads") && e.contains("value"), "{e}");
        // … and a flag in the command slot is named, not mistaken for
        // an unknown command
        let e = err("--threads 4 path");
        assert!(e.contains("--threads") && e.contains("command"), "{e}");
        // the declared spelling parses fine
        let a = parse_sw("path --threads 4", &["certify"], &["threads", "maxpat"]);
        assert_eq!(a.get_usize("threads", 0).unwrap(), 4);
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse("x --n abc");
        assert!(a.get_usize("n", 1).is_err());
    }

    #[test]
    fn require_names_the_missing_flag() {
        let a = parse("predict --model m.txt");
        assert_eq!(a.require("model").unwrap(), "m.txt");
        let e = a.require("dataset").unwrap_err().to_string();
        assert!(e.contains("--dataset"), "{e}");
    }

    #[test]
    fn trailing_switch_is_a_switch() {
        let a = parse("run --verbose");
        assert!(a.switch("verbose"));
        assert!(!a.switch("quiet"));
    }
}
