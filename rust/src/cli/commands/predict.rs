//! `spp predict` — load a persisted model and predict a registry
//! dataset.
//!
//! `--matcher compiled` (the default) routes scoring through the serve
//! layer's compiled matcher — one pass per record instead of one per
//! (record, pattern) pair, streamed in `--batch`-sized windows — and
//! reports its telemetry on the summary line; with `--shards K` the
//! records come off the on-disk shard container one shard at a time,
//! so the resident input is one shard regardless of dataset size.
//! `--matcher naive` keeps the historical per-pattern whole-dataset
//! scorer as a differential oracle.  Predictions are bit-identical
//! either way (pinned by `tests/integration_serve.rs`).  Both matchers
//! are substrate-generic: the compiled arm runs on the serve layer's
//! [`BatchScore`] rows, the naive arm on `SparsePatternModel::predict`,
//! each behind one visitor hop.

use crate::cli::Args;
use crate::data::registry::{
    self, RegistrySubstrate, ShardedSubstrateVisitor, SubstrateVisitor,
};
use crate::model::SparsePatternModel;
use crate::serve::compiled::{BatchScore, CompiledModel, ScoreBatch};
use crate::solver::Task;
use crate::storage::{ShardCodec, ShardedDb};

/// Streaming accumulator for `spp predict`: the running metric, op
/// counts and the first `top` display rows survive each batch — the
/// per-record predictions do not, which is the point of bounded-batch
/// scoring (peak matcher input is one `--batch` window).
pub struct PredictAccum {
    task: Task,
    top: usize,
    n: usize,
    correct: usize,
    sse: f64,
    ops: u64,
    batches: u64,
    rows: Vec<(f64, f64)>,
}

impl PredictAccum {
    fn new(task: Task, top: usize) -> Self {
        PredictAccum {
            task,
            top,
            n: 0,
            correct: 0,
            sse: 0.0,
            ops: 0,
            batches: 0,
            rows: Vec::new(),
        }
    }

    /// Fold one window of final predictions (output transform already
    /// applied) against its aligned target slice.
    fn absorb(&mut self, preds: &[f64], y: &[f64], ops: u64) {
        debug_assert_eq!(preds.len(), y.len());
        self.ops += ops;
        for (&p, &yi) in preds.iter().zip(y) {
            match self.task {
                Task::Classification => {
                    if (p >= 0.0) == (yi > 0.0) {
                        self.correct += 1;
                    }
                }
                Task::Regression => self.sse += (p - yi) * (p - yi),
            }
            if self.rows.len() < self.top {
                self.rows.push((p, yi));
            }
            self.n += 1;
        }
    }
}

/// Score `rows` through the compiled matcher in `batch`-sized windows,
/// folding each window into `acc`.  `score` is the substrate's batch
/// entrypoint ([`BatchScore::score_rows`]); batching is invisible in
/// the results because each record is scored independently.
fn predict_batches<R>(
    compiled: &CompiledModel,
    rows: &[R],
    y: &[f64],
    batch: usize,
    acc: &mut PredictAccum,
    score: impl Fn(&[R]) -> crate::Result<ScoreBatch>,
) -> crate::Result<()> {
    anyhow::ensure!(rows.len() == y.len(), "rows/targets length mismatch");
    let mut lo = 0;
    while lo < rows.len() {
        let hi = (lo + batch).min(rows.len());
        let out = score(&rows[lo..hi])?;
        let preds: Vec<f64> = out.scores.iter().map(|&s| compiled.output(s)).collect();
        acc.absorb(&preds, &y[lo..hi], out.ops);
        acc.batches += 1;
        lo = hi;
    }
    Ok(())
}

/// The historical per-pattern whole-dataset scorer (differential
/// oracle for the compiled matcher).
struct NaiveV<'a> {
    model: &'a SparsePatternModel,
    acc: &'a mut PredictAccum,
}

impl SubstrateVisitor for NaiveV<'_> {
    type Out = u64;
    /// Returns the match-call count the naive scorer performed.
    fn visit<S: RegistrySubstrate>(self, db: &S, y: &[f64]) -> Self::Out {
        let preds = self.model.predict(db);
        self.acc.absorb(&preds, y, 0);
        (self.model.terms.len() as u64) * (db.n_records() as u64)
    }
}

/// Bounded-batch compiled scoring over an in-memory dataset.
struct CompiledV<'a> {
    compiled: &'a CompiledModel,
    batch: usize,
    threads: usize,
    acc: &'a mut PredictAccum,
}

impl SubstrateVisitor for CompiledV<'_> {
    type Out = crate::Result<()>;
    fn visit<S: RegistrySubstrate>(self, db: &S, y: &[f64]) -> Self::Out {
        let CompiledV {
            compiled,
            batch,
            threads,
            acc,
        } = self;
        predict_batches(compiled, db.rows(), y, batch, acc, |w| {
            S::score_rows(compiled, w, threads)
        })
    }
}

/// Bounded-batch compiled scoring streamed shard by shard off the
/// on-disk container; `base` keeps the target slice aligned with each
/// shard's global records, so the resident input stays one shard.
struct ShardedCompiledV<'a> {
    compiled: &'a CompiledModel,
    batch: usize,
    threads: usize,
    acc: &'a mut PredictAccum,
}

impl ShardedSubstrateVisitor for ShardedCompiledV<'_> {
    type Out = crate::Result<()>;
    fn visit<S>(self, db: &ShardedDb<S>, y: &[f64]) -> Self::Out
    where
        S: RegistrySubstrate + ShardCodec,
    {
        let ShardedCompiledV {
            compiled,
            batch,
            threads,
            acc,
        } = self;
        let mut base = 0usize;
        for s in 0..db.n_shards() {
            let shard = db.shard(s)?;
            let rows = shard.rows();
            let ys = &y[base..base + rows.len()];
            predict_batches(compiled, rows, ys, batch, acc, |w| {
                S::score_rows(compiled, w, threads)
            })?;
            base += rows.len();
        }
        Ok(())
    }
}

pub fn run(args: &Args) -> crate::Result<()> {
    let dataset = args.get_or("dataset", "splice");
    let scale = args.get_f64("scale", 1.0)?;
    let top = args.get_usize("top", 10)?;
    let threads = args.get_usize("threads", 0)?;
    // bounded-batch streaming: at most `batch` records are handed to
    // the matcher at once; `--shards` streams them off the disk
    // container one shard at a time
    let batch = args.get_usize("batch", 8192)?;
    anyhow::ensure!(batch >= 1, "--batch must be >= 1");
    let shards = args.get_usize("shards", 0)?;
    let file = args.require("model")?;
    let model = SparsePatternModel::parse(&std::fs::read_to_string(file)?)?;
    let info = registry::require_info(dataset)?;
    // A mismatched model scores every record as sign(b) / b and prints
    // a confidently wrong metric — reject the combination up front.
    anyhow::ensure!(
        model.task == info.task,
        "model {file} is a {:?} model but dataset '{dataset}' is a {:?} task",
        model.task,
        info.task
    );
    let expected_tag = info.kind.tag();
    anyhow::ensure!(
        model.terms.is_empty() || model.terms.iter().any(|(p, _)| p.kind_tag() == expected_tag),
        "model {file} has no {expected_tag}-kind patterns — it was fitted on a different \
         substrate than dataset '{dataset}'"
    );
    let mut acc = PredictAccum::new(model.task, top);
    let telemetry = match args.get_or("matcher", "compiled") {
        "naive" => {
            anyhow::ensure!(
                shards == 0,
                "--matcher naive scores the whole dataset at once; --shards streams \
                 through the compiled matcher"
            );
            let data = registry::lookup(dataset, scale)?;
            let calls = data.visit(NaiveV {
                model: &model,
                acc: &mut acc,
            });
            format!("matcher=naive match_calls={calls}")
        }
        "compiled" => {
            let compiled = CompiledModel::compile_for(&model, expected_tag)?;
            if shards > 0 {
                let dir = args.get_or("shard-dir", "shards");
                let data =
                    registry::lookup_sharded(dataset, scale, shards, std::path::Path::new(dir))?;
                data.visit(ShardedCompiledV {
                    compiled: &compiled,
                    batch,
                    threads,
                    acc: &mut acc,
                })?;
            } else {
                let data = registry::lookup(dataset, scale)?;
                data.visit(CompiledV {
                    compiled: &compiled,
                    batch,
                    threads,
                    acc: &mut acc,
                })?;
            }
            format!(
                "matcher=compiled compiled_patterns={} index_nodes={} batches={} batch={} ops={}",
                compiled.stats.compiled_terms,
                compiled.stats.index_nodes,
                acc.batches,
                batch,
                acc.ops
            )
        }
        other => anyhow::bail!("--matcher must be compiled|naive, got '{other}'"),
    };
    match model.task {
        Task::Classification => println!(
            "predict {dataset}: n={} accuracy={:.1}% ({} patterns in model) {telemetry}",
            acc.n,
            100.0 * acc.correct as f64 / acc.n.max(1) as f64,
            model.terms.len()
        ),
        Task::Regression => println!(
            "predict {dataset}: n={} mse={:.4} ({} patterns in model) {telemetry}",
            acc.n,
            acc.sse / acc.n.max(1) as f64,
            model.terms.len()
        ),
    }
    for (i, (p, yi)) in acc.rows.iter().enumerate() {
        println!("  record {i:<5} pred={p:+.4} y={yi:+.4}");
    }
    Ok(())
}
