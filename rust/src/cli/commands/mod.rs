//! The `spp` subcommands, one module per command, all written against
//! the registry's substrate visitors.
//!
//! Every data-facing command resolves its preset with
//! [`registry::require_info`](crate::data::registry::require_info) /
//! [`registry::lookup`](crate::data::registry::lookup) and then hops
//! through the registry dataset's `visit` method (or its sharded twin)
//! exactly once — from there the code is generic
//! over [`PatternSubstrate`](crate::mining::PatternSubstrate), so
//! item-set, graph, sequence and tabular-rule presets flow through the
//! same bodies with zero per-substrate `match` ladders.  The only
//! enum matches live in the two registries (`data::registry`,
//! `serve::registry`); CI greps for strays.

pub mod cv;
pub mod datasets;
pub mod fit;
pub mod lambda_max;
pub mod mine;
pub mod path;
pub mod predict;
pub mod selftest;
pub mod serve;

use super::Args;
use crate::path::PathConfig;

/// Switches: flags that never consume a non-boolean token (see
/// [`super::Args`]).  `help` keeps the universal `spp <command> --help`
/// habit working under the strict grammar.
pub const SWITCHES: &[&str] = &["certify", "dynamic-screen", "help", "no-reuse", "stdio"];

/// Every value-taking flag any subcommand reads — the complete declared
/// grammar; anything else is rejected with the flag named.
pub const FLAGS: &[&str] = &[
    "artifacts",
    "batch",
    "columns",
    "dataset",
    "engine",
    "folds",
    "json",
    "k-add",
    "lambda-index",
    "lambdas",
    "matcher",
    "maxpat",
    "memory-budget",
    "method",
    "min-ratio",
    "minsup",
    "model",
    "range-chunk",
    "scale",
    "seed",
    "shard-dir",
    "shards",
    "socket",
    "threads",
    "top",
];

pub const HELP: &str = "\
spp — Safe Pattern Pruning (KDD'16 reproduction)

commands:
  path        compute a regularization path (SPP and/or boosting)
  cv          k-fold cross-validation over the path (model selection)
  fit         fit a sparse pattern model (SPP path) and save it
  predict     load a saved model and predict a dataset
  serve       persistent prediction service (JSON lines over stdio/socket)
  lambda-max  compute the paper's §3.4.1 lambda_max by bounded search
  mine        enumerate frequent patterns (substrate smoke test)
  selftest    verify the PJRT/XLA engines against the Rust engines
  datasets    list the registered synthetic datasets (all substrates)
";

/// Route a parsed command line to its subcommand.
pub fn dispatch(args: &Args) -> crate::Result<()> {
    // `spp <command> --help` prints help instead of running the command
    if args.switch("help") {
        print!("{HELP}");
        return Ok(());
    }
    match args.command.as_str() {
        "path" => path::run(args),
        "cv" => cv::run(args),
        "fit" => fit::run(args),
        "predict" => predict::run(args),
        "serve" => serve::run(args),
        "lambda-max" => lambda_max::run(args),
        "mine" => mine::run(args),
        "selftest" => selftest::run(args),
        "datasets" => datasets::run(),
        "" | "help" | "--help" => {
            print!("{HELP}");
            Ok(())
        }
        other => anyhow::bail!("unknown command '{other}' (try `spp help`)"),
    }
}

/// Assemble the [`PathConfig`] every path-shaped command shares.
pub fn path_config(args: &Args) -> crate::Result<PathConfig> {
    let mut cd = crate::solver::CdConfig::default();
    // `--dynamic-screen=false` / `--dynamic-screen false` turns the
    // in-solve gap-safe screening off; absent or bare means on.
    if args.flag("dynamic-screen").is_some() {
        cd.dynamic_screen = args.switch("dynamic-screen");
    }
    Ok(PathConfig {
        n_lambdas: args.get_usize("lambdas", 100)?,
        lambda_min_ratio: args.get_f64("min-ratio", 0.01)?,
        maxpat: args.get_usize("maxpat", 4)?,
        minsup: args.get_usize("minsup", 1)?,
        cd,
        certify: args.switch("certify"),
        // `--no-reuse` falls back to the from-scratch traversal per λ
        // (ablation of the incremental screening forest)
        reuse_forest: !args.switch("no-reuse"),
        // `--threads N` drives the deterministic parallel engine; 0 =
        // auto (SPP_THREADS env, else available parallelism), 1 = the
        // sequential engine — all bit-identical
        threads: args.get_usize("threads", 0)?,
        // `--range-chunk C` drives range-based SPP: one screening mine
        // per chunk of C λs; 0 = auto (SPP_RANGE_CHUNK env, else 1 =
        // per-λ screening) — all bit-identical
        range_chunk: args.get_usize("range-chunk", 0)?,
        // `--columns sparse|hybrid` picks the support-column layout;
        // absent = auto (SPP_COLUMNS env, else hybrid) — bit-identical
        columns: match args.flag("columns") {
            None => None,
            Some("sparse") => Some(crate::columns::ColumnLayout::Sparse),
            Some("hybrid") => Some(crate::columns::ColumnLayout::Hybrid),
            Some(other) => anyhow::bail!("--columns must be sparse|hybrid, got '{other}'"),
        },
        // `--memory-budget BYTES` caps the resident support-column pool
        // (LRU spill to a temp file); 0 = auto (SPP_MEMORY_BUDGET env,
        // else unlimited) — bit-identical at any budget
        memory_budget: args.get_usize("memory-budget", 0)?,
        k_add: args.get_usize("k-add", 1)?,
        ..PathConfig::default()
    })
}
