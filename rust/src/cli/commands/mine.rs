//! `spp mine` — enumerate frequent patterns (substrate smoke test).

use crate::cli::Args;
use crate::data::registry::{self, RegistrySubstrate, SubstrateVisitor};
use crate::mining::{PatternNode, TreeVisitor, Walk};

struct MineV {
    maxpat: usize,
    minsup: usize,
}

impl SubstrateVisitor for MineV {
    type Out = Vec<(usize, String)>;
    fn visit<S: RegistrySubstrate>(self, db: &S, _y: &[f64]) -> Self::Out {
        struct Collect {
            rows: Vec<(usize, String)>,
        }
        impl TreeVisitor for Collect {
            fn visit(&mut self, node: &PatternNode<'_>) -> Walk {
                self.rows
                    .push((node.support.len(), node.to_pattern().display()));
                Walk::Descend
            }
        }
        let mut c = Collect { rows: Vec::new() };
        db.traverse(self.maxpat, self.minsup, &mut c);
        c.rows
    }
}

pub fn run(args: &Args) -> crate::Result<()> {
    let dataset = args.get_or("dataset", "splice");
    let scale = args.get_f64("scale", 0.2)?;
    let maxpat = args.get_usize("maxpat", 3)?;
    let minsup = args.get_usize("minsup", 1)?;
    let top = args.get_usize("top", 20)?;
    let data = registry::lookup(dataset, scale)?;

    let mut rows = data.visit(MineV { maxpat, minsup });
    rows.sort_by(|a, b| b.0.cmp(&a.0));
    println!(
        "dataset={dataset} scale={scale} maxpat={maxpat} minsup={minsup}: {} patterns",
        rows.len()
    );
    for (sup, pat) in rows.into_iter().take(top) {
        println!("  support={sup:<6} {pat}");
    }
    Ok(())
}
