//! `spp path` — regularization paths (SPP and/or boosting), on any
//! engine shape: in-memory, out-of-core sharded, or XLA-solved.  All
//! three run the coordinator's visitor-based experiment runners.

use std::io::Write;

use crate::cli::Args;
use crate::coordinator::{
    report, run_experiment, run_experiment_sharded, run_experiment_xla, ExperimentSpec, Method,
};

pub fn run(args: &Args) -> crate::Result<()> {
    let dataset = args.get_or("dataset", "splice").to_string();
    let scale = args.get_f64("scale", 1.0)?;
    let cfg = super::path_config(args)?;
    let methods: Vec<Method> = match args.get_or("method", "both") {
        "spp" => vec![Method::Spp],
        "boosting" => vec![Method::Boosting],
        "both" => vec![Method::Spp, Method::Boosting],
        other => anyhow::bail!("--method must be spp|boosting|both, got '{other}'"),
    };
    let engine = args.get_or("engine", "rust").to_string();
    // `--shards K` routes through the on-disk shard container: the
    // database is serialized shard by shard and screening streams it
    // back, bit-identical to the in-memory run at any thread count.
    let shards = args.get_usize("shards", 0)?;
    let shard_dir = args.get_or("shard-dir", "shards").to_string();
    anyhow::ensure!(
        shards == 0 || engine == "rust",
        "--shards streams through the rust engine; drop --engine {engine}"
    );

    let mut results = Vec::new();
    for method in methods {
        let spec = ExperimentSpec {
            dataset: dataset.clone(),
            scale,
            maxpat: cfg.maxpat,
            method,
            cfg,
        };
        let r = if shards > 0 {
            run_experiment_sharded(&spec, shards, std::path::Path::new(&shard_dir))?
        } else if engine == "xla" && method == Method::Spp {
            run_experiment_xla(&spec)?
        } else {
            run_experiment(&spec)?
        };
        println!("{}", report::time_row(&r));
        results.push(r);
    }
    if results.len() == 2 {
        println!("{}", report::speedup_row(&results[0], &results[1]));
    }
    if let Some(path) = args.flag("json") {
        let mut f = std::fs::File::create(path)?;
        for r in &results {
            writeln!(f, "{}", report::result_json(r))?;
        }
        println!("wrote {path}");
    }
    Ok(())
}
