//! `spp fit` — fit via the `SppEstimator` facade and persist the
//! chosen model.

use crate::cli::Args;
use crate::data::registry;
use crate::SppEstimator;

pub fn run(args: &Args) -> crate::Result<()> {
    let dataset = args.get_or("dataset", "splice");
    let scale = args.get_f64("scale", 1.0)?;
    let out = args.require("model")?;
    let info = registry::require_info(dataset)?;
    let data = registry::lookup(dataset, scale)?;
    let cfg = super::path_config(args)?;
    let est = SppEstimator::new(info.task)
        .maxpat(cfg.maxpat)
        .minsup(cfg.minsup)
        .lambda_grid(cfg.n_lambdas, cfg.lambda_min_ratio)
        .certify(cfg.certify)
        .reuse_forest(cfg.reuse_forest)
        .threads(cfg.threads)
        .range_chunk(cfg.range_chunk)
        .cd(cfg.cd);
    let est = match cfg.columns {
        Some(layout) => est.columns(layout),
        None => est,
    };
    let fit = est.fit_dataset(&data)?;
    let idx = args.get_usize("lambda-index", fit.path.points.len() - 1)?;
    anyhow::ensure!(
        idx < fit.path.points.len(),
        "--lambda-index {idx} out of range (path has {} points)",
        fit.path.points.len()
    );
    let model = fit.model_at(idx);
    std::fs::write(out, model.serialize()?)?;
    println!(
        "fit {dataset}: n={} task={:?} λ_max={:.6} path={} λs, {} tree nodes",
        data.n_records(),
        info.task,
        fit.path.lambda_max,
        fit.path.points.len(),
        fit.path.total_nodes()
    );
    println!(
        "model @ λ={:.6} (index {idx}): {} patterns, b={:+.4} -> wrote {out}",
        model.lambda,
        model.terms.len(),
        model.b
    );
    Ok(())
}
