//! `spp cv` — k-fold cross-validation over the SPP path: the paper's
//! §3.4.1 model-selection workflow, served by the chunked (range-based
//! SPP) engine — one database search per grid chunk, per fold.

use crate::cli::Args;
use crate::data::registry::{self, RegistrySubstrate, SubstrateVisitor};
use crate::path::cv::{cross_validate, CvResult};
use crate::path::PathConfig;
use crate::solver::Task;

struct CvV<'a> {
    task: Task,
    cfg: &'a PathConfig,
    folds: usize,
    seed: u64,
}

impl SubstrateVisitor for CvV<'_> {
    type Out = crate::Result<CvResult>;
    fn visit<S: RegistrySubstrate>(self, db: &S, y: &[f64]) -> Self::Out {
        cross_validate(db, y, self.task, self.cfg, self.folds, self.seed)
    }
}

pub fn run(args: &Args) -> crate::Result<()> {
    let dataset = args.get_or("dataset", "splice").to_string();
    let scale = args.get_f64("scale", 1.0)?;
    let folds = args.get_usize("folds", 5)?;
    let seed = args.get_usize("seed", 13)? as u64;
    let cfg = super::path_config(args)?;
    let info = registry::require_info(&dataset)?;
    let data = registry::lookup(&dataset, scale)?;
    anyhow::ensure!(
        folds >= 2 && folds <= data.n_records(),
        "--folds must be between 2 and the record count; got {folds} folds for {} records",
        data.n_records()
    );
    let t0 = std::time::Instant::now();
    let cv = data.visit(CvV {
        task: info.task,
        cfg: &cfg,
        folds,
        seed,
    })?;
    let secs = t0.elapsed().as_secs_f64();
    let metric = match info.task {
        Task::Regression => "mse",
        Task::Classification => "error",
    };
    println!(
        "cv {dataset}: n={} task={:?} folds={folds} lambdas={} chunk={} ({secs:.2}s)",
        data.n_records(),
        info.task,
        cfg.n_lambdas,
        crate::screening::range::resolve_range_chunk(cfg.range_chunk),
    );
    println!("{:<6} {:>12} {:>12} {:>12}", "idx", "lambda/lmax", metric, "mean_active");
    for (i, p) in cv.points.iter().enumerate() {
        println!(
            "{:<6} {:>12.6} {:>12.6} {:>12.1}{}",
            i,
            p.lambda_frac,
            p.mean_loss,
            p.mean_active,
            if i == cv.best { "   <- best" } else { "" }
        );
    }
    let best = cv.best_point();
    println!(
        "best: index {} (λ/λ_max = {:.6}), mean {metric} {:.6} over {folds} folds",
        cv.best,
        best.lambda_frac,
        best.mean_loss
    );
    Ok(())
}
