//! `spp serve` — persistent prediction service: line-delimited JSON
//! requests over stdin/stdout (`--stdio`) or a Unix domain socket
//! (`--socket PATH`), with hot-reloadable models and the compiled
//! batch matcher.  Stdio mode writes nothing but response lines to
//! stdout, so canned sessions pipe and diff cleanly (the CI
//! `serve-smoke` job does exactly that against a golden transcript).

use crate::cli::Args;

pub fn run(args: &Args) -> crate::Result<()> {
    let threads = args.get_usize("threads", 0)?;
    let stdio = args.switch("stdio");
    let socket = args.flag("socket");
    match (stdio, socket) {
        (true, Some(_)) => anyhow::bail!("--stdio and --socket are mutually exclusive"),
        (false, Some(path)) => crate::serve::run_unix_socket(path, threads),
        (true, None) => crate::serve::run_stdio(threads),
        (false, None) => {
            anyhow::bail!("serve needs a transport: --stdio or --socket /path/to.sock")
        }
    }
}
