//! `spp selftest` — verify the PJRT/XLA engines against the Rust
//! engines (SPPC scorer vs the fold, FISTA vs coordinate descent).

use crate::cli::Args;
use crate::runtime::{default_artifact_dir, PjrtRuntime, XlaFistaSolver, XlaSppcScorer};
use crate::screening::fold_weights;
use crate::solver::{CdSolver, Task};
use crate::testutil::SplitMix64;

pub fn run(args: &Args) -> crate::Result<()> {
    let dir = args
        .flag("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(default_artifact_dir);
    let rt = PjrtRuntime::cpu(&dir)?;
    println!("platform: {}", rt.platform());

    // 1) SPPC scorer vs the Rust fold
    let mut rng = SplitMix64::new(99);
    let n = 700;
    let y: Vec<f64> = (0..n).map(|_| if rng.coin(0.5) { 1.0 } else { -1.0 }).collect();
    let theta: Vec<f64> = (0..n).map(|_| rng.gauss() * 0.1).collect();
    let (wpos, wneg) = fold_weights(Task::Classification, &y, &theta);
    let supports: Vec<Vec<u32>> = (0..300)
        .map(|_| {
            let m = rng.range(1, 60);
            rng.sample_distinct(n, m).into_iter().map(|i| i as u32).collect()
        })
        .collect();
    let scorer = XlaSppcScorer::new(&rt, n)?;
    let scores = scorer.score(&supports, &wpos, &wneg, 0.3)?;
    let mut max_err = 0.0f64;
    for (sup, sc) in supports.iter().zip(&scores) {
        let pos: f64 = sup.iter().map(|&i| wpos[i as usize]).sum();
        let neg: f64 = sup.iter().map(|&i| wneg[i as usize]).sum();
        let v = sup.len() as f64;
        let want = pos.max(-neg) + 0.3 * v.sqrt();
        max_err = max_err.max((sc.sppc - want).abs());
    }
    anyhow::ensure!(max_err < 1e-3, "sppc mismatch: {max_err}");
    println!(
        "sppc scorer OK (max err {max_err:.2e} over {} patterns)",
        scores.len()
    );

    // 2) FISTA solver vs CD
    let supports2: Vec<Vec<u32>> = supports.iter().take(40).cloned().collect();
    let yv: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
    let xs = XlaFistaSolver::new(&rt).solve(Task::Regression, &supports2, &yv, 2.0)?;
    let cd = CdSolver::default().solve(Task::Regression, &supports2, &yv, 2.0, None);
    let rel = (xs.primal - cd.primal).abs() / cd.primal.abs().max(1.0);
    anyhow::ensure!(rel < 1e-3, "fista vs cd primal mismatch: {rel}");
    println!(
        "fista solver OK (primal {:.6} vs cd {:.6}, {} execs)",
        xs.primal, cd.primal, xs.execs
    );
    println!("selftest OK");
    Ok(())
}
