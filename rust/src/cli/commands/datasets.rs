//! `spp datasets` — list the registered synthetic presets.

use crate::data::registry;

pub fn run() -> crate::Result<()> {
    let (name, kind, task) = ("name", "kind", "task");
    println!("{name:<14} {kind:<8} {task:<15} paper_n");
    for d in registry::ALL {
        println!(
            "{:<14} {:<8} {:<15} {}",
            d.name,
            format!("{:?}", d.kind).to_lowercase(),
            format!("{:?}", d.task).to_lowercase(),
            d.paper_n
        );
    }
    Ok(())
}
