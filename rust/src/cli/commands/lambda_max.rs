//! `spp lambda-max` — the paper's §3.4.1 λ_max by bounded search, on
//! any substrate.

use crate::cli::Args;
use crate::data::registry::{self, RegistrySubstrate, SubstrateVisitor};
use crate::screening::lambda_max::{lambda_max, LambdaMax};
use crate::solver::Task;

struct LmV {
    task: Task,
    maxpat: usize,
}

impl SubstrateVisitor for LmV {
    type Out = LambdaMax;
    fn visit<S: RegistrySubstrate>(self, db: &S, y: &[f64]) -> Self::Out {
        lambda_max(db, y, self.task, self.maxpat, 1)
    }
}

pub fn run(args: &Args) -> crate::Result<()> {
    let dataset = args.get_or("dataset", "splice");
    let scale = args.get_f64("scale", 1.0)?;
    let maxpat = args.get_usize("maxpat", 4)?;
    let info = registry::require_info(dataset)?;
    let data = registry::lookup(dataset, scale)?;
    let lm = data.visit(LmV {
        task: info.task,
        maxpat,
    });
    println!(
        "dataset={dataset} n={} task={:?} maxpat={maxpat} lambda_max={:.6} b0={:.6} nodes={} pruned={}",
        data.n_records(),
        info.task,
        lm.lambda_max,
        lm.b0,
        lm.stats.nodes,
        lm.stats.pruned
    );
    Ok(())
}
