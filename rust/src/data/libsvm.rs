//! LIBSVM sparse text format parser.
//!
//! `<label> <idx>:<val> <idx>:<val> ...` per line, 1-based indices.
//! Values are binarized at `> 0.5` into item occurrences (the paper's
//! item-set experiments use binary indicator features; splice/a9a/dna
//! are already 0/1 coded).  If the real LIBSVM files are available they
//! drop straight into the pipeline through this parser.

use super::{LabeledTransactions, Transactions};

/// Parse LIBSVM text into a labeled transaction database.
///
/// `n_items` is inferred as the max seen index unless `min_items`
/// forces a wider universe (useful to match a preset's `d`).
pub fn parse_libsvm(text: &str, min_items: usize) -> crate::Result<LabeledTransactions> {
    let mut items = Vec::new();
    let mut y = Vec::new();
    let mut max_idx = 0usize;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut toks = line.split_whitespace();
        let label: f64 = toks
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {}: empty", lineno + 1))?
            .parse()
            .map_err(|e| anyhow::anyhow!("line {}: bad label: {e}", lineno + 1))?;
        let mut row = Vec::new();
        for tok in toks {
            let (idx, val) = tok
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("line {}: bad pair '{tok}'", lineno + 1))?;
            let idx: usize = idx
                .parse()
                .map_err(|e| anyhow::anyhow!("line {}: bad index: {e}", lineno + 1))?;
            if idx == 0 {
                anyhow::bail!("line {}: LIBSVM indices are 1-based", lineno + 1);
            }
            let val: f64 = val
                .parse()
                .map_err(|e| anyhow::anyhow!("line {}: bad value: {e}", lineno + 1))?;
            if val > 0.5 {
                row.push((idx - 1) as u32);
                max_idx = max_idx.max(idx);
            }
        }
        row.sort_unstable();
        row.dedup();
        items.push(row);
        y.push(label);
    }
    Ok(LabeledTransactions {
        db: Transactions {
            n_items: max_idx.max(min_items),
            items,
        },
        y,
    })
}

/// Serialize a labeled transaction database to LIBSVM text.
pub fn to_libsvm(data: &LabeledTransactions) -> String {
    let mut out = String::new();
    for (row, &yi) in data.db.items.iter().zip(&data.y) {
        out.push_str(&format!("{yi}"));
        for &j in row {
            out.push_str(&format!(" {}:1", j + 1));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_lines() {
        let d = parse_libsvm("+1 1:1 3:1\n-1 2:0.9\n", 0).unwrap();
        assert_eq!(d.y, vec![1.0, -1.0]);
        assert_eq!(d.db.items[0], vec![0, 2]);
        assert_eq!(d.db.items[1], vec![1]);
        assert_eq!(d.db.n_items, 3);
    }

    #[test]
    fn binarizes_small_values_away() {
        let d = parse_libsvm("1 1:0.2 2:0.8\n", 0).unwrap();
        assert_eq!(d.db.items[0], vec![1]);
    }

    #[test]
    fn respects_min_items() {
        let d = parse_libsvm("1 1:1\n", 100).unwrap();
        assert_eq!(d.db.n_items, 100);
    }

    #[test]
    fn rejects_zero_index() {
        assert!(parse_libsvm("1 0:1\n", 0).is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_libsvm("abc 1:1\n", 0).is_err());
        assert!(parse_libsvm("1 11\n", 0).is_err());
    }

    #[test]
    fn round_trip() {
        let src = "1 1:1 5:1\n-2.5 2:1\n";
        let d = parse_libsvm(src, 0).unwrap();
        let text = to_libsvm(&d);
        let d2 = parse_libsvm(&text, 0).unwrap();
        assert_eq!(d.db.items, d2.db.items);
        assert_eq!(d.y, d2.y);
    }

    #[test]
    fn skips_comments_and_blank_lines() {
        let d = parse_libsvm("# header\n\n1 1:1\n", 0).unwrap();
        assert_eq!(d.y.len(), 1);
    }
}
