//! LIBSVM sparse text format parsers.
//!
//! `<label> <idx>:<val> <idx>:<val> ...` per line, 1-based indices.
//! Two parse paths, one per consuming substrate:
//!
//! * [`parse_libsvm`] — binary indicator features into a transaction
//!   database (the paper's item-set experiments; splice/a9a/dna are
//!   0/1 coded).  Values must be exactly `0` or `1`: a real-valued
//!   file is **refused** with an error pointing at the dense path —
//!   silently binarizing it would change the learning problem.
//! * [`parse_libsvm_dense`] — real-valued features into a dense
//!   numeric [`TabularData`] for the RuleFit rule substrate; absent
//!   indices are 0.0 (the LIBSVM sparse-default convention).
//!
//! If the real LIBSVM files are available they drop straight into the
//! pipeline through these parsers.

use super::tabular::{LabeledTabular, TabularData};
use super::{LabeledTransactions, Transactions};

/// Parse one data line into `(label, sparse (idx, val) pairs)`;
/// `None` for blank/comment lines.  Shared by both parse paths so
/// they agree on the line grammar.
fn parse_line(lineno: usize, line: &str) -> crate::Result<Option<(f64, Vec<(usize, f64)>)>> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(None);
    }
    let mut toks = line.split_whitespace();
    let label: f64 = toks
        .next()
        .ok_or_else(|| anyhow::anyhow!("line {}: empty", lineno + 1))?
        .parse()
        .map_err(|e| anyhow::anyhow!("line {}: bad label: {e}", lineno + 1))?;
    let mut pairs = Vec::new();
    for tok in toks {
        let (idx, val) = tok
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("line {}: bad pair '{tok}'", lineno + 1))?;
        let idx: usize = idx
            .parse()
            .map_err(|e| anyhow::anyhow!("line {}: bad index: {e}", lineno + 1))?;
        if idx == 0 {
            anyhow::bail!("line {}: LIBSVM indices are 1-based", lineno + 1);
        }
        let val: f64 = val
            .parse()
            .map_err(|e| anyhow::anyhow!("line {}: bad value: {e}", lineno + 1))?;
        if !val.is_finite() {
            anyhow::bail!("line {}: value {val} is not finite", lineno + 1);
        }
        pairs.push((idx, val));
    }
    Ok(Some((label, pairs)))
}

/// Parse 0/1-coded LIBSVM text into a labeled transaction database.
///
/// `n_items` is inferred as the max seen index unless `min_items`
/// forces a wider universe (useful to match a preset's `d`).
///
/// Every value must be exactly `0` (item absent) or `1` (item
/// present).  Any other value is an error: real-valued features
/// belong to the tabular substrate — load them with
/// [`parse_libsvm_dense`] instead.
pub fn parse_libsvm(text: &str, min_items: usize) -> crate::Result<LabeledTransactions> {
    let mut items = Vec::new();
    let mut y = Vec::new();
    let mut max_idx = 0usize;
    for (lineno, line) in text.lines().enumerate() {
        let Some((label, pairs)) = parse_line(lineno, line)? else {
            continue;
        };
        let mut row = Vec::new();
        for (idx, val) in pairs {
            if val != 0.0 && val != 1.0 {
                anyhow::bail!(
                    "line {}: value {idx}:{val} is not binary; this file holds \
                     real-valued features, which the transaction (item-set) substrate \
                     cannot represent — load it as dense numeric tabular data \
                     (`parse_libsvm_dense`, dataset kind `tabular`) instead",
                    lineno + 1
                );
            }
            if val == 1.0 {
                row.push((idx - 1) as u32);
                max_idx = max_idx.max(idx);
            }
        }
        row.sort_unstable();
        row.dedup();
        items.push(row);
        y.push(label);
    }
    Ok(LabeledTransactions {
        db: Transactions {
            n_items: max_idx.max(min_items),
            items,
        },
        y,
    })
}

/// Parse real-valued LIBSVM text into a dense labeled tabular
/// database (the RuleFit rule substrate's input).
///
/// `n_features` is inferred as the max seen index unless
/// `min_features` forces a wider table; absent indices are 0.0.
pub fn parse_libsvm_dense(text: &str, min_features: usize) -> crate::Result<LabeledTabular> {
    let mut sparse: Vec<Vec<(usize, f64)>> = Vec::new();
    let mut y = Vec::new();
    let mut max_idx = 0usize;
    for (lineno, line) in text.lines().enumerate() {
        let Some((label, pairs)) = parse_line(lineno, line)? else {
            continue;
        };
        for &(idx, _) in &pairs {
            max_idx = max_idx.max(idx);
        }
        sparse.push(pairs);
        y.push(label);
    }
    let n_features = max_idx.max(min_features);
    let rows = sparse
        .into_iter()
        .map(|pairs| {
            let mut row = vec![0.0; n_features];
            for (idx, val) in pairs {
                row[idx - 1] = val;
            }
            row
        })
        .collect();
    let db = TabularData::new(n_features, rows);
    db.validate()?;
    Ok(LabeledTabular { db, y })
}

/// Serialize a labeled transaction database to LIBSVM text.
pub fn to_libsvm(data: &LabeledTransactions) -> String {
    let mut out = String::new();
    for (row, &yi) in data.db.items.iter().zip(&data.y) {
        out.push_str(&format!("{yi}"));
        for &j in row {
            out.push_str(&format!(" {}:1", j + 1));
        }
        out.push('\n');
    }
    out
}

/// Serialize a labeled tabular database to LIBSVM text (zero values
/// are omitted, per the sparse-default convention; values print
/// through `f64`'s shortest-round-trip `Display`).
pub fn to_libsvm_dense(data: &LabeledTabular) -> String {
    let mut out = String::new();
    for (row, &yi) in data.db.rows.iter().zip(&data.y) {
        out.push_str(&format!("{yi}"));
        for (j, &v) in row.iter().enumerate() {
            if v != 0.0 {
                out.push_str(&format!(" {}:{v}", j + 1));
            }
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_lines() {
        let d = parse_libsvm("+1 1:1 3:1\n-1 2:1\n", 0).unwrap();
        assert_eq!(d.y, vec![1.0, -1.0]);
        assert_eq!(d.db.items[0], vec![0, 2]);
        assert_eq!(d.db.items[1], vec![1]);
        assert_eq!(d.db.n_items, 3);
    }

    #[test]
    fn explicit_zeros_are_absent_items() {
        let d = parse_libsvm("1 1:0 2:1\n", 0).unwrap();
        assert_eq!(d.db.items[0], vec![1]);
    }

    #[test]
    fn rejects_real_values_as_transactions() {
        // regression: these used to be silently binarized at > 0.5
        for src in ["1 1:0.2 2:0.8\n", "-1 2:0.9\n", "1 3:2\n"] {
            let err = parse_libsvm(src, 0).unwrap_err().to_string();
            assert!(err.contains("not binary"), "{err}");
            assert!(err.contains("tabular"), "{err}");
            assert!(err.contains("parse_libsvm_dense"), "{err}");
        }
    }

    #[test]
    fn dense_parses_real_values_with_sparse_defaults() {
        let d = parse_libsvm_dense("+1 1:0.25 3:-1.5\n-1 2:0.9\n", 0).unwrap();
        assert_eq!(d.y, vec![1.0, -1.0]);
        assert_eq!(d.db.n_features, 3);
        assert_eq!(d.db.rows[0], vec![0.25, 0.0, -1.5]);
        assert_eq!(d.db.rows[1], vec![0.0, 0.9, 0.0]);
        d.db.validate().unwrap();
    }

    #[test]
    fn dense_respects_min_features_and_rejects_bad_input() {
        let d = parse_libsvm_dense("1 1:0.5\n", 7).unwrap();
        assert_eq!(d.db.n_features, 7);
        assert_eq!(d.db.rows[0].len(), 7);
        assert!(parse_libsvm_dense("1 0:1\n", 0).is_err());
        assert!(parse_libsvm_dense("abc 1:1\n", 0).is_err());
        assert!(parse_libsvm_dense("1 1:inf\n", 0).is_err());
    }

    #[test]
    fn dense_round_trip_is_bit_exact() {
        let src = "1 1:0.1 3:0.3333333333333333\n-2.5 2:-7\n";
        let d = parse_libsvm_dense(src, 0).unwrap();
        let d2 = parse_libsvm_dense(&to_libsvm_dense(&d), 0).unwrap();
        assert_eq!(d.db.rows, d2.db.rows);
        assert_eq!(d.y, d2.y);
    }

    #[test]
    fn respects_min_items() {
        let d = parse_libsvm("1 1:1\n", 100).unwrap();
        assert_eq!(d.db.n_items, 100);
    }

    #[test]
    fn rejects_zero_index() {
        assert!(parse_libsvm("1 0:1\n", 0).is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_libsvm("abc 1:1\n", 0).is_err());
        assert!(parse_libsvm("1 11\n", 0).is_err());
    }

    #[test]
    fn round_trip() {
        let src = "1 1:1 5:1\n-2.5 2:1\n";
        let d = parse_libsvm(src, 0).unwrap();
        let text = to_libsvm(&d);
        let d2 = parse_libsvm(&text, 0).unwrap();
        assert_eq!(d.db.items, d2.db.items);
        assert_eq!(d.y, d2.y);
    }

    #[test]
    fn skips_comments_and_blank_lines() {
        let d = parse_libsvm("# header\n\n1 1:1\n", 0).unwrap();
        assert_eq!(d.y.len(), 1);
    }
}
