//! Dense numeric tabular databases: the fourth pattern substrate.
//!
//! Records are fixed-width rows of real-valued features (ordinary
//! tabular data: sensor readings, measurements, the classic libsvm
//! regression/classification files); a pattern is a RuleFit-style
//! conjunction of threshold predicates `x_j ≤ t` / `x_j > t` and the
//! binary feature is `x_it = I(rule t holds on row i)`.  The
//! enumeration tree is the rule-refinement lattice of
//! [`crate::mining::rulefit`], which is anti-monotone — so the whole
//! SPP machinery applies unchanged through the [`PatternSubstrate`]
//! impl below, and the per-node SPPC test plays the role of Kato et
//! al.'s meta safe screening bound (one evaluation certifies every
//! refinement of a rule).
//!
//! Real-valued libsvm files load through
//! [`crate::data::libsvm::parse_libsvm_dense`]; like the other
//! substrates, [`generate`] provides a seeded synthetic stand-in with
//! planted predictive rules (registry entry `synth-tab`).

use crate::mining::rulefit::{RulefitMiner, RuleOp, RulePredicate};
use crate::mining::{Pattern, PatternSubstrate, TreeVisitor};
use crate::testutil::SplitMix64;

/// Default per-feature cap on candidate thresholds (see
/// [`TabularData::max_thresholds`]).
pub const DEFAULT_MAX_THRESHOLDS: usize = 16;

/// A dense numeric database: each record is a row of `n_features`
/// finite values.
#[derive(Clone, Debug)]
pub struct TabularData {
    pub n_features: usize,
    pub rows: Vec<Vec<f64>>,
    /// Per-feature cap on candidate split thresholds
    /// ([`crate::mining::rulefit::predicate_universe`] quantile-thins
    /// down to this many cuts).  Part of the database — CV folds and
    /// shards inherit it through `select`/the shard codec, so every
    /// engine enumerates the same tree.
    pub max_thresholds: usize,
}

impl Default for TabularData {
    fn default() -> Self {
        TabularData {
            n_features: 0,
            rows: Vec::new(),
            max_thresholds: DEFAULT_MAX_THRESHOLDS,
        }
    }
}

impl TabularData {
    /// A database with the default threshold cap.
    pub fn new(n_features: usize, rows: Vec<Vec<f64>>) -> Self {
        TabularData {
            n_features,
            rows,
            max_thresholds: DEFAULT_MAX_THRESHOLDS,
        }
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Validate invariants: every row `n_features` wide, every value
    /// finite (NaN/±∞ would poison threshold selection and matching).
    pub fn validate(&self) -> crate::Result<()> {
        for (i, r) in self.rows.iter().enumerate() {
            if r.len() != self.n_features {
                anyhow::bail!(
                    "tabular row {i} has {} values, expected {}",
                    r.len(),
                    self.n_features
                );
            }
            if let Some(&bad) = r.iter().find(|v| !v.is_finite()) {
                anyhow::bail!("tabular row {i} value {bad} is not finite");
            }
        }
        Ok(())
    }
}

/// A supervised tabular dataset.
#[derive(Clone, Debug)]
pub struct LabeledTabular {
    pub db: TabularData,
    /// Regression targets, or ±1 class labels.
    pub y: Vec<f64>,
}

/// Does the conjunction `rule` hold on `row`?  Every predicate must
/// pass; a NaN value or missing column fails its predicate (see
/// [`RulePredicate::eval`]).
pub fn rule_matches(rule: &[RulePredicate], row: &[f64]) -> bool {
    rule.iter().all(|p| p.eval(row))
}

impl PatternSubstrate for TabularData {
    type Record = [f64];

    fn n_records(&self) -> usize {
        self.rows.len()
    }

    fn traverse(&self, maxpat: usize, minsup: usize, visitor: &mut dyn TreeVisitor) {
        let mut m = RulefitMiner::new(self, maxpat);
        m.minsup = minsup;
        m.traverse(visitor);
    }

    fn traverse_parallel<F: crate::mining::SubtreeVisitors>(
        &self,
        maxpat: usize,
        minsup: usize,
        threads: usize,
        factory: &F,
    ) -> Vec<F::V> {
        let mut m = RulefitMiner::new(self, maxpat);
        m.minsup = minsup;
        m.traverse_par(threads, factory)
    }

    fn matches(pattern: &Pattern, record: &[f64]) -> bool {
        match pattern {
            Pattern::Rule(r) => rule_matches(r, record),
            _ => false,
        }
    }

    fn record(&self, i: usize) -> &[f64] {
        &self.rows[i]
    }

    fn select(&self, indices: &[usize]) -> Self {
        TabularData {
            n_features: self.n_features,
            rows: indices.iter().map(|&i| self.rows[i].clone()).collect(),
            max_thresholds: self.max_thresholds,
        }
    }

    fn parse_pattern(body: &str) -> crate::Result<Pattern> {
        let preds = body
            .split('&')
            .map(RulePredicate::parse)
            .collect::<crate::Result<Vec<_>>>()?;
        Ok(Pattern::Rule(preds))
    }

    fn format_pattern(pattern: &Pattern) -> String {
        match pattern {
            Pattern::Rule(r) => r.iter().map(|p| p.display()).collect::<Vec<_>>().join("&"),
            other => unreachable!("rule codec asked to format {other:?}"),
        }
    }

    const KIND_TAG: &'static str = "R";
}

impl crate::storage::ShardCodec for TabularData {
    // The rule miner filters row supports directly, so a sharded
    // tabular database materializes its union for traversal (`STREAMS`
    // stays false) — the container still provides the on-disk format,
    // the O(1) id remap and CV-fold streaming.

    /// Text shard blob: `features <n> thresholds <m>` header, then one
    /// space-separated value row per record.  Values print through
    /// `f64`'s shortest-round-trip `Display`, so decoding recovers the
    /// exact bits.
    fn encode_shard(&self) -> Vec<u8> {
        let mut out = format!("features {} thresholds {}\n", self.n_features, self.max_thresholds);
        for row in &self.rows {
            let mut first = true;
            for &v in row {
                if !first {
                    out.push(' ');
                }
                out.push_str(&v.to_string());
                first = false;
            }
            out.push('\n');
        }
        out.into_bytes()
    }

    fn decode_shard(bytes: &[u8]) -> crate::Result<Self> {
        let text = std::str::from_utf8(bytes)
            .map_err(|e| anyhow::anyhow!("tabular shard is not UTF-8: {e}"))?;
        let mut lines = text.lines();
        let header = lines.next().unwrap_or_default();
        let fields: Vec<&str> = header.split_whitespace().collect();
        let parsed = match fields.as_slice() {
            ["features", n, "thresholds", m] => n
                .parse::<usize>()
                .ok()
                .zip(m.parse::<usize>().ok()),
            _ => None,
        };
        let Some((n_features, max_thresholds)) = parsed else {
            anyhow::bail!("tabular shard header '{header}' malformed");
        };
        let rows = lines
            .map(|line| {
                line.split_whitespace()
                    .map(|t| t.parse::<f64>())
                    .collect::<Result<Vec<f64>, _>>()
            })
            .collect::<Result<Vec<Vec<f64>>, _>>()?;
        let db = TabularData {
            n_features,
            rows,
            max_thresholds,
        };
        db.validate()?;
        Ok(db)
    }

    fn concat(parts: Vec<Self>) -> crate::Result<Self> {
        let n_features = parts.iter().map(|p| p.n_features).max().unwrap_or(0);
        let max_thresholds = parts
            .iter()
            .map(|p| p.max_thresholds)
            .max()
            .unwrap_or(DEFAULT_MAX_THRESHOLDS);
        let mut rows: Vec<Vec<f64>> = Vec::new();
        for p in parts {
            if !p.rows.is_empty() && p.n_features != n_features {
                anyhow::bail!(
                    "tabular shards disagree on width ({} vs {n_features})",
                    p.n_features
                );
            }
            rows.extend(p.rows);
        }
        Ok(TabularData {
            n_features,
            rows,
            max_thresholds,
        })
    }
}

/// One planted rule: rows satisfying every predicate of `rule` get
/// `weight` added to their score.
#[derive(Clone, Debug)]
pub struct PlantedTabRule {
    pub rule: Vec<RulePredicate>,
    pub weight: f64,
}

#[derive(Clone, Debug)]
pub struct TabSynthConfig {
    pub seed: u64,
    pub n: usize,
    /// Number of numeric feature columns (values uniform in `[0, 1]`).
    pub n_features: usize,
    /// Number of planted predictive rules.
    pub n_rules: usize,
    /// Rule lengths are drawn in `[1, max_rule_len]`.
    pub max_rule_len: usize,
    /// Gaussian noise on regression targets / label-flip margin.
    pub noise: f64,
    /// true => ±1 labels (classification); false => real targets.
    pub classify: bool,
}

impl TabSynthConfig {
    fn base(seed: u64, n: usize, n_features: usize, classify: bool) -> Self {
        Self {
            seed,
            n,
            n_features,
            n_rules: 5,
            max_rule_len: 2,
            noise: 0.5,
            classify,
        }
    }

    /// The `synth-tab` registry preset: n = 500 rows over 10 numeric
    /// features, classification.
    pub fn preset_synth_tab(seed: u64) -> Self {
        Self::base(seed, 500, 10, true)
    }

    /// Small config for tests.
    pub fn tiny(seed: u64, classify: bool) -> Self {
        let mut c = Self::base(seed, 60, 5, classify);
        c.n_rules = 3;
        c.noise = 0.25;
        c
    }

    /// Scale record count by `f` (benchmark `--scale` support).
    pub fn scaled(mut self, f: f64) -> Self {
        self.n = ((self.n as f64 * f).round() as usize).max(8);
        self
    }
}

/// Generated dataset plus the ground-truth rules (handy in tests).
#[derive(Clone, Debug)]
pub struct SynthTabular {
    pub db: TabularData,
    pub y: Vec<f64>,
    pub rules: Vec<PlantedTabRule>,
}

impl SynthTabular {
    pub fn labeled(&self) -> LabeledTabular {
        LabeledTabular {
            db: self.db.clone(),
            y: self.y.clone(),
        }
    }
}

/// Generate a dataset per `cfg`.  Fully deterministic in `cfg.seed`.
///
/// Features are independent uniforms on `[0, 1]`; planted rules are
/// conjunctions over distinct features with mid-range thresholds
/// (`[0.25, 0.75]`), so each fires on a non-trivial fraction of rows —
/// no implanting step is needed, threshold rules fire naturally.
pub fn generate(cfg: &TabSynthConfig) -> SynthTabular {
    assert!(cfg.n >= 4 && cfg.n_features >= 2 && cfg.n_rules >= 1 && cfg.max_rule_len >= 1);
    let mut rng = SplitMix64::new(cfg.seed);

    let mut rules = Vec::with_capacity(cfg.n_rules);
    for _ in 0..cfg.n_rules {
        let len = rng.range(1, cfg.max_rule_len.min(cfg.n_features));
        let feats = rng.sample_distinct(cfg.n_features, len);
        let rule: Vec<RulePredicate> = feats
            .iter()
            .map(|&j| {
                let op = if rng.coin(0.5) { RuleOp::Le } else { RuleOp::Gt };
                let thr = 0.25 + 0.5 * rng.next_f64();
                RulePredicate::new(j as u32, op, thr)
            })
            .collect();
        let mag = 1.0 + rng.next_f64() * 2.0;
        let weight = if rng.coin(0.5) { mag } else { -mag };
        rules.push(PlantedTabRule { rule, weight });
    }

    let mut rows = Vec::with_capacity(cfg.n);
    let mut y = Vec::with_capacity(cfg.n);
    for _ in 0..cfg.n {
        let row: Vec<f64> = (0..cfg.n_features).map(|_| rng.next_f64()).collect();
        let mut score = 0.0;
        for r in &rules {
            if rule_matches(&r.rule, &row) {
                score += r.weight;
            }
        }
        score += cfg.noise * rng.gauss();
        if cfg.classify {
            y.push(if score >= 0.0 { 1.0 } else { -1.0 });
        } else {
            y.push(score);
        }
        rows.push(row);
    }

    SynthTabular {
        db: TabularData::new(cfg.n_features, rows),
        y,
        rules,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::ShardCodec;

    #[test]
    fn rule_matcher_cases() {
        let le = RulePredicate::new(0, RuleOp::Le, 0.5);
        let gt = RulePredicate::new(1, RuleOp::Gt, 0.5);
        assert!(rule_matches(&[le, gt], &[0.5, 0.6]));
        assert!(!rule_matches(&[le, gt], &[0.5, 0.5]));
        assert!(!rule_matches(&[le, gt], &[0.6, 0.6]));
        assert!(rule_matches(&[], &[0.0])); // empty conjunction is true
        assert!(!rule_matches(&[le], &[f64::NAN]));
    }

    #[test]
    fn deterministic_in_seed_and_shapes_match() {
        let cfg = TabSynthConfig::tiny(9, true);
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.db.rows, b.db.rows);
        assert_eq!(a.y, b.y);
        assert_eq!(a.db.rows.len(), cfg.n);
        assert_eq!(a.db.n_features, cfg.n_features);
        a.db.validate().unwrap();
        let c = generate(&TabSynthConfig::tiny(10, true));
        assert_ne!(a.db.rows, c.db.rows);
    }

    #[test]
    fn classification_labels_are_pm1_both_classes() {
        let d = generate(&TabSynthConfig::tiny(2, true));
        assert!(d.y.iter().all(|&v| v == 1.0 || v == -1.0));
        assert!(d.y.iter().any(|&v| v == 1.0));
        assert!(d.y.iter().any(|&v| v == -1.0));
    }

    #[test]
    fn planted_rules_have_nontrivial_support() {
        let d = generate(&TabSynthConfig::tiny(4, false));
        for r in &d.rules {
            assert!(!r.rule.is_empty() && r.rule.len() <= 2);
            assert!(r.rule.iter().all(|p| (p.feature as usize) < d.db.n_features));
            assert!(
                d.db.rows.iter().any(|row| rule_matches(&r.rule, row)),
                "rule {:?} supported nowhere",
                r.rule
            );
        }
    }

    #[test]
    fn substrate_matches_agrees_with_miner_supports() {
        use crate::mining::{PatternNode, Walk};
        let d = generate(&TabSynthConfig::tiny(5, false));
        let mut checked = 0usize;
        let mut v = |n: &PatternNode<'_>| {
            let pat = n.to_pattern();
            for i in 0..d.db.n_records() {
                let in_support = n.support.contains(&(i as u32));
                assert_eq!(TabularData::matches(&pat, d.db.record(i)), in_support);
                checked += 1;
            }
            Walk::Descend
        };
        d.db.traverse(2, 1, &mut v);
        assert!(checked > 0);
    }

    #[test]
    fn select_subsets_records_in_order() {
        let db = TabularData::new(1, vec![vec![0.0], vec![1.0], vec![2.0], vec![3.0]]);
        let sub = db.select(&[3, 1]);
        assert_eq!(sub.n_features, 1);
        assert_eq!(sub.max_thresholds, db.max_thresholds);
        assert_eq!(sub.rows, vec![vec![3.0], vec![1.0]]);
    }

    #[test]
    fn validate_rejects_ragged_and_non_finite() {
        let ragged = TabularData::new(2, vec![vec![0.0]]);
        assert!(ragged.validate().is_err());
        let nan = TabularData::new(1, vec![vec![f64::NAN]]);
        assert!(nan.validate().is_err());
        let inf = TabularData::new(1, vec![vec![f64::INFINITY]]);
        assert!(inf.validate().is_err());
    }

    #[test]
    fn pattern_codec_round_trips_exact_bits() {
        let p = Pattern::Rule(vec![
            RulePredicate::new(0, RuleOp::Le, 1.0 / 3.0),
            RulePredicate::new(4, RuleOp::Gt, -0.1),
        ]);
        let body = TabularData::format_pattern(&p);
        assert_eq!(TabularData::parse_pattern(&body).unwrap(), p);
        assert!(TabularData::parse_pattern("x0<1").is_err());
    }

    #[test]
    fn shard_codec_round_trips_exact_bits() {
        let mut db = TabularData::new(2, vec![vec![0.1, 1.0 / 3.0], vec![-2.5, 1e-300]]);
        db.max_thresholds = 7;
        let back = TabularData::decode_shard(&db.encode_shard()).unwrap();
        assert_eq!(back.n_features, 2);
        assert_eq!(back.max_thresholds, 7);
        assert_eq!(back.rows, db.rows);
        assert!(TabularData::decode_shard(b"bogus header\n").is_err());
    }

    #[test]
    fn shard_concat_appends_rows() {
        let a = TabularData::new(1, vec![vec![0.0]]);
        let b = TabularData::new(1, vec![vec![1.0], vec![2.0]]);
        let c = TabularData::concat(vec![a, b]).unwrap();
        assert_eq!(c.rows, vec![vec![0.0], vec![1.0], vec![2.0]]);
        let w = TabularData::new(2, vec![vec![0.0, 1.0]]);
        let v = TabularData::new(1, vec![vec![0.0]]);
        assert!(TabularData::concat(vec![w, v]).is_err());
    }

    #[test]
    fn scaled_changes_n_only() {
        let cfg = TabSynthConfig::preset_synth_tab(0).scaled(0.1);
        assert_eq!(cfg.n, 50);
        assert_eq!(cfg.n_features, 10);
        assert!(TabSynthConfig::preset_synth_tab(0).classify);
    }
}
