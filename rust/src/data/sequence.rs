//! Sequence databases: the third pattern substrate.
//!
//! Records are ordered lists of symbol ids (think event logs, clicks,
//! SMILES-ish token streams, amino-acid runs); a pattern is a
//! subsequence `⟨a_1 … a_k⟩` and the binary feature is
//! `x_it = I(t ⊑ s_i)` (not-necessarily-contiguous, order-preserving
//! containment).  The enumeration tree is PrefixSpan's prefix-extension
//! tree ([`crate::mining::prefixspan`]), which is anti-monotone — so
//! the whole SPP machinery applies unchanged through the
//! [`PatternSubstrate`] impl at the bottom of this module.
//!
//! Like the other substrates, no public sequence benchmark is reachable
//! offline, so [`generate`] provides a seeded synthetic stand-in with
//! planted predictive subsequence motifs (registry entry `synth-seq`).

use crate::mining::prefixspan::PrefixSpanMiner;
use crate::mining::{Pattern, PatternSubstrate, TreeVisitor};
use crate::testutil::SplitMix64;

/// A sequence database: each record is a list of symbol ids in
/// `[0, n_symbols)`; order matters and repeats are allowed.
#[derive(Clone, Debug, Default)]
pub struct Sequences {
    pub n_symbols: usize,
    pub seqs: Vec<Vec<u32>>,
}

impl Sequences {
    pub fn len(&self) -> usize {
        self.seqs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.seqs.is_empty()
    }

    /// Validate invariants: every symbol in range.
    pub fn validate(&self) -> crate::Result<()> {
        for (i, s) in self.seqs.iter().enumerate() {
            if let Some(&bad) = s.iter().find(|&&a| a as usize >= self.n_symbols) {
                anyhow::bail!("sequence {i} symbol {bad} out of range");
            }
        }
        Ok(())
    }
}

/// A supervised sequence dataset.
#[derive(Clone, Debug)]
pub struct LabeledSequences {
    pub db: Sequences,
    /// Regression targets, or ±1 class labels.
    pub y: Vec<f64>,
}

/// Is `needle` an order-preserving (not necessarily contiguous)
/// subsequence of `haystack`?  Greedy leftmost matching is exact for
/// this test.
pub fn is_subsequence(haystack: &[u32], needle: &[u32]) -> bool {
    let mut it = haystack.iter();
    needle.iter().all(|&x| it.by_ref().any(|&h| h == x))
}

impl PatternSubstrate for Sequences {
    type Record = [u32];

    fn n_records(&self) -> usize {
        self.seqs.len()
    }

    fn traverse(&self, maxpat: usize, minsup: usize, visitor: &mut dyn TreeVisitor) {
        let mut m = PrefixSpanMiner::new(self, maxpat);
        m.minsup = minsup;
        m.traverse(visitor);
    }

    fn traverse_parallel<F: crate::mining::SubtreeVisitors>(
        &self,
        maxpat: usize,
        minsup: usize,
        threads: usize,
        factory: &F,
    ) -> Vec<F::V> {
        let mut m = PrefixSpanMiner::new(self, maxpat);
        m.minsup = minsup;
        m.traverse_par(threads, factory)
    }

    fn matches(pattern: &Pattern, record: &[u32]) -> bool {
        match pattern {
            Pattern::Sequence(s) => is_subsequence(record, s),
            _ => false,
        }
    }

    fn record(&self, i: usize) -> &[u32] {
        &self.seqs[i]
    }

    fn select(&self, indices: &[usize]) -> Self {
        Sequences {
            n_symbols: self.n_symbols,
            seqs: indices.iter().map(|&i| self.seqs[i].clone()).collect(),
        }
    }

    fn parse_pattern(body: &str) -> crate::Result<Pattern> {
        let symbols = body
            .split(',')
            .map(|t| t.parse::<u32>())
            .collect::<Result<Vec<_>, _>>()?;
        Ok(Pattern::Sequence(symbols))
    }

    fn format_pattern(pattern: &Pattern) -> String {
        match pattern {
            Pattern::Sequence(s) => s
                .iter()
                .map(|a| a.to_string())
                .collect::<Vec<_>>()
                .join(","),
            other => unreachable!("sequence codec asked to format {other:?}"),
        }
    }

    const KIND_TAG: &'static str = "S";
}

impl crate::storage::ShardCodec for Sequences {
    // PrefixSpan projects the records themselves, so a sharded
    // sequence database materializes its union for traversal
    // (`STREAMS` stays false) — the container still provides the
    // on-disk format, the O(1) id remap and CV-fold streaming.

    /// Text shard blob: `symbols <n_symbols>` header, then one
    /// space-separated symbol row per record.
    fn encode_shard(&self) -> Vec<u8> {
        let mut out = format!("symbols {}\n", self.n_symbols);
        for row in &self.seqs {
            let mut first = true;
            for &a in row {
                if !first {
                    out.push(' ');
                }
                out.push_str(&a.to_string());
                first = false;
            }
            out.push('\n');
        }
        out.into_bytes()
    }

    fn decode_shard(bytes: &[u8]) -> crate::Result<Self> {
        let text = std::str::from_utf8(bytes)
            .map_err(|e| anyhow::anyhow!("sequence shard is not UTF-8: {e}"))?;
        let mut lines = text.lines();
        let header = lines.next().unwrap_or_default();
        let n_symbols = header
            .strip_prefix("symbols ")
            .and_then(|v| v.parse::<usize>().ok())
            .ok_or_else(|| anyhow::anyhow!("sequence shard header '{header}' malformed"))?;
        let seqs = lines
            .map(|line| {
                line.split_whitespace()
                    .map(|t| t.parse::<u32>())
                    .collect::<Result<Vec<u32>, _>>()
            })
            .collect::<Result<Vec<Vec<u32>>, _>>()?;
        let db = Sequences { n_symbols, seqs };
        db.validate()?;
        Ok(db)
    }

    fn concat(parts: Vec<Self>) -> crate::Result<Self> {
        let n_symbols = parts.iter().map(|p| p.n_symbols).max().unwrap_or(0);
        let seqs = parts.into_iter().flat_map(|p| p.seqs).collect();
        Ok(Sequences { n_symbols, seqs })
    }
}

/// One planted rule: records containing `symbols` as a subsequence get
/// `weight` added to their score.
#[derive(Clone, Debug)]
pub struct PlantedSeqRule {
    pub symbols: Vec<u32>,
    pub weight: f64,
}

#[derive(Clone, Debug)]
pub struct SeqSynthConfig {
    pub seed: u64,
    pub n: usize,
    /// Alphabet size.
    pub n_symbols: usize,
    /// Record lengths are drawn uniformly in `[min_len, max_len]`.
    pub min_len: usize,
    pub max_len: usize,
    /// Number of planted subsequence motifs.
    pub n_rules: usize,
    /// Rule lengths are drawn in `[2, max_rule_len]`.
    pub max_rule_len: usize,
    /// Probability a record gets a random rule implanted.
    pub implant_prob: f64,
    /// Gaussian noise on regression targets / label-flip margin.
    pub noise: f64,
    /// true => ±1 labels (classification); false => real targets.
    pub classify: bool,
}

impl SeqSynthConfig {
    fn base(seed: u64, n: usize, n_symbols: usize, classify: bool) -> Self {
        Self {
            seed,
            n,
            n_symbols,
            min_len: 10,
            max_len: 36,
            n_rules: 6,
            max_rule_len: 3,
            implant_prob: 0.4,
            noise: 0.5,
            classify,
        }
    }

    /// The `synth-seq` registry preset: n = 600 event streams over a
    /// 24-symbol alphabet, classification.
    pub fn preset_synth_seq(seed: u64) -> Self {
        Self::base(seed, 600, 24, true)
    }

    /// Small config for tests.
    pub fn tiny(seed: u64, classify: bool) -> Self {
        let mut c = Self::base(seed, 50, 8, classify);
        c.min_len = 4;
        c.max_len = 10;
        c.n_rules = 3;
        c
    }

    /// Scale record count by `f` (benchmark `--scale` support).
    pub fn scaled(mut self, f: f64) -> Self {
        self.n = ((self.n as f64 * f).round() as usize).max(8);
        self
    }
}

/// Generated dataset plus the ground-truth rules (handy in tests).
#[derive(Clone, Debug)]
pub struct SynthSequences {
    pub db: Sequences,
    pub y: Vec<f64>,
    pub rules: Vec<PlantedSeqRule>,
}

impl SynthSequences {
    pub fn labeled(&self) -> LabeledSequences {
        LabeledSequences {
            db: self.db.clone(),
            y: self.y.clone(),
        }
    }
}

/// Generate a dataset per `cfg`.  Fully deterministic in `cfg.seed`.
pub fn generate(cfg: &SeqSynthConfig) -> SynthSequences {
    assert!(cfg.n_symbols >= 4 && cfg.n >= 4 && cfg.min_len >= 2 && cfg.max_len >= cfg.min_len);
    let mut rng = SplitMix64::new(cfg.seed);

    // Power-law symbol marginals (a few frequent, many rare symbols —
    // this shapes the prefix tree's support decay), shuffled so symbol
    // id does not encode frequency.
    let mut marginals: Vec<f64> = (0..cfg.n_symbols)
        .map(|j| 1.0 / (1.0 + j as f64).powf(0.7))
        .collect();
    rng.shuffle(&mut marginals);

    // Planted rules over moderately frequent symbols, so supports are
    // non-trivial; repeats are allowed (sequences, unlike item-sets).
    let mut freq: Vec<u32> = (0..cfg.n_symbols as u32).collect();
    freq.sort_by(|&a, &b| {
        marginals[b as usize]
            .partial_cmp(&marginals[a as usize])
            .unwrap()
    });
    let pool = &freq[..(cfg.n_symbols / 2).max(2)];
    let mut rules = Vec::with_capacity(cfg.n_rules);
    for _ in 0..cfg.n_rules {
        let len = rng.range(2, cfg.max_rule_len.max(2));
        let symbols: Vec<u32> = (0..len).map(|_| pool[rng.below(pool.len())]).collect();
        let mag = 1.0 + rng.next_f64() * 2.0;
        let weight = if rng.coin(0.5) { mag } else { -mag };
        rules.push(PlantedSeqRule { symbols, weight });
    }

    let mut seqs = Vec::with_capacity(cfg.n);
    let mut y = Vec::with_capacity(cfg.n);
    for _ in 0..cfg.n {
        let len = rng.range(cfg.min_len, cfg.max_len);
        let mut row: Vec<u32> = (0..len).map(|_| rng.weighted(&marginals) as u32).collect();
        if rng.coin(cfg.implant_prob) {
            // Implant a rule as a subsequence: insert its symbols at
            // random positions, left to right.
            let r = &rules[rng.below(rules.len())];
            let mut at = 0usize;
            for &a in &r.symbols {
                at = rng.range(at, row.len());
                row.insert(at, a);
                at += 1;
            }
        }
        let mut score = 0.0;
        for r in &rules {
            if is_subsequence(&row, &r.symbols) {
                score += r.weight;
            }
        }
        score += cfg.noise * rng.gauss();
        if cfg.classify {
            y.push(if score >= 0.0 { 1.0 } else { -1.0 });
        } else {
            y.push(score);
        }
        seqs.push(row);
    }

    SynthSequences {
        db: Sequences {
            n_symbols: cfg.n_symbols,
            seqs,
        },
        y,
        rules,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subsequence_matcher_cases() {
        assert!(is_subsequence(&[1, 3, 5], &[1, 5]));
        assert!(is_subsequence(&[1, 3, 5], &[]));
        assert!(is_subsequence(&[1, 1, 2], &[1, 1]));
        assert!(!is_subsequence(&[1, 3, 5], &[5, 1])); // order matters
        assert!(!is_subsequence(&[1, 2], &[1, 1])); // multiplicity matters
        assert!(!is_subsequence(&[], &[0]));
    }

    #[test]
    fn deterministic_in_seed_and_shapes_match() {
        let cfg = SeqSynthConfig::tiny(9, true);
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.db.seqs, b.db.seqs);
        assert_eq!(a.y, b.y);
        assert_eq!(a.db.seqs.len(), cfg.n);
        assert_eq!(a.db.n_symbols, cfg.n_symbols);
        a.db.validate().unwrap();
        let c = generate(&SeqSynthConfig::tiny(10, true));
        assert_ne!(a.db.seqs, c.db.seqs);
    }

    #[test]
    fn classification_labels_are_pm1_both_classes() {
        let d = generate(&SeqSynthConfig::tiny(2, true));
        assert!(d.y.iter().all(|&v| v == 1.0 || v == -1.0));
        assert!(d.y.iter().any(|&v| v == 1.0));
        assert!(d.y.iter().any(|&v| v == -1.0));
    }

    #[test]
    fn implanted_rules_are_recoverable_subsequences() {
        let d = generate(&SeqSynthConfig::tiny(4, false));
        for r in &d.rules {
            assert!(r.symbols.len() >= 2);
            assert!(r.symbols.iter().all(|&a| (a as usize) < d.db.n_symbols));
            // at least one record carries each rule (implant_prob 0.4
            // over 50 records; frequent symbols also co-occur by chance)
            assert!(
                d.db.seqs.iter().any(|s| is_subsequence(s, &r.symbols)),
                "rule {:?} supported nowhere",
                r.symbols
            );
        }
    }

    #[test]
    fn substrate_matches_agrees_with_miner_supports() {
        use crate::mining::{PatternNode, Walk};
        let d = generate(&SeqSynthConfig::tiny(5, false));
        let mut checked = 0usize;
        let mut v = |n: &PatternNode<'_>| {
            let pat = n.to_pattern();
            for i in 0..d.db.n_records() {
                let in_support = n.support.contains(&(i as u32));
                assert_eq!(Sequences::matches(&pat, d.db.record(i)), in_support);
                checked += 1;
            }
            Walk::Descend
        };
        d.db.traverse(2, 1, &mut v);
        assert!(checked > 0);
    }

    #[test]
    fn select_subsets_records_in_order() {
        let db = Sequences {
            n_symbols: 3,
            seqs: vec![vec![0], vec![1], vec![2], vec![0, 1]],
        };
        let sub = db.select(&[3, 1]);
        assert_eq!(sub.n_symbols, 3);
        assert_eq!(sub.seqs, vec![vec![0, 1], vec![1]]);
    }

    #[test]
    fn validate_rejects_out_of_range() {
        let db = Sequences {
            n_symbols: 2,
            seqs: vec![vec![0, 5]],
        };
        assert!(db.validate().is_err());
    }

    #[test]
    fn scaled_changes_n_only() {
        let cfg = SeqSynthConfig::preset_synth_seq(0).scaled(0.1);
        assert_eq!(cfg.n, 60);
        assert_eq!(cfg.n_symbols, 24);
        assert!(SeqSynthConfig::preset_synth_seq(0).classify);
    }
}
