//! Seeded synthetic item-set data with planted predictive conjunctions.
//!
//! Stand-in for the paper's splice / a9a / dna / protein datasets (the
//! LIBSVM site is unreachable offline; DESIGN.md §2 documents the
//! substitution).  The generator matches what drives both miners' and
//! both methods' cost profile:
//!
//! * matched `(n, d)` and per-record item counts (density),
//! * power-law item marginals (real categorical encodings have a few
//!   frequent and many rare items — this shapes the enumeration tree's
//!   support decay),
//! * **planted conjunctions**: a handful of item-sets whose joint
//!   occurrence carries the signal, so the optimal model genuinely needs
//!   patterns of size > 1 (two-stage methods with singletons only would
//!   underfit — the paper's motivation).

use super::{LabeledTransactions, Transactions};
use crate::testutil::SplitMix64;

/// One planted rule: if all `items` co-occur, add `weight` to the score.
#[derive(Clone, Debug)]
pub struct PlantedRule {
    pub items: Vec<u32>,
    pub weight: f64,
}

#[derive(Clone, Debug)]
pub struct ItemsetSynthConfig {
    pub seed: u64,
    pub n: usize,
    pub d: usize,
    /// Mean number of items per transaction (before rule implanting).
    pub avg_items: f64,
    /// Number of planted conjunctions.
    pub n_rules: usize,
    /// Rule sizes are drawn in `[2, max_rule_len]`.
    pub max_rule_len: usize,
    /// Probability a record gets a random rule implanted.
    pub implant_prob: f64,
    /// Gaussian noise on regression targets / flip-driving noise margin.
    pub noise: f64,
    /// true => ±1 labels (classification); false => real targets.
    pub classify: bool,
}

impl ItemsetSynthConfig {
    fn base(seed: u64, n: usize, d: usize, avg_items: f64, classify: bool) -> Self {
        Self {
            seed,
            n,
            d,
            avg_items,
            n_rules: 8,
            max_rule_len: 4,
            implant_prob: 0.35,
            noise: 0.5,
            classify,
        }
    }

    /// splice-scale: n=1000, d=120, categorical-ish density.
    pub fn preset_splice(seed: u64) -> Self {
        Self::base(seed, 1000, 120, 30.0, true)
    }

    /// a9a-scale: n=32561, d=123, sparse one-hot density.
    pub fn preset_a9a(seed: u64) -> Self {
        Self::base(seed, 32_561, 123, 14.0, true)
    }

    /// dna-scale regression: n=2000, d=180.
    pub fn preset_dna(seed: u64) -> Self {
        Self::base(seed, 2000, 180, 45.0, false)
    }

    /// protein-scale regression: n=6621, d=714 (density capped so the
    /// enumeration tree stays finite-sized; see DESIGN.md §2).
    pub fn preset_protein(seed: u64) -> Self {
        Self::base(seed, 6621, 714, 80.0, false)
    }

    /// Small config for tests.
    pub fn tiny(seed: u64, classify: bool) -> Self {
        let mut c = Self::base(seed, 60, 12, 4.0, classify);
        c.n_rules = 3;
        c.max_rule_len = 3;
        c
    }

    /// Out-of-core scale: n=25M, d=256 — 10–100× the paper's largest
    /// preset, only reachable through [`ChunkedItemsetGen`] + the shard
    /// writer (materializing it in one piece costs tens of GB).
    pub fn preset_xxl(seed: u64) -> Self {
        Self::base(seed, 25_000_000, 256, 10.0, false)
    }

    /// Scale record count by `f` (benchmark `--scale` support).
    pub fn scaled(mut self, f: f64) -> Self {
        self.n = ((self.n as f64 * f).round() as usize).max(8);
        self
    }
}

/// Generated dataset plus the ground-truth rules (handy in tests).
#[derive(Clone, Debug)]
pub struct SynthItemsets {
    pub db: Transactions,
    pub y: Vec<f64>,
    pub rules: Vec<PlantedRule>,
}

impl SynthItemsets {
    pub fn to_transactions(&self) -> Transactions {
        self.db.clone()
    }

    pub fn labeled(&self) -> LabeledTransactions {
        LabeledTransactions {
            db: self.db.clone(),
            y: self.y.clone(),
        }
    }
}

/// Streaming face of [`generate`]: the header phase (marginals, planted
/// rules) runs once at construction, then records are drawn in bounded
/// batches from the **same single sequential RNG stream** the one-shot
/// generator uses — so concatenating batches of *any* sizing is
/// byte-identical to one `generate` call (every record is a pure
/// function of the stream position; labels are per-record).  This is
/// what lets the out-of-core shard writer emit the tens-of-millions-
/// record `preset_xxl` shard by shard without ever holding the whole
/// database.
pub struct ChunkedItemsetGen {
    cfg: ItemsetSynthConfig,
    rng: SplitMix64,
    marginals: Vec<f64>,
    rules: Vec<PlantedRule>,
    emitted: usize,
}

impl ChunkedItemsetGen {
    /// Run the header phase for `cfg` (deterministic in `cfg.seed`).
    pub fn new(cfg: ItemsetSynthConfig) -> Self {
        assert!(cfg.d >= 4 && cfg.n >= 4);
        let mut rng = SplitMix64::new(cfg.seed);

        // Power-law item marginals, scaled so the expected row weight is
        // avg_items.
        let mut marginals: Vec<f64> = (0..cfg.d)
            .map(|j| 1.0 / (1.0 + j as f64).powf(0.75))
            .collect();
        let sum: f64 = marginals.iter().sum();
        for m in &mut marginals {
            *m = (*m / sum * cfg.avg_items).min(0.95);
        }
        // Shuffle so item id does not encode frequency (the miner orders by
        // id; correlating the two would make trees artificially easy).
        rng.shuffle(&mut marginals);

        // Planted rules over moderately frequent items so supports are
        // non-trivial.
        let mut freq_items: Vec<u32> = (0..cfg.d as u32).collect();
        freq_items.sort_by(|&a, &b| {
            marginals[b as usize]
                .partial_cmp(&marginals[a as usize])
                .unwrap()
        });
        let pool = &freq_items[..(cfg.d / 2).max(cfg.max_rule_len + 1)];
        let mut rules = Vec::with_capacity(cfg.n_rules);
        for _ in 0..cfg.n_rules {
            let len = rng.range(2, cfg.max_rule_len.max(2));
            let mut items: Vec<u32> = rng
                .sample_distinct(pool.len(), len.min(pool.len()))
                .into_iter()
                .map(|k| pool[k])
                .collect();
            items.sort_unstable();
            items.dedup();
            let mag = 1.0 + rng.next_f64() * 2.0;
            let weight = if rng.coin(0.5) { mag } else { -mag };
            rules.push(PlantedRule { items, weight });
        }

        ChunkedItemsetGen {
            cfg,
            rng,
            marginals,
            rules,
            emitted: 0,
        }
    }

    /// The planted ground-truth rules (fixed after the header phase).
    pub fn rules(&self) -> &[PlantedRule] {
        &self.rules
    }

    /// Records not yet emitted (`cfg.n` down to 0).
    pub fn remaining(&self) -> usize {
        self.cfg.n - self.emitted
    }

    /// Draw the next `max_records.min(remaining)` records and their
    /// targets.  Returns an empty batch once the configured `n` records
    /// have been emitted.
    pub fn next_batch(&mut self, max_records: usize) -> (Transactions, Vec<f64>) {
        let take = max_records.min(self.remaining());
        let mut items_rows = Vec::with_capacity(take);
        let mut y = Vec::with_capacity(take);
        for _ in 0..take {
            let mut row: Vec<u32> = (0..self.cfg.d as u32)
                .filter(|&j| self.rng.coin(self.marginals[j as usize]))
                .collect();
            if self.rng.coin(self.cfg.implant_prob) {
                let r = &self.rules[self.rng.below(self.rules.len())];
                row.extend_from_slice(&r.items);
                row.sort_unstable();
                row.dedup();
            }
            let mut score = 0.0;
            for r in &self.rules {
                if contains_all(&row, &r.items) {
                    score += r.weight;
                }
            }
            score += self.cfg.noise * self.rng.gauss();
            if self.cfg.classify {
                y.push(if score >= 0.0 { 1.0 } else { -1.0 });
            } else {
                y.push(score);
            }
            items_rows.push(row);
        }
        self.emitted += take;
        (
            Transactions {
                n_items: self.cfg.d,
                items: items_rows,
            },
            y,
        )
    }
}

/// Generate a dataset per `cfg`.  Fully deterministic in `cfg.seed`.
pub fn generate(cfg: &ItemsetSynthConfig) -> SynthItemsets {
    let mut chunks = ChunkedItemsetGen::new(cfg.clone());
    let (db, y) = chunks.next_batch(cfg.n);
    SynthItemsets {
        db,
        y,
        rules: chunks.rules,
    }
}

/// `needle ⊆ haystack` for sorted slices.
pub fn contains_all(haystack: &[u32], needle: &[u32]) -> bool {
    let mut it = haystack.iter();
    'outer: for &x in needle {
        for &h in it.by_ref() {
            if h == x {
                continue 'outer;
            }
            if h > x {
                return false;
            }
        }
        return false;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seed() {
        let a = generate(&ItemsetSynthConfig::tiny(9, true));
        let b = generate(&ItemsetSynthConfig::tiny(9, true));
        assert_eq!(a.db.items, b.db.items);
        assert_eq!(a.y, b.y);
        let c = generate(&ItemsetSynthConfig::tiny(10, true));
        assert_ne!(a.db.items, c.db.items);
    }

    #[test]
    fn shapes_match_config() {
        let cfg = ItemsetSynthConfig::tiny(1, false);
        let d = generate(&cfg);
        assert_eq!(d.db.items.len(), cfg.n);
        assert_eq!(d.db.n_items, cfg.d);
        assert_eq!(d.y.len(), cfg.n);
        d.db.validate().unwrap();
    }

    #[test]
    fn classification_labels_are_pm1() {
        let d = generate(&ItemsetSynthConfig::tiny(2, true));
        assert!(d.y.iter().all(|&v| v == 1.0 || v == -1.0));
        // both classes present for a sane config
        assert!(d.y.iter().any(|&v| v == 1.0));
        assert!(d.y.iter().any(|&v| v == -1.0));
    }

    #[test]
    fn density_roughly_matches() {
        let cfg = ItemsetSynthConfig::base(3, 2000, 64, 10.0, false);
        let d = generate(&cfg);
        let avg: f64 =
            d.db.items.iter().map(|r| r.len() as f64).sum::<f64>() / cfg.n as f64;
        // implanting adds a couple of items on top of the base 10
        assert!(avg > 7.0 && avg < 16.0, "avg items {avg}");
    }

    #[test]
    fn rules_are_sorted_distinct_and_in_range() {
        let d = generate(&ItemsetSynthConfig::tiny(4, true));
        for r in &d.rules {
            assert!(r.items.windows(2).all(|w| w[0] < w[1]));
            assert!(r.items.iter().all(|&j| (j as usize) < d.db.n_items));
            assert!(r.weight.abs() >= 1.0);
        }
    }

    #[test]
    fn contains_all_cases() {
        assert!(contains_all(&[1, 3, 5], &[3]));
        assert!(contains_all(&[1, 3, 5], &[1, 5]));
        assert!(contains_all(&[1, 3, 5], &[]));
        assert!(!contains_all(&[1, 3, 5], &[2]));
        assert!(!contains_all(&[1, 3], &[1, 3, 5]));
        assert!(!contains_all(&[], &[0]));
    }

    #[test]
    fn scaled_changes_n_only() {
        let cfg = ItemsetSynthConfig::preset_splice(0).scaled(0.1);
        assert_eq!(cfg.n, 100);
        assert_eq!(cfg.d, 120);
    }

    #[test]
    fn chunked_generation_is_batching_invariant() {
        let cfg = ItemsetSynthConfig::tiny(11, true);
        let whole = generate(&cfg);
        for batch in [1usize, 7, 16, 59, 60, 61] {
            let mut chunks = ChunkedItemsetGen::new(cfg.clone());
            let mut rows = Vec::new();
            let mut y = Vec::new();
            while chunks.remaining() > 0 {
                let (db, yb) = chunks.next_batch(batch);
                rows.extend(db.items);
                y.extend(yb);
            }
            assert_eq!(rows, whole.db.items, "batch={batch}");
            assert_eq!(y, whole.y, "batch={batch}");
            // drained generators emit empty batches
            let (db, yb) = chunks.next_batch(batch);
            assert!(db.items.is_empty() && yb.is_empty());
        }
    }

    #[test]
    fn presets_match_paper_scales() {
        assert_eq!(ItemsetSynthConfig::preset_splice(0).n, 1000);
        assert_eq!(ItemsetSynthConfig::preset_splice(0).d, 120);
        assert_eq!(ItemsetSynthConfig::preset_a9a(0).n, 32_561);
        assert_eq!(ItemsetSynthConfig::preset_a9a(0).d, 123);
        assert_eq!(ItemsetSynthConfig::preset_dna(0).d, 180);
        assert_eq!(ItemsetSynthConfig::preset_protein(0).d, 714);
        assert!(ItemsetSynthConfig::preset_splice(0).classify);
        assert!(!ItemsetSynthConfig::preset_dna(0).classify);
    }
}
