//! Dataset registry: the paper's eight benchmark datasets by name,
//! plus the `synth-seq` sequence preset exercising the third substrate.
//!
//! Every preset is a seeded synthetic stand-in at the paper's scale
//! (DESIGN.md §2).  `lookup` accepts an optional scale factor so the
//! figure benches can run the full sweep at reduced n when wall-clock
//! budget demands it (EXPERIMENTS.md records the scale used).

use super::sequence::{self, LabeledSequences, SeqSynthConfig};
use super::synth_graphs::{self, GraphSynthConfig};
use super::synth_itemsets::{self, ItemsetSynthConfig};
use super::{graph::GraphDatabase, LabeledTransactions};
use crate::solver::problem::Task;

/// Default seed for all registry datasets — fixed so every bench and
/// example sees identical data.
pub const REGISTRY_SEED: u64 = 20160813; // KDD'16 conference date

#[derive(Clone, Debug)]
pub enum Dataset {
    Graphs(GraphDatabase),
    Itemsets(LabeledTransactions),
    Sequences(LabeledSequences),
}

impl Dataset {
    pub fn n_records(&self) -> usize {
        match self {
            Dataset::Graphs(g) => g.len(),
            Dataset::Itemsets(t) => t.db.len(),
            Dataset::Sequences(s) => s.db.len(),
        }
    }

    pub fn targets(&self) -> &[f64] {
        match self {
            Dataset::Graphs(g) => &g.y,
            Dataset::Itemsets(t) => &t.y,
            Dataset::Sequences(s) => &s.y,
        }
    }
}

/// Metadata for one registered dataset.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DatasetInfo {
    pub name: &'static str,
    pub kind: Kind,
    pub task: Task,
    /// Record count at scale 1.0 (the paper's n).
    pub paper_n: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Graph,
    Itemset,
    Sequence,
}

/// All eight paper datasets plus the `synth-seq` sequence preset (the
/// third-substrate workload; `paper_n` is its scale-1.0 record count).
pub const ALL: [DatasetInfo; 9] = [
    DatasetInfo {
        name: "cpdb",
        kind: Kind::Graph,
        task: Task::Classification,
        paper_n: 648,
    },
    DatasetInfo {
        name: "mutagenicity",
        kind: Kind::Graph,
        task: Task::Classification,
        paper_n: 4337,
    },
    DatasetInfo {
        name: "bergstrom",
        kind: Kind::Graph,
        task: Task::Regression,
        paper_n: 185,
    },
    DatasetInfo {
        name: "karthikeyan",
        kind: Kind::Graph,
        task: Task::Regression,
        paper_n: 4173,
    },
    DatasetInfo {
        name: "splice",
        kind: Kind::Itemset,
        task: Task::Classification,
        paper_n: 1000,
    },
    DatasetInfo {
        name: "a9a",
        kind: Kind::Itemset,
        task: Task::Classification,
        paper_n: 32_561,
    },
    DatasetInfo {
        name: "dna",
        kind: Kind::Itemset,
        task: Task::Regression,
        paper_n: 2000,
    },
    DatasetInfo {
        name: "protein",
        kind: Kind::Itemset,
        task: Task::Regression,
        paper_n: 6621,
    },
    DatasetInfo {
        name: "synth-seq",
        kind: Kind::Sequence,
        task: Task::Classification,
        paper_n: 600,
    },
];

pub fn info(name: &str) -> Option<DatasetInfo> {
    ALL.iter().find(|d| d.name == name).copied()
}

/// Materialize a registry dataset, optionally scaled.
pub fn lookup(name: &str, scale: f64) -> crate::Result<Dataset> {
    let seed = REGISTRY_SEED;
    let ds = match name {
        "cpdb" => Dataset::Graphs(
            synth_graphs::generate(&GraphSynthConfig::preset_cpdb(seed).scaled(scale)).db,
        ),
        "mutagenicity" => Dataset::Graphs(
            synth_graphs::generate(&GraphSynthConfig::preset_mutagenicity(seed).scaled(scale)).db,
        ),
        "bergstrom" => Dataset::Graphs(
            synth_graphs::generate(&GraphSynthConfig::preset_bergstrom(seed).scaled(scale)).db,
        ),
        "karthikeyan" => Dataset::Graphs(
            synth_graphs::generate(&GraphSynthConfig::preset_karthikeyan(seed).scaled(scale)).db,
        ),
        "splice" => Dataset::Itemsets(
            synth_itemsets::generate(&ItemsetSynthConfig::preset_splice(seed).scaled(scale))
                .labeled(),
        ),
        "a9a" => Dataset::Itemsets(
            synth_itemsets::generate(&ItemsetSynthConfig::preset_a9a(seed).scaled(scale)).labeled(),
        ),
        "dna" => Dataset::Itemsets(
            synth_itemsets::generate(&ItemsetSynthConfig::preset_dna(seed).scaled(scale)).labeled(),
        ),
        "protein" => Dataset::Itemsets(
            synth_itemsets::generate(&ItemsetSynthConfig::preset_protein(seed).scaled(scale))
                .labeled(),
        ),
        "synth-seq" => Dataset::Sequences(
            sequence::generate(&SeqSynthConfig::preset_synth_seq(seed).scaled(scale)).labeled(),
        ),
        other => anyhow::bail!(
            "unknown dataset '{other}' (expected one of {:?})",
            ALL.map(|d| d.name)
        ),
    };
    Ok(ds)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_materialize_at_tiny_scale() {
        for d in ALL {
            let ds = lookup(d.name, 0.02).unwrap();
            assert!(ds.n_records() > 0, "{} empty", d.name);
            assert_eq!(ds.n_records(), ds.targets().len());
            match (d.kind, &ds) {
                (Kind::Graph, Dataset::Graphs(_)) => {}
                (Kind::Itemset, Dataset::Itemsets(_)) => {}
                (Kind::Sequence, Dataset::Sequences(_)) => {}
                _ => panic!("{}: kind mismatch", d.name),
            }
        }
    }

    #[test]
    fn scale_one_matches_paper_n() {
        let ds = lookup("cpdb", 1.0).unwrap();
        assert_eq!(ds.n_records(), 648);
        let ds = lookup("splice", 1.0).unwrap();
        assert_eq!(ds.n_records(), 1000);
        let ds = lookup("synth-seq", 1.0).unwrap();
        assert_eq!(ds.n_records(), 600);
    }

    #[test]
    fn unknown_name_is_an_error() {
        assert!(lookup("nope", 1.0).is_err());
        assert!(info("nope").is_none());
        assert_eq!(info("a9a").unwrap().paper_n, 32_561);
    }

    #[test]
    fn classification_targets_are_pm1() {
        let ds = lookup("cpdb", 0.05).unwrap();
        assert!(ds.targets().iter().all(|&v| v == 1.0 || v == -1.0));
    }
}
