//! Dataset registry: the paper's eight benchmark datasets by name,
//! plus the `synth-seq` sequence preset exercising the third
//! substrate, the `synth-tab` numeric tabular preset exercising the
//! fourth (RuleFit rules), and the out-of-core `synth-xxl` itemset
//! preset (10–100× the paper's largest n, only reachable through
//! [`lookup_sharded`]).
//!
//! Every preset is a seeded synthetic stand-in at the paper's scale
//! (DESIGN.md §2).  `lookup` accepts an optional scale factor so the
//! figure benches can run the full sweep at reduced n when wall-clock
//! budget demands it (EXPERIMENTS.md records the scale used).
//! [`lookup_sharded`] serializes any preset into an on-disk shard
//! container and hands back a [`crate::storage::ShardedDb`] — the
//! `synth-xxl` preset streams straight from the chunked generator into
//! the shard writer, so at no point is the whole database resident.
//!
//! This file is also the crate's **only substrate dispatch point**
//! (with `serve/registry.rs` for the tag-keyed model side): generic
//! code reaches a concrete substrate through [`Dataset::visit`] /
//! [`ShardedDataset::visit`] with a [`SubstrateVisitor`] /
//! [`ShardedSubstrateVisitor`], monomorphized at the match sites
//! below.  Adding a substrate = implement
//! [`PatternSubstrate`] + [`BatchScore`] (+ `ShardCodec` for
//! out-of-core), add one registry row, and every CLI subcommand,
//! bench and example picks it up (DESIGN.md §3).  CI's
//! dispatch-hygiene gate keeps `Dataset::`/`Kind::` match ladders
//! from regrowing elsewhere.

use std::path::Path;

use super::sequence::{self, LabeledSequences, SeqSynthConfig, Sequences};
use super::synth_graphs::{self, GraphSynthConfig};
use super::synth_itemsets::{self, ChunkedItemsetGen, ItemsetSynthConfig};
use super::tabular::{self, LabeledTabular, TabSynthConfig, TabularData};
use super::{graph::GraphDatabase, LabeledTransactions, Transactions};
use crate::mining::PatternSubstrate;
use crate::serve::compiled::BatchScore;
use crate::solver::problem::Task;
use crate::storage::{write_sharded, ShardCodec, ShardWriter, ShardedDb};

/// Default seed for all registry datasets — fixed so every bench and
/// example sees identical data.
pub const REGISTRY_SEED: u64 = 20160813; // KDD'16 conference date

#[derive(Clone, Debug)]
pub enum Dataset {
    Graphs(GraphDatabase),
    Itemsets(LabeledTransactions),
    Sequences(LabeledSequences),
    Tabular(LabeledTabular),
}

impl Dataset {
    pub fn n_records(&self) -> usize {
        match self {
            Dataset::Graphs(g) => g.len(),
            Dataset::Itemsets(t) => t.db.len(),
            Dataset::Sequences(s) => s.db.len(),
            Dataset::Tabular(t) => t.db.len(),
        }
    }

    pub fn targets(&self) -> &[f64] {
        match self {
            Dataset::Graphs(g) => &g.y,
            Dataset::Itemsets(t) => &t.y,
            Dataset::Sequences(s) => &s.y,
            Dataset::Tabular(t) => &t.y,
        }
    }

    /// THE in-memory dispatch point: run a [`SubstrateVisitor`] on
    /// this dataset's substrate and targets.  Generic code is
    /// monomorphized here, once per substrate — commands, the
    /// coordinator, the estimator and the serve layer all go through
    /// this method instead of matching on the enum, so the only
    /// substrate match ladders in the crate live in this file and in
    /// `serve/registry.rs` (enforced by CI's dispatch-hygiene gate).
    pub fn visit<V: SubstrateVisitor>(&self, v: V) -> V::Out {
        match self {
            Dataset::Graphs(g) => v.visit(g, &g.y),
            Dataset::Itemsets(t) => v.visit(&t.db, &t.y),
            Dataset::Sequences(s) => v.visit(&s.db, &s.y),
            Dataset::Tabular(t) => v.visit(&t.db, &t.y),
        }
    }
}

/// Everything generic code may ask of a registry substrate: the
/// pattern-tree search surface ([`PatternSubstrate`]), the serve
/// layer's batch-scoring capability ([`BatchScore`]), and `Sync` (the
/// deterministic parallel engine and CV fan records out).  Blanket-
/// implemented, so a new substrate only implements the two base
/// traits and gains registry dispatch for free.
pub trait RegistrySubstrate: PatternSubstrate + BatchScore + Sync {}

impl<T: PatternSubstrate + BatchScore + Sync> RegistrySubstrate for T {}

/// A computation generic over every registry substrate.  Implementors
/// write `visit` once against [`RegistrySubstrate`]; [`Dataset::visit`]
/// instantiates it per substrate at the registry's single match site.
///
/// `visit` consumes `self` so a visitor can both carry borrowed inputs
/// (configs, solvers, accumulators) and return owned results.
pub trait SubstrateVisitor {
    type Out;
    fn visit<S: RegistrySubstrate>(self, db: &S, y: &[f64]) -> Self::Out;
}

/// The out-of-core twin of [`SubstrateVisitor`]: the substrate arrives
/// as a [`ShardedDb`] adapter (itself a [`PatternSubstrate`], so path
/// code runs on it unchanged) whose element type `S` still exposes the
/// full [`RegistrySubstrate`] surface for per-shard work (e.g. batch
/// scoring one decoded shard at a time).
pub trait ShardedSubstrateVisitor {
    type Out;
    fn visit<S>(self, db: &ShardedDb<S>, y: &[f64]) -> Self::Out
    where
        S: RegistrySubstrate + ShardCodec;
}

/// Metadata for one registered dataset.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DatasetInfo {
    pub name: &'static str,
    pub kind: Kind,
    pub task: Task,
    /// Record count at scale 1.0 (the paper's n).
    pub paper_n: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Graph,
    Itemset,
    Sequence,
    Tabular,
}

impl Kind {
    /// The substrate `KIND_TAG` of this dataset kind — the tag models
    /// and the serve registry key on.
    pub fn tag(self) -> &'static str {
        match self {
            Kind::Graph => GraphDatabase::KIND_TAG,
            Kind::Itemset => Transactions::KIND_TAG,
            Kind::Sequence => Sequences::KIND_TAG,
            Kind::Tabular => TabularData::KIND_TAG,
        }
    }
}

/// All eight paper datasets plus the `synth-seq` sequence preset (the
/// third-substrate workload), the `synth-tab` tabular preset (the
/// fourth, RuleFit rules) and the out-of-core `synth-xxl` itemset
/// preset (`paper_n` is each one's scale-1.0 record count).
pub const ALL: [DatasetInfo; 11] = [
    DatasetInfo {
        name: "cpdb",
        kind: Kind::Graph,
        task: Task::Classification,
        paper_n: 648,
    },
    DatasetInfo {
        name: "mutagenicity",
        kind: Kind::Graph,
        task: Task::Classification,
        paper_n: 4337,
    },
    DatasetInfo {
        name: "bergstrom",
        kind: Kind::Graph,
        task: Task::Regression,
        paper_n: 185,
    },
    DatasetInfo {
        name: "karthikeyan",
        kind: Kind::Graph,
        task: Task::Regression,
        paper_n: 4173,
    },
    DatasetInfo {
        name: "splice",
        kind: Kind::Itemset,
        task: Task::Classification,
        paper_n: 1000,
    },
    DatasetInfo {
        name: "a9a",
        kind: Kind::Itemset,
        task: Task::Classification,
        paper_n: 32_561,
    },
    DatasetInfo {
        name: "dna",
        kind: Kind::Itemset,
        task: Task::Regression,
        paper_n: 2000,
    },
    DatasetInfo {
        name: "protein",
        kind: Kind::Itemset,
        task: Task::Regression,
        paper_n: 6621,
    },
    DatasetInfo {
        name: "synth-seq",
        kind: Kind::Sequence,
        task: Task::Classification,
        paper_n: 600,
    },
    DatasetInfo {
        name: "synth-tab",
        kind: Kind::Tabular,
        task: Task::Classification,
        paper_n: 500,
    },
    DatasetInfo {
        name: "synth-xxl",
        kind: Kind::Itemset,
        task: Task::Regression,
        paper_n: 25_000_000,
    },
];

pub fn info(name: &str) -> Option<DatasetInfo> {
    ALL.iter().find(|d| d.name == name).copied()
}

/// The one `unknown dataset` error every lookup shares — its message
/// lists the registered preset names so a typo is self-correcting.
fn unknown_dataset(name: &str) -> anyhow::Error {
    anyhow::anyhow!(
        "unknown dataset '{name}' (available presets: {})",
        ALL.map(|d| d.name).join(", ")
    )
}

/// Metadata for `name`, or the registry's [`unknown_dataset`] error.
/// Commands use this instead of hand-rolling `info(...).ok_or_else`.
pub fn require_info(name: &str) -> crate::Result<DatasetInfo> {
    info(name).ok_or_else(|| unknown_dataset(name))
}

/// Materialize a registry dataset, optionally scaled.
pub fn lookup(name: &str, scale: f64) -> crate::Result<Dataset> {
    let seed = REGISTRY_SEED;
    let ds = match name {
        "cpdb" => Dataset::Graphs(
            synth_graphs::generate(&GraphSynthConfig::preset_cpdb(seed).scaled(scale)).db,
        ),
        "mutagenicity" => Dataset::Graphs(
            synth_graphs::generate(&GraphSynthConfig::preset_mutagenicity(seed).scaled(scale)).db,
        ),
        "bergstrom" => Dataset::Graphs(
            synth_graphs::generate(&GraphSynthConfig::preset_bergstrom(seed).scaled(scale)).db,
        ),
        "karthikeyan" => Dataset::Graphs(
            synth_graphs::generate(&GraphSynthConfig::preset_karthikeyan(seed).scaled(scale)).db,
        ),
        "splice" => Dataset::Itemsets(
            synth_itemsets::generate(&ItemsetSynthConfig::preset_splice(seed).scaled(scale))
                .labeled(),
        ),
        "a9a" => Dataset::Itemsets(
            synth_itemsets::generate(&ItemsetSynthConfig::preset_a9a(seed).scaled(scale)).labeled(),
        ),
        "dna" => Dataset::Itemsets(
            synth_itemsets::generate(&ItemsetSynthConfig::preset_dna(seed).scaled(scale)).labeled(),
        ),
        "protein" => Dataset::Itemsets(
            synth_itemsets::generate(&ItemsetSynthConfig::preset_protein(seed).scaled(scale))
                .labeled(),
        ),
        "synth-seq" => Dataset::Sequences(
            sequence::generate(&SeqSynthConfig::preset_synth_seq(seed).scaled(scale)).labeled(),
        ),
        "synth-tab" => Dataset::Tabular(
            tabular::generate(&TabSynthConfig::preset_synth_tab(seed).scaled(scale)).labeled(),
        ),
        // In-memory materialization of the out-of-core preset — only
        // sensible at small scales (tests, smoke runs); real runs go
        // through `lookup_sharded`, which streams it shard by shard.
        "synth-xxl" => Dataset::Itemsets(
            synth_itemsets::generate(&ItemsetSynthConfig::preset_xxl(seed).scaled(scale)).labeled(),
        ),
        other => return Err(unknown_dataset(other)),
    };
    Ok(ds)
}

/// A registry dataset behind the out-of-core shard container: records
/// live on disk in `ShardedDb`'s file and stream one shard at a time;
/// only the targets (O(n) doubles — the path engine consumes the full
/// `y` regardless) are held in memory.
#[derive(Debug)]
pub enum ShardedDataset {
    Itemsets { db: ShardedDb<Transactions>, y: Vec<f64> },
    Graphs { db: ShardedDb<GraphDatabase>, y: Vec<f64> },
    Sequences { db: ShardedDb<Sequences>, y: Vec<f64> },
    Tabular { db: ShardedDb<TabularData>, y: Vec<f64> },
}

impl ShardedDataset {
    pub fn n_records(&self) -> usize {
        match self {
            ShardedDataset::Itemsets { y, .. }
            | ShardedDataset::Graphs { y, .. }
            | ShardedDataset::Sequences { y, .. }
            | ShardedDataset::Tabular { y, .. } => y.len(),
        }
    }

    pub fn targets(&self) -> &[f64] {
        match self {
            ShardedDataset::Itemsets { y, .. }
            | ShardedDataset::Graphs { y, .. }
            | ShardedDataset::Sequences { y, .. }
            | ShardedDataset::Tabular { y, .. } => y,
        }
    }

    /// THE out-of-core dispatch point, the sharded twin of
    /// [`Dataset::visit`]: run a [`ShardedSubstrateVisitor`] on this
    /// dataset's shard container and targets.
    pub fn visit<V: ShardedSubstrateVisitor>(&self, v: V) -> V::Out {
        match self {
            ShardedDataset::Itemsets { db, y } => v.visit(db, y),
            ShardedDataset::Graphs { db, y } => v.visit(db, y),
            ShardedDataset::Sequences { db, y } => v.visit(db, y),
            ShardedDataset::Tabular { db, y } => v.visit(db, y),
        }
    }
}

/// Serialize a registry preset into an on-disk shard container under
/// `dir` (`<name>-s<scale>-x<shards>.spps`, overwritten if present) and
/// open it as a [`ShardedDataset`].
///
/// The `synth-xxl` preset streams batches from [`ChunkedItemsetGen`]
/// straight into the shard writer — identical records to `lookup` at
/// the same scale (batching-invariant generator), but the peak
/// footprint is one shard, not the database.  Every other preset is
/// materialized once and cut into shards.
pub fn lookup_sharded(
    name: &str,
    scale: f64,
    shards: usize,
    dir: &Path,
) -> crate::Result<ShardedDataset> {
    anyhow::ensure!(shards >= 1, "--shards must be >= 1");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{name}-s{scale}-x{shards}.spps"));

    if name == "synth-xxl" {
        let cfg = ItemsetSynthConfig::preset_xxl(REGISTRY_SEED).scaled(scale);
        let shard_size = (cfg.n + shards - 1) / shards;
        let mut chunks = ChunkedItemsetGen::new(cfg);
        let mut writer = ShardWriter::<Transactions>::create(&path, shard_size)?;
        let mut y = Vec::with_capacity(chunks.remaining());
        while chunks.remaining() > 0 {
            let (batch, yb) = chunks.next_batch(shard_size);
            y.extend(yb);
            writer.write_shard(&batch)?;
        }
        writer.finish()?;
        let db = ShardedDb::<Transactions>::open(&path)?;
        return Ok(ShardedDataset::Itemsets { db, y });
    }

    match lookup(name, scale)? {
        Dataset::Itemsets(t) => {
            let shard_size = (t.db.len() + shards - 1) / shards;
            write_sharded(&t.db, &path, shard_size)?;
            let db = ShardedDb::<Transactions>::open(&path)?;
            Ok(ShardedDataset::Itemsets { db, y: t.y })
        }
        Dataset::Graphs(g) => {
            let shard_size = (g.len() + shards - 1) / shards;
            write_sharded(&g, &path, shard_size)?;
            let db = ShardedDb::<GraphDatabase>::open(&path)?;
            let y = g.y;
            Ok(ShardedDataset::Graphs { db, y })
        }
        Dataset::Sequences(s) => {
            let shard_size = (s.db.len() + shards - 1) / shards;
            write_sharded(&s.db, &path, shard_size)?;
            let db = ShardedDb::<Sequences>::open(&path)?;
            Ok(ShardedDataset::Sequences { db, y: s.y })
        }
        Dataset::Tabular(t) => {
            let shard_size = (t.db.len() + shards - 1) / shards;
            write_sharded(&t.db, &path, shard_size)?;
            let db = ShardedDb::<TabularData>::open(&path)?;
            Ok(ShardedDataset::Tabular { db, y: t.y })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_materialize_at_tiny_scale() {
        for d in ALL {
            // the out-of-core preset's paper n is 25M — 2% would still
            // be half a million records, so cap it at ~400 for the test
            let scale = if d.paper_n > 1_000_000 {
                400.0 / d.paper_n as f64
            } else {
                0.02
            };
            let ds = lookup(d.name, scale).unwrap();
            assert!(ds.n_records() > 0, "{} empty", d.name);
            assert_eq!(ds.n_records(), ds.targets().len());
            match (d.kind, &ds) {
                (Kind::Graph, Dataset::Graphs(_)) => {}
                (Kind::Itemset, Dataset::Itemsets(_)) => {}
                (Kind::Sequence, Dataset::Sequences(_)) => {}
                (Kind::Tabular, Dataset::Tabular(_)) => {}
                _ => panic!("{}: kind mismatch", d.name),
            }
        }
    }

    #[test]
    fn scale_one_matches_paper_n() {
        let ds = lookup("cpdb", 1.0).unwrap();
        assert_eq!(ds.n_records(), 648);
        let ds = lookup("splice", 1.0).unwrap();
        assert_eq!(ds.n_records(), 1000);
        let ds = lookup("synth-seq", 1.0).unwrap();
        assert_eq!(ds.n_records(), 600);
        let ds = lookup("synth-tab", 1.0).unwrap();
        assert_eq!(ds.n_records(), 500);
    }

    #[test]
    fn unknown_name_is_an_error() {
        assert!(lookup("nope", 1.0).is_err());
        assert!(info("nope").is_none());
        assert_eq!(info("a9a").unwrap().paper_n, 32_561);
    }

    #[test]
    fn classification_targets_are_pm1() {
        let ds = lookup("cpdb", 0.05).unwrap();
        assert!(ds.targets().iter().all(|&v| v == 1.0 || v == -1.0));
    }

    #[test]
    fn sharded_lookup_round_trips_every_kind() {
        let dir = std::env::temp_dir().join(format!("spp-reg-shards-{}", std::process::id()));
        for (name, shards) in [("splice", 3usize), ("cpdb", 2), ("synth-seq", 4), ("synth-tab", 2)] {
            let ds = lookup_sharded(name, 0.05, shards, &dir).unwrap();
            let mem = lookup(name, 0.05).unwrap();
            assert_eq!(ds.n_records(), mem.n_records(), "{name}");
            assert_eq!(ds.targets(), mem.targets(), "{name}");
        }
        assert!(lookup_sharded("nope", 0.05, 2, &dir).is_err());
        assert!(lookup_sharded("splice", 0.05, 0, &dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sharded_xxl_streams_without_materializing() {
        let dir = std::env::temp_dir().join(format!("spp-reg-xxl-{}", std::process::id()));
        // 25M × 1.6e-5 = 400 records — the streaming path, test-sized
        let scale = 1.6e-5;
        let ds = lookup_sharded("synth-xxl", scale, 5, &dir).unwrap();
        match &ds {
            ShardedDataset::Itemsets { db, y } => {
                assert_eq!(db.n_shards(), 5);
                assert_eq!(db.n_records(), y.len());
                // record-identical to the in-memory materialization at
                // the same scale (the generator is batching-invariant)
                let mem = lookup("synth-xxl", scale).unwrap();
                assert_eq!(&y[..], mem.targets());
                let union = db.materialize().unwrap();
                match mem {
                    Dataset::Itemsets(t) => assert_eq!(union.items, t.db.items),
                    _ => unreachable!(),
                }
            }
            _ => panic!("synth-xxl is an itemset preset"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
