//! Datasets: containers, parsers, and seeded synthetic generators.
//!
//! Two database kinds exist in the paper:
//! * **transaction databases** ([`Transactions`]) for item-set mining —
//!   each record is a set of item ids;
//! * **graph databases** ([`graph::GraphDatabase`]) for subgraph mining —
//!   each record is a labeled undirected graph.
//!
//! The paper's benchmark datasets (CPDB, Mutagenicity, Bergstrom,
//! Karthikeyan from cheminformatics.org; splice/a9a/dna/protein from the
//! LIBSVM site) are not reachable from this offline environment, so
//! [`registry`] exposes *seeded synthetic stand-ins* with matched scale
//! and planted predictive structure (DESIGN.md §2).  The [`libsvm`] and
//! [`graph`] parsers accept the real files unchanged if supplied.

pub mod graph;
pub mod libsvm;
pub mod registry;
pub mod synth_graphs;
pub mod synth_itemsets;

/// A transaction database: each record is a sorted set of item ids in
/// `[0, n_items)`.  Pattern `t` (an item-set) matches record `i` iff
/// `t ⊆ items[i]`; the binary feature is `x_it = I(t ⊆ items[i])`.
#[derive(Clone, Debug, Default)]
pub struct Transactions {
    pub n_items: usize,
    pub items: Vec<Vec<u32>>,
}

impl Transactions {
    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Per-item transaction-id lists (the eclat vertical layout the
    /// item-set miner runs on).  `tidlists()[j]` is sorted ascending.
    pub fn tidlists(&self) -> Vec<Vec<u32>> {
        let mut tids = vec![Vec::new(); self.n_items];
        for (i, t) in self.items.iter().enumerate() {
            for &j in t {
                tids[j as usize].push(i as u32);
            }
        }
        tids
    }

    /// Validate invariants: items sorted, strictly increasing, in range.
    pub fn validate(&self) -> crate::Result<()> {
        for (i, t) in self.items.iter().enumerate() {
            if !t.windows(2).all(|w| w[0] < w[1]) {
                anyhow::bail!("transaction {i} items not strictly sorted");
            }
            if let Some(&max) = t.last() {
                if max as usize >= self.n_items {
                    anyhow::bail!("transaction {i} item {max} out of range");
                }
            }
        }
        Ok(())
    }
}

/// A supervised dataset over either database kind.
#[derive(Clone, Debug)]
pub struct LabeledTransactions {
    pub db: Transactions,
    /// Regression targets, or ±1 class labels.
    pub y: Vec<f64>,
}

impl LabeledTransactions {
    pub fn to_transactions(&self) -> Transactions {
        self.db.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Transactions {
        Transactions {
            n_items: 4,
            items: vec![vec![0, 1], vec![1, 2, 3], vec![0, 3], vec![]],
        }
    }

    #[test]
    fn tidlists_invert_rows() {
        let db = tiny();
        let tids = db.tidlists();
        assert_eq!(tids[0], vec![0, 2]);
        assert_eq!(tids[1], vec![0, 1]);
        assert_eq!(tids[2], vec![1]);
        assert_eq!(tids[3], vec![1, 2]);
    }

    #[test]
    fn validate_accepts_sorted() {
        assert!(tiny().validate().is_ok());
    }

    #[test]
    fn validate_rejects_unsorted() {
        let db = Transactions {
            n_items: 4,
            items: vec![vec![1, 0]],
        };
        assert!(db.validate().is_err());
    }

    #[test]
    fn validate_rejects_out_of_range() {
        let db = Transactions {
            n_items: 2,
            items: vec![vec![0, 5]],
        };
        assert!(db.validate().is_err());
    }
}
