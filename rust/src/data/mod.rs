//! Datasets: containers, parsers, and seeded synthetic generators.
//!
//! Four database kinds, each implementing the open
//! [`crate::mining::PatternSubstrate`] trait next to its container:
//! * **transaction databases** ([`Transactions`]) for item-set mining —
//!   each record is a set of item ids (the paper's first substrate);
//! * **graph databases** ([`graph::GraphDatabase`]) for subgraph mining —
//!   each record is a labeled undirected graph (the paper's second);
//! * **sequence databases** ([`sequence::Sequences`]) for subsequence
//!   mining — each record is an ordered symbol stream (an extension
//!   proving the substrate API is open);
//! * **numeric tabular databases** ([`tabular::TabularData`]) for
//!   RuleFit-style threshold-rule mining — each record is a dense row
//!   of real-valued features (Kato et al.'s Safe RuleFit setting).
//!
//! The paper's benchmark datasets (CPDB, Mutagenicity, Bergstrom,
//! Karthikeyan from cheminformatics.org; splice/a9a/dna/protein from the
//! LIBSVM site) are not reachable from this offline environment, so
//! [`registry`] exposes *seeded synthetic stand-ins* with matched scale
//! and planted predictive structure (DESIGN.md §2).  The [`libsvm`] and
//! [`graph`] parsers accept the real files unchanged if supplied.

pub mod graph;
pub mod libsvm;
pub mod registry;
pub mod sequence;
pub mod synth_graphs;
pub mod synth_itemsets;
pub mod tabular;

use crate::mining::itemset::ItemsetMiner;
use crate::mining::{Pattern, PatternSubstrate, TreeVisitor};

/// A transaction database: each record is a sorted set of item ids in
/// `[0, n_items)`.  Pattern `t` (an item-set) matches record `i` iff
/// `t ⊆ items[i]`; the binary feature is `x_it = I(t ⊆ items[i])`.
#[derive(Clone, Debug, Default)]
pub struct Transactions {
    pub n_items: usize,
    pub items: Vec<Vec<u32>>,
}

impl Transactions {
    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Per-item transaction-id lists (the eclat vertical layout the
    /// item-set miner runs on).  `tidlists()[j]` is sorted ascending.
    pub fn tidlists(&self) -> Vec<Vec<u32>> {
        let mut tids = vec![Vec::new(); self.n_items];
        for (i, t) in self.items.iter().enumerate() {
            for &j in t {
                tids[j as usize].push(i as u32);
            }
        }
        tids
    }

    /// Validate invariants: items sorted, strictly increasing, in range.
    pub fn validate(&self) -> crate::Result<()> {
        for (i, t) in self.items.iter().enumerate() {
            if !t.windows(2).all(|w| w[0] < w[1]) {
                anyhow::bail!("transaction {i} items not strictly sorted");
            }
            if let Some(&max) = t.last() {
                if max as usize >= self.n_items {
                    anyhow::bail!("transaction {i} item {max} out of range");
                }
            }
        }
        Ok(())
    }
}

impl PatternSubstrate for Transactions {
    type Record = [u32];

    fn n_records(&self) -> usize {
        self.items.len()
    }

    fn traverse(&self, maxpat: usize, minsup: usize, visitor: &mut dyn TreeVisitor) {
        let mut m = ItemsetMiner::new(self, maxpat);
        m.minsup = minsup;
        m.traverse(visitor);
    }

    fn traverse_parallel<F: crate::mining::SubtreeVisitors>(
        &self,
        maxpat: usize,
        minsup: usize,
        threads: usize,
        factory: &F,
    ) -> Vec<F::V> {
        let mut m = ItemsetMiner::new(self, maxpat);
        m.minsup = minsup;
        m.traverse_par(threads, factory)
    }

    fn matches(pattern: &Pattern, record: &[u32]) -> bool {
        match pattern {
            Pattern::Itemset(items) => synth_itemsets::contains_all(record, items),
            _ => false,
        }
    }

    fn record(&self, i: usize) -> &[u32] {
        &self.items[i]
    }

    fn select(&self, indices: &[usize]) -> Self {
        Transactions {
            n_items: self.n_items,
            items: indices.iter().map(|&i| self.items[i].clone()).collect(),
        }
    }

    fn parse_pattern(body: &str) -> crate::Result<Pattern> {
        let items = body
            .split(',')
            .map(|t| t.parse::<u32>())
            .collect::<Result<Vec<_>, _>>()?;
        Ok(Pattern::Itemset(items))
    }

    fn format_pattern(pattern: &Pattern) -> String {
        match pattern {
            Pattern::Itemset(items) => items
                .iter()
                .map(|i| i.to_string())
                .collect::<Vec<_>>()
                .join(","),
            other => unreachable!("item-set codec asked to format {other:?}"),
        }
    }

    const KIND_TAG: &'static str = "I";
}

impl crate::storage::ShardCodec for Transactions {
    /// Eclat never touches records directly — only the depth-1
    /// vertical layout — so the sharded traversal below streams shards
    /// instead of materializing the record union.
    const STREAMS: bool = true;

    /// Text shard blob: `items <n_items>` header, then one
    /// space-separated row of ascending item ids per record (an empty
    /// line is an empty transaction).
    fn encode_shard(&self) -> Vec<u8> {
        let mut out = format!("items {}\n", self.n_items);
        for row in &self.items {
            let mut first = true;
            for &j in row {
                if !first {
                    out.push(' ');
                }
                out.push_str(&j.to_string());
                first = false;
            }
            out.push('\n');
        }
        out.into_bytes()
    }

    fn decode_shard(bytes: &[u8]) -> crate::Result<Self> {
        let text = std::str::from_utf8(bytes)
            .map_err(|e| anyhow::anyhow!("itemset shard is not UTF-8: {e}"))?;
        let mut lines = text.lines();
        let header = lines.next().unwrap_or_default();
        let n_items = header
            .strip_prefix("items ")
            .and_then(|v| v.parse::<usize>().ok())
            .ok_or_else(|| anyhow::anyhow!("itemset shard header '{header}' malformed"))?;
        let items = lines
            .map(|line| {
                line.split_whitespace()
                    .map(|t| t.parse::<u32>())
                    .collect::<Result<Vec<u32>, _>>()
            })
            .collect::<Result<Vec<Vec<u32>>, _>>()?;
        let db = Transactions { n_items, items };
        db.validate()?;
        Ok(db)
    }

    fn concat(parts: Vec<Self>) -> crate::Result<Self> {
        let n_items = parts.iter().map(|p| p.n_items).max().unwrap_or(0);
        let items = parts.into_iter().flat_map(|p| p.items).collect();
        Ok(Transactions { n_items, items })
    }

    fn traverse_sharded(
        db: &crate::storage::ShardedDb<Self>,
        maxpat: usize,
        minsup: usize,
        visitor: &mut dyn TreeVisitor,
    ) {
        let mut m = ItemsetMiner::from_tidlists(sharded_tidlists(db, minsup, 1), maxpat);
        m.minsup = minsup;
        m.traverse(visitor);
    }

    fn traverse_sharded_parallel<F: crate::mining::SubtreeVisitors>(
        db: &crate::storage::ShardedDb<Self>,
        maxpat: usize,
        minsup: usize,
        threads: usize,
        factory: &F,
    ) -> Vec<F::V> {
        let mut m = ItemsetMiner::from_tidlists(sharded_tidlists(db, minsup, threads), maxpat);
        m.minsup = minsup;
        m.traverse_par(threads, factory)
    }
}

/// The streamed vertical build: two passes over the shards, each
/// decoding one shard per pool task, reduced **in shard order**.
///
/// * pass 1 — per-shard item counts, summed in shard order, keep items
///   with global support `>= minsup`;
/// * pass 2 — per-shard tid-lists for the kept items only, with global
///   ids (`shard_base + local`), concatenated in shard order.
///
/// Shard bases ascend, so the concatenation of ascending local lists is
/// the ascending global tid-list — exactly what
/// [`Transactions::tidlists`] followed by the minsup filter produces on
/// the union (`root_candidates` applies that same filter), hence the
/// sharded traversal is bit-identical to the in-memory one at any
/// thread count.  Peak residency: one decoded shard per worker plus the
/// minsup-filtered vertical layout (never the full record set).
fn sharded_tidlists(
    db: &crate::storage::ShardedDb<Transactions>,
    minsup: usize,
    threads: usize,
) -> Vec<(u32, Vec<u32>)> {
    if let Some(mem) = db.as_mem() {
        return mem
            .tidlists()
            .into_iter()
            .enumerate()
            .filter(|(_, t)| t.len() >= minsup)
            .map(|(j, t)| (j as u32, t))
            .collect();
    }
    let k = db.n_shards();
    let decode = |s: usize| {
        db.shard(s)
            .unwrap_or_else(|e| panic!("decoding itemset shard {s}: {e}"))
    };
    let per_shard: Vec<Vec<u32>> = crate::runtime::parallel::map_indexed(threads, k, |s| {
        let sh = decode(s);
        let mut counts = vec![0u32; sh.n_items];
        for row in &sh.items {
            for &j in row {
                counts[j as usize] += 1;
            }
        }
        counts
    });
    let n_items = per_shard.iter().map(|c| c.len()).max().unwrap_or(0);
    let mut counts = vec![0u64; n_items];
    for c in &per_shard {
        for (j, &v) in c.iter().enumerate() {
            counts[j] += v as u64;
        }
    }
    let kept: Vec<u32> = (0..n_items)
        .filter(|&j| counts[j] as usize >= minsup)
        .map(|j| j as u32)
        .collect();
    let mut slot = vec![usize::MAX; n_items];
    for (sl, &j) in kept.iter().enumerate() {
        slot[j as usize] = sl;
    }
    let locals: Vec<Vec<Vec<u32>>> = crate::runtime::parallel::map_indexed(threads, k, |s| {
        let sh = decode(s);
        let base = db.shard_base(s) as u32;
        let mut lists = vec![Vec::new(); kept.len()];
        for (li, row) in sh.items.iter().enumerate() {
            for &j in row {
                let sl = slot[j as usize];
                if sl != usize::MAX {
                    lists[sl].push(base + li as u32);
                }
            }
        }
        lists
    });
    let mut out: Vec<(u32, Vec<u32>)> = kept
        .iter()
        .map(|&j| (j, Vec::with_capacity(counts[j as usize] as usize)))
        .collect();
    for shard_lists in locals {
        for (sl, mut list) in shard_lists.into_iter().enumerate() {
            out[sl].1.append(&mut list);
        }
    }
    out
}

/// A supervised dataset over either database kind.
#[derive(Clone, Debug)]
pub struct LabeledTransactions {
    pub db: Transactions,
    /// Regression targets, or ±1 class labels.
    pub y: Vec<f64>,
}

impl LabeledTransactions {
    pub fn to_transactions(&self) -> Transactions {
        self.db.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Transactions {
        Transactions {
            n_items: 4,
            items: vec![vec![0, 1], vec![1, 2, 3], vec![0, 3], vec![]],
        }
    }

    #[test]
    fn tidlists_invert_rows() {
        let db = tiny();
        let tids = db.tidlists();
        assert_eq!(tids[0], vec![0, 2]);
        assert_eq!(tids[1], vec![0, 1]);
        assert_eq!(tids[2], vec![1]);
        assert_eq!(tids[3], vec![1, 2]);
    }

    #[test]
    fn validate_accepts_sorted() {
        assert!(tiny().validate().is_ok());
    }

    #[test]
    fn validate_rejects_unsorted() {
        let db = Transactions {
            n_items: 4,
            items: vec![vec![1, 0]],
        };
        assert!(db.validate().is_err());
    }

    #[test]
    fn validate_rejects_out_of_range() {
        let db = Transactions {
            n_items: 2,
            items: vec![vec![0, 5]],
        };
        assert!(db.validate().is_err());
    }

    #[test]
    fn substrate_impl_matches_and_selects() {
        let db = tiny();
        assert_eq!(db.n_records(), 4);
        assert_eq!(db.record(1), &[1u32, 2, 3][..]);
        let p = Pattern::Itemset(vec![1, 3]);
        assert!(Transactions::matches(&p, db.record(1)));
        assert!(!Transactions::matches(&p, db.record(0)));
        // foreign kinds never match
        assert!(!Transactions::matches(&Pattern::Sequence(vec![0]), db.record(0)));
        let sub = db.select(&[2, 0]);
        assert_eq!(sub.n_items, 4);
        assert_eq!(sub.items, vec![vec![0, 3], vec![0, 1]]);
        // traversal through the trait sees the same tree as the miner
        let mut count = 0usize;
        let mut v = |_: &crate::mining::PatternNode<'_>| {
            count += 1;
            crate::mining::Walk::Descend
        };
        PatternSubstrate::traverse(&db, 2, 1, &mut v);
        assert!(count > 0);
    }
}
