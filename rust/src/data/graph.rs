//! Labeled undirected graphs and the `.gsp` exchange format.
//!
//! The graph container is the substrate under the gSpan miner: vertices
//! and edges carry small integer labels (atom / bond types in the
//! chemistry datasets).  Graphs are simple (no self-loops, no parallel
//! edges) — matching the gSpan paper's setting.  [`GraphDatabase`]'s
//! [`PatternSubstrate`] impl (miner = gSpan, matcher =
//! [`contains_subgraph`]) lives at the bottom of this module.

use std::fmt;

use crate::mining::gspan::{code_to_labeled_graph, DfsEdge, GSpanMiner};
use crate::mining::{Pattern, PatternSubstrate, TreeVisitor};

/// One labeled undirected graph.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Graph {
    /// Vertex labels, indexed by vertex id.
    pub vlabels: Vec<u32>,
    /// Edges as `(u, v, elabel)` with `u < v`, no duplicates.
    pub edges: Vec<(u32, u32, u32)>,
}

impl Graph {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn n_vertices(&self) -> usize {
        self.vlabels.len()
    }

    pub fn n_edges(&self) -> usize {
        self.edges.len()
    }

    pub fn add_vertex(&mut self, label: u32) -> u32 {
        self.vlabels.push(label);
        (self.vlabels.len() - 1) as u32
    }

    /// Add an undirected edge; ignores self-loops and duplicates.
    pub fn add_edge(&mut self, u: u32, v: u32, elabel: u32) -> bool {
        if u == v {
            return false;
        }
        let (a, b) = if u < v { (u, v) } else { (v, u) };
        if self.edges.iter().any(|&(x, y, _)| x == a && y == b) {
            return false;
        }
        self.edges.push((a, b, elabel));
        true
    }

    pub fn has_edge(&self, u: u32, v: u32) -> bool {
        let (a, b) = if u < v { (u, v) } else { (v, u) };
        self.edges.iter().any(|&(x, y, _)| x == a && y == b)
    }

    /// Adjacency lists: `adj()[v]` = `(neighbor, elabel)` pairs.
    pub fn adjacency(&self) -> Vec<Vec<(u32, u32)>> {
        let mut adj = vec![Vec::new(); self.n_vertices()];
        for &(u, v, l) in &self.edges {
            adj[u as usize].push((v, l));
            adj[v as usize].push((u, l));
        }
        adj
    }

    /// Is the graph connected? (Empty graph counts as connected.)
    pub fn is_connected(&self) -> bool {
        if self.n_vertices() <= 1 {
            return true;
        }
        let adj = self.adjacency();
        let mut seen = vec![false; self.n_vertices()];
        let mut stack = vec![0u32];
        seen[0] = true;
        let mut count = 1;
        while let Some(v) = stack.pop() {
            for &(w, _) in &adj[v as usize] {
                if !seen[w as usize] {
                    seen[w as usize] = true;
                    count += 1;
                    stack.push(w);
                }
            }
        }
        count == self.n_vertices()
    }

    pub fn degree(&self, v: u32) -> usize {
        self.edges
            .iter()
            .filter(|&&(a, b, _)| a == v || b == v)
            .count()
    }
}

impl fmt::Display for Graph {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "G(v={}, e={})", self.n_vertices(), self.n_edges())
    }
}

/// A database of labeled graphs with optional targets.
#[derive(Clone, Debug, Default)]
pub struct GraphDatabase {
    pub graphs: Vec<Graph>,
    pub y: Vec<f64>,
}

impl GraphDatabase {
    pub fn len(&self) -> usize {
        self.graphs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.graphs.is_empty()
    }
}

/// Parse the standard gSpan `.gsp` text format:
///
/// ```text
/// t # 0 <y>
/// v 0 <vlabel>
/// v 1 <vlabel>
/// e 0 1 <elabel>
/// t # 1 <y>
/// ...
/// ```
///
/// The trailing `<y>` on the `t` line is this crate's extension for
/// supervised targets; absent targets default to 0.
pub fn parse_gsp(text: &str) -> crate::Result<GraphDatabase> {
    let mut db = GraphDatabase::default();
    let mut cur: Option<Graph> = None;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let toks: Vec<&str> = line.split_whitespace().collect();
        match toks[0] {
            "t" => {
                if let Some(g) = cur.take() {
                    db.graphs.push(g);
                }
                // "t # <id> [y]"
                let y = toks
                    .get(3)
                    .map(|s| s.parse::<f64>())
                    .transpose()
                    .map_err(|e| anyhow::anyhow!("line {}: bad target: {e}", lineno + 1))?
                    .unwrap_or(0.0);
                db.y.push(y);
                cur = Some(Graph::new());
            }
            "v" => {
                let g = cur
                    .as_mut()
                    .ok_or_else(|| anyhow::anyhow!("line {}: v before t", lineno + 1))?;
                let id: u32 = toks[1].parse()?;
                let label: u32 = toks[2].parse()?;
                if id as usize != g.n_vertices() {
                    anyhow::bail!("line {}: non-sequential vertex id", lineno + 1);
                }
                g.add_vertex(label);
            }
            "e" => {
                let g = cur
                    .as_mut()
                    .ok_or_else(|| anyhow::anyhow!("line {}: e before t", lineno + 1))?;
                let u: u32 = toks[1].parse()?;
                let v: u32 = toks[2].parse()?;
                let l: u32 = toks[3].parse()?;
                if u as usize >= g.n_vertices() || v as usize >= g.n_vertices() {
                    anyhow::bail!("line {}: edge endpoint out of range", lineno + 1);
                }
                g.add_edge(u, v, l);
            }
            other => anyhow::bail!("line {}: unknown record '{other}'", lineno + 1),
        }
    }
    if let Some(g) = cur.take() {
        db.graphs.push(g);
    }
    Ok(db)
}

/// Label-respecting subgraph-isomorphism test: is `pattern` (connected,
/// small) contained in `g`?  Plain backtracking over vertex mappings
/// with degree/label pruning — exponential in |pattern| only, which
/// maxpat bounds.
pub fn contains_subgraph(g: &Graph, pattern: &Graph) -> bool {
    if pattern.n_vertices() == 0 {
        return true;
    }
    if pattern.n_vertices() > g.n_vertices() || pattern.n_edges() > g.n_edges() {
        return false;
    }
    let g_adj = g.adjacency();
    let p_adj = pattern.adjacency();
    let mut mapping = vec![u32::MAX; pattern.n_vertices()]; // pattern v -> g v
    let mut used = vec![false; g.n_vertices()];

    // match pattern vertices in a connectivity-respecting order
    let order = connectivity_order(pattern, &p_adj);
    backtrack(g, pattern, &g_adj, &p_adj, &order, 0, &mut mapping, &mut used)
}

fn connectivity_order(pattern: &Graph, adj: &[Vec<(u32, u32)>]) -> Vec<u32> {
    let mut order = vec![0u32];
    let mut seen = vec![false; pattern.n_vertices()];
    seen[0] = true;
    while order.len() < pattern.n_vertices() {
        let mut next = None;
        'outer: for &v in &order {
            for &(w, _) in &adj[v as usize] {
                if !seen[w as usize] {
                    next = Some(w);
                    break 'outer;
                }
            }
        }
        let v = next.expect("pattern must be connected");
        seen[v as usize] = true;
        order.push(v);
    }
    order
}

#[allow(clippy::too_many_arguments)]
fn backtrack(
    g: &Graph,
    pattern: &Graph,
    g_adj: &[Vec<(u32, u32)>],
    p_adj: &[Vec<(u32, u32)>],
    order: &[u32],
    depth: usize,
    mapping: &mut [u32],
    used: &mut [bool],
) -> bool {
    if depth == order.len() {
        return true;
    }
    let pv = order[depth] as usize;
    // candidates: all g vertices with the right label whose edges to
    // already-mapped pattern neighbors exist with matching labels
    'cand: for gv in 0..g.n_vertices() {
        if used[gv] || g.vlabels[gv] != pattern.vlabels[pv] {
            continue;
        }
        for &(pw, el) in &p_adj[pv] {
            let mapped = mapping[pw as usize];
            if mapped != u32::MAX {
                let ok = g_adj[gv]
                    .iter()
                    .any(|&(gn, gel)| gn == mapped && gel == el);
                if !ok {
                    continue 'cand;
                }
            }
        }
        mapping[pv] = gv as u32;
        used[gv] = true;
        if backtrack(g, pattern, g_adj, p_adj, order, depth + 1, mapping, used) {
            return true;
        }
        mapping[pv] = u32::MAX;
        used[gv] = false;
    }
    false
}

impl PatternSubstrate for GraphDatabase {
    type Record = Graph;

    fn n_records(&self) -> usize {
        self.graphs.len()
    }

    fn traverse(&self, maxpat: usize, minsup: usize, visitor: &mut dyn TreeVisitor) {
        let mut m = GSpanMiner::new(self, maxpat);
        m.minsup = minsup;
        m.traverse(visitor);
    }

    fn traverse_parallel<F: crate::mining::SubtreeVisitors>(
        &self,
        maxpat: usize,
        minsup: usize,
        threads: usize,
        factory: &F,
    ) -> Vec<F::V> {
        let mut m = GSpanMiner::new(self, maxpat);
        m.minsup = minsup;
        m.traverse_par(threads, factory)
    }

    fn matches(pattern: &Pattern, record: &Graph) -> bool {
        match pattern {
            Pattern::Subgraph(code) => contains_subgraph(record, &code_to_labeled_graph(code)),
            _ => false,
        }
    }

    fn record(&self, i: usize) -> &Graph {
        &self.graphs[i]
    }

    fn select(&self, indices: &[usize]) -> Self {
        // y.len() == graphs.len() is a database invariant; index
        // directly so a violation surfaces instead of fabricating 0.0
        // labels.
        GraphDatabase {
            graphs: indices.iter().map(|&i| self.graphs[i].clone()).collect(),
            y: indices.iter().map(|&i| self.y[i]).collect(),
        }
    }

    fn parse_pattern(body: &str) -> crate::Result<Pattern> {
        let code: Vec<DfsEdge> = body
            .split(',')
            .map(|t| -> crate::Result<DfsEdge> {
                let p: Vec<&str> = t.split(':').collect();
                anyhow::ensure!(p.len() == 5, "bad edge '{t}'");
                Ok(DfsEdge {
                    from: p[0].parse()?,
                    to: p[1].parse()?,
                    from_label: p[2].parse()?,
                    elabel: p[3].parse()?,
                    to_label: p[4].parse()?,
                })
            })
            .collect::<crate::Result<Vec<_>>>()?;
        // Validate what the matcher assumes, so a corrupted model file
        // errors here instead of panicking (or allocating absurdly) at
        // predict time.  A k-edge DFS code names vertices 0..=k, every
        // vertex label must be determined by some edge, and the encoded
        // pattern graph must be connected.
        for e in &code {
            anyhow::ensure!(
                (e.from as usize) <= code.len() && (e.to as usize) <= code.len(),
                "bad DFS code: vertex id {} out of range for {} edges",
                e.from.max(e.to),
                code.len()
            );
        }
        let n_vertices = code
            .iter()
            .map(|e| e.from.max(e.to) as usize + 1)
            .max()
            .unwrap_or(0);
        let mut labeled = vec![false; n_vertices];
        for e in &code {
            if e.from_label >= 0 {
                labeled[e.from as usize] = true;
            }
            if e.to_label >= 0 {
                labeled[e.to as usize] = true;
            }
        }
        anyhow::ensure!(
            labeled.iter().all(|&k| k),
            "bad DFS code: undetermined vertex label"
        );
        anyhow::ensure!(
            code_to_labeled_graph(&code).is_connected(),
            "bad DFS code: pattern graph not connected"
        );
        Ok(Pattern::Subgraph(code))
    }

    fn format_pattern(pattern: &Pattern) -> String {
        match pattern {
            Pattern::Subgraph(code) => code
                .iter()
                .map(|e| {
                    format!(
                        "{}:{}:{}:{}:{}",
                        e.from, e.to, e.from_label, e.elabel, e.to_label
                    )
                })
                .collect::<Vec<_>>()
                .join(","),
            other => unreachable!("subgraph codec asked to format {other:?}"),
        }
    }

    const KIND_TAG: &'static str = "G";
}

/// Serialize to the `.gsp` format accepted by [`parse_gsp`].
pub fn to_gsp(db: &GraphDatabase) -> String {
    let mut out = String::new();
    for (i, g) in db.graphs.iter().enumerate() {
        out.push_str(&format!("t # {} {}\n", i, db.y.get(i).copied().unwrap_or(0.0)));
        for (v, &l) in g.vlabels.iter().enumerate() {
            out.push_str(&format!("v {v} {l}\n"));
        }
        for &(u, v, l) in &g.edges {
            out.push_str(&format!("e {u} {v} {l}\n"));
        }
    }
    out
}

impl crate::storage::ShardCodec for GraphDatabase {
    // gSpan grows DFS codes against the graphs themselves, so a
    // sharded graph database materializes its union for traversal
    // (`STREAMS` stays false).  The shard blob is the `.gsp` text
    // format — the same codec `parse_gsp`/`to_gsp` round-trip, targets
    // included (graph databases carry `y` inline).

    fn encode_shard(&self) -> Vec<u8> {
        to_gsp(self).into_bytes()
    }

    fn decode_shard(bytes: &[u8]) -> crate::Result<Self> {
        let text = std::str::from_utf8(bytes)
            .map_err(|e| anyhow::anyhow!("graph shard is not UTF-8: {e}"))?;
        parse_gsp(text)
    }

    fn concat(parts: Vec<Self>) -> crate::Result<Self> {
        let mut db = GraphDatabase::default();
        for mut p in parts {
            db.graphs.append(&mut p.graphs);
            db.y.append(&mut p.y);
        }
        Ok(db)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle() -> Graph {
        let mut g = Graph::new();
        let a = g.add_vertex(0);
        let b = g.add_vertex(1);
        let c = g.add_vertex(2);
        g.add_edge(a, b, 0);
        g.add_edge(b, c, 1);
        g.add_edge(a, c, 2);
        g
    }

    #[test]
    fn add_edge_rejects_self_loops_and_dups() {
        let mut g = triangle();
        assert!(!g.add_edge(0, 0, 5));
        assert!(!g.add_edge(1, 0, 5)); // duplicate (0,1)
        assert_eq!(g.n_edges(), 3);
    }

    #[test]
    fn adjacency_is_symmetric() {
        let g = triangle();
        let adj = g.adjacency();
        assert_eq!(adj[0].len(), 2);
        assert_eq!(adj[1].len(), 2);
        assert_eq!(adj[2].len(), 2);
    }

    #[test]
    fn connectivity() {
        assert!(triangle().is_connected());
        let mut g = Graph::new();
        g.add_vertex(0);
        g.add_vertex(0);
        assert!(!g.is_connected());
        g.add_edge(0, 1, 0);
        assert!(g.is_connected());
    }

    #[test]
    fn gsp_round_trip() {
        let mut db = GraphDatabase::default();
        db.graphs.push(triangle());
        db.y.push(1.0);
        let mut g2 = Graph::new();
        g2.add_vertex(3);
        g2.add_vertex(4);
        g2.add_edge(0, 1, 7);
        db.graphs.push(g2);
        db.y.push(-1.0);

        let text = to_gsp(&db);
        let back = parse_gsp(&text).unwrap();
        assert_eq!(back.graphs, db.graphs);
        assert_eq!(back.y, db.y);
    }

    #[test]
    fn gsp_rejects_bad_edges() {
        assert!(parse_gsp("t # 0 0\nv 0 1\ne 0 5 0\n").is_err());
        assert!(parse_gsp("v 0 1\n").is_err());
    }

    #[test]
    fn substrate_impl_matches_and_selects() {
        let mut db = GraphDatabase::default();
        db.graphs.push(triangle());
        db.y.push(1.0);
        let mut g2 = Graph::new();
        g2.add_vertex(7);
        db.graphs.push(g2);
        db.y.push(-1.0);

        assert_eq!(db.n_records(), 2);
        let edge = Pattern::Subgraph(vec![DfsEdge {
            from: 0,
            to: 1,
            from_label: 0,
            elabel: 0,
            to_label: 1,
        }]);
        assert!(GraphDatabase::matches(&edge, db.record(0)));
        assert!(!GraphDatabase::matches(&edge, db.record(1)));
        assert!(!GraphDatabase::matches(&Pattern::Itemset(vec![0]), db.record(0)));

        let sub = db.select(&[1]);
        assert_eq!(sub.graphs.len(), 1);
        assert_eq!(sub.y, vec![-1.0]);

        let mut count = 0usize;
        let mut v = |_: &crate::mining::PatternNode<'_>| {
            count += 1;
            crate::mining::Walk::Descend
        };
        PatternSubstrate::traverse(&db, 2, 1, &mut v);
        assert!(count > 0);
    }
}
