//! Seeded synthetic molecule-like graphs with planted subgraph motifs.
//!
//! Stand-in for CPDB / Mutagenicity / Bergstrom / Karthikeyan
//! (cheminformatics.org is unreachable; DESIGN.md §2).  What matters for
//! reproducing the paper's *relative* SPP-vs-boosting behaviour is the
//! shape of the subgraph enumeration tree and the correlation between
//! pattern supports and targets, so the generator mimics small organic
//! molecules:
//!
//! * atom labels with chemistry-like marginals (C dominant), max degree 4,
//! * random backbone tree + a few ring-closing edges,
//! * bond labels (single/double/aromatic-ish),
//! * **planted motifs**: small connected subgraphs spliced into a random
//!   subset of molecules; targets are a sparse linear function of motif
//!   occurrences plus noise — exactly the signal class the paper's model
//!   (eq. 1) is built to recover.

use super::graph::{Graph, GraphDatabase};
use crate::testutil::SplitMix64;

/// A planted motif with its regression weight.
#[derive(Clone, Debug)]
pub struct PlantedMotif {
    pub graph: Graph,
    pub weight: f64,
}

#[derive(Clone, Debug)]
pub struct GraphSynthConfig {
    pub seed: u64,
    pub n: usize,
    /// Vertex count range per molecule.
    pub min_atoms: usize,
    pub max_atoms: usize,
    /// Number of distinct vertex labels (atom types).
    pub n_vlabels: usize,
    /// Number of distinct edge labels (bond types).
    pub n_elabels: usize,
    /// Probability of adding each potential ring-closure edge.
    pub ring_prob: f64,
    /// Number of planted motifs.
    pub n_motifs: usize,
    /// Motif edge counts in `[2, max_motif_edges]`.
    pub max_motif_edges: usize,
    /// Probability a molecule receives a motif splice.
    pub implant_prob: f64,
    pub noise: f64,
    pub classify: bool,
}

impl GraphSynthConfig {
    fn base(seed: u64, n: usize, classify: bool) -> Self {
        Self {
            seed,
            n,
            min_atoms: 8,
            max_atoms: 28,
            n_vlabels: 6,
            n_elabels: 3,
            ring_prob: 0.12,
            n_motifs: 6,
            max_motif_edges: 4,
            implant_prob: 0.4,
            noise: 0.5,
            classify,
        }
    }

    /// CPDB-scale classification: n = 648.
    pub fn preset_cpdb(seed: u64) -> Self {
        Self::base(seed, 648, true)
    }

    /// Mutagenicity-scale classification: n = 4337.
    pub fn preset_mutagenicity(seed: u64) -> Self {
        Self::base(seed, 4337, true)
    }

    /// Bergstrom-scale regression (melting point): n = 185.
    pub fn preset_bergstrom(seed: u64) -> Self {
        Self::base(seed, 185, false)
    }

    /// Karthikeyan-scale regression: n = 4173.
    pub fn preset_karthikeyan(seed: u64) -> Self {
        Self::base(seed, 4173, false)
    }

    /// Small config for tests.
    pub fn tiny(seed: u64, classify: bool) -> Self {
        let mut c = Self::base(seed, 40, classify);
        c.min_atoms = 4;
        c.max_atoms = 10;
        c.n_motifs = 3;
        c.max_motif_edges = 3;
        c
    }

    pub fn scaled(mut self, f: f64) -> Self {
        self.n = ((self.n as f64 * f).round() as usize).max(8);
        self
    }
}

#[derive(Clone, Debug)]
pub struct SynthGraphs {
    pub db: GraphDatabase,
    pub motifs: Vec<PlantedMotif>,
}

/// Chemistry-like atom-label weights (label 0 = "carbon" dominates).
fn vlabel_weights(n_vlabels: usize) -> Vec<f64> {
    (0..n_vlabels)
        .map(|i| match i {
            0 => 0.62,
            1 => 0.12,
            2 => 0.10,
            3 => 0.08,
            _ => 0.08 / (n_vlabels - 4).max(1) as f64,
        })
        .collect()
}

fn elabel_weights(n_elabels: usize) -> Vec<f64> {
    (0..n_elabels)
        .map(|i| match i {
            0 => 0.78,
            1 => 0.15,
            _ => 0.07 / (n_elabels - 2).max(1) as f64,
        })
        .collect()
}

/// Random connected molecule-like graph (backbone tree + ring closures,
/// degree capped at 4).
fn random_molecule(rng: &mut SplitMix64, cfg: &GraphSynthConfig) -> Graph {
    let n_atoms = rng.range(cfg.min_atoms, cfg.max_atoms);
    let vw = vlabel_weights(cfg.n_vlabels);
    let ew = elabel_weights(cfg.n_elabels);
    let mut g = Graph::new();
    for _ in 0..n_atoms {
        let l = rng.weighted(&vw) as u32;
        g.add_vertex(l);
    }
    let mut degree = vec![0usize; n_atoms];
    // Backbone: attach each new vertex to a previous one with capacity.
    for v in 1..n_atoms {
        // prefer low-degree attachment (chains over stars)
        let mut cand: Vec<usize> = (0..v).filter(|&u| degree[u] < 4).collect();
        if cand.is_empty() {
            cand = (0..v).collect();
        }
        let weights: Vec<f64> = cand.iter().map(|&u| 1.0 / (1.0 + degree[u] as f64)).collect();
        let u = cand[rng.weighted(&weights)];
        let l = rng.weighted(&ew) as u32;
        g.add_edge(u as u32, v as u32, l);
        degree[u] += 1;
        degree[v] += 1;
    }
    // Ring closures.
    let n_closures = ((n_atoms as f64) * cfg.ring_prob).round() as usize;
    for _ in 0..n_closures {
        let u = rng.below(n_atoms);
        let v = rng.below(n_atoms);
        if u != v && degree[u] < 4 && degree[v] < 4 && !g.has_edge(u as u32, v as u32) {
            let l = rng.weighted(&ew) as u32;
            if g.add_edge(u as u32, v as u32, l) {
                degree[u] += 1;
                degree[v] += 1;
            }
        }
    }
    g
}

/// Random small connected motif (path/branch/triangle shaped).
fn random_motif(rng: &mut SplitMix64, cfg: &GraphSynthConfig) -> Graph {
    let n_edges = rng.range(2, cfg.max_motif_edges.max(2));
    let vw = vlabel_weights(cfg.n_vlabels);
    let ew = elabel_weights(cfg.n_elabels);
    let mut g = Graph::new();
    g.add_vertex(rng.weighted(&vw) as u32);
    while g.n_edges() < n_edges {
        // mostly grow (tree edge), sometimes close a cycle
        if g.n_vertices() >= 3 && rng.coin(0.25) {
            let u = rng.below(g.n_vertices()) as u32;
            let v = rng.below(g.n_vertices()) as u32;
            if u != v && !g.has_edge(u, v) {
                g.add_edge(u, v, rng.weighted(&ew) as u32);
                continue;
            }
        }
        let u = rng.below(g.n_vertices()) as u32;
        let v = g.add_vertex(rng.weighted(&vw) as u32);
        g.add_edge(u, v, rng.weighted(&ew) as u32);
    }
    g
}

/// Splice `motif` into `g`: add its vertices/edges and connect one motif
/// vertex to one existing vertex (keeps the molecule connected).
fn splice_motif(rng: &mut SplitMix64, g: &mut Graph, motif: &Graph, n_elabels: usize) {
    let offset = g.n_vertices() as u32;
    for &l in &motif.vlabels {
        g.add_vertex(l);
    }
    for &(u, v, l) in &motif.edges {
        g.add_edge(offset + u, offset + v, l);
    }
    if offset > 0 {
        let anchor = rng.below(offset as usize) as u32;
        let port = offset + rng.below(motif.n_vertices()) as u32;
        let ew = elabel_weights(n_elabels);
        g.add_edge(anchor, port, rng.weighted(&ew) as u32);
    }
}

/// Generate a dataset per `cfg`.  Fully deterministic in `cfg.seed`.
pub fn generate(cfg: &GraphSynthConfig) -> SynthGraphs {
    let mut rng = SplitMix64::new(cfg.seed);
    let mut motifs = Vec::with_capacity(cfg.n_motifs);
    for _ in 0..cfg.n_motifs {
        let graph = random_motif(&mut rng, cfg);
        let mag = 1.0 + rng.next_f64() * 2.0;
        let weight = if rng.coin(0.5) { mag } else { -mag };
        motifs.push(PlantedMotif { graph, weight });
    }

    let mut db = GraphDatabase::default();
    for _ in 0..cfg.n {
        let mut g = random_molecule(&mut rng, cfg);
        let mut score = 0.0;
        if rng.coin(cfg.implant_prob) {
            let m = rng.below(motifs.len());
            splice_motif(&mut rng, &mut g, &motifs[m].graph, cfg.n_elabels);
            score += motifs[m].weight;
        }
        // mild dependence on composition so regression targets are not
        // purely motif-driven
        score += 0.05
            * g.vlabels
                .iter()
                .map(|&l| if l == 0 { 1.0 } else { -0.5 })
                .sum::<f64>();
        score += cfg.noise * rng.gauss();
        let y = if cfg.classify {
            if score >= 0.0 {
                1.0
            } else {
                -1.0
            }
        } else {
            score
        };
        db.graphs.push(g);
        db.y.push(y);
    }

    SynthGraphs { db, motifs }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seed() {
        let a = generate(&GraphSynthConfig::tiny(5, true));
        let b = generate(&GraphSynthConfig::tiny(5, true));
        assert_eq!(a.db.graphs, b.db.graphs);
        assert_eq!(a.db.y, b.db.y);
    }

    #[test]
    fn molecules_are_connected_and_degree_capped() {
        let d = generate(&GraphSynthConfig::tiny(6, false));
        for g in &d.db.graphs {
            assert!(g.is_connected(), "disconnected molecule");
            for v in 0..g.n_vertices() as u32 {
                assert!(g.degree(v) <= 5, "degree too high"); // +1 from splice port
            }
        }
    }

    #[test]
    fn motifs_are_connected_small() {
        let d = generate(&GraphSynthConfig::tiny(7, true));
        for m in &d.motifs {
            assert!(m.graph.is_connected());
            assert!((2..=4).contains(&m.graph.n_edges()));
        }
    }

    #[test]
    fn presets_match_paper_scales() {
        assert_eq!(GraphSynthConfig::preset_cpdb(0).n, 648);
        assert_eq!(GraphSynthConfig::preset_mutagenicity(0).n, 4337);
        assert_eq!(GraphSynthConfig::preset_bergstrom(0).n, 185);
        assert_eq!(GraphSynthConfig::preset_karthikeyan(0).n, 4173);
        assert!(GraphSynthConfig::preset_cpdb(0).classify);
        assert!(!GraphSynthConfig::preset_bergstrom(0).classify);
    }

    #[test]
    fn classification_labels_pm1_both_classes() {
        let mut cfg = GraphSynthConfig::tiny(8, true);
        cfg.n = 200;
        let d = generate(&cfg);
        assert!(d.db.y.iter().all(|&v| v == 1.0 || v == -1.0));
        assert!(d.db.y.iter().any(|&v| v == 1.0));
        assert!(d.db.y.iter().any(|&v| v == -1.0));
    }

    #[test]
    fn atom_sizes_in_range() {
        let cfg = GraphSynthConfig::tiny(9, false);
        let d = generate(&cfg);
        for g in &d.db.graphs {
            // splice can add up to max_motif_edges+1 vertices
            assert!(g.n_vertices() >= cfg.min_atoms);
            assert!(g.n_vertices() <= cfg.max_atoms + cfg.max_motif_edges + 1);
        }
    }
}
