//! K-fold cross-validation over the regularization path — the model
//! selection the paper motivates Algorithm 1 with (§3.4.1: "In model
//! selection, a sequence of solutions with various different penalty
//! parameters must be trained").
//!
//! [`cross_validate`] is generic over [`PatternSubstrate`]: folds are
//! split with the substrate's `select`, each fold computes a full
//! warm-started SPP path on its training split, and validation loss is
//! evaluated per λ by scoring held-out records through the substrate's
//! `matches` (via [`crate::model::SparsePatternModel`]).  The λ
//! minimizing the mean validation loss wins.
//!
//! Folds are independent path solves, so they run on the
//! `runtime::parallel` worker pool (`PathConfig::threads`; the
//! substrate is shared read-only, hence the `Sync` bound).  Per-fold
//! results come back in fold order and are reduced in that order, so
//! the summary is bit-identical at any worker count.  Support pools are
//! deliberately per-fold: a support column indexes *training-split*
//! record ids, which differ fold to fold — interning across folds would
//! alias unrelated columns.

use crate::data::graph::GraphDatabase;
use crate::data::Transactions;
use crate::mining::PatternSubstrate;
use crate::model::SparsePatternModel;
use crate::path::{compute_path_spp, PathConfig};
use crate::solver::Task;
use crate::testutil::SplitMix64;

/// Per-λ cross-validation summary.
#[derive(Clone, Debug)]
pub struct CvPoint {
    pub lambda_frac: f64,
    /// Mean validation loss (MSE for regression, error rate for
    /// classification) across folds.
    pub mean_loss: f64,
    pub fold_losses: Vec<f64>,
    pub mean_active: f64,
}

/// Cross-validation result.
#[derive(Clone, Debug)]
pub struct CvResult {
    pub points: Vec<CvPoint>,
    /// Index of the best (lowest mean loss) λ fraction.
    pub best: usize,
}

impl CvResult {
    pub fn best_point(&self) -> &CvPoint {
        &self.points[self.best]
    }
}

/// Shuffled fold assignment: record i -> fold id in `[0, k)`.
pub fn fold_assignment(n: usize, k: usize, seed: u64) -> Vec<usize> {
    assert!(k >= 2 && n >= k);
    let mut idx: Vec<usize> = (0..n).collect();
    SplitMix64::new(seed).shuffle(&mut idx);
    let mut fold = vec![0usize; n];
    for (rank, &i) in idx.iter().enumerate() {
        fold[i] = rank % k;
    }
    fold
}

fn loss(task: Task, pred: f64, y: f64) -> f64 {
    match task {
        Task::Regression => (pred - y) * (pred - y),
        Task::Classification => {
            if (pred >= 0.0) == (y > 0.0) {
                0.0
            } else {
                1.0
            }
        }
    }
}

/// K-fold CV over the SPP path, generic over the pattern substrate.
///
/// λ values are aligned across folds *by grid position* (each fold has
/// its own λ_max, so absolute λ differs; the fraction `λ/λ_max` is the
/// shared coordinate, as is standard for path-based CV).
pub fn cross_validate<S: PatternSubstrate + Sync>(
    db: &S,
    y: &[f64],
    task: Task,
    cfg: &PathConfig,
    k: usize,
    seed: u64,
) -> CvResult {
    let n = db.n_records();
    assert_eq!(n, y.len());
    let folds = fold_assignment(n, k, seed);
    let threads = crate::runtime::parallel::resolve_threads(cfg.threads);
    // When the folds themselves fan out they already saturate the
    // worker budget, so the path solves inside them are pinned to one
    // worker — otherwise each fold would re-resolve `cfg.threads` and
    // the two parallel levels would multiply into k×threads live
    // threads.  Bit-identity makes this a pure scheduling choice.
    let fold_workers = crate::runtime::parallel::effective_workers(threads, k);
    let mut fold_cfg = *cfg;
    fold_cfg.threads = if fold_workers > 1 { 1 } else { threads };
    let fold_cfg = &fold_cfg;

    // one task per fold: full path on the training split, then per-λ
    // validation losses + active counts (reduced in fold order below,
    // so the summary is independent of worker count)
    let per_fold: Vec<(Vec<f64>, Vec<f64>)> =
        crate::runtime::parallel::map_indexed(threads, k, |f| {
            let train_idx: Vec<usize> = (0..n).filter(|&i| folds[i] != f).collect();
            let val_idx: Vec<usize> = (0..n).filter(|&i| folds[i] == f).collect();
            let train = db.select(&train_idx);
            let y_train: Vec<f64> = train_idx.iter().map(|&i| y[i]).collect();
            let path = compute_path_spp(&train, &y_train, task, fold_cfg);
            let mut losses = vec![0.0f64; cfg.n_lambdas];
            let mut active = vec![0.0f64; cfg.n_lambdas];
            for (li, p) in path.points.iter().enumerate() {
                let model = SparsePatternModel::from_path_point(task, p);
                let mut l = 0.0;
                for &i in &val_idx {
                    l += loss(task, model.score::<S>(db.record(i)), y[i]);
                }
                losses[li] = l / val_idx.len().max(1) as f64;
                active[li] = p.active.len() as f64;
            }
            (losses, active)
        });

    let mut fold_losses = vec![vec![0.0f64; k]; cfg.n_lambdas];
    let mut actives = vec![0.0f64; cfg.n_lambdas];
    for (f, (losses, active)) in per_fold.into_iter().enumerate() {
        for li in 0..cfg.n_lambdas {
            fold_losses[li][f] = losses[li];
            actives[li] += active[li] / k as f64;
        }
    }

    finish(cfg, fold_losses, actives)
}

/// K-fold CV for item-set databases (thin wrapper over
/// [`cross_validate`]).
pub fn cross_validate_itemsets(
    db: &Transactions,
    y: &[f64],
    task: Task,
    cfg: &PathConfig,
    k: usize,
    seed: u64,
) -> CvResult {
    cross_validate(db, y, task, cfg, k, seed)
}

/// K-fold CV for graph databases (thin wrapper over
/// [`cross_validate`]; targets come from the database).
pub fn cross_validate_graphs(
    db: &GraphDatabase,
    task: Task,
    cfg: &PathConfig,
    k: usize,
    seed: u64,
) -> CvResult {
    cross_validate(db, &db.y, task, cfg, k, seed)
}

fn finish(cfg: &PathConfig, fold_losses: Vec<Vec<f64>>, actives: Vec<f64>) -> CvResult {
    let mut points = Vec::with_capacity(cfg.n_lambdas);
    for (li, losses) in fold_losses.into_iter().enumerate() {
        let mean = losses.iter().sum::<f64>() / losses.len() as f64;
        points.push(CvPoint {
            lambda_frac: cfg
                .lambda_min_ratio
                .powf(li as f64 / (cfg.n_lambdas - 1) as f64),
            mean_loss: mean,
            fold_losses: losses,
            mean_active: actives[li],
        });
    }
    let best = points
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.mean_loss.partial_cmp(&b.1.mean_loss).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    CvResult { points, best }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_itemsets::{generate, ItemsetSynthConfig};

    #[test]
    fn fold_assignment_is_balanced_and_deterministic() {
        let f1 = fold_assignment(103, 5, 9);
        let f2 = fold_assignment(103, 5, 9);
        assert_eq!(f1, f2);
        let mut counts = vec![0usize; 5];
        for &f in &f1 {
            counts[f] += 1;
        }
        assert!(counts.iter().all(|&c| c == 20 || c == 21), "{counts:?}");
        assert_ne!(fold_assignment(103, 5, 10), f1);
    }

    #[test]
    fn cv_selects_an_interior_lambda_on_planted_data() {
        let mut c = ItemsetSynthConfig::tiny(88, false);
        c.n = 160;
        c.d = 20;
        c.avg_items = 6.0;
        let d = generate(&c);
        let cfg = PathConfig {
            n_lambdas: 10,
            lambda_min_ratio: 0.05,
            maxpat: 2,
            ..PathConfig::default()
        };
        let cv = cross_validate_itemsets(&d.db, &d.y, Task::Regression, &cfg, 4, 1);
        assert_eq!(cv.points.len(), 10);
        // λ_max (index 0) predicts the mean only — it must not win
        assert_ne!(cv.best, 0, "CV picked the intercept-only model");
        // the chosen loss beats the intercept-only loss clearly
        assert!(cv.best_point().mean_loss < 0.9 * cv.points[0].mean_loss);
        // fractions are monotone decreasing from 1.0
        assert!((cv.points[0].lambda_frac - 1.0).abs() < 1e-12);
        for w in cv.points.windows(2) {
            assert!(w[1].lambda_frac < w[0].lambda_frac);
        }
    }

    #[test]
    fn cv_classification_error_rates_are_probabilities() {
        let d = generate(&ItemsetSynthConfig::tiny(89, true));
        let cfg = PathConfig {
            n_lambdas: 5,
            lambda_min_ratio: 0.1,
            maxpat: 2,
            ..PathConfig::default()
        };
        let cv = cross_validate_itemsets(&d.db, &d.y, Task::Classification, &cfg, 3, 2);
        for p in &cv.points {
            assert!((0.0..=1.0).contains(&p.mean_loss));
            assert_eq!(p.fold_losses.len(), 3);
        }
    }

    #[test]
    fn cv_graphs_runs_end_to_end() {
        use crate::data::synth_graphs::{generate as ggen, GraphSynthConfig};
        let mut c = GraphSynthConfig::tiny(90, true);
        c.n = 40;
        let d = ggen(&c);
        let cfg = PathConfig {
            n_lambdas: 4,
            lambda_min_ratio: 0.2,
            maxpat: 2,
            ..PathConfig::default()
        };
        let cv = cross_validate_graphs(&d.db, Task::Classification, &cfg, 4, 3);
        assert_eq!(cv.points.len(), 4);
        assert!(cv.best_point().mean_loss <= cv.points[0].mean_loss + 1e-12);
    }

    #[test]
    fn cv_sequences_runs_end_to_end() {
        use crate::data::sequence::{generate as sgen, SeqSynthConfig};
        let d = sgen(&SeqSynthConfig::tiny(91, false));
        let cfg = PathConfig {
            n_lambdas: 4,
            lambda_min_ratio: 0.2,
            maxpat: 2,
            ..PathConfig::default()
        };
        let cv = cross_validate(&d.db, &d.y, Task::Regression, &cfg, 4, 5);
        assert_eq!(cv.points.len(), 4);
        assert!(cv.best_point().mean_loss <= cv.points[0].mean_loss + 1e-12);
    }
}
