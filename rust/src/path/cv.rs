//! K-fold cross-validation over the regularization path — the model
//! selection the paper motivates Algorithm 1 with (§3.4.1: "In model
//! selection, a sequence of solutions with various different penalty
//! parameters must be trained").
//!
//! [`cross_validate`] is generic over [`PatternSubstrate`]: folds are
//! split with the substrate's `select`, each fold computes a full
//! warm-started SPP path on its training split, and validation loss is
//! evaluated per λ by scoring held-out records through the substrate's
//! `matches` (via [`crate::model::SparsePatternModel`]).  The λ
//! minimizing the mean validation loss wins.
//!
//! Each fold runs the **chunked engine** of
//! [`crate::path::compute_path_spp`]: with `PathConfig::range_chunk = C`
//! the fold's grid is served by one range-based screening mine per
//! chunk of `C` λs (Yoshida et al. 2023; see `screening::range`), so a
//! k-fold CV does `folds × ⌈grid/C⌉` database searches instead of
//! `folds × grid` — the first workload where a single search serves a
//! whole stretch of the grid, per fold.  Chunked and per-λ folds are
//! bit-identical (pinned by `tests/integration_range.rs`), so the best
//! λ and every fold loss are engine-independent.
//!
//! **Fold assignment is stratified for classification**: a plain
//! shuffle can hand an imbalanced dataset a single-class training split
//! (the minority class all lands in one validation fold), which makes
//! that fold's λ_max collapse to 0 — `hinge_intercept` returns ±1 and
//! every slack is 0.  [`fold_assignment_stratified`] shuffles within
//! each class and deals members round-robin, so every fold's training
//! split keeps both classes whenever the minority class has ≥ 2
//! members.  Degenerate folds that still arise (all-constant regression
//! targets, a minority class of size 1) surface as an `Err` naming the
//! fold instead of silently producing an all-zero λ grid.
//!
//! Folds are independent path solves, so they run on the
//! `runtime::parallel` worker pool (`PathConfig::threads`; the
//! substrate is shared read-only, hence the `Sync` bound).  Per-fold
//! results come back in fold order and are reduced in that order, so
//! the summary is bit-identical at any worker count.  Support pools are
//! deliberately per-fold: a support column indexes *training-split*
//! record ids, which differ fold to fold — interning across folds would
//! alias unrelated columns.

use anyhow::Context as _;

use crate::data::graph::GraphDatabase;
use crate::data::Transactions;
use crate::mining::PatternSubstrate;
use crate::model::SparsePatternModel;
use crate::path::{compute_path_spp, PathConfig};
use crate::solver::Task;
use crate::testutil::SplitMix64;

/// Per-λ cross-validation summary.
#[derive(Clone, Debug)]
pub struct CvPoint {
    pub lambda_frac: f64,
    /// Mean validation loss (MSE for regression, error rate for
    /// classification) across folds.
    pub mean_loss: f64,
    pub fold_losses: Vec<f64>,
    pub mean_active: f64,
}

/// Cross-validation result.
#[derive(Clone, Debug)]
pub struct CvResult {
    pub points: Vec<CvPoint>,
    /// Index of the best (lowest mean loss) λ fraction.
    pub best: usize,
}

impl CvResult {
    pub fn best_point(&self) -> &CvPoint {
        &self.points[self.best]
    }
}

/// Shuffled fold assignment: record i -> fold id in `[0, k)`.  Used for
/// regression; classification goes through
/// [`fold_assignment_stratified`].
pub fn fold_assignment(n: usize, k: usize, seed: u64) -> Vec<usize> {
    assert!(k >= 2 && n >= k);
    let mut idx: Vec<usize> = (0..n).collect();
    SplitMix64::new(seed).shuffle(&mut idx);
    let mut fold = vec![0usize; n];
    for (rank, &i) in idx.iter().enumerate() {
        fold[i] = rank % k;
    }
    fold
}

/// Stratified fold assignment for ±1 labels: shuffle each class
/// separately (one seeded stream, classes in a fixed order, so the
/// split is deterministic) and deal its members round-robin across the
/// k folds.  Every fold then holds `⌊c/k⌋` or `⌈c/k⌉` members of a
/// class of size `c` — so each *training* split keeps at least
/// `c − ⌈c/k⌉ ≥ 1` minority members whenever `c ≥ 2`, which is what
/// keeps a fold's `λ_max` from collapsing to 0 on imbalanced data (see
/// module docs).
///
/// The deal *continues* across classes (cumulative offset instead of
/// restarting at fold 0), so overall fold sizes stay within ±1 exactly
/// like [`fold_assignment`]'s — no fold can come out empty even when
/// every class has fewer than `k` members.
pub fn fold_assignment_stratified(y: &[f64], k: usize, seed: u64) -> Vec<usize> {
    let n = y.len();
    assert!(k >= 2 && n >= k);
    let mut rng = SplitMix64::new(seed);
    let mut fold = vec![0usize; n];
    let mut dealt = 0usize;
    for class_positive in [true, false] {
        let mut idx: Vec<usize> = (0..n).filter(|&i| (y[i] > 0.0) == class_positive).collect();
        rng.shuffle(&mut idx);
        for (rank, &i) in idx.iter().enumerate() {
            fold[i] = (dealt + rank) % k;
        }
        dealt += idx.len();
    }
    fold
}

fn loss(task: Task, pred: f64, y: f64) -> f64 {
    match task {
        Task::Regression => (pred - y) * (pred - y),
        Task::Classification => {
            if (pred >= 0.0) == (y > 0.0) {
                0.0
            } else {
                1.0
            }
        }
    }
}

/// K-fold CV over the SPP path, generic over the pattern substrate.
///
/// λ values are aligned across folds *by grid position* (each fold has
/// its own λ_max, so absolute λ differs; the fraction `λ/λ_max` is the
/// shared coordinate, as is standard for path-based CV).  Errors when a
/// fold's training split is degenerate (constant target / single class
/// — see the module docs), naming the fold.
pub fn cross_validate<S: PatternSubstrate + Sync>(
    db: &S,
    y: &[f64],
    task: Task,
    cfg: &PathConfig,
    k: usize,
    seed: u64,
) -> crate::Result<CvResult> {
    let n = db.n_records();
    assert_eq!(n, y.len());
    let folds = match task {
        Task::Classification => fold_assignment_stratified(y, k, seed),
        Task::Regression => fold_assignment(n, k, seed),
    };
    let threads = crate::runtime::parallel::resolve_threads(cfg.threads);
    // When the folds themselves fan out they already saturate the
    // worker budget, so the path solves inside them are pinned to one
    // worker — otherwise each fold would re-resolve `cfg.threads` and
    // the two parallel levels would multiply into k×threads live
    // threads.  Bit-identity makes this a pure scheduling choice.
    let fold_workers = crate::runtime::parallel::effective_workers(threads, k);
    let mut fold_cfg = *cfg;
    fold_cfg.threads = if fold_workers > 1 { 1 } else { threads };
    let fold_cfg = &fold_cfg;

    // one task per fold: full (chunked) path on the training split,
    // then per-λ validation losses + active counts (reduced in fold
    // order below, so the summary is independent of worker count)
    let per_fold: Vec<crate::Result<(Vec<f64>, Vec<f64>)>> =
        crate::runtime::parallel::map_indexed(threads, k, |f| {
            let train_idx: Vec<usize> = (0..n).filter(|&i| folds[i] != f).collect();
            let val_idx: Vec<usize> = (0..n).filter(|&i| folds[i] == f).collect();
            let train = db.select(&train_idx);
            let y_train: Vec<f64> = train_idx.iter().map(|&i| y[i]).collect();
            let path = compute_path_spp(&train, &y_train, task, fold_cfg)
                .with_context(|| format!("CV fold {f} ({} training records)", train_idx.len()))?;
            let mut losses = vec![0.0f64; cfg.n_lambdas];
            let mut active = vec![0.0f64; cfg.n_lambdas];
            for (li, p) in path.points.iter().enumerate() {
                let model = SparsePatternModel::from_path_point(task, p);
                let mut l = 0.0;
                for &i in &val_idx {
                    l += loss(task, model.score::<S>(db.record(i)), y[i]);
                }
                losses[li] = l / val_idx.len().max(1) as f64;
                active[li] = p.active.len() as f64;
            }
            Ok((losses, active))
        });

    let mut fold_losses = vec![vec![0.0f64; k]; cfg.n_lambdas];
    let mut actives = vec![0.0f64; cfg.n_lambdas];
    for (f, result) in per_fold.into_iter().enumerate() {
        let (losses, active) = result?;
        for li in 0..cfg.n_lambdas {
            fold_losses[li][f] = losses[li];
            actives[li] += active[li] / k as f64;
        }
    }

    Ok(finish(cfg, fold_losses, actives))
}

/// K-fold CV for item-set databases (thin wrapper over
/// [`cross_validate`]).
pub fn cross_validate_itemsets(
    db: &Transactions,
    y: &[f64],
    task: Task,
    cfg: &PathConfig,
    k: usize,
    seed: u64,
) -> crate::Result<CvResult> {
    cross_validate(db, y, task, cfg, k, seed)
}

/// K-fold CV for graph databases (thin wrapper over
/// [`cross_validate`]; targets come from the database).
pub fn cross_validate_graphs(
    db: &GraphDatabase,
    task: Task,
    cfg: &PathConfig,
    k: usize,
    seed: u64,
) -> crate::Result<CvResult> {
    cross_validate(db, &db.y, task, cfg, k, seed)
}

fn finish(cfg: &PathConfig, fold_losses: Vec<Vec<f64>>, actives: Vec<f64>) -> CvResult {
    let mut points = Vec::with_capacity(cfg.n_lambdas);
    for (li, losses) in fold_losses.into_iter().enumerate() {
        let mean = losses.iter().sum::<f64>() / losses.len() as f64;
        points.push(CvPoint {
            lambda_frac: cfg
                .lambda_min_ratio
                .powf(li as f64 / (cfg.n_lambdas - 1) as f64),
            mean_loss: mean,
            fold_losses: losses,
            mean_active: actives[li],
        });
    }
    let best = points
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.mean_loss.partial_cmp(&b.1.mean_loss).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    CvResult { points, best }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_itemsets::{generate, ItemsetSynthConfig};

    #[test]
    fn fold_assignment_is_balanced_and_deterministic() {
        let f1 = fold_assignment(103, 5, 9);
        let f2 = fold_assignment(103, 5, 9);
        assert_eq!(f1, f2);
        let mut counts = vec![0usize; 5];
        for &f in &f1 {
            counts[f] += 1;
        }
        assert!(counts.iter().all(|&c| c == 20 || c == 21), "{counts:?}");
        assert_ne!(fold_assignment(103, 5, 10), f1);
    }

    #[test]
    fn stratified_folds_spread_both_classes() {
        // 9:1 imbalance, the regression case of the bug report: a plain
        // shuffle can strand the minority class in one fold; the
        // stratified split must keep every training split two-class
        let n = 60;
        let y: Vec<f64> = (0..n).map(|i| if i % 10 == 0 { -1.0 } else { 1.0 }).collect();
        let k = 4;
        for seed in 0..20u64 {
            let folds = fold_assignment_stratified(&y, k, seed);
            assert_eq!(folds.len(), n);
            for f in 0..k {
                let train_neg = (0..n).filter(|&i| folds[i] != f && y[i] < 0.0).count();
                let train_pos = (0..n).filter(|&i| folds[i] != f && y[i] > 0.0).count();
                assert!(
                    train_neg >= 1 && train_pos >= 1,
                    "seed {seed} fold {f}: single-class training split \
                     ({train_pos} pos / {train_neg} neg)"
                );
                // per-class round-robin ⇒ per-fold class counts within ±1
                let fold_neg = (0..n).filter(|&i| folds[i] == f && y[i] < 0.0).count();
                assert!((1..=2).contains(&fold_neg), "seed {seed} fold {f}: {fold_neg} neg");
            }
        }
        // deterministic in the seed
        assert_eq!(fold_assignment_stratified(&y, k, 7), fold_assignment_stratified(&y, k, 7));
        assert_ne!(fold_assignment_stratified(&y, k, 7), fold_assignment_stratified(&y, k, 8));
    }

    #[test]
    fn stratified_folds_never_leave_a_fold_empty() {
        // both classes smaller than k: the continuous (offset) deal
        // must still populate every fold — a per-class restart at fold
        // 0 would leave fold 3 empty here, and its "validation loss"
        // would be a fabricated 0.0
        let y = vec![1.0, 1.0, 1.0, -1.0, -1.0, -1.0];
        for seed in 0..10u64 {
            let folds = fold_assignment_stratified(&y, 4, seed);
            let mut counts = vec![0usize; 4];
            for &f in &folds {
                counts[f] += 1;
            }
            assert!(counts.iter().all(|&c| c >= 1), "seed {seed}: empty fold in {counts:?}");
            // overall balance matches the plain shuffle's ±1 guarantee
            assert!(counts.iter().all(|&c| c <= 2), "seed {seed}: {counts:?}");
        }
    }

    #[test]
    fn imbalanced_classification_cv_runs_clean() {
        // the end-to-end regression test for the stratification bugfix:
        // 9:1 labels, k = 4 — every fold must produce a real path (no
        // λ_max collapse) and probability-shaped losses
        let d = generate(&ItemsetSynthConfig::tiny(92, true));
        let y: Vec<f64> = (0..d.y.len())
            .map(|i| if i % 10 == 0 { -1.0 } else { 1.0 })
            .collect();
        let cfg = PathConfig {
            n_lambdas: 5,
            lambda_min_ratio: 0.1,
            maxpat: 2,
            ..PathConfig::default()
        };
        let cv = cross_validate_itemsets(&d.db, &y, Task::Classification, &cfg, 4, 11).unwrap();
        for p in &cv.points {
            assert_eq!(p.fold_losses.len(), 4);
            for &l in &p.fold_losses {
                assert!((0.0..=1.0).contains(&l), "loss {l} is not an error rate");
            }
        }
        // the all-positive predictor gets ≤ 10% error, so the winner
        // must too — a collapsed fold would have dragged the mean past it
        assert!(cv.best_point().mean_loss <= 0.2, "{}", cv.best_point().mean_loss);
    }

    #[test]
    fn degenerate_fold_errors_name_the_fold() {
        // every target identical: each fold's training split is
        // constant, λ_max = 0, and CV must surface a clear error
        let d = generate(&ItemsetSynthConfig::tiny(93, false));
        let y = vec![1.5; d.y.len()];
        let cfg = PathConfig {
            n_lambdas: 4,
            lambda_min_ratio: 0.2,
            maxpat: 2,
            ..PathConfig::default()
        };
        let err = cross_validate_itemsets(&d.db, &y, Task::Regression, &cfg, 3, 5).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("CV fold"), "{msg}");
        assert!(msg.contains("λ_max"), "{msg}");
    }

    #[test]
    fn cv_selects_an_interior_lambda_on_planted_data() {
        let mut c = ItemsetSynthConfig::tiny(88, false);
        c.n = 160;
        c.d = 20;
        c.avg_items = 6.0;
        let d = generate(&c);
        let cfg = PathConfig {
            n_lambdas: 10,
            lambda_min_ratio: 0.05,
            maxpat: 2,
            ..PathConfig::default()
        };
        let cv = cross_validate_itemsets(&d.db, &d.y, Task::Regression, &cfg, 4, 1).unwrap();
        assert_eq!(cv.points.len(), 10);
        // λ_max (index 0) predicts the mean only — it must not win
        assert_ne!(cv.best, 0, "CV picked the intercept-only model");
        // the chosen loss beats the intercept-only loss clearly
        assert!(cv.best_point().mean_loss < 0.9 * cv.points[0].mean_loss);
        // fractions are monotone decreasing from 1.0
        assert!((cv.points[0].lambda_frac - 1.0).abs() < 1e-12);
        for w in cv.points.windows(2) {
            assert!(w[1].lambda_frac < w[0].lambda_frac);
        }
    }

    #[test]
    fn cv_classification_error_rates_are_probabilities() {
        let d = generate(&ItemsetSynthConfig::tiny(89, true));
        let cfg = PathConfig {
            n_lambdas: 5,
            lambda_min_ratio: 0.1,
            maxpat: 2,
            ..PathConfig::default()
        };
        let cv = cross_validate_itemsets(&d.db, &d.y, Task::Classification, &cfg, 3, 2).unwrap();
        for p in &cv.points {
            assert!((0.0..=1.0).contains(&p.mean_loss));
            assert_eq!(p.fold_losses.len(), 3);
        }
    }

    #[test]
    fn cv_graphs_runs_end_to_end() {
        use crate::data::synth_graphs::{generate as ggen, GraphSynthConfig};
        let mut c = GraphSynthConfig::tiny(90, true);
        c.n = 40;
        let d = ggen(&c);
        let cfg = PathConfig {
            n_lambdas: 4,
            lambda_min_ratio: 0.2,
            maxpat: 2,
            ..PathConfig::default()
        };
        let cv = cross_validate_graphs(&d.db, Task::Classification, &cfg, 4, 3).unwrap();
        assert_eq!(cv.points.len(), 4);
        assert!(cv.best_point().mean_loss <= cv.points[0].mean_loss + 1e-12);
    }

    #[test]
    fn cv_sequences_runs_end_to_end() {
        use crate::data::sequence::{generate as sgen, SeqSynthConfig};
        let d = sgen(&SeqSynthConfig::tiny(91, false));
        let cfg = PathConfig {
            n_lambdas: 4,
            lambda_min_ratio: 0.2,
            maxpat: 2,
            ..PathConfig::default()
        };
        let cv = cross_validate(&d.db, &d.y, Task::Regression, &cfg, 4, 5).unwrap();
        assert_eq!(cv.points.len(), 4);
        assert!(cv.best_point().mean_loss <= cv.points[0].mean_loss + 1e-12);
    }
}
