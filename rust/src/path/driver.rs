//! The one λ-loop (paper Algorithm 1) behind every path engine.
//!
//! Historically the repo carried three hand-cloned copies of the
//! regularization-path loop — the SPP engine, the boosting baseline,
//! and (by transitivity) every CV fold.  [`PathDriver`] is the single
//! copy: it owns the per-λ scaffolding every method shares —
//!
//! * the λ_max search, its degeneracy guard, and the log grid;
//! * the [`SupportPool`] with its column layout, memory budget, and
//!   spill policy;
//! * the chunk walk over the grid tail;
//! * per-λ budget enforcement, [`SpillStats`] delta accounting, the
//!   active-set snapshot, and [`PathPoint`] emission —
//!
//! and delegates *what happens at one λ* to an [`ActiveSetStrategy`]:
//! [`SppStrategy`] (screen → restricted solve, unifying the scratch,
//! screening-forest, and range-chunk shapes behind the `screen_at`
//! seam) and [`BoostingStrategy`] (constraint-generation rounds).  The
//! public entry points `compute_path_spp{,_with}` and
//! `compute_path_boosting` in [`crate::path`] are thin wrappers that
//! pick a strategy and run the driver; `path/cv.rs` folds call those
//! wrappers, so every fold runs this loop too.
//!
//! The driver is deliberately *not* where engine shapes live: a new
//! path method (e.g. the selective-inference layer of ROADMAP item 5)
//! is one new strategy — it inherits the grid, the pool, the spill
//! accounting, and the telemetry for free, and its paths are
//! comparable point-for-point with the existing methods because every
//! strategy emits the same [`PathPoint`] currency.
//!
//! Bit-identity contract: the driver performs the exact operation
//! sequence of the pre-refactor loops (pre-mine → screen → assemble →
//! solve → certify → enforce → snapshot), so paths are bit-for-bit
//! what they were — pinned by `tests/integration_dispatch.rs` across
//! all four substrates × forest/scratch × range-chunk × threads.

use std::collections::HashMap;
use std::time::Instant;

use crate::boosting::{solve_lambda as boosting_solve, BoostingConfig};
use crate::columns::resolve_columns;
use crate::mining::{Pattern, PatternSubstrate, TraverseStats};
use crate::runtime::parallel::{self, ThreadStats};
use crate::screening::certify::certify;
use crate::screening::forest::ScreenForest;
use crate::screening::lambda_max::{lambda_max, LambdaMax};
use crate::screening::pool::{resolve_memory_budget, SpillStats, SupportId, SupportPool};
use crate::screening::range;
use crate::screening::sppc::{screen_pass, Survivor};
use crate::solver::Task;

use super::working_set::WorkingSet;
use super::{
    lambda_grid, PathConfig, PathPoint, PathResult, RestrictedSolver, ReuseStats,
};

/// Mutable path state owned by the driver and shared with the
/// strategy: the column pool, the working set, and the warm-start
/// weights/intercept.  A strategy mutates these in [`ActiveSetStrategy::step`];
/// the driver reads them back for the per-λ active-set snapshot.
pub struct PathState {
    /// Column-interning arena spanning the whole path (ids stay stable
    /// across λ steps, so warm starts and dedup survive every engine
    /// shape).
    pub pool: SupportPool,
    /// Resolved resident-byte ceiling (`0` = unlimited); strategies
    /// consult it before forest walks / solves that read columns by id.
    pub budget: usize,
    /// Working set of the most recent restricted solve.
    pub ws: WorkingSet,
    /// Optimal weights aligned with `ws`.
    pub w: Vec<f64>,
    /// Intercept.
    pub b: f64,
}

/// What one λ step reports back to the driver: the telemetry half of a
/// [`PathPoint`] (the model half — active set, weights, intercept —
/// is read from [`PathState`]).
pub struct StepOutcome {
    pub gap: f64,
    pub traverse_secs: f64,
    pub solve_secs: f64,
    pub stats: TraverseStats,
    pub rounds: usize,
    pub cd_epochs: usize,
    pub reuse: ReuseStats,
    pub threads: ThreadStats,
}

/// One path method: how the active set is produced at each λ.  The
/// driver calls `init` once (from the analytic λ_max solution), then
/// walks the grid tail in chunks of `chunk_span()` points, calling
/// `begin_chunk` once per chunk and `step` once per λ.
pub trait ActiveSetStrategy<S: PatternSubstrate> {
    /// Whether the pool may enforce its budget *inside* `intern`.
    /// Only safe when no engine re-reads previously-interned columns
    /// mid-screen (the from-scratch per-λ SPP shape); forest-walking
    /// engines restore residency per walk and spill between phases.
    fn spill_on_intern(&self, cfg: &PathConfig) -> bool;

    /// Grid points covered by one chunk: `1` = per-λ (the paper's
    /// Algorithm 1 cadence), `C > 1` = the range-based chunked shape.
    fn chunk_span(&self) -> usize;

    /// Seed strategy state from the λ_max solution (dual certificate,
    /// slacks) before the first chunk.
    fn init(&mut self, lm: &LambdaMax);

    /// Once per chunk, before its λ steps (e.g. the range-based SPP
    /// pre-mine).  Default: nothing.
    fn begin_chunk(
        &mut self,
        db: &S,
        y: &[f64],
        task: Task,
        cfg: &PathConfig,
        chunk_lams: &[f64],
        st: &mut PathState,
    ) {
        let _ = (db, y, task, cfg, chunk_lams, st);
    }

    /// One λ step: produce the active set and the solution at `lam`,
    /// mutating `st.{ws, w, b}` (and any warm-start state the strategy
    /// carries).  `j` is the λ's index within its chunk, `span` the
    /// chunk's length.
    #[allow(clippy::too_many_arguments)]
    fn step(
        &mut self,
        db: &S,
        y: &[f64],
        task: Task,
        cfg: &PathConfig,
        j: usize,
        span: usize,
        lam: f64,
        st: &mut PathState,
    ) -> StepOutcome;
}

/// The shared λ-loop.  Construct one per path over a [`PathConfig`],
/// pick a strategy, and [`PathDriver::run`] it.
pub struct PathDriver<'c> {
    cfg: &'c PathConfig,
}

impl<'c> PathDriver<'c> {
    pub fn new(cfg: &'c PathConfig) -> Self {
        PathDriver { cfg }
    }

    /// Algorithm 1's outer loop: λ_max + guard + grid, pool setup,
    /// chunk walk, and per-λ `step` → budget enforcement → spill
    /// deltas → active snapshot → [`PathPoint`].
    pub fn run<S, A>(
        &self,
        db: &S,
        y: &[f64],
        task: Task,
        strategy: &mut A,
    ) -> crate::Result<PathResult>
    where
        S: PatternSubstrate,
        A: ActiveSetStrategy<S>,
    {
        let cfg = self.cfg;
        let n = y.len();
        anyhow::ensure!(
            db.n_records() == n,
            "database has {} records but y has {n} targets",
            db.n_records()
        );

        // λ_0 = λ_max; analytic zero solution + its dual certificate.
        // The λ_max search stays sequential: its envelope pruning
        // tightens with the best value found so far, which is
        // traversal-order-dependent — sharing it across workers would
        // change node counts run to run.
        let t0 = Instant::now();
        let lm = lambda_max(db, y, task, cfg.maxpat, cfg.minsup);
        let lmax_secs = t0.elapsed().as_secs_f64();
        super::lambda_max_guard(lm.lambda_max, task)?;
        let grid = lambda_grid(lm.lambda_max, cfg.n_lambdas, cfg.lambda_min_ratio);

        let mut points: Vec<PathPoint> = Vec::with_capacity(grid.len());
        points.push(PathPoint {
            lambda: grid[0],
            active: Vec::new(),
            b: lm.b0,
            gap: 0.0,
            traverse_secs: lmax_secs,
            solve_secs: 0.0,
            stats: lm.stats,
            working_size: 0,
            rounds: 1,
            cd_epochs: 0,
            reuse: ReuseStats::default(),
            threads: ThreadStats::sequential(),
            spill: SpillStats::default(),
        });

        let mut st = PathState {
            pool: SupportPool::with_layout(resolve_columns(cfg.columns)),
            budget: resolve_memory_budget(cfg.memory_budget),
            ws: WorkingSet::new(),
            w: Vec::new(),
            b: lm.b0,
        };
        st.pool.set_memory_budget(st.budget);
        st.pool.set_spill_on_intern(strategy.spill_on_intern(cfg));
        let mut spill_base = st.pool.spill_stats();
        strategy.init(&lm);

        let chunk_size = strategy.chunk_span().max(1);
        let tail = &grid[1..];
        let mut k = 0usize;
        while k < tail.len() {
            let span = chunk_size.min(tail.len() - k);
            let chunk_lams = &tail[k..k + span];
            strategy.begin_chunk(db, y, task, cfg, chunk_lams, &mut st);

            for (j, &lam) in chunk_lams.iter().enumerate() {
                let out = strategy.step(db, y, task, cfg, j, span, lam, &mut st);

                // settle the pool back under the budget and account
                // this λ's spill traffic (deltas of the lifetime
                // counters; a chunk pre-mine's traffic lands on its
                // leading λ).
                st.pool.enforce_budget();
                let spill_now = st.pool.spill_stats();
                let spill = SpillStats {
                    reloaded: spill_now.reloaded - spill_base.reloaded,
                    evicted: spill_now.evicted - spill_base.evicted,
                    ..spill_now
                };
                spill_base = spill_now;

                let active: Vec<(Pattern, f64)> = st
                    .ws
                    .patterns
                    .iter()
                    .zip(&st.w)
                    .filter(|(_, &wi)| wi != 0.0)
                    .map(|(p, &wi)| (p.clone(), wi))
                    .collect();
                points.push(PathPoint {
                    lambda: lam,
                    active,
                    b: st.b,
                    gap: out.gap,
                    traverse_secs: out.traverse_secs,
                    solve_secs: out.solve_secs,
                    stats: out.stats,
                    working_size: st.ws.len(),
                    rounds: out.rounds,
                    cd_epochs: out.cd_epochs,
                    reuse: out.reuse,
                    threads: out.threads,
                    spill,
                });
            }
            k += span;
        }

        Ok(PathResult {
            lambda_max: lm.lambda_max,
            points,
        })
    }
}

/// Â for one λ: survivors ∪ previously-active patterns (the latter are
/// kept even if tolerance slop screened them; safety tests verify this
/// set is a superset of the true active set).  Patterns with
/// *identical* support columns — id equality in the pool — are
/// collapsed to one representative: redundant columns change neither
/// the optimal objective nor the fitted model, and dominate |Â| on
/// dense data.  Previous representatives are inserted first so warm
/// starts transfer exactly.
fn assemble_working_set(prev: &WorkingSet, w: &[f64], survivors: Vec<Survivor>) -> WorkingSet {
    let mut next = WorkingSet::new();
    let mut seen: HashMap<SupportId, usize> = HashMap::new();
    for (i, p) in prev.patterns.iter().enumerate() {
        if w[i] != 0.0 {
            let sid = prev.support_ids[i];
            let idx = next.insert(p.clone(), sid);
            seen.entry(sid).or_insert(idx);
        }
    }
    for s in survivors {
        if seen.contains_key(&s.support) {
            continue;
        }
        let idx = next.insert(s.pattern, s.support);
        seen.insert(s.support, idx);
    }
    next
}

/// One λ's screening pass: on a stored forest when one exists
/// (persistent or chunk-local), from scratch otherwise.  The single
/// dispatch point of the per-λ loop, shared by every SPP engine shape.
#[allow(clippy::too_many_arguments)]
fn screen_at<S: PatternSubstrate>(
    db: &S,
    task: Task,
    y: &[f64],
    theta: &[f64],
    radius: f64,
    cfg: &PathConfig,
    threads: usize,
    forest: Option<&mut ScreenForest>,
    pool: &mut SupportPool,
) -> (Vec<Survivor>, TraverseStats, ReuseStats, ThreadStats) {
    match forest {
        Some(f) => {
            let out = f.screen(db, task, y, theta, radius, true, threads, pool);
            let reuse = ReuseStats {
                forest_hits: out.forest_hits,
                cert_skips: out.cert_skips,
                reopened: out.reopened,
                ..ReuseStats::default()
            };
            (out.survivors, out.stats, reuse, out.threads)
        }
        None => {
            let (survivors, stats, tstats) = screen_pass(
                db, task, y, theta, radius, true, cfg.maxpat, cfg.minsup, threads, pool,
            );
            (survivors, stats, ReuseStats::default(), tstats)
        }
    }
}

/// The SPP strategy (paper Algorithm 1): per λ, one screening pass
/// with the SPP rule built from the previous λ's primal/dual pair,
/// then *one* restricted solve on Â.  Unifies the three screening
/// shapes behind the `screen_at` seam:
///
/// * **forest** (`reuse_forest`, the default) — a persistent
///   [`ScreenForest`] re-evaluated in place across λs;
/// * **scratch** (`--no-reuse`) — the paper-literal traversal per λ;
/// * **range-chunk** (`range_chunk > 1`) — one interval-radius
///   pre-mine per chunk ([`range::interval_radius`]) materializes
///   every subtree any λ in the chunk can need, and each λ re-derives
///   its exact survivor set from the stored columns (a chunk-local
///   forest when `reuse_forest` is off, so the ablation baseline never
///   carries state across chunks).
///
/// All shapes produce bit-identical paths.
pub struct SppStrategy<'a> {
    solver: &'a dyn RestrictedSolver,
    /// Resolved once for the whole path: `--threads 1` is the
    /// sequential engine, anything else is bit-identical to it.
    threads: usize,
    /// Resolved once: `--range-chunk 1` is the per-λ engine.
    chunk_size: usize,
    chunked: bool,
    forest: Option<ScreenForest>,
    /// Chunked mode without forest reuse screens against a chunk-local
    /// forest instead (fresh per chunk; the SupportPool still spans the
    /// whole path, so ids stay stable for warm starts and dedup).
    chunk_forest: Option<ScreenForest>,
    slack: Vec<f64>,
    theta: Vec<f64>,
    // Carry of the chunk pre-mine, merged into the chunk-leading λ's
    // telemetry by `step`.
    chunk_mine: TraverseStats,
    chunk_mine_reuse: ReuseStats,
    chunk_mine_threads: ThreadStats,
    chunk_mine_secs: f64,
}

impl<'a> SppStrategy<'a> {
    pub fn new(cfg: &PathConfig, solver: &'a dyn RestrictedSolver) -> Self {
        let chunk_size = range::resolve_range_chunk(cfg.range_chunk);
        SppStrategy {
            solver,
            threads: parallel::resolve_threads(cfg.threads),
            chunk_size,
            chunked: chunk_size > 1,
            forest: cfg
                .reuse_forest
                .then(|| ScreenForest::new(cfg.maxpat, cfg.minsup)),
            chunk_forest: None,
            slack: Vec::new(),
            theta: Vec::new(),
            chunk_mine: TraverseStats::default(),
            chunk_mine_reuse: ReuseStats::default(),
            chunk_mine_threads: ThreadStats::sequential(),
            chunk_mine_secs: 0.0,
        }
    }
}

impl<S: PatternSubstrate> ActiveSetStrategy<S> for SppStrategy<'_> {
    fn spill_on_intern(&self, cfg: &PathConfig) -> bool {
        // Budget enforcement *inside* `intern` is only safe for
        // from-scratch per-λ screening: forest walks (persistent or
        // chunk-local) read previously-interned columns by id, so
        // those engines restore full residency per walk and spill
        // between phases instead (module docs of `screening::pool`).
        !cfg.reuse_forest && !self.chunked
    }

    fn chunk_span(&self) -> usize {
        self.chunk_size
    }

    fn init(&mut self, lm: &LambdaMax) {
        self.slack = lm.slack0.clone();
        self.theta = lm.slack0.iter().map(|&s| s / lm.lambda_max).collect();
    }

    /// The chunk pre-mine: ONE traversal at the interval radius of the
    /// pair entering the chunk covers every λ the chunk holds
    /// (range-based SPP; survivors are discarded — the per-λ screens
    /// re-derive their exact sets from the stored columns).
    fn begin_chunk(
        &mut self,
        db: &S,
        y: &[f64],
        task: Task,
        cfg: &PathConfig,
        chunk_lams: &[f64],
        st: &mut PathState,
    ) {
        if self.chunked && !cfg.reuse_forest {
            self.chunk_forest = Some(ScreenForest::new(cfg.maxpat, cfg.minsup));
        }
        self.chunk_mine = TraverseStats::default();
        self.chunk_mine_reuse = ReuseStats::default();
        self.chunk_mine_threads = ThreadStats::sequential();
        self.chunk_mine_secs = 0.0;
        let span = chunk_lams.len();
        if span > 1 {
            let l1: f64 = st.w.iter().map(|x| x.abs()).sum();
            let r_chunk = range::interval_radius(
                task,
                y,
                &self.theta,
                &self.slack,
                l1,
                chunk_lams[span - 1],
                chunk_lams[0],
            );
            if st.budget > 0 {
                st.pool.ensure_all_resident();
            }
            let f = self
                .forest
                .as_mut()
                .or(self.chunk_forest.as_mut())
                .expect("chunked mode always screens on a forest");
            let t = Instant::now();
            let (_, mine_stats, mine_reuse, mine_threads) = screen_at(
                db,
                task,
                y,
                &self.theta,
                r_chunk,
                cfg,
                self.threads,
                Some(f),
                &mut st.pool,
            );
            self.chunk_mine_secs = t.elapsed().as_secs_f64();
            self.chunk_mine = mine_stats;
            self.chunk_mine_reuse = mine_reuse;
            self.chunk_mine_threads = mine_threads;
        }
    }

    fn step(
        &mut self,
        db: &S,
        y: &[f64],
        task: Task,
        cfg: &PathConfig,
        j: usize,
        span: usize,
        lam: f64,
        st: &mut PathState,
    ) -> StepOutcome {
        // (1) SPP rule from the previous pair, evaluated at the new λ —
        // on the stored forest when one exists (persistent or
        // chunk-local), from scratch otherwise.  The radius comes from
        // the same kernel the interval bound is built on, so the
        // endpoint rule's per-λ ≤ chunk dominance is exact.
        let l1: f64 = st.w.iter().map(|x| x.abs()).sum();
        let radius = range::lambda_radius(task, y, &self.theta, &self.slack, l1, lam);

        // A forest walk reads every stored column by id, so restore
        // full residency first — the transient peak is the forest-mode
        // budget caveat; `--no-reuse --range-chunk 1` holds the
        // ceiling mid-screen (see `PathConfig::memory_budget`).
        if st.budget > 0 && (self.forest.is_some() || self.chunk_forest.is_some()) {
            st.pool.ensure_all_resident();
        }
        let t1 = Instant::now();
        let engine = self.forest.as_mut().or(self.chunk_forest.as_mut());
        let (survivors, stats, mut reuse, tstats) = screen_at(
            db,
            task,
            y,
            &self.theta,
            radius,
            cfg,
            self.threads,
            engine,
            &mut st.pool,
        );
        let mut traverse_secs = t1.elapsed().as_secs_f64();
        let mut stats = stats;
        // chunk telemetry: a hit = a non-leading λ fully served by its
        // chunk's stored tree (no substrate re-entry); the pre-mine's
        // cost AND its forest telemetry land on the chunk-leading λ,
        // so chunked totals stay honest.
        reuse.chunk_hit = j > 0 && span > 1 && stats.nodes == 0;
        let mut tstats = tstats;
        if j == 0 {
            reuse.forest_hits += self.chunk_mine_reuse.forest_hits;
            reuse.cert_skips += self.chunk_mine_reuse.cert_skips;
            reuse.reopened += self.chunk_mine_reuse.reopened;
            reuse.chunk_mine_nodes = self.chunk_mine.nodes;
            stats.nodes += self.chunk_mine.nodes;
            stats.pruned += self.chunk_mine.pruned;
            traverse_secs += self.chunk_mine_secs;
            // the pre-mine is usually this λ's dominant screening
            // phase; report whichever pass farmed more tasks
            if self.chunk_mine_threads.tasks > tstats.tasks {
                tstats = self.chunk_mine_threads;
            }
        }

        // (2) Â = survivors ∪ previously-active, deduped by SupportId.
        let new_ws = assemble_working_set(&st.ws, &st.w, survivors);
        let w0 = new_ws.transfer_weights(&st.ws, &st.w);
        st.ws = new_ws;

        // (3) restricted solve, warm-started, on borrowed column views
        // — after making exactly the working set's columns resident
        // (they are exempt from the reload's enforcement pass).
        if st.budget > 0 {
            st.pool.ensure_resident(&st.ws.support_ids);
        }
        let t2 = Instant::now();
        let sol = {
            let cols = st.ws.columns(&st.pool);
            self.solver.solve_restricted(task, &cols, y, lam, &w0, st.b)
        };
        let solve_secs = t2.elapsed().as_secs_f64();
        st.w = sol.w.clone();
        st.b = sol.b;
        self.slack = sol.slack.clone();
        self.theta = sol.theta.clone();
        reuse.solver_screened = sol.screened;

        // (4) optional exact feasibility pass for the *next* screening.
        if cfg.certify {
            let t3 = Instant::now();
            let c = certify(db, y, task, &self.theta, cfg.maxpat, cfg.minsup);
            traverse_secs += t3.elapsed().as_secs_f64();
            stats.nodes += c.stats.nodes;
            stats.pruned += c.stats.pruned;
            self.theta = c.theta;
        }

        StepOutcome {
            gap: sol.gap,
            traverse_secs,
            solve_secs,
            stats,
            rounds: 1,
            cd_epochs: sol.epochs,
            reuse,
            threads: tstats,
        }
    }
}

/// The boosting baseline (paper §2.2 / §4): per λ, constraint-
/// generation rounds (most-violating search + solve per round) on a
/// working set inherited across the path.  `cfg.range_chunk` is
/// ignored (there is no screening pass to chunk), so `chunk_span` is
/// pinned at 1.
pub struct BoostingStrategy {
    bcfg: BoostingConfig,
}

impl BoostingStrategy {
    pub fn new(cfg: &PathConfig) -> Self {
        BoostingStrategy {
            bcfg: BoostingConfig {
                k_add: cfg.k_add,
                viol_tol: cfg.viol_tol,
                max_rounds: 10_000,
                cd: cfg.cd,
            },
        }
    }
}

impl<S: PatternSubstrate> ActiveSetStrategy<S> for BoostingStrategy {
    fn spill_on_intern(&self, _cfg: &PathConfig) -> bool {
        false
    }

    fn chunk_span(&self) -> usize {
        1
    }

    fn init(&mut self, _lm: &LambdaMax) {}

    fn step(
        &mut self,
        db: &S,
        y: &[f64],
        task: Task,
        cfg: &PathConfig,
        _j: usize,
        _span: usize,
        lam: f64,
        st: &mut PathState,
    ) -> StepOutcome {
        // Boosting interleaves searching, interning and column reads
        // inside each round, so the budget is enforced at λ boundaries:
        // full residency during the λ, spilled back down (by the
        // driver) before the gauges are recorded.
        if st.budget > 0 {
            st.pool.ensure_all_resident();
        }
        let out = boosting_solve(
            db,
            y,
            task,
            lam,
            cfg.maxpat,
            cfg.minsup,
            &mut st.pool,
            &mut st.ws,
            &mut st.w,
            &mut st.b,
            &self.bcfg,
        );
        StepOutcome {
            gap: out.solution.gap,
            traverse_secs: out.traverse_secs,
            solve_secs: out.solve_secs,
            stats: out.stats,
            rounds: out.rounds,
            cd_epochs: out.solution.epochs,
            reuse: ReuseStats {
                solver_screened: out.solution.screened,
                ..ReuseStats::default()
            },
            // boosting's most-violating search tracks a global top-k —
            // order-dependent pruning, kept sequential
            threads: ThreadStats::sequential(),
        }
    }
}
