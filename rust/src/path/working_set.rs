//! The working set: the finite pattern collection a solver actually
//! sees — Â for SPP, the cutting-plane set for boosting.

use std::collections::HashMap;

use crate::mining::Pattern;

/// Patterns with their support columns and an id index.
#[derive(Clone, Debug, Default)]
pub struct WorkingSet {
    pub patterns: Vec<Pattern>,
    pub supports: Vec<Vec<u32>>,
    index: HashMap<Pattern, usize>,
}

impl WorkingSet {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.patterns.len()
    }

    pub fn is_empty(&self) -> bool {
        self.patterns.is_empty()
    }

    pub fn contains(&self, p: &Pattern) -> bool {
        self.index.contains_key(p)
    }

    pub fn position(&self, p: &Pattern) -> Option<usize> {
        self.index.get(p).copied()
    }

    /// Insert if absent; returns the pattern's index either way.
    pub fn insert(&mut self, pattern: Pattern, support: Vec<u32>) -> usize {
        if let Some(&i) = self.index.get(&pattern) {
            return i;
        }
        let i = self.patterns.len();
        self.index.insert(pattern.clone(), i);
        self.patterns.push(pattern);
        self.supports.push(support);
        i
    }

    /// Map a weight vector indexed by *another* working set onto this
    /// one (warm-start transfer between λ steps).  Missing patterns get
    /// weight 0; patterns absent here are dropped (they were screened
    /// as inactive).
    pub fn transfer_weights(&self, other: &WorkingSet, w_other: &[f64]) -> Vec<f64> {
        let mut w = vec![0.0; self.len()];
        for (i, p) in other.patterns.iter().enumerate() {
            if w_other[i] != 0.0 {
                if let Some(j) = self.position(p) {
                    w[j] = w_other[i];
                }
            }
        }
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(items: &[u32]) -> Pattern {
        Pattern::Itemset(items.to_vec())
    }

    #[test]
    fn insert_is_idempotent() {
        let mut ws = WorkingSet::new();
        let i = ws.insert(p(&[1]), vec![0, 1]);
        let j = ws.insert(p(&[1]), vec![0, 1]);
        assert_eq!(i, j);
        assert_eq!(ws.len(), 1);
        assert!(ws.contains(&p(&[1])));
        assert!(!ws.contains(&p(&[2])));
    }

    #[test]
    fn transfer_maps_by_pattern_identity() {
        let mut a = WorkingSet::new();
        a.insert(p(&[1]), vec![0]);
        a.insert(p(&[2]), vec![1]);
        let mut b = WorkingSet::new();
        b.insert(p(&[2]), vec![1]);
        b.insert(p(&[3]), vec![2]);
        let w_a = vec![0.5, -0.7];
        let w_b = b.transfer_weights(&a, &w_a);
        assert_eq!(w_b, vec![-0.7, 0.0]);
    }
}
