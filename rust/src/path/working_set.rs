//! The working set: the finite pattern collection a solver actually
//! sees — Â for SPP, the cutting-plane set for boosting.
//!
//! Support columns are held by [`SupportId`] into a shared
//! [`SupportPool`], so the set never clones a column: inserting a
//! survivor is two integer pushes, "same feature" is id equality, and
//! warm-start weight transfer between λ steps is an id-indexed copy
//! (no per-pattern hash probes — ids are stable across the whole path
//! because the pool is append-only).

use std::collections::HashMap;

use crate::columns::ColumnView;
use crate::mining::Pattern;
use crate::screening::pool::{SupportId, SupportPool};

/// Patterns with their interned support columns and an id index.
#[derive(Clone, Debug, Default)]
pub struct WorkingSet {
    pub patterns: Vec<Pattern>,
    pub support_ids: Vec<SupportId>,
    index: HashMap<Pattern, usize>,
    /// `support id -> column + 1` (0 = absent); grown lazily to the
    /// pool's id space.  First inserter wins on duplicate columns.
    by_support: Vec<u32>,
}

impl WorkingSet {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.patterns.len()
    }

    pub fn is_empty(&self) -> bool {
        self.patterns.is_empty()
    }

    pub fn contains(&self, p: &Pattern) -> bool {
        self.index.contains_key(p)
    }

    pub fn position(&self, p: &Pattern) -> Option<usize> {
        self.index.get(p).copied()
    }

    /// Column holding support `sid` (the first inserted, if several
    /// patterns share the column).
    #[inline]
    pub fn position_by_support(&self, sid: SupportId) -> Option<usize> {
        match self.by_support.get(sid.index()) {
            Some(&c) if c != 0 => Some(c as usize - 1),
            _ => None,
        }
    }

    /// Insert if absent; returns the pattern's index either way.
    pub fn insert(&mut self, pattern: Pattern, sid: SupportId) -> usize {
        if let Some(&i) = self.index.get(&pattern) {
            return i;
        }
        let i = self.patterns.len();
        self.index.insert(pattern.clone(), i);
        self.patterns.push(pattern);
        self.support_ids.push(sid);
        if self.by_support.len() <= sid.index() {
            self.by_support.resize(sid.index() + 1, 0);
        }
        if self.by_support[sid.index()] == 0 {
            self.by_support[sid.index()] = (i + 1) as u32;
        }
        i
    }

    /// Borrowed layout-aware column views in column order (what the
    /// restricted solver consumes; sparse or hybrid per the pool).
    pub fn columns<'p>(&self, pool: &'p SupportPool) -> Vec<ColumnView<'p>> {
        pool.view(&self.support_ids)
    }

    /// Map a weight vector indexed by *another* working set onto this
    /// one (warm-start transfer between λ steps): an id-indexed copy —
    /// columns are matched by [`SupportId`] (identical support columns
    /// are the same feature), so no hashing happens per pattern.
    /// Missing columns get weight 0; columns absent here are dropped
    /// (they were screened as inactive).
    ///
    /// **Precondition**: the *nonzero-weight* entries of `other` must
    /// hold distinct support columns (the SPP path guarantees this —
    /// `assemble_working_set` dedups Â by id).  Two nonzero weights on
    /// one column would land in the same destination slot; the debug
    /// assertion below makes that misuse loud.
    pub fn transfer_weights(&self, other: &WorkingSet, w_other: &[f64]) -> Vec<f64> {
        #[cfg(debug_assertions)]
        {
            let mut seen = std::collections::HashSet::new();
            for (i, &sid) in other.support_ids.iter().enumerate() {
                if w_other[i] != 0.0 {
                    debug_assert!(
                        seen.insert(sid),
                        "transfer_weights: duplicate support column among \
                         nonzero-weight source entries"
                    );
                }
            }
        }
        let mut w = vec![0.0; self.len()];
        for (i, &sid) in other.support_ids.iter().enumerate() {
            if w_other[i] != 0.0 {
                if let Some(j) = self.position_by_support(sid) {
                    w[j] = w_other[i];
                }
            }
        }
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::columns::ColumnRead;

    fn p(items: &[u32]) -> Pattern {
        Pattern::Itemset(items.to_vec())
    }

    #[test]
    fn insert_is_idempotent() {
        let mut pool = SupportPool::new();
        let mut ws = WorkingSet::new();
        let sid = pool.intern(&[0, 1]);
        let i = ws.insert(p(&[1]), sid);
        let j = ws.insert(p(&[1]), sid);
        assert_eq!(i, j);
        assert_eq!(ws.len(), 1);
        assert!(ws.contains(&p(&[1])));
        assert!(!ws.contains(&p(&[2])));
        assert_eq!(ws.position_by_support(sid), Some(0));
        let cols = ws.columns(&pool);
        assert_eq!(cols.len(), 1);
        assert_eq!(cols[0].ids(), &[0, 1]);
    }

    #[test]
    fn transfer_maps_by_support_id() {
        let mut pool = SupportPool::new();
        let (s0, s1, s2) = (pool.intern(&[0]), pool.intern(&[1]), pool.intern(&[2]));
        let mut a = WorkingSet::new();
        a.insert(p(&[1]), s0);
        a.insert(p(&[2]), s1);
        let mut b = WorkingSet::new();
        b.insert(p(&[2]), s1);
        b.insert(p(&[3]), s2);
        let w_a = vec![0.5, -0.7];
        let w_b = b.transfer_weights(&a, &w_a);
        assert_eq!(w_b, vec![-0.7, 0.0]);
    }

    #[test]
    fn transfer_matches_identical_columns_across_pattern_renames() {
        // two DIFFERENT patterns with the same support column are the
        // same feature: the warm start must carry the weight over even
        // when the λ step picked a different representative pattern
        let mut pool = SupportPool::new();
        let sid = pool.intern(&[3, 5]);
        let mut a = WorkingSet::new();
        a.insert(p(&[1]), sid);
        let mut b = WorkingSet::new();
        b.insert(p(&[9]), sid);
        assert_eq!(b.transfer_weights(&a, &[1.25]), vec![1.25]);
    }

    #[test]
    fn duplicate_columns_keep_first_position() {
        let mut pool = SupportPool::new();
        let sid = pool.intern(&[7]);
        let mut ws = WorkingSet::new();
        ws.insert(p(&[1]), sid);
        ws.insert(p(&[2]), sid);
        assert_eq!(ws.len(), 2);
        assert_eq!(ws.position_by_support(sid), Some(0));
    }
}
