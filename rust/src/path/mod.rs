//! Regularization-path computation (paper Algorithm 1).
//!
//! A log-spaced grid of `n_lambdas` penalties from `λ_max` down to
//! `lambda_min_ratio · λ_max` (the paper uses 100 and 0.01).  Both
//! methods run with warm starts:
//!
//! * **SPP**: per λ, *one* tree search with the SPP rule built from the
//!   previous λ's primal/dual pair, then *one* restricted solve on Â.
//! * **boosting**: per λ, constraint-generation rounds (search + solve
//!   per round) on a working set inherited across the path.
//!
//! Every per-λ record captures the figures' currency: traverse seconds,
//! solve seconds, traversed node count, |Â| (or working-set size), and
//! the certified duality gap.

pub mod cv;
pub mod working_set;

use std::time::Instant;

use crate::boosting::{solve_lambda as boosting_solve, BoostingConfig};
use crate::mining::{Counting, Pattern, PatternSubstrate, TraverseStats};
use crate::screening::certify::certify;
use crate::screening::lambda_max::lambda_max;
use crate::screening::sppc::SppScreen;
use crate::solver::dual::safe_radius;
use crate::solver::problem::{dual_value, primal_value};
use crate::solver::{CdConfig, CdSolver, Task};
use working_set::WorkingSet;

/// Path configuration shared by both methods.
#[derive(Clone, Copy, Debug)]
pub struct PathConfig {
    /// Grid size (paper: 100).
    pub n_lambdas: usize,
    /// `λ_min / λ_max` (paper: 0.01).
    pub lambda_min_ratio: f64,
    /// Maximum pattern size (items / edges).
    pub maxpat: usize,
    /// Minimum support for enumeration.
    pub minsup: usize,
    /// Restricted-solver settings (gap tolerance 1e-6, as in the paper).
    pub cd: CdConfig,
    /// Run the exact feasibility pass per λ (extension; see
    /// `screening::certify`).
    pub certify: bool,
    /// Boosting: patterns added per round.
    pub k_add: usize,
    /// Boosting: violation tolerance.
    pub viol_tol: f64,
}

impl Default for PathConfig {
    fn default() -> Self {
        PathConfig {
            n_lambdas: 100,
            lambda_min_ratio: 0.01,
            maxpat: 4,
            minsup: 1,
            cd: CdConfig::default(),
            certify: false,
            k_add: 1,
            viol_tol: 1e-6,
        }
    }
}

/// Per-λ record.
#[derive(Clone, Debug)]
pub struct PathPoint {
    pub lambda: f64,
    /// Active patterns with their optimal weights.
    pub active: Vec<(Pattern, f64)>,
    pub b: f64,
    pub gap: f64,
    /// Seconds spent searching trees at this λ.
    pub traverse_secs: f64,
    /// Seconds spent in the restricted solver at this λ.
    pub solve_secs: f64,
    pub stats: TraverseStats,
    /// |Â| (SPP) or working-set size (boosting) when solving.
    pub working_size: usize,
    /// Constraint-generation rounds (1 for SPP).
    pub rounds: usize,
    pub cd_epochs: usize,
}

/// Whole-path result.
#[derive(Clone, Debug)]
pub struct PathResult {
    pub lambda_max: f64,
    pub points: Vec<PathPoint>,
}

impl PathResult {
    pub fn total_traverse_secs(&self) -> f64 {
        self.points.iter().map(|p| p.traverse_secs).sum()
    }

    pub fn total_solve_secs(&self) -> f64 {
        self.points.iter().map(|p| p.solve_secs).sum()
    }

    pub fn total_nodes(&self) -> u64 {
        self.points.iter().map(|p| p.stats.nodes).sum()
    }

    pub fn total_secs(&self) -> f64 {
        self.total_traverse_secs() + self.total_solve_secs()
    }
}

/// The λ grid: `n` log-spaced values from `λ_max` to `ratio·λ_max`.
pub fn lambda_grid(lambda_max: f64, n: usize, ratio: f64) -> Vec<f64> {
    assert!(n >= 2 && ratio > 0.0 && ratio < 1.0);
    (0..n)
        .map(|k| lambda_max * ratio.powf(k as f64 / (n - 1) as f64))
        .collect()
}

/// A restricted-problem solver (paper eq. 6) pluggable into the path:
/// the default is the in-process CD solver; the XLA engine
/// (`runtime::engine`) implements this over the AOT FISTA artifacts.
pub trait RestrictedSolver {
    fn solve_restricted(
        &self,
        task: Task,
        supports: &[Vec<u32>],
        y: &[f64],
        lam: f64,
        warm_w: &[f64],
        warm_b: f64,
    ) -> crate::solver::Solution;
}

/// The default engine: pure-Rust coordinate descent.
pub struct CdRestricted(pub CdSolver);

impl RestrictedSolver for CdRestricted {
    fn solve_restricted(
        &self,
        task: Task,
        supports: &[Vec<u32>],
        y: &[f64],
        lam: f64,
        warm_w: &[f64],
        warm_b: f64,
    ) -> crate::solver::Solution {
        self.0.solve(
            task,
            supports,
            y,
            lam,
            Some(crate::solver::cd::Warm {
                w: warm_w,
                b: warm_b,
            }),
        )
    }
}

/// Algorithm 1: SPP regularization path (default CD engine) on any
/// [`PatternSubstrate`].
pub fn compute_path_spp<S: PatternSubstrate>(
    db: &S,
    y: &[f64],
    task: Task,
    cfg: &PathConfig,
) -> PathResult {
    let solver = CdRestricted(CdSolver::new(cfg.cd));
    compute_path_spp_with(db, y, task, cfg, &solver)
}

/// Algorithm 1 with an explicit restricted-solver engine.
pub fn compute_path_spp_with<S: PatternSubstrate>(
    db: &S,
    y: &[f64],
    task: Task,
    cfg: &PathConfig,
    solver: &dyn RestrictedSolver,
) -> PathResult {
    let n = y.len();
    assert_eq!(db.n_records(), n);

    // λ_0 = λ_max; analytic zero solution + its dual certificate.
    let t0 = Instant::now();
    let lm = lambda_max(db, y, task, cfg.maxpat, cfg.minsup);
    let lmax_secs = t0.elapsed().as_secs_f64();
    let grid = lambda_grid(lm.lambda_max, cfg.n_lambdas, cfg.lambda_min_ratio);

    let mut points: Vec<PathPoint> = Vec::with_capacity(grid.len());
    points.push(PathPoint {
        lambda: grid[0],
        active: Vec::new(),
        b: lm.b0,
        gap: 0.0,
        traverse_secs: lmax_secs,
        solve_secs: 0.0,
        stats: lm.stats,
        working_size: 0,
        rounds: 1,
        cd_epochs: 0,
    });

    // screening state from the previous λ
    let mut ws = WorkingSet::new();
    let mut w: Vec<f64> = Vec::new();
    let mut b = lm.b0;
    let mut slack: Vec<f64> = lm.slack0.clone();
    let mut theta: Vec<f64> = lm.slack0.iter().map(|&s| s / lm.lambda_max).collect();

    for &lam in &grid[1..] {
        // (1) SPP rule from the previous pair, evaluated at the new λ.
        let l1: f64 = w.iter().map(|x| x.abs()).sum();
        let primal = primal_value(&slack, l1, lam);
        let dualv = dual_value(task, &theta, y, lam);
        let radius = safe_radius(primal, dualv, lam);

        let mut screen = SppScreen::new(task, y, &theta, radius);
        let t1 = Instant::now();
        let stats = {
            let mut counting = Counting::new(&mut screen);
            db.traverse(cfg.maxpat, cfg.minsup, &mut counting);
            counting.stats
        };
        let mut traverse_secs = t1.elapsed().as_secs_f64();
        let mut stats = stats;

        // (2) Â = survivors ∪ previously-active patterns (the latter are
        // kept even if tolerance slop screened them; safety tests verify
        // this set is a superset of the true active set).  Patterns with
        // *identical support columns* are collapsed to one
        // representative — redundant columns change neither the optimal
        // objective nor the fitted model, and dominate |Â| on dense
        // data.  Previous representatives are inserted first so warm
        // starts transfer exactly.
        let mut new_ws = WorkingSet::new();
        let mut seen: std::collections::HashMap<Vec<u32>, usize> =
            std::collections::HashMap::new();
        for (i, p) in ws.patterns.iter().enumerate() {
            if w[i] != 0.0 {
                let idx = new_ws.insert(p.clone(), ws.supports[i].clone());
                seen.entry(ws.supports[i].clone()).or_insert(idx);
            }
        }
        for s in screen.survivors {
            if seen.contains_key(&s.support) {
                continue;
            }
            let idx = new_ws.insert(s.pattern, s.support.clone());
            seen.insert(s.support, idx);
        }
        let w0 = new_ws.transfer_weights(&ws, &w);
        ws = new_ws;

        // (3) restricted solve, warm-started.
        let t2 = Instant::now();
        let sol = solver.solve_restricted(task, &ws.supports, y, lam, &w0, b);
        let solve_secs = t2.elapsed().as_secs_f64();
        w = sol.w.clone();
        b = sol.b;
        slack = sol.slack.clone();
        theta = sol.theta.clone();

        // (4) optional exact feasibility pass for the *next* screening.
        if cfg.certify {
            let t3 = Instant::now();
            let c = certify(db, y, task, &theta, cfg.maxpat, cfg.minsup);
            traverse_secs += t3.elapsed().as_secs_f64();
            stats.nodes += c.stats.nodes;
            stats.pruned += c.stats.pruned;
            theta = c.theta;
        }

        let active: Vec<(Pattern, f64)> = ws
            .patterns
            .iter()
            .zip(&w)
            .filter(|(_, &wi)| wi != 0.0)
            .map(|(p, &wi)| (p.clone(), wi))
            .collect();
        points.push(PathPoint {
            lambda: lam,
            active,
            b,
            gap: sol.gap,
            traverse_secs,
            solve_secs,
            stats,
            working_size: ws.len(),
            rounds: 1,
            cd_epochs: sol.epochs,
        });
    }

    PathResult {
        lambda_max: lm.lambda_max,
        points,
    }
}

/// The boosting baseline over the same grid (paper §2.2 / §4).
pub fn compute_path_boosting<S: PatternSubstrate>(
    db: &S,
    y: &[f64],
    task: Task,
    cfg: &PathConfig,
) -> PathResult {
    let n = y.len();
    assert_eq!(db.n_records(), n);

    let t0 = Instant::now();
    let lm = lambda_max(db, y, task, cfg.maxpat, cfg.minsup);
    let lmax_secs = t0.elapsed().as_secs_f64();
    let grid = lambda_grid(lm.lambda_max, cfg.n_lambdas, cfg.lambda_min_ratio);

    let bcfg = BoostingConfig {
        k_add: cfg.k_add,
        viol_tol: cfg.viol_tol,
        max_rounds: 10_000,
        cd: cfg.cd,
    };

    let mut points: Vec<PathPoint> = Vec::with_capacity(grid.len());
    points.push(PathPoint {
        lambda: grid[0],
        active: Vec::new(),
        b: lm.b0,
        gap: 0.0,
        traverse_secs: lmax_secs,
        solve_secs: 0.0,
        stats: lm.stats,
        working_size: 0,
        rounds: 1,
        cd_epochs: 0,
    });

    let mut ws = WorkingSet::new();
    let mut w: Vec<f64> = Vec::new();
    let mut b = lm.b0;
    for &lam in &grid[1..] {
        let out = boosting_solve(
            db, y, task, lam, cfg.maxpat, cfg.minsup, &mut ws, &mut w, &mut b, &bcfg,
        );
        let active: Vec<(Pattern, f64)> = ws
            .patterns
            .iter()
            .zip(&w)
            .filter(|(_, &wi)| wi != 0.0)
            .map(|(p, &wi)| (p.clone(), wi))
            .collect();
        points.push(PathPoint {
            lambda: lam,
            active,
            b,
            gap: out.solution.gap,
            traverse_secs: out.traverse_secs,
            solve_secs: out.solve_secs,
            stats: out.stats,
            working_size: ws.len(),
            rounds: out.rounds,
            cd_epochs: out.solution.epochs,
        });
    }

    PathResult {
        lambda_max: lm.lambda_max,
        points,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_itemsets::{generate, ItemsetSynthConfig};

    fn tiny_cfg() -> PathConfig {
        PathConfig {
            n_lambdas: 10,
            lambda_min_ratio: 0.05,
            maxpat: 3,
            ..PathConfig::default()
        }
    }

    #[test]
    fn grid_is_log_spaced_and_anchored() {
        let g = lambda_grid(10.0, 5, 0.01);
        assert_eq!(g.len(), 5);
        assert!((g[0] - 10.0).abs() < 1e-12);
        assert!((g[4] - 0.1).abs() < 1e-9);
        // constant ratio
        for i in 1..5 {
            assert!((g[i] / g[i - 1] - g[1] / g[0]).abs() < 1e-9);
        }
    }

    #[test]
    fn spp_and_boosting_paths_agree() {
        for (seed, classify) in [(21u64, false), (22, true)] {
            let d = generate(&ItemsetSynthConfig::tiny(seed, classify));
            let task = if classify {
                Task::Classification
            } else {
                Task::Regression
            };
            let cfg = tiny_cfg();
            let spp = compute_path_spp(&d.db, &d.y, task, &cfg);
            let boost = compute_path_boosting(&d.db, &d.y, task, &cfg);
            assert_eq!(spp.points.len(), boost.points.len());
            for (a, b) in spp.points.iter().zip(&boost.points) {
                // same objective value at every λ (both are optimal)
                let pa = objective_of(a, &d.y, task);
                let pb = objective_of(b, &d.y, task);
                assert!(
                    (pa - pb).abs() < 1e-3 * (1.0 + pa.abs()),
                    "λ={}: {} vs {}",
                    a.lambda,
                    pa,
                    pb
                );
            }
        }
    }

    /// Recompute the primal objective of a path point from scratch
    /// (independent check; uses the recorded active set only).
    fn objective_of(p: &PathPoint, y: &[f64], task: Task) -> f64 {
        // reconstruct supports from the pattern identity is not possible
        // here without the db; use slack-free definition via stats
        // instead: rely on gap + recorded active-set weights is overkill;
        // this helper only sums |w| and uses gap-certified primal via
        // b and weights on the stored supports — so instead we check the
        // recorded gap is tiny and compare sparsity + intercepts.
        let _ = (y, task);
        let l1: f64 = p.active.iter().map(|(_, w)| w.abs()).sum();
        assert!(p.gap <= 2e-6, "uncertified point at λ={}", p.lambda);
        l1 + p.b // proxy: identical optima ⇒ identical (‖w‖₁, b)
    }

    #[test]
    fn spp_visits_fewer_nodes_than_boosting() {
        let d = generate(&ItemsetSynthConfig::tiny(23, false));
        let cfg = tiny_cfg();
        let spp = compute_path_spp(&d.db, &d.y, Task::Regression, &cfg);
        let boost = compute_path_boosting(&d.db, &d.y, Task::Regression, &cfg);
        assert!(
            spp.total_nodes() <= boost.total_nodes(),
            "spp {} vs boosting {}",
            spp.total_nodes(),
            boost.total_nodes()
        );
    }

    #[test]
    fn active_set_grows_as_lambda_shrinks() {
        let d = generate(&ItemsetSynthConfig::tiny(24, false));
        let spp = compute_path_spp(&d.db, &d.y, Task::Regression, &tiny_cfg());
        let first_active = spp.points[1].active.len();
        let last_active = spp.points.last().unwrap().active.len();
        assert!(last_active >= first_active);
        assert!(spp.points[0].active.is_empty());
    }

    #[test]
    fn certify_mode_keeps_paths_identical() {
        let d = generate(&ItemsetSynthConfig::tiny(25, false));
        let mut cfg = tiny_cfg();
        let plain = compute_path_spp(&d.db, &d.y, Task::Regression, &cfg);
        cfg.certify = true;
        let certified = compute_path_spp(&d.db, &d.y, Task::Regression, &cfg);
        for (a, b) in plain.points.iter().zip(&certified.points) {
            assert_eq!(a.active.len(), b.active.len(), "λ={}", a.lambda);
            assert!((a.b - b.b).abs() < 1e-6);
        }
        // certification costs extra traversal
        assert!(certified.total_nodes() >= plain.total_nodes());
    }
}
