//! Regularization-path computation (paper Algorithm 1), incremental by
//! default.
//!
//! A log-spaced grid of `n_lambdas` penalties from `λ_max` down to
//! `lambda_min_ratio · λ_max` (the paper uses 100 and 0.01).  Both
//! methods run with warm starts:
//!
//! * **SPP**: per λ, one screening pass with the SPP rule built from
//!   the previous λ's primal/dual pair, then *one* restricted solve on
//!   Â.  By default the screening pass runs on the **incremental
//!   screening forest** ([`crate::screening::forest`]): the pruned
//!   pattern tree of earlier λs is re-evaluated in place (interned
//!   support columns, λ-range drift certificates) and the substrate is
//!   re-entered only below frontier nodes whose SPPC climbed back —
//!   `reuse_forest: false` (CLI `--no-reuse`) restores the
//!   paper-literal from-scratch traversal for ablation.  Both modes
//!   produce bit-identical paths (pinned by `tests/integration_forest`).
//! * **boosting**: per λ, constraint-generation rounds (search + solve
//!   per round) on a working set inherited across the path.
//!
//! Support columns live once in a [`SupportPool`]; the working set, the
//! identical-column dedup and the restricted solver all reference them
//! by [`SupportId`].  Every per-λ record captures the figures' currency
//! — traverse seconds, solve seconds, traversed node count, |Â|, the
//! certified duality gap — plus the reuse telemetry in
//! [`PathPoint::reuse`] and the thread utilisation in
//! [`PathPoint::threads`].
//!
//! The SPP engine is **deterministically parallel**
//! ([`PathConfig::threads`], CLI `--threads N`): scratch-mode screening
//! farms substrate subtrees to the `runtime::parallel` pool, forest
//! mode chunks the stored-node re-check across it, and CV runs folds on
//! it — all with results spliced back in canonical order, so every
//! worker count produces bit-identical paths (`--threads 1` is
//! byte-for-byte the sequential engine; pinned by
//! `tests/integration_parallel.rs` and CI's `test-matrix`).
//!
//! The grid itself is solved in **chunks** ([`PathConfig::range_chunk`],
//! CLI `--range-chunk C`): with `C > 1` the engine evaluates the
//! range-based SPP bound of [`crate::screening::range`] once per chunk
//! of `C` grid points — one substrate mine at the interval radius
//! materializes every subtree any λ in the chunk can need — and each
//! λ then re-derives its *exact* survivor set from the stored columns
//! (the screening-forest walk; a frontier that still climbs back is
//! re-opened, so exactness never rests on the interval bound).  Chunked
//! and per-λ engines produce **bit-identical** paths — active sets,
//! weights, intercepts, gaps — differing only in where the traversal
//! work happens (pinned by `tests/integration_range.rs`; per-λ
//! telemetry of the trade lands in [`ReuseStats::chunk_mine_nodes`] and
//! [`ReuseStats::chunk_hit`]).  `C = 1` (the default) is the classic
//! one-search-per-λ engine; `0` resolves the `SPP_RANGE_CHUNK`
//! environment variable (CI's test-matrix runs the suite both ways).
//!
//! All of the above is **one loop**: the per-λ scaffolding (λ_max
//! guard + grid, the [`screening::pool::SupportPool`](crate::screening::pool::SupportPool)
//! with its budget and spill accounting, chunk walk, [`PathPoint`]
//! emission) lives once in [`driver::PathDriver`], parameterized by an
//! [`driver::ActiveSetStrategy`] — [`driver::SppStrategy`] and
//! [`driver::BoostingStrategy`] are the two shipped methods, and the
//! `compute_path_*` entry points below are thin wrappers over them.
//! CV folds call those wrappers, so K-fold runs the same driver.  A
//! new path method (e.g. a selective-inference layer) is one new
//! strategy, not a new loop.

pub mod cv;
pub mod driver;
pub mod working_set;

use crate::columns::{ColumnLayout, ColumnView};
use crate::mining::{Pattern, PatternSubstrate, TraverseStats};
use crate::runtime::parallel::ThreadStats;
use crate::screening::pool::SpillStats;
use crate::solver::{CdConfig, CdSolver, Task};

pub use driver::{
    ActiveSetStrategy, BoostingStrategy, PathDriver, PathState, SppStrategy, StepOutcome,
};

/// Path configuration shared by both methods.
#[derive(Clone, Copy, Debug)]
pub struct PathConfig {
    /// Grid size (paper: 100).
    pub n_lambdas: usize,
    /// `λ_min / λ_max` (paper: 0.01).
    pub lambda_min_ratio: f64,
    /// Maximum pattern size (items / edges).
    pub maxpat: usize,
    /// Minimum support for enumeration.
    pub minsup: usize,
    /// Restricted-solver settings (gap tolerance 1e-6, as in the paper;
    /// `cd.dynamic_screen` toggles in-solve gap-safe screening).
    pub cd: CdConfig,
    /// Run the exact feasibility pass per λ (extension; see
    /// `screening::certify`).
    pub certify: bool,
    /// Reuse the screening forest across λ steps (the incremental
    /// engine; `false` = paper-literal from-scratch traversal per λ).
    pub reuse_forest: bool,
    /// Worker count for the deterministic parallel engine (subtree
    /// traversal, forest re-checks, CV folds): `0` = auto
    /// (`SPP_THREADS` env, else available parallelism), `1` =
    /// byte-for-byte the sequential engine, `N` = that many pool
    /// workers.  Any value produces bit-identical paths
    /// (`tests/integration_parallel.rs`).
    pub threads: usize,
    /// λ grid points per screening chunk (range-based SPP; see
    /// `screening::range`): `1` = one screening pass per λ (the paper's
    /// Algorithm 1 cadence), `C > 1` = one substrate mine at the
    /// interval radius per chunk of `C` λs, each λ then screened
    /// exactly against the stored columns.  `0` = auto (`SPP_RANGE_CHUNK`
    /// env, else 1).  Every value produces bit-identical paths
    /// (`tests/integration_range.rs`).
    pub range_chunk: usize,
    /// Support-column layout of the path's [`SupportPool`] (CLI
    /// `--columns sparse|hybrid`): `Hybrid` interns columns with dense
    /// 64-bit bitmap chunks so the screening folds and the CD solver
    /// run word kernels, `Sparse` keeps plain sorted id lists (the
    /// scalar oracle).  `None` = auto (`SPP_COLUMNS` env, else hybrid).
    /// Both layouts produce bit-identical paths
    /// (`tests/integration_columns.rs`).
    pub columns: Option<ColumnLayout>,
    /// Resident-byte ceiling for the path's [`SupportPool`] (CLI
    /// `--memory-budget BYTES`): least-recently-touched support columns
    /// spill to a temp file and reload on demand, with per-λ telemetry
    /// in [`PathPoint::spill`].  `0` = auto (`SPP_MEMORY_BUDGET` env,
    /// else unlimited).  Columns reload byte-identical, so every budget
    /// produces bit-identical paths (`tests/integration_shards.rs`);
    /// the from-scratch per-λ engine (`--no-reuse --range-chunk 1`)
    /// additionally holds the ceiling *during* screening, while
    /// forest-walking engines restore full residency per walk and spill
    /// back down between λs.
    pub memory_budget: usize,
    /// Boosting: patterns added per round.
    pub k_add: usize,
    /// Boosting: violation tolerance.
    pub viol_tol: f64,
}

impl Default for PathConfig {
    fn default() -> Self {
        PathConfig {
            n_lambdas: 100,
            lambda_min_ratio: 0.01,
            maxpat: 4,
            minsup: 1,
            cd: CdConfig::default(),
            certify: false,
            reuse_forest: true,
            threads: 0,
            range_chunk: 0,
            columns: None,
            memory_budget: 0,
            k_add: 1,
            viol_tol: 1e-6,
        }
    }
}

/// Reuse telemetry of one λ step.  The forest fields are zero in
/// scratch mode and for boosting; `solver_screened` is populated by
/// every engine whenever the CD solver's dynamic screening is on.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ReuseStats {
    /// Stored forest nodes decided from interned columns (no substrate
    /// work).
    pub forest_hits: u64,
    /// Of those, skipped by the λ-range drift certificate alone.
    pub cert_skips: u64,
    /// Frontier subtrees re-opened (substrate re-entered below them).
    pub reopened: u64,
    /// Columns frozen by the solver's dynamic gap-safe screening.
    pub solver_screened: usize,
    /// Substrate nodes spent by the chunk pre-mine this λ leads (the
    /// one interval-radius traversal of range-based SPP; `0` on
    /// non-leading λs and in per-λ mode).  Also counted in
    /// [`PathPoint::stats`] — this field says how much of that work was
    /// the chunk mine.  The pre-mine's forest telemetry (stored-node
    /// hits, certificate skips, re-opened frontiers) is merged into the
    /// leading λ's counters above, so chunked-mode totals stay honest.
    pub chunk_mine_nodes: u64,
    /// Chunked mode only, non-leading λs: this λ's screen needed no
    /// substrate re-entry — it was fully served by stored columns (a
    /// `false` on a non-leading λ under chunking means a frontier
    /// climbed back past the interval bound and was re-opened).
    /// Always `false` on chunk leaders (their substrate bill is the
    /// pre-mine itself) and in per-λ mode.  With the *persistent*
    /// forest the credit is shared: earlier λs' trees serve screens
    /// too, so the scratch family (`--no-reuse`), where the chunk
    /// pre-mine is the only possible source of stored columns, is the
    /// clean ablation readout (benches/ablation_range.rs).
    pub chunk_hit: bool,
}

/// Per-λ record.
#[derive(Clone, Debug)]
pub struct PathPoint {
    pub lambda: f64,
    /// Active patterns with their optimal weights.
    pub active: Vec<(Pattern, f64)>,
    pub b: f64,
    pub gap: f64,
    /// Seconds spent searching trees at this λ.
    pub traverse_secs: f64,
    /// Seconds spent in the restricted solver at this λ.
    pub solve_secs: f64,
    /// Substrate visitor invocations (real tree work only: in forest
    /// mode, stored-forest hits are in `reuse`, not here).
    pub stats: TraverseStats,
    /// |Â| (SPP) or working-set size (boosting) when solving.
    pub working_size: usize,
    /// Constraint-generation rounds (1 for SPP).
    pub rounds: usize,
    pub cd_epochs: usize,
    /// Incremental-engine telemetry.
    pub reuse: ReuseStats,
    /// Thread utilisation of this λ's screening phase (workers used,
    /// tasks farmed; `workers == 1` for a sequential pass).
    pub threads: ThreadStats,
    /// Column-pool spill telemetry: residency gauges after this λ's
    /// budget enforcement, plus this λ's reload/eviction deltas (all
    /// zero without `--memory-budget`).
    pub spill: SpillStats,
}

/// Whole-path result.
#[derive(Clone, Debug)]
pub struct PathResult {
    pub lambda_max: f64,
    pub points: Vec<PathPoint>,
}

impl PathResult {
    pub fn total_traverse_secs(&self) -> f64 {
        self.points.iter().map(|p| p.traverse_secs).sum()
    }

    pub fn total_solve_secs(&self) -> f64 {
        self.points.iter().map(|p| p.solve_secs).sum()
    }

    pub fn total_nodes(&self) -> u64 {
        self.points.iter().map(|p| p.stats.nodes).sum()
    }

    pub fn total_secs(&self) -> f64 {
        self.total_traverse_secs() + self.total_solve_secs()
    }

    /// Stored-forest evaluations across the path (reuse telemetry).
    pub fn total_forest_hits(&self) -> u64 {
        self.points.iter().map(|p| p.reuse.forest_hits).sum()
    }

    /// Frontier subtrees re-opened across the path.
    pub fn total_reopened(&self) -> u64 {
        self.points.iter().map(|p| p.reuse.reopened).sum()
    }

    /// Columns frozen by in-solve dynamic screening across the path.
    pub fn total_solver_screened(&self) -> usize {
        self.points.iter().map(|p| p.reuse.solver_screened).sum()
    }

    /// Substrate nodes spent by chunk pre-mines across the path
    /// (range-based SPP; 0 in per-λ mode).
    pub fn total_chunk_mine_nodes(&self) -> u64 {
        self.points.iter().map(|p| p.reuse.chunk_mine_nodes).sum()
    }

    /// λ steps whose screen was fully served by their chunk's stored
    /// tree (no substrate re-entry; 0 in per-λ mode).
    pub fn chunk_hits(&self) -> usize {
        self.points.iter().filter(|p| p.reuse.chunk_hit).count()
    }

    /// Peak of the per-λ resident-byte gauges — what the A6 bench
    /// reports as the pool's memory ceiling under `--memory-budget`.
    pub fn max_resident_bytes(&self) -> usize {
        self.points.iter().map(|p| p.spill.resident_bytes).max().unwrap_or(0)
    }

    /// Columns reloaded from the spill file across the path.
    pub fn total_spill_reloads(&self) -> u64 {
        self.points.iter().map(|p| p.spill.reloaded).sum()
    }

    /// Columns evicted to the spill file across the path.
    pub fn total_spill_evictions(&self) -> u64 {
        self.points.iter().map(|p| p.spill.evicted).sum()
    }
}

/// The λ grid: `n` log-spaced values from `λ_max` to `ratio·λ_max`.
pub fn lambda_grid(lambda_max: f64, n: usize, ratio: f64) -> Vec<f64> {
    assert!(n >= 2 && ratio > 0.0 && ratio < 1.0);
    (0..n)
        .map(|k| lambda_max * ratio.powf(k as f64 / (n - 1) as f64))
        .collect()
}

/// A restricted-problem solver (paper eq. 6) pluggable into the path:
/// the default is the in-process CD solver; the XLA engine
/// (`runtime::engine`) implements this over the AOT FISTA artifacts.
/// Columns arrive as views borrowed from the path's [`SupportPool`].
pub trait RestrictedSolver {
    fn solve_restricted(
        &self,
        task: Task,
        supports: &[ColumnView<'_>],
        y: &[f64],
        lam: f64,
        warm_w: &[f64],
        warm_b: f64,
    ) -> crate::solver::Solution;
}

/// The default engine: pure-Rust coordinate descent.
pub struct CdRestricted(pub CdSolver);

impl RestrictedSolver for CdRestricted {
    fn solve_restricted(
        &self,
        task: Task,
        supports: &[ColumnView<'_>],
        y: &[f64],
        lam: f64,
        warm_w: &[f64],
        warm_b: f64,
    ) -> crate::solver::Solution {
        self.0.solve(
            task,
            supports,
            y,
            lam,
            Some(crate::solver::cd::Warm {
                w: warm_w,
                b: warm_b,
            }),
        )
    }
}

/// Algorithm 1: SPP regularization path (default CD engine) on any
/// [`PatternSubstrate`].
///
/// Errors when the problem is degenerate: a constant regression target
/// or a single-class classification split makes `λ_max = 0` (the
/// all-zero model is already optimal everywhere) and the log grid
/// would collapse to zero, running the solver effectively
/// unregularized.
pub fn compute_path_spp<S: PatternSubstrate>(
    db: &S,
    y: &[f64],
    task: Task,
    cfg: &PathConfig,
) -> crate::Result<PathResult> {
    let solver = CdRestricted(CdSolver::new(cfg.cd));
    compute_path_spp_with(db, y, task, cfg, &solver)
}

/// Reject a degenerate λ_max before a grid is built on it: `λ_max <= 0`
/// or non-finite means every pattern column is exactly uncorrelated
/// with the zero-model slacks — a constant regression target, or a
/// classification split where one class is absent (the hinge intercept
/// sits at ±1 and every slack is 0).  A grid anchored there would be
/// all zeros and the CD solver would run effectively unregularized, so
/// the path entry points surface this as an error instead (the CV
/// driver names the offending fold).
fn lambda_max_guard(lambda_max: f64, task: Task) -> crate::Result<()> {
    if lambda_max.is_finite() && lambda_max > 0.0 {
        return Ok(());
    }
    let (name, cause) = match task {
        Task::Regression => ("regression", "effectively constant"),
        Task::Classification => ("classification", "e.g. a single-class (training) split"),
    };
    anyhow::bail!(
        "λ_max = {lambda_max} is not a positive finite value, so the λ grid would \
         collapse to zero and the solver would run unregularized; either no pattern \
         met the search bounds (minsup/maxpat) or the {name} target is degenerate \
         ({cause})"
    )
}

/// Algorithm 1 with an explicit restricted-solver engine: the
/// [`PathDriver`] running [`SppStrategy`].
///
/// With `cfg.range_chunk > 1` the grid is solved in chunks: one
/// substrate mine at the interval radius per chunk (the range-based
/// SPP bound, anchored at the pair entering the chunk) materializes
/// every subtree any λ in the chunk can need into the screening
/// forest; each λ then derives its exact survivor set from the stored
/// columns.  A fresh chunk-local forest is used when `reuse_forest` is
/// off, so the ablation baseline still never carries state across
/// chunks.  All engine shapes produce bit-identical paths.
pub fn compute_path_spp_with<S: PatternSubstrate>(
    db: &S,
    y: &[f64],
    task: Task,
    cfg: &PathConfig,
    solver: &dyn RestrictedSolver,
) -> crate::Result<PathResult> {
    let mut strategy = SppStrategy::new(cfg, solver);
    PathDriver::new(cfg).run(db, y, task, &mut strategy)
}

/// The boosting baseline over the same grid (paper §2.2 / §4): the
/// [`PathDriver`] running [`BoostingStrategy`].  `cfg.range_chunk` is
/// ignored (boosting has no screening pass to chunk); degenerate
/// targets error exactly like the SPP path.
pub fn compute_path_boosting<S: PatternSubstrate>(
    db: &S,
    y: &[f64],
    task: Task,
    cfg: &PathConfig,
) -> crate::Result<PathResult> {
    let mut strategy = BoostingStrategy::new(cfg);
    PathDriver::new(cfg).run(db, y, task, &mut strategy)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_itemsets::{generate, ItemsetSynthConfig};
    use crate::data::Transactions;
    use crate::solver::problem::primal_value;

    fn tiny_cfg() -> PathConfig {
        PathConfig {
            n_lambdas: 10,
            lambda_min_ratio: 0.05,
            maxpat: 3,
            ..PathConfig::default()
        }
    }

    #[test]
    fn grid_is_log_spaced_and_anchored() {
        let g = lambda_grid(10.0, 5, 0.01);
        assert_eq!(g.len(), 5);
        assert!((g[0] - 10.0).abs() < 1e-12);
        assert!((g[4] - 0.1).abs() < 1e-9);
        // constant ratio
        for i in 1..5 {
            assert!((g[i] / g[i - 1] - g[1] / g[0]).abs() < 1e-9);
        }
    }

    /// The primal objective of a path point, recomputed from scratch:
    /// active-pattern supports are rebuilt from the database through
    /// the substrate matcher (independent of the miners and of any
    /// state the path recorded), the model margins follow, and the
    /// objective is `Σ f(slack) + λ‖w‖₁`.
    fn objective_of(p: &PathPoint, db: &Transactions, y: &[f64], task: Task) -> f64 {
        let n = y.len();
        let mut m = vec![p.b; n];
        for (pat, wt) in &p.active {
            for i in 0..n {
                if Transactions::matches(pat, db.record(i)) {
                    m[i] += wt;
                }
            }
        }
        let slack: Vec<f64> = match task {
            Task::Regression => y.iter().zip(&m).map(|(&yi, &mi)| yi - mi).collect(),
            Task::Classification => y
                .iter()
                .zip(&m)
                .map(|(&yi, &mi)| (1.0 - yi * mi).max(0.0))
                .collect(),
        };
        let l1: f64 = p.active.iter().map(|(_, wt)| wt.abs()).sum();
        primal_value(&slack, l1, p.lambda)
    }

    #[test]
    fn spp_and_boosting_paths_agree() {
        for (seed, classify) in [(21u64, false), (22, true)] {
            let d = generate(&ItemsetSynthConfig::tiny(seed, classify));
            let task = if classify {
                Task::Classification
            } else {
                Task::Regression
            };
            let cfg = tiny_cfg();
            let spp = compute_path_spp(&d.db, &d.y, task, &cfg).unwrap();
            let boost = compute_path_boosting(&d.db, &d.y, task, &cfg).unwrap();
            assert_eq!(spp.points.len(), boost.points.len());
            for (a, b) in spp.points.iter().zip(&boost.points) {
                // both methods must reach the same true objective value
                // at every λ (recomputed independently from the
                // database — both are certified optimal to 1e-6)
                assert!(a.gap <= 2e-6 && b.gap <= 2e-6, "uncertified λ={}", a.lambda);
                let pa = objective_of(a, &d.db, &d.y, task);
                let pb = objective_of(b, &d.db, &d.y, task);
                assert!(
                    (pa - pb).abs() < 1e-4 * (1.0 + pa.abs()),
                    "λ={}: objective {} vs {}",
                    a.lambda,
                    pa,
                    pb
                );
            }
        }
    }

    #[test]
    fn recorded_gap_certifies_the_recomputed_objective() {
        // Full certification of the recorded (active, b, gap) triple:
        // the primal recomputed from the database must sit within the
        // certified gap of the FULL-problem optimum, solved here to
        // high precision over an exhaustive pattern enumeration
        // (independent of the miners and of the path machinery).
        let d = generate(&ItemsetSynthConfig::tiny(26, false));
        let cfg = tiny_cfg();
        let path = compute_path_spp(&d.db, &d.y, Task::Regression, &cfg).unwrap();
        let all = crate::testutil::oracle::all_itemsets(&d.db, cfg.maxpat);
        let supports: Vec<Vec<u32>> = all.into_iter().map(|(_, s)| s).collect();
        let mut oracle = CdSolver::default();
        oracle.cfg.tol = 1e-10;
        for p in &path.points[1..] {
            assert!(p.gap <= 2e-6, "λ={} gap {}", p.lambda, p.gap);
            let primal = objective_of(p, &d.db, &d.y, Task::Regression);
            let opt = oracle
                .solve(Task::Regression, &supports, &d.y, p.lambda, None)
                .primal;
            assert!(
                primal >= opt - 1e-8 * (1.0 + opt.abs()),
                "λ={}: recomputed primal {primal} beats the optimum {opt}",
                p.lambda
            );
            // certificate validity: primal − optimum ≤ gap, plus the
            // tolerance-level slop Algorithm 1 accepts in the screening
            // pair's full-space dual feasibility (see integration_safety)
            assert!(
                primal - opt <= p.gap + 2e-6 * (1.0 + opt.abs()),
                "λ={}: recomputed primal {primal} exceeds optimum {opt} by more \
                 than the certified gap {}",
                p.lambda,
                p.gap
            );
        }
    }

    #[test]
    fn spp_visits_fewer_nodes_than_boosting() {
        let d = generate(&ItemsetSynthConfig::tiny(23, false));
        // node-count comparison: per-λ engine pinned (chunking moves
        // the traversal bill; its contract lives in integration_range)
        let mut cfg = tiny_cfg();
        cfg.range_chunk = 1;
        let spp = compute_path_spp(&d.db, &d.y, Task::Regression, &cfg).unwrap();
        let boost = compute_path_boosting(&d.db, &d.y, Task::Regression, &cfg).unwrap();
        assert!(
            spp.total_nodes() <= boost.total_nodes(),
            "spp {} vs boosting {}",
            spp.total_nodes(),
            boost.total_nodes()
        );
    }

    #[test]
    fn active_set_grows_as_lambda_shrinks() {
        let d = generate(&ItemsetSynthConfig::tiny(24, false));
        let spp = compute_path_spp(&d.db, &d.y, Task::Regression, &tiny_cfg()).unwrap();
        let first_active = spp.points[1].active.len();
        let last_active = spp.points.last().unwrap().active.len();
        assert!(last_active >= first_active);
        assert!(spp.points[0].active.is_empty());
    }

    #[test]
    fn certify_mode_keeps_paths_identical() {
        let d = generate(&ItemsetSynthConfig::tiny(25, false));
        let mut cfg = tiny_cfg();
        // the traversal-cost assertion below is a per-λ-engine property
        cfg.range_chunk = 1;
        let plain = compute_path_spp(&d.db, &d.y, Task::Regression, &cfg).unwrap();
        cfg.certify = true;
        let certified = compute_path_spp(&d.db, &d.y, Task::Regression, &cfg).unwrap();
        for (a, b) in plain.points.iter().zip(&certified.points) {
            assert_eq!(a.active.len(), b.active.len(), "λ={}", a.lambda);
            assert!((a.b - b.b).abs() < 1e-6);
        }
        // certification costs extra traversal
        assert!(certified.total_nodes() >= plain.total_nodes());
    }

    #[test]
    fn forest_reuse_records_telemetry() {
        let d = generate(&ItemsetSynthConfig::tiny(27, false));
        // per-λ engine pinned: the assertions below describe its exact
        // telemetry shape (a chunked run records chunk hits instead)
        let mut cfg = tiny_cfg();
        cfg.range_chunk = 1;
        let path = compute_path_spp(&d.db, &d.y, Task::Regression, &cfg).unwrap();
        assert!(
            path.total_forest_hits() > 0,
            "incremental engine never evaluated a stored node"
        );
        // first screening λ builds the forest (no hits yet)
        assert_eq!(path.points[1].reuse.forest_hits, 0);
        assert!(path.points[1].stats.nodes > 0);
        // per-λ mode records no chunk telemetry
        assert_eq!(path.total_chunk_mine_nodes(), 0);
        assert_eq!(path.chunk_hits(), 0);
    }

    #[test]
    fn chunked_engine_is_bit_identical_and_records_chunk_telemetry() {
        let d = generate(&ItemsetSynthConfig::tiny(28, false));
        for reuse in [true, false] {
            let mut per_lambda = tiny_cfg();
            per_lambda.range_chunk = 1;
            per_lambda.reuse_forest = reuse;
            let mut chunked = per_lambda;
            chunked.range_chunk = 4;
            let a = compute_path_spp(&d.db, &d.y, Task::Regression, &per_lambda).unwrap();
            let b = compute_path_spp(&d.db, &d.y, Task::Regression, &chunked).unwrap();
            assert_eq!(a.points.len(), b.points.len());
            for (p, q) in a.points.iter().zip(&b.points) {
                assert_eq!(p.lambda.to_bits(), q.lambda.to_bits());
                assert_eq!(p.active.len(), q.active.len(), "λ={}", p.lambda);
                for ((pa, wa), (pb, wb)) in p.active.iter().zip(&q.active) {
                    assert_eq!(pa, pb);
                    assert_eq!(wa.to_bits(), wb.to_bits(), "reuse={reuse} λ={}", p.lambda);
                }
                assert_eq!(p.b.to_bits(), q.b.to_bits());
                assert_eq!(p.gap.to_bits(), q.gap.to_bits());
                assert_eq!(p.working_size, q.working_size);
            }
            // the chunked run actually chunked: pre-mines happened and
            // most λs were served from the stored chunk tree
            assert!(b.total_chunk_mine_nodes() > 0, "reuse={reuse}: no chunk pre-mine ran");
            assert!(b.chunk_hits() > 0, "reuse={reuse}: no λ hit its chunk's stored tree");
            assert_eq!(a.total_chunk_mine_nodes(), 0);
        }
    }

    #[test]
    fn degenerate_lambda_max_is_a_clear_error() {
        let d = generate(&ItemsetSynthConfig::tiny(29, false));
        // constant regression target: every slack is 0 after centering
        let y = vec![3.25; d.y.len()];
        let err = compute_path_spp(&d.db, &y, Task::Regression, &tiny_cfg()).unwrap_err();
        assert!(err.to_string().contains("λ_max"), "{err}");
        let err = compute_path_boosting(&d.db, &y, Task::Regression, &tiny_cfg()).unwrap_err();
        assert!(err.to_string().contains("unregularized"), "{err}");
        // single-class classification split: hinge intercept ±1, slacks 0
        let y = vec![1.0; d.y.len()];
        let err = compute_path_spp(&d.db, &y, Task::Classification, &tiny_cfg()).unwrap_err();
        assert!(err.to_string().contains("single-class"), "{err}");
    }
}
