//! `spp serve` — a persistent prediction service.
//!
//! The serve layer keeps fitted [`crate::model::SparsePatternModel`]s
//! resident and answers scoring requests over a line-delimited JSON
//! protocol ([`protocol`]), on stdin/stdout ([`run_stdio`]) or a Unix
//! domain socket ([`run_unix_socket`]). The payoff over `spp predict`
//! is the compiled matcher ([`compiled`]): patterns are specialized
//! into a per-substrate index at load time, so a score batch walks
//! each record once instead of once per pattern, while staying
//! bit-identical to the naive per-pattern scorer.
//!
//! Design invariants:
//!
//! - **Errors never kill the process.** A malformed line, an unknown
//!   op, a bad model, an oversized or non-UTF-8 line — each produces
//!   one `"ok":false` response and the loop keeps reading.
//! - **Responses are deterministic.** One response per request, in
//!   request order; object fields emit in fixed order; batch scoring
//!   splices chunk results in record order, so output bytes are
//!   identical at any `--threads` value. Stats report counters only
//!   (no wall-clock), so whole sessions replay byte-for-byte — CI
//!   pipes a canned session through the binary and diffs a golden
//!   transcript.
//! - **Hot reload.** `load` for an already-served kind swaps the
//!   model between requests; the next `score` sees the new weights.

pub mod compiled;
pub mod protocol;
pub mod registry;

use std::io::{BufRead, Read, Write};

use crate::runtime::parallel::resolve_threads;
use crate::solver::Task;

use protocol::{
    decode_records, err_line, obj, ok_line, Json, Matcher, ModelSource, RecordBatch, Request,
};
use registry::ModelRegistry;

/// Upper bound on one request line (inline models included); longer
/// lines are drained and answered with an error instead of buffering
/// without bound.
const MAX_LINE_BYTES: u64 = 64 * 1024 * 1024;

/// The serving engine: registry, thread budget, and session counters.
/// Transport-agnostic — [`run_session`] drives it over any
/// `BufRead`/`Write` pair, which is also how the integration tests
/// exercise full sessions in memory.
pub struct ServeEngine {
    registry: ModelRegistry,
    threads: usize,
    requests: u64,
    errors: u64,
    loads: u64,
    unloads: u64,
    score_batches: u64,
    records_scored: u64,
}

/// One handled request: the response line (no newline) and whether
/// the session should stop.
pub struct Reply {
    pub line: String,
    pub shutdown: bool,
}

impl ServeEngine {
    /// `threads = 0` resolves through `SPP_THREADS` / available
    /// parallelism, like every other engine knob in the crate.
    pub fn new(threads: usize) -> ServeEngine {
        ServeEngine {
            registry: ModelRegistry::new(),
            threads: resolve_threads(threads),
            requests: 0,
            errors: 0,
            loads: 0,
            unloads: 0,
            score_batches: 0,
            records_scored: 0,
        }
    }

    /// Handle one request line and produce exactly one response line.
    pub fn handle_line(&mut self, line: &str) -> Reply {
        self.requests += 1;
        let (id, req) = protocol::parse_request(line);
        let outcome = req.and_then(|r| self.apply(r));
        match outcome {
            Ok((result, shutdown)) => Reply { line: ok_line(id.as_ref(), result), shutdown },
            Err(e) => {
                self.errors += 1;
                Reply { line: err_line(id.as_ref(), &format!("{e:#}")), shutdown: false }
            }
        }
    }

    fn apply(&mut self, req: Request) -> crate::Result<(Json, bool)> {
        match req {
            Request::Load { kind, source } => {
                self.do_load(kind.as_deref(), source).map(|r| (r, false))
            }
            Request::Unload { kind } => {
                let kind = self.registry.unload(&kind)?;
                self.unloads += 1;
                let result = obj(vec![
                    ("kind", Json::Str(kind.to_string())),
                    ("unloaded", Json::Bool(true)),
                ]);
                Ok((result, false))
            }
            Request::List => Ok((self.do_list(), false)),
            Request::Score { kind, records, matcher } => {
                self.do_score(&kind, &records, matcher).map(|r| (r, false))
            }
            Request::Stats => Ok((self.do_stats(), false)),
            Request::Shutdown => Ok((obj(vec![("shutting_down", Json::Bool(true))]), true)),
        }
    }

    fn do_load(&mut self, kind: Option<&str>, source: ModelSource) -> crate::Result<Json> {
        let text = match source {
            ModelSource::Inline(t) => t,
            ModelSource::File(path) => std::fs::read_to_string(&path)
                .map_err(|e| anyhow::anyhow!("cannot read model file '{path}': {e}"))?,
        };
        let report = self.registry.load(&text, kind)?;
        self.loads += 1;
        let entry = self.registry.get_mut(report.kind)?;
        Ok(obj(vec![
            ("kind", Json::Str(report.kind.to_string())),
            ("task", Json::Str(task_name(entry.model.task).to_string())),
            ("lambda", Json::Num(entry.model.lambda)),
            ("patterns", Json::Num(entry.model.terms.len() as f64)),
            ("compiled_terms", Json::Num(entry.compiled.stats.compiled_terms as f64)),
            ("index_nodes", Json::Num(entry.compiled.stats.index_nodes as f64)),
            ("reloaded", Json::Bool(report.reloaded)),
        ]))
    }

    fn do_list(&self) -> Json {
        let models = self
            .registry
            .iter()
            .map(|(kind, e)| {
                obj(vec![
                    ("kind", Json::Str(kind.to_string())),
                    ("task", Json::Str(task_name(e.model.task).to_string())),
                    ("lambda", Json::Num(e.model.lambda)),
                    ("patterns", Json::Num(e.model.terms.len() as f64)),
                    ("compiled_terms", Json::Num(e.compiled.stats.compiled_terms as f64)),
                    ("index_nodes", Json::Num(e.compiled.stats.index_nodes as f64)),
                    ("loads", Json::Num(e.loads as f64)),
                ])
            })
            .collect();
        obj(vec![("models", Json::Arr(models))])
    }

    fn do_score(&mut self, kind: &str, records: &Json, matcher: Matcher) -> crate::Result<Json> {
        let entry = self.registry.get_mut(kind)?;
        let batch = decode_records(entry.compiled.kind, records)?;
        let n = batch.len();
        let (scores, ops, matcher_name) = match matcher {
            Matcher::Compiled => {
                let threads = self.threads;
                let out = match &batch {
                    RecordBatch::Itemsets(rows) => entry.compiled.score_itemsets(rows, threads)?,
                    RecordBatch::Graphs(gs) => entry.compiled.score_graphs(gs, threads)?,
                    RecordBatch::Sequences(s) => entry.compiled.score_sequences(s, threads)?,
                    RecordBatch::Tabular(rows) => entry.compiled.score_tabular(rows, threads)?,
                };
                (out.scores, out.ops, "compiled")
            }
            Matcher::Naive => {
                // Differential oracle: one matcher call per
                // (record, pattern) pair, exactly `spp predict`'s path.
                let model = &entry.model;
                let scores: Vec<f64> = match &batch {
                    RecordBatch::Itemsets(rows) => {
                        rows.iter().map(|r| model.score_itemset(r)).collect()
                    }
                    RecordBatch::Graphs(gs) => gs.iter().map(|g| model.score_graph(g)).collect(),
                    RecordBatch::Sequences(seqs) => {
                        seqs.iter().map(|s| model.score_sequence(s)).collect()
                    }
                    RecordBatch::Tabular(rows) => {
                        rows.iter().map(|r| model.score_tabular_row(r)).collect()
                    }
                };
                let ops = (model.terms.len() as u64) * (n as u64);
                (scores, ops, "naive")
            }
        };
        entry.score_batches += 1;
        entry.records_scored += n as u64;
        self.score_batches += 1;
        self.records_scored += n as u64;
        let preds: Vec<Json> =
            scores.iter().map(|&s| Json::Num(entry.compiled.output(s))).collect();
        Ok(obj(vec![
            ("kind", Json::Str(entry.compiled.kind.to_string())),
            ("matcher", Json::Str(matcher_name.to_string())),
            ("n", Json::Num(n as f64)),
            ("ops", Json::Num(ops as f64)),
            ("scores", Json::Arr(scores.into_iter().map(Json::Num).collect())),
            ("preds", Json::Arr(preds)),
        ]))
    }

    /// Counters only — no wall-clock, no memory figures — so a
    /// replayed session produces byte-identical stats. The `requests`
    /// counter includes the stats request itself, and transport-level
    /// rejections (oversized or non-UTF-8 lines) count as requests
    /// and errors.
    fn do_stats(&self) -> Json {
        let models = self
            .registry
            .iter()
            .map(|(kind, e)| {
                obj(vec![
                    ("kind", Json::Str(kind.to_string())),
                    ("patterns", Json::Num(e.model.terms.len() as f64)),
                    ("loads", Json::Num(e.loads as f64)),
                    ("score_batches", Json::Num(e.score_batches as f64)),
                    ("records_scored", Json::Num(e.records_scored as f64)),
                ])
            })
            .collect();
        obj(vec![
            ("requests", Json::Num(self.requests as f64)),
            ("errors", Json::Num(self.errors as f64)),
            ("loads", Json::Num(self.loads as f64)),
            ("unloads", Json::Num(self.unloads as f64)),
            ("score_batches", Json::Num(self.score_batches as f64)),
            ("records_scored", Json::Num(self.records_scored as f64)),
            ("models", Json::Arr(models)),
        ])
    }
}

fn task_name(task: Task) -> &'static str {
    match task {
        Task::Regression => "regression",
        Task::Classification => "classification",
    }
}

/// Drive one session: read request lines, write response lines, one
/// per request in order, flushing after each. Returns `Ok(true)` on
/// an explicit `shutdown`, `Ok(false)` on EOF. Only genuine transport
/// failures (broken pipe, read errors other than invalid UTF-8)
/// propagate as `Err`.
pub fn run_session<R: BufRead, W: Write>(
    engine: &mut ServeEngine,
    mut reader: R,
    mut writer: W,
) -> std::io::Result<bool> {
    let mut buf = String::new();
    loop {
        buf.clear();
        let n = match reader.by_ref().take(MAX_LINE_BYTES).read_line(&mut buf) {
            Ok(n) => n,
            Err(e) if e.kind() == std::io::ErrorKind::InvalidData => {
                // Non-UTF-8 bytes: the offending line is consumed;
                // answer an error and keep serving.
                engine.requests += 1;
                engine.errors += 1;
                writeln!(writer, "{}", err_line(None, "request line is not valid UTF-8"))?;
                writer.flush()?;
                continue;
            }
            Err(e) => return Err(e),
        };
        if n == 0 {
            return Ok(false);
        }
        if n as u64 >= MAX_LINE_BYTES && !buf.ends_with('\n') {
            drain_line(&mut reader)?;
            engine.requests += 1;
            engine.errors += 1;
            writeln!(writer, "{}", err_line(None, "request line too long"))?;
            writer.flush()?;
            continue;
        }
        let line = buf.trim();
        if line.is_empty() {
            continue;
        }
        let reply = engine.handle_line(line);
        writeln!(writer, "{}", reply.line)?;
        writer.flush()?;
        if reply.shutdown {
            return Ok(true);
        }
    }
}

/// Discard the remainder of an over-long line, up to and including
/// its newline (or EOF).
fn drain_line<R: BufRead>(reader: &mut R) -> std::io::Result<()> {
    let mut chunk = Vec::new();
    loop {
        chunk.clear();
        let n = reader.by_ref().take(MAX_LINE_BYTES).read_until(b'\n', &mut chunk)?;
        if n == 0 || chunk.last() == Some(&b'\n') {
            return Ok(());
        }
    }
}

/// Serve on stdin/stdout until EOF or `shutdown`. Nothing but
/// response lines is written to stdout, so sessions pipe cleanly.
pub fn run_stdio(threads: usize) -> crate::Result<()> {
    let mut engine = ServeEngine::new(threads);
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    run_session(&mut engine, stdin.lock(), stdout.lock())?;
    Ok(())
}

/// Serve on a Unix domain socket, one connection at a time, until a
/// client sends `shutdown`. Models persist across connections —
/// that is the point of a resident service. A stale socket file from
/// a previous run is removed before binding.
#[cfg(unix)]
pub fn run_unix_socket(path: &str, threads: usize) -> crate::Result<()> {
    use std::os::unix::net::UnixListener;

    let _ = std::fs::remove_file(path);
    let listener = UnixListener::bind(path)
        .map_err(|e| anyhow::anyhow!("cannot bind socket '{path}': {e}"))?;
    eprintln!("spp serve: listening on {path}");
    let mut engine = ServeEngine::new(threads);
    let mut shutdown = false;
    while !shutdown {
        let (stream, _) = match listener.accept() {
            Ok(conn) => conn,
            Err(e) => {
                eprintln!("spp serve: accept failed: {e}");
                continue;
            }
        };
        let reader = std::io::BufReader::new(&stream);
        match run_session(&mut engine, reader, &stream) {
            Ok(stop) => shutdown = stop,
            // A dropped client must not take the server down.
            Err(e) => eprintln!("spp serve: connection error: {e}"),
        }
    }
    let _ = std::fs::remove_file(path);
    Ok(())
}

/// Socket serving is Unix-only; elsewhere the request is an error.
#[cfg(not(unix))]
pub fn run_unix_socket(_path: &str, _threads: usize) -> crate::Result<()> {
    anyhow::bail!("--socket requires a Unix platform; use --stdio")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn session(threads: usize, input: &str) -> String {
        let mut engine = ServeEngine::new(threads);
        let mut out = Vec::new();
        run_session(&mut engine, input.as_bytes(), &mut out).unwrap();
        String::from_utf8(out).unwrap()
    }

    #[test]
    fn errors_do_not_end_the_session() {
        let input = "garbage\n{\"op\":\"list\"}\n";
        let out = session(1, input);
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with(r#"{"spp":1,"ok":false,"error":"#));
        assert_eq!(lines[1], r#"{"spp":1,"ok":true,"result":{"models":[]}}"#);
    }

    #[test]
    fn blank_lines_are_skipped_and_eof_ends() {
        let out = session(1, "\n   \n");
        assert!(out.is_empty());
    }

    #[test]
    fn shutdown_stops_reading() {
        let input = "{\"op\":\"shutdown\"}\n{\"op\":\"list\"}\n";
        let out = session(1, input);
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 1, "nothing is read past shutdown");
        assert_eq!(lines[0], r#"{"spp":1,"ok":true,"result":{"shutting_down":true}}"#);
    }

    #[test]
    fn invalid_utf8_line_gets_an_error_response() {
        let mut engine = ServeEngine::new(1);
        let input: &[u8] = b"\xff\xfe garbage\n{\"op\":\"list\"}\n";
        let mut out = Vec::new();
        run_session(&mut engine, input, &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains("not valid UTF-8"));
        assert!(lines[1].ends_with(r#""result":{"models":[]}}"#));
    }

    #[test]
    fn stats_count_transport_rejections() {
        let input = "garbage\n{\"op\":\"stats\"}\n";
        let out = session(1, input);
        let stats = out.lines().nth(1).unwrap();
        assert!(stats.contains(r#""requests":2,"errors":1"#), "got {stats}");
    }
}
