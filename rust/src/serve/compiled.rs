//! Compiled pattern matchers: batch scoring in one pass per record.
//!
//! The naive scorer ([`crate::model::SparsePatternModel::score`]) walks
//! every pattern for every record — `O(records × patterns)` matcher
//! calls. [`CompiledModel`] instead specializes the model's patterns
//! into a per-substrate index at load time, so a batch walks each
//! record once:
//!
//! - **Item sets** — an inverted index from single items to the
//!   pattern terms containing them. Scanning a (strictly sorted) row
//!   bumps a counter per posted term; a term fires when its counter
//!   saturates at the pattern length. Patterns that are not in
//!   transaction normal form (strictly increasing) can never match a
//!   normal-form row under the merge semantics of
//!   [`crate::data::synth_itemsets::contains_all`], so they compile to
//!   a never-match sentinel.
//! - **Sequences** — a shared-prefix discrimination trie simulated as
//!   an NFA over the record. A trie node is activated the first time
//!   its prefix embeds as a subsequence; activation order makes this
//!   the leftmost embedding, which is exactly what the greedy
//!   [`crate::data::sequence::is_subsequence`] oracle computes.
//! - **Graphs** — a DFS-code prefix tree. Each node holds the labeled
//!   graph of its code prefix (when
//!   [`crate::mining::gspan::checked_prefix_graph`] validates it) plus
//!   a cheap label/degree signature. Because a validated prefix graph
//!   is a subgraph of every extension, a failed prefix check prunes
//!   the whole subtree before any full subgraph-isomorphism test runs.
//! - **Tabular rules** — per-term interval collapse. A rule is a
//!   conjunction of `x_f ≤ t` / `x_f > t` predicates; all predicates
//!   on one feature collapse to a single half-open interval
//!   `lo < x_f ≤ hi` (`lo` = max `>` threshold, `hi` = min `≤`
//!   threshold), so a term needs at most one comparison pair per
//!   distinct feature instead of one per predicate, with
//!   short-circuit on the first failed conjunct. NaN and
//!   out-of-range features fail the interval test exactly as they
//!   fail every individual predicate, so the collapse is semantics-
//!   preserving.
//!
//! Scores are **bit-identical** to the naive scorer: matching only
//! produces per-record boolean flags, and the final accumulation adds
//! the intercept and then the flagged weights *in model term order* —
//! the same float additions, in the same order, as `score`.
//! Batches fan out over [`crate::runtime::parallel::map_indexed`] in
//! fixed chunks; each record is pure, so results are deterministic at
//! any worker count.

use std::collections::BTreeMap;

use crate::data::graph::{contains_subgraph, Graph, GraphDatabase};
use crate::data::registry::{Dataset, RegistrySubstrate, SubstrateVisitor};
use crate::data::sequence::Sequences;
use crate::data::tabular::TabularData;
use crate::data::Transactions;
use crate::mining::gspan::{checked_prefix_graph, code_to_labeled_graph, DfsEdge};
use crate::mining::itemset::is_strictly_increasing;
use crate::mining::rulefit::{RuleOp, RulePredicate};
use crate::mining::PatternSubstrate;
use crate::model::{task_output, SparsePatternModel};
use crate::runtime::parallel::map_indexed;
use crate::solver::Task;

/// Records scored per parallel work unit.
const CHUNK: usize = 64;

/// Sizes reported by [`CompiledModel::compile_for`].
#[derive(Clone, Copy, Debug)]
pub struct CompileStats {
    /// Terms in the source model (all substrate kinds).
    pub model_terms: usize,
    /// Terms of the compiled kind — the weights actually indexed.
    pub compiled_terms: usize,
    /// Index nodes: posting lists, trie nodes, or DFS-tree nodes.
    pub index_nodes: usize,
}

/// One scored batch: spliced scores plus a matcher-work metric.
///
/// `ops` counts item-posting visits (item sets), trie-node
/// activations (sequences), `contains_subgraph` calls (graphs), or
/// interval-conjunct comparisons (tabular rules) — the quantity the
/// compiled index exists to shrink relative to the naive
/// `records × patterns` bound. Summed in chunk order, so it is
/// deterministic at any thread count.
pub struct ScoreBatch {
    pub scores: Vec<f64>,
    pub ops: u64,
}

/// A [`SparsePatternModel`] specialized for batch scoring on one
/// substrate kind. Terms of other kinds are dropped at compile time;
/// they would contribute nothing to `score` on this substrate anyway,
/// so the remaining weights still accumulate in naive order.
pub struct CompiledModel {
    pub task: Task,
    pub lambda: f64,
    pub b: f64,
    /// The substrate `KIND_TAG` this matcher is specialized for.
    pub kind: &'static str,
    pub stats: CompileStats,
    weights: Vec<f64>,
    kernel: Kernel,
}

enum Kernel {
    Itemset(ItemsetIndex),
    Sequence(SequenceTrie),
    Graph(CodePrefixTree),
    Rule(RuleIntervalIndex),
}

impl Kernel {
    fn index_nodes(&self) -> usize {
        match self {
            Kernel::Itemset(idx) => idx.postings.len(),
            Kernel::Sequence(trie) => trie.len(),
            Kernel::Graph(tree) => tree.nodes.len(),
            Kernel::Rule(idx) => idx.index_nodes(),
        }
    }
}

impl CompiledModel {
    /// Compile the model's `kind`-tagged terms into a batch matcher.
    ///
    /// `kind` is one of the substrate `KIND_TAG`s (`"I"`, `"G"`,
    /// `"S"`). A model may legitimately compile to zero terms (the
    /// batch then scores every record as the intercept, like `score`
    /// would).
    pub fn compile_for(model: &SparsePatternModel, kind: &str) -> crate::Result<CompiledModel> {
        let mut weights = Vec::new();
        let (kind, kernel) = if kind == Transactions::KIND_TAG {
            let mut pats: Vec<&[u32]> = Vec::new();
            for (p, w) in &model.terms {
                if let Some(items) = p.as_itemset() {
                    pats.push(items);
                    weights.push(*w);
                }
            }
            (Transactions::KIND_TAG, Kernel::Itemset(ItemsetIndex::build(&pats)))
        } else if kind == GraphDatabase::KIND_TAG {
            let mut pats: Vec<&[DfsEdge]> = Vec::new();
            for (p, w) in &model.terms {
                if let Some(code) = p.as_subgraph() {
                    pats.push(code);
                    weights.push(*w);
                }
            }
            (GraphDatabase::KIND_TAG, Kernel::Graph(CodePrefixTree::build(&pats)))
        } else if kind == Sequences::KIND_TAG {
            let mut pats: Vec<&[u32]> = Vec::new();
            for (p, w) in &model.terms {
                if let Some(syms) = p.as_sequence() {
                    pats.push(syms);
                    weights.push(*w);
                }
            }
            (Sequences::KIND_TAG, Kernel::Sequence(SequenceTrie::build(&pats)))
        } else if kind == TabularData::KIND_TAG {
            let mut pats: Vec<&[RulePredicate]> = Vec::new();
            for (p, w) in &model.terms {
                if let Some(rule) = p.as_rule() {
                    pats.push(rule);
                    weights.push(*w);
                }
            }
            (TabularData::KIND_TAG, Kernel::Rule(RuleIntervalIndex::build(&pats)))
        } else {
            anyhow::bail!("unknown substrate kind '{kind}' (the shipped tags are I, G, S, R)");
        };
        let index_nodes = kernel.index_nodes();
        Ok(CompiledModel {
            task: model.task,
            lambda: model.lambda,
            b: model.b,
            kind,
            stats: CompileStats {
                model_terms: model.terms.len(),
                compiled_terms: weights.len(),
                index_nodes,
            },
            weights,
            kernel,
        })
    }

    /// Map a raw score to the task output (sign for classification,
    /// identity for regression) — same rule as
    /// [`SparsePatternModel::predict`].
    pub fn output(&self, score: f64) -> f64 {
        task_output(self.task, score)
    }

    /// Score a batch of transaction rows. Rows must be in transaction
    /// normal form (strictly increasing), the invariant every
    /// [`Transactions`] loader maintains.
    pub fn score_itemsets(&self, rows: &[Vec<u32>], threads: usize) -> crate::Result<ScoreBatch> {
        let Kernel::Itemset(idx) = &self.kernel else {
            anyhow::bail!("model compiled for kind '{}' cannot score item-set records", self.kind);
        };
        Ok(self.batch(
            rows,
            threads,
            || vec![0u32; self.weights.len()],
            |row, counters, flags| idx.matches_into(row, counters, flags),
        ))
    }

    /// Score a batch of symbol sequences.
    pub fn score_sequences(&self, seqs: &[Vec<u32>], threads: usize) -> crate::Result<ScoreBatch> {
        let Kernel::Sequence(trie) = &self.kernel else {
            anyhow::bail!("model compiled for kind '{}' cannot score sequence records", self.kind);
        };
        Ok(self.batch(
            seqs,
            threads,
            || TrieScratch::new(trie.len()),
            |seq, scratch, flags| trie.matches_into(seq, scratch, flags),
        ))
    }

    /// Score a batch of labeled graphs.
    pub fn score_graphs(&self, graphs: &[Graph], threads: usize) -> crate::Result<ScoreBatch> {
        let Kernel::Graph(tree) = &self.kernel else {
            anyhow::bail!("model compiled for kind '{}' cannot score graph records", self.kind);
        };
        Ok(self.batch(graphs, threads, || (), |g, _scratch, flags| tree.matches_into(g, flags)))
    }

    /// Score a batch of numeric tabular rows (rule models).
    pub fn score_tabular(&self, rows: &[Vec<f64>], threads: usize) -> crate::Result<ScoreBatch> {
        let Kernel::Rule(idx) = &self.kernel else {
            anyhow::bail!("model compiled for kind '{}' cannot score tabular records", self.kind);
        };
        Ok(self.batch(rows, threads, || (), |row, _scratch, flags| idx.matches_into(row, flags)))
    }

    /// Score a whole registry dataset; the dataset kind must match the
    /// compiled kind.  One visitor dispatch — the per-substrate batch
    /// entrypoint is picked by [`BatchScore`], not a match ladder.
    pub fn score_dataset(&self, data: &Dataset, threads: usize) -> crate::Result<ScoreBatch> {
        struct Score<'a> {
            compiled: &'a CompiledModel,
            threads: usize,
        }
        impl SubstrateVisitor for Score<'_> {
            type Out = crate::Result<ScoreBatch>;
            fn visit<S: RegistrySubstrate>(self, db: &S, _y: &[f64]) -> Self::Out {
                S::score_rows(self.compiled, db.rows(), self.threads)
            }
        }
        data.visit(Score {
            compiled: self,
            threads,
        })
    }

    /// Chunked batch driver. Each chunk gets private scratch and a
    /// private flag vector; per-record work is pure, and both scores
    /// and the ops metric are recombined in chunk (= record) order, so
    /// the result is identical at any thread count.
    fn batch<R, S, MS, M>(
        &self,
        records: &[R],
        threads: usize,
        scratch: MS,
        matches: M,
    ) -> ScoreBatch
    where
        R: Sync,
        MS: Fn() -> S + Sync,
        M: Fn(&R, &mut S, &mut [bool]) -> u64 + Sync,
    {
        let starts: Vec<usize> = (0..records.len()).step_by(CHUNK).collect();
        let parts = map_indexed(threads, starts.len(), |c| {
            let lo = starts[c];
            let hi = (lo + CHUNK).min(records.len());
            let mut scratch = scratch();
            let mut flags = vec![false; self.weights.len()];
            let mut ops = 0u64;
            let mut scores = Vec::with_capacity(hi - lo);
            for r in &records[lo..hi] {
                flags.fill(false);
                ops += matches(r, &mut scratch, &mut flags);
                // Same additions in the same order as the naive
                // scorer: intercept first, then flagged weights in
                // model term order.
                let mut s = self.b;
                for (w, &hit) in self.weights.iter().zip(flags.iter()) {
                    if hit {
                        s += w;
                    }
                }
                scores.push(s);
            }
            (scores, ops)
        });
        let mut scores = Vec::with_capacity(records.len());
        let mut ops = 0u64;
        for (s, o) in parts {
            scores.extend(s);
            ops += o;
        }
        ScoreBatch { scores, ops }
    }
}

/// The batch-scoring capability of a registry substrate: its owned
/// record rows plus the compiled-matcher entrypoint that scores them.
/// This is the serve-layer half of
/// [`crate::data::registry::RegistrySubstrate`] — generic code reaches
/// a substrate's batch kernel through `S::score_rows` instead of a
/// per-kind match ladder, so adding a substrate means one `BatchScore`
/// impl here, one registry row, and nothing else.
pub trait BatchScore: PatternSubstrate {
    /// The owned per-record row type the batch kernels consume
    /// (`Vec<u32>` transactions/sequences, [`Graph`]s, `Vec<f64>`
    /// tabular rows).
    type Row: Sync;

    /// The substrate's records, as stored.
    fn rows(&self) -> &[Self::Row];

    /// Score `rows` through `compiled`'s batch kernel; errors when the
    /// model was compiled for a different substrate kind.
    fn score_rows(
        compiled: &CompiledModel,
        rows: &[Self::Row],
        threads: usize,
    ) -> crate::Result<ScoreBatch>;
}

impl BatchScore for Transactions {
    type Row = Vec<u32>;

    fn rows(&self) -> &[Vec<u32>] {
        &self.items
    }

    fn score_rows(
        compiled: &CompiledModel,
        rows: &[Vec<u32>],
        threads: usize,
    ) -> crate::Result<ScoreBatch> {
        compiled.score_itemsets(rows, threads)
    }
}

impl BatchScore for GraphDatabase {
    type Row = Graph;

    fn rows(&self) -> &[Graph] {
        &self.graphs
    }

    fn score_rows(
        compiled: &CompiledModel,
        rows: &[Graph],
        threads: usize,
    ) -> crate::Result<ScoreBatch> {
        compiled.score_graphs(rows, threads)
    }
}

impl BatchScore for Sequences {
    type Row = Vec<u32>;

    fn rows(&self) -> &[Vec<u32>] {
        &self.seqs
    }

    fn score_rows(
        compiled: &CompiledModel,
        rows: &[Vec<u32>],
        threads: usize,
    ) -> crate::Result<ScoreBatch> {
        compiled.score_sequences(rows, threads)
    }
}

impl BatchScore for TabularData {
    type Row = Vec<f64>;

    fn rows(&self) -> &[Vec<f64>] {
        &self.rows
    }

    fn score_rows(
        compiled: &CompiledModel,
        rows: &[Vec<f64>],
        threads: usize,
    ) -> crate::Result<ScoreBatch> {
        compiled.score_tabular(rows, threads)
    }
}

/// Inverted single-item index over item-set patterns.
struct ItemsetIndex {
    /// `(item, ids of terms whose pattern contains it)`, sorted by
    /// item for binary search.
    postings: Vec<(u32, Vec<u32>)>,
    /// Distinct items each term needs before it fires; `u32::MAX`
    /// marks a term that can never match a normal-form row.
    needed: Vec<u32>,
    /// Terms with empty patterns — they match every record.
    always: Vec<u32>,
}

impl ItemsetIndex {
    fn build(patterns: &[&[u32]]) -> ItemsetIndex {
        let mut map: BTreeMap<u32, Vec<u32>> = BTreeMap::new();
        let mut needed = vec![0u32; patterns.len()];
        let mut always = Vec::new();
        for (t, items) in patterns.iter().enumerate() {
            if items.is_empty() {
                always.push(t as u32);
                continue;
            }
            if !is_strictly_increasing(items) {
                // contains_all's merge scan never matches these
                // against a strictly sorted row; don't post them.
                needed[t] = u32::MAX;
                continue;
            }
            needed[t] = items.len() as u32;
            for &j in *items {
                map.entry(j).or_default().push(t as u32);
            }
        }
        ItemsetIndex { postings: map.into_iter().collect(), needed, always }
    }

    /// One pass over a sorted row; returns the posting visits made.
    /// Consecutive duplicate items are skipped so a malformed row
    /// cannot double-count toward saturation.
    fn matches_into(&self, row: &[u32], counters: &mut [u32], flags: &mut [bool]) -> u64 {
        for c in counters.iter_mut() {
            *c = 0;
        }
        for &t in &self.always {
            flags[t as usize] = true;
        }
        let mut ops = 0u64;
        let mut prev: Option<u32> = None;
        for &j in row {
            if prev == Some(j) {
                continue;
            }
            prev = Some(j);
            if let Ok(k) = self.postings.binary_search_by_key(&j, |p| p.0) {
                for &t in &self.postings[k].1 {
                    ops += 1;
                    let c = &mut counters[t as usize];
                    *c += 1;
                    if *c == self.needed[t as usize] {
                        flags[t as usize] = true;
                    }
                }
            }
        }
        ops
    }
}

/// Shared-prefix trie over sequence patterns, matched by NFA subset
/// simulation.
struct SequenceTrie {
    /// `children[n]` = `(symbol, child node)`, sorted by symbol.
    children: Vec<Vec<(u32, u32)>>,
    /// Term ids whose pattern ends at each node (root = empty
    /// patterns, which match everything).
    terms: Vec<Vec<u32>>,
}

/// Reusable per-worker state for [`SequenceTrie::matches_into`].
struct TrieScratch {
    /// Activated nodes, in activation order; the root is re-seeded per
    /// record.
    active: Vec<u32>,
    /// `stamped[n]` — node already in `active` (cleared per record by
    /// walking `active`, not the whole vector).
    stamped: Vec<bool>,
}

impl TrieScratch {
    fn new(nodes: usize) -> TrieScratch {
        TrieScratch { active: Vec::with_capacity(nodes), stamped: vec![false; nodes] }
    }
}

impl SequenceTrie {
    /// Build from lex-sorted patterns with a prefix stack; siblings
    /// come out sorted by symbol, which `matches_into` binary-searches.
    fn build(patterns: &[&[u32]]) -> SequenceTrie {
        let mut order: Vec<u32> = (0..patterns.len() as u32).collect();
        order.sort_by(|&a, &b| patterns[a as usize].cmp(patterns[b as usize]).then(a.cmp(&b)));
        let mut trie = SequenceTrie { children: vec![Vec::new()], terms: vec![Vec::new()] };
        // stack[d] = node for the previous pattern's length-d prefix.
        let mut stack: Vec<u32> = vec![0];
        let mut prev: &[u32] = &[];
        for &t in &order {
            let pat = patterns[t as usize];
            let keep = crate::mining::prefixspan::common_prefix_len(prev, pat);
            stack.truncate(keep + 1);
            for &sym in &pat[keep..] {
                let parent = *stack.last().expect("stack holds at least the root") as usize;
                let id = trie.children.len() as u32;
                trie.children.push(Vec::new());
                trie.terms.push(Vec::new());
                trie.children[parent].push((sym, id));
                stack.push(id);
            }
            let end = *stack.last().expect("stack holds at least the root") as usize;
            trie.terms[end].push(t);
            prev = pat;
        }
        trie
    }

    fn len(&self) -> usize {
        self.children.len()
    }

    /// One pass over the record; returns the node activations made.
    ///
    /// A node is activated the first time its prefix embeds as a
    /// subsequence of the record seen so far — the leftmost embedding,
    /// which dominates every other embedding for extending further.
    /// The frontier length is snapshotted per symbol so a node
    /// activated *by* a position never consumes that same position.
    fn matches_into(&self, seq: &[u32], scratch: &mut TrieScratch, flags: &mut [bool]) -> u64 {
        for &t in &self.terms[0] {
            flags[t as usize] = true;
        }
        scratch.active.clear();
        scratch.active.push(0);
        scratch.stamped[0] = true;
        let mut ops = 0u64;
        for &a in seq {
            let frontier = scratch.active.len();
            let mut idx = 0;
            while idx < frontier {
                let node = scratch.active[idx] as usize;
                idx += 1;
                let kids = &self.children[node];
                if let Ok(k) = kids.binary_search_by_key(&a, |c| c.0) {
                    let child = kids[k].1;
                    if !scratch.stamped[child as usize] {
                        scratch.stamped[child as usize] = true;
                        scratch.active.push(child);
                        ops += 1;
                        for &t in &self.terms[child as usize] {
                            flags[t as usize] = true;
                        }
                    }
                }
            }
        }
        for &n in &scratch.active {
            scratch.stamped[n as usize] = false;
        }
        ops
    }
}

/// Cheap necessary-condition signature for subgraph containment:
/// if any count in the pattern exceeds the record's, the pattern
/// cannot embed and the full isomorphism search is skipped.
struct GraphSig {
    n_vertices: u32,
    n_edges: u32,
    max_degree: u32,
    /// `(label, count)` sorted by label.
    vlabels: Vec<(u32, u32)>,
    elabels: Vec<(u32, u32)>,
}

impl GraphSig {
    fn of(g: &Graph) -> GraphSig {
        let mut vl: BTreeMap<u32, u32> = BTreeMap::new();
        for &l in &g.vlabels {
            *vl.entry(l).or_default() += 1;
        }
        let mut el: BTreeMap<u32, u32> = BTreeMap::new();
        let mut deg = vec![0u32; g.n_vertices()];
        for &(u, v, l) in &g.edges {
            *el.entry(l).or_default() += 1;
            deg[u as usize] += 1;
            deg[v as usize] += 1;
        }
        GraphSig {
            n_vertices: g.n_vertices() as u32,
            n_edges: g.n_edges() as u32,
            max_degree: deg.iter().copied().max().unwrap_or(0),
            vlabels: vl.into_iter().collect(),
            elabels: el.into_iter().collect(),
        }
    }

    /// Can a graph with this signature possibly embed into one with
    /// `rec`? (Embedding maps vertices injectively, preserves labels,
    /// and maps edges to edges — so every per-label count and the
    /// maximum degree are monotone under it.)
    fn may_embed_in(&self, rec: &GraphSig) -> bool {
        self.n_vertices <= rec.n_vertices
            && self.n_edges <= rec.n_edges
            && self.max_degree <= rec.max_degree
            && counts_subsumed(&self.vlabels, &rec.vlabels)
            && counts_subsumed(&self.elabels, &rec.elabels)
    }
}

/// Is every `(label, count)` in `need` covered by `have`? Both sorted
/// by label.
fn counts_subsumed(need: &[(u32, u32)], have: &[(u32, u32)]) -> bool {
    let mut j = 0;
    for &(l, c) in need {
        while j < have.len() && have[j].0 < l {
            j += 1;
        }
        if j >= have.len() || have[j].0 != l || have[j].1 < c {
            return false;
        }
    }
    true
}

struct CodeNode {
    children: Vec<u32>,
    /// Term ids whose full code ends at this node.
    terms: Vec<u32>,
    /// Validated prefix graph + signature. On a hit the subtree is
    /// explored and any terms here are matched; on a miss the whole
    /// subtree prunes (a validated prefix graph is a connected
    /// subgraph of every extension's graph, so prefix ⊄ record ⟹
    /// extension ⊄ record — the same anti-monotonicity SPP exploits).
    gate: Option<(Graph, GraphSig)>,
    /// For terms ending at a node whose prefix failed validation: the
    /// unvalidated full-pattern graph, matched exactly the way the
    /// naive scorer would (`code_to_labeled_graph` + containment).
    raw: Option<(Graph, GraphSig)>,
}

/// DFS-code prefix tree over subgraph patterns.
struct CodePrefixTree {
    nodes: Vec<CodeNode>,
    roots: Vec<u32>,
    /// Terms with empty codes — `contains_subgraph` treats the empty
    /// pattern as matching everything.
    always: Vec<u32>,
}

impl CodePrefixTree {
    fn build(patterns: &[&[DfsEdge]]) -> CodePrefixTree {
        let mut order: Vec<u32> = (0..patterns.len() as u32).collect();
        order.sort_by(|&a, &b| patterns[a as usize].cmp(patterns[b as usize]).then(a.cmp(&b)));
        let mut tree = CodePrefixTree { nodes: Vec::new(), roots: Vec::new(), always: Vec::new() };
        // stack[d] = node for the previous code's length-(d+1) prefix.
        let mut stack: Vec<u32> = Vec::new();
        let mut prev: &[DfsEdge] = &[];
        for &t in &order {
            let code = patterns[t as usize];
            if code.is_empty() {
                tree.always.push(t);
                continue;
            }
            let mut keep = 0;
            while keep < stack.len() && keep < code.len() && prev[keep] == code[keep] {
                keep += 1;
            }
            stack.truncate(keep);
            for depth in keep..code.len() {
                let id = tree.nodes.len() as u32;
                let gate = checked_prefix_graph(&code[..depth + 1]).map(|g| {
                    let sig = GraphSig::of(&g);
                    (g, sig)
                });
                tree.nodes.push(CodeNode {
                    children: Vec::new(),
                    terms: Vec::new(),
                    gate,
                    raw: None,
                });
                match stack.last() {
                    Some(&p) => tree.nodes[p as usize].children.push(id),
                    None => tree.roots.push(id),
                }
                stack.push(id);
            }
            let end = *stack.last().expect("non-empty code pushed at least one node") as usize;
            let node = &mut tree.nodes[end];
            node.terms.push(t);
            if node.gate.is_none() && node.raw.is_none() {
                let g = code_to_labeled_graph(code);
                let sig = GraphSig::of(&g);
                node.raw = Some((g, sig));
            }
            prev = code;
        }
        tree
    }

    /// One prefix-tree walk per record; returns the
    /// `contains_subgraph` calls made. Unvalidated interior nodes are
    /// walked through unchecked (no false pruning); their terms, if
    /// any, are tested against the exact naive pattern graph.
    fn matches_into(&self, g: &Graph, flags: &mut [bool]) -> u64 {
        for &t in &self.always {
            flags[t as usize] = true;
        }
        if self.nodes.is_empty() {
            return 0;
        }
        let rsig = GraphSig::of(g);
        let mut ops = 0u64;
        let mut stack: Vec<u32> = self.roots.clone();
        while let Some(id) = stack.pop() {
            let node = &self.nodes[id as usize];
            match &node.gate {
                Some((pg, psig)) => {
                    if !psig.may_embed_in(&rsig) {
                        continue;
                    }
                    ops += 1;
                    if !contains_subgraph(g, pg) {
                        continue;
                    }
                    for &t in &node.terms {
                        flags[t as usize] = true;
                    }
                    stack.extend_from_slice(&node.children);
                }
                None => {
                    if let Some((pg, psig)) = &node.raw {
                        if psig.may_embed_in(&rsig) {
                            ops += 1;
                            if contains_subgraph(g, pg) {
                                for &t in &node.terms {
                                    flags[t as usize] = true;
                                }
                            }
                        }
                    }
                    stack.extend_from_slice(&node.children);
                }
            }
        }
        ops
    }
}

/// Per-term interval collapse over rule patterns.
struct RuleIntervalIndex {
    /// Per term: `(feature, lo, hi)` conjuncts, feature-sorted. The
    /// rule matches iff every conjunct holds as `lo < x_f ≤ hi`
    /// (`lo` = −∞ with no `>` predicate, `hi` = +∞ with no `≤`).
    terms: Vec<Vec<(u32, f64, f64)>>,
}

impl RuleIntervalIndex {
    fn build(patterns: &[&[RulePredicate]]) -> RuleIntervalIndex {
        let terms = patterns
            .iter()
            .map(|rule| {
                let mut iv: BTreeMap<u32, (f64, f64)> = BTreeMap::new();
                for p in *rule {
                    let e = iv.entry(p.feature).or_insert((f64::NEG_INFINITY, f64::INFINITY));
                    // A conjunction of `> t_i` is `> max t_i`; of
                    // `≤ t_i`, `≤ min t_i` — exact, not approximate.
                    match p.op {
                        RuleOp::Gt => e.0 = e.0.max(p.threshold()),
                        RuleOp::Le => e.1 = e.1.min(p.threshold()),
                    }
                }
                iv.into_iter().map(|(f, (lo, hi))| (f, lo, hi)).collect()
            })
            .collect();
        RuleIntervalIndex { terms }
    }

    fn index_nodes(&self) -> usize {
        self.terms.iter().map(|t| t.len()).sum()
    }

    /// One short-circuit pass per term; returns the conjunct
    /// comparisons made. A missing feature or a NaN fails its
    /// conjunct, exactly as it fails every predicate the conjunct
    /// collapsed.
    fn matches_into(&self, row: &[f64], flags: &mut [bool]) -> u64 {
        let mut ops = 0u64;
        for (t, iv) in self.terms.iter().enumerate() {
            let mut hit = true;
            for &(f, lo, hi) in iv {
                ops += 1;
                match row.get(f as usize) {
                    Some(&v) if v > lo && v <= hi => {}
                    _ => {
                        hit = false;
                        break;
                    }
                }
            }
            if hit {
                flags[t] = true;
            }
        }
        ops
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mining::Pattern;

    fn model_of(task: Task, b: f64, terms: Vec<(Pattern, f64)>) -> SparsePatternModel {
        SparsePatternModel { task, lambda: 0.5, b, terms }
    }

    fn assert_bits_eq(a: f64, b: f64) {
        assert_eq!(a.to_bits(), b.to_bits(), "scores differ: {a} vs {b}");
    }

    #[test]
    fn itemset_kernel_matches_naive_bitwise() {
        // Deliberately includes an empty pattern (always matches), a
        // duplicate-item pattern and an unsorted pattern (never match
        // a normal-form row), and shared items across patterns.
        let model = model_of(
            Task::Classification,
            0.25,
            vec![
                (Pattern::Itemset(vec![1, 2]), 0.7),
                (Pattern::Itemset(vec![2]), -0.3),
                (Pattern::Itemset(vec![1, 1]), 10.0),
                (Pattern::Itemset(vec![]), 0.1),
                (Pattern::Itemset(vec![3, 1]), -10.0),
                (Pattern::Itemset(vec![1, 2, 4]), 0.11),
            ],
        );
        let compiled = CompiledModel::compile_for(&model, "I").unwrap();
        assert_eq!(compiled.stats.compiled_terms, 6);
        let rows: Vec<Vec<u32>> = vec![
            vec![1, 2],
            vec![2],
            vec![],
            vec![1, 2, 3, 4],
            vec![1, 3],
            vec![4],
        ];
        for threads in [1, 4] {
            let out = compiled.score_itemsets(&rows, threads).unwrap();
            assert_eq!(out.scores.len(), rows.len());
            for (row, &s) in rows.iter().zip(&out.scores) {
                assert_bits_eq(s, model.score_itemset(row));
            }
        }
    }

    #[test]
    fn sequence_kernel_matches_naive_bitwise() {
        // Repeated symbols and shared prefixes exercise the
        // one-occurrence-per-position rule.
        let model = model_of(
            Task::Regression,
            -0.5,
            vec![
                (Pattern::Sequence(vec![1]), 0.2),
                (Pattern::Sequence(vec![1, 2]), 0.4),
                (Pattern::Sequence(vec![1, 1]), 0.8),
                (Pattern::Sequence(vec![2]), 1.6),
                (Pattern::Sequence(vec![]), 3.2),
                (Pattern::Sequence(vec![2, 1]), 6.4),
                (Pattern::Sequence(vec![1, 2]), 12.8),
            ],
        );
        let compiled = CompiledModel::compile_for(&model, "S").unwrap();
        let seqs: Vec<Vec<u32>> = vec![
            vec![1, 2, 1],
            vec![2, 2],
            vec![],
            vec![1, 1],
            vec![1],
            vec![2, 1, 2],
        ];
        for threads in [1, 4] {
            let out = compiled.score_sequences(&seqs, threads).unwrap();
            for (seq, &s) in seqs.iter().zip(&out.scores) {
                assert_bits_eq(s, model.score_sequence(seq));
            }
        }
    }

    #[test]
    fn rule_kernel_matches_naive_bitwise() {
        let r = RulePredicate::new;
        // An interval pair collapsing to one conjunct, a contradictory
        // (never-fire) interval, an empty rule (always fires), and a
        // predicate on a feature some rows do not have.
        let model = model_of(
            Task::Regression,
            0.125,
            vec![
                (Pattern::Rule(vec![r(0, RuleOp::Le, 0.5)]), 0.7),
                (Pattern::Rule(vec![r(0, RuleOp::Gt, 0.25), r(0, RuleOp::Le, 0.75)]), -0.3),
                (Pattern::Rule(vec![r(1, RuleOp::Gt, 0.0), r(2, RuleOp::Le, 1.0)]), 0.11),
                (Pattern::Rule(vec![]), 0.05),
                (Pattern::Rule(vec![r(0, RuleOp::Gt, 0.9), r(0, RuleOp::Le, 0.1)]), 10.0),
                (Pattern::Rule(vec![r(5, RuleOp::Gt, -1.0)]), 0.9),
            ],
        );
        let compiled = CompiledModel::compile_for(&model, "R").unwrap();
        assert_eq!(compiled.stats.compiled_terms, 6);
        // 1 + 1 (pair collapsed) + 2 + 0 + 1 (contradiction collapsed)
        // + 1 conjuncts.
        assert_eq!(compiled.stats.index_nodes, 6);
        let rows: Vec<Vec<f64>> = vec![
            vec![0.3, 0.5, 0.5],
            vec![0.6, -1.0, 2.0],
            vec![0.5, 0.1, 0.9, 0.0, 0.0, 3.0],
            vec![],
            vec![f64::NAN, 1.0, 0.5],
        ];
        for threads in [1, 4] {
            let out = compiled.score_tabular(&rows, threads).unwrap();
            assert_eq!(out.scores.len(), rows.len());
            for (row, &s) in rows.iter().zip(&out.scores) {
                assert_bits_eq(s, model.score_tabular_row(row));
            }
        }
        // Wrong record kind for the compiled kernel is an error.
        assert!(compiled.score_itemsets(&[vec![1]], 1).is_err());
    }

    fn path_graph(labels: &[u32]) -> Graph {
        let mut g = Graph::new();
        for &l in labels {
            g.add_vertex(l);
        }
        for v in 1..labels.len() as u32 {
            g.add_edge(v - 1, v, 0);
        }
        g
    }

    fn edge(from: u32, to: u32, fl: i32, el: u32, tl: i32) -> DfsEdge {
        DfsEdge { from, to, from_label: fl, elabel: el, to_label: tl }
    }

    #[test]
    fn graph_kernel_matches_naive_bitwise() {
        // Two chains sharing a one-edge prefix, plus a single edge and
        // an empty code.
        let model = model_of(
            Task::Classification,
            0.0,
            vec![
                (Pattern::Subgraph(vec![edge(0, 1, 5, 0, 6)]), 0.5),
                (Pattern::Subgraph(vec![edge(0, 1, 5, 0, 6), edge(1, 2, 6, 0, 7)]), 0.25),
                (Pattern::Subgraph(vec![edge(0, 1, 5, 0, 6), edge(1, 2, 6, 0, 9)]), 0.125),
                (Pattern::Subgraph(vec![edge(0, 1, 7, 0, 7)]), 0.0625),
                (Pattern::Subgraph(vec![]), 0.03125),
            ],
        );
        let compiled = CompiledModel::compile_for(&model, "G").unwrap();
        let graphs = vec![
            path_graph(&[5, 6, 7]),
            path_graph(&[5, 6, 9]),
            path_graph(&[7, 7]),
            path_graph(&[8]),
        ];
        for threads in [1, 4] {
            let out = compiled.score_graphs(&graphs, threads).unwrap();
            for (g, &s) in graphs.iter().zip(&out.scores) {
                assert_bits_eq(s, model.score_graph(g));
            }
        }
    }

    #[test]
    fn graph_prefix_gate_prunes_but_terminal_still_fires() {
        // A chain whose 2-edge prefix cannot embed in a short record:
        // the gate must prune without suppressing the shorter sibling.
        let model = model_of(
            Task::Regression,
            0.0,
            vec![
                (Pattern::Subgraph(vec![edge(0, 1, 5, 0, 5)]), 1.0),
                (
                    Pattern::Subgraph(vec![
                        edge(0, 1, 5, 0, 5),
                        edge(1, 2, 5, 0, 5),
                        edge(2, 3, 5, 0, 5),
                    ]),
                    2.0,
                ),
            ],
        );
        let compiled = CompiledModel::compile_for(&model, "G").unwrap();
        let graphs = vec![path_graph(&[5, 5]), path_graph(&[5, 5, 5, 5])];
        let out = compiled.score_graphs(&graphs, 1).unwrap();
        assert_bits_eq(out.scores[0], model.score_graph(&graphs[0]));
        assert_bits_eq(out.scores[1], model.score_graph(&graphs[1]));
        // The long record pays at most one containment call per tree
        // node; the short record prunes the chain after its prefix.
        assert!(out.ops <= 2 * compiled.stats.index_nodes as u64);
    }

    #[test]
    fn mixed_model_compiles_per_kind_and_stays_naive_identical() {
        let model = model_of(
            Task::Classification,
            0.5,
            vec![
                (Pattern::Itemset(vec![1]), 0.3),
                (Pattern::Sequence(vec![1]), 0.9),
                (Pattern::Itemset(vec![2]), -0.2),
            ],
        );
        let compiled = CompiledModel::compile_for(&model, "I").unwrap();
        assert_eq!(compiled.stats.model_terms, 3);
        assert_eq!(compiled.stats.compiled_terms, 2);
        let rows: Vec<Vec<u32>> = vec![vec![1], vec![2], vec![1, 2]];
        let out = compiled.score_itemsets(&rows, 1).unwrap();
        for (row, &s) in rows.iter().zip(&out.scores) {
            assert_bits_eq(s, model.score_itemset(row));
        }
        // Wrong record kind for the compiled kernel is an error, not a
        // silent zero.
        assert!(compiled.score_sequences(&rows, 1).is_err());
    }

    #[test]
    fn unknown_kind_is_rejected() {
        let model = model_of(Task::Regression, 0.0, vec![]);
        assert!(CompiledModel::compile_for(&model, "X").is_err());
        let compiled = CompiledModel::compile_for(&model, "I").unwrap();
        assert_eq!(compiled.stats.compiled_terms, 0);
        let out = compiled.score_itemsets(&[vec![1, 2]], 1).unwrap();
        assert_bits_eq(out.scores[0], 0.0);
    }
}
