//! Line-delimited JSON wire protocol for `spp serve`.
//!
//! One request per line, one response per line, in request order. The
//! vendored crate set has no serde, so the JSON layer is hand-rolled:
//! a small [`Json`] value type, a strict recursive-descent parser with
//! a nesting cap, and a deterministic writer (object fields emit in
//! insertion order; numbers format canonically via [`fmt_f64`]), so a
//! given request stream always produces byte-identical responses.
//!
//! Request grammar (all requests are objects with a string `"op"`; an
//! optional `"id"` is echoed back verbatim):
//!
//! ```text
//! {"op":"load", "model":<text>|"file":<path>, "kind":<tag>?, "id":...?}
//! {"op":"unload", "kind":<tag>}
//! {"op":"list"}
//! {"op":"score", "kind":<tag>, "records":[...], "matcher":"compiled"|"naive"?}
//! {"op":"stats"}
//! {"op":"shutdown"}
//! ```
//!
//! `<tag>` is a substrate `KIND_TAG`: `"I"` (item sets), `"G"`
//! (graphs), `"S"` (sequences), `"R"` (numeric tabular rows for rule
//! models). Records are arrays of non-negative integers for `I`/`S`,
//! arrays of finite numbers for `R`, and
//! `{"v":[labels],"e":[[u,v,elabel],...]}` objects for `G`.
//!
//! Responses are enveloped as
//! `{"spp":1,"ok":true,"id":...,"result":{...}}` or
//! `{"spp":1,"ok":false,"id":...,"error":"..."}`.

use std::fmt::{self, Write as _};

use crate::data::graph::{Graph, GraphDatabase};
use crate::data::sequence::Sequences;
use crate::data::tabular::TabularData;
use crate::data::Transactions;
use crate::mining::itemset::normalize_items;
use crate::mining::PatternSubstrate;

/// Protocol version stamped on every response line.
pub const PROTOCOL_VERSION: u64 = 1;

/// Maximum JSON nesting depth accepted by the parser. Deeper input is
/// a protocol error, not a stack overflow.
const MAX_DEPTH: usize = 64;

/// A JSON value. Objects keep their fields in insertion order (a
/// `Vec`, not a map) so emission is deterministic and ids echo back
/// exactly as structured.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse one complete JSON value; trailing non-whitespace is an
    /// error (a request line is exactly one value).
    pub fn parse(text: &str) -> crate::Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        let v = p.value(0)?;
        p.skip_ws();
        anyhow::ensure!(p.pos == p.bytes.len(), "trailing garbage after JSON value");
        Ok(v)
    }

    /// Object field lookup (first occurrence); `None` on non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// The value as an exact non-negative integer, if it is one.
    pub fn as_u32(&self) -> Option<u32> {
        let v = self.as_f64()?;
        (v >= 0.0 && v <= u32::MAX as f64 && v.trunc() == v).then_some(v as u32)
    }
}

/// Canonical JSON number formatting: integral values print as
/// integers (covering every count and every score the golden fixtures
/// pin), anything else as Rust's shortest round-trip `{:e}` form, and
/// non-finite values (unrepresentable in JSON) degrade to `null`.
pub fn fmt_f64(v: f64) -> String {
    if !v.is_finite() {
        return "null".to_string();
    }
    if v == v.trunc() && v.abs() < 9.0e15 {
        format!("{}", v as i64)
    } else {
        format!("{v:e}")
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => f.write_str(if *b { "true" } else { "false" }),
            Json::Num(v) => f.write_str(&fmt_f64(*v)),
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(items) => {
                f.write_str("[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{v}")?;
                }
                f.write_str("]")
            }
            Json::Obj(fields) => {
                f.write_str("{")?;
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_escaped(f, k)?;
                    f.write_str(":")?;
                    write!(f, "{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for ch in s.chars() {
        match ch {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => f.write_char(c)?,
        }
    }
    f.write_str("\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while matches!(self.bytes.get(self.pos), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn value(&mut self, depth: usize) -> crate::Result<Json> {
        anyhow::ensure!(depth < MAX_DEPTH, "JSON nested deeper than {MAX_DEPTH} levels");
        self.skip_ws();
        match self.peek() {
            None => anyhow::bail!("unexpected end of input"),
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true").map(|_| Json::Bool(true)),
            Some(b'f') => self.literal("false").map(|_| Json::Bool(false)),
            Some(b'n') => self.literal("null").map(|_| Json::Null),
            Some(_) => self.number(),
        }
    }

    fn literal(&mut self, lit: &str) -> crate::Result<()> {
        anyhow::ensure!(
            self.bytes[self.pos..].starts_with(lit.as_bytes()),
            "invalid JSON at byte {}",
            self.pos
        );
        self.pos += lit.len();
        Ok(())
    }

    fn number(&mut self) -> crate::Result<Json> {
        let start = self.pos;
        while matches!(self.peek(), Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).expect("ascii number span");
        anyhow::ensure!(!s.is_empty(), "unexpected character at byte {start}");
        let v: f64 = s.parse().map_err(|_| anyhow::anyhow!("bad JSON number '{s}'"))?;
        anyhow::ensure!(v.is_finite(), "JSON number '{s}' out of range");
        Ok(Json::Num(v))
    }

    fn hex4(&mut self) -> crate::Result<u32> {
        anyhow::ensure!(self.pos + 4 <= self.bytes.len(), "truncated \\u escape");
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| anyhow::anyhow!("bad \\u escape"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| anyhow::anyhow!("bad \\u escape '{s}'"))?;
        self.pos += 4;
        Ok(v)
    }

    fn string(&mut self) -> crate::Result<String> {
        self.pos += 1; // opening quote
        let mut out = String::new();
        loop {
            let Some(c) = self.peek() else {
                anyhow::bail!("unterminated JSON string");
            };
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(e) = self.peek() else {
                        anyhow::bail!("unterminated escape");
                    };
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                anyhow::ensure!(
                                    self.peek() == Some(b'\\'),
                                    "lone high surrogate in \\u escape"
                                );
                                self.pos += 1;
                                anyhow::ensure!(
                                    self.peek() == Some(b'u'),
                                    "lone high surrogate in \\u escape"
                                );
                                self.pos += 1;
                                let lo = self.hex4()?;
                                anyhow::ensure!(
                                    (0xDC00..0xE000).contains(&lo),
                                    "invalid low surrogate in \\u escape"
                                );
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                hi
                            };
                            let ch = char::from_u32(cp)
                                .ok_or_else(|| anyhow::anyhow!("invalid \\u escape"))?;
                            out.push(ch);
                        }
                        other => anyhow::bail!("invalid escape '\\{}'", other as char),
                    }
                }
                _ if c < 0x20 => anyhow::bail!("unescaped control character in string"),
                _ if c < 0x80 => out.push(c as char),
                _ => {
                    // The input is a &str, so multi-byte sequences are
                    // well-formed; absorb the continuation bytes.
                    let start = self.pos - 1;
                    while self.peek().map(|b| b & 0xC0 == 0x80).unwrap_or(false) {
                        self.pos += 1;
                    }
                    let span = std::str::from_utf8(&self.bytes[start..self.pos])
                        .expect("source text is valid UTF-8");
                    out.push_str(span);
                }
            }
        }
    }

    fn array(&mut self, depth: usize) -> crate::Result<Json> {
        self.pos += 1; // '['
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => anyhow::bail!("expected ',' or ']' in array at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self, depth: usize) -> crate::Result<Json> {
        self.pos += 1; // '{'
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            anyhow::ensure!(
                self.peek() == Some(b'"'),
                "expected string key in object at byte {}",
                self.pos
            );
            let key = self.string()?;
            self.skip_ws();
            anyhow::ensure!(
                self.peek() == Some(b':'),
                "expected ':' after object key at byte {}",
                self.pos
            );
            self.pos += 1;
            let value = self.value(depth + 1)?;
            fields.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => anyhow::bail!("expected ',' or '}}' in object at byte {}", self.pos),
            }
        }
    }
}

/// Which matcher a `score` request runs; `compiled` is the default,
/// `naive` keeps the per-pattern oracle reachable over the wire for
/// differential checks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Matcher {
    Compiled,
    Naive,
}

/// Where `load` finds the model text.
#[derive(Clone, Debug)]
pub enum ModelSource {
    /// The `spp-model v1` text itself, inline in the request.
    Inline(String),
    /// A path the server reads at load time.
    File(String),
}

/// A decoded request.
#[derive(Clone, Debug)]
pub enum Request {
    Load { kind: Option<String>, source: ModelSource },
    Unload { kind: String },
    List,
    Score { kind: String, records: Json, matcher: Matcher },
    Stats,
    Shutdown,
}

/// Parse one request line into its echoable `"id"` (when present) and
/// the decoded request. The id is extracted before request validation
/// so error responses can still correlate.
pub fn parse_request(line: &str) -> (Option<Json>, crate::Result<Request>) {
    let v = match Json::parse(line) {
        Ok(v) => v,
        Err(e) => return (None, Err(e)),
    };
    let id = v.get("id").cloned();
    (id, decode_request(&v))
}

fn decode_request(v: &Json) -> crate::Result<Request> {
    anyhow::ensure!(matches!(v, Json::Obj(_)), "request must be a JSON object");
    let op = v
        .get("op")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("request needs a string \"op\" field"))?;
    match op {
        "load" => {
            let kind = match v.get("kind") {
                None => None,
                Some(k) => Some(
                    k.as_str()
                        .ok_or_else(|| anyhow::anyhow!("\"kind\" must be a string tag"))?
                        .to_string(),
                ),
            };
            let source = match (v.get("model"), v.get("file")) {
                (Some(m), None) => ModelSource::Inline(
                    m.as_str()
                        .ok_or_else(|| anyhow::anyhow!("\"model\" must be the model text"))?
                        .to_string(),
                ),
                (None, Some(f)) => ModelSource::File(
                    f.as_str()
                        .ok_or_else(|| anyhow::anyhow!("\"file\" must be a path string"))?
                        .to_string(),
                ),
                (Some(_), Some(_)) => {
                    anyhow::bail!("load takes \"model\" or \"file\", not both")
                }
                (None, None) => {
                    anyhow::bail!("load needs \"model\" (inline text) or \"file\" (path)")
                }
            };
            Ok(Request::Load { kind, source })
        }
        "unload" => Ok(Request::Unload { kind: req_kind(v)? }),
        "list" => Ok(Request::List),
        "score" => {
            let matcher = match v.get("matcher") {
                None => Matcher::Compiled,
                Some(m) => match m.as_str() {
                    Some("compiled") => Matcher::Compiled,
                    Some("naive") => Matcher::Naive,
                    _ => anyhow::bail!("\"matcher\" must be \"compiled\" or \"naive\""),
                },
            };
            let records = v
                .get("records")
                .ok_or_else(|| anyhow::anyhow!("score needs a \"records\" array"))?
                .clone();
            Ok(Request::Score { kind: req_kind(v)?, records, matcher })
        }
        "stats" => Ok(Request::Stats),
        "shutdown" => Ok(Request::Shutdown),
        other => anyhow::bail!(
            "unknown op '{other}' (expected load, unload, list, score, stats or shutdown)"
        ),
    }
}

fn req_kind(v: &Json) -> crate::Result<String> {
    v.get("kind")
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| anyhow::anyhow!("request needs a string \"kind\" field (I, G, S or R)"))
}

/// A decoded `records` payload, already normalized for its substrate.
pub enum RecordBatch {
    Itemsets(Vec<Vec<u32>>),
    Graphs(Vec<Graph>),
    Sequences(Vec<Vec<u32>>),
    Tabular(Vec<Vec<f64>>),
}

impl RecordBatch {
    pub fn len(&self) -> usize {
        match self {
            RecordBatch::Itemsets(rows) => rows.len(),
            RecordBatch::Graphs(gs) => gs.len(),
            RecordBatch::Sequences(seqs) => seqs.len(),
            RecordBatch::Tabular(rows) => rows.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Decode a `records` array for a substrate kind. Item-set rows are
/// normalized to transaction normal form (the loader invariant the
/// matchers rely on); sequences keep their order; graphs are validated
/// structurally (edge endpoints in range) before construction, since
/// [`Graph::add_edge`] itself does not bounds-check.
pub fn decode_records(kind: &str, v: &Json) -> crate::Result<RecordBatch> {
    let arr = v.as_arr().ok_or_else(|| anyhow::anyhow!("\"records\" must be an array"))?;
    if kind == Transactions::KIND_TAG {
        let mut rows = Vec::with_capacity(arr.len());
        for (i, r) in arr.iter().enumerate() {
            let row = u32_list(r).map_err(|e| anyhow::anyhow!("record {i}: {e}"))?;
            rows.push(normalize_items(row));
        }
        Ok(RecordBatch::Itemsets(rows))
    } else if kind == Sequences::KIND_TAG {
        let mut seqs = Vec::with_capacity(arr.len());
        for (i, r) in arr.iter().enumerate() {
            seqs.push(u32_list(r).map_err(|e| anyhow::anyhow!("record {i}: {e}"))?);
        }
        Ok(RecordBatch::Sequences(seqs))
    } else if kind == GraphDatabase::KIND_TAG {
        let mut graphs = Vec::with_capacity(arr.len());
        for (i, r) in arr.iter().enumerate() {
            graphs.push(decode_graph(r).map_err(|e| anyhow::anyhow!("record {i}: {e}"))?);
        }
        Ok(RecordBatch::Graphs(graphs))
    } else if kind == TabularData::KIND_TAG {
        let mut rows = Vec::with_capacity(arr.len());
        for (i, r) in arr.iter().enumerate() {
            rows.push(f64_list(r).map_err(|e| anyhow::anyhow!("record {i}: {e}"))?);
        }
        Ok(RecordBatch::Tabular(rows))
    } else {
        anyhow::bail!("unknown substrate kind '{kind}' (the shipped tags are I, G, S, R)")
    }
}

fn u32_list(v: &Json) -> crate::Result<Vec<u32>> {
    let arr = v
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("expected an array of non-negative integers"))?;
    arr.iter()
        .map(|x| x.as_u32().ok_or_else(|| anyhow::anyhow!("expected a non-negative integer")))
        .collect()
}

fn f64_list(v: &Json) -> crate::Result<Vec<f64>> {
    let arr = v.as_arr().ok_or_else(|| anyhow::anyhow!("expected an array of finite numbers"))?;
    arr.iter()
        .map(|x| match x.as_f64() {
            Some(f) if f.is_finite() => Ok(f),
            _ => Err(anyhow::anyhow!("expected a finite number")),
        })
        .collect()
}

fn decode_graph(v: &Json) -> crate::Result<Graph> {
    let vl = v
        .get("v")
        .ok_or_else(|| anyhow::anyhow!("graph record needs \"v\" (vertex labels)"))?;
    let labels = u32_list(vl)?;
    let edges = v
        .get("e")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("graph record needs an \"e\" edge array"))?;
    let mut g = Graph::new();
    for &l in &labels {
        g.add_vertex(l);
    }
    for e in edges {
        let t = u32_list(e)?;
        anyhow::ensure!(t.len() == 3, "graph edge must be [u, v, elabel]");
        anyhow::ensure!(
            (t[0] as usize) < labels.len() && (t[1] as usize) < labels.len(),
            "edge endpoint out of range"
        );
        // Self-loops and duplicate edges are ignored by add_edge, the
        // same policy as the .gsp file parser.
        g.add_edge(t[0], t[1], t[2]);
    }
    Ok(g)
}

/// Build a JSON object from `(&str, Json)` pairs, preserving order.
pub fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// A success response line (no trailing newline).
pub fn ok_line(id: Option<&Json>, result: Json) -> String {
    envelope(id, true, ("result", result))
}

/// An error response line (no trailing newline).
pub fn err_line(id: Option<&Json>, message: &str) -> String {
    envelope(id, false, ("error", Json::Str(message.to_string())))
}

fn envelope(id: Option<&Json>, ok: bool, payload: (&str, Json)) -> String {
    let mut fields = vec![
        ("spp".to_string(), Json::Num(PROTOCOL_VERSION as f64)),
        ("ok".to_string(), Json::Bool(ok)),
    ];
    if let Some(id) = id {
        fields.push(("id".to_string(), id.clone()));
    }
    fields.push((payload.0.to_string(), payload.1));
    Json::Obj(fields).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_round_trips_deterministically() {
        let cases = [
            r#"{"op":"list"}"#,
            r#"{"a":[1,2,3],"b":{"c":null,"d":true,"e":false}}"#,
            r#"{"s":"line\nbreak \"quoted\" \\slash","n":-4}"#,
            r#"[[],{},"",0]"#,
        ];
        for text in cases {
            let v = Json::parse(text).unwrap();
            assert_eq!(v.to_string(), text, "canonical form should round-trip");
            let again = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, again);
        }
    }

    #[test]
    fn unicode_escapes_parse() {
        let v = Json::parse(r#""aéb😀c""#).unwrap();
        assert_eq!(v.as_str(), Some("a\u{e9}b\u{1f600}c"));
        // Raw multi-byte UTF-8 passes through untouched.
        let v = Json::parse("\"héllo — ok\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo — ok"));
        assert!(Json::parse(r#""\ud83d""#).is_err(), "lone surrogate");
    }

    #[test]
    fn malformed_json_is_an_error_not_a_panic() {
        for bad in [
            "",
            "{",
            "[1,",
            "{\"a\":}",
            "{\"a\" 1}",
            "tru",
            "1e999",
            "\"unterminated",
            "{} {}",
            "nul",
            "[1] 2",
            "{\"a\":1,}",
        ] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
        // Nesting past the cap is rejected, not overflowed.
        let deep = "[".repeat(200) + &"]".repeat(200);
        assert!(Json::parse(&deep).is_err());
    }

    #[test]
    fn number_formatting_is_canonical() {
        assert_eq!(fmt_f64(0.0), "0");
        assert_eq!(fmt_f64(-0.0), "0");
        assert_eq!(fmt_f64(3.0), "3");
        assert_eq!(fmt_f64(-17.0), "-17");
        assert_eq!(fmt_f64(f64::NAN), "null");
        assert_eq!(fmt_f64(f64::INFINITY), "null");
        // Non-integral values round-trip through the shortest {:e}.
        let v: f64 = fmt_f64(0.1).parse().unwrap();
        assert_eq!(v, 0.1);
    }

    #[test]
    fn requests_decode() {
        let (id, req) = parse_request(r#"{"op":"load","model":"spp-model ...","id":7}"#);
        assert_eq!(id, Some(Json::Num(7.0)));
        assert!(matches!(
            req.unwrap(),
            Request::Load { kind: None, source: ModelSource::Inline(_) }
        ));

        let (_, req) = parse_request(r#"{"op":"score","kind":"I","records":[[1,2]]}"#);
        let Request::Score { kind, matcher, .. } = req.unwrap() else {
            panic!("expected score");
        };
        assert_eq!(kind, "I");
        assert_eq!(matcher, Matcher::Compiled);

        let (_, req) =
            parse_request(r#"{"op":"score","kind":"S","records":[],"matcher":"naive"}"#);
        assert!(matches!(req.unwrap(), Request::Score { matcher: Matcher::Naive, .. }));

        for bad in [
            "garbage",
            "[1,2]",
            r#"{"op":"frobnicate"}"#,
            r#"{"op":"score","records":[]}"#,
            r#"{"op":"load"}"#,
            r#"{"op":"load","model":"x","file":"y"}"#,
            r#"{"op":"score","kind":"I","records":[],"matcher":"quantum"}"#,
        ] {
            let (_, req) = parse_request(bad);
            assert!(req.is_err(), "should reject {bad:?}");
        }
        // The id is still recovered from a well-formed line whose
        // request is invalid.
        let (id, req) = parse_request(r#"{"op":"frobnicate","id":"x9"}"#);
        assert_eq!(id, Some(Json::Str("x9".to_string())));
        assert!(req.is_err());
    }

    #[test]
    fn records_decode_per_substrate() {
        let v = Json::parse("[[3,1,2,2],[]]").unwrap();
        let RecordBatch::Itemsets(rows) = decode_records("I", &v).unwrap() else {
            panic!("expected itemsets");
        };
        assert_eq!(rows, vec![vec![1, 2, 3], vec![]], "rows normalize to sorted-unique");

        let RecordBatch::Sequences(seqs) = decode_records("S", &v).unwrap() else {
            panic!("expected sequences");
        };
        assert_eq!(seqs, vec![vec![3, 1, 2, 2], vec![]], "sequence order is preserved");

        let g = Json::parse(r#"[{"v":[5,6],"e":[[0,1,2]]}]"#).unwrap();
        let RecordBatch::Graphs(gs) = decode_records("G", &g).unwrap() else {
            panic!("expected graphs");
        };
        assert_eq!(gs[0].n_vertices(), 2);
        assert_eq!(gs[0].n_edges(), 1);

        let t = Json::parse("[[0.5,-1.25],[]]").unwrap();
        let RecordBatch::Tabular(rows) = decode_records("R", &t).unwrap() else {
            panic!("expected tabular rows");
        };
        assert_eq!(rows, vec![vec![0.5, -1.25], vec![]]);

        let bad = Json::parse(r#"[{"v":[5],"e":[[0,1,2]]}]"#).unwrap();
        assert!(decode_records("G", &bad).is_err(), "endpoint out of range");
        assert!(decode_records("X", &v).is_err(), "unknown kind");
        assert!(decode_records("I", &Json::parse("[[1.5]]").unwrap()).is_err());
        assert!(decode_records("I", &Json::parse("[[-1]]").unwrap()).is_err());
        assert!(decode_records("R", &Json::parse(r#"[["a"]]"#).unwrap()).is_err());
        assert!(decode_records("R", &Json::parse("[0.5]").unwrap()).is_err());
    }

    #[test]
    fn envelopes_echo_ids_first_fields_fixed() {
        let id = Json::Num(3.0);
        assert_eq!(
            ok_line(Some(&id), obj(vec![("n", Json::Num(1.0))])),
            r#"{"spp":1,"ok":true,"id":3,"result":{"n":1}}"#
        );
        assert_eq!(err_line(None, "boom"), r#"{"spp":1,"ok":false,"error":"boom"}"#);
    }
}
