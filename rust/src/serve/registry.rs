//! Model registry: one served model per substrate kind, hot-reloadable.
//!
//! The registry is keyed by the canonical (`'static`) substrate
//! `KIND_TAG`, so `score` requests route by the same tag the model
//! format and the miners use. Loading a model for a kind that already
//! has one replaces it atomically between requests — in-flight
//! batches always see exactly one model. A `BTreeMap` keeps listing
//! order deterministic (`G` < `I` < `R` < `S`).

use std::collections::BTreeMap;

use crate::data::graph::GraphDatabase;
use crate::data::sequence::Sequences;
use crate::data::tabular::TabularData;
use crate::data::Transactions;
use crate::mining::PatternSubstrate;
use crate::model::SparsePatternModel;

use super::compiled::CompiledModel;

/// Resolve a wire-supplied substrate tag to its canonical `'static`
/// form, rejecting unknown tags.
pub fn canonical_tag(kind: &str) -> crate::Result<&'static str> {
    if kind == Transactions::KIND_TAG {
        Ok(Transactions::KIND_TAG)
    } else if kind == GraphDatabase::KIND_TAG {
        Ok(GraphDatabase::KIND_TAG)
    } else if kind == Sequences::KIND_TAG {
        Ok(Sequences::KIND_TAG)
    } else if kind == TabularData::KIND_TAG {
        Ok(TabularData::KIND_TAG)
    } else {
        anyhow::bail!("unknown substrate kind '{kind}' (the shipped tags are I, G, S, R)")
    }
}

/// The single substrate tag of a model's terms: `None` for an empty
/// model, an error for a mixed-kind model — the registry key and the
/// record decoder are both per-substrate, so a mixed model is not
/// servable as one entry.
fn unique_kind(model: &SparsePatternModel) -> crate::Result<Option<&'static str>> {
    let mut found: Option<&'static str> = None;
    for (p, _) in &model.terms {
        let tag = p.kind_tag();
        match found {
            None => found = Some(tag),
            Some(t) if t == tag => {}
            Some(t) => anyhow::bail!(
                "mixed-substrate model ({t} and {tag} terms) cannot be served; split it per kind"
            ),
        }
    }
    Ok(found)
}

/// A served model: the parsed source (kept for the naive matcher),
/// its compiled form, and per-entry counters.
pub struct ModelEntry {
    pub model: SparsePatternModel,
    pub compiled: CompiledModel,
    /// Times a model was loaded under this kind, hot reloads included.
    pub loads: u64,
    pub score_batches: u64,
    pub records_scored: u64,
}

/// What a successful `load` reports back.
pub struct LoadReport {
    pub kind: &'static str,
    /// `true` when an existing model for this kind was replaced.
    pub reloaded: bool,
}

#[derive(Default)]
pub struct ModelRegistry {
    entries: BTreeMap<&'static str, ModelEntry>,
}

impl ModelRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse, compile and install a model. The kind is inferred from
    /// the model's terms; an explicit `kind_hint` is validated against
    /// the inference and is required for empty models (which carry no
    /// terms to infer from).
    pub fn load(&mut self, text: &str, kind_hint: Option<&str>) -> crate::Result<LoadReport> {
        let model = SparsePatternModel::parse(text)?;
        let inferred = unique_kind(&model)?;
        let kind = match (kind_hint, inferred) {
            (Some(h), Some(i)) => {
                let h = canonical_tag(h)?;
                anyhow::ensure!(
                    h == i,
                    "model holds {i}-kind patterns but the request says kind '{h}'"
                );
                i
            }
            (Some(h), None) => canonical_tag(h)?,
            (None, Some(i)) => i,
            (None, None) => {
                anyhow::bail!("an empty model needs an explicit \"kind\" (I, G, S or R)")
            }
        };
        let compiled = CompiledModel::compile_for(&model, kind)?;
        let loads = self.entries.get(kind).map(|e| e.loads).unwrap_or(0) + 1;
        let entry = ModelEntry { model, compiled, loads, score_batches: 0, records_scored: 0 };
        let reloaded = self.entries.insert(kind, entry).is_some();
        Ok(LoadReport { kind, reloaded })
    }

    /// Remove the model for a kind; an error if none is loaded.
    pub fn unload(&mut self, kind: &str) -> crate::Result<&'static str> {
        let kind = canonical_tag(kind)?;
        anyhow::ensure!(self.entries.remove(kind).is_some(), "no model loaded for kind '{kind}'");
        Ok(kind)
    }

    /// The entry for a kind, mutably (scoring updates its counters).
    pub fn get_mut(&mut self, kind: &str) -> crate::Result<&mut ModelEntry> {
        let kind = canonical_tag(kind)?;
        self.entries
            .get_mut(kind)
            .ok_or_else(|| anyhow::anyhow!("no model loaded for kind '{kind}'"))
    }

    /// Entries in deterministic tag-sorted order.
    pub fn iter(&self) -> impl Iterator<Item = (&'static str, &ModelEntry)> {
        self.entries.iter().map(|(k, v)| (*k, v))
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ITEMSET_MODEL: &str = "spp-model v1 task=classification lambda=1 b=0\nI 1 1,2\n";
    const SEQ_MODEL: &str = "spp-model v1 task=classification lambda=1 b=0\nS 1 3,4\n";
    const RULE_MODEL: &str = "spp-model v1 task=regression lambda=1 b=0\nR 1 x0<=0.5&x2>0.25\n";
    const EMPTY_MODEL: &str = "spp-model v1 task=regression lambda=1 b=0.5\n";

    #[test]
    fn load_infers_kind_and_hot_reloads() {
        let mut reg = ModelRegistry::new();
        let r = reg.load(ITEMSET_MODEL, None).unwrap();
        assert_eq!(r.kind, "I");
        assert!(!r.reloaded);
        assert_eq!(reg.get_mut("I").unwrap().loads, 1);

        // Same kind again: replaced, load counter carried forward.
        let r = reg.load(ITEMSET_MODEL, Some("I")).unwrap();
        assert!(r.reloaded);
        assert_eq!(reg.get_mut("I").unwrap().loads, 2);

        // Other kinds coexist; listing order is tag-sorted.
        reg.load(SEQ_MODEL, None).unwrap();
        let r = reg.load(RULE_MODEL, None).unwrap();
        assert_eq!(r.kind, "R");
        let kinds: Vec<&str> = reg.iter().map(|(k, _)| k).collect();
        assert_eq!(kinds, vec!["I", "R", "S"]);
        assert_eq!(reg.len(), 3);
    }

    #[test]
    fn kind_validation() {
        let mut reg = ModelRegistry::new();
        assert!(reg.load(ITEMSET_MODEL, Some("S")).is_err(), "hint contradicts terms");
        assert!(reg.load(ITEMSET_MODEL, Some("Z")).is_err(), "unknown hint");
        assert!(reg.load(EMPTY_MODEL, None).is_err(), "empty model needs a kind");
        let r = reg.load(EMPTY_MODEL, Some("G")).unwrap();
        assert_eq!(r.kind, "G");
        assert!(reg.load("not a model", None).is_err(), "parse errors propagate");
    }

    #[test]
    fn unload_and_lookup_errors() {
        let mut reg = ModelRegistry::new();
        assert!(reg.get_mut("I").is_err());
        assert!(reg.unload("I").is_err());
        assert!(reg.unload("Q").is_err());
        reg.load(ITEMSET_MODEL, None).unwrap();
        assert_eq!(reg.unload("I").unwrap(), "I");
        assert!(reg.is_empty());
    }
}
