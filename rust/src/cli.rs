//! Minimal CLI argument parsing (the vendored crate set has no clap).
//!
//! Grammar: `spp <command> [--flag value | --switch] [positional...]`.
//! Flags may appear anywhere after the command; `--flag=value` works.

use std::collections::HashMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut it = raw.into_iter().peekable();
        let command = it.next().unwrap_or_default();
        let mut args = Args {
            command,
            ..Args::default()
        };
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|nxt| !nxt.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.flags.insert(name.to_string(), v);
                } else {
                    args.switches.push(name.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.flags.contains_key(name)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.flag(name).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name}: bad number '{v}': {e}")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name}: bad integer '{v}': {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_flags_switches_positionals() {
        // note: a bare `--switch` followed by a non-flag token consumes
        // it as a value (documented grammar); positionals go first or
        // the switch goes last.
        let a = parse("path out.json --dataset cpdb --maxpat 5 --certify");
        assert_eq!(a.command, "path");
        assert_eq!(a.flag("dataset"), Some("cpdb"));
        assert_eq!(a.get_usize("maxpat", 0).unwrap(), 5);
        assert!(a.switch("certify"));
        assert_eq!(a.positional, vec!["out.json"]);
    }

    #[test]
    fn switch_before_positional_swallows_it() {
        // the documented footgun, pinned so it stays documented
        let a = parse("path --certify out.json");
        assert_eq!(a.flag("certify"), Some("out.json"));
        assert!(a.switch("certify"));
        assert!(a.positional.is_empty());
    }

    #[test]
    fn equals_form_and_defaults() {
        let a = parse("mine --scale=0.5");
        assert_eq!(a.get_f64("scale", 1.0).unwrap(), 0.5);
        assert_eq!(a.get_f64("missing", 2.5).unwrap(), 2.5);
        assert_eq!(a.get_or("dataset", "cpdb"), "cpdb");
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse("x --n abc");
        assert!(a.get_usize("n", 1).is_err());
    }

    #[test]
    fn trailing_switch_is_a_switch() {
        let a = parse("run --verbose");
        assert!(a.switch("verbose"));
        assert!(!a.switch("quiet"));
    }
}
