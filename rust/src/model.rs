//! Fitted models: prediction on new data and persistence.
//!
//! A [`SparsePatternModel`] is what a path point denotes as a usable
//! artifact: the intercept plus `(pattern, weight)` pairs.  Prediction
//! evaluates `x_it = I(t ⊆ G_i)` on *new* records — trivial subset
//! tests for item-sets, subgraph-isomorphism (label-respecting
//! backtracking, fine at pattern size ≤ maxpat) for graphs.
//!
//! Persistence is a line-oriented text format (the vendored crate set
//! has no serde): stable, diffable, and round-trip tested.

use crate::data::graph::Graph;
use crate::data::synth_itemsets::contains_all;
use crate::data::Transactions;
use crate::mining::gspan::{code_to_labeled_graph, DfsEdge};
use crate::mining::Pattern;
use crate::path::PathPoint;
use crate::solver::Task;

/// A fitted sparse linear model over patterns.
#[derive(Clone, Debug, PartialEq)]
pub struct SparsePatternModel {
    pub task: Task,
    pub lambda: f64,
    pub b: f64,
    pub terms: Vec<(Pattern, f64)>,
}

impl SparsePatternModel {
    /// Extract the model at one path point.
    pub fn from_path_point(task: Task, p: &PathPoint) -> Self {
        SparsePatternModel {
            task,
            lambda: p.lambda,
            b: p.b,
            terms: p.active.clone(),
        }
    }

    /// Raw score `Σ_t w_t·I(t ⊆ row) + b` for one transaction.
    pub fn score_itemset(&self, row: &[u32]) -> f64 {
        let mut s = self.b;
        for (pat, w) in &self.terms {
            if let Pattern::Itemset(items) = pat {
                if contains_all(row, items) {
                    s += w;
                }
            }
        }
        s
    }

    /// Raw score for one graph record.
    pub fn score_graph(&self, g: &Graph) -> f64 {
        let mut s = self.b;
        for (pat, w) in &self.terms {
            if let Pattern::Subgraph(code) = pat {
                if contains_subgraph(g, &code_to_labeled_graph(code)) {
                    s += w;
                }
            }
        }
        s
    }

    /// Predictions for a transaction database (sign for classification).
    pub fn predict_itemsets(&self, db: &Transactions) -> Vec<f64> {
        db.items
            .iter()
            .map(|row| self.output(self.score_itemset(row)))
            .collect()
    }

    /// Predictions for a slice of graphs.
    pub fn predict_graphs(&self, graphs: &[Graph]) -> Vec<f64> {
        graphs
            .iter()
            .map(|g| self.output(self.score_graph(g)))
            .collect()
    }

    fn output(&self, score: f64) -> f64 {
        match self.task {
            Task::Regression => score,
            Task::Classification => {
                if score >= 0.0 {
                    1.0
                } else {
                    -1.0
                }
            }
        }
    }

    /// Serialize to the line format parsed by [`SparsePatternModel::parse`].
    pub fn serialize(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "spp-model v1 task={} lambda={:.17e} b={:.17e}\n",
            match self.task {
                Task::Regression => "regression",
                Task::Classification => "classification",
            },
            self.lambda,
            self.b
        ));
        for (pat, w) in &self.terms {
            match pat {
                Pattern::Itemset(items) => {
                    let list: Vec<String> = items.iter().map(|i| i.to_string()).collect();
                    out.push_str(&format!("I {:.17e} {}\n", w, list.join(",")));
                }
                Pattern::Subgraph(code) => {
                    let list: Vec<String> = code
                        .iter()
                        .map(|e| {
                            format!("{}:{}:{}:{}:{}", e.from, e.to, e.from_label, e.elabel, e.to_label)
                        })
                        .collect();
                    out.push_str(&format!("G {:.17e} {}\n", w, list.join(",")));
                }
            }
        }
        out
    }

    /// Parse the [`SparsePatternModel::serialize`] format.
    pub fn parse(text: &str) -> crate::Result<Self> {
        let mut lines = text.lines();
        let header = lines.next().ok_or_else(|| anyhow::anyhow!("empty model file"))?;
        let mut task = None;
        let mut lambda = None;
        let mut b = None;
        for tok in header.split_whitespace().skip(2) {
            let (k, v) = tok
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("bad header token '{tok}'"))?;
            match k {
                "task" => {
                    task = Some(match v {
                        "regression" => Task::Regression,
                        "classification" => Task::Classification,
                        other => anyhow::bail!("unknown task '{other}'"),
                    })
                }
                "lambda" => lambda = Some(v.parse::<f64>()?),
                "b" => b = Some(v.parse::<f64>()?),
                other => anyhow::bail!("unknown header key '{other}'"),
            }
        }
        if !header.starts_with("spp-model v1") {
            anyhow::bail!("not an spp-model v1 file");
        }
        let mut terms = Vec::new();
        for (lineno, line) in lines.enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut f = line.splitn(3, ' ');
            let kind = f.next().unwrap();
            let w: f64 = f
                .next()
                .ok_or_else(|| anyhow::anyhow!("line {}: missing weight", lineno + 2))?
                .parse()?;
            let body = f
                .next()
                .ok_or_else(|| anyhow::anyhow!("line {}: missing pattern", lineno + 2))?;
            let pat = match kind {
                "I" => Pattern::Itemset(
                    body.split(',')
                        .map(|t| t.parse::<u32>())
                        .collect::<Result<Vec<_>, _>>()?,
                ),
                "G" => {
                    let code: Vec<DfsEdge> = body
                        .split(',')
                        .map(|t| -> crate::Result<DfsEdge> {
                            let p: Vec<&str> = t.split(':').collect();
                            anyhow::ensure!(p.len() == 5, "bad edge '{t}'");
                            Ok(DfsEdge {
                                from: p[0].parse()?,
                                to: p[1].parse()?,
                                from_label: p[2].parse()?,
                                elabel: p[3].parse()?,
                                to_label: p[4].parse()?,
                            })
                        })
                        .collect::<crate::Result<Vec<_>>>()?;
                    Pattern::Subgraph(code)
                }
                other => anyhow::bail!("line {}: unknown record '{other}'", lineno + 2),
            };
            terms.push((pat, w));
        }
        Ok(SparsePatternModel {
            task: task.ok_or_else(|| anyhow::anyhow!("header missing task"))?,
            lambda: lambda.ok_or_else(|| anyhow::anyhow!("header missing lambda"))?,
            b: b.ok_or_else(|| anyhow::anyhow!("header missing b"))?,
            terms,
        })
    }
}

/// Label-respecting subgraph-isomorphism test: is `pattern` (connected,
/// small) contained in `g`?  Plain backtracking over vertex mappings
/// with degree/label pruning — exponential in |pattern| only, which
/// maxpat bounds.
pub fn contains_subgraph(g: &Graph, pattern: &Graph) -> bool {
    if pattern.n_vertices() == 0 {
        return true;
    }
    if pattern.n_vertices() > g.n_vertices() || pattern.n_edges() > g.n_edges() {
        return false;
    }
    let g_adj = g.adjacency();
    let p_adj = pattern.adjacency();
    let mut mapping = vec![u32::MAX; pattern.n_vertices()]; // pattern v -> g v
    let mut used = vec![false; g.n_vertices()];

    // match pattern vertices in a connectivity-respecting order
    let order = connectivity_order(pattern, &p_adj);
    backtrack(g, pattern, &g_adj, &p_adj, &order, 0, &mut mapping, &mut used)
}

fn connectivity_order(pattern: &Graph, adj: &[Vec<(u32, u32)>]) -> Vec<u32> {
    let mut order = vec![0u32];
    let mut seen = vec![false; pattern.n_vertices()];
    seen[0] = true;
    while order.len() < pattern.n_vertices() {
        let mut next = None;
        'outer: for &v in &order {
            for &(w, _) in &adj[v as usize] {
                if !seen[w as usize] {
                    next = Some(w);
                    break 'outer;
                }
            }
        }
        let v = next.expect("pattern must be connected");
        seen[v as usize] = true;
        order.push(v);
    }
    order
}

#[allow(clippy::too_many_arguments)]
fn backtrack(
    g: &Graph,
    pattern: &Graph,
    g_adj: &[Vec<(u32, u32)>],
    p_adj: &[Vec<(u32, u32)>],
    order: &[u32],
    depth: usize,
    mapping: &mut Vec<u32>,
    used: &mut Vec<bool>,
) -> bool {
    if depth == order.len() {
        return true;
    }
    let pv = order[depth] as usize;
    // candidates: all g vertices with the right label whose edges to
    // already-mapped pattern neighbors exist with matching labels
    'cand: for gv in 0..g.n_vertices() {
        if used[gv] || g.vlabels[gv] != pattern.vlabels[pv] {
            continue;
        }
        for &(pw, el) in &p_adj[pv] {
            let mapped = mapping[pw as usize];
            if mapped != u32::MAX {
                let ok = g_adj[gv]
                    .iter()
                    .any(|&(gn, gel)| gn == mapped && gel == el);
                if !ok {
                    continue 'cand;
                }
            }
        }
        mapping[pv] = gv as u32;
        used[gv] = true;
        if backtrack(g, pattern, g_adj, p_adj, order, depth + 1, mapping, used) {
            return true;
        }
        mapping[pv] = u32::MAX;
        used[gv] = false;
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mining::{PatternNode, TreeVisitor, Walk};
    use crate::screening::Database;

    fn path(labels: &[u32], elabels: &[u32]) -> Graph {
        let mut g = Graph::new();
        for &l in labels {
            g.add_vertex(l);
        }
        for (i, &el) in elabels.iter().enumerate() {
            g.add_edge(i as u32, i as u32 + 1, el);
        }
        g
    }

    #[test]
    fn subgraph_containment_basic() {
        let host = path(&[0, 1, 2, 1], &[0, 1, 0]);
        assert!(contains_subgraph(&host, &path(&[0, 1], &[0])));
        assert!(contains_subgraph(&host, &path(&[1, 2], &[1])));
        assert!(contains_subgraph(&host, &path(&[2, 1], &[0]))); // reversed
        assert!(!contains_subgraph(&host, &path(&[0, 2], &[0]))); // no such edge
        assert!(!contains_subgraph(&host, &path(&[0, 1], &[7]))); // wrong elabel
        assert!(!contains_subgraph(&host, &path(&[0, 1, 2, 1, 0], &[0, 1, 0, 0]))); // too big
    }

    #[test]
    fn subgraph_containment_triangle_vs_path() {
        let mut tri = Graph::new();
        for _ in 0..3 {
            tri.add_vertex(0);
        }
        tri.add_edge(0, 1, 0);
        tri.add_edge(1, 2, 0);
        tri.add_edge(0, 2, 0);
        let p3 = path(&[0, 0, 0], &[0, 0]);
        assert!(contains_subgraph(&tri, &p3));
        assert!(!contains_subgraph(&p3, &tri), "triangle is not in a path");
    }

    #[test]
    fn gspan_supports_match_containment_matcher() {
        // independent cross-check of two different matchers
        use crate::data::synth_graphs::{generate, GraphSynthConfig};
        let mut cfg = GraphSynthConfig::tiny(77, true);
        cfg.n = 10;
        cfg.min_atoms = 3;
        cfg.max_atoms = 6;
        let d = generate(&cfg);
        let mut checked = 0;
        let mut v = |n: &PatternNode<'_>| {
            if let Pattern::Subgraph(code) = n.to_pattern() {
                let pat = code_to_labeled_graph(&code);
                for (gid, g) in d.db.graphs.iter().enumerate() {
                    let in_support = n.support.contains(&(gid as u32));
                    assert_eq!(
                        contains_subgraph(g, &pat),
                        in_support,
                        "matcher disagrees with gSpan on gid {gid}"
                    );
                    checked += 1;
                }
            }
            Walk::Descend
        };
        Database::Graphs(&d.db).traverse(2, 1, &mut v);
        assert!(checked > 0);
    }

    #[test]
    fn model_round_trip_itemsets() {
        let m = SparsePatternModel {
            task: Task::Classification,
            lambda: 0.25,
            b: -0.5,
            terms: vec![
                (Pattern::Itemset(vec![1, 4, 9]), 1.5),
                (Pattern::Itemset(vec![2]), -0.75),
            ],
        };
        let back = SparsePatternModel::parse(&m.serialize()).unwrap();
        assert_eq!(m, back);
        // predictions: row {1,4,9} -> b + 1.5 = 1.0 -> +1
        assert_eq!(back.score_itemset(&[1, 4, 9]), 1.0);
        let db = Transactions {
            n_items: 10,
            items: vec![vec![1, 4, 9], vec![2], vec![]],
        };
        assert_eq!(back.predict_itemsets(&db), vec![1.0, -1.0, -1.0]);
    }

    #[test]
    fn model_round_trip_graphs() {
        use crate::mining::gspan::DfsEdge;
        let code = vec![DfsEdge {
            from: 0,
            to: 1,
            from_label: 0,
            elabel: 2,
            to_label: 1,
        }];
        let m = SparsePatternModel {
            task: Task::Regression,
            lambda: 1.0,
            b: 0.25,
            terms: vec![(Pattern::Subgraph(code), 2.0)],
        };
        let back = SparsePatternModel::parse(&m.serialize()).unwrap();
        assert_eq!(m, back);
        let has = path(&[0, 1], &[2]);
        let hasnt = path(&[0, 1], &[0]);
        assert_eq!(back.predict_graphs(&[has, hasnt]), vec![2.25, 0.25]);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(SparsePatternModel::parse("").is_err());
        assert!(SparsePatternModel::parse("not a model\n").is_err());
        assert!(SparsePatternModel::parse("spp-model v1 task=regression lambda=1 b=0\nX 1 2\n").is_err());
        assert!(SparsePatternModel::parse("spp-model v1 task=regression lambda=1 b=0\nI nope 2\n").is_err());
    }
}
