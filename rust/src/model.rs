//! Fitted models: prediction on new data and persistence.
//!
//! A [`SparsePatternModel`] is what a path point denotes as a usable
//! artifact: the intercept plus `(pattern, weight)` pairs.  Prediction
//! evaluates `x_it = I(t occurs in record)` on *new* records through
//! the owning substrate's [`PatternSubstrate::matches`] — subset tests
//! for item-sets, subgraph isomorphism for graphs, subsequence
//! containment for sequences.  Nothing in this module knows the
//! pattern kinds; scoring and the text codec both route through the
//! substrate trait, so a fourth substrate needs no change here.
//!
//! Persistence is a line-oriented text format (the vendored crate set
//! has no serde): stable, diffable, and round-trip tested.  Each term
//! line is `<KIND_TAG> <weight> <body>`, with tag and body delegated
//! to the substrate codec via [`Pattern::encode_body`] /
//! [`Pattern::decode`].

use crate::data::graph::{Graph, GraphDatabase};
use crate::data::sequence::Sequences;
use crate::data::tabular::TabularData;
use crate::data::Transactions;
use crate::mining::{Pattern, PatternSubstrate};
use crate::path::PathPoint;
use crate::solver::Task;

pub use crate::data::graph::contains_subgraph;

/// A fitted sparse linear model over patterns.
#[derive(Clone, Debug, PartialEq)]
pub struct SparsePatternModel {
    pub task: Task,
    pub lambda: f64,
    pub b: f64,
    pub terms: Vec<(Pattern, f64)>,
}

impl SparsePatternModel {
    /// Extract the model at one path point.
    pub fn from_path_point(task: Task, p: &PathPoint) -> Self {
        SparsePatternModel {
            task,
            lambda: p.lambda,
            b: p.b,
            terms: p.active.clone(),
        }
    }

    /// Raw score `Σ_t w_t·I(t occurs in record) + b` for one record of
    /// substrate `S`.  Terms of foreign pattern kinds contribute
    /// nothing (their `matches` is `false` by the substrate contract).
    pub fn score<S: PatternSubstrate>(&self, record: &S::Record) -> f64 {
        let mut s = self.b;
        for (pat, w) in &self.terms {
            if S::matches(pat, record) {
                s += w;
            }
        }
        s
    }

    /// Predictions for a whole database (sign for classification).
    pub fn predict<S: PatternSubstrate>(&self, db: &S) -> Vec<f64> {
        (0..db.n_records())
            .map(|i| self.output(self.score::<S>(db.record(i))))
            .collect()
    }

    /// Raw score for one transaction (see [`SparsePatternModel::score`]).
    pub fn score_itemset(&self, row: &[u32]) -> f64 {
        self.score::<Transactions>(row)
    }

    /// Raw score for one graph record.
    pub fn score_graph(&self, g: &Graph) -> f64 {
        self.score::<GraphDatabase>(g)
    }

    /// Raw score for one sequence record.
    pub fn score_sequence(&self, seq: &[u32]) -> f64 {
        self.score::<Sequences>(seq)
    }

    /// Raw score for one numeric tabular row (rule terms).
    pub fn score_tabular_row(&self, row: &[f64]) -> f64 {
        self.score::<TabularData>(row)
    }

    /// Predictions for a transaction database (sign for classification).
    pub fn predict_itemsets(&self, db: &Transactions) -> Vec<f64> {
        self.predict(db)
    }

    /// Predictions for a slice of graphs.
    pub fn predict_graphs(&self, graphs: &[Graph]) -> Vec<f64> {
        graphs
            .iter()
            .map(|g| self.output(self.score_graph(g)))
            .collect()
    }

    fn output(&self, score: f64) -> f64 {
        task_output(self.task, score)
    }

    /// Serialize to the line format parsed by [`SparsePatternModel::parse`].
    ///
    /// Errors with a `non-finite model` message if any weight, the
    /// intercept or λ is NaN/±inf: `{:.17e}` happily emits `NaN`, which
    /// would persist a model file [`SparsePatternModel::parse`] (and
    /// any sane consumer) rejects — `spp fit` must not write what
    /// `spp predict` cannot load.  Non-finite values here always mean
    /// an upstream numerical failure, so refusing loudly is the only
    /// safe behaviour.
    pub fn serialize(&self) -> crate::Result<String> {
        anyhow::ensure!(
            self.lambda.is_finite() && self.b.is_finite(),
            "non-finite model: lambda={} b={} — refusing to serialize",
            self.lambda,
            self.b
        );
        let mut out = String::new();
        out.push_str(&format!(
            "spp-model v1 task={} lambda={:.17e} b={:.17e}\n",
            match self.task {
                Task::Regression => "regression",
                Task::Classification => "classification",
            },
            self.lambda,
            self.b
        ));
        for (pat, w) in &self.terms {
            anyhow::ensure!(
                w.is_finite(),
                "non-finite model: weight {w} on pattern {} — refusing to serialize",
                pat.display()
            );
            out.push_str(&format!(
                "{} {:.17e} {}\n",
                pat.kind_tag(),
                w,
                pat.encode_body()
            ));
        }
        Ok(out)
    }

    /// Parse the [`SparsePatternModel::serialize`] format.
    pub fn parse(text: &str) -> crate::Result<Self> {
        let mut lines = text.lines();
        let header = lines.next().ok_or_else(|| anyhow::anyhow!("empty model file"))?;
        let mut task = None;
        let mut lambda = None;
        let mut b = None;
        for tok in header.split_whitespace().skip(2) {
            let (k, v) = tok
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("bad header token '{tok}'"))?;
            match k {
                "task" => {
                    task = Some(match v {
                        "regression" => Task::Regression,
                        "classification" => Task::Classification,
                        other => anyhow::bail!("unknown task '{other}'"),
                    })
                }
                "lambda" => lambda = Some(parse_finite(v, "lambda")?),
                "b" => b = Some(parse_finite(v, "b")?),
                other => anyhow::bail!("unknown header key '{other}'"),
            }
        }
        if !header.starts_with("spp-model v1") {
            anyhow::bail!("not an spp-model v1 file");
        }
        let mut terms = Vec::new();
        for (lineno, line) in lines.enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut f = line.splitn(3, ' ');
            let kind = f.next().unwrap();
            let w: f64 = match f.next() {
                Some(v) => parse_finite(v, "weight")
                    .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 2))?,
                None => anyhow::bail!("line {}: missing weight", lineno + 2),
            };
            let body = f
                .next()
                .ok_or_else(|| anyhow::anyhow!("line {}: missing pattern", lineno + 2))?;
            let pat = Pattern::decode(kind, body)
                .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 2))?;
            terms.push((pat, w));
        }
        Ok(SparsePatternModel {
            task: task.ok_or_else(|| anyhow::anyhow!("header missing task"))?,
            lambda: lambda.ok_or_else(|| anyhow::anyhow!("header missing lambda"))?,
            b: b.ok_or_else(|| anyhow::anyhow!("header missing b"))?,
            terms,
        })
    }
}

/// The task's output transform on a raw score: identity for
/// regression, `sign` (with `0 ↦ +1`) for classification.
///
/// Public so every scorer — [`SparsePatternModel::predict`] and the
/// serve-time compiled matcher (`serve::compiled`) — applies the *same*
/// transform; the differential tests pin them bit-identical.
pub fn task_output(task: Task, score: f64) -> f64 {
    match task {
        Task::Regression => score,
        Task::Classification => {
            if score >= 0.0 {
                1.0
            } else {
                -1.0
            }
        }
    }
}

/// Parse an f64 that must be finite (Rust's `FromStr` happily accepts
/// `NaN`/`inf`, which are never legitimate in a persisted model).
fn parse_finite(v: &str, what: &str) -> crate::Result<f64> {
    let x: f64 = v
        .parse()
        .map_err(|e| anyhow::anyhow!("bad {what} '{v}': {e}"))?;
    anyhow::ensure!(x.is_finite(), "non-finite {what} '{v}'");
    Ok(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mining::gspan::code_to_labeled_graph;
    use crate::mining::{PatternNode, Walk};

    fn path(labels: &[u32], elabels: &[u32]) -> Graph {
        let mut g = Graph::new();
        for &l in labels {
            g.add_vertex(l);
        }
        for (i, &el) in elabels.iter().enumerate() {
            g.add_edge(i as u32, i as u32 + 1, el);
        }
        g
    }

    #[test]
    fn subgraph_containment_basic() {
        let host = path(&[0, 1, 2, 1], &[0, 1, 0]);
        assert!(contains_subgraph(&host, &path(&[0, 1], &[0])));
        assert!(contains_subgraph(&host, &path(&[1, 2], &[1])));
        assert!(contains_subgraph(&host, &path(&[2, 1], &[0]))); // reversed
        assert!(!contains_subgraph(&host, &path(&[0, 2], &[0]))); // no such edge
        assert!(!contains_subgraph(&host, &path(&[0, 1], &[7]))); // wrong elabel
        assert!(!contains_subgraph(&host, &path(&[0, 1, 2, 1, 0], &[0, 1, 0, 0]))); // too big
    }

    #[test]
    fn subgraph_containment_triangle_vs_path() {
        let mut tri = Graph::new();
        for _ in 0..3 {
            tri.add_vertex(0);
        }
        tri.add_edge(0, 1, 0);
        tri.add_edge(1, 2, 0);
        tri.add_edge(0, 2, 0);
        let p3 = path(&[0, 0, 0], &[0, 0]);
        assert!(contains_subgraph(&tri, &p3));
        assert!(!contains_subgraph(&p3, &tri), "triangle is not in a path");
    }

    #[test]
    fn gspan_supports_match_containment_matcher() {
        // independent cross-check of two different matchers
        use crate::data::synth_graphs::{generate, GraphSynthConfig};
        let mut cfg = GraphSynthConfig::tiny(77, true);
        cfg.n = 10;
        cfg.min_atoms = 3;
        cfg.max_atoms = 6;
        let d = generate(&cfg);
        let mut checked = 0;
        let mut v = |n: &PatternNode<'_>| {
            if let Pattern::Subgraph(code) = n.to_pattern() {
                let pat = code_to_labeled_graph(&code);
                for (gid, g) in d.db.graphs.iter().enumerate() {
                    let in_support = n.support.contains(&(gid as u32));
                    assert_eq!(
                        contains_subgraph(g, &pat),
                        in_support,
                        "matcher disagrees with gSpan on gid {gid}"
                    );
                    checked += 1;
                }
            }
            Walk::Descend
        };
        d.db.traverse(2, 1, &mut v);
        assert!(checked > 0);
    }

    #[test]
    fn model_round_trip_itemsets() {
        let m = SparsePatternModel {
            task: Task::Classification,
            lambda: 0.25,
            b: -0.5,
            terms: vec![
                (Pattern::Itemset(vec![1, 4, 9]), 1.5),
                (Pattern::Itemset(vec![2]), -0.75),
            ],
        };
        let back = SparsePatternModel::parse(&m.serialize().unwrap()).unwrap();
        assert_eq!(m, back);
        // predictions: row {1,4,9} -> b + 1.5 = 1.0 -> +1
        assert_eq!(back.score_itemset(&[1, 4, 9]), 1.0);
        let db = Transactions {
            n_items: 10,
            items: vec![vec![1, 4, 9], vec![2], vec![]],
        };
        assert_eq!(back.predict_itemsets(&db), vec![1.0, -1.0, -1.0]);
    }

    #[test]
    fn model_round_trip_graphs() {
        use crate::mining::gspan::DfsEdge;
        let code = vec![DfsEdge {
            from: 0,
            to: 1,
            from_label: 0,
            elabel: 2,
            to_label: 1,
        }];
        let m = SparsePatternModel {
            task: Task::Regression,
            lambda: 1.0,
            b: 0.25,
            terms: vec![(Pattern::Subgraph(code), 2.0)],
        };
        let back = SparsePatternModel::parse(&m.serialize().unwrap()).unwrap();
        assert_eq!(m, back);
        let has = path(&[0, 1], &[2]);
        let hasnt = path(&[0, 1], &[0]);
        assert_eq!(back.predict_graphs(&[has, hasnt]), vec![2.25, 0.25]);
    }

    #[test]
    fn model_round_trip_sequences() {
        let m = SparsePatternModel {
            task: Task::Classification,
            lambda: 0.5,
            b: -0.25,
            terms: vec![
                (Pattern::Sequence(vec![3, 3, 1]), 1.0),
                (Pattern::Sequence(vec![2]), -0.5),
            ],
        };
        let text = m.serialize().unwrap();
        assert!(text.contains("\nS "), "sequence terms use the S tag:\n{text}");
        let back = SparsePatternModel::parse(&text).unwrap();
        assert_eq!(m, back);
        // <3,3,1> ⊑ [3,0,3,1]: b + 1.0 = 0.75 -> +1; [2,3]: b - 0.5 -> -1
        let db = Sequences {
            n_symbols: 4,
            seqs: vec![vec![3, 0, 3, 1], vec![2, 3], vec![]],
        };
        assert_eq!(back.score_sequence(&[3, 0, 3, 1]), 0.75);
        assert_eq!(back.predict(&db), vec![1.0, -1.0, -1.0]);
    }

    #[test]
    fn model_round_trip_rules() {
        use crate::mining::rulefit::{RuleOp, RulePredicate};
        let m = SparsePatternModel {
            task: Task::Classification,
            lambda: 0.5,
            b: -0.25,
            terms: vec![
                (
                    // thresholds that are not exactly representable in
                    // decimal must still round-trip bit-exactly
                    Pattern::Rule(vec![
                        RulePredicate::new(0, RuleOp::Le, 1.0 / 3.0),
                        RulePredicate::new(2, RuleOp::Gt, 0.1),
                    ]),
                    1.0,
                ),
                (Pattern::Rule(vec![RulePredicate::new(1, RuleOp::Gt, -2.5)]), -0.5),
            ],
        };
        let text = m.serialize().unwrap();
        assert!(text.contains("\nR "), "rule terms use the R tag:\n{text}");
        let back = SparsePatternModel::parse(&text).unwrap();
        assert_eq!(m, back);
        // row [0.2, -3.0, 0.5]: rule 1 holds, rule 2 doesn't -> 0.75 -> +1
        assert_eq!(back.score_tabular_row(&[0.2, -3.0, 0.5]), 0.75);
        let db = TabularData::new(3, vec![vec![0.2, -3.0, 0.5], vec![0.9, 0.0, 0.0]]);
        assert_eq!(back.predict(&db), vec![1.0, -1.0]);
    }

    #[test]
    fn mixed_substrate_model_scores_only_its_own_terms() {
        // a model holding all three kinds round-trips and each scorer
        // sees only the matching kind
        let m = SparsePatternModel {
            task: Task::Regression,
            lambda: 1.0,
            b: 0.0,
            terms: vec![
                (Pattern::Itemset(vec![1]), 1.0),
                (Pattern::Sequence(vec![1]), 2.0),
            ],
        };
        let back = SparsePatternModel::parse(&m.serialize().unwrap()).unwrap();
        assert_eq!(m, back);
        assert_eq!(back.score_itemset(&[1]), 1.0);
        assert_eq!(back.score_sequence(&[1]), 2.0);
    }

    #[test]
    fn non_finite_models_refuse_to_serialize_and_parse_rejects_them() {
        // the fit→persist→predict round trip must fail CLOSED: a model
        // with a NaN/inf weight (an upstream numerical failure) is
        // rejected at serialize time with a named error, and a file
        // that somehow holds one is rejected at parse time too
        let finite = SparsePatternModel {
            task: Task::Regression,
            lambda: 0.5,
            b: 0.25,
            terms: vec![(Pattern::Itemset(vec![1, 2]), -0.75)],
        };
        // the finite model round-trips bit-exactly
        let back = SparsePatternModel::parse(&finite.serialize().unwrap()).unwrap();
        assert_eq!(finite, back);
        for bad_w in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let mut m = finite.clone();
            m.terms[0].1 = bad_w;
            let err = m.serialize().unwrap_err();
            assert!(
                err.to_string().contains("non-finite model"),
                "weight {bad_w}: {err}"
            );
        }
        let mut m = finite.clone();
        m.b = f64::NAN;
        assert!(m.serialize().unwrap_err().to_string().contains("non-finite model"));
        m.b = 0.25;
        m.lambda = f64::INFINITY;
        assert!(m.serialize().is_err());
        // parse-side rejection of hand-written non-finite values (Rust's
        // f64 FromStr accepts "NaN" and "inf", so this needs the guard)
        for text in [
            "spp-model v1 task=regression lambda=1 b=0\nI NaN 1,2\n",
            "spp-model v1 task=regression lambda=1 b=0\nI inf 1,2\n",
            "spp-model v1 task=regression lambda=NaN b=0\n",
            "spp-model v1 task=regression lambda=1 b=inf\n",
        ] {
            let err = SparsePatternModel::parse(text).unwrap_err();
            assert!(err.to_string().contains("non-finite"), "{text:?}: {err}");
        }
    }

    #[test]
    fn parse_rejects_garbage() {
        let with_term = |term: &str| {
            SparsePatternModel::parse(&format!(
                "spp-model v1 task=regression lambda=1 b=0\n{term}\n"
            ))
        };
        assert!(SparsePatternModel::parse("").is_err());
        assert!(SparsePatternModel::parse("not a model\n").is_err());
        assert!(with_term("X 1 2").is_err());
        assert!(with_term("I nope 2").is_err());
        assert!(with_term("S 1 x").is_err());
    }

    #[test]
    fn parse_rejects_malformed_dfs_codes() {
        let model = |body: &str| {
            SparsePatternModel::parse(&format!(
                "spp-model v1 task=regression lambda=1 b=0\nG 1 {body}\n"
            ))
        };
        // vertex id out of range for the edge count (would allocate
        // huge graphs at predict time)
        assert!(model("0:100000000:0:0:1").is_err());
        // disconnected pattern graph (would panic in the matcher)
        assert!(model("0:1:0:0:1,2:3:5:0:6,0:1:0:0:1").is_err());
        // undetermined vertex label
        assert!(model("0:1:0:0:1,1:2:-1:0:-1").is_err());
        // a well-formed code still parses
        assert!(model("0:1:0:0:1,1:2:-1:0:2").is_ok());
    }
}
