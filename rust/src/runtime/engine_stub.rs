//! Graceful-degradation stub for the PJRT engines (default build).
//!
//! The real backend (`engine_xla.rs`, feature `pjrt`) executes the AOT
//! JAX/Pallas artifacts through the external `xla` bindings crate.
//! That crate needs a native `xla_extension` install, so the default
//! build mounts this stub at the same module path instead:
//!
//! * every public type and signature of the real engine exists here,
//!   so downstream code (CLI `--engine xla`, `spp selftest`, the
//!   integration tests, ablation A3) compiles unchanged;
//! * [`PjrtRuntime::cpu`] — the only way to construct a runtime —
//!   returns a descriptive error, so every artifact-dependent code
//!   path reports "built without the `pjrt` feature" up front instead
//!   of crashing, and the runtime-gated tests and benches skip
//!   themselves exactly as they do when `artifacts/` is absent.
//!
//! Because no [`PjrtRuntime`] can ever exist in a stub build, the
//! remaining types ([`XlaSppcScorer`], [`XlaFistaSolver`],
//! [`XlaRestricted`]) are **compile-parity stubs**: their methods are
//! unreachable in practice.  [`XlaRestricted`]'s
//! [`crate::path::RestrictedSolver`] impl keeps the engine seam
//! compiling and, if ever invoked, simply delegates to the f64 CD
//! solver — the same fallback the real engine takes when no artifact
//! fits — but the live degradation path in default builds is the
//! caller's own: `--engine rust` (the default) never touches this
//! module, and `--engine xla` fails fast at [`PjrtRuntime::cpu`].

use std::path::Path;

use super::artifacts::ArtifactSet;
use crate::columns::{ColumnRead, ColumnView};
use crate::solver::Task;

pub use super::engine_common::{cd_solve_views, power_lipschitz, SppcScore, XlaSolution};

/// Error message shared by every stubbed entry point.
const UNAVAILABLE: &str =
    "PJRT runtime unavailable: spp was built without the `pjrt` feature \
     (enable the `xla` dependency in rust/Cargo.toml and build with \
     `--features pjrt`)";

/// Stub of the PJRT CPU client.  [`PjrtRuntime::cpu`] always errors, so
/// no instance can be constructed; the methods exist for API parity.
pub struct PjrtRuntime {
    artifacts: ArtifactSet,
}

impl PjrtRuntime {
    /// Always errors in stub builds (see module docs).
    pub fn cpu(_dir: &Path) -> crate::Result<Self> {
        anyhow::bail!("{UNAVAILABLE}")
    }

    pub fn artifacts(&self) -> &ArtifactSet {
        &self.artifacts
    }

    pub fn platform(&self) -> String {
        "unavailable (built without the `pjrt` feature)".to_string()
    }
}

/// Stub of the batched SPPC frontier scorer.
pub struct XlaSppcScorer<'r> {
    rt: &'r PjrtRuntime,
}

impl<'r> XlaSppcScorer<'r> {
    pub fn new(rt: &'r PjrtRuntime, _n: usize) -> crate::Result<Self> {
        let _ = &rt.artifacts;
        anyhow::bail!("{UNAVAILABLE}")
    }

    /// Patterns per launch (0: no artifact is loadable in stub builds).
    pub fn block_width(&self) -> usize {
        let _ = self.rt;
        0
    }

    pub fn score<S: ColumnRead>(
        &self,
        _supports: &[S],
        _wpos: &[f64],
        _wneg: &[f64],
        _radius: f64,
    ) -> crate::Result<Vec<SppcScore>> {
        anyhow::bail!("{UNAVAILABLE}")
    }
}

/// Stub of the FISTA active-set solver.
pub struct XlaFistaSolver<'r> {
    rt: &'r PjrtRuntime,
    /// Relative gap tolerance (unused in stub builds).
    pub tol: f64,
    /// Hard cap on artifact executions per solve (unused in stub builds).
    pub max_execs: usize,
}

impl<'r> XlaFistaSolver<'r> {
    pub fn new(rt: &'r PjrtRuntime) -> Self {
        XlaFistaSolver {
            rt,
            tol: 1e-4,
            max_execs: 400,
        }
    }

    pub fn solve<S: ColumnRead>(
        &self,
        _task: Task,
        _supports: &[S],
        _y: &[f64],
        _lam: f64,
    ) -> crate::Result<XlaSolution> {
        let _ = self.rt;
        anyhow::bail!("{UNAVAILABLE}")
    }
}

/// Stub path-engine adapter: every restricted solve falls back to the
/// pure-Rust CD solver (recorded in `fallbacks`), mirroring the real
/// adapter's behaviour when no artifact fits the problem.
pub struct XlaRestricted<'r> {
    pub fista: XlaFistaSolver<'r>,
    pub cd: crate::solver::CdSolver,
    pub fallbacks: std::cell::Cell<usize>,
    /// CD polish flag (kept for API parity; the stub always solves with
    /// CD outright).
    pub polish: bool,
}

impl<'r> XlaRestricted<'r> {
    pub fn new(rt: &'r PjrtRuntime) -> Self {
        XlaRestricted {
            fista: XlaFistaSolver::new(rt),
            cd: crate::solver::CdSolver::default(),
            fallbacks: std::cell::Cell::new(0),
            polish: true,
        }
    }
}

impl crate::path::RestrictedSolver for XlaRestricted<'_> {
    fn solve_restricted(
        &self,
        task: Task,
        supports: &[ColumnView<'_>],
        y: &[f64],
        lam: f64,
        warm_w: &[f64],
        warm_b: f64,
    ) -> crate::solver::Solution {
        self.fallbacks.set(self.fallbacks.get() + 1);
        // the shared vectorized-CD entry: hybrid views run the word
        // kernels instead of degrading to the scalar walk
        cd_solve_views(&self.cd, task, supports, y, lam, warm_w, warm_b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_reports_missing_feature() {
        let err = PjrtRuntime::cpu(Path::new("artifacts")).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
