//! Artifact manifest discovery.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.txt` alongside the
//! HLO text files: one tab-separated row per artifact
//! (`name kind n cols steps file`).  The runtime discovers artifacts
//! exclusively through the manifest — file names are never parsed.

use std::path::{Path, PathBuf};

/// What computation an artifact implements.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// Batched SPPC frontier scorer (inputs `x[n,b], w_pos, w_neg, r`).
    Sppc,
    /// FISTA epoch + gap epilogue, squared loss.
    FistaSquared,
    /// FISTA epoch + gap epilogue, squared hinge.
    FistaHinge,
}

impl ArtifactKind {
    fn parse(s: &str) -> Option<Self> {
        match s {
            "sppc" => Some(ArtifactKind::Sppc),
            "fista_sq" => Some(ArtifactKind::FistaSquared),
            "fista_hinge" => Some(ArtifactKind::FistaHinge),
            _ => None,
        }
    }
}

/// One artifact's metadata.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub kind: ArtifactKind,
    /// Padded sample count.
    pub n: usize,
    /// Padded column count (SPPC block width / FISTA active-set width).
    pub cols: usize,
    /// FISTA iterations per execution (0 for SPPC).
    pub steps: usize,
    pub path: PathBuf,
}

/// All artifacts in one directory.
#[derive(Clone, Debug, Default)]
pub struct ArtifactSet {
    pub entries: Vec<ArtifactInfo>,
}

impl ArtifactSet {
    /// Parse `dir/manifest.txt`; missing files are an error.
    pub fn discover(dir: &Path) -> crate::Result<Self> {
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} (run `make artifacts` first): {e}",
                manifest.display()
            )
        })?;
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let f: Vec<&str> = line.split('\t').collect();
            if f.len() != 6 {
                anyhow::bail!("manifest line {}: expected 6 fields", lineno + 1);
            }
            let kind = ArtifactKind::parse(f[1]).ok_or_else(|| {
                anyhow::anyhow!("manifest line {}: unknown kind '{}'", lineno + 1, f[1])
            })?;
            let info = ArtifactInfo {
                name: f[0].to_string(),
                kind,
                n: f[2].parse()?,
                cols: f[3].parse()?,
                steps: f[4].parse()?,
                path: dir.join(f[5]),
            };
            if !info.path.is_file() {
                anyhow::bail!("manifest references missing file {}", info.path.display());
            }
            entries.push(info);
        }
        Ok(ArtifactSet { entries })
    }

    /// Smallest artifact of `kind` that fits `n` samples and `cols`
    /// columns (ties broken by padded area).
    pub fn best_fit(&self, kind: ArtifactKind, n: usize, cols: usize) -> Option<&ArtifactInfo> {
        self.entries
            .iter()
            .filter(|a| a.kind == kind && a.n >= n && a.cols >= cols)
            .min_by_key(|a| a.n * a.cols)
    }

    /// Largest column capacity available for `kind` at sample count `n`
    /// (used to split oversized active sets into solvable chunks).
    pub fn max_cols(&self, kind: ArtifactKind, n: usize) -> Option<usize> {
        self.entries
            .iter()
            .filter(|a| a.kind == kind && a.n >= n)
            .map(|a| a.cols)
            .max()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, rows: &[&str]) {
        for r in rows {
            let file = r.split('\t').next_back().unwrap();
            std::fs::File::create(dir.join(file))
                .unwrap()
                .write_all(b"HloModule fake")
                .unwrap();
        }
        let mut f = std::fs::File::create(dir.join("manifest.txt")).unwrap();
        writeln!(f, "# header").unwrap();
        for r in rows {
            writeln!(f, "{r}").unwrap();
        }
    }

    #[test]
    fn discover_and_best_fit() {
        let tmp = std::env::temp_dir().join(format!("spp-artifacts-{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        write_manifest(
            &tmp,
            &[
                "sppc_1024x256\tsppc\t1024\t256\t0\ta.hlo.txt",
                "sppc_8192x256\tsppc\t8192\t256\t0\tb.hlo.txt",
                "fista_sq_8192x1024\tfista_sq\t8192\t1024\t16\tc.hlo.txt",
            ],
        );
        let set = ArtifactSet::discover(&tmp).unwrap();
        assert_eq!(set.entries.len(), 3);
        let a = set.best_fit(ArtifactKind::Sppc, 600, 100).unwrap();
        assert_eq!(a.n, 1024);
        let b = set.best_fit(ArtifactKind::Sppc, 2000, 256).unwrap();
        assert_eq!(b.n, 8192);
        assert!(set.best_fit(ArtifactKind::Sppc, 100_000, 1).is_none());
        assert_eq!(set.max_cols(ArtifactKind::FistaSquared, 1000), Some(1024));
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn missing_manifest_is_helpful_error() {
        let err = ArtifactSet::discover(Path::new("/nonexistent-dir-xyz")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
