//! PJRT runtime: load and execute the AOT JAX/Pallas artifacts from the
//! Rust hot path.  Python never runs here — `make artifacts` produced
//! HLO text once; this module compiles it on the PJRT CPU client
//! (`xla` crate) and executes it with concrete buffers.
//!
//! * [`artifacts`] — manifest discovery (`artifacts/manifest.txt`),
//!   shape-family lookup (smallest padded shape that fits the live
//!   data).
//! * [`engine`] — the two accelerated engines: the batched SPPC
//!   frontier scorer (L1 Pallas kernel) and the FISTA active-set
//!   subproblem solver (L2 graph), both pad-to-shape.
//! * [`parallel`] — the deterministic scoped worker pool behind the
//!   engine's `--threads` knob (subtree-parallel traversal, forest
//!   re-screening, CV folds); dependency-free, results in task order.

pub mod artifacts;
mod engine_common;
pub mod parallel;

/// The engine backend: real PJRT execution with the `pjrt` feature
/// (`engine_xla.rs`, needs the external `xla` crate), a graceful
/// same-API stub otherwise (`engine_stub.rs`).
#[cfg(feature = "pjrt")]
#[path = "engine_xla.rs"]
pub mod engine;
#[cfg(not(feature = "pjrt"))]
#[path = "engine_stub.rs"]
pub mod engine;

pub use artifacts::{ArtifactInfo, ArtifactKind, ArtifactSet};
pub use engine::{PjrtRuntime, SppcScore, XlaFistaSolver, XlaSppcScorer};

/// Default artifact directory, overridable via `SPP_ARTIFACTS`.
pub fn default_artifact_dir() -> std::path::PathBuf {
    if let Ok(dir) = std::env::var("SPP_ARTIFACTS") {
        return dir.into();
    }
    // walk up from CWD looking for artifacts/manifest.txt (covers
    // `cargo test`/`cargo bench` execution from target subdirs)
    let mut cur = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.txt").is_file() {
            return cand;
        }
        if !cur.pop() {
            return "artifacts".into();
        }
    }
}
