//! The accelerated engines: PJRT execution of the AOT artifacts.
//!
//! This is the real backend, compiled only with the `pjrt` feature (it
//! needs the external `xla` bindings crate — see `rust/Cargo.toml`).
//! Without the feature, `engine_stub.rs` is mounted at this module path
//! instead and degrades gracefully to the pure-Rust engines.
//!
//! Pad-to-shape discipline: artifacts have fixed `(n, cols)`; live data
//! is zero-padded up to the smallest fitting artifact.  A `mask` input
//! (FISTA) / zero support columns (SPPC) make padding semantically
//! inert — verified against the pure-Rust implementations in
//! `tests/integration_runtime.rs`.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;

use super::artifacts::{ArtifactInfo, ArtifactKind, ArtifactSet};
use crate::columns::{ColumnRead, ColumnView};
use crate::solver::Task;

pub use super::engine_common::{cd_solve_views, power_lipschitz, SppcScore, XlaSolution};

/// A PJRT CPU client plus a compile cache over the artifact set.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    artifacts: ArtifactSet,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl PjrtRuntime {
    /// Create a CPU runtime over `dir` (see
    /// [`super::default_artifact_dir`]).
    pub fn cpu(dir: &std::path::Path) -> crate::Result<Self> {
        let artifacts = ArtifactSet::discover(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(PjrtRuntime {
            client,
            artifacts,
            cache: RefCell::new(HashMap::new()),
        })
    }

    pub fn artifacts(&self) -> &ArtifactSet {
        &self.artifacts
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact's executable.
    fn load(&self, info: &ArtifactInfo) -> crate::Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(&info.name) {
            return Ok(exe.clone());
        }
        let exe = Rc::new(compile_hlo(&self.client, &info.path)?);
        self.cache
            .borrow_mut()
            .insert(info.name.clone(), exe.clone());
        Ok(exe)
    }
}

fn compile_hlo(
    client: &xla::PjRtClient,
    path: &PathBuf,
) -> crate::Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow::anyhow!("compile {}: {e:?}", path.display()))
}

fn lit_f32_vec(v: &[f32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

fn lit_f32_mat(v: &[f32], rows: usize, cols: usize) -> crate::Result<xla::Literal> {
    xla::Literal::vec1(v)
        .reshape(&[rows as i64, cols as i64])
        .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
}

// ---------------------------------------------------------------------------
// SPPC frontier scorer
// ---------------------------------------------------------------------------

/// Batched SPPC scorer backed by the L1 Pallas kernel.
///
/// Densifies frontier support columns into the artifact's `(n, b)`
/// panel and scores up to `b` patterns per launch.
pub struct XlaSppcScorer<'r> {
    rt: &'r PjrtRuntime,
    info: ArtifactInfo,
    exe: Rc<xla::PjRtLoadedExecutable>,
}

impl<'r> XlaSppcScorer<'r> {
    /// Pick the smallest SPPC artifact fitting `n` samples.
    pub fn new(rt: &'r PjrtRuntime, n: usize) -> crate::Result<Self> {
        let info = rt
            .artifacts
            .best_fit(ArtifactKind::Sppc, n, 1)
            .ok_or_else(|| anyhow::anyhow!("no sppc artifact for n={n}"))?
            .clone();
        let exe = rt.load(&info)?;
        Ok(XlaSppcScorer { rt, info, exe })
    }

    /// Patterns per launch.
    pub fn block_width(&self) -> usize {
        self.info.cols
    }

    /// Score a frontier of supports.  `wpos`/`wneg` are the folded
    /// per-sample weights (see `screening::fold_weights`), `radius` the
    /// gap-safe radius.  Any number of supports is accepted; they are
    /// processed in blocks of [`Self::block_width`].
    pub fn score<S: ColumnRead>(
        &self,
        supports: &[S],
        wpos: &[f64],
        wneg: &[f64],
        radius: f64,
    ) -> crate::Result<Vec<SppcScore>> {
        let _ = self.rt;
        let n_pad = self.info.n;
        let b = self.info.cols;
        anyhow::ensure!(wpos.len() <= n_pad, "n={} exceeds artifact n={}", wpos.len(), n_pad);
        let mut wpos_f: Vec<f32> = vec![0.0; n_pad];
        let mut wneg_f: Vec<f32> = vec![0.0; n_pad];
        for (i, &v) in wpos.iter().enumerate() {
            wpos_f[i] = v as f32;
        }
        for (i, &v) in wneg.iter().enumerate() {
            wneg_f[i] = v as f32;
        }
        let wpos_lit = lit_f32_vec(&wpos_f);
        let wneg_lit = lit_f32_vec(&wneg_f);
        let r_lit = xla::Literal::scalar(radius as f32);

        let mut out = Vec::with_capacity(supports.len());
        let mut x = vec![0.0f32; n_pad * b];
        for chunk in supports.chunks(b) {
            x.iter_mut().for_each(|v| *v = 0.0);
            for (t, sup) in chunk.iter().enumerate() {
                sup.for_each_id(|i| x[i * b + t] = 1.0);
            }
            let x_lit = lit_f32_mat(&x, n_pad, b)?;
            let result = self
                .exe
                .execute::<xla::Literal>(&[
                    x_lit,
                    wpos_lit.clone_literal()?,
                    wneg_lit.clone_literal()?,
                    r_lit.clone_literal()?,
                ])
                .map_err(|e| anyhow::anyhow!("sppc execute: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("sppc readback: {e:?}"))?;
            let packed = result
                .to_tuple1()
                .map_err(|e| anyhow::anyhow!("sppc untuple: {e:?}"))?;
            let vals: Vec<f32> = packed
                .to_vec()
                .map_err(|e| anyhow::anyhow!("sppc to_vec: {e:?}"))?;
            for t in 0..chunk.len() {
                out.push(SppcScore {
                    sppc: vals[t * 3] as f64,
                    u: vals[t * 3 + 1] as f64,
                    v: vals[t * 3 + 2] as f64,
                });
            }
        }
        Ok(out)
    }
}

/// The `xla` crate's `Literal` is not `Clone`; round-trip through raw
/// bytes to duplicate small constant inputs across launches.
trait CloneLiteral {
    fn clone_literal(&self) -> crate::Result<xla::Literal>;
}

impl CloneLiteral for xla::Literal {
    fn clone_literal(&self) -> crate::Result<xla::Literal> {
        let shape = self
            .array_shape()
            .map_err(|e| anyhow::anyhow!("literal shape: {e:?}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let count = self.element_count();
        let mut buf: Vec<f32> = vec![0.0; count];
        self.copy_raw_to(&mut buf)
            .map_err(|e| anyhow::anyhow!("literal copy: {e:?}"))?;
        if dims.is_empty() {
            Ok(xla::Literal::scalar(buf[0]))
        } else if dims.len() == 1 {
            Ok(xla::Literal::vec1(&buf))
        } else {
            lit_f32_mat(&buf, dims[0], dims[1])
        }
    }
}

// ---------------------------------------------------------------------------
// FISTA subproblem solver
// ---------------------------------------------------------------------------

/// FISTA active-set solver backed by the L2 artifact family.
pub struct XlaFistaSolver<'r> {
    rt: &'r PjrtRuntime,
    /// Relative gap tolerance.
    pub tol: f64,
    /// Hard cap on artifact executions per solve.
    pub max_execs: usize,
}

impl<'r> XlaFistaSolver<'r> {
    pub fn new(rt: &'r PjrtRuntime) -> Self {
        XlaFistaSolver {
            rt,
            // f32 arithmetic floors the reachable gap around 1e-5·P; the
            // path engine (XlaRestricted) polishes to the paper's 1e-6
            // in f64 CD afterwards.
            tol: 1e-4,
            max_execs: 400,
        }
    }

    /// Solve the restricted problem over `supports` via the AOT FISTA
    /// artifact.  Requires an artifact with `n >= y.len()` and
    /// `cols >= supports.len()`.
    pub fn solve<S: ColumnRead>(
        &self,
        task: Task,
        supports: &[S],
        y: &[f64],
        lam: f64,
    ) -> crate::Result<XlaSolution> {
        let kind = match task {
            Task::Regression => ArtifactKind::FistaSquared,
            Task::Classification => ArtifactKind::FistaHinge,
        };
        let n = y.len();
        let k = supports.len();
        let info = self
            .rt
            .artifacts
            .best_fit(kind, n, k.max(1))
            .ok_or_else(|| anyhow::anyhow!("no {kind:?} artifact for n={n}, d={k}"))?
            .clone();
        let exe = self.rt.load(&info)?;
        let (n_pad, d_pad) = (info.n, info.cols);

        // dense padded panel + targets + mask
        let mut x = vec![0.0f32; n_pad * d_pad];
        for (t, sup) in supports.iter().enumerate() {
            sup.for_each_id(|i| x[i * d_pad + t] = 1.0);
        }
        let mut y_f = vec![0.0f32; n_pad];
        let mut mask = vec![0.0f32; n_pad];
        for i in 0..n {
            y_f[i] = y[i] as f32;
            mask[i] = 1.0;
        }
        // Lipschitz constant: σ_max²([X 1]) by power iteration (the
        // Frobenius bound is 10–100× looser and throttles FISTA's step).
        let lip = power_lipschitz(supports, n) * 1.05;

        let mut w = vec![0.0f32; d_pad];
        let mut vw = vec![0.0f32; d_pad];
        let mut tail = vec![0.0f32; 8];
        tail[2] = 1.0; // tk
        // constant inputs are built ONCE; `execute` takes Borrow<Literal>
        // so the big X panel is not re-marshalled per call
        let x_lit = lit_f32_mat(&x, n_pad, d_pad)?;
        let y_lit = lit_f32_vec(&y_f);
        let mask_lit = lit_f32_vec(&mask);
        let lam_lit = lit_f32_vec(&[lam as f32]);
        let lip_lit = lit_f32_vec(&[lip as f32]);
        let mut execs = 0usize;
        let (mut primal, mut dual, mut gap) = (f64::INFINITY, f64::NEG_INFINITY, f64::INFINITY);
        let mut stagnant = 0usize;
        while execs < self.max_execs {
            execs += 1;
            let w_lit = lit_f32_vec(&w);
            let vw_lit = lit_f32_vec(&vw);
            let tail_lit = lit_f32_vec(&tail);
            let inputs: [&xla::Literal; 8] = [
                &x_lit, &y_lit, &mask_lit, &w_lit, &vw_lit, &tail_lit, &lam_lit, &lip_lit,
            ];
            let result = exe
                .execute::<&xla::Literal>(&inputs)
                .map_err(|e| anyhow::anyhow!("fista execute: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("fista readback: {e:?}"))?;
            let (w_l, vw_l, tail_l) = result
                .to_tuple3()
                .map_err(|e| anyhow::anyhow!("fista untuple: {e:?}"))?;
            w = w_l.to_vec().map_err(|e| anyhow::anyhow!("{e:?}"))?;
            vw = vw_l.to_vec().map_err(|e| anyhow::anyhow!("{e:?}"))?;
            tail = tail_l.to_vec().map_err(|e| anyhow::anyhow!("{e:?}"))?;
            primal = tail[3] as f64;
            dual = tail[4] as f64;
            let new_gap = tail[5] as f64;
            // f32 stagnation guard: stop when the gap has flatlined
            if new_gap >= gap * 0.999 {
                stagnant += 1;
                if stagnant >= 20 {
                    gap = new_gap.min(gap);
                    break;
                }
            } else {
                stagnant = 0;
            }
            gap = new_gap;
            if gap <= self.tol * primal.abs().max(1.0) {
                break;
            }
        }
        Ok(XlaSolution {
            w: w[..k].iter().map(|&v| v as f64).collect(),
            b: tail[0] as f64,
            primal,
            dual,
            gap,
            execs,
        })
    }
}

// ---------------------------------------------------------------------------
// Path-engine adapter
// ---------------------------------------------------------------------------

/// Adapter: the XLA FISTA engine as a [`crate::path::RestrictedSolver`].
///
/// The artifact returns `(w, b)` in f32; the certificate (slack, dual
/// point, objectives) is recomputed in f64 on the Rust side so the gap
/// fed to the *next* λ's screening rule has full precision.  If the
/// active set outgrows every artifact, the adapter falls back to the CD
/// solver (recorded in `fallbacks`).
pub struct XlaRestricted<'r> {
    pub fista: XlaFistaSolver<'r>,
    pub cd: crate::solver::CdSolver,
    pub fallbacks: std::cell::Cell<usize>,
    /// CD polish after the XLA solve (keeps the 1e-6 f64 gap contract
    /// while XLA does the bulk of the descent in f32).
    pub polish: bool,
}

impl<'r> XlaRestricted<'r> {
    pub fn new(rt: &'r PjrtRuntime) -> Self {
        XlaRestricted {
            fista: XlaFistaSolver::new(rt),
            cd: crate::solver::CdSolver::default(),
            fallbacks: std::cell::Cell::new(0),
            polish: true,
        }
    }
}

impl crate::path::RestrictedSolver for XlaRestricted<'_> {
    fn solve_restricted(
        &self,
        task: Task,
        supports: &[ColumnView<'_>],
        y: &[f64],
        lam: f64,
        warm_w: &[f64],
        warm_b: f64,
    ) -> crate::solver::Solution {
        let kind = match task {
            Task::Regression => ArtifactKind::FistaSquared,
            Task::Classification => ArtifactKind::FistaHinge,
        };
        let fits = self
            .fista
            .rt
            .artifacts()
            .best_fit(kind, y.len(), supports.len().max(1))
            .is_some();
        if !fits || supports.is_empty() {
            self.fallbacks.set(self.fallbacks.get() + 1);
            return cd_solve_views(&self.cd, task, supports, y, lam, warm_w, warm_b);
        }
        match self.fista.solve(task, supports, y, lam) {
            Ok(xs) => {
                if self.polish {
                    cd_solve_views(&self.cd, task, supports, y, lam, &xs.w, xs.b)
                } else {
                    // certificate in f64 at the f32 iterate
                    let mut quick = crate::solver::CdSolver::default();
                    quick.cfg.max_epochs = 0;
                    cd_solve_views(&quick, task, supports, y, lam, &xs.w, xs.b)
                }
            }
            Err(_) => {
                self.fallbacks.set(self.fallbacks.get() + 1);
                cd_solve_views(&self.cd, task, supports, y, lam, warm_w, warm_b)
            }
        }
    }
}
