//! Engine types shared by the real PJRT backend (`engine_xla.rs`,
//! feature `pjrt`) and the graceful-degradation stub
//! (`engine_stub.rs`, the default).  Both are mounted as
//! [`super::engine`], so downstream code is feature-agnostic.

use crate::columns::{ColumnRead, ColumnView};
use crate::solver::cd::Warm;
use crate::solver::{CdSolver, Solution, Task};

/// Scores for one pattern: the SPP criterion and its ingredients.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SppcScore {
    pub sppc: f64,
    pub u: f64,
    pub v: f64,
}

/// Result of an XLA-backed subproblem solve.
#[derive(Clone, Debug)]
pub struct XlaSolution {
    pub w: Vec<f64>,
    pub b: f64,
    pub primal: f64,
    pub dual: f64,
    pub gap: f64,
    /// Artifact executions (each = `steps` FISTA iterations).
    pub execs: usize,
}

/// Warm-started coordinate-descent solve over layout-aware column
/// views — the shared restricted-solve kernel behind both engine
/// builds' fallback/polish/certify arms.  With a hybrid pool the CD
/// update's gathers and the dynamic-screening folds run over 64-bit
/// bitmap words ([`crate::columns`]); with a sparse pool the same call
/// is the scalar oracle.  Either way the result is bit-identical to
/// `cd.solve` on plain `&[u32]` views of the same columns.
pub fn cd_solve_views(
    cd: &CdSolver,
    task: Task,
    supports: &[ColumnView<'_>],
    y: &[f64],
    lam: f64,
    warm_w: &[f64],
    warm_b: f64,
) -> Solution {
    cd.solve(task, supports, y, lam, Some(Warm { w: warm_w, b: warm_b }))
}

/// σ_max² of the intercept-augmented design `[X 1]` by power iteration
/// over the support columns (any [`ColumnRead`] carrier; hybrid
/// columns gather over bitmap words).  30 iterations are ample for a
/// step-size estimate (a 1.05 safety factor absorbs the residual).
pub fn power_lipschitz<S: ColumnRead>(supports: &[S], n: usize) -> f64 {
    let k = supports.len();
    let mut v = vec![1.0 / ((k + 1) as f64).sqrt(); k + 1];
    let mut sigma2 = n as f64; // the all-ones column alone gives n
    for _ in 0..30 {
        // u = A v
        let mut u = vec![v[k]; n];
        for (t, sup) in supports.iter().enumerate() {
            if v[t] != 0.0 {
                sup.for_each_id(|i| u[i] += v[t]);
            }
        }
        // v' = Aᵀ u
        let mut v2 = vec![0.0; k + 1];
        for (t, sup) in supports.iter().enumerate() {
            v2[t] = sup.dot(&u);
        }
        v2[k] = u.iter().sum();
        let norm = v2.iter().map(|x| x * x).sum::<f64>().sqrt();
        if norm <= 1e-30 {
            break;
        }
        sigma2 = norm; // ‖AᵀA v‖ → σ_max² as v converges
        v2.iter_mut().for_each(|x| *x /= norm);
        v = v2;
    }
    sigma2.max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_lipschitz_matches_dense_norm_on_tiny_problems() {
        // [X 1] with X = [[1],[1],[0]]: A^T A = [[2,2],[2,3]],
        // eigenvalues (5 ± sqrt(17))/2 -> sigma_max^2 ≈ 4.5616
        let sup = vec![vec![0u32, 1]];
        let got = power_lipschitz(&sup, 3);
        let want = (5.0 + 17.0f64.sqrt()) / 2.0;
        assert!((got - want).abs() < 1e-6, "{got} vs {want}");
    }

    #[test]
    fn power_lipschitz_no_columns_gives_n() {
        // only the all-ones intercept column: sigma_max^2 = n
        let none: [Vec<u32>; 0] = [];
        assert!((power_lipschitz(&none, 7) - 7.0).abs() < 1e-9);
    }
}
