//! `runtime::parallel` — the dependency-free deterministic worker pool.
//!
//! Every parallel phase of the engine (subtree-parallel substrate
//! traversal, screening-forest re-evaluation, CV folds) is expressed as
//! the same primitive: [`map_indexed`] runs `n` independent tasks on a
//! scoped `std::thread` pool behind a work-sharing index queue and
//! returns the results **in task order**.  Determinism therefore never
//! depends on scheduling: a caller that (a) makes task `i` a pure
//! function of the inputs and (b) combines the returned vector in index
//! order produces bit-identical output at any worker count — the
//! contract `tests/integration_parallel.rs` pins end-to-end and the CI
//! `test-matrix` job enforces at `SPP_THREADS ∈ {1, 4}` on every push.
//!
//! The pool is scoped ([`std::thread::scope`]), so tasks may borrow the
//! caller's data freely (databases, interned column pools, fold
//! vectors); no `'static` bounds, no channels, no external crates — the
//! build stays registry-hermetic.
//!
//! Thread-count resolution ([`resolve_threads`]): an explicit knob
//! (`--threads N`, `PathConfig::threads`, `SppEstimator::threads`)
//! wins; `0` means *auto* — the `SPP_THREADS` environment variable if
//! set, else [`std::thread::available_parallelism`].  `1` runs every
//! phase inline on the caller's thread, byte-for-byte the sequential
//! engine.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Thread-utilisation telemetry of one engine phase (recorded per λ in
/// `path::PathPoint::threads`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ThreadStats {
    /// Workers the phase actually ran on (1 = inline on the caller).
    pub workers: usize,
    /// Independent tasks farmed to those workers (subtree roots, stored
    /// forest roots, CV folds).  `0` whenever the phase ran inline, so
    /// `tasks > 0 ⇔ workers > 1` holds across every engine.
    pub tasks: usize,
}

impl ThreadStats {
    /// The sequential phase marker: one worker, nothing farmed.
    pub fn sequential() -> Self {
        ThreadStats {
            workers: 1,
            tasks: 0,
        }
    }

    /// Telemetry for a phase that offered `tasks` tasks at a `threads`
    /// knob: records the effective worker count, normalizing inline
    /// passes to [`ThreadStats::sequential`] — the one place the
    /// `tasks > 0 ⇔ workers > 1` invariant is encoded.
    pub fn for_phase(threads: usize, tasks: usize) -> Self {
        let workers = effective_workers(threads, tasks);
        if workers > 1 {
            ThreadStats { workers, tasks }
        } else {
            ThreadStats::sequential()
        }
    }
}

/// Resolve a thread-count knob: `requested > 0` is explicit; `0` means
/// auto — `SPP_THREADS` if set to a positive integer, else the
/// machine's available parallelism (1 if unknown).
pub fn resolve_threads(requested: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    if let Ok(v) = std::env::var("SPP_THREADS") {
        if let Ok(k) = v.trim().parse::<usize>() {
            if k > 0 {
                return k;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Workers [`map_indexed`] will use for `n` tasks at a `threads` knob:
/// never more workers than tasks, and `threads <= 1` or `n <= 1` stays
/// inline.
pub fn effective_workers(threads: usize, n: usize) -> usize {
    if threads <= 1 || n <= 1 {
        1
    } else {
        threads.min(n)
    }
}

/// Run `task(i)` for every `i < n` and return the results in index
/// order.
///
/// With more than one effective worker, indices are handed out through
/// a shared atomic cursor (the work-sharing queue: a fast worker simply
/// takes more subtree roots) and each result lands in its own slot, so
/// the output is independent of scheduling.  A panicking task panics
/// the caller when the scope joins, matching the inline behaviour.
pub fn map_indexed<T, F>(threads: usize, n: usize, task: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = effective_workers(threads, n);
    if workers <= 1 {
        return (0..n).map(task).collect();
    }
    let next = AtomicUsize::new(0);
    let out: Mutex<Vec<Option<T>>> = Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = task(i);
                out.lock().unwrap()[i] = Some(r);
            });
        }
    });
    out.into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("every index is claimed exactly once"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_index_order() {
        for threads in [1usize, 2, 4, 16] {
            let got = map_indexed(threads, 37, |i| i * i);
            let want: Vec<usize> = (0..37).map(|i| i * i).collect();
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn tasks_may_borrow_caller_state() {
        let data: Vec<u64> = (0..100).collect();
        let sums = map_indexed(4, 10, |i| data[i * 10..(i + 1) * 10].iter().sum::<u64>());
        assert_eq!(sums.iter().sum::<u64>(), data.iter().sum::<u64>());
    }

    #[test]
    fn empty_and_single_task_run_inline() {
        assert!(map_indexed::<usize, _>(8, 0, |_| unreachable!()).is_empty());
        assert_eq!(map_indexed(8, 1, |i| i + 1), vec![1]);
    }

    #[test]
    fn effective_workers_never_exceeds_tasks() {
        assert_eq!(effective_workers(8, 3), 3);
        assert_eq!(effective_workers(2, 100), 2);
        assert_eq!(effective_workers(1, 100), 1);
        assert_eq!(effective_workers(0, 100), 1);
        assert_eq!(effective_workers(8, 1), 1);
        assert_eq!(effective_workers(8, 0), 1);
    }

    #[test]
    fn resolve_honours_explicit_requests() {
        assert_eq!(resolve_threads(3), 3);
        assert_eq!(resolve_threads(1), 1);
        // auto resolves to something usable regardless of environment
        assert!(resolve_threads(0) >= 1);
    }

    #[test]
    fn every_index_is_computed_exactly_once() {
        use std::sync::atomic::AtomicU32;
        let calls: Vec<AtomicU32> = (0..64).map(|_| AtomicU32::new(0)).collect();
        map_indexed(6, 64, |i| calls[i].fetch_add(1, Ordering::Relaxed));
        assert!(calls.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn sequential_marker_reads_as_one_worker() {
        let s = ThreadStats::sequential();
        assert_eq!(s.workers, 1);
        assert_eq!(s.tasks, 0);
    }

    #[test]
    fn phase_telemetry_normalizes_inline_passes() {
        // parallel phases record workers + tasks …
        let p = ThreadStats::for_phase(4, 10);
        assert_eq!((p.workers, p.tasks), (4, 10));
        let p = ThreadStats::for_phase(8, 3);
        assert_eq!((p.workers, p.tasks), (3, 3));
        // … and every inline pass reads as the sequential marker, so
        // `tasks > 0 ⇔ workers > 1` regardless of engine
        for (threads, tasks) in [(1, 10), (4, 1), (4, 0), (0, 10)] {
            assert_eq!(ThreadStats::for_phase(threads, tasks), ThreadStats::sequential());
        }
    }
}
