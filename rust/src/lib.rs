//! # Safe Pattern Pruning (SPP)
//!
//! A production reproduction of *"Safe Pattern Pruning: An Efficient
//! Approach for Predictive Pattern Mining"* (Nakagawa et al., KDD 2016)
//! as a three-layer Rust + JAX + Pallas stack.
//!
//! The library fits L1-regularized linear models over the (exponentially
//! large) space of **patterns** in a database — item-sets of a
//! transaction database or connected subgraphs of a graph database —
//! without ever materializing that space.  The paper's contribution, the
//! **SPP rule**, is a gap-safe screening test evaluable at any node of
//! the pattern-enumeration tree; when it fires, the *entire subtree* is
//! certified to carry zero weight at the optimum and is skipped.
//!
//! ## Layout (one module per subsystem; see DESIGN.md)
//!
//! * [`data`] — datasets: LIBSVM parser, graph containers, seeded
//!   synthetic generators standing in for the paper's benchmark data.
//! * [`mining`] — the pattern-tree substrates: a prefix-extension
//!   item-set enumerator and a full gSpan implementation, both driven
//!   through the same [`mining::TreeVisitor`] API.
//! * [`solver`] — L1 solvers (coordinate descent, ISTA oracle), the
//!   paper's unified problem form, duality gaps, dual-feasible points.
//! * [`screening`] — the SPP rule itself, per-feature gap-safe tests,
//!   and the `lambda_max` tree search.
//! * [`boosting`] — the cutting-plane baseline the paper compares with.
//! * [`path`] — Algorithm 1: the warm-started regularization path.
//! * [`runtime`] — PJRT execution of the AOT JAX/Pallas artifacts
//!   (`artifacts/*.hlo.txt`) from the Rust hot path.
//! * [`coordinator`] — experiment orchestration: worker pool, metrics,
//!   result reporting; drives every figure bench.
//! * [`testutil`] — SplitMix64 PRNG, property-test harness, brute-force
//!   oracles (exhaustive miners, dense ISTA) used across the test suite.
//! * [`cli`] — the minimal argument parser behind the `spp` binary.
//!
//! ## Quickstart
//!
//! ```no_run
//! use spp::data::synth_itemsets::{ItemsetSynthConfig, generate};
//! use spp::path::{PathConfig, compute_path_spp};
//! use spp::screening::Database;
//! use spp::solver::problem::Task;
//!
//! let data = generate(&ItemsetSynthConfig::preset_splice(42));
//! let cfg = PathConfig { n_lambdas: 100, lambda_min_ratio: 0.01,
//!                        maxpat: 4, ..PathConfig::default() };
//! let path = compute_path_spp(&Database::Itemsets(&data.db), &data.y,
//!                             Task::Classification, &cfg);
//! println!("active patterns at smallest lambda: {}",
//!          path.points.last().unwrap().active.len());
//! ```

pub mod benchkit;
pub mod boosting;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod mining;
pub mod model;
pub mod path;
pub mod runtime;
pub mod screening;
pub mod solver;
pub mod testutil;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
