//! # Safe Pattern Pruning (SPP)
//!
//! A production reproduction of *"Safe Pattern Pruning: An Efficient
//! Approach for Predictive Pattern Mining"* (Nakagawa et al., KDD 2016)
//! as a three-layer Rust + JAX + Pallas stack.
//!
//! The library fits L1-regularized linear models over the (exponentially
//! large) space of **patterns** in a database — item-sets of a
//! transaction database, connected subgraphs of a graph database,
//! subsequences of a sequence database, or RuleFit-style threshold
//! rules over numeric tabular data — without ever materializing
//! that space.  The paper's contribution, the **SPP rule**, is a
//! gap-safe screening test evaluable at any node of the
//! pattern-enumeration tree; when it fires, the *entire subtree* is
//! certified to carry zero weight at the optimum and is skipped.  The
//! rule only needs an anti-monotone tree, so everything is generic over
//! the open [`mining::PatternSubstrate`] trait.
//!
//! ## Layout (one module per subsystem; see DESIGN.md)
//!
//! * [`data`] — datasets: LIBSVM parsers (binary transactions and
//!   dense numeric), graph/sequence/tabular containers, seeded
//!   synthetic generators standing in for the paper's benchmark data;
//!   each container implements [`mining::PatternSubstrate`].
//!   [`data::registry`] is also the crate's **single substrate
//!   dispatch point**: generic code reaches a concrete substrate
//!   through the dataset's `visit` hop with a
//!   [`data::registry::SubstrateVisitor`], monomorphized at the
//!   registry's one match site (CI greps for strays).
//! * [`mining`] — the pattern-tree substrates: a prefix-extension
//!   item-set enumerator, a full gSpan implementation, a PrefixSpan
//!   subsequence miner, and a RuleFit threshold-rule miner, all driven
//!   through the same [`mining::TreeVisitor`] API, plus the open
//!   [`mining::PatternSubstrate`] trait every search is generic over.
//! * [`columns`] — hybrid sparse/bitset support columns: the
//!   [`columns::ColumnRead`] fold/dot kernels every layer shares, the
//!   chunked [`columns::HybridColumn`] layout, and the
//!   `SPP_COLUMNS` knob keeping the scalar layout alive as the test
//!   oracle.
//! * [`solver`] — L1 solvers (coordinate descent, ISTA oracle), the
//!   paper's unified problem form, duality gaps, dual-feasible points.
//! * [`screening`] — the SPP rule itself, per-feature gap-safe tests,
//!   the `lambda_max` tree search, the [`screening::SupportPool`]
//!   column-interning arena, the incremental screening forest that
//!   reuses the pruned tree across the λ path, and the range-based
//!   (interval) SPP bound behind the chunked path engine.
//! * [`boosting`] — the cutting-plane baseline the paper compares with.
//! * [`path`] — Algorithm 1: the warm-started regularization path,
//!   run by the one shared λ loop [`path::PathDriver`] with a
//!   per-method [`path::ActiveSetStrategy`] (SPP screening — the
//!   incremental forest by default, from-scratch under `--no-reuse`,
//!   chunked range-based screening under `--range-chunk C` — or the
//!   boosting baseline), and K-fold cross-validation over it
//!   (stratified folds for classification).
//! * [`estimator`] — [`SppEstimator`], the sklearn-style builder facade
//!   over the path machinery.
//! * [`runtime`] — PJRT execution of the AOT JAX/Pallas artifacts
//!   (`artifacts/*.hlo.txt`) from the Rust hot path, and
//!   [`runtime::parallel`] — the deterministic worker pool behind the
//!   engine's `--threads` knob (parallel runs are bit-identical to
//!   sequential; DESIGN.md §6).
//! * [`serve`] — the persistent prediction service behind `spp serve`:
//!   a line-delimited JSON protocol, a hot-reloadable model registry,
//!   and compiled per-substrate matchers that score a batch in one
//!   pass per record while staying bit-identical to the naive scorer.
//! * [`storage`] — out-of-core sharded databases: a fixed-size shard
//!   container with a footer index, and [`storage::ShardedDb`], the
//!   `PatternSubstrate` adapter that streams one shard at a time
//!   (item-set traversal never materializes the record union) while
//!   the column pool's spill tier keeps resident bytes under
//!   `--memory-budget`.
//! * [`coordinator`] — experiment orchestration: worker pool, metrics,
//!   result reporting; drives every figure bench.
//! * [`testutil`] — SplitMix64 PRNG, property-test harness, brute-force
//!   oracles (exhaustive miners, dense ISTA) used across the test suite.
//! * [`cli`] — the minimal argument parser behind the `spp` binary,
//!   plus [`cli::commands`]: one module per subcommand, written
//!   against the registry visitors (the binary itself is a thin
//!   parse-and-dispatch shell).
//!
//! ## Quickstart
//!
//! ```no_run
//! use spp::data::synth_itemsets::{ItemsetSynthConfig, generate};
//! use spp::solver::Task;
//! use spp::SppEstimator;
//!
//! let data = generate(&ItemsetSynthConfig::preset_splice(42));
//! let fit = SppEstimator::new(Task::Classification)
//!     .maxpat(4)
//!     .lambda_grid(100, 0.01)
//!     .fit(&data.db, &data.y)
//!     .unwrap();
//! println!("active patterns at smallest lambda: {}", fit.model.terms.len());
//! println!("certified path: {} λ values, {} tree nodes",
//!          fit.path.points.len(), fit.path.total_nodes());
//! ```
//!
//! The same three lines fit graph databases (`&graph_db`, gSpan tree),
//! sequence databases (`&sequences`, PrefixSpan tree) and numeric
//! tabular databases (`&tabular`, RuleFit threshold-rule tree) — `fit`
//! is generic over [`mining::PatternSubstrate`].

pub mod benchkit;
pub mod boosting;
pub mod cli;
pub mod columns;
pub mod coordinator;
pub mod data;
pub mod estimator;
pub mod mining;
pub mod model;
pub mod path;
pub mod runtime;
pub mod screening;
pub mod serve;
pub mod solver;
pub mod storage;
pub mod testutil;

pub use estimator::{SppEstimator, SppFit};

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
