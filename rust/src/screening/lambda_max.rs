//! λ_max computation (paper §3.4.1) as a bounded tree search.
//!
//! `λ_max = max_t |Σ_i α_it θ̂⁰_i·λ|` where `θ̂⁰` is the dual-optimal
//! point of the all-zero primal solution: for regression the centered
//! targets `y − ȳ`; for classification the hinge slacks at the optimal
//! intercept-only model `b⁰`.  The anti-monotone envelope
//! `max(Σ_{g>0,i∈supp} g_i, −Σ_{g<0,i∈supp} g_i)` bounds every
//! descendant's score, so subtrees that cannot beat the incumbent are
//! pruned — the same Morishita/Kudo-style bound the SPP rule uses.

use crate::mining::{PatternNode, PatternSubstrate, TraverseStats, TreeVisitor, Walk};
use crate::solver::Task;

/// Result of the λ_max search.
#[derive(Clone, Debug)]
pub struct LambdaMax {
    pub lambda_max: f64,
    /// Optimal intercept of the all-zero model (ȳ / b⁰).
    pub b0: f64,
    /// Per-sample slack of the all-zero model (r⁰ / h⁰); `θ⁰ = slack/λ_max`.
    pub slack0: Vec<f64>,
    pub stats: TraverseStats,
}

/// Intercept-only optimum for the squared hinge:
/// `b⁰ = argmin_b Σ_i max(0, 1 − y_i b)²/2` by bisection on the
/// (monotone) derivative.
pub fn hinge_intercept(y: &[f64]) -> f64 {
    let deriv = |b: f64| -> f64 {
        y.iter()
            .map(|&yi| {
                let h = 1.0 - yi * b;
                if h > 0.0 {
                    -yi * h
                } else {
                    0.0
                }
            })
            .sum()
    };
    // optimum lies in [-1, 1]: outside, every sample on one side is slack-free
    let (mut lo, mut hi) = (-1.0f64, 1.0f64);
    if deriv(lo) >= 0.0 {
        return lo;
    }
    if deriv(hi) <= 0.0 {
        return hi;
    }
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if deriv(mid) > 0.0 {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    0.5 * (lo + hi)
}

/// Visitor maximizing `|Σ_{i∈supp} g_i|` with envelope pruning.
pub struct MaxAbsSearch<'a> {
    /// Per-sample weights (`g_i`).
    pub g: &'a [f64],
    pub best: f64,
    pub best_pattern: Option<crate::mining::Pattern>,
}

impl<'a> MaxAbsSearch<'a> {
    pub fn new(g: &'a [f64]) -> Self {
        MaxAbsSearch {
            g,
            best: 0.0,
            best_pattern: None,
        }
    }
}

impl TreeVisitor for MaxAbsSearch<'_> {
    fn visit(&mut self, node: &PatternNode<'_>) -> Walk {
        let mut pos = 0.0;
        let mut neg = 0.0;
        for &i in node.support {
            // branchless sign split (see screening::sppc)
            let gi = self.g[i as usize];
            pos += gi.max(0.0);
            neg += gi.min(0.0);
        }
        let score = (pos + neg).abs();
        if score > self.best {
            self.best = score;
            self.best_pattern = Some(node.to_pattern());
        }
        let bound = pos.max(-neg);
        if bound <= self.best {
            Walk::Prune // no descendant can beat the incumbent
        } else {
            Walk::Descend
        }
    }
}

/// Compute λ_max, the zero-solution intercept and slack (paper §3.4.1)
/// on any [`PatternSubstrate`].
pub fn lambda_max<S: PatternSubstrate>(
    db: &S,
    y: &[f64],
    task: Task,
    maxpat: usize,
    minsup: usize,
) -> LambdaMax {
    let b0 = match task {
        Task::Regression => y.iter().sum::<f64>() / y.len() as f64,
        Task::Classification => hinge_intercept(y),
    };
    let slack0: Vec<f64> = match task {
        Task::Regression => y.iter().map(|&yi| yi - b0).collect(),
        Task::Classification => y.iter().map(|&yi| (1.0 - yi * b0).max(0.0)).collect(),
    };
    // g_i = a_i * slack_i  (λ_max = max_t |Σ_{i∈supp(t)} g_i|)
    let g: Vec<f64> = y
        .iter()
        .zip(&slack0)
        .map(|(&yi, &s)| task.a(yi) * s)
        .collect();
    let mut search = MaxAbsSearch::new(&g);
    let mut counting = crate::mining::Counting::new(&mut search);
    db.traverse(maxpat, minsup, &mut counting);
    let stats = counting.stats;
    LambdaMax {
        lambda_max: search.best,
        b0,
        slack0,
        stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Transactions;
    use crate::mining::{Pattern, Walk};

    fn db() -> Transactions {
        Transactions {
            n_items: 3,
            items: vec![vec![0], vec![0, 1], vec![1, 2], vec![2]],
        }
    }

    /// Brute-force λ_max over all item-sets up to maxpat.
    fn brute_lambda_max(t: &Transactions, g: &[f64], maxpat: usize) -> f64 {
        let mut best: f64 = 0.0;
        let mut all = Vec::new();
        let mut v = |n: &PatternNode<'_>| {
            all.push(n.support.to_vec());
            Walk::Descend
        };
        crate::mining::itemset::ItemsetMiner::new(t, maxpat).traverse(&mut v);
        for sup in all {
            let s: f64 = sup.iter().map(|&i| g[i as usize]).sum();
            best = best.max(s.abs());
        }
        best
    }

    #[test]
    fn matches_brute_force_regression() {
        let t = db();
        let y = vec![2.0, -1.0, 0.5, 3.0];
        let lm = lambda_max(&t, &y, Task::Regression, 3, 1);
        let ybar = y.iter().sum::<f64>() / 4.0;
        let g: Vec<f64> = y.iter().map(|&v| v - ybar).collect();
        let brute = brute_lambda_max(&t, &g, 3);
        assert!((lm.lambda_max - brute).abs() < 1e-12);
        assert!((lm.b0 - ybar).abs() < 1e-12);
    }

    #[test]
    fn matches_brute_force_classification() {
        let t = db();
        let y = vec![1.0, -1.0, 1.0, -1.0];
        let lm = lambda_max(&t, &y, Task::Classification, 3, 1);
        let b0 = hinge_intercept(&y);
        let g: Vec<f64> = y.iter().map(|&yi| yi * (1.0 - yi * b0).max(0.0)).collect();
        let brute = brute_lambda_max(&t, &g, 3);
        assert!((lm.lambda_max - brute).abs() < 1e-10);
    }

    #[test]
    fn pruning_still_finds_max() {
        // pruned search must equal exhaustive search even on bigger data
        use crate::data::synth_itemsets::{generate, ItemsetSynthConfig};
        let d = generate(&ItemsetSynthConfig::tiny(77, false));
        let ybar = d.y.iter().sum::<f64>() / d.y.len() as f64;
        let g: Vec<f64> = d.y.iter().map(|&v| v - ybar).collect();
        let lm = lambda_max(&d.db, &d.y, Task::Regression, 3, 1);
        let brute = brute_lambda_max(&d.db, &g, 3);
        assert!((lm.lambda_max - brute).abs() < 1e-10);
        assert!(lm.stats.pruned > 0, "expected some pruning");
    }

    #[test]
    fn hinge_intercept_balanced_is_zero_and_one_sided_is_one() {
        assert!(hinge_intercept(&[1.0, -1.0]).abs() < 1e-9);
        assert!((hinge_intercept(&[1.0, 1.0, 1.0]) - 1.0).abs() < 1e-9);
        assert!((hinge_intercept(&[-1.0, -1.0]) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn best_pattern_is_reported() {
        let t = db();
        let y = vec![10.0, 10.0, -10.0, -10.0];
        let lm = lambda_max(&t, &y, Task::Regression, 2, 1);
        assert!(lm.best_pattern_is_some_sanity());
    }

    impl LambdaMax {
        fn best_pattern_is_some_sanity(&self) -> bool {
            self.lambda_max > 0.0
        }
    }

    #[test]
    fn theta0_is_dual_feasible_at_lambda_max() {
        // |x_t^T theta0| <= 1 for every pattern, == 1 at the argmax
        let t = db();
        let y = vec![2.0, -1.0, 0.5, 3.0];
        let lm = lambda_max(&t, &y, Task::Regression, 3, 1);
        let theta0: Vec<f64> = lm.slack0.iter().map(|&s| s / lm.lambda_max).collect();
        let mut worst: f64 = 0.0;
        let mut v = |n: &PatternNode<'_>| {
            let s: f64 = n.support.iter().map(|&i| theta0[i as usize]).sum();
            worst = worst.max(s.abs());
            Walk::Descend
        };
        crate::mining::itemset::ItemsetMiner::new(&t, 3).traverse(&mut v);
        assert!(worst <= 1.0 + 1e-12);
        assert!((worst - 1.0).abs() < 1e-9);
        let _ = Pattern::Itemset(vec![]); // silence unused import in cfg(test)
    }
}
