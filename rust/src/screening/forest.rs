//! The incremental screening forest: reuse one λ's pruned pattern tree
//! at the next λ instead of re-enumerating the substrate from the root.
//!
//! `compute_path_spp` evaluates the SPP rule ~100 times on trees whose
//! survivor sets shrink slowly between adjacent λs — the redundancy the
//! multi-λ screening reuse of Yoshida et al. (2023) eliminates.  The
//! forest materializes every node a traversal has ever visited
//! (pattern, interned support column, child links, and a *frontier*
//! flag on nodes whose subtree was pruned before enumeration).  At the
//! next λ the SPPC is re-evaluated **on the stored forest** — a linear
//! scan over interned columns, with none of the substrate's
//! intersection / canonicality / embedding work — and the substrate
//! [`PatternSubstrate::traverse`] is re-opened only below frontier
//! nodes whose SPPC climbed back to `>= 1`.
//!
//! Two certificates keep the re-evaluation itself cheap and safe:
//!
//! * **Anti-monotonicity** (Corollary 3): `SPPC(child) <= SPPC(parent)`
//!   for the same dual point, so the forest walk prunes whole stored
//!   subtrees exactly like the live traversal does.
//! * **A per-node λ-range certificate** (Yoshida et al.'s range idea in
//!   drift form): for folded weights `g`, `u_t` is 1-Lipschitz per
//!   sample, so with `D(e, now)` an upper bound on `‖g_now − g_e‖₂`
//!   (maintained as a prefix sum of consecutive-epoch distances),
//!
//!   ```text
//!   SPPC_now(t) <= u_t(g_e) + √v_t · (D(e, now) + r_now)
//!   ```
//!
//!   — when that bound is already `< 1`, node `t` is certifiably still
//!   pruned and is skipped without touching its support column at all.
//!   Nodes whose screening pair has drifted far below the threshold are
//!   therefore never re-examined for the rest of the grid.
//!
//! **Equivalence contract**: for the same per-λ screening pairs, the
//! forest emits *bit-identical* survivors, in the same canonical DFS
//! order, as a from-scratch [`SppScreen`] traversal — so the
//! incremental path produces bit-identical active sets, weights, and
//! certified gaps (pinned by `tests/integration_forest.rs` on all three
//! substrates).  The contract is *state-independent*: survivors for a
//! pair depend only on the pair, never on how much of the tree is
//! already materialized — which is what lets the chunked path engine
//! (range-based SPP, [`super::range`]) pre-mine a whole λ-chunk's
//! subtrees at an interval radius and still recover every λ's exact
//! survivor sequence from the stored columns.
//!
//! [`SppScreen`]: super::sppc::SppScreen

use std::collections::HashMap;

use super::pool::SupportPool;
use super::sppc::{decide, fold_sums, NodeDecision, Survivor};
use crate::columns::ColumnRead;
use crate::mining::{
    Counting, Pattern, PatternNode, PatternSubstrate, TraverseStats, TreeVisitor, Walk,
};
use crate::runtime::parallel::{self, ThreadStats};
use crate::solver::Task;

const NO_PARENT: u32 = u32::MAX;

/// One materialized node of the screening forest.
struct ForestNode {
    pattern: Pattern,
    support: super::pool::SupportId,
    /// `|supp|` cached as f64 (the SPPC weight).
    v: f64,
    parent: u32,
    /// Children in substrate enumeration order (complete once the node
    /// has been descended; empty while `frontier`).
    children: Vec<u32>,
    /// Subtree never enumerated: the node was pruned at every λ that
    /// reached it and sits below `maxpat` (re-opened when its SPPC
    /// climbs back to `>= 1`).
    frontier: bool,
    /// `u_t` stamped with the fold vector of epoch `epoch`.
    u: f64,
    epoch: u32,
}

/// Per-λ outcome of a forest screening pass.
pub struct ForestScreenOutcome {
    /// Â, bit-identical (content and order) to a from-scratch
    /// [`super::sppc::SppScreen`] traversal with the same pair.
    pub survivors: Vec<Survivor>,
    /// Substrate traversal statistics — counts **only** real substrate
    /// visits (initial build + re-opened subtrees), which is the
    /// figure-4/5 currency the scratch mode reports.
    pub stats: TraverseStats,
    /// Stored nodes decided from interned columns (no substrate work).
    pub forest_hits: u64,
    /// Of those, nodes skipped by the λ-range drift certificate alone
    /// (not even their support column was read).
    pub cert_skips: u64,
    /// Frontier subtrees re-opened below (substrate re-entered).
    pub reopened: u64,
    /// Worker utilisation of the stored-forest re-check (phase 1): the
    /// per-root walks are farmed to the pool and spliced back in root
    /// order.  The initial build and the guided re-open traversal are
    /// sequential by construction (they *create* canonical order).
    pub threads: ThreadStats,
}

/// The forest itself; one instance spans a whole λ path (fixed
/// `maxpat`/`minsup`).
pub struct ScreenForest {
    maxpat: usize,
    minsup: usize,
    nodes: Vec<ForestNode>,
    roots: Vec<u32>,
    index: HashMap<Pattern, u32>,
    /// `drift[k]` = Σ of consecutive `‖g_j − g_{j−1}‖₂` up to epoch `k`
    /// (prefix sums; the triangle inequality makes `drift[now] −
    /// drift[e]` an upper bound on `‖g_now − g_e‖₂`).
    drift: Vec<f64>,
    g_prev: Vec<f64>,
    built: bool,
}

/// Ordered emission events of the stored-forest pass (phase 1).
enum Ev {
    Keep { node: u32, sppc: f64, ub: f64 },
    Open(u32),
}

impl ScreenForest {
    pub fn new(maxpat: usize, minsup: usize) -> Self {
        ScreenForest {
            maxpat,
            minsup,
            nodes: Vec::new(),
            roots: Vec::new(),
            index: HashMap::new(),
            drift: Vec::new(),
            g_prev: Vec::new(),
            built: false,
        }
    }

    /// Stored nodes (diagnostics).
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// One λ step: evaluate the SPP rule for the pair `(θ, radius)`
    /// against the stored forest, re-opening the substrate only where
    /// needed.  Drop-in replacement for one `SppScreen` traversal.
    /// `threads > 1` chunks the stored-node re-check across the worker
    /// pool (bit-identical output at any worker count — see
    /// `runtime::parallel`).
    #[allow(clippy::too_many_arguments)]
    pub fn screen<S: PatternSubstrate>(
        &mut self,
        db: &S,
        task: Task,
        y: &[f64],
        theta: &[f64],
        radius: f64,
        feature_test: bool,
        threads: usize,
        pool: &mut SupportPool,
    ) -> ForestScreenOutcome {
        let g: Vec<f64> = y
            .iter()
            .zip(theta)
            .map(|(&yi, &ti)| task.a(yi) * ti)
            .collect();
        let n = y.len() as f64;

        // epoch advance: extend the drift prefix sums
        let epoch = self.drift.len() as u32;
        if self.g_prev.is_empty() {
            self.drift.push(0.0);
        } else {
            let d: f64 = g
                .iter()
                .zip(&self.g_prev)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            self.drift.push(self.drift[epoch as usize - 1] + d);
        }
        self.g_prev = g.clone();

        if !self.built {
            // first screening λ: one full substrate traversal records
            // the whole pruned tree
            let (blocks, stats) =
                self.reopen(db, &g, radius, n, feature_test, epoch, &[], &[], pool);
            self.built = true;
            let survivors = blocks.into_iter().flat_map(|(_, s)| s).collect();
            return ForestScreenOutcome {
                survivors,
                stats,
                forest_hits: 0,
                cert_skips: 0,
                reopened: 0,
                threads: ThreadStats::sequential(),
            };
        }

        // phase 1: decide every reachable stored node from its interned
        // column (or the drift certificate), collecting ordered events.
        // Each stored root's walk is independent of its siblings'
        // (a node reads only its own stamps, and every node is visited
        // at most once per pass), so the walks are farmed to the worker
        // pool and their event streams spliced back in root order —
        // exactly the sequential DFS.  Stamp updates come back as data
        // and are applied after the join (disjoint per node).
        let drift_now = self.drift[epoch as usize];
        let walks: Vec<RootWalk> = {
            let nodes = &self.nodes;
            let drift = &self.drift;
            let roots = &self.roots;
            let pool_ref: &SupportPool = pool;
            parallel::map_indexed(threads, roots.len(), |i| {
                walk_stored(
                    nodes, drift, roots[i], &g, radius, n, feature_test, drift_now, pool_ref,
                )
            })
        };
        let tstats = ThreadStats::for_phase(threads, self.roots.len());
        let mut evs: Vec<Ev> = Vec::new();
        let mut reopen_ids: Vec<u32> = Vec::new();
        let mut hits = 0u64;
        let mut cert_skips = 0u64;
        for mut w in walks {
            for (id, u) in w.stamps.drain(..) {
                let node = &mut self.nodes[id as usize];
                node.u = u;
                node.epoch = epoch;
            }
            evs.append(&mut w.evs);
            reopen_ids.append(&mut w.reopen_ids);
            hits += w.hits;
            cert_skips += w.cert_skips;
        }

        // phase 2: re-enter the substrate below the re-opened frontiers
        // (one guided traversal; skipped entirely when nothing climbed
        // back over the threshold)
        let reopened = reopen_ids.len() as u64;
        let (mut blocks, stats) = if reopen_ids.is_empty() {
            (Vec::new(), TraverseStats::default())
        } else {
            let mut on_path = vec![false; self.nodes.len()];
            let mut reopen_flag = vec![false; self.nodes.len()];
            for &t in &reopen_ids {
                reopen_flag[t as usize] = true;
                let mut p = self.nodes[t as usize].parent;
                while p != NO_PARENT && !on_path[p as usize] {
                    on_path[p as usize] = true;
                    p = self.nodes[p as usize].parent;
                }
            }
            self.reopen(db, &g, radius, n, feature_test, epoch, &on_path, &reopen_flag, pool)
        };

        // phase 3: splice — each re-opened frontier's fresh subtree
        // lands right after the frontier's own entry, reproducing the
        // substrate's canonical DFS order exactly
        let mut survivors: Vec<Survivor> = Vec::new();
        let mut bi = 0usize;
        for ev in evs {
            match ev {
                Ev::Keep { node, sppc, ub } => {
                    let nd = &self.nodes[node as usize];
                    survivors.push(Survivor {
                        pattern: nd.pattern.clone(),
                        support: nd.support,
                        sppc,
                        ub,
                    });
                }
                Ev::Open(f) => {
                    debug_assert_eq!(blocks[bi].0, f, "frontier block order mismatch");
                    survivors.append(&mut blocks[bi].1);
                    bi += 1;
                }
            }
        }
        debug_assert_eq!(bi, blocks.len());

        ForestScreenOutcome {
            survivors,
            stats,
            forest_hits: hits,
            cert_skips,
            reopened,
            threads: tstats,
        }
    }

    /// One guided substrate traversal: descend through on-path
    /// ancestors, re-open flagged frontiers, record + screen every new
    /// node, prune everywhere else.  With empty `on_path`/`reopen_flag`
    /// and an empty forest this IS the initial full build.
    #[allow(clippy::too_many_arguments)]
    fn reopen<S: PatternSubstrate>(
        &mut self,
        db: &S,
        g: &[f64],
        radius: f64,
        n: f64,
        feature_test: bool,
        epoch: u32,
        on_path: &[bool],
        reopen_flag: &[bool],
        pool: &mut SupportPool,
    ) -> (Vec<(u32, Vec<Survivor>)>, TraverseStats) {
        let (maxpat, minsup) = (self.maxpat, self.minsup);
        let mut guide = Guide {
            forest: self,
            pool,
            g,
            radius,
            n,
            feature_test,
            epoch,
            on_path,
            reopen_flag,
            parents: Vec::new(),
            open: vec![Block {
                frontier: NO_PARENT,
                depth: 0,
                out: Vec::new(),
            }],
            done: Vec::new(),
        };
        let stats = {
            let mut counting = Counting::new(&mut guide);
            db.traverse(maxpat, minsup, &mut counting);
            counting.stats
        };
        // close any block still open when the traversal ended
        while let Some(b) = guide.open.pop() {
            if b.frontier != NO_PARENT {
                guide.done.push((b.frontier, b.out));
            } else if guide.done.is_empty() && !b.out.is_empty() {
                // initial build: everything lives in the sentinel block
                guide.done.push((NO_PARENT, b.out));
            }
        }
        (guide.done, stats)
    }
}

/// Outcome of one stored root's re-check walk (phase 1 task).
#[derive(Default)]
struct RootWalk {
    evs: Vec<Ev>,
    reopen_ids: Vec<u32>,
    hits: u64,
    cert_skips: u64,
    /// `(node, u_t)` stamps for every node whose column was read this
    /// pass; the caller applies them (with the current epoch) after the
    /// join — deferral is sound because each node is visited at most
    /// once per pass and reads only its own previous stamp.
    stamps: Vec<(u32, f64)>,
}

/// Walk one stored root's subtree for the pair `(g, radius)`: the
/// sequential re-check logic, made pure over the shared forest state so
/// sibling roots can run on pool workers concurrently.  Per-node
/// verdicts come from the crate's single [`decide`] kernel.
#[allow(clippy::too_many_arguments)]
fn walk_stored(
    nodes: &[ForestNode],
    drift: &[f64],
    root: u32,
    g: &[f64],
    radius: f64,
    n: f64,
    feature_test: bool,
    drift_now: f64,
    pool: &SupportPool,
) -> RootWalk {
    let mut out = RootWalk::default();
    let mut stack: Vec<u32> = vec![root];
    while let Some(t) = stack.pop() {
        out.hits += 1;
        let node = &nodes[t as usize];
        // λ-range certificate: SPPC_now <= u_e + √v·(drift + r)
        let drifted = drift_now - drift[node.epoch as usize];
        if node.u + node.v.sqrt() * (drifted + radius) < 1.0 {
            out.cert_skips += 1;
            continue; // certifiably pruned, column untouched
        }
        // layout-aware fold: hybrid pools run the 64-bit word kernel
        // (bit-identical to the scalar `fold_sums`; `crate::columns`)
        let (pos, neg) = pool.col(node.support).fold_signed(g);
        match decide(pos, neg, node.v, n, radius, feature_test) {
            NodeDecision::Prune { u } => {
                // pruned (Theorem 2); stored subtree skipped
                out.stamps.push((t, u));
            }
            NodeDecision::Descend { u, sppc, ub, keep } => {
                out.stamps.push((t, u));
                if keep {
                    out.evs.push(Ev::Keep { node: t, sppc, ub });
                }
                if node.frontier {
                    out.evs.push(Ev::Open(t));
                    out.reopen_ids.push(t);
                } else {
                    stack.extend(node.children.iter().rev());
                }
            }
        }
    }
    out
}

/// Survivors collected under one re-opened frontier (or the sentinel
/// root block on the initial build).
struct Block {
    frontier: u32,
    depth: usize,
    out: Vec<Survivor>,
}

struct Guide<'a, 'p> {
    forest: &'a mut ScreenForest,
    pool: &'p mut SupportPool,
    g: &'a [f64],
    radius: f64,
    n: f64,
    feature_test: bool,
    epoch: u32,
    on_path: &'a [bool],
    reopen_flag: &'a [bool],
    /// Forest id of the current ancestor at each depth (1-based).
    parents: Vec<u32>,
    open: Vec<Block>,
    done: Vec<(u32, Vec<Survivor>)>,
}

impl TreeVisitor for Guide<'_, '_> {
    fn visit(&mut self, node: &PatternNode<'_>) -> Walk {
        let depth = node.depth;
        // leaving a re-opened frontier's subtree closes its block
        while let Some(b) = self.open.last() {
            if b.frontier == NO_PARENT || depth > b.depth {
                break;
            }
            let b = self.open.pop().unwrap();
            self.done.push((b.frontier, b.out));
        }
        self.parents.truncate(depth - 1);

        let pat = node.to_pattern();
        if let Some(&id) = self.forest.index.get(&pat) {
            // known node: pure routing, no screening work
            self.parents.push(id);
            if self.reopen_flag.get(id as usize).copied().unwrap_or(false) {
                self.forest.nodes[id as usize].frontier = false;
                self.open.push(Block {
                    frontier: id,
                    depth,
                    out: Vec::new(),
                });
                return Walk::Descend;
            }
            if self.on_path.get(id as usize).copied().unwrap_or(false) {
                return Walk::Descend;
            }
            return Walk::Prune;
        }

        // new node: one verdict from the shared `decide` kernel, then
        // record it in the forest
        let (pos, neg) = fold_sums(self.g, node.support);
        let v = node.support.len() as f64;
        let dec = decide(pos, neg, v, self.n, self.radius, self.feature_test);
        let (u, prune) = match dec {
            NodeDecision::Prune { u } => (u, true),
            NodeDecision::Descend { u, .. } => (u, false),
        };
        let sid = self.pool.intern(node.support);
        let id = self.forest.nodes.len() as u32;
        let parent = if depth == 1 {
            NO_PARENT
        } else {
            self.parents[depth - 2]
        };
        self.forest.nodes.push(ForestNode {
            pattern: pat.clone(),
            support: sid,
            v,
            parent,
            children: Vec::new(),
            frontier: prune && depth < self.forest.maxpat,
            u,
            epoch: self.epoch,
        });
        self.forest.index.insert(pat.clone(), id);
        if parent == NO_PARENT {
            self.forest.roots.push(id);
        } else {
            self.forest.nodes[parent as usize].children.push(id);
        }
        self.parents.push(id);
        match dec {
            NodeDecision::Prune { .. } => Walk::Prune,
            NodeDecision::Descend { sppc, ub, keep, .. } => {
                if keep {
                    let block = self.open.last_mut().expect("a block is always open");
                    block.out.push(Survivor {
                        pattern: pat,
                        support: sid,
                        sppc,
                        ub,
                    });
                }
                Walk::Descend
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_itemsets::{generate, ItemsetSynthConfig};
    use crate::screening::sppc::SppScreen;

    /// From-scratch survivors for one pair (the reference semantics).
    fn scratch(
        d: &crate::data::Transactions,
        y: &[f64],
        theta: &[f64],
        radius: f64,
        maxpat: usize,
        pool: &mut SupportPool,
    ) -> (Vec<Survivor>, TraverseStats) {
        let mut screen = SppScreen::new(Task::Regression, y, theta, radius, pool);
        let stats = {
            let mut counting = Counting::new(&mut screen);
            crate::mining::PatternSubstrate::traverse(d, maxpat, 1, &mut counting);
            counting.stats
        };
        (std::mem::take(&mut screen.survivors), stats)
    }

    fn assert_same(a: &[Survivor], b: &[Survivor]) {
        assert_eq!(a.len(), b.len(), "survivor count mismatch");
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.pattern, y.pattern);
            assert_eq!(x.support, y.support, "{:?}", x.pattern);
            assert_eq!(x.sppc, y.sppc, "{:?}", x.pattern);
            assert_eq!(x.ub, y.ub, "{:?}", x.pattern);
        }
    }

    #[test]
    fn forest_matches_scratch_over_shrinking_radii() {
        // simulate a λ path: the same dual point at shrinking radii
        // (so frontiers re-open), plus a perturbed pair (so the drift
        // certificate is exercised)
        let d = generate(&ItemsetSynthConfig::tiny(9, false));
        let n = d.y.len();
        let theta: Vec<f64> = (0..n).map(|i| ((i * 7 % 13) as f64 - 6.0) * 0.02).collect();
        let theta2: Vec<f64> = theta.iter().map(|t| t * 0.8 + 0.001).collect();
        let maxpat = 3;
        let mut forest = ScreenForest::new(maxpat, 1);
        let mut fpool = SupportPool::new();
        let mut snodes_total = 0u64;
        let mut fnodes_total = 0u64;
        for (th, radius) in [
            (&theta, 0.05),
            (&theta, 0.3),
            (&theta2, 0.2),
            (&theta, 1.0),
            (&theta2, 0.01),
        ] {
            let mut spool = SupportPool::new();
            let (want, sstats) = scratch(&d.db, &d.y, th, radius, maxpat, &mut spool);
            let out = forest.screen(&d.db, Task::Regression, &d.y, th, radius, true, 1, &mut fpool);
            // compare by resolved columns (pools differ across modes)
            assert_eq!(out.survivors.len(), want.len(), "radius {radius}");
            for (f, s) in out.survivors.iter().zip(&want) {
                assert_eq!(f.pattern, s.pattern);
                assert_eq!(fpool.get(f.support), spool.get(s.support));
                assert_eq!(f.sppc, s.sppc);
                assert_eq!(f.ub, s.ub);
            }
            snodes_total += sstats.nodes;
            fnodes_total += out.stats.nodes;
        }
        assert!(
            fnodes_total < snodes_total,
            "forest re-traversed as much as scratch: {fnodes_total} vs {snodes_total}"
        );
    }

    #[test]
    fn second_identical_pair_needs_no_substrate_work() {
        let d = generate(&ItemsetSynthConfig::tiny(10, false));
        let theta: Vec<f64> = d.y.iter().map(|&v| v * 0.01).collect();
        let mut forest = ScreenForest::new(3, 1);
        let mut pool = SupportPool::new();
        let first = forest.screen(&d.db, Task::Regression, &d.y, &theta, 0.2, true, 1, &mut pool);
        assert!(first.stats.nodes > 0);
        let second = forest.screen(&d.db, Task::Regression, &d.y, &theta, 0.2, true, 1, &mut pool);
        assert_eq!(second.stats.nodes, 0, "no frontier climbed: zero substrate visits");
        assert_eq!(second.reopened, 0);
        assert!(second.forest_hits > 0);
        assert_same(&first.survivors, &second.survivors);
    }

    #[test]
    fn drift_certificate_skips_dead_nodes_without_reading_columns() {
        let d = generate(&ItemsetSynthConfig::tiny(11, false));
        let theta: Vec<f64> = d.y.iter().map(|&v| v * 0.01).collect();
        let mut forest = ScreenForest::new(3, 1);
        let mut pool = SupportPool::new();
        // big radius first: everything enumerated
        forest.screen(&d.db, Task::Regression, &d.y, &theta, 10.0, true, 1, &mut pool);
        // tiny radius, same pair: deep nodes are certifiably dead
        let out = forest.screen(&d.db, Task::Regression, &d.y, &theta, 1e-6, true, 1, &mut pool);
        assert!(out.cert_skips > 0, "drift certificate never fired");
        assert_eq!(out.stats.nodes, 0);
    }

    #[test]
    fn growing_radius_reopens_frontiers() {
        let d = generate(&ItemsetSynthConfig::tiny(12, false));
        let theta: Vec<f64> = d.y.iter().map(|&v| v * 0.01).collect();
        let mut forest = ScreenForest::new(3, 1);
        let mut pool = SupportPool::new();
        let small = forest.screen(&d.db, Task::Regression, &d.y, &theta, 0.05, true, 1, &mut pool);
        let big = forest.screen(&d.db, Task::Regression, &d.y, &theta, 5.0, true, 1, &mut pool);
        assert!(big.reopened > 0, "no frontier re-opened on a radius jump");
        assert!(big.stats.nodes > 0);
        assert!(big.survivors.len() > small.survivors.len());
    }

    #[test]
    fn parallel_recheck_is_bit_identical_to_sequential() {
        // twin forests fed the same pair sequence, one re-checked
        // inline and one on 4 workers: every outcome field that is not
        // wall-clock must match bit-for-bit, including the telemetry
        let d = generate(&ItemsetSynthConfig::tiny(13, false));
        let n = d.y.len();
        let theta: Vec<f64> = (0..n).map(|i| ((i * 5 % 11) as f64 - 5.0) * 0.03).collect();
        let theta2: Vec<f64> = theta.iter().map(|t| t * 0.7 - 0.002).collect();
        let task = Task::Regression;
        let mut sf = ScreenForest::new(3, 1);
        let mut pf = ScreenForest::new(3, 1);
        let mut sp = SupportPool::new();
        let mut pp = SupportPool::new();
        let mut saw_parallel = false;
        for (th, radius) in
            [(&theta, 0.4), (&theta, 0.1), (&theta2, 0.3), (&theta, 2.0), (&theta2, 0.01)]
        {
            let a = sf.screen(&d.db, task, &d.y, th, radius, true, 1, &mut sp);
            let b = pf.screen(&d.db, task, &d.y, th, radius, true, 4, &mut pp);
            assert_same(&a.survivors, &b.survivors);
            assert_eq!(a.stats, b.stats, "radius {radius}");
            assert_eq!(a.forest_hits, b.forest_hits);
            assert_eq!(a.cert_skips, b.cert_skips);
            assert_eq!(a.reopened, b.reopened);
            saw_parallel |= b.threads.workers > 1;
        }
        assert!(saw_parallel, "4-worker re-check never actually fanned out");
        assert_eq!(sp.len(), pp.len());
    }
}
