//! Range-based (interval) SPP: one screening pass certified for a whole
//! λ-interval.
//!
//! The paper's Algorithm 1 motivates SPP with model selection — "a
//! sequence of solutions with various different penalty parameters must
//! be trained" (§3.4.1) — yet evaluates the rule once per grid point.
//! Yoshida et al., *Efficient Model Selection for Predictive Pattern
//! Mining Model by Safe Pattern Pruning* (2023), observe that the
//! gap-safe ball construction extends from a single λ to a whole
//! hyperparameter **interval**: a reference primal/dual pair
//! `(w̃, b̃, θ̃)` stays feasible at every λ (the dual box `|α_tᵀθ| ≤ 1`
//! does not depend on λ), so evaluating its duality gap *at* each λ
//! yields a per-λ safe radius
//!
//! ```text
//! r(λ) = √(2·gap_λ(w̃, θ̃)) / λ ,
//! gap_λ = ½‖s̃‖² + λ‖w̃‖₁  +  ½λ²‖θ̃‖² − λ·δᵀθ̃
//! ```
//!
//! (`s̃` = the pair's slacks; [`crate::solver::problem`]).  Screening
//! with the interval radius `R = sup_{λ∈[λ_lo, λ_hi]} r(λ)` therefore
//! produces a **survivor superset valid for every λ in the interval**:
//! `SPPC_λ(t) = u_t + r(λ)·√v_t ≤ u_t + R·√v_t`, so a node the interval
//! pass prunes is pruned at every λ in the range (Theorem 2 applied
//! pointwise).  One tree search per *chunk* of the grid replaces one
//! per grid *point* — `path::compute_path_spp` mines once per chunk and
//! re-derives each λ's exact survivor set from the stored columns.
//!
//! ## The endpoint rule
//!
//! The supremum needs no search.  Substituting `u = 1/λ`:
//!
//! ```text
//! r²(u) = ‖s̃‖²·u² + 2(‖w̃‖₁ − δᵀθ̃)·u + ‖θ̃‖²
//! ```
//!
//! a quadratic in `u` with non-negative leading coefficient, hence
//! **convex in u** — its maximum over an interval sits at an endpoint,
//! and `u = 1/λ` maps λ-intervals to u-intervals monotonically.  So
//!
//! ```text
//! sup_{λ∈[λ_lo, λ_hi]} r(λ) = max( r(λ_lo), r(λ_hi) )
//! ```
//!
//! exactly — [`interval_radius`] evaluates the two endpoints and is
//! valid for the *continuous* interval, not just the grid points inside
//! it (pinned by the property test below).
//!
//! ## Exactness is never at stake
//!
//! The interval radius only decides which subtrees get *materialized*
//! into the screening forest ahead of time.  Each λ still runs its own
//! stored-tree screen with its own exact pair and radius (and the
//! forest re-opens a frontier if anything climbs back over the
//! threshold), so the chunked engine's survivor sequence — and hence
//! active sets, weights and certified gaps — is bit-identical to the
//! per-λ engine's (pinned by `tests/integration_range.rs` on all three
//! substrates).  A too-small interval radius costs a re-open; it cannot
//! cost correctness.

use crate::solver::dual::safe_radius;
use crate::solver::problem::{dual_value, primal_value};
use crate::solver::Task;

/// Resolve the `range_chunk` knob: `requested > 0` is explicit (1 =
/// per-λ screening, `N` = λs per chunk); `0` means auto — the
/// `SPP_RANGE_CHUNK` environment variable if set to a positive integer,
/// else 1 (the per-λ engine).  Mirrors
/// [`crate::runtime::parallel::resolve_threads`], and CI's test-matrix
/// uses the env form to run the whole suite under both engines.
pub fn resolve_range_chunk(requested: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    if let Ok(v) = std::env::var("SPP_RANGE_CHUNK") {
        if let Ok(k) = v.trim().parse::<usize>() {
            if k > 0 {
                return k;
            }
        }
    }
    1
}

/// The reference pair's safe radius evaluated at penalty `lam`
/// (Lemma 5 with the pair's gap re-evaluated at `lam`): `slack`/`l1`
/// describe the primal side `(w̃, b̃)`, `theta` the dual-feasible point.
pub fn lambda_radius(
    task: Task,
    y: &[f64],
    theta: &[f64],
    slack: &[f64],
    l1: f64,
    lam: f64,
) -> f64 {
    let primal = primal_value(slack, l1, lam);
    let dualv = dual_value(task, theta, y, lam);
    safe_radius(primal, dualv, lam)
}

/// The interval radius `R = sup_{λ∈[λ_lo, λ_hi]} r(λ)` for the
/// reference pair — exactly `max(r(λ_lo), r(λ_hi))` by the endpoint
/// rule (module docs).  Screening with `R` is safe for every λ in the
/// closed interval.
pub fn interval_radius(
    task: Task,
    y: &[f64],
    theta: &[f64],
    slack: &[f64],
    l1: f64,
    lambda_lo: f64,
    lambda_hi: f64,
) -> f64 {
    debug_assert!(
        lambda_lo > 0.0 && lambda_lo <= lambda_hi,
        "interval_radius needs 0 < λ_lo <= λ_hi, got [{lambda_lo}, {lambda_hi}]"
    );
    let r_lo = lambda_radius(task, y, theta, slack, l1, lambda_lo);
    let r_hi = lambda_radius(task, y, theta, slack, l1, lambda_hi);
    r_lo.max(r_hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::SplitMix64;

    /// A plausible reference pair for either task: slacks from the
    /// targets, a small feasible-looking θ (feasibility w.r.t. columns
    /// is irrelevant to the radius algebra).
    fn pair(seed: u64, n: usize, classify: bool) -> (Vec<f64>, Vec<f64>, Vec<f64>, f64) {
        let mut rng = SplitMix64::new(seed);
        let y: Vec<f64> = (0..n)
            .map(|_| {
                if classify {
                    if rng.coin(0.5) {
                        1.0
                    } else {
                        -1.0
                    }
                } else {
                    rng.gauss() * 2.0
                }
            })
            .collect();
        let slack: Vec<f64> = (0..n)
            .map(|_| if classify { rng.next_f64() } else { rng.gauss() })
            .collect();
        let theta: Vec<f64> = slack.iter().map(|&s| s * 0.3).collect();
        let l1 = rng.next_f64() * 3.0;
        (y, theta, slack, l1)
    }

    #[test]
    fn endpoint_rule_dominates_every_interior_lambda() {
        // the whole point of the module: R bounds r(λ) on the interval
        for (seed, classify) in [(3u64, false), (4, true), (5, false)] {
            let (y, theta, slack, l1) = pair(seed, 50, classify);
            let task = if classify {
                Task::Classification
            } else {
                Task::Regression
            };
            let (lo, hi) = (0.07, 2.9);
            let r = interval_radius(task, &y, &theta, &slack, l1, lo, hi);
            for k in 0..=200 {
                let lam = lo + (hi - lo) * k as f64 / 200.0;
                let rl = lambda_radius(task, &y, &theta, &slack, l1, lam);
                assert!(
                    rl <= r + 1e-12 * (1.0 + r),
                    "interior λ={lam} radius {rl} exceeds interval radius {r} \
                     (seed {seed})"
                );
            }
        }
    }

    #[test]
    fn degenerate_interval_is_the_pointwise_radius() {
        let (y, theta, slack, l1) = pair(6, 30, false);
        let lam = 0.8;
        let r1 = lambda_radius(Task::Regression, &y, &theta, &slack, l1, lam);
        let r2 = interval_radius(Task::Regression, &y, &theta, &slack, l1, lam, lam);
        assert_eq!(r1.to_bits(), r2.to_bits());
    }

    #[test]
    fn widening_the_interval_never_shrinks_the_radius() {
        let (y, theta, slack, l1) = pair(7, 40, true);
        let task = Task::Classification;
        let mut prev = 0.0f64;
        for widen in 1..=10 {
            let (lo, hi) = (1.0 / widen as f64, widen as f64);
            let r = interval_radius(task, &y, &theta, &slack, l1, lo, hi);
            assert!(r >= prev, "radius shrank when widening to [{lo}, {hi}]");
            prev = r;
        }
    }

    #[test]
    fn resolve_honours_explicit_requests() {
        assert_eq!(resolve_range_chunk(1), 1);
        assert_eq!(resolve_range_chunk(7), 7);
        // auto resolves to something usable regardless of environment
        assert!(resolve_range_chunk(0) >= 1);
    }
}
