//! The Safe Pattern Pruning rule (paper Theorem 2) as a tree visitor.
//!
//! At node `t` with support `supp(t)`:
//!
//! ```text
//! u_t    = max( Σ_{i: g_i>0, i∈supp} g_i ,  −Σ_{i: g_i<0, i∈supp} g_i )
//! v_t    = |supp(t)|                    (binary features, a_i² = 1)
//! SPPC(t)= u_t + r·√v_t                 < 1  ⟹  prune subtree
//! ```
//!
//! with `g_i = a_iθ̃_i` and `r = √(2·gap)/λ` the gap-safe radius.  Nodes
//! that survive the subtree test are additionally screened by the
//! per-feature bound (Lemma 6),
//!
//! ```text
//! UB(t) = |Σ_{i∈supp} g_i| + r·√(v_t − v_t²/n)   < 1 ⟹ w*_t = 0,
//! ```
//!
//! (using `Σ_i α_itβ_i = v_t` and `‖β‖² = n`, true for both of the
//! paper's instantiations) so Â contains only nodes that can actually
//! be active — the subtree is still descended because *descendants* may
//! survive their own tests.
//!
//! Survivor support columns are interned into a shared
//! [`SupportPool`], so identical columns collapse to one [`SupportId`]
//! and the working set / restricted solver never clone them.

use super::pool::{SupportId, SupportPool};
use crate::columns::ColumnRead;
use crate::mining::{
    Counting, Pattern, PatternNode, PatternSubstrate, SubtreeVisitors, TraverseStats, TreeVisitor,
    Walk,
};
use crate::runtime::parallel::ThreadStats;
use crate::solver::Task;

/// One surviving pattern: identity, interned support column, and the
/// two screening values computed at the node — the subtree criterion
/// `SPPC(t)` (Theorem 2) and the per-feature bound `UB(t)` (Lemma 6).
/// By Lemma 7, `ub <= sppc` always.
#[derive(Clone, Debug)]
pub struct Survivor {
    pub pattern: Pattern,
    pub support: SupportId,
    /// `SPPC(t)` — the subtree test value (diagnostics/ablation).
    pub sppc: f64,
    /// `UB(t)` — the Lemma-6 per-feature bound that admitted this node
    /// into Â (`>= 1`, unless the feature test was disabled).
    pub ub: f64,
}

/// Positive/negative partial sums of `g` over a support column (the
/// shared kernel of every bound in this module and the forest).
///
/// Delegates to [`ColumnRead::fold_signed`]: on plain id slices that is
/// the branchless scalar sign-split loop (one memory stream, no
/// mispredicts); on hybrid columns it is the 64-bit word kernel, which
/// visits the same ids in the same ascending order and is therefore
/// bit-identical ([`crate::columns`] module docs).
#[inline]
pub(crate) fn fold_sums(g: &[f64], support: &[u32]) -> (f64, f64) {
    support.fold_signed(g)
}

/// `UB(t)` from the partial sums (Lemma 6; `n` = record count).
#[inline]
pub(crate) fn feature_ub_from(pos: f64, neg: f64, v: f64, n: f64, radius: f64) -> f64 {
    let inner = (v - v * v / n).max(0.0);
    (pos + neg).abs() + radius * inner.sqrt()
}

/// Outcome of the per-node screening decision (see [`decide`]).  `u_t`
/// is carried in both arms because the forest stamps it for the λ-range
/// drift certificate.
#[derive(Clone, Copy)]
pub(crate) enum NodeDecision {
    /// `SPPC(t) < 1`: the whole subtree is certified inactive.
    Prune { u: f64 },
    /// Subtree survives; `keep` says whether the node itself enters Â
    /// (the Lemma-6 test, or the feature test being disabled).
    Descend { u: f64, sppc: f64, ub: f64, keep: bool },
}

/// The Theorem-2 / Lemma-6 decision sequence for one node, from its
/// folded partial sums.  This is the ONE copy of the rule, shared by
/// the sequential visitor ([`SppScreen`]), the parallel shards, and the
/// screening forest's builder and re-check walks — so the engines
/// cannot drift apart: any change here reaches all four, and the
/// float-op order stays bitwise identical across engines and worker
/// counts.
#[inline]
pub(crate) fn decide(
    pos: f64,
    neg: f64,
    v: f64,
    n: f64,
    radius: f64,
    feature_test: bool,
) -> NodeDecision {
    let u = pos.max(-neg);
    let sppc = u + radius * v.sqrt();
    if sppc < 1.0 {
        return NodeDecision::Prune { u };
    }
    let ub = feature_ub_from(pos, neg, v, n, radius);
    NodeDecision::Descend {
        u,
        sppc,
        ub,
        keep: !feature_test || ub >= 1.0,
    }
}

/// The SPP screening visitor.  Collects Â as `survivors`.
pub struct SppScreen<'p> {
    /// Folded per-sample weights `g_i = a_iθ̃_i` (one array: the sign
    /// split of the paper's u_t happens in the fold loop — one memory
    /// stream instead of two, +10% on the traversal hot path).
    g: Vec<f64>,
    /// Gap-safe radius `r_λ`.
    pub radius: f64,
    n: f64,
    /// Apply the Lemma-6 per-feature test to trim Â (on by default;
    /// ablation A1 switches it off to measure its contribution).
    pub feature_test: bool,
    pub survivors: Vec<Survivor>,
    pool: &'p mut SupportPool,
}

impl<'p> SppScreen<'p> {
    /// Build the rule from a feasible primal/dual pair's folded data.
    ///
    /// `theta` must be dual-feasible; `radius` is
    /// [`crate::solver::dual::safe_radius`] of the pair's gap.
    /// Survivor columns are interned into `pool`.
    pub fn new(
        task: Task,
        y: &[f64],
        theta: &[f64],
        radius: f64,
        pool: &'p mut SupportPool,
    ) -> Self {
        let g: Vec<f64> = y
            .iter()
            .zip(theta)
            .map(|(&yi, &ti)| task.a(yi) * ti)
            .collect();
        SppScreen {
            g,
            radius,
            n: y.len() as f64,
            feature_test: true,
            survivors: Vec::new(),
            pool,
        }
    }

    /// The subtree criterion SPPC(t); exposed for tests/diagnostics.
    /// Generic over the column layout: hybrid columns fold over bitmap
    /// words, id slices over the scalar loop — bit-identically.
    #[inline]
    pub fn sppc<S: ColumnRead + ?Sized>(&self, support: &S) -> f64 {
        let (pos, neg) = support.fold_signed(&self.g);
        let u = pos.max(-neg);
        u + self.radius * (support.len() as f64).sqrt()
    }

    /// The per-feature bound UB(t) (Lemma 6); layout-generic like
    /// [`SppScreen::sppc`].
    #[inline]
    pub fn feature_ub<S: ColumnRead + ?Sized>(&self, support: &S) -> f64 {
        let (pos, neg) = support.fold_signed(&self.g);
        feature_ub_from(pos, neg, support.len() as f64, self.n, self.radius)
    }
}

impl TreeVisitor for SppScreen<'_> {
    fn visit(&mut self, node: &PatternNode<'_>) -> Walk {
        let (pos, neg) = fold_sums(&self.g, node.support);
        let v = node.support.len() as f64;
        match decide(pos, neg, v, self.n, self.radius, self.feature_test) {
            // Theorem 2: subtree inactive
            NodeDecision::Prune { .. } => Walk::Prune,
            NodeDecision::Descend { sppc, ub, keep, .. } => {
                if keep {
                    self.survivors.push(Survivor {
                        pattern: node.to_pattern(),
                        support: self.pool.intern(node.support),
                        sppc,
                        ub,
                    });
                }
                Walk::Descend
            }
        }
    }
}

/// One survivor as collected inside a parallel shard: identity plus the
/// raw column.  Interning is deferred to the splice, so [`SupportId`]s
/// are assigned in canonical DFS order regardless of worker count.
struct RawSurvivor {
    pattern: Pattern,
    column: Vec<u32>,
    sppc: f64,
    ub: f64,
}

/// Per-subtree visitor of the parallel screening pass: the same
/// [`decide`] kernel as [`SppScreen`]'s visitor, with survivors kept as
/// raw columns and traversal statistics counted locally.
struct ScreenShard<'a> {
    g: &'a [f64],
    radius: f64,
    n: f64,
    feature_test: bool,
    out: Vec<RawSurvivor>,
    nodes: u64,
    pruned: u64,
}

impl TreeVisitor for ScreenShard<'_> {
    fn visit(&mut self, node: &PatternNode<'_>) -> Walk {
        self.nodes += 1;
        let (pos, neg) = fold_sums(self.g, node.support);
        let v = node.support.len() as f64;
        match decide(pos, neg, v, self.n, self.radius, self.feature_test) {
            NodeDecision::Prune { .. } => {
                self.pruned += 1;
                Walk::Prune
            }
            NodeDecision::Descend { sppc, ub, keep, .. } => {
                if keep {
                    self.out.push(RawSurvivor {
                        pattern: node.to_pattern(),
                        column: node.support.to_vec(),
                        sppc,
                        ub,
                    });
                }
                Walk::Descend
            }
        }
    }
}

/// Shard factory: the folded weights and the pair's radius, shared
/// read-only across workers.
struct ScreenFactory<'a> {
    g: &'a [f64],
    radius: f64,
    n: f64,
    feature_test: bool,
}

impl<'a> SubtreeVisitors for ScreenFactory<'a> {
    type V = ScreenShard<'a>;

    fn visitor(&self, _root: usize) -> ScreenShard<'a> {
        ScreenShard {
            g: self.g,
            radius: self.radius,
            n: self.n,
            feature_test: self.feature_test,
            out: Vec::new(),
            nodes: 0,
            pruned: 0,
        }
    }
}

/// One full SPP screening pass over a substrate — the deterministic
/// parallel engine's scratch-mode entry point.
///
/// `threads <= 1` is byte-for-byte the classic sequential [`SppScreen`]
/// traversal (interning into `pool` as nodes are visited).
/// `threads > 1` farms depth-1 subtrees to pool workers
/// ([`PatternSubstrate::traverse_parallel`]) and splices the survivor
/// blocks back in canonical root order, interning into `pool` in the
/// same DFS order — so survivors (patterns, [`SupportId`]s, `sppc`/`ub`
/// values) and traversal statistics are **bit-identical** at any worker
/// count (pinned by `tests/integration_parallel.rs`).
#[allow(clippy::too_many_arguments)]
pub fn screen_pass<S: PatternSubstrate>(
    db: &S,
    task: Task,
    y: &[f64],
    theta: &[f64],
    radius: f64,
    feature_test: bool,
    maxpat: usize,
    minsup: usize,
    threads: usize,
    pool: &mut SupportPool,
) -> (Vec<Survivor>, TraverseStats, ThreadStats) {
    if threads <= 1 {
        let mut screen = SppScreen::new(task, y, theta, radius, pool);
        screen.feature_test = feature_test;
        let stats = {
            let mut counting = Counting::new(&mut screen);
            db.traverse(maxpat, minsup, &mut counting);
            counting.stats
        };
        return (
            std::mem::take(&mut screen.survivors),
            stats,
            ThreadStats::sequential(),
        );
    }
    let g: Vec<f64> = y.iter().zip(theta).map(|(&yi, &ti)| task.a(yi) * ti).collect();
    let factory = ScreenFactory {
        g: &g,
        radius,
        n: y.len() as f64,
        feature_test,
    };
    let shards = db.traverse_parallel(maxpat, minsup, threads, &factory);
    let tstats = ThreadStats::for_phase(threads, shards.len());
    let mut survivors = Vec::new();
    let mut stats = TraverseStats::default();
    for shard in shards {
        stats.nodes += shard.nodes;
        stats.pruned += shard.pruned;
        for raw in shard.out {
            survivors.push(Survivor {
                pattern: raw.pattern,
                support: pool.intern_owned(raw.column),
                sppc: raw.sppc,
                ub: raw.ub,
            });
        }
    }
    (survivors, stats, tstats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Transactions;
    use crate::mining::itemset::ItemsetMiner;

    fn db() -> Transactions {
        Transactions {
            n_items: 3,
            items: vec![vec![0, 1], vec![0], vec![1, 2], vec![0, 1, 2]],
        }
    }

    #[test]
    fn zero_radius_keeps_only_box_violators() {
        // theta chosen so only item 0's column has |corr| >= 1
        let y = vec![1.0; 4];
        let theta = vec![0.6, 0.5, -0.05, -0.05];
        let mut pool = SupportPool::new();
        let mut screen = SppScreen::new(Task::Regression, &y, &theta, 0.0, &mut pool);
        ItemsetMiner::new(&db(), 2).traverse(&mut screen);
        let names: Vec<String> =
            screen.survivors.iter().map(|s| s.pattern.display()).collect();
        assert!(names.contains(&"{0}".into()), "{names:?}");
        assert!(!names.contains(&"{2}".into()), "{names:?}");
    }

    #[test]
    fn huge_radius_keeps_everything() {
        let y = vec![1.0; 4];
        let theta = vec![0.0; 4];
        let mut pool = SupportPool::new();
        let mut screen = SppScreen::new(Task::Regression, &y, &theta, 100.0, &mut pool);
        let stats = {
            let mut counting = Counting::new(&mut screen);
            ItemsetMiner::new(&db(), 3).traverse(&mut counting);
            counting.stats
        };
        assert_eq!(screen.survivors.len() as u64, stats.nodes);
        assert_eq!(stats.pruned, 0);
    }

    #[test]
    fn survivors_record_sppc_and_the_lemma6_ub_distinctly() {
        // Regression test for the Survivor fields: `sppc` must be the
        // Theorem-2 subtree value, `ub` the Lemma-6 per-feature bound —
        // NOT the same number stored twice.
        let y = vec![1.0, -1.0, 1.0, -1.0];
        let theta = vec![0.6, -0.5, 0.4, -0.3];
        let mut pool = SupportPool::new();
        let mut screen = SppScreen::new(Task::Regression, &y, &theta, 0.9, &mut pool);
        ItemsetMiner::new(&db(), 3).traverse(&mut screen);
        let survivors = std::mem::take(&mut screen.survivors);
        assert!(!survivors.is_empty());
        let mut pool2 = SupportPool::new();
        let check = SppScreen::new(Task::Regression, &y, &theta, 0.9, &mut pool2);
        let mut distinct = 0;
        for s in &survivors {
            let col = pool.get(s.support);
            assert_eq!(s.sppc, check.sppc(col), "sppc mismatch on {col:?}");
            assert_eq!(s.ub, check.feature_ub(col), "ub mismatch on {col:?}");
            assert!(s.ub <= s.sppc + 1e-12, "Lemma 7: UB must not exceed SPPC");
            assert!(s.ub >= 1.0, "feature test admitted a sub-threshold node");
            if (s.ub - s.sppc).abs() > 1e-9 {
                distinct += 1;
            }
        }
        assert!(distinct > 0, "ub never differed from sppc — field is a duplicate");
    }

    #[test]
    fn survivors_share_interned_columns() {
        // items 1 and the pair {1,2} of this db have different columns,
        // but repeated traversals intern into the same pool slots
        let y = vec![1.0; 4];
        let theta = vec![0.0; 4];
        let mut pool = SupportPool::new();
        for _ in 0..2 {
            let mut screen = SppScreen::new(Task::Regression, &y, &theta, 100.0, &mut pool);
            ItemsetMiner::new(&db(), 3).traverse(&mut screen);
            assert!(!screen.survivors.is_empty());
        }
        // second pass added no new columns
        let before = pool.len();
        let mut screen = SppScreen::new(Task::Regression, &y, &theta, 100.0, &mut pool);
        ItemsetMiner::new(&db(), 3).traverse(&mut screen);
        drop(screen);
        assert_eq!(pool.len(), before);
    }

    #[test]
    fn screen_pass_is_bit_identical_at_any_worker_count() {
        use crate::data::synth_itemsets::{generate, ItemsetSynthConfig};
        let d = generate(&ItemsetSynthConfig::tiny(7, false));
        let theta: Vec<f64> = d.y.iter().map(|&v| v * 0.02).collect();
        for radius in [0.05, 0.5, 5.0] {
            let mut pool1 = SupportPool::new();
            let (s1, st1, t1) = screen_pass(
                &d.db,
                Task::Regression,
                &d.y,
                &theta,
                radius,
                true,
                3,
                1,
                1,
                &mut pool1,
            );
            assert_eq!(t1, ThreadStats::sequential());
            if radius >= 5.0 {
                assert!(!s1.is_empty(), "huge radius must keep survivors");
            }
            for threads in [2usize, 4, 8] {
                let mut poolk = SupportPool::new();
                let (sk, stk, tk) = screen_pass(
                    &d.db,
                    Task::Regression,
                    &d.y,
                    &theta,
                    radius,
                    true,
                    3,
                    1,
                    threads,
                    &mut poolk,
                );
                assert_eq!(st1, stk, "radius={radius} threads={threads}");
                assert_eq!(s1.len(), sk.len(), "radius={radius} threads={threads}");
                for (a, b) in s1.iter().zip(&sk) {
                    assert_eq!(a.pattern, b.pattern);
                    // same interning order ⇒ the very same dense ids
                    assert_eq!(a.support, b.support);
                    assert_eq!(a.sppc.to_bits(), b.sppc.to_bits());
                    assert_eq!(a.ub.to_bits(), b.ub.to_bits());
                    assert_eq!(pool1.get(a.support), poolk.get(b.support));
                }
                assert_eq!(tk.tasks > 1, tk.workers > 1);
            }
        }
    }

    #[test]
    fn sppc_dominates_feature_ub() {
        // Theorem 2 / Lemma 7: SPPC(t) >= UB(t) at the same node
        let y = vec![1.0, -1.0, 1.0, -1.0];
        let theta = vec![0.4, -0.3, 0.2, -0.1];
        let mut pool = SupportPool::new();
        let screen = SppScreen::new(Task::Classification, &y, &theta, 0.7, &mut pool);
        for sup in [vec![0u32], vec![0, 1], vec![0, 1, 2, 3], vec![2, 3]] {
            assert!(
                screen.sppc(&sup) >= screen.feature_ub(&sup) - 1e-12,
                "SPPC < UB on {sup:?}"
            );
        }
    }

    #[test]
    fn sppc_is_antimonotone_on_support_subsets() {
        // Corollary 3 in support terms: child support ⊆ parent support
        // => SPPC(child) <= SPPC(parent)
        let y = vec![1.0; 5];
        let theta = vec![0.3, -0.2, 0.5, -0.4, 0.1];
        let mut pool = SupportPool::new();
        let screen = SppScreen::new(Task::Regression, &y, &theta, 0.25, &mut pool);
        let parent = vec![0u32, 1, 2, 3, 4];
        let children = [vec![0u32, 2, 4], vec![1u32, 3], vec![2u32]];
        for c in &children {
            assert!(screen.sppc(c) <= screen.sppc(&parent) + 1e-12);
        }
    }

    #[test]
    fn empty_support_always_prunes() {
        let y = vec![1.0; 3];
        let theta = vec![0.5; 3];
        let mut pool = SupportPool::new();
        let mut screen = SppScreen::new(Task::Regression, &y, &theta, 0.5, &mut pool);
        let sup: Vec<u32> = vec![];
        let items = vec![1u32];
        let node = PatternNode::itemset(&items, &sup);
        assert_eq!(screen.visit(&node), Walk::Prune);
    }

    #[test]
    fn feature_test_only_trims_a_hat_not_search() {
        let y = vec![1.0; 4];
        let theta = vec![0.35, 0.35, 0.2, 0.1];
        let mk = |ft: bool| {
            let mut pool = SupportPool::new();
            let mut s = SppScreen::new(Task::Regression, &y, &theta, 0.2, &mut pool);
            s.feature_test = ft;
            let mut c = Counting::new(&mut s);
            ItemsetMiner::new(&db(), 3).traverse(&mut c);
            let nodes = c.stats.nodes;
            (s.survivors.len(), nodes)
        };
        let (with_ft, nodes_ft) = mk(true);
        let (without_ft, nodes_raw) = mk(false);
        assert_eq!(nodes_ft, nodes_raw, "feature test must not change traversal");
        assert!(with_ft <= without_ft);
    }
}
