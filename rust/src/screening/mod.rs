//! Safe screening: the paper's contribution.
//!
//! * [`sppc`] — the **SPP rule** (Theorem 2): a visitor that prunes
//!   whole subtrees whose patterns are certified inactive, and applies
//!   the tighter per-feature UB test (Lemma 6) to the nodes it keeps.
//! * [`pool`] — the [`SupportPool`] interning arena: every support
//!   column is stored once; survivors, working sets and the restricted
//!   solver reference columns by [`SupportId`].
//! * [`forest`] — the incremental screening forest: re-evaluate the SPP
//!   rule on the stored pruned tree across λ steps, re-entering the
//!   substrate only below frontier nodes whose SPPC climbed back.
//! * [`range`] — range-based (interval) SPP after Yoshida et al.
//!   (2023): the anchored safe radius valid for a whole λ-interval
//!   (endpoint rule), behind the chunked path engine's one-mine-per-
//!   chunk screening (`PathConfig::range_chunk`).
//! * [`lambda_max`] — the §3.4.1 search for the smallest λ with an
//!   all-zero solution, using the same anti-monotone envelope bound.
//! * [`certify`] — an exact feasibility pass: one bounded tree search
//!   computing `max_t |α_tᵀθ̃|` over *all* of `T`, so the dual point can
//!   be rescaled into exact feasibility (removes the tolerance-level
//!   slop the paper's Algorithm 1 tolerates; used by the safety tests
//!   and exposed as `--certify`).

pub mod certify;
pub mod forest;
pub mod lambda_max;
pub mod pool;
pub mod range;
pub mod sppc;

pub use forest::{ForestScreenOutcome, ScreenForest};
pub use pool::{SupportId, SupportPool};

pub use crate::columns::{ColumnLayout, ColumnRead, ColumnView, HybridColumn};

use crate::data::graph::GraphDatabase;
use crate::data::Transactions;
use crate::mining::{Pattern, PatternSubstrate, TreeVisitor};

/// Closed two-substrate wrapper, superseded by the open
/// [`PatternSubstrate`] trait.
///
/// Every search is now generic over the trait, so call sites pass the
/// concrete database directly (`&transactions`, `&graph_db`,
/// `&sequences`).  This enum remains for one release as a thin shim —
/// it implements [`PatternSubstrate`] for its traversal surface, so
/// `compute_path_spp(&Database::Itemsets(&t), …)`-era code keeps
/// compiling — but it cannot score records (`Record = ()`), cannot be
/// split for CV, and will be removed.
#[deprecated(
    note = "pass the concrete substrate (`&Transactions`, `&GraphDatabase`, `&Sequences`) \
            to the now-generic searches instead; see `mining::PatternSubstrate`"
)]
#[derive(Clone, Copy)]
pub enum Database<'a> {
    Itemsets(&'a Transactions),
    Graphs(&'a GraphDatabase),
}

#[allow(deprecated)]
impl Database<'_> {
    pub fn n_records(&self) -> usize {
        match self {
            Database::Itemsets(t) => PatternSubstrate::n_records(*t),
            Database::Graphs(g) => PatternSubstrate::n_records(*g),
        }
    }

    /// Depth-first canonical traversal with subtree pruning.
    pub fn traverse(&self, maxpat: usize, minsup: usize, visitor: &mut dyn TreeVisitor) {
        match self {
            Database::Itemsets(t) => PatternSubstrate::traverse(*t, maxpat, minsup, visitor),
            Database::Graphs(g) => PatternSubstrate::traverse(*g, maxpat, minsup, visitor),
        }
    }
}

#[allow(deprecated)]
impl PatternSubstrate for Database<'_> {
    /// The shim cannot expose a per-variant record type; record-level
    /// APIs (`matches`, `record`, `select`, the codec) are unsupported
    /// and panic or error.  Searches only need `n_records`/`traverse`.
    type Record = ();

    fn n_records(&self) -> usize {
        Database::n_records(self)
    }

    fn traverse(&self, maxpat: usize, minsup: usize, visitor: &mut dyn TreeVisitor) {
        Database::traverse(self, maxpat, minsup, visitor)
    }

    fn matches(_pattern: &Pattern, _record: &()) -> bool {
        unreachable!("deprecated Database shim has no record view; use the concrete substrate")
    }

    fn record(&self, _i: usize) -> &() {
        unreachable!("deprecated Database shim has no record view; use the concrete substrate")
    }

    fn select(&self, _indices: &[usize]) -> Self {
        unreachable!("deprecated Database shim cannot be split; use the concrete substrate")
    }

    fn parse_pattern(_body: &str) -> crate::Result<Pattern> {
        anyhow::bail!("deprecated Database shim has no pattern codec; use the concrete substrate")
    }

    fn format_pattern(pattern: &Pattern) -> String {
        unreachable!("deprecated Database shim asked to format {pattern:?}")
    }

    const KIND_TAG: &'static str = "?";
}

/// Fold `(task, y, θ)` into the per-sample weights every bound uses:
/// `g_i = a_i θ_i` split into positive/negative parts (`a = β` for both
/// of the paper's instantiations, so the `β_iθ̃_i` sign split equals the
/// sign of `g_i`).
pub fn fold_weights(task: crate::solver::Task, y: &[f64], theta: &[f64]) -> (Vec<f64>, Vec<f64>) {
    let mut wpos = vec![0.0; y.len()];
    let mut wneg = vec![0.0; y.len()];
    for i in 0..y.len() {
        let g = task.a(y[i]) * theta[i];
        if g > 0.0 {
            wpos[i] = g;
        } else if g < 0.0 {
            wneg[i] = g;
        }
    }
    (wpos, wneg)
}

#[cfg(test)]
#[allow(deprecated)] // pins the deprecated Database shim's behaviour
mod tests {
    use super::*;
    use crate::mining::{PatternNode, Walk};
    use crate::solver::Task;

    #[test]
    fn fold_weights_splits_signs() {
        let y = vec![1.0, -1.0, 1.0];
        let theta = vec![0.5, 0.5, -0.2];
        // regression: g = theta
        let (wp, wn) = fold_weights(Task::Regression, &y, &theta);
        assert_eq!(wp, vec![0.5, 0.5, 0.0]);
        assert_eq!(wn, vec![0.0, 0.0, -0.2]);
        // classification: g = y*theta
        let (wp, wn) = fold_weights(Task::Classification, &y, &theta);
        assert_eq!(wp, vec![0.5, 0.0, 0.0]);
        assert_eq!(wn, vec![0.0, -0.5, -0.2]);
    }

    #[test]
    fn database_traverses_both_kinds() {
        let t = Transactions {
            n_items: 3,
            items: vec![vec![0, 1], vec![1, 2]],
        };
        let mut count = 0usize;
        let mut v = |_: &PatternNode<'_>| {
            count += 1;
            Walk::Descend
        };
        Database::Itemsets(&t).traverse(3, 1, &mut v);
        assert!(count > 0);

        let mut gdb = GraphDatabase::default();
        let mut g = crate::data::graph::Graph::new();
        g.add_vertex(0);
        g.add_vertex(1);
        g.add_edge(0, 1, 0);
        gdb.graphs.push(g);
        gdb.y.push(1.0);
        count = 0;
        let mut v = |_: &PatternNode<'_>| {
            count += 1;
            Walk::Descend
        };
        Database::Graphs(&gdb).traverse(2, 1, &mut v);
        assert_eq!(count, 1);
    }
}
