//! Safe screening: the paper's contribution.
//!
//! * [`sppc`] — the **SPP rule** (Theorem 2): a visitor that prunes
//!   whole subtrees whose patterns are certified inactive, and applies
//!   the tighter per-feature UB test (Lemma 6) to the nodes it keeps.
//! * [`lambda_max`] — the §3.4.1 search for the smallest λ with an
//!   all-zero solution, using the same anti-monotone envelope bound.
//! * [`certify`] — an exact feasibility pass: one bounded tree search
//!   computing `max_t |α_tᵀθ̃|` over *all* of `T`, so the dual point can
//!   be rescaled into exact feasibility (removes the tolerance-level
//!   slop the paper's Algorithm 1 tolerates; used by the safety tests
//!   and exposed as `--certify`).

pub mod certify;
pub mod lambda_max;
pub mod sppc;

use crate::data::graph::GraphDatabase;
use crate::data::Transactions;
use crate::mining::gspan::GSpanMiner;
use crate::mining::itemset::ItemsetMiner;
use crate::mining::TreeVisitor;

/// A pattern database of either kind, traversable by any visitor.
/// Every search in this crate (SPP, boosting, λ_max, certify) walks
/// the same trees through this one entry point — the fairness
/// discipline behind the paper's timing comparisons.
#[derive(Clone, Copy)]
pub enum Database<'a> {
    Itemsets(&'a Transactions),
    Graphs(&'a GraphDatabase),
}

impl<'a> Database<'a> {
    pub fn n_records(&self) -> usize {
        match self {
            Database::Itemsets(t) => t.len(),
            Database::Graphs(g) => g.len(),
        }
    }

    /// Depth-first canonical traversal with subtree pruning.
    pub fn traverse(&self, maxpat: usize, minsup: usize, visitor: &mut dyn TreeVisitor) {
        match self {
            Database::Itemsets(t) => {
                let mut m = ItemsetMiner::new(t, maxpat);
                m.minsup = minsup;
                m.traverse(visitor);
            }
            Database::Graphs(g) => {
                let mut m = GSpanMiner::new(g, maxpat);
                m.minsup = minsup;
                m.traverse(visitor);
            }
        }
    }
}

/// Fold `(task, y, θ)` into the per-sample weights every bound uses:
/// `g_i = a_i θ_i` split into positive/negative parts (`a = β` for both
/// of the paper's instantiations, so the `β_iθ̃_i` sign split equals the
/// sign of `g_i`).
pub fn fold_weights(task: crate::solver::Task, y: &[f64], theta: &[f64]) -> (Vec<f64>, Vec<f64>) {
    let mut wpos = vec![0.0; y.len()];
    let mut wneg = vec![0.0; y.len()];
    for i in 0..y.len() {
        let g = task.a(y[i]) * theta[i];
        if g > 0.0 {
            wpos[i] = g;
        } else if g < 0.0 {
            wneg[i] = g;
        }
    }
    (wpos, wneg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mining::{PatternNode, Walk};
    use crate::solver::Task;

    #[test]
    fn fold_weights_splits_signs() {
        let y = vec![1.0, -1.0, 1.0];
        let theta = vec![0.5, 0.5, -0.2];
        // regression: g = theta
        let (wp, wn) = fold_weights(Task::Regression, &y, &theta);
        assert_eq!(wp, vec![0.5, 0.5, 0.0]);
        assert_eq!(wn, vec![0.0, 0.0, -0.2]);
        // classification: g = y*theta
        let (wp, wn) = fold_weights(Task::Classification, &y, &theta);
        assert_eq!(wp, vec![0.5, 0.0, 0.0]);
        assert_eq!(wn, vec![0.0, -0.5, -0.2]);
    }

    #[test]
    fn database_traverses_both_kinds() {
        let t = Transactions {
            n_items: 3,
            items: vec![vec![0, 1], vec![1, 2]],
        };
        let mut count = 0usize;
        let mut v = |_: &PatternNode<'_>| {
            count += 1;
            Walk::Descend
        };
        Database::Itemsets(&t).traverse(3, 1, &mut v);
        assert!(count > 0);

        let mut gdb = GraphDatabase::default();
        let mut g = crate::data::graph::Graph::new();
        g.add_vertex(0);
        g.add_vertex(1);
        g.add_edge(0, 1, 0);
        gdb.graphs.push(g);
        gdb.y.push(1.0);
        count = 0;
        let mut v = |_: &PatternNode<'_>| {
            count += 1;
            Walk::Descend
        };
        Database::Graphs(&gdb).traverse(2, 1, &mut v);
        assert_eq!(count, 1);
    }
}
