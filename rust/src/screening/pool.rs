//! [`SupportPool`] — the support-column interning arena.
//!
//! Every search in the crate produces support columns (sorted record-id
//! lists), and the same column recurs constantly: across λ steps of a
//! path, across patterns with identical occurrence sets, and between
//! the screening survivors and the previously-active working set.  The
//! pool stores each distinct column **once** and hands out a dense
//! [`SupportId`]; everything downstream — [`crate::screening::sppc::Survivor`],
//! [`crate::path::working_set::WorkingSet`], the path's
//! identical-column dedup, the screening forest — references columns by
//! id, so "same feature" checks are integer equality instead of
//! `Vec<u32>` hashing, and warm-start weight transfer between λ steps
//! is an id-indexed copy.
//!
//! Ids are append-only and therefore **stable for the lifetime of the
//! pool**: a path computation owns one pool for its whole λ grid.
//!
//! ## Column layout
//!
//! The pool interns into one of two layouts ([`ColumnLayout`], module
//! docs of [`crate::columns`]): plain sorted `Vec<u32>` lists (the
//! scalar oracle) or [`HybridColumn`]s whose dense 4096-id chunks carry
//! bitmap words for the vectorized fold/intersection kernels.  Both
//! layouts expose the same sorted ids — [`SupportPool::get`] still
//! borrows a `&[u32]` — and the fold kernels visit ids in the same
//! ascending order, so results are bit-identical across layouts
//! (pinned by `tests/integration_columns.rs`).  Consumers that can
//! exploit the words take a [`ColumnView`] via [`SupportPool::col`] /
//! [`SupportPool::view`].

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};

use crate::columns::{resolve_columns, ColumnLayout, ColumnView, HybridColumn};

/// Dense handle of one interned support column.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SupportId(u32);

impl SupportId {
    /// Position of the column in the pool (dense, `0..pool.len()`).
    #[inline]
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// One interned column in the pool's layout.
#[derive(Clone, Debug)]
enum Stored {
    Sparse(Vec<u32>),
    Hybrid(HybridColumn),
}

impl Stored {
    #[inline]
    fn ids(&self) -> &[u32] {
        match self {
            Stored::Sparse(ids) => ids,
            Stored::Hybrid(col) => col.ids(),
        }
    }

    #[inline]
    fn view(&self) -> ColumnView<'_> {
        match self {
            Stored::Sparse(ids) => ColumnView::Sparse(ids),
            Stored::Hybrid(col) => ColumnView::Hybrid(col),
        }
    }
}

/// Interning arena for support columns (see module docs).
///
/// Each column is stored exactly once, in `columns`; the dedup index
/// maps a column's content hash to the candidate ids sharing it (the
/// arena is the single owner — keying the map by the columns themselves
/// would double the pool's resident memory, and columns dominate a
/// path's allocations at paper scale).
#[derive(Clone, Debug)]
pub struct SupportPool {
    layout: ColumnLayout,
    columns: Vec<Stored>,
    index: HashMap<u64, Vec<SupportId>>,
}

impl Default for SupportPool {
    /// Same as [`SupportPool::new`]: layout resolved through the
    /// `SPP_COLUMNS` knob so the whole test suite follows CI's
    /// layout-matrix cell.
    fn default() -> Self {
        Self::new()
    }
}

fn col_hash(col: &[u32]) -> u64 {
    let mut h = DefaultHasher::new();
    col.hash(&mut h);
    h.finish()
}

impl SupportPool {
    /// A pool in the auto-resolved layout (`SPP_COLUMNS`, default
    /// hybrid — [`crate::columns::resolve_columns`]).
    pub fn new() -> Self {
        Self::with_layout(resolve_columns(None))
    }

    /// A pool interning columns in an explicit layout (what the path
    /// engines use to honor `PathConfig::columns`, and what the
    /// differential tests use to pin sparse-vs-hybrid bit-identity
    /// without racing on the process environment).
    pub fn with_layout(layout: ColumnLayout) -> Self {
        Self {
            layout,
            columns: Vec::new(),
            index: HashMap::new(),
        }
    }

    /// The layout this pool interns into.
    #[inline]
    pub fn layout(&self) -> ColumnLayout {
        self.layout
    }

    /// Number of distinct columns interned so far.
    pub fn len(&self) -> usize {
        self.columns.len()
    }

    pub fn is_empty(&self) -> bool {
        self.columns.is_empty()
    }

    /// Intern `col`, returning the id of the canonical copy.  Two calls
    /// with equal content always return the same id, so id equality is
    /// column equality.
    pub fn intern(&mut self, col: &[u32]) -> SupportId {
        let hv = col_hash(col);
        match self.find(hv, col) {
            Some(id) => id,
            None => self.push_new(hv, col.to_vec()),
        }
    }

    /// Intern an owned column: identical dedup semantics to
    /// [`SupportPool::intern`], without re-copying when the column is
    /// new (the parallel screening splice hands shard buffers straight
    /// in instead of copying every survivor column a second time).
    pub fn intern_owned(&mut self, col: Vec<u32>) -> SupportId {
        let hv = col_hash(&col);
        match self.find(hv, &col) {
            Some(id) => id,
            None => self.push_new(hv, col),
        }
    }

    fn find(&self, hv: u64, col: &[u32]) -> Option<SupportId> {
        self.index
            .get(&hv)?
            .iter()
            .copied()
            .find(|id| self.columns[id.index()].ids() == col)
    }

    fn push_new(&mut self, hv: u64, col: Vec<u32>) -> SupportId {
        let id = SupportId(self.columns.len() as u32);
        self.columns.push(match self.layout {
            ColumnLayout::Sparse => Stored::Sparse(col),
            ColumnLayout::Hybrid => Stored::Hybrid(HybridColumn::from_sorted(col)),
        });
        self.index.entry(hv).or_default().push(id);
        id
    }

    /// Borrow the canonical column for `id` as its sorted record ids
    /// (both layouts keep the full id list; module docs).
    #[inline]
    pub fn get(&self, id: SupportId) -> &[u32] {
        self.columns[id.index()].ids()
    }

    /// Borrow the canonical column for `id` as a layout-aware view —
    /// what the fold kernels consume so hybrid columns run over words.
    #[inline]
    pub fn col(&self, id: SupportId) -> ColumnView<'_> {
        self.columns[id.index()].view()
    }

    /// Layout-aware views of many columns at once (what the restricted
    /// solver consumes).
    pub fn view(&self, ids: &[SupportId]) -> Vec<ColumnView<'_>> {
        ids.iter().map(|&id| self.col(id)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::columns::ColumnRead;
    use crate::testutil::SplitMix64;

    #[test]
    fn intern_dedups_by_content() {
        let mut pool = SupportPool::new();
        let a = pool.intern(&[0, 2, 5]);
        let b = pool.intern(&[1]);
        let c = pool.intern(&[0, 2, 5]);
        assert_eq!(a, c);
        assert_ne!(a, b);
        assert_eq!(pool.len(), 2);
        assert_eq!(pool.get(a), &[0, 2, 5]);
        assert_eq!(pool.get(b), &[1]);
    }

    #[test]
    fn intern_owned_dedups_against_borrowed_interning() {
        let mut pool = SupportPool::new();
        let a = pool.intern(&[0, 2, 5]);
        // owned interning of equal content returns the same id …
        assert_eq!(pool.intern_owned(vec![0, 2, 5]), a);
        // … and a new owned column lands without an extra copy semantic
        let b = pool.intern_owned(vec![9]);
        assert_ne!(a, b);
        assert_eq!(pool.intern(&[9]), b);
        assert_eq!(pool.len(), 2);
    }

    #[test]
    fn ids_are_stable_and_dense() {
        let mut pool = SupportPool::new();
        let a = pool.intern(&[7]);
        let b = pool.intern(&[8]);
        assert_eq!(a.index(), 0);
        assert_eq!(b.index(), 1);
        // later interns never move earlier columns
        pool.intern(&[9, 10]);
        assert_eq!(pool.get(a), &[7]);
    }

    #[test]
    fn view_resolves_in_order() {
        let mut pool = SupportPool::new();
        let a = pool.intern(&[1, 2]);
        let b = pool.intern(&[3]);
        let v = pool.view(&[b, a, b]);
        let ids: Vec<&[u32]> = v.iter().map(|c| c.ids()).collect();
        assert_eq!(ids, vec![&[3][..], &[1, 2][..], &[3][..]]);
    }

    #[test]
    fn empty_column_interns_fine() {
        let mut pool = SupportPool::new();
        let e = pool.intern(&[]);
        assert_eq!(pool.get(e), &[] as &[u32]);
        assert_eq!(pool.intern(&[]), e);
    }

    #[test]
    fn both_layouts_round_trip_identical_ids() {
        let mut rng = SplitMix64::new(31);
        let n = 9000usize; // straddles two 4096-id chunks
        let cols: Vec<Vec<u32>> = [0usize, 1, 63, 64, 65, 300, 4096, 4097, n]
            .iter()
            .map(|&m| rng.sample_distinct(n, m).into_iter().map(|i| i as u32).collect())
            .collect();
        let mut sparse = SupportPool::with_layout(ColumnLayout::Sparse);
        let mut hybrid = SupportPool::with_layout(ColumnLayout::Hybrid);
        for col in &cols {
            let a = sparse.intern(col);
            let b = hybrid.intern(col);
            assert_eq!(a, b, "both layouts assign the same dense ids");
            assert_eq!(sparse.get(a), &col[..]);
            assert_eq!(hybrid.get(b), &col[..], "hybrid keeps the canonical sorted ids");
            assert_eq!(hybrid.col(b).ids(), sparse.col(a).ids());
        }
        // dedup semantics are layout-independent
        assert_eq!(sparse.len(), hybrid.len());
    }

    #[test]
    fn hybrid_views_fold_bit_identically_to_sparse() {
        let mut rng = SplitMix64::new(37);
        let n = 5000usize;
        let g: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
        let col: Vec<u32> = rng.sample_distinct(n, n / 2).into_iter().map(|i| i as u32).collect();
        let mut sparse = SupportPool::with_layout(ColumnLayout::Sparse);
        let mut hybrid = SupportPool::with_layout(ColumnLayout::Hybrid);
        let a = sparse.intern(&col);
        let b = hybrid.intern(&col);
        assert_eq!(sparse.col(a).dot(&g).to_bits(), hybrid.col(b).dot(&g).to_bits());
        let (sp, sn) = sparse.col(a).fold_signed(&g);
        let (hp, hn) = hybrid.col(b).fold_signed(&g);
        assert_eq!((sp.to_bits(), sn.to_bits()), (hp.to_bits(), hn.to_bits()));
    }

    #[test]
    fn hash_collisions_keep_columns_distinct() {
        // Two distinct columns forced into one `index` bucket: the
        // `find` path must fall through on content inequality, and
        // `push_new` must append to the shared bucket — regression
        // cover for the collision arm, which real DefaultHasher inputs
        // essentially never hit.
        let mut pool = SupportPool::new();
        let fake_hash = 0xDEAD_BEEFu64;
        let a = pool.push_new(fake_hash, vec![1, 2, 3]);
        assert_eq!(pool.find(fake_hash, &[4, 5]), None, "collision probe misses on content");
        let b = pool.push_new(fake_hash, vec![4, 5]);
        assert_ne!(a, b);
        assert_eq!(pool.len(), 2);
        // both columns stay findable through the shared bucket …
        assert_eq!(pool.find(fake_hash, &[1, 2, 3]), Some(a));
        assert_eq!(pool.find(fake_hash, &[4, 5]), Some(b));
        // … and resolve to their own content
        assert_eq!(pool.get(a), &[1, 2, 3]);
        assert_eq!(pool.get(b), &[4, 5]);
    }
}
