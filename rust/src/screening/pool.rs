//! [`SupportPool`] — the support-column interning arena.
//!
//! Every search in the crate produces support columns (sorted record-id
//! lists), and the same column recurs constantly: across λ steps of a
//! path, across patterns with identical occurrence sets, and between
//! the screening survivors and the previously-active working set.  The
//! pool stores each distinct column **once** and hands out a dense
//! [`SupportId`]; everything downstream — [`crate::screening::sppc::Survivor`],
//! [`crate::path::working_set::WorkingSet`], the path's
//! identical-column dedup, the screening forest — references columns by
//! id, so "same feature" checks are integer equality instead of
//! `Vec<u32>` hashing, and warm-start weight transfer between λ steps
//! is an id-indexed copy.
//!
//! Ids are append-only and therefore **stable for the lifetime of the
//! pool**: a path computation owns one pool for its whole λ grid.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};

/// Dense handle of one interned support column.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SupportId(u32);

impl SupportId {
    /// Position of the column in the pool (dense, `0..pool.len()`).
    #[inline]
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// Interning arena for support columns (see module docs).
///
/// Each column is stored exactly once, in `columns`; the dedup index
/// maps a column's content hash to the candidate ids sharing it (the
/// arena is the single owner — keying the map by the columns themselves
/// would double the pool's resident memory, and columns dominate a
/// path's allocations at paper scale).
#[derive(Clone, Debug, Default)]
pub struct SupportPool {
    columns: Vec<Vec<u32>>,
    index: HashMap<u64, Vec<SupportId>>,
}

fn col_hash(col: &[u32]) -> u64 {
    let mut h = DefaultHasher::new();
    col.hash(&mut h);
    h.finish()
}

impl SupportPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of distinct columns interned so far.
    pub fn len(&self) -> usize {
        self.columns.len()
    }

    pub fn is_empty(&self) -> bool {
        self.columns.is_empty()
    }

    /// Intern `col`, returning the id of the canonical copy.  Two calls
    /// with equal content always return the same id, so id equality is
    /// column equality.
    pub fn intern(&mut self, col: &[u32]) -> SupportId {
        let hv = col_hash(col);
        match self.find(hv, col) {
            Some(id) => id,
            None => self.push_new(hv, col.to_vec()),
        }
    }

    /// Intern an owned column: identical dedup semantics to
    /// [`SupportPool::intern`], without re-copying when the column is
    /// new (the parallel screening splice hands shard buffers straight
    /// in instead of copying every survivor column a second time).
    pub fn intern_owned(&mut self, col: Vec<u32>) -> SupportId {
        let hv = col_hash(&col);
        match self.find(hv, &col) {
            Some(id) => id,
            None => self.push_new(hv, col),
        }
    }

    fn find(&self, hv: u64, col: &[u32]) -> Option<SupportId> {
        self.index
            .get(&hv)?
            .iter()
            .copied()
            .find(|id| self.columns[id.index()] == col)
    }

    fn push_new(&mut self, hv: u64, col: Vec<u32>) -> SupportId {
        let id = SupportId(self.columns.len() as u32);
        self.columns.push(col);
        self.index.entry(hv).or_default().push(id);
        id
    }

    /// Borrow the canonical column for `id`.
    #[inline]
    pub fn get(&self, id: SupportId) -> &[u32] {
        &self.columns[id.index()]
    }

    /// Borrowed views of many columns at once (what the restricted
    /// solver consumes).
    pub fn view(&self, ids: &[SupportId]) -> Vec<&[u32]> {
        ids.iter().map(|&id| self.get(id)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intern_dedups_by_content() {
        let mut pool = SupportPool::new();
        let a = pool.intern(&[0, 2, 5]);
        let b = pool.intern(&[1]);
        let c = pool.intern(&[0, 2, 5]);
        assert_eq!(a, c);
        assert_ne!(a, b);
        assert_eq!(pool.len(), 2);
        assert_eq!(pool.get(a), &[0, 2, 5]);
        assert_eq!(pool.get(b), &[1]);
    }

    #[test]
    fn intern_owned_dedups_against_borrowed_interning() {
        let mut pool = SupportPool::new();
        let a = pool.intern(&[0, 2, 5]);
        // owned interning of equal content returns the same id …
        assert_eq!(pool.intern_owned(vec![0, 2, 5]), a);
        // … and a new owned column lands without an extra copy semantic
        let b = pool.intern_owned(vec![9]);
        assert_ne!(a, b);
        assert_eq!(pool.intern(&[9]), b);
        assert_eq!(pool.len(), 2);
    }

    #[test]
    fn ids_are_stable_and_dense() {
        let mut pool = SupportPool::new();
        let a = pool.intern(&[7]);
        let b = pool.intern(&[8]);
        assert_eq!(a.index(), 0);
        assert_eq!(b.index(), 1);
        // later interns never move earlier columns
        pool.intern(&[9, 10]);
        assert_eq!(pool.get(a), &[7]);
    }

    #[test]
    fn view_resolves_in_order() {
        let mut pool = SupportPool::new();
        let a = pool.intern(&[1, 2]);
        let b = pool.intern(&[3]);
        let v = pool.view(&[b, a, b]);
        assert_eq!(v, vec![&[3][..], &[1, 2][..], &[3][..]]);
    }

    #[test]
    fn empty_column_interns_fine() {
        let mut pool = SupportPool::new();
        let e = pool.intern(&[]);
        assert_eq!(pool.get(e), &[] as &[u32]);
        assert_eq!(pool.intern(&[]), e);
    }
}
