//! [`SupportPool`] — the support-column interning arena.
//!
//! Every search in the crate produces support columns (sorted record-id
//! lists), and the same column recurs constantly: across λ steps of a
//! path, across patterns with identical occurrence sets, and between
//! the screening survivors and the previously-active working set.  The
//! pool stores each distinct column **once** and hands out a dense
//! [`SupportId`]; everything downstream — [`crate::screening::sppc::Survivor`],
//! [`crate::path::working_set::WorkingSet`], the path's
//! identical-column dedup, the screening forest — references columns by
//! id, so "same feature" checks are integer equality instead of
//! `Vec<u32>` hashing, and warm-start weight transfer between λ steps
//! is an id-indexed copy.
//!
//! Ids are append-only and therefore **stable for the lifetime of the
//! pool**: a path computation owns one pool for its whole λ grid.
//!
//! ## Column layout
//!
//! The pool interns into one of two layouts ([`ColumnLayout`], module
//! docs of [`crate::columns`]): plain sorted `Vec<u32>` lists (the
//! scalar oracle) or [`HybridColumn`]s whose dense 4096-id chunks carry
//! bitmap words for the vectorized fold/intersection kernels.  Both
//! layouts expose the same sorted ids — [`SupportPool::get`] still
//! borrows a `&[u32]` — and the fold kernels visit ids in the same
//! ascending order, so results are bit-identical across layouts
//! (pinned by `tests/integration_columns.rs`).  Consumers that can
//! exploit the words take a [`ColumnView`] via [`SupportPool::col`] /
//! [`SupportPool::view`].
//!
//! ## Spill tier
//!
//! Columns dominate a path's allocations, so the pool optionally
//! carries an LRU spill-to-disk tier: under a byte budget
//! ([`SupportPool::set_memory_budget`], wired from `--memory-budget`),
//! least-recently-touched columns are evicted to an append-only temp
//! file (canonical sorted ids, 4 bytes each, written once — columns
//! are immutable, so re-eviction is free) and transparently reloaded
//! by [`SupportPool::ensure_resident`].  Reloading rebuilds the
//! layout-specific carrier from the same sorted ids, so a reloaded
//! column is byte-identical to the original and results never depend
//! on the budget.  Reads ([`SupportPool::get`] / [`SupportPool::col`])
//! take `&self` and therefore never reload: reading a spilled column
//! is a caller bug and panics — the path engine brackets every read
//! phase with `ensure_resident`/`ensure_all_resident` and spills back
//! down with [`SupportPool::enforce_budget`].  Dedup (`intern`)
//! compares against spilled candidates through a scratch read without
//! making them resident.  Telemetry: [`SpillStats`], recorded per λ in
//! `path::PathPoint::spill`.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::fs::File;
use std::hash::{Hash, Hasher};
use std::io::{Read as _, Seek, SeekFrom, Write as _};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::columns::{resolve_columns, ColumnLayout, ColumnView, HybridColumn};

/// Dense handle of one interned support column.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SupportId(u32);

impl SupportId {
    /// Position of the column in the pool (dense, `0..pool.len()`).
    #[inline]
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// One interned column in the pool's layout.
#[derive(Clone, Debug)]
enum Stored {
    Sparse(Vec<u32>),
    Hybrid(HybridColumn),
    /// Evicted to the spill file; the canonical ids live at the extent
    /// recorded in `SupportPool::extents`.
    Spilled,
}

impl Stored {
    #[inline]
    fn ids(&self) -> &[u32] {
        match self {
            Stored::Sparse(ids) => ids,
            Stored::Hybrid(col) => col.ids(),
            Stored::Spilled => {
                panic!("support column is spilled; call ensure_resident before reading")
            }
        }
    }

    #[inline]
    fn view(&self) -> ColumnView<'_> {
        match self {
            Stored::Sparse(ids) => ColumnView::Sparse(ids),
            Stored::Hybrid(col) => ColumnView::Hybrid(col),
            Stored::Spilled => {
                panic!("support column is spilled; call ensure_resident before reading")
            }
        }
    }

    #[inline]
    fn is_resident(&self) -> bool {
        !matches!(self, Stored::Spilled)
    }
}

/// Spill-tier telemetry: residency gauges at sample time plus
/// reload/eviction counters (the path engine records per-λ deltas of
/// the counters in `PathPoint::spill`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpillStats {
    /// Columns currently resident in memory.
    pub resident_cols: usize,
    /// Accounted heap bytes of the resident columns.
    pub resident_bytes: usize,
    /// Columns currently evicted to the spill file.
    pub spilled_cols: usize,
    /// Columns reloaded from the spill file.
    pub reloaded: u64,
    /// Columns evicted to the spill file.
    pub evicted: u64,
}

/// The append-only spill file backing evicted columns.  Created lazily
/// on first eviction; removed on drop.
#[derive(Debug)]
struct SpillFile {
    file: File,
    path: PathBuf,
    /// Logical end of the file — writes always land here (reads seek
    /// freely in between).
    write_pos: u64,
}

impl Drop for SpillFile {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

/// Interning arena for support columns (see module docs).
///
/// Each column is stored exactly once, in `columns`; the dedup index
/// maps a column's content hash to the candidate ids sharing it (the
/// arena is the single owner — keying the map by the columns themselves
/// would double the pool's resident memory, and columns dominate a
/// path's allocations at paper scale).
#[derive(Debug)]
pub struct SupportPool {
    layout: ColumnLayout,
    columns: Vec<Stored>,
    index: HashMap<u64, Vec<SupportId>>,
    /// Resident-byte budget; `0` = unlimited (no spilling ever).
    budget: usize,
    /// Enforce the budget inside `intern` (safe only when no shared
    /// `&pool` reader holds column views across interns — the path
    /// engine enables this for from-scratch screening and leaves it
    /// off while the screening forest reads cached columns).
    spill_on_intern: bool,
    /// Accounted heap bytes of currently-resident columns.
    resident_bytes: usize,
    /// Per-column accounted bytes (stable across spill/reload: the
    /// carrier is rebuilt from the same sorted ids).
    bytes_of: Vec<usize>,
    /// Per-column extent `(offset, n_ids)` in the spill file, once
    /// written; immutable columns are written at most once.
    extents: Vec<Option<(u64, u32)>>,
    /// Per-column last-touch stamps (monotone clock) driving LRU
    /// eviction; touched on intern hits and `ensure_resident`.
    stamps: Vec<u64>,
    clock: u64,
    spill: Option<SpillFile>,
    reloads: u64,
    evictions: u64,
}

impl Default for SupportPool {
    /// Same as [`SupportPool::new`]: layout resolved through the
    /// `SPP_COLUMNS` knob so the whole test suite follows CI's
    /// layout-matrix cell.
    fn default() -> Self {
        Self::new()
    }
}

fn col_hash(col: &[u32]) -> u64 {
    let mut h = DefaultHasher::new();
    col.hash(&mut h);
    h.finish()
}

/// Resolve a requested memory budget in bytes: `0` = auto — the
/// `SPP_MEMORY_BUDGET` environment variable if set, else unlimited
/// (same knob convention as `resolve_threads` / `resolve_range_chunk`).
pub fn resolve_memory_budget(requested: usize) -> usize {
    if requested != 0 {
        return requested;
    }
    std::env::var("SPP_MEMORY_BUDGET")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .unwrap_or(0)
}

impl SupportPool {
    /// A pool in the auto-resolved layout (`SPP_COLUMNS`, default
    /// hybrid — [`crate::columns::resolve_columns`]).
    pub fn new() -> Self {
        Self::with_layout(resolve_columns(None))
    }

    /// A pool interning columns in an explicit layout (what the path
    /// engines use to honor `PathConfig::columns`, and what the
    /// differential tests use to pin sparse-vs-hybrid bit-identity
    /// without racing on the process environment).
    pub fn with_layout(layout: ColumnLayout) -> Self {
        Self {
            layout,
            columns: Vec::new(),
            index: HashMap::new(),
            budget: 0,
            spill_on_intern: false,
            resident_bytes: 0,
            bytes_of: Vec::new(),
            extents: Vec::new(),
            stamps: Vec::new(),
            clock: 0,
            spill: None,
            reloads: 0,
            evictions: 0,
        }
    }

    /// The layout this pool interns into.
    #[inline]
    pub fn layout(&self) -> ColumnLayout {
        self.layout
    }

    /// Number of distinct columns interned so far.
    pub fn len(&self) -> usize {
        self.columns.len()
    }

    pub fn is_empty(&self) -> bool {
        self.columns.is_empty()
    }

    /// Intern `col`, returning the id of the canonical copy.  Two calls
    /// with equal content always return the same id, so id equality is
    /// column equality.
    pub fn intern(&mut self, col: &[u32]) -> SupportId {
        let hv = col_hash(col);
        match self.find(hv, col) {
            Some(id) => {
                self.touch(id);
                id
            }
            None => self.push_new(hv, col.to_vec()),
        }
    }

    /// Intern an owned column: identical dedup semantics to
    /// [`SupportPool::intern`], without re-copying when the column is
    /// new (the parallel screening splice hands shard buffers straight
    /// in instead of copying every survivor column a second time).
    pub fn intern_owned(&mut self, col: Vec<u32>) -> SupportId {
        let hv = col_hash(&col);
        match self.find(hv, &col) {
            Some(id) => {
                self.touch(id);
                id
            }
            None => self.push_new(hv, col),
        }
    }

    fn find(&mut self, hv: u64, col: &[u32]) -> Option<SupportId> {
        // The candidate list is cloned (tiny — collisions are rare) so
        // spilled candidates can be compared through a scratch read
        // without fighting the borrow of `index`.
        let candidates = self.index.get(&hv)?.clone();
        candidates.into_iter().find(|&id| self.column_equals(id, col))
    }

    /// Content equality against column `id`, resident or spilled; a
    /// spilled column is compared through a scratch read and stays
    /// spilled.
    fn column_equals(&mut self, id: SupportId, col: &[u32]) -> bool {
        let i = id.index();
        if let Stored::Spilled = self.columns[i] {
            let (off, len) = self.extents[i].expect("spilled column has an extent");
            return len as usize == col.len()
                && self.read_extent(off, len).expect("spill file read") == col;
        }
        self.columns[i].ids() == col
    }

    fn push_new(&mut self, hv: u64, col: Vec<u32>) -> SupportId {
        let id = SupportId(self.columns.len() as u32);
        let stored = self.carrier(col);
        let bytes = Self::stored_bytes(&stored);
        self.columns.push(stored);
        self.bytes_of.push(bytes);
        self.extents.push(None);
        self.stamps.push(0);
        self.resident_bytes += bytes;
        self.index.entry(hv).or_default().push(id);
        self.touch(id);
        if self.spill_on_intern && self.budget > 0 && self.resident_bytes > self.budget {
            self.spill_lru(&[id]);
        }
        id
    }

    /// Build the layout-specific carrier for sorted ids — the one
    /// constructor both interning and reloading go through, so a
    /// reloaded column is byte-identical to the original.
    fn carrier(&self, col: Vec<u32>) -> Stored {
        match self.layout {
            ColumnLayout::Sparse => Stored::Sparse(col),
            ColumnLayout::Hybrid => Stored::Hybrid(HybridColumn::from_sorted(col)),
        }
    }

    /// Accounted heap bytes of one resident carrier.
    fn stored_bytes(stored: &Stored) -> usize {
        match stored {
            Stored::Sparse(ids) => ids.len() * std::mem::size_of::<u32>(),
            Stored::Hybrid(col) => col.heap_bytes(),
            Stored::Spilled => 0,
        }
    }

    fn touch(&mut self, id: SupportId) {
        self.clock += 1;
        self.stamps[id.index()] = self.clock;
    }

    /// Borrow the canonical column for `id` as its sorted record ids
    /// (both layouts keep the full id list; module docs).
    #[inline]
    pub fn get(&self, id: SupportId) -> &[u32] {
        self.columns[id.index()].ids()
    }

    /// Borrow the canonical column for `id` as a layout-aware view —
    /// what the fold kernels consume so hybrid columns run over words.
    #[inline]
    pub fn col(&self, id: SupportId) -> ColumnView<'_> {
        self.columns[id.index()].view()
    }

    /// Layout-aware views of many columns at once (what the restricted
    /// solver consumes).
    pub fn view(&self, ids: &[SupportId]) -> Vec<ColumnView<'_>> {
        ids.iter().map(|&id| self.col(id)).collect()
    }

    // ---- spill tier -----------------------------------------------------

    /// Set the resident-byte budget (`0` = unlimited).  Takes effect on
    /// the next enforcement point — existing residents are not evicted
    /// here.
    pub fn set_memory_budget(&mut self, bytes: usize) {
        self.budget = bytes;
    }

    /// The resident-byte budget (`0` = unlimited).
    #[inline]
    pub fn memory_budget(&self) -> usize {
        self.budget
    }

    /// Enable/disable budget enforcement inside `intern` (see the field
    /// docs: safe only while no shared reader holds views across
    /// interns).
    pub fn set_spill_on_intern(&mut self, on: bool) {
        self.spill_on_intern = on;
    }

    /// Make every listed column resident (reloading spilled ones),
    /// touch them, then re-enforce the budget while exempting exactly
    /// these columns — the caller is about to read them.
    pub fn ensure_resident(&mut self, ids: &[SupportId]) {
        for &id in ids {
            self.reload_column(id);
            self.touch(id);
        }
        if self.budget > 0 {
            self.spill_lru(ids);
        }
    }

    /// Reload every spilled column (the incremental forest reads cached
    /// columns by id with no working-set manifest, so the path engine
    /// restores full residency before each forest walk and spills back
    /// down afterwards with [`SupportPool::enforce_budget`]).
    pub fn ensure_all_resident(&mut self) {
        for i in 0..self.columns.len() {
            self.reload_column(SupportId(i as u32));
        }
    }

    /// Spill least-recently-touched columns until resident bytes fit
    /// the budget (no-op when the budget is unlimited).
    pub fn enforce_budget(&mut self) {
        if self.budget > 0 {
            self.spill_lru(&[]);
        }
    }

    /// Current residency gauges and lifetime reload/eviction counters.
    pub fn spill_stats(&self) -> SpillStats {
        let resident_cols = self.columns.iter().filter(|c| c.is_resident()).count();
        SpillStats {
            resident_cols,
            resident_bytes: self.resident_bytes,
            spilled_cols: self.columns.len() - resident_cols,
            reloaded: self.reloads,
            evicted: self.evictions,
        }
    }

    /// Evict least-recently-touched resident columns (never the
    /// `exempt` ones) until `resident_bytes <= budget` or nothing
    /// evictable remains.
    fn spill_lru(&mut self, exempt: &[SupportId]) {
        if self.resident_bytes <= self.budget {
            return;
        }
        // Oldest-first victim order; computed once per enforcement
        // point (enforcement runs between phases, not per read).
        let mut victims: Vec<SupportId> = (0..self.columns.len() as u32)
            .map(SupportId)
            .filter(|id| {
                self.columns[id.index()].is_resident()
                    && self.bytes_of[id.index()] > 0
                    && !exempt.contains(id)
            })
            .collect();
        victims.sort_by_key(|id| self.stamps[id.index()]);
        for id in victims {
            if self.resident_bytes <= self.budget {
                break;
            }
            self.spill_column(id);
        }
    }

    /// Evict one resident column to the spill file.  The canonical ids
    /// are written on first eviction only (columns are immutable, so
    /// the extent stays valid forever and re-eviction is free).
    fn spill_column(&mut self, id: SupportId) {
        let i = id.index();
        if !self.columns[i].is_resident() {
            return;
        }
        if self.extents[i].is_none() {
            let ids = self.columns[i].ids();
            let mut buf = Vec::with_capacity(ids.len() * 4);
            for &v in ids {
                buf.extend_from_slice(&v.to_le_bytes());
            }
            let n_ids = ids.len() as u32;
            let spill = self.spill_file_mut();
            let off = spill.write_pos;
            spill.file.seek(SeekFrom::Start(off)).expect("spill file seek");
            spill.file.write_all(&buf).expect("spill file write");
            spill.write_pos += buf.len() as u64;
            self.extents[i] = Some((off, n_ids));
        }
        self.resident_bytes -= self.bytes_of[i];
        self.columns[i] = Stored::Spilled;
        self.evictions += 1;
    }

    /// Reload `id` from the spill file if spilled; no-op otherwise.
    /// The carrier is rebuilt from the same sorted ids through
    /// [`SupportPool::carrier`], so the reloaded column is
    /// byte-identical to the original.
    fn reload_column(&mut self, id: SupportId) {
        let i = id.index();
        if self.columns[i].is_resident() {
            return;
        }
        let (off, len) = self.extents[i].expect("spilled column has an extent");
        let ids = self.read_extent(off, len).expect("spill file read");
        let carrier = self.carrier(ids);
        self.columns[i] = carrier;
        self.resident_bytes += self.bytes_of[i];
        self.reloads += 1;
    }

    /// Read one extent of canonical sorted ids back from the spill file.
    fn read_extent(&mut self, off: u64, len: u32) -> crate::Result<Vec<u32>> {
        let spill = self.spill.as_mut().expect("spill file exists for recorded extents");
        spill.file.seek(SeekFrom::Start(off))?;
        let mut buf = vec![0u8; len as usize * 4];
        spill.file.read_exact(&mut buf)?;
        Ok(buf
            .chunks_exact(4)
            .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    /// The spill file, created lazily on first eviction.  The name is
    /// unique per process *and* per pool, so concurrent test binaries
    /// (and multiple pools in one process) never collide.
    fn spill_file_mut(&mut self) -> &mut SpillFile {
        if self.spill.is_none() {
            static NEXT: AtomicU64 = AtomicU64::new(0);
            let path = std::env::temp_dir().join(format!(
                "spp-spill-{}-{}.bin",
                std::process::id(),
                NEXT.fetch_add(1, Ordering::Relaxed)
            ));
            let file = File::options()
                .read(true)
                .write(true)
                .create(true)
                .truncate(true)
                .open(&path)
                .expect("create spill file in temp dir");
            self.spill = Some(SpillFile { file, path, write_pos: 0 });
        }
        self.spill.as_mut().expect("spill file just ensured")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::columns::ColumnRead;
    use crate::testutil::SplitMix64;

    #[test]
    fn intern_dedups_by_content() {
        let mut pool = SupportPool::new();
        let a = pool.intern(&[0, 2, 5]);
        let b = pool.intern(&[1]);
        let c = pool.intern(&[0, 2, 5]);
        assert_eq!(a, c);
        assert_ne!(a, b);
        assert_eq!(pool.len(), 2);
        assert_eq!(pool.get(a), &[0, 2, 5]);
        assert_eq!(pool.get(b), &[1]);
    }

    #[test]
    fn intern_owned_dedups_against_borrowed_interning() {
        let mut pool = SupportPool::new();
        let a = pool.intern(&[0, 2, 5]);
        // owned interning of equal content returns the same id …
        assert_eq!(pool.intern_owned(vec![0, 2, 5]), a);
        // … and a new owned column lands without an extra copy semantic
        let b = pool.intern_owned(vec![9]);
        assert_ne!(a, b);
        assert_eq!(pool.intern(&[9]), b);
        assert_eq!(pool.len(), 2);
    }

    #[test]
    fn ids_are_stable_and_dense() {
        let mut pool = SupportPool::new();
        let a = pool.intern(&[7]);
        let b = pool.intern(&[8]);
        assert_eq!(a.index(), 0);
        assert_eq!(b.index(), 1);
        // later interns never move earlier columns
        pool.intern(&[9, 10]);
        assert_eq!(pool.get(a), &[7]);
    }

    #[test]
    fn view_resolves_in_order() {
        let mut pool = SupportPool::new();
        let a = pool.intern(&[1, 2]);
        let b = pool.intern(&[3]);
        let v = pool.view(&[b, a, b]);
        let ids: Vec<&[u32]> = v.iter().map(|c| c.ids()).collect();
        assert_eq!(ids, vec![&[3][..], &[1, 2][..], &[3][..]]);
    }

    #[test]
    fn empty_column_interns_fine() {
        let mut pool = SupportPool::new();
        let e = pool.intern(&[]);
        assert_eq!(pool.get(e), &[] as &[u32]);
        assert_eq!(pool.intern(&[]), e);
    }

    #[test]
    fn both_layouts_round_trip_identical_ids() {
        let mut rng = SplitMix64::new(31);
        let n = 9000usize; // straddles two 4096-id chunks
        let cols: Vec<Vec<u32>> = [0usize, 1, 63, 64, 65, 300, 4096, 4097, n]
            .iter()
            .map(|&m| rng.sample_distinct(n, m).into_iter().map(|i| i as u32).collect())
            .collect();
        let mut sparse = SupportPool::with_layout(ColumnLayout::Sparse);
        let mut hybrid = SupportPool::with_layout(ColumnLayout::Hybrid);
        for col in &cols {
            let a = sparse.intern(col);
            let b = hybrid.intern(col);
            assert_eq!(a, b, "both layouts assign the same dense ids");
            assert_eq!(sparse.get(a), &col[..]);
            assert_eq!(hybrid.get(b), &col[..], "hybrid keeps the canonical sorted ids");
            assert_eq!(hybrid.col(b).ids(), sparse.col(a).ids());
        }
        // dedup semantics are layout-independent
        assert_eq!(sparse.len(), hybrid.len());
    }

    #[test]
    fn hybrid_views_fold_bit_identically_to_sparse() {
        let mut rng = SplitMix64::new(37);
        let n = 5000usize;
        let g: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
        let col: Vec<u32> = rng.sample_distinct(n, n / 2).into_iter().map(|i| i as u32).collect();
        let mut sparse = SupportPool::with_layout(ColumnLayout::Sparse);
        let mut hybrid = SupportPool::with_layout(ColumnLayout::Hybrid);
        let a = sparse.intern(&col);
        let b = hybrid.intern(&col);
        assert_eq!(sparse.col(a).dot(&g).to_bits(), hybrid.col(b).dot(&g).to_bits());
        let (sp, sn) = sparse.col(a).fold_signed(&g);
        let (hp, hn) = hybrid.col(b).fold_signed(&g);
        assert_eq!((sp.to_bits(), sn.to_bits()), (hp.to_bits(), hn.to_bits()));
    }

    #[test]
    fn hash_collisions_keep_columns_distinct() {
        // Two distinct columns forced into one `index` bucket: the
        // `find` path must fall through on content inequality, and
        // `push_new` must append to the shared bucket — regression
        // cover for the collision arm, which real DefaultHasher inputs
        // essentially never hit.
        let mut pool = SupportPool::new();
        let fake_hash = 0xDEAD_BEEFu64;
        let a = pool.push_new(fake_hash, vec![1, 2, 3]);
        assert_eq!(pool.find(fake_hash, &[4, 5]), None, "collision probe misses on content");
        let b = pool.push_new(fake_hash, vec![4, 5]);
        assert_ne!(a, b);
        assert_eq!(pool.len(), 2);
        // both columns stay findable through the shared bucket …
        assert_eq!(pool.find(fake_hash, &[1, 2, 3]), Some(a));
        assert_eq!(pool.find(fake_hash, &[4, 5]), Some(b));
        // … and resolve to their own content
        assert_eq!(pool.get(a), &[1, 2, 3]);
        assert_eq!(pool.get(b), &[4, 5]);
    }

    #[test]
    fn budget_spills_lru_and_reload_is_bit_identical() {
        let mut rng = SplitMix64::new(41);
        let n = 5000usize;
        let cols: Vec<Vec<u32>> = (0..6)
            .map(|_| rng.sample_distinct(n, 800).into_iter().map(|i| i as u32).collect())
            .collect();
        let mut pool = SupportPool::new();
        let ids: Vec<SupportId> = cols.iter().map(|c| pool.intern(c)).collect();
        let baseline: Vec<Vec<u32>> = ids.iter().map(|&id| pool.get(id).to_vec()).collect();
        let full = pool.spill_stats().resident_bytes;

        // Budget below one full residency forces evictions …
        pool.set_memory_budget(full / 2);
        pool.enforce_budget();
        let s = pool.spill_stats();
        assert!(s.spilled_cols > 0, "budget below residency must evict");
        assert!(s.resident_bytes <= full / 2, "gauge respects the budget");
        assert_eq!(s.resident_cols + s.spilled_cols, pool.len());

        // … the oldest-touched columns go first …
        assert!(!pool.columns[ids[0].index()].is_resident(), "LRU evicts the oldest");

        // … and ensure_resident restores exactly the bytes interned.
        pool.ensure_resident(&ids);
        for (&id, want) in ids.iter().zip(&baseline) {
            assert_eq!(pool.get(id), &want[..], "reload is bit-identical");
        }
        let s = pool.spill_stats();
        assert!(s.reloaded > 0 && s.evicted > 0);
        assert_eq!(s.resident_bytes, full, "round trip restores the accounted bytes");
    }

    #[test]
    fn intern_dedups_against_spilled_columns_without_reloading() {
        let mut pool = SupportPool::new();
        let a = pool.intern(&[0, 2, 5, 9]);
        let b = pool.intern(&[1, 3]);
        pool.set_memory_budget(1); // below any column: evict everything evictable
        pool.enforce_budget();
        assert!(pool.spill_stats().spilled_cols >= 2);
        // Dedup still resolves by content — via a scratch read that
        // leaves the column spilled.
        assert_eq!(pool.intern(&[0, 2, 5, 9]), a);
        assert_eq!(pool.intern(&[1, 3]), b);
        assert_eq!(pool.spill_stats().reloaded, 0, "dedup never reloads");
        // A genuinely new column still lands.
        let c = pool.intern(&[0, 2, 5]);
        assert_ne!(c, a);
        assert_eq!(pool.len(), 3);
    }

    #[test]
    fn spill_on_intern_keeps_residency_bounded_mid_stream() {
        let mut rng = SplitMix64::new(43);
        let n = 4000usize;
        let mut pool = SupportPool::new();
        pool.set_memory_budget(4 * 1024);
        pool.set_spill_on_intern(true);
        let mut ids = Vec::new();
        let mut want = Vec::new();
        for _ in 0..32 {
            let col: Vec<u32> =
                rng.sample_distinct(n, 600).into_iter().map(|i| i as u32).collect();
            // The freshly interned column is exempt from its own
            // enforcement pass, but the pool never holds *more* than
            // budget + that one column.
            let ceiling = 4 * 1024 + ids_upper_bound(&col);
            ids.push(pool.intern(&col));
            want.push(col);
            let s = pool.spill_stats();
            assert!(
                s.resident_bytes <= ceiling,
                "mid-stream residency stays near the budget"
            );
        }
        assert!(pool.spill_stats().evicted > 0);
        // Unlimited again: full residency round-trips every column.
        pool.set_memory_budget(0);
        pool.ensure_all_resident();
        for (&id, col) in ids.iter().zip(&want) {
            assert_eq!(pool.get(id), &col[..]);
        }
    }

    #[test]
    #[should_panic(expected = "support column is spilled")]
    fn reading_a_spilled_column_panics() {
        let mut pool = SupportPool::new();
        let a = pool.intern(&[0, 1, 2, 3, 4, 5, 6, 7]);
        pool.set_memory_budget(1);
        pool.enforce_budget();
        let _ = pool.get(a);
    }

    /// A crude upper bound on the accounted bytes any layout spends on
    /// one id list (hybrid adds chunk headers and bitmap words on top
    /// of the raw ids).
    fn ids_upper_bound(ids: &[u32]) -> usize {
        ids.len() * 4 + 64 * 1024
    }
}
