//! Exact dual-feasibility certification.
//!
//! Algorithm 1 reuses the previous λ's subproblem dual solution as the
//! screening pair's `θ̃`.  That point is feasible for the *restricted*
//! problem's constraints; feasibility over all of `T` holds only up to
//! the solver tolerance.  This pass closes the loophole: one bounded
//! tree search (the same envelope as [`super::lambda_max`]) computes
//! the true `max_t |α_tᵀθ̃|` over every pattern; if it exceeds 1 the
//! dual point is shrunk by that factor, after which the SPP rule's
//! safety premise holds *exactly*.
//!
//! This is an extension beyond the paper (which accepts the tolerance
//! slop); the safety integration tests run with it on, and the
//! `--certify` CLI flag / `PathConfig::certify` expose it.  Cost: one
//! extra traversal per λ, measured in ablation A2.

use super::lambda_max::MaxAbsSearch;
use crate::mining::{Counting, PatternSubstrate, TraverseStats};
use crate::solver::Task;

/// Outcome of a certification pass.
#[derive(Clone, Debug)]
pub struct Certified {
    /// The (possibly rescaled) exactly-feasible dual point.
    pub theta: Vec<f64>,
    /// `max_t |α_tᵀθ̃|` before rescaling.
    pub max_violation: f64,
    pub stats: TraverseStats,
}

/// Certify `theta` against every pattern in the database; rescale into
/// the dual box if any constraint is violated.
pub fn certify<S: PatternSubstrate>(
    db: &S,
    y: &[f64],
    task: Task,
    theta: &[f64],
    maxpat: usize,
    minsup: usize,
) -> Certified {
    // g_i = a_i θ_i, so |Σ_{i∈supp(t)} g_i| = |α_tᵀθ|.
    let g: Vec<f64> = y
        .iter()
        .zip(theta)
        .map(|(&yi, &ti)| task.a(yi) * ti)
        .collect();
    let mut search = MaxAbsSearch::new(&g);
    let mut counting = Counting::new(&mut search);
    db.traverse(maxpat, minsup, &mut counting);
    let stats = counting.stats;
    let max_violation = search.best;
    let theta = if max_violation > 1.0 {
        let s = 1.0 / max_violation;
        theta.iter().map(|&t| t * s).collect()
    } else {
        theta.to_vec()
    };
    Certified {
        theta,
        max_violation,
        stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Transactions;

    fn db() -> Transactions {
        Transactions {
            n_items: 3,
            items: vec![vec![0, 1], vec![0], vec![1, 2], vec![2]],
        }
    }

    #[test]
    fn feasible_theta_is_untouched() {
        let t = db();
        let y = vec![1.0; 4];
        let theta = vec![0.2, -0.2, 0.1, -0.1];
        let c = certify(
            &t,
            &y,
            Task::Regression,
            &theta,
            3,
            1,
        );
        assert!(c.max_violation <= 1.0);
        assert_eq!(c.theta, theta);
    }

    #[test]
    fn violating_theta_is_rescaled_exactly() {
        let t = db();
        let y = vec![1.0; 4];
        // column {0} has theta-sum 3.0 -> violation 3
        let theta = vec![2.0, 1.0, 0.0, 0.0];
        let c = certify(
            &t,
            &y,
            Task::Regression,
            &theta,
            3,
            1,
        );
        assert!((c.max_violation - 3.0).abs() < 1e-12);
        // after rescale the worst column sits exactly on the box
        let c2 = certify(
            &t,
            &y,
            Task::Regression,
            &c.theta,
            3,
            1,
        );
        assert!((c2.max_violation - 1.0).abs() < 1e-12);
    }

    #[test]
    fn classification_uses_alpha_folding() {
        let t = db();
        let y = vec![1.0, -1.0, 1.0, -1.0];
        // alpha = y .* x: column {0} sees g = [2, -1] -> |sum| = 1,
        // column {1} sees g = [2, 1] -> 3 (violation through sign fold)
        let theta = vec![2.0, 1.0, 1.0, 0.0];
        let c = certify(
            &t,
            &y,
            Task::Classification,
            &theta,
            1,
            1,
        );
        assert!((c.max_violation - 3.0).abs() < 1e-12);
    }
}
