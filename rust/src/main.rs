//! `spp` — Safe Pattern Pruning CLI (the L3 leader entrypoint).
//!
//! ```text
//! spp path       --dataset cpdb --maxpat 5 [--method spp|boosting|both]
//!                [--lambdas 100] [--min-ratio 0.01] [--scale 1.0]
//!                [--certify] [--no-reuse] [--dynamic-screen=false]
//!                [--threads N]          # 0 = auto; 1 = sequential
//!                [--range-chunk C]      # 0 = auto; 1 = per-λ screening
//!                [--columns sparse|hybrid]  # support-column layout
//!                [--memory-budget B]    # pool spill ceiling in bytes; 0 = off
//!                [--shards K]           # out-of-core: K-shard on-disk db
//!                [--shard-dir DIR]      # where the shard container lives
//!                [--engine rust|xla] [--json out.json]
//! spp cv         --dataset splice --maxpat 3 [--folds 5] [--seed 13]
//!                [--lambdas 100] [--min-ratio 0.01] [--scale 1.0]
//!                [--range-chunk C] [--threads N]
//! spp fit        --dataset synth-seq --maxpat 3 --model out.spp
//!                [--lambdas 100] [--min-ratio 0.01] [--scale 1.0]
//!                [--lambda-index K]     # default: smallest λ
//! spp predict    --dataset synth-seq --model out.spp [--scale 1.0]
//!                [--top 10] [--matcher compiled|naive] [--threads N]
//!                [--batch N]            # records scored per bounded batch
//!                [--shards K --shard-dir DIR]   # stream shard by shard
//! spp serve      --stdio | --socket /path/to.sock [--threads N]
//!                # persistent JSON-lines prediction service (see
//!                # DESIGN.md: compiled matcher, hot reload)
//! spp lambda-max --dataset splice --maxpat 4 [--scale 1.0]
//! spp mine       --dataset cpdb --maxpat 3 [--top 20] [--minsup 2]
//! spp selftest   [--artifacts DIR]     # PJRT round-trip vs Rust engine
//! spp datasets                          # list registry presets
//! ```
//!
//! The binary is a thin shell: parse the declared grammar, then
//! [`spp::cli::commands::dispatch`].  The subcommands live in
//! `spp::cli::commands`, written against the registry's substrate
//! visitors — every data-facing command dispatches the dataset enum
//! exactly once (in `data::registry`) and runs generic
//! `PatternSubstrate` code from there.

use spp::cli::{self, commands};

fn main() {
    let code = match cli::Args::parse_with_switches(
        std::env::args().skip(1),
        commands::SWITCHES,
        commands::FLAGS,
    )
    .and_then(|args| commands::dispatch(&args))
    {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}
