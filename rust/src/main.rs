//! `spp` — Safe Pattern Pruning CLI (the L3 leader entrypoint).
//!
//! ```text
//! spp path       --dataset cpdb --maxpat 5 [--method spp|boosting|both]
//!                [--lambdas 100] [--min-ratio 0.01] [--scale 1.0]
//!                [--certify] [--no-reuse] [--dynamic-screen=false]
//!                [--threads N]          # 0 = auto; 1 = sequential
//!                [--range-chunk C]      # 0 = auto; 1 = per-λ screening
//!                [--columns sparse|hybrid]  # support-column layout
//!                [--memory-budget B]    # pool spill ceiling in bytes; 0 = off
//!                [--shards K]           # out-of-core: K-shard on-disk db
//!                [--shard-dir DIR]      # where the shard container lives
//!                [--engine rust|xla] [--json out.json]
//! spp cv         --dataset splice --maxpat 3 [--folds 5] [--seed 13]
//!                [--lambdas 100] [--min-ratio 0.01] [--scale 1.0]
//!                [--range-chunk C] [--threads N]
//! spp fit        --dataset synth-seq --maxpat 3 --model out.spp
//!                [--lambdas 100] [--min-ratio 0.01] [--scale 1.0]
//!                [--lambda-index K]     # default: smallest λ
//! spp predict    --dataset synth-seq --model out.spp [--scale 1.0]
//!                [--top 10] [--matcher compiled|naive] [--threads N]
//!                [--batch N]            # records scored per bounded batch
//!                [--shards K --shard-dir DIR]   # stream shard by shard
//! spp serve      --stdio | --socket /path/to.sock [--threads N]
//!                # persistent JSON-lines prediction service (see
//!                # DESIGN.md: compiled matcher, hot reload)
//! spp lambda-max --dataset splice --maxpat 4 [--scale 1.0]
//! spp mine       --dataset cpdb --maxpat 3 [--top 20] [--minsup 2]
//! spp selftest   [--artifacts DIR]     # PJRT round-trip vs Rust engine
//! spp datasets                          # list registry presets
//! ```
//!
//! Every data-facing command dispatches the registry [`Dataset`] once
//! and then runs generic code over [`PatternSubstrate`] — item-set,
//! graph, sequence and tabular-rule presets all flow through the same
//! paths.

use std::io::Write;

use spp::cli;
use spp::coordinator::{report, run_experiment, ExperimentSpec, Method};
use spp::data::registry::{self, Dataset};
use spp::mining::{PatternNode, PatternSubstrate, TreeVisitor, Walk};
use spp::model::SparsePatternModel;
use spp::path::PathConfig;
use spp::screening::lambda_max::lambda_max;
use spp::solver::Task;
use spp::SppEstimator;

/// Switches: flags that never consume a non-boolean token (see
/// `cli::Args`).  `help` keeps the universal `spp <command> --help`
/// habit working under the strict grammar.
const SWITCHES: &[&str] = &["certify", "dynamic-screen", "help", "no-reuse", "stdio"];

/// Every value-taking flag any subcommand reads — the complete declared
/// grammar; anything else is rejected with the flag named.
const FLAGS: &[&str] = &[
    "artifacts",
    "batch",
    "columns",
    "dataset",
    "engine",
    "folds",
    "json",
    "k-add",
    "lambda-index",
    "lambdas",
    "matcher",
    "maxpat",
    "memory-budget",
    "method",
    "min-ratio",
    "minsup",
    "model",
    "range-chunk",
    "scale",
    "seed",
    "shard-dir",
    "shards",
    "socket",
    "threads",
    "top",
];

fn main() {
    let code = match cli::Args::parse_with_switches(std::env::args().skip(1), SWITCHES, FLAGS)
        .and_then(|args| dispatch(&args))
    {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn dispatch(args: &cli::Args) -> spp::Result<()> {
    // `spp <command> --help` prints help instead of running the command
    if args.switch("help") {
        print!("{HELP}");
        return Ok(());
    }
    match args.command.as_str() {
        "path" => cmd_path(args),
        "cv" => cmd_cv(args),
        "fit" => cmd_fit(args),
        "predict" => cmd_predict(args),
        "serve" => cmd_serve(args),
        "lambda-max" => cmd_lambda_max(args),
        "mine" => cmd_mine(args),
        "selftest" => cmd_selftest(args),
        "datasets" => cmd_datasets(),
        "" | "help" | "--help" => {
            print!("{HELP}");
            Ok(())
        }
        other => anyhow::bail!("unknown command '{other}' (try `spp help`)"),
    }
}

const HELP: &str = "\
spp — Safe Pattern Pruning (KDD'16 reproduction)

commands:
  path        compute a regularization path (SPP and/or boosting)
  cv          k-fold cross-validation over the path (model selection)
  fit         fit a sparse pattern model (SPP path) and save it
  predict     load a saved model and predict a dataset
  serve       persistent prediction service (JSON lines over stdio/socket)
  lambda-max  compute the paper's §3.4.1 lambda_max by bounded search
  mine        enumerate frequent patterns (substrate smoke test)
  selftest    verify the PJRT/XLA engines against the Rust engines
  datasets    list the registered synthetic datasets (all substrates)
";

fn path_config(args: &cli::Args) -> spp::Result<PathConfig> {
    let mut cd = spp::solver::CdConfig::default();
    // `--dynamic-screen=false` / `--dynamic-screen false` turns the
    // in-solve gap-safe screening off; absent or bare means on.
    if args.flag("dynamic-screen").is_some() {
        cd.dynamic_screen = args.switch("dynamic-screen");
    }
    Ok(PathConfig {
        n_lambdas: args.get_usize("lambdas", 100)?,
        lambda_min_ratio: args.get_f64("min-ratio", 0.01)?,
        maxpat: args.get_usize("maxpat", 4)?,
        minsup: args.get_usize("minsup", 1)?,
        cd,
        certify: args.switch("certify"),
        // `--no-reuse` falls back to the from-scratch traversal per λ
        // (ablation of the incremental screening forest)
        reuse_forest: !args.switch("no-reuse"),
        // `--threads N` drives the deterministic parallel engine; 0 =
        // auto (SPP_THREADS env, else available parallelism), 1 = the
        // sequential engine — all bit-identical
        threads: args.get_usize("threads", 0)?,
        // `--range-chunk C` drives range-based SPP: one screening mine
        // per chunk of C λs; 0 = auto (SPP_RANGE_CHUNK env, else 1 =
        // per-λ screening) — all bit-identical
        range_chunk: args.get_usize("range-chunk", 0)?,
        // `--columns sparse|hybrid` picks the support-column layout;
        // absent = auto (SPP_COLUMNS env, else hybrid) — bit-identical
        columns: match args.flag("columns") {
            None => None,
            Some("sparse") => Some(spp::columns::ColumnLayout::Sparse),
            Some("hybrid") => Some(spp::columns::ColumnLayout::Hybrid),
            Some(other) => anyhow::bail!("--columns must be sparse|hybrid, got '{other}'"),
        },
        // `--memory-budget BYTES` caps the resident support-column pool
        // (LRU spill to a temp file); 0 = auto (SPP_MEMORY_BUDGET env,
        // else unlimited) — bit-identical at any budget
        memory_budget: args.get_usize("memory-budget", 0)?,
        k_add: args.get_usize("k-add", 1)?,
        ..PathConfig::default()
    })
}

fn cmd_path(args: &cli::Args) -> spp::Result<()> {
    let dataset = args.get_or("dataset", "splice").to_string();
    let scale = args.get_f64("scale", 1.0)?;
    let cfg = path_config(args)?;
    let methods: Vec<Method> = match args.get_or("method", "both") {
        "spp" => vec![Method::Spp],
        "boosting" => vec![Method::Boosting],
        "both" => vec![Method::Spp, Method::Boosting],
        other => anyhow::bail!("--method must be spp|boosting|both, got '{other}'"),
    };
    let engine = args.get_or("engine", "rust").to_string();
    // `--shards K` routes through the on-disk shard container: the
    // database is serialized shard by shard and screening streams it
    // back, bit-identical to the in-memory run at any thread count.
    let shards = args.get_usize("shards", 0)?;
    let shard_dir = args.get_or("shard-dir", "shards").to_string();
    anyhow::ensure!(
        shards == 0 || engine == "rust",
        "--shards streams through the rust engine; drop --engine {engine}"
    );

    let mut results = Vec::new();
    for method in methods {
        let spec = ExperimentSpec {
            dataset: dataset.clone(),
            scale,
            maxpat: cfg.maxpat,
            method,
            cfg,
        };
        let r = if shards > 0 {
            run_path_sharded(&spec, shards, &shard_dir)?
        } else if engine == "xla" && method == Method::Spp {
            run_path_xla(&spec)?
        } else {
            run_experiment(&spec)?
        };
        println!("{}", report::time_row(&r));
        results.push(r);
    }
    if results.len() == 2 {
        println!("{}", report::speedup_row(&results[0], &results[1]));
    }
    if let Some(path) = args.flag("json") {
        let mut f = std::fs::File::create(path)?;
        for r in &results {
            writeln!(f, "{}", report::result_json(r))?;
        }
        println!("wrote {path}");
    }
    Ok(())
}

/// K-fold cross-validation over the SPP path: the paper's §3.4.1
/// model-selection workflow, served by the chunked (range-based SPP)
/// engine — one database search per grid chunk, per fold.
fn cmd_cv(args: &cli::Args) -> spp::Result<()> {
    use spp::path::cv::cross_validate;

    let dataset = args.get_or("dataset", "splice").to_string();
    let scale = args.get_f64("scale", 1.0)?;
    let folds = args.get_usize("folds", 5)?;
    let seed = args.get_usize("seed", 13)? as u64;
    let cfg = path_config(args)?;
    let info = registry::info(&dataset)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset '{dataset}'"))?;
    let data = registry::lookup(&dataset, scale)?;
    anyhow::ensure!(
        folds >= 2 && folds <= data.n_records(),
        "--folds must be between 2 and the record count; got {folds} folds for {} records",
        data.n_records()
    );
    let t0 = std::time::Instant::now();
    let cv = match &data {
        Dataset::Graphs(g) => cross_validate(g, &g.y, info.task, &cfg, folds, seed)?,
        Dataset::Itemsets(t) => cross_validate(&t.db, &t.y, info.task, &cfg, folds, seed)?,
        Dataset::Sequences(s) => cross_validate(&s.db, &s.y, info.task, &cfg, folds, seed)?,
        Dataset::Tabular(t) => cross_validate(&t.db, &t.y, info.task, &cfg, folds, seed)?,
    };
    let secs = t0.elapsed().as_secs_f64();
    let metric = match info.task {
        Task::Regression => "mse",
        Task::Classification => "error",
    };
    println!(
        "cv {dataset}: n={} task={:?} folds={folds} lambdas={} chunk={} ({secs:.2}s)",
        data.n_records(),
        info.task,
        cfg.n_lambdas,
        spp::screening::range::resolve_range_chunk(cfg.range_chunk),
    );
    println!("{:<6} {:>12} {:>12} {:>12}", "idx", "lambda/lmax", metric, "mean_active");
    for (i, p) in cv.points.iter().enumerate() {
        println!(
            "{:<6} {:>12.6} {:>12.6} {:>12.1}{}",
            i,
            p.lambda_frac,
            p.mean_loss,
            p.mean_active,
            if i == cv.best { "   <- best" } else { "" }
        );
    }
    let best = cv.best_point();
    println!(
        "best: index {} (λ/λ_max = {:.6}), mean {metric} {:.6} over {folds} folds",
        cv.best,
        best.lambda_frac,
        best.mean_loss
    );
    Ok(())
}

/// Fit via the `SppEstimator` facade and persist the chosen model.
fn cmd_fit(args: &cli::Args) -> spp::Result<()> {
    let dataset = args.get_or("dataset", "splice");
    let scale = args.get_f64("scale", 1.0)?;
    let out = args.require("model")?;
    let info = registry::info(dataset)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset '{dataset}'"))?;
    let data = registry::lookup(dataset, scale)?;
    let cfg = path_config(args)?;
    let est = SppEstimator::new(info.task)
        .maxpat(cfg.maxpat)
        .minsup(cfg.minsup)
        .lambda_grid(cfg.n_lambdas, cfg.lambda_min_ratio)
        .certify(cfg.certify)
        .reuse_forest(cfg.reuse_forest)
        .threads(cfg.threads)
        .range_chunk(cfg.range_chunk)
        .cd(cfg.cd);
    let est = match cfg.columns {
        Some(layout) => est.columns(layout),
        None => est,
    };
    let fit = match &data {
        Dataset::Graphs(g) => est.fit(g, &g.y)?,
        Dataset::Itemsets(t) => est.fit(&t.db, &t.y)?,
        Dataset::Sequences(s) => est.fit(&s.db, &s.y)?,
        Dataset::Tabular(t) => est.fit(&t.db, &t.y)?,
    };
    let idx = args.get_usize("lambda-index", fit.path.points.len() - 1)?;
    anyhow::ensure!(
        idx < fit.path.points.len(),
        "--lambda-index {idx} out of range (path has {} points)",
        fit.path.points.len()
    );
    let model = fit.model_at(idx);
    std::fs::write(out, model.serialize()?)?;
    println!(
        "fit {dataset}: n={} task={:?} λ_max={:.6} path={} λs, {} tree nodes",
        data.n_records(),
        info.task,
        fit.path.lambda_max,
        fit.path.points.len(),
        fit.path.total_nodes()
    );
    println!(
        "model @ λ={:.6} (index {idx}): {} patterns, b={:+.4} -> wrote {out}",
        model.lambda,
        model.terms.len(),
        model.b
    );
    Ok(())
}

/// Streaming accumulator for `spp predict`: the running metric, op
/// counts and the first `top` display rows survive each batch — the
/// per-record predictions do not, which is the point of bounded-batch
/// scoring (peak matcher input is one `--batch` window).
struct PredictAccum {
    task: Task,
    top: usize,
    n: usize,
    correct: usize,
    sse: f64,
    ops: u64,
    batches: u64,
    rows: Vec<(f64, f64)>,
}

impl PredictAccum {
    fn new(task: Task, top: usize) -> Self {
        PredictAccum {
            task,
            top,
            n: 0,
            correct: 0,
            sse: 0.0,
            ops: 0,
            batches: 0,
            rows: Vec::new(),
        }
    }

    /// Fold one window of final predictions (output transform already
    /// applied) against its aligned target slice.
    fn absorb(&mut self, preds: &[f64], y: &[f64], ops: u64) {
        debug_assert_eq!(preds.len(), y.len());
        self.ops += ops;
        for (&p, &yi) in preds.iter().zip(y) {
            match self.task {
                Task::Classification => {
                    if (p >= 0.0) == (yi > 0.0) {
                        self.correct += 1;
                    }
                }
                Task::Regression => self.sse += (p - yi) * (p - yi),
            }
            if self.rows.len() < self.top {
                self.rows.push((p, yi));
            }
            self.n += 1;
        }
    }
}

/// Score `rows` through the compiled matcher in `batch`-sized windows,
/// folding each window into `acc`.  `score` is the substrate-specific
/// batch entrypoint (`score_itemsets` / `score_graphs` /
/// `score_sequences`); batching is invisible in the results because
/// each record is scored independently.
fn predict_batches<R>(
    compiled: &spp::serve::compiled::CompiledModel,
    rows: &[R],
    y: &[f64],
    batch: usize,
    acc: &mut PredictAccum,
    score: impl Fn(&[R]) -> spp::Result<spp::serve::compiled::ScoreBatch>,
) -> spp::Result<()> {
    anyhow::ensure!(rows.len() == y.len(), "rows/targets length mismatch");
    let mut lo = 0;
    while lo < rows.len() {
        let hi = (lo + batch).min(rows.len());
        let out = score(&rows[lo..hi])?;
        let preds: Vec<f64> = out.scores.iter().map(|&s| compiled.output(s)).collect();
        acc.absorb(&preds, &y[lo..hi], out.ops);
        acc.batches += 1;
        lo = hi;
    }
    Ok(())
}

/// Load a persisted model and predict a registry dataset.
///
/// `--matcher compiled` (the default) routes scoring through the serve
/// layer's compiled matcher — one pass per record instead of one per
/// (record, pattern) pair, streamed in `--batch`-sized windows — and
/// reports its telemetry on the summary line; with `--shards K` the
/// records come off the on-disk shard container one shard at a time,
/// so the resident input is one shard regardless of dataset size.
/// `--matcher naive` keeps the historical per-pattern whole-dataset
/// scorer as a differential oracle.  Predictions are bit-identical
/// either way (pinned by `tests/integration_serve.rs`).
fn cmd_predict(args: &cli::Args) -> spp::Result<()> {
    let dataset = args.get_or("dataset", "splice");
    let scale = args.get_f64("scale", 1.0)?;
    let top = args.get_usize("top", 10)?;
    let threads = args.get_usize("threads", 0)?;
    // bounded-batch streaming: at most `batch` records are handed to
    // the matcher at once; `--shards` streams them off the disk
    // container one shard at a time
    let batch = args.get_usize("batch", 8192)?;
    anyhow::ensure!(batch >= 1, "--batch must be >= 1");
    let shards = args.get_usize("shards", 0)?;
    let file = args.require("model")?;
    let model = SparsePatternModel::parse(&std::fs::read_to_string(file)?)?;
    let info = registry::info(dataset)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset '{dataset}'"))?;
    // A mismatched model scores every record as sign(b) / b and prints
    // a confidently wrong metric — reject the combination up front.
    anyhow::ensure!(
        model.task == info.task,
        "model {file} is a {:?} model but dataset '{dataset}' is a {:?} task",
        model.task,
        info.task
    );
    let expected_tag = {
        use spp::data::{
            graph::GraphDatabase, sequence::Sequences, tabular::TabularData, Transactions,
        };
        match info.kind {
            registry::Kind::Itemset => Transactions::KIND_TAG,
            registry::Kind::Graph => GraphDatabase::KIND_TAG,
            registry::Kind::Sequence => Sequences::KIND_TAG,
            registry::Kind::Tabular => TabularData::KIND_TAG,
        }
    };
    anyhow::ensure!(
        model.terms.is_empty() || model.terms.iter().any(|(p, _)| p.kind_tag() == expected_tag),
        "model {file} has no {expected_tag}-kind patterns — it was fitted on a different \
         substrate than dataset '{dataset}'"
    );
    let mut acc = PredictAccum::new(model.task, top);
    let telemetry = match args.get_or("matcher", "compiled") {
        "naive" => {
            anyhow::ensure!(
                shards == 0,
                "--matcher naive scores the whole dataset at once; --shards streams \
                 through the compiled matcher"
            );
            let data = registry::lookup(dataset, scale)?;
            let preds = match &data {
                Dataset::Graphs(g) => model.predict(g),
                Dataset::Itemsets(t) => model.predict(&t.db),
                Dataset::Sequences(s) => model.predict(&s.db),
                Dataset::Tabular(t) => model.predict(&t.db),
            };
            let calls = (model.terms.len() as u64) * (data.n_records() as u64);
            acc.absorb(&preds, data.targets(), 0);
            format!("matcher=naive match_calls={calls}")
        }
        "compiled" => {
            let compiled =
                spp::serve::compiled::CompiledModel::compile_for(&model, expected_tag)?;
            if shards > 0 {
                use spp::data::registry::ShardedDataset;
                let dir = args.get_or("shard-dir", "shards");
                let data =
                    registry::lookup_sharded(dataset, scale, shards, std::path::Path::new(dir))?;
                // walk the container shard by shard; `base` keeps the
                // target slice aligned with the shard's global records
                let mut base = 0usize;
                match &data {
                    ShardedDataset::Itemsets { db, y } => {
                        for s in 0..db.n_shards() {
                            let shard = db.shard(s)?;
                            let ys = &y[base..base + shard.items.len()];
                            predict_batches(&compiled, &shard.items, ys, batch, &mut acc, |w| {
                                compiled.score_itemsets(w, threads)
                            })?;
                            base += shard.items.len();
                        }
                    }
                    ShardedDataset::Graphs { db, y } => {
                        for s in 0..db.n_shards() {
                            let shard = db.shard(s)?;
                            let ys = &y[base..base + shard.graphs.len()];
                            predict_batches(&compiled, &shard.graphs, ys, batch, &mut acc, |w| {
                                compiled.score_graphs(w, threads)
                            })?;
                            base += shard.graphs.len();
                        }
                    }
                    ShardedDataset::Sequences { db, y } => {
                        for s in 0..db.n_shards() {
                            let shard = db.shard(s)?;
                            let ys = &y[base..base + shard.seqs.len()];
                            predict_batches(&compiled, &shard.seqs, ys, batch, &mut acc, |w| {
                                compiled.score_sequences(w, threads)
                            })?;
                            base += shard.seqs.len();
                        }
                    }
                    ShardedDataset::Tabular { db, y } => {
                        for s in 0..db.n_shards() {
                            let shard = db.shard(s)?;
                            let ys = &y[base..base + shard.rows.len()];
                            predict_batches(&compiled, &shard.rows, ys, batch, &mut acc, |w| {
                                compiled.score_tabular(w, threads)
                            })?;
                            base += shard.rows.len();
                        }
                    }
                }
            } else {
                let data = registry::lookup(dataset, scale)?;
                let y = data.targets();
                match &data {
                    Dataset::Itemsets(t) => {
                        predict_batches(&compiled, &t.db.items, y, batch, &mut acc, |w| {
                            compiled.score_itemsets(w, threads)
                        })?
                    }
                    Dataset::Graphs(g) => {
                        predict_batches(&compiled, &g.graphs, y, batch, &mut acc, |w| {
                            compiled.score_graphs(w, threads)
                        })?
                    }
                    Dataset::Sequences(s) => {
                        predict_batches(&compiled, &s.db.seqs, y, batch, &mut acc, |w| {
                            compiled.score_sequences(w, threads)
                        })?
                    }
                    Dataset::Tabular(t) => {
                        predict_batches(&compiled, &t.db.rows, y, batch, &mut acc, |w| {
                            compiled.score_tabular(w, threads)
                        })?
                    }
                }
            }
            format!(
                "matcher=compiled compiled_patterns={} index_nodes={} batches={} batch={} ops={}",
                compiled.stats.compiled_terms,
                compiled.stats.index_nodes,
                acc.batches,
                batch,
                acc.ops
            )
        }
        other => anyhow::bail!("--matcher must be compiled|naive, got '{other}'"),
    };
    match model.task {
        Task::Classification => println!(
            "predict {dataset}: n={} accuracy={:.1}% ({} patterns in model) {telemetry}",
            acc.n,
            100.0 * acc.correct as f64 / acc.n.max(1) as f64,
            model.terms.len()
        ),
        Task::Regression => println!(
            "predict {dataset}: n={} mse={:.4} ({} patterns in model) {telemetry}",
            acc.n,
            acc.sse / acc.n.max(1) as f64,
            model.terms.len()
        ),
    }
    for (i, (p, yi)) in acc.rows.iter().enumerate() {
        println!("  record {i:<5} pred={p:+.4} y={yi:+.4}");
    }
    Ok(())
}

/// Persistent prediction service: line-delimited JSON requests over
/// stdin/stdout (`--stdio`) or a Unix domain socket (`--socket PATH`),
/// with hot-reloadable models and the compiled batch matcher.  Stdio
/// mode writes nothing but response lines to stdout, so canned
/// sessions pipe and diff cleanly (the CI `serve-smoke` job does
/// exactly that against a golden transcript).
fn cmd_serve(args: &cli::Args) -> spp::Result<()> {
    let threads = args.get_usize("threads", 0)?;
    let stdio = args.switch("stdio");
    let socket = args.flag("socket");
    match (stdio, socket) {
        (true, Some(_)) => anyhow::bail!("--stdio and --socket are mutually exclusive"),
        (false, Some(path)) => spp::serve::run_unix_socket(path, threads),
        (true, None) => spp::serve::run_stdio(threads),
        (false, None) => {
            anyhow::bail!("serve needs a transport: --stdio or --socket /path/to.sock")
        }
    }
}

/// Path over an on-disk sharded database ([`registry::lookup_sharded`]).
///
/// Identical math to [`run_experiment`] — `ShardedDb` implements
/// [`PatternSubstrate`], so the whole path stack runs unchanged; the
/// shard layer only changes *where the records live* during the
/// screening traversals (per-shard streaming for item sets, a resident
/// union for graph/sequence shards — DESIGN.md "Out-of-core shards").
fn run_path_sharded(
    spec: &ExperimentSpec,
    shards: usize,
    dir: &str,
) -> spp::Result<spp::coordinator::ExperimentResult> {
    use spp::data::registry::ShardedDataset;
    use spp::path::{compute_path_boosting, compute_path_spp, PathResult};

    fn run<S: PatternSubstrate>(
        db: &S,
        y: &[f64],
        task: Task,
        method: Method,
        cfg: &PathConfig,
    ) -> spp::Result<PathResult> {
        match method {
            Method::Spp => compute_path_spp(db, y, task, cfg),
            Method::Boosting => compute_path_boosting(db, y, task, cfg),
        }
    }

    let info = registry::info(&spec.dataset)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset '{}'", spec.dataset))?;
    let data =
        registry::lookup_sharded(&spec.dataset, spec.scale, shards, std::path::Path::new(dir))?;
    let t = std::time::Instant::now();
    let path = match &data {
        ShardedDataset::Itemsets { db, y } => run(db, y, info.task, spec.method, &spec.cfg)?,
        ShardedDataset::Graphs { db, y } => run(db, y, info.task, spec.method, &spec.cfg)?,
        ShardedDataset::Sequences { db, y } => run(db, y, info.task, spec.method, &spec.cfg)?,
        ShardedDataset::Tabular { db, y } => run(db, y, info.task, spec.method, &spec.cfg)?,
    };
    eprintln!(
        "sharded engine: {} shards in {dir}, peak resident columns {} bytes, {} reloads",
        shards,
        path.max_resident_bytes(),
        path.total_spill_reloads()
    );
    let max_gap = path.points.iter().map(|p| p.gap).fold(0.0f64, f64::max);
    Ok(spp::coordinator::ExperimentResult {
        task: info.task,
        n_records: data.n_records(),
        lambda_max: path.lambda_max,
        traverse_secs: path.total_traverse_secs(),
        solve_secs: path.total_solve_secs(),
        total_secs: path.total_secs(),
        wall_secs: t.elapsed().as_secs_f64(),
        traverse_nodes: path.total_nodes(),
        final_active: path.points.last().map(|p| p.active.len()).unwrap_or(0),
        max_gap,
        path,
        spec: spec.clone(),
    })
}

/// SPP path with the XLA FISTA engine for the restricted solves.
fn run_path_xla(spec: &ExperimentSpec) -> spp::Result<spp::coordinator::ExperimentResult> {
    use spp::path::compute_path_spp_with;
    use spp::runtime::{default_artifact_dir, engine::XlaRestricted, PjrtRuntime};

    let info = registry::info(&spec.dataset)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset '{}'", spec.dataset))?;
    let data = registry::lookup(&spec.dataset, spec.scale)?;
    let rt = PjrtRuntime::cpu(&default_artifact_dir())?;
    let solver = XlaRestricted::new(&rt);
    let t = std::time::Instant::now();
    let path = match &data {
        Dataset::Graphs(g) => compute_path_spp_with(g, &g.y, info.task, &spec.cfg, &solver)?,
        Dataset::Itemsets(tr) => {
            compute_path_spp_with(&tr.db, &tr.y, info.task, &spec.cfg, &solver)?
        }
        Dataset::Sequences(s) => {
            compute_path_spp_with(&s.db, &s.y, info.task, &spec.cfg, &solver)?
        }
        Dataset::Tabular(t) => {
            compute_path_spp_with(&t.db, &t.y, info.task, &spec.cfg, &solver)?
        }
    };
    eprintln!(
        "xla engine: {} subproblem fallbacks to CD",
        solver.fallbacks.get()
    );
    let max_gap = path.points.iter().map(|p| p.gap).fold(0.0f64, f64::max);
    Ok(spp::coordinator::ExperimentResult {
        task: info.task,
        n_records: data.n_records(),
        lambda_max: path.lambda_max,
        traverse_secs: path.total_traverse_secs(),
        solve_secs: path.total_solve_secs(),
        total_secs: path.total_secs(),
        wall_secs: t.elapsed().as_secs_f64(),
        traverse_nodes: path.total_nodes(),
        final_active: path.points.last().map(|p| p.active.len()).unwrap_or(0),
        max_gap,
        path,
        spec: spec.clone(),
    })
}

fn cmd_lambda_max(args: &cli::Args) -> spp::Result<()> {
    let dataset = args.get_or("dataset", "splice");
    let scale = args.get_f64("scale", 1.0)?;
    let maxpat = args.get_usize("maxpat", 4)?;
    let info = registry::info(dataset)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset '{dataset}'"))?;
    let data = registry::lookup(dataset, scale)?;
    let lm = match &data {
        Dataset::Graphs(g) => lambda_max(g, &g.y, info.task, maxpat, 1),
        Dataset::Itemsets(t) => lambda_max(&t.db, &t.y, info.task, maxpat, 1),
        Dataset::Sequences(s) => lambda_max(&s.db, &s.y, info.task, maxpat, 1),
        Dataset::Tabular(t) => lambda_max(&t.db, &t.y, info.task, maxpat, 1),
    };
    println!(
        "dataset={dataset} n={} task={:?} maxpat={maxpat} lambda_max={:.6} b0={:.6} nodes={} pruned={}",
        data.n_records(),
        info.task,
        lm.lambda_max,
        lm.b0,
        lm.stats.nodes,
        lm.stats.pruned
    );
    Ok(())
}

fn cmd_mine(args: &cli::Args) -> spp::Result<()> {
    let dataset = args.get_or("dataset", "splice");
    let scale = args.get_f64("scale", 0.2)?;
    let maxpat = args.get_usize("maxpat", 3)?;
    let minsup = args.get_usize("minsup", 1)?;
    let top = args.get_usize("top", 20)?;
    let data = registry::lookup(dataset, scale)?;

    struct Collect {
        rows: Vec<(usize, String)>,
    }
    impl TreeVisitor for Collect {
        fn visit(&mut self, node: &PatternNode<'_>) -> Walk {
            self.rows
                .push((node.support.len(), node.to_pattern().display()));
            Walk::Descend
        }
    }
    let mut c = Collect { rows: Vec::new() };
    match &data {
        Dataset::Graphs(g) => g.traverse(maxpat, minsup, &mut c),
        Dataset::Itemsets(t) => t.db.traverse(maxpat, minsup, &mut c),
        Dataset::Sequences(s) => s.db.traverse(maxpat, minsup, &mut c),
        Dataset::Tabular(t) => t.db.traverse(maxpat, minsup, &mut c),
    }
    c.rows.sort_by(|a, b| b.0.cmp(&a.0));
    println!(
        "dataset={dataset} scale={scale} maxpat={maxpat} minsup={minsup}: {} patterns",
        c.rows.len()
    );
    for (sup, pat) in c.rows.into_iter().take(top) {
        println!("  support={sup:<6} {pat}");
    }
    Ok(())
}

fn cmd_selftest(args: &cli::Args) -> spp::Result<()> {
    use spp::runtime::{default_artifact_dir, PjrtRuntime, XlaFistaSolver, XlaSppcScorer};
    use spp::screening::fold_weights;
    use spp::solver::CdSolver;
    use spp::testutil::SplitMix64;

    let dir = args
        .flag("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(default_artifact_dir);
    let rt = PjrtRuntime::cpu(&dir)?;
    println!("platform: {}", rt.platform());

    // 1) SPPC scorer vs the Rust fold
    let mut rng = SplitMix64::new(99);
    let n = 700;
    let y: Vec<f64> = (0..n).map(|_| if rng.coin(0.5) { 1.0 } else { -1.0 }).collect();
    let theta: Vec<f64> = (0..n).map(|_| rng.gauss() * 0.1).collect();
    let (wpos, wneg) = fold_weights(Task::Classification, &y, &theta);
    let supports: Vec<Vec<u32>> = (0..300)
        .map(|_| {
            let m = rng.range(1, 60);
            rng.sample_distinct(n, m).into_iter().map(|i| i as u32).collect()
        })
        .collect();
    let scorer = XlaSppcScorer::new(&rt, n)?;
    let scores = scorer.score(&supports, &wpos, &wneg, 0.3)?;
    let mut max_err = 0.0f64;
    for (sup, sc) in supports.iter().zip(&scores) {
        let pos: f64 = sup.iter().map(|&i| wpos[i as usize]).sum();
        let neg: f64 = sup.iter().map(|&i| wneg[i as usize]).sum();
        let v = sup.len() as f64;
        let want = pos.max(-neg) + 0.3 * v.sqrt();
        max_err = max_err.max((sc.sppc - want).abs());
    }
    anyhow::ensure!(max_err < 1e-3, "sppc mismatch: {max_err}");
    println!(
        "sppc scorer OK (max err {max_err:.2e} over {} patterns)",
        scores.len()
    );

    // 2) FISTA solver vs CD
    let supports2: Vec<Vec<u32>> = supports.iter().take(40).cloned().collect();
    let yv: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
    let xs = XlaFistaSolver::new(&rt).solve(Task::Regression, &supports2, &yv, 2.0)?;
    let cd = CdSolver::default().solve(Task::Regression, &supports2, &yv, 2.0, None);
    let rel = (xs.primal - cd.primal).abs() / cd.primal.abs().max(1.0);
    anyhow::ensure!(rel < 1e-3, "fista vs cd primal mismatch: {rel}");
    println!(
        "fista solver OK (primal {:.6} vs cd {:.6}, {} execs)",
        xs.primal, cd.primal, xs.execs
    );
    println!("selftest OK");
    Ok(())
}

fn cmd_datasets() -> spp::Result<()> {
    let (name, kind, task) = ("name", "kind", "task");
    println!("{name:<14} {kind:<8} {task:<15} paper_n");
    for d in registry::ALL {
        println!(
            "{:<14} {:<8} {:<15} {}",
            d.name,
            format!("{:?}", d.kind).to_lowercase(),
            format!("{:?}", d.task).to_lowercase(),
            d.paper_n
        );
    }
    Ok(())
}
