//! `SppEstimator` — the sklearn-style front door.
//!
//! The lower-level API (assemble a [`PathConfig`], call
//! [`compute_path_spp`], freeze a [`SparsePatternModel`]) stays public
//! for benchmarks and ablations, but the common "fit a model on this
//! database" workflow is three lines, generic over any
//! [`PatternSubstrate`] (this example runs under `cargo test --doc`;
//! the paper-scale settings are `maxpat(4).lambda_grid(100, 0.01)`):
//!
//! ```
//! use spp::data::synth_itemsets::{generate, ItemsetSynthConfig};
//! use spp::solver::Task;
//! use spp::SppEstimator;
//!
//! let data = generate(&ItemsetSynthConfig::tiny(42, true));
//! let fit = SppEstimator::new(Task::Classification)
//!     .maxpat(2)
//!     .lambda_grid(5, 0.1)
//!     .fit(&data.db, &data.y)
//!     .unwrap();
//! assert!(fit.path.points.iter().all(|p| p.gap <= 2e-6), "certified");
//! assert_eq!(fit.predict(&data.db).len(), data.db.len());
//! ```

use crate::mining::PatternSubstrate;
use crate::model::SparsePatternModel;
use crate::path::{compute_path_spp, PathConfig, PathResult};
use crate::solver::{CdConfig, Task};

/// Builder for a Safe-Pattern-Pruning fit: task + the handful of knobs
/// that matter, defaulting to the paper's settings (100 λs down to
/// 0.01·λ_max, maxpat 4, gap tolerance 1e-6).
#[derive(Clone, Copy, Debug)]
pub struct SppEstimator {
    task: Task,
    cfg: PathConfig,
}

impl SppEstimator {
    pub fn new(task: Task) -> Self {
        SppEstimator {
            task,
            cfg: PathConfig::default(),
        }
    }

    /// Maximum pattern size (#items / #edges / #symbols).
    pub fn maxpat(mut self, maxpat: usize) -> Self {
        self.cfg.maxpat = maxpat;
        self
    }

    /// Minimum support for enumeration.
    pub fn minsup(mut self, minsup: usize) -> Self {
        self.cfg.minsup = minsup;
        self
    }

    /// λ grid: `n_lambdas` log-spaced values from λ_max down to
    /// `min_ratio · λ_max` (paper: 100 and 0.01).
    pub fn lambda_grid(mut self, n_lambdas: usize, min_ratio: f64) -> Self {
        self.cfg.n_lambdas = n_lambdas;
        self.cfg.lambda_min_ratio = min_ratio;
        self
    }

    /// Run the exact dual-feasibility pass per λ (see
    /// `screening::certify`).
    pub fn certify(mut self, on: bool) -> Self {
        self.cfg.certify = on;
        self
    }

    /// Reuse the screening forest across λ steps (on by default; off =
    /// paper-literal from-scratch traversal per λ, for ablation).
    pub fn reuse_forest(mut self, on: bool) -> Self {
        self.cfg.reuse_forest = on;
        self
    }

    /// Gap-safe dynamic screening inside the restricted solver (on by
    /// default; see `solver::cd`).
    pub fn dynamic_screening(mut self, on: bool) -> Self {
        self.cfg.cd.dynamic_screen = on;
        self
    }

    /// Worker count for the deterministic parallel engine: `0` (the
    /// default) = auto (`SPP_THREADS` env, else available parallelism),
    /// `1` = the sequential engine, `N` = that many pool workers.  Any
    /// setting fits the bit-identical model (see `runtime::parallel`).
    pub fn threads(mut self, threads: usize) -> Self {
        self.cfg.threads = threads;
        self
    }

    /// λ grid points per screening chunk (range-based SPP, Yoshida et
    /// al. 2023; see `screening::range`): `1` = one screening pass per
    /// λ, `C > 1` = one substrate mine per chunk of `C` λs, `0` (the
    /// default) = auto (`SPP_RANGE_CHUNK` env, else 1).  Any setting
    /// produces bit-identical fits.
    pub fn range_chunk(mut self, chunk: usize) -> Self {
        self.cfg.range_chunk = chunk;
        self
    }

    /// Support-column layout of the interned pool (see
    /// `crate::columns`): `Hybrid` (the resolved default) stores dense
    /// supports as 64-bit bitmap chunks and runs the word kernels,
    /// `Sparse` keeps plain sorted id lists (the scalar oracle).  Both
    /// produce bit-identical fits.  Unset = auto (`SPP_COLUMNS` env,
    /// else hybrid).
    pub fn columns(mut self, layout: crate::columns::ColumnLayout) -> Self {
        self.cfg.columns = Some(layout);
        self
    }

    /// Resident-byte ceiling for the path's support-column pool (see
    /// `PathConfig::memory_budget`): least-recently-used columns spill
    /// to a temp file and reload on demand.  Every budget produces
    /// bit-identical fits.  `0` (the default) = auto
    /// (`SPP_MEMORY_BUDGET` env, else unlimited).
    pub fn memory_budget(mut self, bytes: usize) -> Self {
        self.cfg.memory_budget = bytes;
        self
    }

    /// Restricted-solver settings (tolerance, epoch caps).
    pub fn cd(mut self, cd: CdConfig) -> Self {
        self.cfg.cd = cd;
        self
    }

    /// The assembled [`PathConfig`] (escape hatch to the low-level API).
    pub fn config(&self) -> PathConfig {
        self.cfg
    }

    /// Compute the full SPP regularization path on `db` and freeze the
    /// smallest-λ model.  Works on any substrate: transactions, graphs,
    /// sequences, numeric tabular rules, or your own
    /// [`PatternSubstrate`] impl.
    ///
    /// On tabular data the fitted terms are interpretable threshold
    /// rules:
    ///
    /// ```
    /// use spp::data::tabular::{self, TabSynthConfig};
    /// use spp::solver::Task;
    /// use spp::SppEstimator;
    ///
    /// let d = tabular::generate(&TabSynthConfig::tiny(7, false));
    /// let fit = SppEstimator::new(Task::Regression)
    ///     .maxpat(2)
    ///     .lambda_grid(5, 0.1)
    ///     .fit(&d.db, &d.y)
    ///     .unwrap();
    /// for (pat, w) in &fit.model.terms {
    ///     println!("{w:+.3} * {}", pat.display()); // e.g. +0.82 * [x3<=0.41 & x0>0.63]
    /// }
    /// ```
    pub fn fit<S: PatternSubstrate>(&self, db: &S, y: &[f64]) -> crate::Result<SppFit> {
        anyhow::ensure!(
            db.n_records() == y.len(),
            "database has {} records but y has {} targets",
            db.n_records(),
            y.len()
        );
        anyhow::ensure!(db.n_records() >= 2, "need at least 2 records to fit");
        anyhow::ensure!(
            self.cfg.n_lambdas >= 2
                && self.cfg.lambda_min_ratio > 0.0
                && self.cfg.lambda_min_ratio < 1.0,
            "lambda grid must have >= 2 values and ratio in (0, 1)"
        );
        if self.task == Task::Classification {
            anyhow::ensure!(
                y.iter().all(|&v| v == 1.0 || v == -1.0),
                "classification targets must be ±1"
            );
        }
        let path = compute_path_spp(db, y, self.task, &self.cfg)?;
        let last = path
            .points
            .last()
            .ok_or_else(|| anyhow::anyhow!("empty path"))?;
        let model = SparsePatternModel::from_path_point(self.task, last);
        Ok(SppFit {
            task: self.task,
            model,
            path,
        })
    }

    /// [`fit`](Self::fit) against a registry
    /// [`Dataset`](crate::data::registry::Dataset), whatever
    /// substrate it wraps — the one visitor hop the CLI and examples
    /// use instead of matching on the dataset enum.
    pub fn fit_dataset(&self, data: &crate::data::registry::Dataset) -> crate::Result<SppFit> {
        struct FitV<'a>(&'a SppEstimator);
        impl crate::data::registry::SubstrateVisitor for FitV<'_> {
            type Out = crate::Result<SppFit>;
            fn visit<S: crate::data::registry::RegistrySubstrate>(
                self,
                db: &S,
                y: &[f64],
            ) -> Self::Out {
                self.0.fit(db, y)
            }
        }
        data.visit(FitV(self))
    }
}

/// A completed fit: the whole certified path plus the smallest-λ model.
#[derive(Clone, Debug)]
pub struct SppFit {
    pub task: Task,
    /// Model at the smallest λ (the densest end of the path).
    pub model: SparsePatternModel,
    /// Every per-λ record (weights, gaps, traversal statistics).
    pub path: PathResult,
}

impl SppFit {
    /// Freeze the model at path point `index` (0 = λ_max).
    pub fn model_at(&self, index: usize) -> SparsePatternModel {
        SparsePatternModel::from_path_point(self.task, &self.path.points[index])
    }

    /// Predictions of the smallest-λ model on a database (sign for
    /// classification).
    pub fn predict<S: PatternSubstrate>(&self, db: &S) -> Vec<f64> {
        self.model.predict(db)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sequence::{generate as sgen, SeqSynthConfig};
    use crate::data::synth_itemsets::{generate, ItemsetSynthConfig};

    #[test]
    fn reuse_and_screening_knobs_reach_the_config() {
        use crate::columns::ColumnLayout;
        let est = SppEstimator::new(Task::Regression)
            .reuse_forest(false)
            .dynamic_screening(false)
            .threads(3)
            .range_chunk(5)
            .columns(ColumnLayout::Sparse)
            .memory_budget(1 << 20);
        assert!(!est.config().reuse_forest);
        assert!(!est.config().cd.dynamic_screen);
        assert_eq!(est.config().threads, 3);
        assert_eq!(est.config().range_chunk, 5);
        assert_eq!(est.config().columns, Some(ColumnLayout::Sparse));
        assert_eq!(est.config().memory_budget, 1 << 20);
        let est = SppEstimator::new(Task::Regression);
        assert!(est.config().reuse_forest, "forest reuse must default on");
        assert!(est.config().cd.dynamic_screen, "dynamic screening must default on");
        assert_eq!(est.config().threads, 0, "threads must default to auto");
        assert_eq!(est.config().range_chunk, 0, "range chunk must default to auto");
        assert_eq!(est.config().columns, None, "column layout must default to auto");
        assert_eq!(est.config().memory_budget, 0, "memory budget must default to auto");
    }

    #[test]
    fn chunked_fits_are_bit_identical_to_per_lambda() {
        let d = generate(&ItemsetSynthConfig::tiny(35, false));
        let base = SppEstimator::new(Task::Regression).maxpat(2).lambda_grid(8, 0.1);
        let per_lambda = base.range_chunk(1).fit(&d.db, &d.y).unwrap();
        let chunked = base.range_chunk(3).fit(&d.db, &d.y).unwrap();
        assert_eq!(per_lambda.model.terms.len(), chunked.model.terms.len());
        for ((pa, wa), (pb, wb)) in per_lambda.model.terms.iter().zip(&chunked.model.terms) {
            assert_eq!(pa, pb);
            assert_eq!(wa.to_bits(), wb.to_bits());
        }
        assert_eq!(per_lambda.model.b.to_bits(), chunked.model.b.to_bits());
        assert!(chunked.path.total_chunk_mine_nodes() > 0);
    }

    #[test]
    fn degenerate_targets_surface_as_fit_errors() {
        let d = generate(&ItemsetSynthConfig::tiny(36, false));
        let y = vec![2.0; d.db.len()];
        let err = SppEstimator::new(Task::Regression)
            .maxpat(2)
            .lambda_grid(4, 0.1)
            .fit(&d.db, &y)
            .unwrap_err();
        assert!(err.to_string().contains("λ_max"), "{err}");
    }

    #[test]
    fn fits_are_bit_identical_across_worker_counts() {
        let d = generate(&ItemsetSynthConfig::tiny(34, false));
        let base = SppEstimator::new(Task::Regression).maxpat(2).lambda_grid(6, 0.1);
        let seq = base.threads(1).fit(&d.db, &d.y).unwrap();
        let par = base.threads(4).fit(&d.db, &d.y).unwrap();
        assert_eq!(seq.model.terms.len(), par.model.terms.len());
        for ((pa, wa), (pb, wb)) in seq.model.terms.iter().zip(&par.model.terms) {
            assert_eq!(pa, pb);
            assert_eq!(wa.to_bits(), wb.to_bits());
        }
        assert_eq!(seq.model.b.to_bits(), par.model.b.to_bits());
    }

    #[test]
    fn fit_matches_low_level_path_api() {
        let d = generate(&ItemsetSynthConfig::tiny(31, false));
        let est = SppEstimator::new(Task::Regression)
            .maxpat(2)
            .lambda_grid(6, 0.1);
        let fit = est.fit(&d.db, &d.y).unwrap();
        let path = compute_path_spp(&d.db, &d.y, Task::Regression, &est.config()).unwrap();
        assert_eq!(fit.path.points.len(), path.points.len());
        let last = path.points.last().unwrap();
        assert_eq!(fit.model.lambda, last.lambda);
        assert_eq!(fit.model.terms.len(), last.active.len());
        assert_eq!(fit.model_at(0).terms.len(), 0, "λ_max model is empty");
        // predictions come back for every record
        assert_eq!(fit.predict(&d.db).len(), d.db.len());
    }

    #[test]
    fn fit_works_on_sequences() {
        let d = sgen(&SeqSynthConfig::tiny(32, false));
        let fit = SppEstimator::new(Task::Regression)
            .maxpat(2)
            .lambda_grid(5, 0.1)
            .fit(&d.db, &d.y)
            .unwrap();
        assert!(fit.path.lambda_max > 0.0);
        assert!(fit.path.points.iter().all(|p| p.gap <= 2e-6));
        assert_eq!(fit.predict(&d.db).len(), d.db.len());
    }

    #[test]
    fn fit_works_on_tabular() {
        use crate::data::tabular::{generate as tgen, TabSynthConfig};
        let d = tgen(&TabSynthConfig::tiny(32, false));
        let fit = SppEstimator::new(Task::Regression)
            .maxpat(2)
            .lambda_grid(5, 0.1)
            .fit(&d.db, &d.y)
            .unwrap();
        assert!(fit.path.lambda_max > 0.0);
        assert!(fit.path.points.iter().all(|p| p.gap <= 2e-6));
        assert_eq!(fit.predict(&d.db).len(), d.db.len());
    }

    #[test]
    fn fit_validates_inputs() {
        let d = generate(&ItemsetSynthConfig::tiny(33, false));
        let est = SppEstimator::new(Task::Regression);
        assert!(est.fit(&d.db, &d.y[..d.y.len() - 1]).is_err());
        let est = SppEstimator::new(Task::Classification);
        assert!(est.fit(&d.db, &d.y).is_err(), "regression targets are not ±1");
        let bad = SppEstimator::new(Task::Regression).lambda_grid(1, 0.1);
        assert!(bad.fit(&d.db, &d.y).is_err());
    }
}
