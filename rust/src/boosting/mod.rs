//! The boosting / cutting-plane baseline (paper §2.2).
//!
//! Solves the dual (eq. 5) by constraint generation, mirroring the
//! gBoost family [Saigo et al.]: start from the working-set problem,
//! and in each round (i) solve the restricted problem, (ii) search the
//! pattern tree for the **most violated constraint** `|α_tᵀθ| > 1`
//! using the Morishita/Kudo envelope bound to prune, (iii) add the top
//! violating pattern(s) and re-solve.  Terminates when no constraint is
//! violated — at which point the restricted optimum is the full-space
//! optimum.
//!
//! The search walks the *same* trees through the same visitor API as
//! SPP, and the restricted problems use the *same* CD solver — so the
//! paper's timing comparison (Figs. 2–5) measures exactly the
//! methodological difference: one search per λ (SPP) vs one search per
//! round (boosting).

use std::time::Instant;

use crate::mining::{
    Counting, Pattern, PatternNode, PatternSubstrate, TraverseStats, TreeVisitor, Walk,
};
use crate::path::working_set::WorkingSet;
use crate::screening::pool::SupportPool;
use crate::solver::{CdConfig, CdSolver, Solution, Task};

/// Baseline configuration.
#[derive(Clone, Copy, Debug)]
pub struct BoostingConfig {
    /// Patterns added per round (gBoost-style multiple pricing).
    pub k_add: usize,
    /// A constraint counts as violated when `|α_tᵀθ| > 1 + viol_tol`.
    pub viol_tol: f64,
    /// Hard cap on constraint-generation rounds per λ.
    pub max_rounds: usize,
    pub cd: CdConfig,
}

impl Default for BoostingConfig {
    fn default() -> Self {
        BoostingConfig {
            k_add: 1,
            viol_tol: 1e-6,
            max_rounds: 10_000,
            cd: CdConfig::default(),
        }
    }
}

/// Per-λ result of the baseline.
#[derive(Debug)]
pub struct BoostingOutcome {
    pub solution: Solution,
    pub rounds: usize,
    pub stats: TraverseStats,
    pub traverse_secs: f64,
    pub solve_secs: f64,
}

/// Top-k most-violating-pattern search with envelope pruning.
///
/// Keeps the k best scores above `floor`; the prune threshold is the
/// k-th best (or `floor` while fewer than k found), exactly like the
/// single-best search when `k = 1`.
pub struct ViolationSearch<'a> {
    g: &'a [f64],
    exclude: &'a WorkingSet,
    floor: f64,
    k: usize,
    /// Ascending by score; at most `k` entries.
    pub found: Vec<(f64, Pattern, Vec<u32>)>,
}

impl<'a> ViolationSearch<'a> {
    pub fn new(g: &'a [f64], exclude: &'a WorkingSet, floor: f64, k: usize) -> Self {
        ViolationSearch {
            g,
            exclude,
            floor,
            k: k.max(1),
            found: Vec::new(),
        }
    }

    fn threshold(&self) -> f64 {
        if self.found.len() < self.k {
            self.floor
        } else {
            self.found[0].0.max(self.floor)
        }
    }
}

impl TreeVisitor for ViolationSearch<'_> {
    fn visit(&mut self, node: &PatternNode<'_>) -> Walk {
        let mut pos = 0.0;
        let mut neg = 0.0;
        for &i in node.support {
            // branchless sign split (see screening::sppc)
            let gi = self.g[i as usize];
            pos += gi.max(0.0);
            neg += gi.min(0.0);
        }
        let score = (pos + neg).abs();
        if score > self.threshold() {
            let pat = node.to_pattern();
            if !self.exclude.contains(&pat) {
                self.found.push((score, pat, node.support.to_vec()));
                self.found
                    .sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                if self.found.len() > self.k {
                    self.found.remove(0);
                }
            }
        }
        // Envelope: max |α_t'ᵀθ| over descendants t' <= max(pos, -neg).
        if pos.max(-neg) <= self.threshold() {
            Walk::Prune
        } else {
            Walk::Descend
        }
    }
}

/// Solve one λ by constraint generation, growing `ws` in place (new
/// columns are interned into `pool`).
/// `w` is the warm-start weight vector aligned with `ws` (extended with
/// zeros as patterns are added); it is updated to the final weights.
#[allow(clippy::too_many_arguments)]
pub fn solve_lambda<S: PatternSubstrate>(
    db: &S,
    y: &[f64],
    task: Task,
    lam: f64,
    maxpat: usize,
    minsup: usize,
    pool: &mut SupportPool,
    ws: &mut WorkingSet,
    w: &mut Vec<f64>,
    b: &mut f64,
    cfg: &BoostingConfig,
) -> BoostingOutcome {
    assert_eq!(w.len(), ws.len());
    let solver = CdSolver::new(cfg.cd);
    let mut stats = TraverseStats::default();
    let mut traverse_secs = 0.0;
    let mut solve_secs = 0.0;
    let mut rounds = 0usize;
    loop {
        rounds += 1;
        let t0 = Instant::now();
        let sol = {
            let cols = ws.columns(pool);
            solver.solve(
                task,
                &cols,
                y,
                lam,
                Some(crate::solver::cd::Warm { w, b: *b }),
            )
        };
        solve_secs += t0.elapsed().as_secs_f64();
        *w = sol.w.clone();
        *b = sol.b;

        // most-violating search over the full tree
        let g: Vec<f64> = y
            .iter()
            .zip(&sol.theta)
            .map(|(&yi, &ti)| task.a(yi) * ti)
            .collect();
        let floor = 1.0 + cfg.viol_tol;
        let mut search = ViolationSearch::new(&g, ws, floor, cfg.k_add);
        let t1 = Instant::now();
        {
            let mut counting = Counting::new(&mut search);
            db.traverse(maxpat, minsup, &mut counting);
            stats.nodes += counting.stats.nodes;
            stats.pruned += counting.stats.pruned;
        }
        traverse_secs += t1.elapsed().as_secs_f64();

        if search.found.is_empty() || rounds >= cfg.max_rounds {
            return BoostingOutcome {
                solution: sol,
                rounds,
                stats,
                traverse_secs,
                solve_secs,
            };
        }
        for (_, pat, sup) in search.found.into_iter().rev() {
            ws.insert(pat, pool.intern(&sup));
            w.push(0.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_itemsets::{generate, ItemsetSynthConfig};
    use crate::screening::lambda_max::lambda_max;
    use crate::solver::ista;
    use crate::testutil::oracle;

    #[test]
    fn violation_search_finds_global_max() {
        let d = generate(&ItemsetSynthConfig::tiny(3, false));
        let ybar = d.y.iter().sum::<f64>() / d.y.len() as f64;
        let g: Vec<f64> = d.y.iter().map(|&v| v - ybar).collect();
        let empty = WorkingSet::new();
        let mut s = ViolationSearch::new(&g, &empty, 0.0, 1);
        d.db.traverse(3, 1, &mut s);
        // brute force
        let mut best = 0.0f64;
        for (_, sup) in oracle::all_itemsets(&d.db, 3) {
            let v: f64 = sup.iter().map(|&i| g[i as usize]).sum();
            best = best.max(v.abs());
        }
        assert!(!s.found.is_empty());
        assert!((s.found[0].0 - best).abs() < 1e-10);
    }

    #[test]
    fn excluded_patterns_are_skipped_but_descended() {
        let d = generate(&ItemsetSynthConfig::tiny(4, false));
        let ybar = d.y.iter().sum::<f64>() / d.y.len() as f64;
        let g: Vec<f64> = d.y.iter().map(|&v| v - ybar).collect();
        // exclude the true argmax; search must return the runner-up
        let empty = WorkingSet::new();
        let mut s0 = ViolationSearch::new(&g, &empty, 0.0, 1);
        d.db.traverse(3, 1, &mut s0);
        let (best_score, best_pat, best_sup) = s0.found.pop().unwrap();

        let mut pool = crate::screening::pool::SupportPool::new();
        let mut ws = WorkingSet::new();
        ws.insert(best_pat.clone(), pool.intern(&best_sup));
        let mut s1 = ViolationSearch::new(&g, &ws, 0.0, 1);
        d.db.traverse(3, 1, &mut s1);
        let (second, pat2, _) = s1.found.pop().unwrap();
        assert_ne!(pat2, best_pat);
        assert!(second <= best_score + 1e-12);
    }

    #[test]
    fn boosting_reaches_full_space_optimum() {
        // small problem: boosting over the tree == dense solve over ALL
        // enumerated patterns
        let d = generate(&ItemsetSynthConfig::tiny(5, false));
        let db = &d.db;
        let lm = lambda_max(db, &d.y, Task::Regression, 2, 1);
        let lam = 0.3 * lm.lambda_max;

        let mut pool = crate::screening::pool::SupportPool::new();
        let mut ws = WorkingSet::new();
        let mut w = Vec::new();
        let mut b = lm.b0;
        let out = solve_lambda(
            db,
            &d.y,
            Task::Regression,
            lam,
            2,
            1,
            &mut pool,
            &mut ws,
            &mut w,
            &mut b,
            &BoostingConfig::default(),
        );

        let all = oracle::all_itemsets(&d.db, 2);
        let supports: Vec<Vec<u32>> = all.iter().map(|(_, s)| s.clone()).collect();
        let dense = ista::solve_dense(Task::Regression, &supports, &d.y, lam, 1e-10, 500_000);
        assert!(
            (out.solution.primal - dense.primal).abs() < 1e-4 * (1.0 + dense.primal.abs()),
            "boosting {} vs dense {}",
            out.solution.primal,
            dense.primal
        );
        assert!(out.rounds >= 1);
        assert!(out.stats.nodes > 0);
    }

    #[test]
    fn k_add_speeds_up_rounds() {
        let d = generate(&ItemsetSynthConfig::tiny(6, false));
        let db = &d.db;
        let lm = lambda_max(db, &d.y, Task::Regression, 3, 1);
        let lam = 0.1 * lm.lambda_max;
        let run = |k: usize| {
            let mut pool = crate::screening::pool::SupportPool::new();
            let mut ws = WorkingSet::new();
            let mut w = Vec::new();
            let mut b = lm.b0;
            let cfg = BoostingConfig {
                k_add: k,
                ..BoostingConfig::default()
            };
            solve_lambda(
                db, &d.y, Task::Regression, lam, 3, 1, &mut pool, &mut ws, &mut w, &mut b, &cfg,
            )
        };
        let r1 = run(1);
        let r5 = run(5);
        assert!(r5.rounds <= r1.rounds);
        let rel = 1e-4 * (1.0 + r1.solution.primal.abs());
        assert!((r1.solution.primal - r5.solution.primal).abs() < rel);
    }
}
