//! Shared benchmark harness (the vendored crate set has no criterion).
//!
//! Two facilities:
//!
//! * [`run_figure`] — the figure-bench driver: a (dataset × maxpat ×
//!   method) sweep printing paper-style rows plus machine-readable
//!   `ROW ...` lines that EXPERIMENTS.md records.  Workload size is
//!   tunable via env:
//!     - `SPP_BENCH_SCALE`   — multiply every dataset's scale,
//!     - `SPP_BENCH_LAMBDAS` — grid size (default 20; paper: 100),
//!     - `SPP_BENCH_RATIO`   — λ_min/λ_max (default 0.05; paper: 0.01),
//!     - `SPP_BENCH_THREADS` — engine workers (default 1 — see below),
//!     - `SPP_BENCH_RANGE_CHUNK` — λs per screening chunk (default 1 =
//!       per-λ screening; the A5 ablation sweeps this explicitly),
//!     - `SPP_BENCH_FULL=1`  — paper-exact sweep (full n, 100 λs, 0.01,
//!       full maxpat set).  Budget hours, not minutes.
//! * [`bench_fn`] — a criterion-style micro-bench: warmup, fixed sample
//!   count, reports min/median/mean.
//!
//! All figure benches pin the engine to a single worker
//! ([`bench_threads`] defaults to 1, NOT the engine's auto setting):
//! the paper measures a single core of a Xeon E5-2643 v2, and pinned
//! ROW lines stay comparable across machines.  Set
//! `SPP_BENCH_THREADS=N` to measure the parallel engine — the computed
//! paths are bit-identical at any worker count.

use std::time::Instant;

use crate::coordinator::{report, run_experiment, ExperimentSpec, Method};
use crate::path::PathConfig;

fn env_f64(name: &str) -> Option<f64> {
    std::env::var(name).ok()?.parse().ok()
}

fn env_usize(name: &str) -> Option<usize> {
    std::env::var(name).ok()?.parse().ok()
}

pub fn full_sweep() -> bool {
    std::env::var("SPP_BENCH_FULL").map(|v| v == "1").unwrap_or(false)
}

/// Resolve the `SPP_BENCH_*` env knobs for one workload:
/// `(scale, n_lambdas, lambda_min_ratio)`.  `SPP_BENCH_FULL=1` swaps in
/// the paper's setup (full n, 100 λs, ratio 0.01); `SPP_BENCH_SCALE`
/// multiplies the scale either way.  Single source of truth for every
/// bench ([`run_figure`] and the standalone ablations alike).
pub fn bench_knobs(default_scale: f64, default_lambdas: usize) -> (f64, usize, f64) {
    let full = full_sweep();
    let scale = if full { 1.0 } else { default_scale } * env_f64("SPP_BENCH_SCALE").unwrap_or(1.0);
    let n_lambdas =
        env_usize("SPP_BENCH_LAMBDAS").unwrap_or(if full { 100 } else { default_lambdas });
    let ratio = env_f64("SPP_BENCH_RATIO").unwrap_or(if full { 0.01 } else { 0.05 });
    (scale, n_lambdas, ratio)
}

/// Engine worker count for bench path computations: `SPP_BENCH_THREADS`
/// if set, else 1 (single-worker paper discipline).  Every bench that
/// builds a `PathConfig` must route it through here — never the
/// engine's auto default, which would silently time however many cores
/// the CI runner has.
pub fn bench_threads() -> usize {
    env_usize("SPP_BENCH_THREADS").unwrap_or(1).max(1)
}

/// λs per screening chunk for bench path computations:
/// `SPP_BENCH_RANGE_CHUNK` if set, else 1 (per-λ screening, the
/// paper's cadence — keeps ROW lines comparable).  Pinned explicitly
/// for the same reason as [`bench_threads`]: the engine's auto default
/// would silently pick up a stray `SPP_RANGE_CHUNK` from the
/// environment.  Chunked paths are bit-identical either way; only the
/// traversal accounting moves.
pub fn bench_range_chunk() -> usize {
    env_usize("SPP_BENCH_RANGE_CHUNK").unwrap_or(1).max(1)
}

/// One workload of a figure sweep.
#[derive(Clone, Copy)]
pub struct Workload {
    pub dataset: &'static str,
    /// Default scale at which the sweep stays within a CI-sized budget.
    pub scale: f64,
    pub maxpats: &'static [usize],
    /// maxpat sweep at `SPP_BENCH_FULL=1` (the paper's).
    pub full_maxpats: &'static [usize],
}

/// Run a figure sweep and print both human and `ROW` lines.
///
/// `fig`: figure tag for the ROW lines (e.g. "fig2").
pub fn run_figure(fig: &str, workloads: &[Workload]) {
    let full = full_sweep();
    let scale_mult = env_f64("SPP_BENCH_SCALE").unwrap_or(1.0);
    let (_, n_lambdas, ratio) = bench_knobs(1.0, 20);
    let threads = bench_threads();
    let range_chunk = bench_range_chunk();
    println!(
        "# {fig}: lambdas={n_lambdas} ratio={ratio} scale_mult={scale_mult} \
         threads={threads} range_chunk={range_chunk} full={full}"
    );
    println!(
        "# paper setup: 100 lambdas, ratio 0.01, full n — set SPP_BENCH_FULL=1 to match"
    );

    for w in workloads {
        let (scale, _, _) = bench_knobs(w.scale, 20);
        let maxpats = if full { w.full_maxpats } else { w.maxpats };
        for &maxpat in maxpats {
            let mut pair = Vec::new();
            for method in [Method::Spp, Method::Boosting] {
                let spec = ExperimentSpec {
                    dataset: w.dataset.into(),
                    scale,
                    maxpat,
                    method,
                    cfg: PathConfig {
                        n_lambdas,
                        lambda_min_ratio: ratio,
                        maxpat,
                        threads,
                        range_chunk,
                        ..PathConfig::default()
                    },
                };
                match run_experiment(&spec) {
                    Ok(r) => {
                        assert!(
                            r.max_gap <= 2e-6,
                            "{}/{:?}: uncertified path (gap {})",
                            w.dataset,
                            method,
                            r.max_gap
                        );
                        println!("{}", report::time_row(&r));
                        println!(
                            "ROW fig={fig} dataset={} n={} maxpat={} method={} total={:.4} \
                             traverse={:.4} solve={:.4} nodes={} active={}",
                            w.dataset,
                            r.n_records,
                            maxpat,
                            method.name(),
                            r.total_secs,
                            r.traverse_secs,
                            r.solve_secs,
                            r.traverse_nodes,
                            r.final_active
                        );
                        pair.push(r);
                    }
                    Err(e) => {
                        println!("ROW fig={fig} dataset={} maxpat={} ERROR {e}", w.dataset, maxpat)
                    }
                }
            }
            if pair.len() == 2 {
                println!("{}", report::speedup_row(&pair[0], &pair[1]));
            }
        }
    }
}

/// The paper's graph workloads (Figures 2 and 4).
pub const GRAPH_WORKLOADS: &[Workload] = &[
    Workload {
        dataset: "cpdb",
        scale: 0.3,
        maxpats: &[3, 4, 5],
        full_maxpats: &[5, 6, 7, 8, 9, 10],
    },
    Workload {
        dataset: "mutagenicity",
        scale: 0.05,
        maxpats: &[3, 4, 5],
        full_maxpats: &[5, 6, 7, 8, 9, 10],
    },
    Workload {
        dataset: "bergstrom",
        scale: 1.0,
        maxpats: &[3, 4, 5],
        full_maxpats: &[5, 6, 7, 8, 9, 10],
    },
    Workload {
        dataset: "karthikeyan",
        scale: 0.05,
        maxpats: &[3, 4, 5],
        full_maxpats: &[5, 6, 7, 8, 9, 10],
    },
];

/// The paper's item-set workloads (Figures 3 and 5).
pub const ITEMSET_WORKLOADS: &[Workload] = &[
    Workload {
        dataset: "splice",
        scale: 0.2,
        maxpats: &[2, 3],
        full_maxpats: &[3, 4, 5, 6],
    },
    Workload {
        dataset: "a9a",
        scale: 0.03,
        maxpats: &[2, 3],
        full_maxpats: &[3, 4, 5, 6],
    },
    Workload {
        dataset: "dna",
        scale: 0.15,
        maxpats: &[2, 3],
        full_maxpats: &[3, 4, 5, 6],
    },
    Workload {
        dataset: "protein",
        scale: 0.02,
        maxpats: &[2],
        full_maxpats: &[3, 4, 5, 6],
    },
];

/// The sequence-substrate workload (beyond the paper; exercises the
/// PrefixSpan tree through the same SPP-vs-boosting sweep).
pub const SEQ_WORKLOADS: &[Workload] = &[Workload {
    dataset: "synth-seq",
    scale: 0.25,
    maxpats: &[2, 3],
    full_maxpats: &[3, 4, 5],
}];

/// The tabular-rule workload (beyond the paper; exercises the RuleFit
/// threshold-refinement tree through the same SPP-vs-boosting sweep).
pub const TAB_WORKLOADS: &[Workload] = &[Workload {
    dataset: "synth-tab",
    scale: 0.25,
    maxpats: &[1, 2],
    full_maxpats: &[2, 3],
}];

/// Criterion-style micro benchmark: returns (min, median, mean) seconds
/// per iteration and prints one line.
pub fn bench_fn<F: FnMut()>(name: &str, samples: usize, mut f: F) -> (f64, f64, f64) {
    // warmup
    for _ in 0..3 {
        f();
    }
    let mut times: Vec<f64> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let min = times[0];
    let median = times[times.len() / 2];
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    println!(
        "BENCH {name}: min={:.3}ms median={:.3}ms mean={:.3}ms ({} samples)",
        1e3 * min,
        1e3 * median,
        1e3 * mean,
        samples
    );
    (min, median, mean)
}

/// ns/op convenience for tight loops: runs `f` `iters` times per sample.
pub fn bench_throughput<F: FnMut() -> u64>(name: &str, samples: usize, mut f: F) {
    let mut best_rate = 0.0f64;
    for _ in 0..samples {
        let t = Instant::now();
        let ops = f();
        let dt = t.elapsed().as_secs_f64();
        best_rate = best_rate.max(ops as f64 / dt);
    }
    println!("BENCH {name}: {:.2} Mops/s (best of {samples})", best_rate / 1e6);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_fn_reports_sane_stats() {
        let (min, median, mean) = bench_fn("noop-spin", 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(min <= median && median <= mean * 5.0);
        assert!(min >= 0.0);
    }

    #[test]
    fn workload_tables_reference_registry_names() {
        for w in GRAPH_WORKLOADS
            .iter()
            .chain(ITEMSET_WORKLOADS)
            .chain(SEQ_WORKLOADS)
            .chain(TAB_WORKLOADS)
        {
            assert!(
                crate::data::registry::info(w.dataset).is_some(),
                "unknown dataset {}",
                w.dataset
            );
            assert!(!w.maxpats.is_empty() && !w.full_maxpats.is_empty());
        }
    }
}
