//! Out-of-core sharded databases behind the [`PatternSubstrate`] seam.
//!
//! The rest of the engine is generic over `PatternSubstrate`, so the
//! out-of-core story is one adapter: [`ShardedDb<S>`] implements the
//! trait over a shard container ([`shard`]) instead of an in-memory
//! database, mapping global record ids to `(shard, local id)` and
//! streaming one shard at a time.  Two hooks on [`ShardCodec`] let a
//! substrate traverse *without* materializing the record union:
//!
//! * `Transactions` overrides them — Eclat only ever touches records
//!   through its depth-1 vertical layout, so the sharded itemset
//!   traversal streams each shard once to build exactly the tidlists
//!   the in-memory miner would have built (per-shard counts and lists
//!   computed on pool workers, reduced **in shard order**, so the
//!   traversal is bit-identical at any thread count — same discipline
//!   as `runtime::parallel`).  Record rows are resident one shard at a
//!   time; only the minsup-filtered vertical layout stays in memory.
//! * gSpan / PrefixSpan grow patterns against the records themselves,
//!   so [`ShardedDb::open`] materializes the union for those substrates
//!   up front (`ShardCodec::STREAMS = false`) — the honest fallback;
//!   the adapter still buys them the on-disk interchange format, the
//!   O(1) id remap and the spill-tier column budget
//!   (`screening::pool`).
//!
//! DESIGN.md §"Out-of-core shards" documents the file format, the
//! determinism argument and the memory model.

pub mod shard;

use std::path::{Path, PathBuf};

use crate::mining::{Pattern, PatternSubstrate, SubtreeVisitors, TreeVisitor};

pub use shard::{read_index, read_shard_bytes, ShardIndex, ShardWriter, MAGIC};

/// A substrate that can live in a shard container: a per-shard record
/// codec plus (optionally) a traversal that streams shards instead of
/// materializing the union.
pub trait ShardCodec: PatternSubstrate + Clone + Sized {
    /// Does [`traverse_sharded`](ShardCodec::traverse_sharded) stream
    /// shards without the record union?  When `false` (the default),
    /// [`ShardedDb::open`] materializes the union eagerly so every
    /// `PatternSubstrate` method works unchanged.
    const STREAMS: bool = false;

    /// Serialize this database as one standalone shard blob (must
    /// round-trip through [`decode_shard`](ShardCodec::decode_shard)).
    fn encode_shard(&self) -> Vec<u8>;

    /// Decode one shard blob back into a database.
    fn decode_shard(bytes: &[u8]) -> crate::Result<Self>;

    /// Concatenate shard databases, in order, into one database whose
    /// record `i` is record `i` of the concatenation.
    fn concat(parts: Vec<Self>) -> crate::Result<Self>;

    /// Sequential canonical traversal of a sharded database; must
    /// visit the exact node sequence `PatternSubstrate::traverse`
    /// visits on the materialized union.  The default delegates to the
    /// union.
    fn traverse_sharded(
        db: &ShardedDb<Self>,
        maxpat: usize,
        minsup: usize,
        visitor: &mut dyn TreeVisitor,
    ) {
        db.union_db().traverse(maxpat, minsup, visitor)
    }

    /// Subtree-parallel twin of
    /// [`traverse_sharded`](ShardCodec::traverse_sharded); same splice
    /// contract as `PatternSubstrate::traverse_parallel`.
    fn traverse_sharded_parallel<F: SubtreeVisitors>(
        db: &ShardedDb<Self>,
        maxpat: usize,
        minsup: usize,
        threads: usize,
        factory: &F,
    ) -> Vec<F::V> {
        db.union_db().traverse_parallel(maxpat, minsup, threads, factory)
    }
}

enum Backing<S> {
    File {
        path: PathBuf,
        index: ShardIndex,
        /// Materialized record union — `Some` for non-streaming
        /// substrates (filled by [`ShardedDb::open`]).
        union: Option<Box<S>>,
    },
    Mem(S),
}

/// A [`PatternSubstrate`] over a shard container (or, after
/// [`select`](PatternSubstrate::select), over an in-memory database —
/// CV folds of a sharded db are ordinary databases).
pub struct ShardedDb<S: ShardCodec> {
    backing: Backing<S>,
}

impl<S: ShardCodec> ShardedDb<S> {
    /// Open a shard container written by [`ShardWriter`] for this
    /// substrate.  Non-streaming substrates materialize the record
    /// union here, once.
    pub fn open(path: &Path) -> crate::Result<Self> {
        let index = shard::read_index(path)?;
        anyhow::ensure!(
            index.kind == S::KIND_TAG,
            "{}: shard kind '{}' does not match substrate '{}'",
            path.display(),
            index.kind,
            S::KIND_TAG
        );
        let mut db = ShardedDb {
            backing: Backing::File {
                path: path.to_path_buf(),
                index,
                union: None,
            },
        };
        if !S::STREAMS {
            let materialized = db.materialize()?;
            if let Backing::File { union, .. } = &mut db.backing {
                *union = Some(Box::new(materialized));
            }
        }
        Ok(db)
    }

    /// Wrap an in-memory database (one logical shard).
    pub fn from_mem(db: S) -> Self {
        ShardedDb {
            backing: Backing::Mem(db),
        }
    }

    /// The in-memory database, if this adapter is memory-backed.
    pub fn as_mem(&self) -> Option<&S> {
        match &self.backing {
            Backing::Mem(db) => Some(db),
            Backing::File { .. } => None,
        }
    }

    /// The container path, if file-backed.
    pub fn path(&self) -> Option<&Path> {
        match &self.backing {
            Backing::File { path, .. } => Some(path),
            Backing::Mem(_) => None,
        }
    }

    /// Number of shards (a memory backing counts as one).
    pub fn n_shards(&self) -> usize {
        match &self.backing {
            Backing::File { index, .. } => index.n_shards(),
            Backing::Mem(_) => 1,
        }
    }

    /// Records per full shard.
    pub fn shard_size(&self) -> usize {
        match &self.backing {
            Backing::File { index, .. } => index.shard_size,
            Backing::Mem(db) => db.n_records().max(1),
        }
    }

    /// Global id of the first record in shard `s`.
    pub fn shard_base(&self, s: usize) -> usize {
        s * self.shard_size()
    }

    /// Records held by shard `s`.
    pub fn shard_records(&self, s: usize) -> usize {
        match &self.backing {
            Backing::File { index, .. } => index.shard_records(s),
            Backing::Mem(db) => db.n_records(),
        }
    }

    /// Map a global record id to `(shard, local id)`.
    pub fn locate(&self, gid: usize) -> (usize, usize) {
        match &self.backing {
            Backing::File { index, .. } => index.locate(gid),
            Backing::Mem(_) => (0, gid),
        }
    }

    /// Decode shard `s` into an owned database (fresh file handle, so
    /// pool workers may call this concurrently).
    pub fn shard(&self, s: usize) -> crate::Result<S> {
        match &self.backing {
            Backing::File { path, index, .. } => {
                S::decode_shard(&shard::read_shard_bytes(path, index, s)?)
            }
            Backing::Mem(db) => {
                anyhow::ensure!(s == 0, "memory backing has a single shard");
                Ok(db.clone())
            }
        }
    }

    /// Decode and concatenate every shard into one in-memory database.
    pub fn materialize(&self) -> crate::Result<S> {
        match &self.backing {
            Backing::File {
                path,
                index,
                union,
            } => {
                if let Some(u) = union {
                    return Ok((**u).clone());
                }
                let mut parts = Vec::with_capacity(index.n_shards());
                for s in 0..index.n_shards() {
                    parts.push(S::decode_shard(&shard::read_shard_bytes(path, index, s)?)?);
                }
                S::concat(parts)
            }
            Backing::Mem(db) => Ok(db.clone()),
        }
    }

    /// Borrow the materialized record union.  Panics for a streaming
    /// substrate's file backing (those never materialize; record-level
    /// access goes through [`ShardedDb::shard`]).
    pub fn union_db(&self) -> &S {
        match &self.backing {
            Backing::Mem(db) => db,
            Backing::File { union: Some(u), .. } => u,
            Backing::File { path, .. } => panic!(
                "record union of streaming substrate '{}' is not materialized ({}); \
                 stream records via ShardedDb::shard",
                S::KIND_TAG,
                path.display()
            ),
        }
    }
}

impl<S: ShardCodec> PatternSubstrate for ShardedDb<S> {
    type Record = S::Record;

    fn n_records(&self) -> usize {
        match &self.backing {
            Backing::File { index, .. } => index.n_records,
            Backing::Mem(db) => db.n_records(),
        }
    }

    fn traverse(&self, maxpat: usize, minsup: usize, visitor: &mut dyn TreeVisitor) {
        S::traverse_sharded(self, maxpat, minsup, visitor)
    }

    fn traverse_parallel<F: SubtreeVisitors>(
        &self,
        maxpat: usize,
        minsup: usize,
        threads: usize,
        factory: &F,
    ) -> Vec<F::V> {
        S::traverse_sharded_parallel(self, maxpat, minsup, threads, factory)
    }

    fn matches(pattern: &Pattern, record: &Self::Record) -> bool {
        S::matches(pattern, record)
    }

    fn record(&self, i: usize) -> &Self::Record {
        self.union_db().record(i)
    }

    /// Record-subset clone: shards are streamed in order, the requested
    /// rows extracted per shard, and the concatenation permuted back to
    /// the caller's index order — so arbitrary (even duplicated) index
    /// lists behave exactly like the in-memory `select`, while at most
    /// one shard's records are decoded at a time beyond the selection
    /// itself.  The result is memory-backed (CV folds are ordinary
    /// databases).
    fn select(&self, indices: &[usize]) -> Self {
        if let Some(db) = self.as_mem() {
            return ShardedDb::from_mem(db.select(indices));
        }
        let n = self.n_records();
        // (gid, original position), stably sorted by gid: duplicates
        // keep their relative order, so the permutation below is total.
        let mut order: Vec<(usize, usize)> = indices
            .iter()
            .copied()
            .enumerate()
            .map(|(p, g)| (g, p))
            .collect();
        for &(g, _) in &order {
            assert!(g < n, "select index {g} out of range ({n} records)");
        }
        order.sort_by_key(|&(g, _)| g);
        let mut parts = Vec::new();
        let mut i = 0;
        for s in 0..self.n_shards() {
            let base = self.shard_base(s);
            let end = base + self.shard_records(s);
            let lo = i;
            while i < order.len() && order[i].0 < end {
                i += 1;
            }
            if lo < i {
                let locals: Vec<usize> = order[lo..i].iter().map(|&(g, _)| g - base).collect();
                let sh = self
                    .shard(s)
                    .unwrap_or_else(|e| panic!("decoding shard {s} for select: {e}"));
                parts.push(sh.select(&locals));
            }
        }
        let sorted = S::concat(parts).unwrap_or_else(|e| panic!("concatenating selection: {e}"));
        let mut perm = vec![0usize; order.len()];
        for (j, &(_, p)) in order.iter().enumerate() {
            perm[p] = j;
        }
        ShardedDb::from_mem(sorted.select(&perm))
    }

    fn parse_pattern(body: &str) -> crate::Result<Pattern> {
        S::parse_pattern(body)
    }

    fn format_pattern(pattern: &Pattern) -> String {
        S::format_pattern(pattern)
    }

    const KIND_TAG: &'static str = S::KIND_TAG;
}

/// Shard an in-memory database into a container at `path`: records are
/// cut into runs of `shard_size` via `select`, encoded and streamed
/// out.  (The huge synthetic presets bypass this and write shards
/// straight from their chunked generator — `data::registry` wires
/// that.)
pub fn write_sharded<S: ShardCodec>(
    db: &S,
    path: &Path,
    shard_size: usize,
) -> crate::Result<ShardIndex> {
    let n = db.n_records();
    anyhow::ensure!(n > 0, "cannot shard an empty database");
    let mut writer = ShardWriter::<S>::create(path, shard_size)?;
    let mut base = 0usize;
    while base < n {
        let end = (base + shard_size).min(n);
        let idx: Vec<usize> = (base..end).collect();
        writer.write_shard(&db.select(&idx))?;
        base = end;
    }
    writer.finish()
}
