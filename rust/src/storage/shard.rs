//! On-disk shard container: fixed-size record shards + a footer index.
//!
//! One `.spps` file holds an entire database as a sequence of opaque
//! shard blobs followed by a self-describing footer:
//!
//! ```text
//! [shard 0 blob][shard 1 blob] … [shard k-1 blob]
//! spp-shards v1
//! kind <KIND_TAG>
//! records <n>
//! shard_size <m>
//! offset <o_0>
//! …
//! offset <o_k>            ← k+1 prefix byte offsets; o_k = payload len
//! [footer_len: u64 LE][b"SPPSHRD1"]
//! ```
//!
//! The blobs are opaque to this layer — each substrate's
//! [`ShardCodec`](super::ShardCodec) defines the per-shard encoding.
//! Every shard except the last holds exactly `shard_size` records
//! ([`ShardWriter::write_shard`] enforces it), so a global record id
//! maps to `(id / shard_size, id % shard_size)` with no per-record
//! index — the O(1) remap [`ShardIndex::locate`] implements and
//! `tests/integration_shards.rs` pins at the shard-size edge cases.
//!
//! The footer lives at the *end* so the writer can stream shards
//! front-to-back without knowing the shard count up front (the
//! tens-of-millions-of-records synthetic preset is generated and
//! written one shard at a time).  The fixed 16-byte trailer (footer
//! length + magic) makes the file self-locating: readers seek to the
//! end, read the trailer, then parse the footer — no side-car index
//! file.

use std::fs::File;
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::marker::PhantomData;
use std::path::{Path, PathBuf};

use anyhow::Context as _;

use super::ShardCodec;

/// Trailing magic identifying a shard container file.
pub const MAGIC: &[u8; 8] = b"SPPSHRD1";

/// Parsed footer of a shard container: everything a reader needs to
/// stream any shard independently.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardIndex {
    /// `KIND_TAG` of the substrate the shards encode (`I`, `G`, `S`).
    pub kind: String,
    /// Total records across all shards.
    pub n_records: usize,
    /// Records per shard; every shard but the last holds exactly this
    /// many.  Always `> 0`.
    pub shard_size: usize,
    /// `n_shards + 1` ascending byte offsets into the payload region;
    /// shard `s` occupies `offsets[s]..offsets[s + 1]`.
    pub offsets: Vec<u64>,
}

impl ShardIndex {
    /// Number of shards in the container.
    pub fn n_shards(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Global id of the first record in shard `s`.
    pub fn shard_base(&self, s: usize) -> usize {
        s * self.shard_size
    }

    /// Records held by shard `s` (only the last shard may be short).
    pub fn shard_records(&self, s: usize) -> usize {
        let base = self.shard_base(s);
        self.shard_size.min(self.n_records - base)
    }

    /// Map a global record id to `(shard, local id)` — the O(1) remap
    /// the fixed shard size buys.
    pub fn locate(&self, gid: usize) -> (usize, usize) {
        (gid / self.shard_size, gid % self.shard_size)
    }
}

/// Streaming shard writer: feed databases of exactly `shard_size`
/// records (the last may be short), then [`ShardWriter::finish`] to
/// write the footer.  Generic over the substrate so the footer records
/// the right `KIND_TAG` and a reader for a different substrate refuses
/// the file.
pub struct ShardWriter<S: ShardCodec> {
    out: BufWriter<File>,
    path: PathBuf,
    shard_size: usize,
    offsets: Vec<u64>,
    records: usize,
    /// A short shard has been written — it must remain the last.
    sealed: bool,
    _marker: PhantomData<S>,
}

impl<S: ShardCodec> ShardWriter<S> {
    /// Create (truncate) `path` and start a container with the given
    /// shard size.
    pub fn create(path: &Path, shard_size: usize) -> crate::Result<Self> {
        anyhow::ensure!(shard_size > 0, "shard_size must be positive");
        let file = File::create(path)
            .with_context(|| format!("creating shard file {}", path.display()))?;
        Ok(ShardWriter {
            out: BufWriter::new(file),
            path: path.to_path_buf(),
            shard_size,
            offsets: vec![0],
            records: 0,
            sealed: false,
            _marker: PhantomData,
        })
    }

    /// Records written so far.
    pub fn records(&self) -> usize {
        self.records
    }

    /// Append one shard.  Every shard must hold exactly `shard_size`
    /// records except the last, which may be short — enforced here so
    /// [`ShardIndex::locate`]'s division remap stays valid.
    pub fn write_shard(&mut self, shard: &S) -> crate::Result<()> {
        let n = shard.n_records();
        anyhow::ensure!(
            !self.sealed,
            "a short shard was already written; only the last shard may hold \
             fewer than shard_size={} records",
            self.shard_size
        );
        anyhow::ensure!(
            n > 0 && n <= self.shard_size,
            "shard holds {n} records; expected 1..={}",
            self.shard_size
        );
        if n < self.shard_size {
            self.sealed = true;
        }
        let blob = shard.encode_shard();
        self.out
            .write_all(&blob)
            .with_context(|| format!("writing shard to {}", self.path.display()))?;
        self.records += n;
        let end = *self.offsets.last().expect("offsets start at [0]") + blob.len() as u64;
        self.offsets.push(end);
        Ok(())
    }

    /// Write the footer + trailer and flush; returns the index the
    /// footer encodes.
    pub fn finish(mut self) -> crate::Result<ShardIndex> {
        let mut footer = String::from("spp-shards v1\n");
        footer.push_str(&format!("kind {}\n", S::KIND_TAG));
        footer.push_str(&format!("records {}\n", self.records));
        footer.push_str(&format!("shard_size {}\n", self.shard_size));
        for o in &self.offsets {
            footer.push_str(&format!("offset {o}\n"));
        }
        self.out.write_all(footer.as_bytes())?;
        self.out.write_all(&(footer.len() as u64).to_le_bytes())?;
        self.out.write_all(MAGIC)?;
        self.out
            .flush()
            .with_context(|| format!("finishing shard file {}", self.path.display()))?;
        Ok(ShardIndex {
            kind: S::KIND_TAG.to_string(),
            n_records: self.records,
            shard_size: self.shard_size,
            offsets: self.offsets,
        })
    }
}

/// Read and validate the footer of a shard container.
pub fn read_index(path: &Path) -> crate::Result<ShardIndex> {
    let mut f =
        File::open(path).with_context(|| format!("opening shard file {}", path.display()))?;
    let len = f.seek(SeekFrom::End(0))?;
    anyhow::ensure!(len >= 16, "{}: too short for a shard container", path.display());
    f.seek(SeekFrom::End(-16))?;
    let mut trailer = [0u8; 16];
    f.read_exact(&mut trailer)?;
    anyhow::ensure!(
        &trailer[8..] == MAGIC,
        "{}: missing shard magic (not a spp-shards file)",
        path.display()
    );
    let footer_len = u64::from_le_bytes(trailer[..8].try_into().expect("8-byte slice"));
    anyhow::ensure!(
        footer_len + 16 <= len,
        "{}: footer length {footer_len} exceeds file size {len}",
        path.display()
    );
    f.seek(SeekFrom::Start(len - 16 - footer_len))?;
    let mut buf = vec![0u8; footer_len as usize];
    f.read_exact(&mut buf)?;
    let text = std::str::from_utf8(&buf)
        .with_context(|| format!("{}: footer is not UTF-8", path.display()))?;
    parse_footer(text, len - 16 - footer_len)
        .with_context(|| format!("parsing shard footer of {}", path.display()))
}

fn parse_footer(text: &str, payload_len: u64) -> crate::Result<ShardIndex> {
    let mut lines = text.lines();
    anyhow::ensure!(
        lines.next() == Some("spp-shards v1"),
        "unsupported shard footer header"
    );
    let mut kind: Option<String> = None;
    let mut n_records: Option<usize> = None;
    let mut shard_size: Option<usize> = None;
    let mut offsets: Vec<u64> = Vec::new();
    for line in lines {
        let (key, value) = line
            .split_once(' ')
            .ok_or_else(|| anyhow::anyhow!("malformed footer line '{line}'"))?;
        match key {
            "kind" => kind = Some(value.to_string()),
            "records" => n_records = Some(value.parse()?),
            "shard_size" => shard_size = Some(value.parse()?),
            "offset" => offsets.push(value.parse()?),
            other => anyhow::bail!("unknown footer key '{other}'"),
        }
    }
    let kind = kind.ok_or_else(|| anyhow::anyhow!("footer missing 'kind'"))?;
    let n_records = n_records.ok_or_else(|| anyhow::anyhow!("footer missing 'records'"))?;
    let shard_size = shard_size.ok_or_else(|| anyhow::anyhow!("footer missing 'shard_size'"))?;
    anyhow::ensure!(shard_size > 0, "shard_size must be positive");
    anyhow::ensure!(!offsets.is_empty(), "footer missing offsets");
    anyhow::ensure!(
        offsets.windows(2).all(|w| w[0] <= w[1]),
        "shard offsets must be non-decreasing"
    );
    anyhow::ensure!(
        *offsets.last().expect("non-empty") == payload_len,
        "last offset {} does not match payload length {payload_len}",
        offsets.last().expect("non-empty")
    );
    let n_shards = offsets.len() - 1;
    let capacity_ok = if n_records == 0 {
        n_shards == 0
    } else {
        n_records > (n_shards - 1) * shard_size && n_records <= n_shards * shard_size
    };
    anyhow::ensure!(
        capacity_ok,
        "{n_records} records do not fit {n_shards} shards of size {shard_size}"
    );
    Ok(ShardIndex {
        kind,
        n_records,
        shard_size,
        offsets,
    })
}

/// Read the raw blob of shard `s` (a fresh file handle per call, so
/// concurrent pool workers can each stream their own shard).
pub fn read_shard_bytes(path: &Path, index: &ShardIndex, s: usize) -> crate::Result<Vec<u8>> {
    anyhow::ensure!(s < index.n_shards(), "shard {s} out of range");
    let (lo, hi) = (index.offsets[s], index.offsets[s + 1]);
    let mut f =
        File::open(path).with_context(|| format!("opening shard file {}", path.display()))?;
    f.seek(SeekFrom::Start(lo))?;
    let mut buf = vec![0u8; (hi - lo) as usize];
    f.read_exact(&mut buf)
        .with_context(|| format!("reading shard {s} of {}", path.display()))?;
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn locate_covers_shard_size_edges() {
        for (shard_size, n) in [(1usize, 5usize), (2, 5), (3, 5), (5, 5), (4, 13)] {
            let n_shards = (n + shard_size - 1) / shard_size;
            let idx = ShardIndex {
                kind: "I".into(),
                n_records: n,
                shard_size,
                offsets: vec![0; n_shards + 1],
            };
            assert_eq!(idx.n_shards(), n_shards);
            let mut seen = 0usize;
            for s in 0..n_shards {
                assert_eq!(idx.shard_base(s), seen);
                seen += idx.shard_records(s);
            }
            assert_eq!(seen, n);
            for gid in 0..n {
                let (s, local) = idx.locate(gid);
                assert!(s < n_shards && local < idx.shard_records(s));
                assert_eq!(idx.shard_base(s) + local, gid);
            }
        }
    }

    #[test]
    fn footer_round_trips_and_rejects_corruption() {
        let idx = ShardIndex {
            kind: "I".into(),
            n_records: 7,
            shard_size: 3,
            offsets: vec![0, 10, 20, 26],
        };
        let mut footer = String::from("spp-shards v1\n");
        footer.push_str("kind I\nrecords 7\nshard_size 3\n");
        for o in &idx.offsets {
            footer.push_str(&format!("offset {o}\n"));
        }
        assert_eq!(parse_footer(&footer, 26).unwrap(), idx);
        assert!(parse_footer(&footer, 25).is_err(), "payload length mismatch");
        assert!(parse_footer("garbage\n", 0).is_err(), "bad header");
        assert!(
            parse_footer("spp-shards v1\nkind I\nrecords 9\nshard_size 3\noffset 0\n", 0).is_err(),
            "record count exceeding shard capacity"
        );
    }
}
