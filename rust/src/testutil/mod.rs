//! Test utilities: deterministic PRNG, property-test harness, and
//! brute-force oracles used to validate the miners, the solver and the
//! screening rules.
//!
//! The vendored crate set has no `rand`/`proptest`, so this module is
//! self-contained: [`SplitMix64`] provides reproducible streams, and
//! [`for_each_case`] gives proptest-style seed sweeps with readable
//! failure messages (the failing seed is printed, so a case can be
//! replayed in isolation).

pub mod oracle;

/// SplitMix64 — tiny, high-quality 64-bit PRNG (Steele et al. 2014).
///
/// Deterministic across platforms; every generator in `data::synth_*`
/// and every property test derives its stream from one of these.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn coin(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn gauss(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Pick an index according to (unnormalized, non-negative) weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx.sort_unstable();
        idx
    }

    /// Fork an independent stream (for per-record generators).
    pub fn fork(&mut self) -> SplitMix64 {
        SplitMix64::new(self.next_u64() ^ 0xA5A5_5A5A_DEAD_BEEF)
    }
}

/// Run `body` for `cases` derived seeds; on panic, report the seed that
/// failed so the case can be replayed (`SEED=... cargo test`-style).
pub fn for_each_case(base_seed: u64, cases: usize, mut body: impl FnMut(u64, &mut SplitMix64)) {
    for c in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(c as u64);
        let mut rng = SplitMix64::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            body(seed, &mut rng);
        }));
        if let Err(e) = result {
            eprintln!("property case failed: base_seed={base_seed} case={c} seed={seed}");
            std::panic::resume_unwind(e);
        }
    }
}

/// Assert two floats agree to a relative-or-absolute tolerance.
#[macro_export]
macro_rules! assert_close {
    ($a:expr, $b:expr, $tol:expr) => {{
        let (a, b, tol): (f64, f64, f64) = ($a as f64, $b as f64, $tol as f64);
        let scale = 1.0_f64.max(a.abs()).max(b.abs());
        assert!(
            (a - b).abs() <= tol * scale,
            "assert_close failed: {} vs {} (tol {}, scaled {})",
            a,
            b,
            tol,
            tol * scale
        );
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(7);
        let mut b = SplitMix64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_f64_in_unit_interval() {
        let mut rng = SplitMix64::new(1);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_stays_in_range() {
        let mut rng = SplitMix64::new(2);
        for n in 1..50 {
            for _ in 0..100 {
                assert!(rng.below(n) < n);
            }
        }
    }

    #[test]
    fn gauss_has_sane_moments() {
        let mut rng = SplitMix64::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_distinct_is_distinct_and_sorted() {
        let mut rng = SplitMix64::new(4);
        for _ in 0..50 {
            let v = rng.sample_distinct(20, 7);
            assert_eq!(v.len(), 7);
            assert!(v.windows(2).all(|w| w[0] < w[1]));
            assert!(v.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn weighted_respects_zero_weight() {
        let mut rng = SplitMix64::new(5);
        for _ in 0..1000 {
            let i = rng.weighted(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = SplitMix64::new(6);
        let mut v: Vec<usize> = (0..30).collect();
        rng.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..30).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_diverge() {
        let mut rng = SplitMix64::new(8);
        let mut a = rng.fork();
        let mut b = rng.fork();
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
