//! Brute-force oracles: independent, slow implementations used to
//! validate the miners, the screening rules and the solvers on small
//! inputs.  Nothing here shares code with the production paths.

use std::collections::{BTreeMap, BTreeSet};

use crate::data::graph::{Graph, GraphDatabase};
use crate::data::sequence::Sequences;
use crate::data::synth_itemsets::contains_all;
use crate::data::tabular::TabularData;
use crate::data::Transactions;
use crate::mining::rulefit::RulePredicate;

/// Exhaustively enumerate every item-set of size `1..=maxpat` with
/// non-empty support, by direct combination search (no tid-list
/// machinery — deliberately different from the production miner).
pub fn all_itemsets(db: &Transactions, maxpat: usize) -> Vec<(Vec<u32>, Vec<u32>)> {
    let mut out = Vec::new();
    let mut current: Vec<u32> = Vec::new();
    fn rec(
        db: &Transactions,
        maxpat: usize,
        start: u32,
        current: &mut Vec<u32>,
        out: &mut Vec<(Vec<u32>, Vec<u32>)>,
    ) {
        for j in start..db.n_items as u32 {
            current.push(j);
            let support: Vec<u32> = db
                .items
                .iter()
                .enumerate()
                .filter(|(_, row)| contains_all(row, current))
                .map(|(i, _)| i as u32)
                .collect();
            if !support.is_empty() {
                out.push((current.clone(), support));
                if current.len() < maxpat {
                    rec(db, maxpat, j + 1, current, out);
                }
            }
            current.pop();
        }
    }
    rec(db, maxpat, 0, &mut current, &mut out);
    out
}

/// Naive subsequence test by explicit two-pointer scan — deliberately
/// written independently of `data::sequence::is_subsequence`.
fn subseq_naive(haystack: &[u32], needle: &[u32]) -> bool {
    let mut j = 0usize;
    for &h in haystack {
        if j < needle.len() && h == needle[j] {
            j += 1;
        }
    }
    j == needle.len()
}

/// Exhaustively enumerate every subsequence pattern of length
/// `1..=maxpat` with non-empty support, by direct extension over the
/// whole alphabet (no projection machinery — deliberately different
/// from the production PrefixSpan miner).
pub fn all_sequences(db: &Sequences, maxpat: usize) -> BTreeMap<Vec<u32>, Vec<u32>> {
    let mut out = BTreeMap::new();
    let mut current: Vec<u32> = Vec::new();
    fn rec(
        db: &Sequences,
        maxpat: usize,
        current: &mut Vec<u32>,
        out: &mut BTreeMap<Vec<u32>, Vec<u32>>,
    ) {
        for a in 0..db.n_symbols as u32 {
            current.push(a);
            let support: Vec<u32> = db
                .seqs
                .iter()
                .enumerate()
                .filter(|(_, s)| subseq_naive(s, current))
                .map(|(i, _)| i as u32)
                .collect();
            if !support.is_empty() {
                out.insert(current.clone(), support);
                if current.len() < maxpat {
                    rec(db, maxpat, current, out);
                }
            }
            current.pop();
        }
    }
    if maxpat > 0 {
        rec(db, maxpat, &mut current, &mut out);
    }
    out
}

/// Exhaustively enumerate every canonical rule conjunction of length
/// `1..=maxpat` with support `>= minsup` over the predicate universe
/// `preds` (same universe the production miner enumerates; pass
/// `rulefit::predicate_universe(db)`), by direct whole-rule evaluation
/// against every row (no incremental support filtering — deliberately
/// different from the production miner).  Canonical rules extend by
/// strictly increasing universe index and never repeat a
/// `(feature, direction)` pair, mirroring the miner's definition.
pub fn all_rules(
    db: &TabularData,
    maxpat: usize,
    minsup: usize,
    preds: &[RulePredicate],
) -> BTreeMap<Vec<RulePredicate>, Vec<u32>> {
    let mut out = BTreeMap::new();
    let mut current: Vec<RulePredicate> = Vec::new();
    #[allow(clippy::too_many_arguments)]
    fn rec(
        db: &TabularData,
        maxpat: usize,
        minsup: usize,
        preds: &[RulePredicate],
        start: usize,
        current: &mut Vec<RulePredicate>,
        out: &mut BTreeMap<Vec<RulePredicate>, Vec<u32>>,
    ) {
        for pid in start..preds.len() {
            let p = preds[pid];
            if current.iter().any(|q| q.feature == p.feature && q.op == p.op) {
                continue;
            }
            current.push(p);
            let support: Vec<u32> = db
                .rows
                .iter()
                .enumerate()
                .filter(|(_, row)| current.iter().all(|q| q.eval(row)))
                .map(|(i, _)| i as u32)
                .collect();
            if support.len() >= minsup.max(1) {
                out.insert(current.clone(), support);
                if current.len() < maxpat {
                    rec(db, maxpat, minsup, preds, pid + 1, current, out);
                }
            }
            current.pop();
        }
    }
    if maxpat > 0 {
        rec(db, maxpat, minsup, preds, 0, &mut current, &mut out);
    }
    out
}

/// Canonical string of a small labeled graph: lexicographically minimal
/// `(vlabels under π, sorted relabeled edges)` over all vertex
/// permutations π.  Exponential — test-sized graphs only.
pub fn canonical_form(g: &Graph) -> String {
    let k = g.n_vertices();
    let mut perm: Vec<usize> = (0..k).collect();
    let mut best: Option<String> = None;
    permute(&mut perm, 0, &mut |p| {
        let mut inv = vec![0usize; k];
        for (new, &old) in p.iter().enumerate() {
            inv[old] = new;
        }
        let vl: Vec<String> = p.iter().map(|&old| g.vlabels[old].to_string()).collect();
        let mut edges: Vec<(usize, usize, u32)> = g
            .edges
            .iter()
            .map(|&(u, v, l)| {
                let (a, b) = (inv[u as usize], inv[v as usize]);
                (a.min(b), a.max(b), l)
            })
            .collect();
        edges.sort_unstable();
        let s = format!("V{};E{:?}", vl.join(","), edges);
        if best.as_ref().map_or(true, |b| s < *b) {
            best = Some(s);
        }
    });
    best.unwrap_or_else(|| "V;E[]".to_string())
}

fn permute(perm: &mut [usize], i: usize, f: &mut impl FnMut(&[usize])) {
    if i == perm.len() {
        f(perm);
        return;
    }
    for j in i..perm.len() {
        perm.swap(i, j);
        permute(perm, i + 1, f);
        perm.swap(i, j);
    }
}

/// Connected edge-subsets of `g` with `1..=max_edges` edges, as induced
/// labeled subgraphs.
fn connected_subgraphs(g: &Graph, max_edges: usize) -> Vec<Graph> {
    let n_e = g.n_edges();
    let mut seen: BTreeSet<u64> = BTreeSet::new();
    let mut frontier: Vec<u64> = Vec::new();
    for e in 0..n_e {
        let m = 1u64 << e;
        if seen.insert(m) {
            frontier.push(m);
        }
    }
    let mut all: Vec<u64> = frontier.clone();
    for _size in 1..max_edges {
        let mut next = Vec::new();
        for &mask in &frontier {
            // vertices touched by mask
            let mut verts = BTreeSet::new();
            for e in 0..n_e {
                if mask >> e & 1 == 1 {
                    let (u, v, _) = g.edges[e];
                    verts.insert(u);
                    verts.insert(v);
                }
            }
            for e in 0..n_e {
                if mask >> e & 1 == 0 {
                    let (u, v, _) = g.edges[e];
                    if verts.contains(&u) || verts.contains(&v) {
                        let m2 = mask | 1 << e;
                        if seen.insert(m2) {
                            next.push(m2);
                        }
                    }
                }
            }
        }
        all.extend_from_slice(&next);
        frontier = next;
    }
    // materialize induced subgraphs
    all.iter()
        .map(|&mask| {
            let mut vmap: BTreeMap<u32, u32> = BTreeMap::new();
            let mut sub = Graph::new();
            for e in 0..n_e {
                if mask >> e & 1 == 1 {
                    let (u, v, _) = g.edges[e];
                    for &x in &[u, v] {
                        vmap.entry(x).or_insert_with(|| {
                            sub.add_vertex(g.vlabels[x as usize])
                        });
                    }
                }
            }
            for e in 0..n_e {
                if mask >> e & 1 == 1 {
                    let (u, v, l) = g.edges[e];
                    sub.add_edge(vmap[&u], vmap[&v], l);
                }
            }
            sub
        })
        .collect()
}

/// Exhaustive canonical subgraph enumeration over a database: canonical
/// form → sorted list of supporting graph ids.
pub fn all_subgraphs_canonical(db: &GraphDatabase, max_edges: usize) -> BTreeMap<String, Vec<u32>> {
    let mut out: BTreeMap<String, BTreeSet<u32>> = BTreeMap::new();
    for (gid, g) in db.graphs.iter().enumerate() {
        let mut local: BTreeSet<String> = BTreeSet::new();
        for sub in connected_subgraphs(g, max_edges) {
            local.insert(canonical_form(&sub));
        }
        for c in local {
            out.entry(c).or_default().insert(gid as u32);
        }
    }
    out.into_iter()
        .map(|(k, v)| (k, v.into_iter().collect()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_itemsets_tiny() {
        let db = Transactions {
            n_items: 3,
            items: vec![vec![0, 1], vec![1, 2]],
        };
        let got = all_itemsets(&db, 2);
        // {0}:[0] {0,1}:[0] {1}:[0,1] {1,2}:[1] {2}:[1]
        assert_eq!(got.len(), 5);
        let m: BTreeMap<Vec<u32>, Vec<u32>> = got.into_iter().collect();
        assert_eq!(m[&vec![1u32]], vec![0, 1]);
        assert_eq!(m[&vec![0u32, 1]], vec![0]);
    }

    #[test]
    fn all_sequences_tiny() {
        let db = Sequences {
            n_symbols: 3,
            seqs: vec![vec![0, 1], vec![1, 1]],
        };
        let got = all_sequences(&db, 2);
        // <0>:[0] <0,1>:[0] <1>:[0,1] <1,1>:[1]
        assert_eq!(got.len(), 4);
        assert_eq!(got[&vec![1u32]], vec![0, 1]);
        assert_eq!(got[&vec![1u32, 1]], vec![1]);
        assert_eq!(got[&vec![0u32, 1]], vec![0]);
        assert!(all_sequences(&db, 0).is_empty());
    }

    #[test]
    fn all_rules_tiny() {
        use crate::mining::rulefit::{predicate_universe, RuleOp};
        let db = TabularData::new(1, vec![vec![0.0], vec![1.0]]);
        let preds = predicate_universe(&db);
        // one cut at 0.5, both directions
        assert_eq!(preds.len(), 2);
        let got = all_rules(&db, 2, 1, &preds);
        // x0<=0.5:[0]  x0>0.5:[1]  (their conjunction has empty support)
        assert_eq!(got.len(), 2);
        assert_eq!(got[&vec![RulePredicate::new(0, RuleOp::Le, 0.5)]], vec![0]);
        assert_eq!(got[&vec![RulePredicate::new(0, RuleOp::Gt, 0.5)]], vec![1]);
        assert!(all_rules(&db, 0, 1, &preds).is_empty());
    }

    #[test]
    fn canonical_form_is_isomorphism_invariant() {
        // path 0-1-2 labeled (5,6,7) in two different vertex orders
        let mut g1 = Graph::new();
        g1.add_vertex(5);
        g1.add_vertex(6);
        g1.add_vertex(7);
        g1.add_edge(0, 1, 0);
        g1.add_edge(1, 2, 1);
        let mut g2 = Graph::new();
        g2.add_vertex(7);
        g2.add_vertex(5);
        g2.add_vertex(6);
        g2.add_edge(2, 0, 1);
        g2.add_edge(1, 2, 0);
        assert_eq!(canonical_form(&g1), canonical_form(&g2));

        // different edge label => different form
        let mut g3 = g1.clone();
        g3.edges[1].2 = 2;
        assert_ne!(canonical_form(&g1), canonical_form(&g3));
    }

    #[test]
    fn connected_subgraphs_of_triangle() {
        let mut g = Graph::new();
        for _ in 0..3 {
            g.add_vertex(0);
        }
        g.add_edge(0, 1, 0);
        g.add_edge(1, 2, 0);
        g.add_edge(0, 2, 0);
        // 3 single edges, 3 two-edge paths, 1 triangle
        assert_eq!(connected_subgraphs(&g, 3).len(), 7);
        assert_eq!(connected_subgraphs(&g, 1).len(), 3);
    }

    #[test]
    fn subgraph_canonical_supports() {
        let mut db = GraphDatabase::default();
        for _ in 0..2 {
            let mut g = Graph::new();
            g.add_vertex(1);
            g.add_vertex(2);
            g.add_edge(0, 1, 0);
            db.graphs.push(g);
            db.y.push(0.0);
        }
        let m = all_subgraphs_canonical(&db, 1);
        assert_eq!(m.len(), 1);
        assert_eq!(m.values().next().unwrap(), &vec![0, 1]);
    }
}
