//! Hybrid sparse/bitset support columns.
//!
//! Every hot kernel in the crate folds a vector over a support column —
//! a sorted, duplicate-free list of `u32` record ids: the SPPC /
//! Lemma-6 bounds ([`crate::screening::sppc`]), the per-check dynamic
//! screening and CD epochs ([`crate::solver::cd`]), the dual box
//! ([`crate::solver::dual`]), and the child-support intersections of
//! the itemset miner ([`crate::mining::itemset`]).  A flat `Vec<u32>`
//! walk is optimal for *rare* patterns but wasteful for *dense* ones
//! (a pattern supported by half the records touches `n/2` ids, 4 bytes
//! each, with a data-dependent gather per id).
//!
//! [`HybridColumn`] stores a column in roaring-style fixed-width
//! chunks: each chunk covers [`CHUNK_SPAN`] = 4096 consecutive record
//! ids, and a chunk holding at least [`DENSE_CUTOFF`] = 256 of them
//! additionally materializes a 64-word bitmap (64 × 64 = 4096 bits).
//! The sorted id list is **always kept** alongside the bitmap — it is
//! the canonical view (`ids()`), so every consumer that wants a
//! `&[u32]` (pattern nodes, matchers, codecs, scatter loops) keeps
//! working unchanged; the words are an acceleration index for the fold
//! and intersection kernels.  A dense chunk costs 512 extra bytes per
//! 4096-id span — at the ≥ 256-id cutoff that is ≤ 0.5 bytes per id of
//! overhead against the 4-byte id it accelerates.
//!
//! ## Bit-identity
//!
//! The kernels here are drop-in replacements for the scalar loops, not
//! approximations: iterating a word's set bits LSB-first
//! (`trailing_zeros`, then `bits &= bits - 1`) over ascending words and
//! chunks visits record ids in exactly the ascending order the scalar
//! `for &i in ids` loop uses, so every floating-point accumulation
//! performs the *same additions in the same order* and the results are
//! bit-identical, layout notwithstanding.  Set intersections are exact
//! integer operations.  The scalar layout therefore stays alive as the
//! test oracle behind the [`ColumnLayout`] knob (`SPP_COLUMNS`), and
//! `tests/integration_columns.rs` (plus the tabular cross in
//! `tests/integration_tabular.rs`) pins sparse-vs-hybrid bit-identity
//! end to end per substrate.

/// Record ids covered by one chunk (4096 = 64 words × 64 bits).
pub const CHUNK_SPAN: u32 = 4096;
/// Bitmap words per dense chunk.
pub const WORDS_PER_CHUNK: usize = 64;
/// A chunk with at least this many ids gets a bitmap (≥ 1/16 density).
pub const DENSE_CUTOFF: usize = 256;

/// Storage layout for interned support columns (the `SPP_COLUMNS`
/// knob): `Sparse` keeps plain sorted id lists — the scalar reference
/// the differential tests treat as the oracle — while `Hybrid` (the
/// default) adds bitmap words to dense chunks so the fold and
/// intersection kernels run over 64-bit words.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ColumnLayout {
    /// Plain sorted `Vec<u32>` columns (the scalar oracle).
    Sparse,
    /// Chunked sparse/bitset columns (vectorized kernels).
    #[default]
    Hybrid,
}

/// Resolve the column-layout knob: an explicit request wins; `None`
/// means auto — the `SPP_COLUMNS` environment variable if set to
/// `sparse` or `hybrid`, else [`ColumnLayout::Hybrid`].  Mirrors
/// [`crate::screening::range::resolve_range_chunk`], and CI's
/// test-matrix uses the env form to run the whole suite under both
/// layouts.
pub fn resolve_columns(requested: Option<ColumnLayout>) -> ColumnLayout {
    if let Some(layout) = requested {
        return layout;
    }
    if let Ok(v) = std::env::var("SPP_COLUMNS") {
        match v.trim() {
            "sparse" => return ColumnLayout::Sparse,
            "hybrid" => return ColumnLayout::Hybrid,
            _ => {}
        }
    }
    ColumnLayout::Hybrid
}

/// One span of 4096 record ids: `ids[start..end]` of the owning column,
/// plus the bitmap words when the span is dense enough.
#[derive(Clone, Debug)]
struct Chunk {
    /// `id >> 12` shared by every id in the chunk.
    base: u32,
    /// Start of the chunk's ids in the column's id list.
    start: u32,
    /// End (exclusive) of the chunk's ids in the column's id list.
    end: u32,
    /// Bitmap of the chunk's ids, present iff `end - start >=
    /// DENSE_CUTOFF` (bit `b` of word `w` ⇔ id `base·4096 + w·64 + b`).
    words: Option<Box<[u64; WORDS_PER_CHUNK]>>,
}

/// A support column in the hybrid layout (module docs): the canonical
/// sorted id list plus a chunk index with bitmap words on dense spans.
#[derive(Clone, Debug, Default)]
pub struct HybridColumn {
    ids: Vec<u32>,
    chunks: Vec<Chunk>,
}

impl PartialEq for HybridColumn {
    /// Column equality is id-set equality; the chunk index is derived
    /// deterministically from the ids.
    fn eq(&self, other: &Self) -> bool {
        self.ids == other.ids
    }
}

impl Eq for HybridColumn {}

fn build_chunks(ids: &[u32]) -> Vec<Chunk> {
    let mut chunks = Vec::new();
    let mut i = 0usize;
    while i < ids.len() {
        let base = ids[i] >> 12;
        let mut j = i + 1;
        while j < ids.len() && ids[j] >> 12 == base {
            j += 1;
        }
        let words = if j - i >= DENSE_CUTOFF {
            let mut w = Box::new([0u64; WORDS_PER_CHUNK]);
            for &id in &ids[i..j] {
                let off = (id & (CHUNK_SPAN - 1)) as usize;
                w[off >> 6] |= 1u64 << (off & 63);
            }
            Some(w)
        } else {
            None
        };
        chunks.push(Chunk { base, start: i as u32, end: j as u32, words });
        i = j;
    }
    chunks
}

impl HybridColumn {
    /// Build from a strictly increasing id list (every support column
    /// in the crate is one; checked in debug builds).
    pub fn from_sorted(ids: Vec<u32>) -> Self {
        debug_assert!(ids.windows(2).all(|w| w[0] < w[1]), "ids must be strictly increasing");
        let chunks = build_chunks(&ids);
        Self { ids, chunks }
    }

    /// The canonical sorted id list.
    #[inline]
    pub fn ids(&self) -> &[u32] {
        &self.ids
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.ids.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    /// Accounted heap bytes of this column: the id list, the chunk
    /// index, and one bitmap block per dense chunk.  What the pool's
    /// spill tier charges against `--memory-budget`.
    pub fn heap_bytes(&self) -> usize {
        let dense = self.chunks.iter().filter(|c| c.words.is_some()).count();
        self.ids.len() * std::mem::size_of::<u32>()
            + self.chunks.len() * std::mem::size_of::<Chunk>()
            + dense * WORDS_PER_CHUNK * std::mem::size_of::<u64>()
    }

    /// Membership test: bitmap word probe on dense chunks, binary
    /// search on sparse ones.
    pub fn contains(&self, id: u32) -> bool {
        let base = id >> 12;
        let Ok(c) = self.chunks.binary_search_by_key(&base, |c| c.base) else {
            return false;
        };
        let c = &self.chunks[c];
        match &c.words {
            Some(words) => {
                let off = (id & (CHUNK_SPAN - 1)) as usize;
                words[off >> 6] & (1u64 << (off & 63)) != 0
            }
            None => self.ids[c.start as usize..c.end as usize].binary_search(&id).is_ok(),
        }
    }

    /// `Σ_{i∈col} g_i`, bit-identical to the scalar ascending-id sum
    /// (module docs): dense chunks walk bitmap words LSB-first, with a
    /// contiguous-slice sum on full words.
    pub fn dot_words(&self, g: &[f64]) -> f64 {
        let mut acc = 0.0f64;
        for c in &self.chunks {
            match &c.words {
                Some(words) => {
                    let lo = (c.base as usize) << 12;
                    for (w, &word) in words.iter().enumerate() {
                        if word == 0 {
                            continue;
                        }
                        let row = lo + (w << 6);
                        if word == u64::MAX {
                            for &gi in &g[row..row + 64] {
                                acc += gi;
                            }
                        } else {
                            let mut bits = word;
                            while bits != 0 {
                                acc += g[row + bits.trailing_zeros() as usize];
                                bits &= bits - 1;
                            }
                        }
                    }
                }
                None => {
                    for &i in &self.ids[c.start as usize..c.end as usize] {
                        acc += g[i as usize];
                    }
                }
            }
        }
        acc
    }

    /// `(Σ max(g_i,0), Σ min(g_i,0))` over the column, bit-identical to
    /// the scalar ascending-id fold used by the SPPC bounds
    /// ([`crate::screening::sppc`]).
    pub fn fold_signed_words(&self, g: &[f64]) -> (f64, f64) {
        let mut pos = 0.0f64;
        let mut neg = 0.0f64;
        for c in &self.chunks {
            match &c.words {
                Some(words) => {
                    let lo = (c.base as usize) << 12;
                    for (w, &word) in words.iter().enumerate() {
                        if word == 0 {
                            continue;
                        }
                        let row = lo + (w << 6);
                        if word == u64::MAX {
                            for &gi in &g[row..row + 64] {
                                pos += gi.max(0.0);
                                neg += gi.min(0.0);
                            }
                        } else {
                            let mut bits = word;
                            while bits != 0 {
                                let gi = g[row + bits.trailing_zeros() as usize];
                                pos += gi.max(0.0);
                                neg += gi.min(0.0);
                                bits &= bits - 1;
                            }
                        }
                    }
                }
                None => {
                    for &i in &self.ids[c.start as usize..c.end as usize] {
                        let gi = g[i as usize];
                        pos += gi.max(0.0);
                        neg += gi.min(0.0);
                    }
                }
            }
        }
        (pos, neg)
    }

    /// Intersect `a ∩ b` into `out` (reusing its buffers).  Chunk pairs
    /// dispatch on density: dense×dense is a 64-word AND with LSB-first
    /// id emission, dense×sparse probes the bitmap per id, and
    /// sparse×sparse is a linear merge.  The output is a well-formed
    /// hybrid column (sorted ids, dense chunks re-detected from the
    /// intersection's own counts).
    pub fn intersect_into(a: &Self, b: &Self, out: &mut Self) {
        out.ids.clear();
        out.chunks.clear();
        let (mut ia, mut ib) = (0usize, 0usize);
        while ia < a.chunks.len() && ib < b.chunks.len() {
            let ca = &a.chunks[ia];
            let cb = &b.chunks[ib];
            if ca.base < cb.base {
                ia += 1;
                continue;
            }
            if cb.base < ca.base {
                ib += 1;
                continue;
            }
            let start = out.ids.len();
            let mut dense_words: Option<[u64; WORDS_PER_CHUNK]> = None;
            match (&ca.words, &cb.words) {
                (Some(wa), Some(wb)) => {
                    let lo = (ca.base << 12) as usize;
                    let mut words = [0u64; WORDS_PER_CHUNK];
                    for (w, (slot, (&ba, &bb))) in
                        words.iter_mut().zip(wa.iter().zip(wb.iter())).enumerate()
                    {
                        let mut bits = ba & bb;
                        *slot = bits;
                        let row = (lo + (w << 6)) as u32;
                        while bits != 0 {
                            out.ids.push(row + bits.trailing_zeros());
                            bits &= bits - 1;
                        }
                    }
                    dense_words = Some(words);
                }
                (Some(wa), None) => {
                    for &id in &b.ids[cb.start as usize..cb.end as usize] {
                        let off = (id & (CHUNK_SPAN - 1)) as usize;
                        if wa[off >> 6] & (1u64 << (off & 63)) != 0 {
                            out.ids.push(id);
                        }
                    }
                }
                (None, Some(wb)) => {
                    for &id in &a.ids[ca.start as usize..ca.end as usize] {
                        let off = (id & (CHUNK_SPAN - 1)) as usize;
                        if wb[off >> 6] & (1u64 << (off & 63)) != 0 {
                            out.ids.push(id);
                        }
                    }
                }
                (None, None) => {
                    let sa = &a.ids[ca.start as usize..ca.end as usize];
                    let sb = &b.ids[cb.start as usize..cb.end as usize];
                    let (mut x, mut y) = (0usize, 0usize);
                    while x < sa.len() && y < sb.len() {
                        match sa[x].cmp(&sb[y]) {
                            std::cmp::Ordering::Less => x += 1,
                            std::cmp::Ordering::Greater => y += 1,
                            std::cmp::Ordering::Equal => {
                                out.ids.push(sa[x]);
                                x += 1;
                                y += 1;
                            }
                        }
                    }
                }
            }
            let count = out.ids.len() - start;
            if count > 0 {
                let words = match dense_words {
                    Some(words) if count >= DENSE_CUTOFF => Some(Box::new(words)),
                    _ => None,
                };
                out.chunks.push(Chunk {
                    base: ca.base,
                    start: start as u32,
                    end: out.ids.len() as u32,
                    words,
                });
            }
            ia += 1;
            ib += 1;
        }
    }
}

/// Read-only access to a support column, however it is stored.
///
/// The one required method is [`ColumnRead::ids`] — the sorted record
/// ids — and every default is the scalar reference loop over it, in
/// ascending-id order.  [`HybridColumn`] (and hybrid
/// [`ColumnView`]s) override the folds with the word kernels, which
/// visit ids in the *same* order, so generic consumers — the CD
/// solver, the dual box, the engines' densify loops — are bit-identical
/// across layouts by construction.
///
/// Implemented explicitly (not via a blanket `AsRef<[u32]>` impl, which
/// would conflict with the view types under coherence) for exactly the
/// column carriers the crate uses — `[u32]`, `Vec<u32>`,
/// [`HybridColumn`], [`ColumnView`] — plus a delegating impl for
/// references, so `&[u32]` / `&HybridColumn` element types work in
/// generic `&[S]` positions.
pub trait ColumnRead {
    /// The column's sorted record ids.
    fn ids(&self) -> &[u32];

    /// Number of supporting records (`v_t` in the paper's bounds).
    #[inline]
    fn len(&self) -> usize {
        self.ids().len()
    }

    #[inline]
    fn is_empty(&self) -> bool {
        self.ids().is_empty()
    }

    /// Visit each record id (as `usize`) in ascending order — the
    /// scatter side of the CD update and the engines' densify loops.
    #[inline]
    fn for_each_id<F: FnMut(usize)>(&self, mut f: F) {
        for &i in self.ids() {
            f(i as usize);
        }
    }

    /// `Σ_{i∈col} g_i` (ascending-id accumulation).
    #[inline]
    fn dot(&self, g: &[f64]) -> f64 {
        let mut acc = 0.0f64;
        for &i in self.ids() {
            acc += g[i as usize];
        }
        acc
    }

    /// `(Σ max(g_i,0), Σ min(g_i,0))` — the SPPC sign-split fold.
    #[inline]
    fn fold_signed(&self, g: &[f64]) -> (f64, f64) {
        let mut pos = 0.0f64;
        let mut neg = 0.0f64;
        for &i in self.ids() {
            let gi = g[i as usize];
            pos += gi.max(0.0);
            neg += gi.min(0.0);
        }
        (pos, neg)
    }
}

impl ColumnRead for [u32] {
    #[inline]
    fn ids(&self) -> &[u32] {
        self
    }
}

impl ColumnRead for Vec<u32> {
    #[inline]
    fn ids(&self) -> &[u32] {
        self
    }
}

/// References delegate every method (including the overridden word
/// kernels) to the referent.
impl<C: ColumnRead + ?Sized> ColumnRead for &C {
    #[inline]
    fn ids(&self) -> &[u32] {
        (**self).ids()
    }

    #[inline]
    fn len(&self) -> usize {
        (**self).len()
    }

    #[inline]
    fn is_empty(&self) -> bool {
        (**self).is_empty()
    }

    #[inline]
    fn for_each_id<F: FnMut(usize)>(&self, f: F) {
        (**self).for_each_id(f)
    }

    #[inline]
    fn dot(&self, g: &[f64]) -> f64 {
        (**self).dot(g)
    }

    #[inline]
    fn fold_signed(&self, g: &[f64]) -> (f64, f64) {
        (**self).fold_signed(g)
    }
}

impl ColumnRead for HybridColumn {
    #[inline]
    fn ids(&self) -> &[u32] {
        self.ids()
    }

    #[inline]
    fn dot(&self, g: &[f64]) -> f64 {
        self.dot_words(g)
    }

    #[inline]
    fn fold_signed(&self, g: &[f64]) -> (f64, f64) {
        self.fold_signed_words(g)
    }
}

/// Borrowed view of one interned column, whatever the pool's layout —
/// what [`crate::screening::pool::SupportPool::view`] hands the
/// restricted solvers.  Equality is id-set equality across variants.
#[derive(Clone, Copy, Debug)]
pub enum ColumnView<'a> {
    /// A plain sorted id slice.
    Sparse(&'a [u32]),
    /// A chunked sparse/bitset column.
    Hybrid(&'a HybridColumn),
}

impl ColumnRead for ColumnView<'_> {
    #[inline]
    fn ids(&self) -> &[u32] {
        match self {
            ColumnView::Sparse(ids) => ids,
            ColumnView::Hybrid(col) => col.ids(),
        }
    }

    #[inline]
    fn dot(&self, g: &[f64]) -> f64 {
        match self {
            ColumnView::Sparse(ids) => ids.dot(g),
            ColumnView::Hybrid(col) => col.dot_words(g),
        }
    }

    #[inline]
    fn fold_signed(&self, g: &[f64]) -> (f64, f64) {
        match self {
            ColumnView::Sparse(ids) => ids.fold_signed(g),
            ColumnView::Hybrid(col) => col.fold_signed_words(g),
        }
    }
}

impl PartialEq for ColumnView<'_> {
    fn eq(&self, other: &Self) -> bool {
        self.ids() == other.ids()
    }
}

impl Eq for ColumnView<'_> {}

/// A transaction-id set the itemset miner can build, grow and
/// intersect — `Vec<u32>` (the scalar oracle, via the galloping merge
/// in [`crate::mining::itemset::intersect_into`]) or [`HybridColumn`]
/// (chunked word kernels).  `ids()` keeps the miner's pattern nodes on
/// plain sorted slices either way.
pub trait TidSet: Default {
    /// Build from a strictly increasing id list.
    fn from_sorted(ids: Vec<u32>) -> Self;

    /// The sorted record ids.
    fn ids(&self) -> &[u32];

    #[inline]
    fn len(&self) -> usize {
        self.ids().len()
    }

    #[inline]
    fn is_empty(&self) -> bool {
        self.ids().is_empty()
    }

    /// Reset to the empty set, keeping buffers for reuse.
    fn clear(&mut self);

    /// Intersect `a ∩ b` into `out` (clears `out` first).
    fn intersect(a: &Self, b: &Self, out: &mut Self);
}

impl TidSet for HybridColumn {
    #[inline]
    fn from_sorted(ids: Vec<u32>) -> Self {
        HybridColumn::from_sorted(ids)
    }

    #[inline]
    fn ids(&self) -> &[u32] {
        HybridColumn::ids(self)
    }

    #[inline]
    fn clear(&mut self) {
        self.ids.clear();
        self.chunks.clear();
    }

    #[inline]
    fn intersect(a: &Self, b: &Self, out: &mut Self) {
        HybridColumn::intersect_into(a, b, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::SplitMix64;

    /// Scalar references the kernels must match bit-for-bit.
    fn scalar_dot(ids: &[u32], g: &[f64]) -> f64 {
        let mut acc = 0.0f64;
        for &i in ids {
            acc += g[i as usize];
        }
        acc
    }

    fn scalar_fold(ids: &[u32], g: &[f64]) -> (f64, f64) {
        let mut pos = 0.0f64;
        let mut neg = 0.0f64;
        for &i in ids {
            let gi = g[i as usize];
            pos += gi.max(0.0);
            neg += gi.min(0.0);
        }
        (pos, neg)
    }

    fn scalar_intersect(a: &[u32], b: &[u32]) -> Vec<u32> {
        a.iter().filter(|i| b.binary_search(i).is_ok()).copied().collect()
    }

    fn random_ids(rng: &mut SplitMix64, n: usize, m: usize) -> Vec<u32> {
        let mut ids: Vec<u32> = rng.sample_distinct(n, m).into_iter().map(|i| i as u32).collect();
        ids.sort_unstable();
        ids
    }

    #[test]
    fn explicit_layout_request_wins() {
        assert_eq!(resolve_columns(Some(ColumnLayout::Sparse)), ColumnLayout::Sparse);
        assert_eq!(resolve_columns(Some(ColumnLayout::Hybrid)), ColumnLayout::Hybrid);
        // the None arm resolves through SPP_COLUMNS (exercised by CI's
        // test-matrix); its default is pinned by the type default
        assert_eq!(ColumnLayout::default(), ColumnLayout::Hybrid);
    }

    #[test]
    fn boundary_sizes_round_trip() {
        // sizes straddling word and chunk boundaries, incl. the dense
        // cutoff and the one-past-a-chunk cases
        for m in [0usize, 1, 63, 64, 65, 255, 256, 257, 4095, 4096, 4097] {
            let ids: Vec<u32> = (0..m as u32).collect();
            let col = HybridColumn::from_sorted(ids.clone());
            assert_eq!(col.ids(), &ids[..], "m={m}");
            assert_eq!(col.len(), m);
            assert_eq!(col.is_empty(), m == 0);
            for &i in &ids {
                assert!(col.contains(i), "m={m} missing {i}");
            }
            assert!(!col.contains(m as u32 + CHUNK_SPAN));
        }
    }

    #[test]
    fn one_id_per_chunk_stays_sparse_and_sorted() {
        let ids: Vec<u32> = (0..10u32).map(|c| c * CHUNK_SPAN + 7).collect();
        let col = HybridColumn::from_sorted(ids.clone());
        assert_eq!(col.ids(), &ids[..]);
        for &i in &ids {
            assert!(col.contains(i));
            assert!(!col.contains(i + 1));
        }
    }

    #[test]
    fn folds_are_bit_identical_to_scalar() {
        let mut rng = SplitMix64::new(41);
        let n = 3 * CHUNK_SPAN as usize + 137; // straddles chunk edges
        let g: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
        for m in [0usize, 1, 63, 64, 65, 300, 1000, n / 2, n - 1, n] {
            let ids = random_ids(&mut rng, n, m);
            let col = HybridColumn::from_sorted(ids.clone());
            assert_eq!(col.dot_words(&g).to_bits(), scalar_dot(&ids, &g).to_bits(), "dot m={m}");
            let (p, q) = col.fold_signed_words(&g);
            let (sp, sq) = scalar_fold(&ids, &g);
            assert_eq!((p.to_bits(), q.to_bits()), (sp.to_bits(), sq.to_bits()), "fold m={m}");
            // trait dispatch hits the word kernels too
            assert_eq!(ColumnRead::dot(&col, &g).to_bits(), scalar_dot(&ids, &g).to_bits());
            assert_eq!(ColumnRead::fold_signed(&col, &g), (sp, sq));
        }
    }

    #[test]
    fn full_word_fast_path_is_bit_identical() {
        // an all-records column exercises the word == u64::MAX slice sum
        let mut rng = SplitMix64::new(43);
        let n = CHUNK_SPAN as usize + 64;
        let g: Vec<f64> = (0..n).map(|_| rng.gauss() * 3.0).collect();
        let ids: Vec<u32> = (0..n as u32).collect();
        let col = HybridColumn::from_sorted(ids.clone());
        assert_eq!(col.dot_words(&g).to_bits(), scalar_dot(&ids, &g).to_bits());
        let (p, q) = col.fold_signed_words(&g);
        let (sp, sq) = scalar_fold(&ids, &g);
        assert_eq!((p.to_bits(), q.to_bits()), (sp.to_bits(), sq.to_bits()));
    }

    #[test]
    fn intersections_match_scalar_across_density_mix() {
        let mut rng = SplitMix64::new(47);
        let n = 2 * CHUNK_SPAN as usize + 511;
        // densities chosen to produce dense×dense, dense×sparse and
        // sparse×sparse chunk pairs
        let sizes = [3usize, 100, 700, n / 2, n];
        let mut out = HybridColumn::default();
        for &ma in &sizes {
            for &mb in &sizes {
                let a = random_ids(&mut rng, n, ma);
                let b = random_ids(&mut rng, n, mb);
                let want = scalar_intersect(&a, &b);
                let ca = HybridColumn::from_sorted(a);
                let cb = HybridColumn::from_sorted(b);
                HybridColumn::intersect_into(&ca, &cb, &mut out);
                assert_eq!(out.ids(), &want[..], "ma={ma} mb={mb}");
                // the output is a well-formed column: membership agrees
                for &i in &want {
                    assert!(out.contains(i));
                }
            }
        }
    }

    #[test]
    fn column_view_equality_is_id_equality() {
        let col = HybridColumn::from_sorted(vec![1, 2, 3]);
        let ids = [1u32, 2, 3];
        assert_eq!(ColumnView::Hybrid(&col), ColumnView::Sparse(&ids[..]));
        assert_ne!(ColumnView::Sparse(&ids[..1]), ColumnView::Sparse(&ids[..]));
    }

    #[test]
    fn tidset_hybrid_intersects_and_clears() {
        let a = HybridColumn::from_sorted(vec![0, 5, 9, 4096]);
        let b = HybridColumn::from_sorted(vec![5, 9, 4095, 4096]);
        let mut out = HybridColumn::default();
        TidSet::intersect(&a, &b, &mut out);
        assert_eq!(TidSet::ids(&out), &[5, 9, 4096]);
        out.clear();
        assert!(TidSet::is_empty(&out));
    }
}
